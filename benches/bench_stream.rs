//! Streaming per-tick update vs full recompute — the tentpole speedup.
//!
//! Per tick, the incremental path does an O(n²) rank-2 update of the
//! Pearson sufficient statistics plus an O(n²) correlation extraction;
//! the baseline recomputes pearson_correlation on the window contents,
//! O(n²·L). At L=256 the asymptotic gap is ~L/2; the acceptance bar is
//! ≥5× at n=500.
//!
//!     cargo bench --bench bench_stream
//! Env: BENCH_REPS, BENCH_WARMUP (see util::bench).

use tmfg::data::corr::pearson_correlation;
use tmfg::stream::SlidingWindow;
use tmfg::util::bench::BenchSuite;
use tmfg::util::rng::Rng;

fn main() {
    let l: usize = std::env::var("BENCH_WINDOW")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(256);
    let mut suite = BenchSuite::new("stream");
    let mut speedups = Vec::new();
    for &n in &[100usize, 500, 1000] {
        let mut rng = Rng::new(n as u64);
        let mut sample = vec![0.0f32; n];
        let mut w = SlidingWindow::new(n, l, 0);
        for _ in 0..l {
            for v in sample.iter_mut() {
                *v = rng.next_gaussian() as f32;
            }
            w.push(&sample);
        }

        let incremental = suite
            .meta("n", &n.to_string())
            .meta("window", &l.to_string())
            .meta("mode", "incremental")
            .run(&format!("tick/incremental/n{n}"), |_| {
                for v in sample.iter_mut() {
                    *v = rng.next_gaussian() as f32;
                }
                w.push(&sample);
                let s = w.corr_matrix();
                assert_eq!(s.rows, n);
            })
            .mean;

        let full = suite
            .meta("n", &n.to_string())
            .meta("window", &l.to_string())
            .meta("mode", "full-recompute")
            .run(&format!("tick/full-recompute/n{n}"), |_| {
                for v in sample.iter_mut() {
                    *v = rng.next_gaussian() as f32;
                }
                w.push(&sample);
                let panel = w.contents();
                let s = pearson_correlation(&panel);
                assert_eq!(s.rows, n);
            })
            .mean;

        let speedup = full / incremental.max(1e-12);
        speedups.push((n, speedup));
        println!("n={n} L={l}: per-tick incremental speedup {speedup:.1}x\n");
    }
    println!("== per-tick speedup summary (L={l}, ΔL=1) ==");
    for (n, s) in &speedups {
        println!("n={n:5}: {s:.1}x");
    }
    suite.write_csv().unwrap();
    // Machine-readable artifact (results/BENCH_stream.json) with
    // median/p50/p95/p99 + peak RSS, asserted by the CI smoke step.
    suite.write_json().unwrap();
}
