//! APSP benchmarks (§4.3 / §5.1): exact parallel Dijkstra vs the
//! approximate hub-based algorithm, on TMFGs of the largest datasets.
//! The paper reports a 2–3× speedup for approximate APSP.

use tmfg::apsp::{apsp_exact, apsp_hub, CsrGraph, HubConfig};
use tmfg::coordinator::registry;
use tmfg::data::corr::pearson_correlation;
use tmfg::tmfg::heap_tmfg;
use tmfg::util::bench::BenchSuite;

fn main() {
    let scale: f64 = std::env::var("BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.1);
    let mut suite = BenchSuite::new("bench_apsp");
    for name in registry::largest3_names() {
        let ds = registry::get_dataset(name, scale, registry::DEFAULT_SEED).unwrap();
        let s = pearson_correlation(&ds.data);
        let g = CsrGraph::from_tmfg(&heap_tmfg(&s, &Default::default()).unwrap(), &s);
        let n = g.n.to_string();

        suite
            .meta("dataset", name)
            .meta("n", &n)
            .meta("mode", "exact")
            .run(&format!("{name}/exact"), |_| {
                let m = apsp_exact(&g);
                assert_eq!(m.rows, g.n);
            });
        suite
            .meta("dataset", name)
            .meta("n", &n)
            .meta("mode", "approx")
            .run(&format!("{name}/approx"), |_| {
                let m = apsp_hub(&g, &HubConfig::default());
                assert_eq!(m.rows, g.n);
            });
        // hub-count ablation
        for hubs in [8usize, 16, 64] {
            suite
                .meta("dataset", name)
                .meta("n", &n)
                .meta("mode", &format!("approx-h{hubs}"))
                .run(&format!("{name}/approx-h{hubs}"), |_| {
                    let cfg = HubConfig { n_hubs: hubs, ..Default::default() };
                    let m = apsp_hub(&g, &cfg);
                    assert_eq!(m.rows, g.n);
                });
        }
    }
    suite.write_csv().unwrap();

    let mean = |needle: &str| {
        let xs: Vec<f64> = suite
            .results
            .iter()
            .filter(|s| s.name.ends_with(needle))
            .map(|s| s.mean)
            .collect();
        xs.iter().sum::<f64>() / xs.len().max(1) as f64
    };
    println!(
        "\nexact/approx speedup: {:.2}x (paper reports 2-3x on most datasets)",
        mean("/exact") / mean("/approx")
    );
}
