//! APSP benchmarks (§4.3 / §5.1): exact parallel Dijkstra vs the dense
//! hub matrix vs the streaming hub oracle, on sparse-kNN TMFGs at
//! n ∈ {512, 2048, 8192}. The paper reports a 2–3× APSP speedup for the
//! hub scheme; the oracle adds the memory story, so the suite runs in
//! two phases — every streaming (oracle) case first, then the dense
//! n×n builders — and records the process peak RSS (`peak_rss_kb`,
//! Linux VmHWM, a monotonic high-water mark) after each phase as a
//! metadata-only scenario. Writes the machine-readable perf-trajectory
//! artifact `results/BENCH_apsp.json` (asserted by CI).
//!
//! Env: `BENCH_MAX_N` caps the size sweep (CI smoke uses 1024);
//! `BENCH_REPS`/`BENCH_WARMUP` come from the shared harness.

use tmfg::apsp::{apsp_exact, apsp_hub, ApspOracle, CsrGraph, HubConfig, HubOracle};
use tmfg::data::synth::SynthSpec;
use tmfg::sparse::{knn_candidates, sparse_tmfg, KnnConfig};
use tmfg::util::bench::BenchSuite;

/// Peak resident set size (Linux VmHWM) as a metadata string; "na" where
/// /proc is unavailable. Shared probe from the bench harness.
fn peak_rss_kb() -> String {
    tmfg::util::bench::peak_rss_kb().map(|kb| kb.to_string()).unwrap_or_else(|| "na".into())
}

/// A TMFG graph at size n built through the sparse pipeline (the dense
/// similarity matrix would dominate setup time and memory at 8192).
fn tmfg_graph(n: usize) -> CsrGraph {
    let ds = SynthSpec::new("bench", n, 48, 8).generate(1);
    let cand = knn_candidates(&ds.data, &KnnConfig::new(16, 1)).expect("knn");
    let (r, _) = sparse_tmfg(&cand).expect("sparse tmfg");
    CsrGraph::from_tmfg(&r, &cand)
}

fn main() {
    let max_n: usize = std::env::var("BENCH_MAX_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(8192);
    let mut suite = BenchSuite::new("apsp");
    let cfg = HubConfig::default();
    let graphs: Vec<CsrGraph> = [512usize, 2048, 8192]
        .into_iter()
        .filter(|&n| n <= max_n)
        .map(tmfg_graph)
        .collect();

    // Phase 1: streaming oracle only — no n×n buffer exists anywhere in
    // the process yet, which the phase's peak-RSS note demonstrates.
    for g in &graphs {
        let n = g.n;
        let ns = n.to_string();
        suite
            .meta("n", &ns)
            .meta("mode", "hub-oracle-build")
            .run(&format!("n{n}/hub-oracle-build"), |_| {
                let o = HubOracle::build(g, &cfg);
                assert_eq!(o.n(), n);
            });
        // Apples-to-apples with the dense builders: build once, stream
        // every row (all n² values produced, O(n) resident scratch).
        let oracle = HubOracle::build(g, &cfg);
        suite
            .meta("n", &ns)
            .meta("mode", "hub-oracle-rows")
            .meta("oracle_bytes", &oracle.bytes().to_string())
            .run(&format!("n{n}/hub-oracle-rows"), |_| {
                let mut buf = vec![0f32; n];
                let mut acc = 0f64;
                for u in 0..n {
                    oracle.row_into(u, &mut buf);
                    acc += buf[n - 1 - u] as f64;
                }
                std::hint::black_box(acc);
            });
    }
    suite
        .meta("phase", "streaming")
        .meta("peak_rss_kb", &peak_rss_kb())
        .run("rss/after-streaming-phase", |_| {});

    // Phase 2: the dense n×n builders.
    for g in &graphs {
        let n = g.n;
        let ns = n.to_string();
        suite
            .meta("n", &ns)
            .meta("mode", "exact")
            .run(&format!("n{n}/exact"), |_| {
                let m = apsp_exact(g);
                assert_eq!(m.rows, n);
            });
        suite
            .meta("n", &ns)
            .meta("mode", "hub-matrix")
            .run(&format!("n{n}/hub-matrix"), |_| {
                let m = apsp_hub(g, &cfg);
                assert_eq!(m.rows, n);
            });
    }
    suite
        .meta("phase", "dense")
        .meta("peak_rss_kb", &peak_rss_kb())
        .run("rss/after-dense-phase", |_| {});

    suite.write_json().unwrap();
    suite.write_csv().unwrap();

    let median = |needle: &str| {
        let xs: Vec<f64> = suite
            .results
            .iter()
            .filter(|s| s.name.ends_with(needle))
            .map(|s| s.median)
            .collect();
        xs.iter().sum::<f64>() / xs.len().max(1) as f64
    };
    println!(
        "\nexact/hub-matrix speedup: {:.2}x (paper reports 2-3x); \
         exact/hub-oracle-rows: {:.2}x",
        median("/exact") / median("/hub-matrix").max(1e-12),
        median("/exact") / median("/hub-oracle-rows").max(1e-12),
    );
}
