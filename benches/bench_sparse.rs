//! Sparse large-n pipeline benchmarks: k-NN candidate construction
//! (exact and prefiltered), sparse-gain TMFG, and the end-to-end sparse
//! request vs the dense pipeline at the same n — the headline numbers
//! for the O(n·k)-memory path. `BENCH_SPARSE_N` scales the large case.

use std::sync::Arc;
use tmfg::api::{ApspMode, ClusterRequest, TmfgAlgo};
use tmfg::data::synth::SynthSpec;
use tmfg::parlay;
use tmfg::sparse::{knn_candidates, sparse_tmfg, KnnConfig, SparseSimilarity};
use tmfg::util::bench::BenchSuite;

fn main() {
    let big_n: usize = std::env::var("BENCH_SPARSE_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4096);
    let k = 32usize;
    let mut suite = BenchSuite::new("sparse");
    let threads = parlay::num_threads().to_string();

    // Candidate construction: exact vs prefiltered at the same n.
    let ds = SynthSpec::new("bench", big_n, 48, 16).generate(1);
    let panel = Arc::new(ds.data);
    suite
        .meta("n", &big_n.to_string())
        .meta("k", &k.to_string())
        .meta("threads", &threads)
        .run(&format!("knn_exact/n{big_n}"), |_| {
            let mut cfg = KnnConfig::new(k, 1);
            cfg.prefilter_above = usize::MAX; // force the exact path
            let sp = knn_candidates(&panel, &cfg).unwrap();
            assert!(sp.nnz() >= big_n * k);
        });
    suite
        .meta("n", &big_n.to_string())
        .meta("k", &k.to_string())
        .meta("threads", &threads)
        .run(&format!("knn_prefiltered/n{big_n}"), |_| {
            let mut cfg = KnnConfig::new(k, 1);
            cfg.prefilter_above = 0; // force the prefilter path
            let sp = knn_candidates(&panel, &cfg).unwrap();
            assert!(sp.nnz() >= big_n * k);
        });

    // Sparse-gain TMFG over a prebuilt candidate graph.
    let cand = knn_candidates(&panel, &KnnConfig::new(k, 1)).unwrap();
    suite
        .meta("n", &big_n.to_string())
        .meta("k", &k.to_string())
        .meta("threads", &threads)
        .run(&format!("sparse_tmfg/n{big_n}"), |_| {
            let (r, _) = sparse_tmfg(&cand).unwrap();
            assert_eq!(r.edges.len(), 3 * big_n - 6);
        });
    // Dense CORR-TMFG baseline at a size the dense path still handles.
    let small_n = big_n.min(2048);
    let small = SynthSpec::new("bench", small_n, 48, 16).generate(1);
    let dense_s = tmfg::data::corr::pearson_correlation(&small.data);
    let dense_cand = SparseSimilarity::from_dense(&dense_s, k).unwrap();
    suite
        .meta("n", &small_n.to_string())
        .meta("k", &k.to_string())
        .meta("threads", &threads)
        .run(&format!("sparse_tmfg_vs_dense/sparse_n{small_n}"), |_| {
            sparse_tmfg(&dense_cand).unwrap();
        });
    suite
        .meta("n", &small_n.to_string())
        .meta("algo", "corr-tdbht")
        .meta("threads", &threads)
        .run(&format!("sparse_tmfg_vs_dense/dense_n{small_n}"), |_| {
            tmfg::tmfg::corr_tmfg(&dense_s, &Default::default()).unwrap();
        });

    // End-to-end requests through the typed API.
    let small_panel = Arc::new(small.data);
    suite
        .meta("n", &small_n.to_string())
        .meta("k", &k.to_string())
        .meta("threads", &threads)
        .run(&format!("pipeline_sparse/n{small_n}"), |_| {
            let out = ClusterRequest::panel(small_panel.clone())
                .algo(TmfgAlgo::Opt)
                .apsp(ApspMode::Approx)
                .sparse_knn(k, 1)
                .k(16)
                .run()
                .unwrap();
            assert!(out.sparse.is_some());
        });
    suite
        .meta("n", &small_n.to_string())
        .meta("threads", &threads)
        .run(&format!("pipeline_dense/n{small_n}"), |_| {
            let out = ClusterRequest::panel(small_panel.clone())
                .algo(TmfgAlgo::Opt)
                .apsp(ApspMode::Approx)
                .use_xla(false)
                .k(16)
                .run()
                .unwrap();
            assert!(out.sparse.is_none());
        });

    suite.write_csv().unwrap();
    suite.write_json().unwrap();
}
