//! Microbenchmarks for the parallel substrate: dispatch overhead,
//! sort/radix throughput, scan variants — the knobs the §Perf pass tunes.

use tmfg::parlay;
use tmfg::tmfg::scan::{scan_chunked, scan_scalar, scan_wide};
use tmfg::util::bench::BenchSuite;
use tmfg::util::rng::Rng;
use std::sync::atomic::{AtomicU64, Ordering};

fn main() {
    let mut suite = BenchSuite::new("parlay");
    let threads = parlay::num_threads().to_string();

    // Dispatch overhead: many tiny parallel-fors (the ORIG-TMFG pattern).
    suite
        .meta("threads", &threads)
        .meta("kind", "dispatch")
        .run("dispatch/10k tiny parfors", |_| {
        let c = AtomicU64::new(0);
        for _ in 0..10_000 {
            parlay::parallel_for(64, 8, |_| {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(c.load(Ordering::Relaxed), 640_000);
    });

    // Big parallel map (the CORR-TMFG initial-sort pattern width).
    suite
        .meta("threads", &threads)
        .meta("kind", "map")
        .run("map/4M f32 ops", |_| {
        let v = parlay::par_map(4_000_000, 4096, |i| (i as f32).sqrt());
        assert_eq!(v.len(), 4_000_000);
    });

    // Sorting: comparison vs radix on one large row.
    let mut rng = Rng::new(5);
    let base: Vec<(f32, u32)> = (0..2_000_000)
        .map(|i| (rng.next_f32() * 2.0 - 1.0, i as u32))
        .collect();
    suite
        .meta("threads", &threads)
        .meta("kind", "sort")
        .run("sort/merge 2M pairs", |_| {
        let mut v = base.clone();
        parlay::par_sort_pairs_desc(&mut v);
        assert!(v[0].0 >= v[v.len() - 1].0);
    });
    suite
        .meta("threads", &threads)
        .meta("kind", "sort")
        .run("sort/radix 2M pairs", |_| {
        let mut v = base.clone();
        parlay::par_radix_sort_pairs_desc(&mut v);
        assert!(v[0].0 >= v[v.len() - 1].0);
    });

    // Row-sized sequential sorts inside a parallel loop (the real
    // CORR-TMFG shape: n rows of n-1 entries).
    let n = 2000;
    suite
        .meta("threads", &threads)
        .meta("kind", "sort")
        .run("sort/2k rows of 2k (pdqsort)", |_| {
        parlay::parallel_for(n, 1, |r| {
            let mut rng = Rng::new(r as u64);
            let mut row: Vec<(f32, u32)> =
                (0..n as u32).map(|i| (rng.next_f32(), i)).collect();
            row.sort_unstable_by(|a, b| b.0.total_cmp(&a.0));
        });
    });
    suite
        .meta("threads", &threads)
        .meta("kind", "sort")
        .run("sort/2k rows of 2k (radix)", |_| {
        parlay::parallel_for(n, 1, |r| {
            let mut rng = Rng::new(r as u64);
            let mut row: Vec<(f32, u32)> =
                (0..n as u32).map(|i| (rng.next_f32(), i)).collect();
            parlay::par_radix_sort_pairs_desc(&mut row);
        });
    });

    // MaxCorrs scans.
    let m = 1_000_000;
    let mut rng2 = Rng::new(9);
    let row: Vec<u32> = {
        let mut v: Vec<u32> = (0..m as u32).collect();
        rng2.shuffle(&mut v);
        v
    };
    let inserted: Vec<u8> = (0..m).map(|_| (rng2.next_below(10) < 9) as u8).collect();
    suite
        .meta("threads", &threads)
        .meta("kind", "scan")
        .run("scan/scalar 1M", |_| {
        let mut p = 0usize;
        let mut hits = 0;
        while p < m {
            p = scan_scalar(&row, &inserted, p) + 1;
            hits += 1;
        }
        assert!(hits > 0);
    });
    suite
        .meta("threads", &threads)
        .meta("kind", "scan")
        .run("scan/chunked 1M", |_| {
        let mut p = 0usize;
        let mut hits = 0;
        while p < m {
            p = scan_chunked(&row, &inserted, p) + 1;
            hits += 1;
        }
        assert!(hits > 0);
    });
    suite
        .meta("threads", &threads)
        .meta("kind", "scan")
        .run("scan/wide 1M", |_| {
        let mut p = 0usize;
        let mut hits = 0;
        while p < m {
            p = scan_wide(&row, &inserted, p) + 1;
            hits += 1;
        }
        assert!(hits > 0);
    });

    suite.write_csv().unwrap();
    // Machine-readable perf trajectory (results/BENCH_parlay.json),
    // smoke-run and gated in CI.
    suite.write_json().unwrap();
}
