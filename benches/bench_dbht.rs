//! DBHT hierarchy benchmarks past the dense ceiling: the full
//! bubble-tree → direction → converging-assignment → three-layer
//! agglomeration stage over a sparse k-NN TMFG with the resident hub
//! oracle — the regime where representative sampling (`REP_CAP`) and
//! chunked coarsening (`GROUP_CHUNK`) keep the stage near-linear.
//! `BENCH_DBHT_NS` (comma-separated sizes) shrinks the CI smoke; the
//! committed baseline covers n ∈ {16384, 65536}.

use tmfg::apsp::{CsrGraph, HubConfig, HubOracle};
use tmfg::data::synth::SynthSpec;
use tmfg::dbht::{dbht_dendrogram, Linkage};
use tmfg::parlay;
use tmfg::sparse::{knn_candidates, sparse_tmfg, KnnConfig};
use tmfg::util::bench::BenchSuite;

fn main() {
    let sizes: Vec<usize> = std::env::var("BENCH_DBHT_NS")
        .ok()
        .map(|v| v.split(',').filter_map(|s| s.trim().parse().ok()).collect())
        .unwrap_or_else(|| vec![16384, 65536]);
    let k = 16usize;
    let mut suite = BenchSuite::new("dbht");
    let threads = parlay::num_threads().to_string();

    for n in sizes {
        // Setup (not timed): panel → candidate graph → TMFG → hub oracle.
        let ds = SynthSpec::new("bench", n, 48, 16).generate(1);
        let cand = knn_candidates(&ds.data, &KnnConfig::new(k, 1)).unwrap();
        let (r, _) = sparse_tmfg(&cand).unwrap();
        let g = CsrGraph::from_tmfg(&r, &cand);
        let oracle = HubOracle::build(&g, &HubConfig::default());
        suite
            .meta("n", &n.to_string())
            .meta("k", &k.to_string())
            .meta("linkage", "complete")
            .meta("threads", &threads)
            .run(&format!("dbht_hub/n{n}"), |_| {
                let out = dbht_dendrogram(&cand, &r, &oracle, Linkage::Complete).unwrap();
                assert!(out.dendrogram.is_complete(), "n={n}: incomplete dendrogram");
            });
    }

    suite.write_csv().unwrap();
    suite.write_json().unwrap();
}
