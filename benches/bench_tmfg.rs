//! TMFG-construction benchmarks (§5.1 text numbers): per-algorithm
//! construction time on the three largest datasets, plus the §4.3
//! optimization ablation (scan kind × sort kind).
//!
//!     cargo bench --bench bench_tmfg
//! Env: BENCH_SCALE (default 0.1), BENCH_REPS, BENCH_WARMUP.

use tmfg::coordinator::registry;
use tmfg::data::corr::pearson_correlation;
use tmfg::tmfg::{corr_tmfg, heap_tmfg, orig_tmfg, ScanKind, SortKind, TmfgConfig};
use tmfg::util::bench::BenchSuite;

fn main() {
    let scale: f64 = std::env::var("BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.1);
    let mut suite = BenchSuite::new("tmfg");
    for name in registry::largest3_names() {
        let ds = registry::get_dataset(name, scale, registry::DEFAULT_SEED).unwrap();
        let s = pearson_correlation(&ds.data);
        let n = ds.n();

        for p in [1usize, 10, 200] {
            suite
                .meta("dataset", name)
                .meta("n", &n.to_string())
                .meta("algo", &format!("par-{p}"))
                .run(&format!("{name}/par-{p}"), |_| {
                    let r = orig_tmfg(&s, p).unwrap();
                    assert_eq!(r.edges.len(), 3 * n - 6);
                });
        }
        suite
            .meta("dataset", name)
            .meta("n", &n.to_string())
            .meta("algo", "corr")
            .run(&format!("{name}/corr"), |_| {
                let r = corr_tmfg(&s, &TmfgConfig::default()).unwrap();
                assert_eq!(r.edges.len(), 3 * n - 6);
            });
        suite
            .meta("dataset", name)
            .meta("n", &n.to_string())
            .meta("algo", "heap")
            .run(&format!("{name}/heap"), |_| {
                let r = heap_tmfg(&s, &TmfgConfig::default()).unwrap();
                assert_eq!(r.edges.len(), 3 * n - 6);
            });
        // §4.3 ablation: scan × sort on the heap algorithm (OPT = wide+radix).
        for (scan, sort, label) in [
            (ScanKind::Chunked, SortKind::Comparison, "heap+scan"),
            (ScanKind::Wide, SortKind::Comparison, "heap+wide"),
            (ScanKind::Scalar, SortKind::Radix, "heap+radix"),
            (ScanKind::Wide, SortKind::Radix, "opt"),
        ] {
            suite
                .meta("dataset", name)
                .meta("n", &n.to_string())
                .meta("algo", label)
                .run(&format!("{name}/{label}"), |_| {
                    let r = heap_tmfg(&s, &TmfgConfig { prefix: 1, scan, sort }).unwrap();
                    assert_eq!(r.edges.len(), 3 * n - 6);
                });
        }
    }
    suite.write_csv().unwrap();
    // Machine-readable perf trajectory (results/BENCH_tmfg.json),
    // smoke-run and gated in CI.
    suite.write_json().unwrap();

    // Paper's qualitative claims, asserted on the measured means:
    // TMFG construction in heap-tdbht is faster than par-tdbht-10.
    let mean = |needle: &str| {
        let xs: Vec<f64> = suite
            .results
            .iter()
            .filter(|s| s.name.ends_with(needle))
            .map(|s| s.mean)
            .collect();
        xs.iter().sum::<f64>() / xs.len().max(1) as f64
    };
    let (par10, heap, corr) = (mean("/par-10"), mean("/heap"), mean("/corr"));
    println!("\nmean construction: par-10 {par10:.3}s  corr {corr:.3}s  heap {heap:.3}s");
    println!("speedup corr vs par-10: {:.1}x ; heap vs par-10: {:.1}x", par10 / corr, par10 / heap);
}
