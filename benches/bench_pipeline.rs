//! End-to-end pipeline benchmark (Fig. 2 in criterion-style form) plus a
//! thread-scaling mini-sweep (Figs. 3/4 shape check). Drives the typed
//! staged API with a shared `Arc` similarity matrix, so each timed
//! iteration measures one full request — build/validation (a single
//! O(n²) finiteness scan, no payload copies) plus the pipeline stages.
//! For stage-only timings see `tmfg experiment fig2`, which builds the
//! plan before starting the stopwatch.

use std::sync::Arc;
use tmfg::api::{ClusterRequest, TmfgAlgo};
use tmfg::coordinator::registry;
use tmfg::data::corr::pearson_correlation;
use tmfg::data::matrix::Matrix;
use tmfg::parlay;
use tmfg::util::bench::BenchSuite;

fn run_once(algo: TmfgAlgo, s: &Arc<Matrix>, labels: &[usize], k: usize) {
    let out = ClusterRequest::similarity(s.clone())
        .algo(algo)
        .labels(labels.to_vec())
        .k(k.max(1))
        .run()
        .unwrap();
    assert!(out.ari.is_some());
}

fn main() {
    let scale: f64 = std::env::var("BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.1);
    let mut suite = BenchSuite::new("bench_pipeline");
    let algos = [
        TmfgAlgo::Par(1),
        TmfgAlgo::Par(10),
        TmfgAlgo::Corr,
        TmfgAlgo::Heap,
        TmfgAlgo::Opt,
    ];
    // Fig-2-style: per-dataset end-to-end times (similarity precomputed,
    // as in the paper).
    for name in ["CBF", "ECG5000", "Crop", "StarLightCurves"] {
        let ds = registry::get_dataset(name, scale, registry::DEFAULT_SEED).unwrap();
        let s = Arc::new(pearson_correlation(&ds.data));
        for algo in algos {
            suite
                .meta("dataset", name)
                .meta("n", &ds.n().to_string())
                .meta("algo", &algo.name())
                .meta("threads", &parlay::num_threads().to_string())
                .run(&format!("{name}/{}", algo.name()), |_| {
                    run_once(algo, &s, &ds.labels, ds.n_classes);
                });
        }
    }
    // Scaling mini-sweep on the largest dataset: OPT vs PAR-10.
    let ds = registry::get_dataset("Crop", scale, registry::DEFAULT_SEED).unwrap();
    let s = Arc::new(pearson_correlation(&ds.data));
    let max_t = parlay::num_threads();
    let mut threads = vec![1usize];
    let mut t = 2;
    while t < max_t {
        threads.push(t);
        t *= 2;
    }
    threads.push(max_t);
    for algo in [TmfgAlgo::Opt, TmfgAlgo::Par(10)] {
        for &t in &threads {
            suite
                .meta("dataset", "Crop")
                .meta("n", &ds.n().to_string())
                .meta("algo", &algo.name())
                .meta("threads", &t.to_string())
                .run(&format!("scaling/{}@{t}", algo.name()), |_| {
                    parlay::with_threads(t, || {
                        run_once(algo, &s, &ds.labels, ds.n_classes);
                    })
                });
        }
    }
    suite.write_csv().unwrap();
}
