//! End-to-end pipeline benchmark (Fig. 2 in criterion-style form) plus a
//! thread-scaling mini-sweep (Figs. 3/4 shape check), a concurrent-
//! clients serving scenario (single dispatcher vs the sharded worker
//! pool), and an artifact-cache hit-path scenario. The pipeline cases
//! drive the typed staged API with a shared `Arc` similarity matrix, so
//! each timed iteration measures one full request — build/validation (a
//! single O(n²) finiteness scan, no payload copies) plus the pipeline
//! stages. For stage-only timings see `tmfg experiment fig2`, which
//! builds the plan before starting the stopwatch.

use std::sync::Arc;
use tmfg::api::{ClusterRequest, TmfgAlgo};
use tmfg::coordinator::registry;
use tmfg::coordinator::service::{serve, Client, ServiceConfig};
use tmfg::data::corr::pearson_correlation;
use tmfg::data::matrix::Matrix;
use tmfg::parlay;
use tmfg::util::bench::BenchSuite;
use tmfg::util::json::Json;

fn run_once(algo: TmfgAlgo, s: &Arc<Matrix>, labels: &[usize], k: usize) {
    let out = ClusterRequest::similarity(s.clone())
        .algo(algo)
        .labels(labels.to_vec())
        .k(k.max(1))
        .run()
        .unwrap();
    assert!(out.ari.is_some());
}

fn main() {
    let scale: f64 = std::env::var("BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.1);
    let mut suite = BenchSuite::new("pipeline");

    // Correlation-kernel scenarios: the dispatched Gram kernel (AVX2
    // where the host has it) vs the forced scalar core on the same
    // standardized panel — the O(n²·l) top cost of every cold request,
    // and the pair the perf gate's ≥1.3× kernel claim is recorded
    // against. `BENCH_CORR_MAX_N` caps the sweep (CI smoke uses 1024).
    {
        use tmfg::data::corr::{gram_kernel_name, pearson_correlation_scalar};
        use tmfg::data::synth::SynthSpec;
        let corr_max_n: usize = std::env::var("BENCH_CORR_MAX_N")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(4096);
        let threads = parlay::num_threads().to_string();
        for n in [512usize, 2048, 4096] {
            if n > corr_max_n {
                continue;
            }
            let ds = SynthSpec::new("corrbench", n, 128, 8).generate(7);
            suite
                .meta("n", &n.to_string())
                .meta("len", "128")
                .meta("threads", &threads)
                .meta("kernel", gram_kernel_name())
                .run(&format!("corr_kernel/n{n}"), |_| {
                    let s = pearson_correlation(&ds.data);
                    assert_eq!(s.rows, n);
                });
            suite
                .meta("n", &n.to_string())
                .meta("len", "128")
                .meta("threads", &threads)
                .meta("kernel", "scalar")
                .run(&format!("corr_kernel_scalar/n{n}"), |_| {
                    let s = pearson_correlation_scalar(&ds.data);
                    assert_eq!(s.rows, n);
                });
        }
    }

    let algos = [
        TmfgAlgo::Par(1),
        TmfgAlgo::Par(10),
        TmfgAlgo::Corr,
        TmfgAlgo::Heap,
        TmfgAlgo::Opt,
    ];
    // Fig-2-style: per-dataset end-to-end times (similarity precomputed,
    // as in the paper).
    for name in ["CBF", "ECG5000", "Crop", "StarLightCurves"] {
        let ds = registry::get_dataset(name, scale, registry::DEFAULT_SEED).unwrap();
        let s = Arc::new(pearson_correlation(&ds.data));
        for algo in algos {
            suite
                .meta("dataset", name)
                .meta("n", &ds.n().to_string())
                .meta("algo", &algo.name())
                .meta("threads", &parlay::num_threads().to_string())
                .run(&format!("{name}/{}", algo.name()), |_| {
                    run_once(algo, &s, &ds.labels, ds.n_classes);
                });
        }
    }
    // Scaling mini-sweep on the largest dataset: OPT vs PAR-10.
    let ds = registry::get_dataset("Crop", scale, registry::DEFAULT_SEED).unwrap();
    let s = Arc::new(pearson_correlation(&ds.data));
    let max_t = parlay::num_threads();
    let mut threads = vec![1usize];
    let mut t = 2;
    while t < max_t {
        threads.push(t);
        t *= 2;
    }
    threads.push(max_t);
    for algo in [TmfgAlgo::Opt, TmfgAlgo::Par(10)] {
        for &t in &threads {
            suite
                .meta("dataset", "Crop")
                .meta("n", &ds.n().to_string())
                .meta("algo", &algo.name())
                .meta("threads", &t.to_string())
                .run(&format!("scaling/{}@{t}", algo.name()), |_| {
                    parlay::with_threads(t, || {
                        run_once(algo, &s, &ds.labels, ds.n_classes);
                    })
                });
        }
    }
    // Concurrent-clients serving scenario: 4 clients fire named-dataset
    // requests at the TCP service with 1 dispatch worker (the old
    // single-dispatcher architecture) vs 4. Distinct seeds defeat the
    // artifact cache, so the comparison isolates dispatch concurrency;
    // the acceptance bar is >1.5x aggregate throughput at 4 workers on a
    // 4-core host.
    for workers in [1usize, 4] {
        let h = serve(ServiceConfig {
            addr: "127.0.0.1:0".into(),
            dispatch_workers: workers,
            cache_entries: 0,
            ..Default::default()
        })
        .expect("start service");
        let addr = h.addr.clone();
        suite
            .meta("dataset", "CBF")
            .meta("workers", &workers.to_string())
            .meta("clients", "4")
            .run(&format!("service/4clients@{workers}w"), |rep| {
                let joins: Vec<_> = (0..4)
                    .map(|c| {
                        let addr = addr.clone();
                        std::thread::spawn(move || {
                            let mut client = Client::connect(&addr).expect("connect");
                            for r in 0..2 {
                                let req = Json::obj(vec![
                                    ("dataset", Json::str("CBF")),
                                    ("scale", Json::Num(scale)),
                                    ("seed", Json::Num((1 + rep * 100 + c * 10 + r) as f64)),
                                    ("algo", Json::str("opt")),
                                ]);
                                let resp = client.call(&req).expect("call");
                                assert_eq!(
                                    resp.get("ok").as_bool(),
                                    Some(true),
                                    "{resp:?}"
                                );
                            }
                        })
                    })
                    .collect();
                for j in joins {
                    j.join().unwrap();
                }
            });
        h.stop();
    }

    // Observability disabled-path overhead pin: a burst of span! sites
    // with no live trace session must cost ~one relaxed atomic load each
    // (label closures never evaluated). Regressions here slow down every
    // instrumented hot loop in the repo.
    {
        const SPANS_PER_REP: usize = 1_000_000;
        assert!(!tmfg::obs::tracing_enabled(), "bench requires tracing disabled");
        suite
            .meta("spans", &SPANS_PER_REP.to_string())
            .meta("mode", "disabled")
            .run("obs/disabled_span_1M", |_| {
                for i in 0..SPANS_PER_REP {
                    let _g = tmfg::span!("stage", "never evaluated {i}");
                }
            });
    }

    // Flight-recorder wide-event cost pins: the disabled path (budget 0,
    // closure never evaluated) must stay ~one branch per request, and
    // the enabled path bounds the serialize+ring cost the service layer
    // pays per completed request under the default 1 MiB budget.
    {
        use tmfg::obs::FlightRecorder;
        const EVENTS_PER_REP: usize = 1_000_000;
        let wide_event = |i: usize| {
            Json::obj(vec![
                ("trace_id", Json::str(&format!("req-{i:08x}"))),
                ("kind", Json::str("batch")),
                ("tenant", Json::Null),
                ("outcome", Json::str("ok")),
                ("ts_ms", Json::Num(1_700_000_000_000.0 + i as f64)),
                ("queue_delay_ms", Json::Num(0.42)),
                ("wall_ms", Json::Num(12.5)),
                (
                    "stages",
                    Json::obj(vec![
                        ("similarity", Json::Num(3.0)),
                        ("tmfg:add-vertices", Json::Num(4.0)),
                        ("apsp", Json::Num(2.0)),
                        ("dbht", Json::Num(2.5)),
                    ]),
                ),
                ("response_bytes", Json::Num(2048.0)),
                ("cache", Json::str("miss")),
            ])
        };
        // The SLO window config rides along as metadata so a future
        // window change skips (not false-fails) the baseline comparison.
        let slo_windows = format!(
            "{}/{}",
            tmfg::obs::slo::SHORT_WINDOW_SECS,
            tmfg::obs::slo::LONG_WINDOW_SECS
        );
        let disabled = FlightRecorder::new(0);
        suite
            .meta("events", &EVENTS_PER_REP.to_string())
            .meta("mode", "disabled")
            .meta("recorder_budget_bytes", "0")
            .meta("slo_windows", &slo_windows)
            .run("obs/wide_event_1M_disabled", |_| {
                for i in 0..EVENTS_PER_REP {
                    disabled.record_with(|| wide_event(i));
                }
            });
        let enabled = FlightRecorder::new(FlightRecorder::DEFAULT_BUDGET);
        suite
            .meta("events", &EVENTS_PER_REP.to_string())
            .meta("mode", "enabled")
            .meta("recorder_budget_bytes", &FlightRecorder::DEFAULT_BUDGET.to_string())
            .meta("slo_windows", &slo_windows)
            .run("obs/wide_event_1M_enabled", |_| {
                for i in 0..EVENTS_PER_REP {
                    enabled.record_with(|| wide_event(i));
                }
                assert!(enabled.stats().bytes <= FlightRecorder::DEFAULT_BUDGET);
            });
    }

    // Artifact-cache hit path: repeated identical requests skip the
    // similarity + TMFG stages entirely.
    {
        let h = serve(ServiceConfig {
            addr: "127.0.0.1:0".into(),
            dispatch_workers: 4,
            ..Default::default()
        })
        .expect("start service");
        let mut client = Client::connect(&h.addr).expect("connect");
        let req = Json::obj(vec![
            ("dataset", Json::str("CBF")),
            ("scale", Json::Num(scale)),
            ("seed", Json::Num(1.0)),
            ("algo", Json::str("opt")),
        ]);
        // warm the cache, then time pure hits
        let warm = client.call(&req).expect("warm");
        assert_eq!(warm.get("ok").as_bool(), Some(true), "{warm:?}");
        suite.meta("dataset", "CBF").meta("workers", "4").run("service/cache_hit", |_| {
            let resp = client.call(&req).expect("call");
            assert_eq!(resp.get("cache").as_str(), Some("hit"), "{resp:?}");
        });
        h.stop();
    }

    suite.write_csv().unwrap();
    // Machine-readable perf trajectory (results/BENCH_pipeline.json):
    // scenario → median ns plus the n/threads metadata, smoke-run in CI.
    suite.write_json().unwrap();
}
