//! Offline stub for the `xla` (xla-rs / PJRT) bindings.
//!
//! The real bindings link against the native XLA/PJRT shared library,
//! which is not present in the offline build environment. This stub
//! mirrors exactly the API surface used by `rust/src/runtime/client.rs`
//! and fails at the first runtime entry point (`PjRtClient::cpu`), so
//! `CorrEngine::auto` falls back to the native Rust correlation path and
//! the rest of the system runs unchanged. Swap the `vendor/xla` path
//! dependency for the real bindings to light the XLA path back up.

use std::fmt;
use std::path::Path;

#[derive(Debug)]
pub struct XlaError(String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "{what}: PJRT/XLA native runtime not linked (offline stub build; \
         swap vendor/xla for the real bindings)"
    ))
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".into()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &Path) -> Result<HloModuleProto> {
        Err(unavailable(&format!(
            "HloModuleProto::from_text_file({})",
            path.display()
        )))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(self, _dims: &[i64]) -> Result<Literal> {
        Ok(self)
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().map(|_| ()).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("not linked"), "{msg}");
    }

    #[test]
    fn literal_shape_ops_are_inert() {
        let lit = Literal::vec1(&[1.0, 2.0]).reshape(&[2, 1]).unwrap();
        assert!(lit.to_vec::<f32>().is_err());
    }
}
