//! Minimal offline stand-in for the `anyhow` crate (1.x API subset).
//!
//! The registry is unreachable in the offline build environment, so this
//! shim provides exactly the surface the `tmfg` crate uses — [`Result`],
//! [`Error`], the [`Context`] extension trait, and the `anyhow!` /
//! `bail!` / `ensure!` macros — with `{:#}`-style context chains.
//! Swapping the path dependency in the root `Cargo.toml` for the real
//! crate restores backtraces and downcasting; no caller changes needed.

use std::fmt;

/// An error with a chain of context messages, outermost first
/// (`Display` shows index 0; `{:#}` shows the whole chain).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message (the `anyhow!` macro body).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Context messages from outermost to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain.join(": "))
    }
}

// The same non-overlapping blanket conversion the real crate uses
// (sound because `Error` itself deliberately does not implement
// `std::error::Error`).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(...)` / `.with_context(|| ...)` on `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        // `{:#}` flattens an inner `Error`'s chain; for plain errors the
        // alternate form matches the normal one.
        self.map_err(|e| Error { chain: vec![context.to_string(), format!("{e:#}")] })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { chain: vec![f().to_string(), format!("{e:#}")] })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::Error::msg(format!($($arg)*)) };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn context_chains_and_display() {
        let r: Result<()> = Err(io_err()).context("read config");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "read config");
        assert_eq!(format!("{e:#}"), "read config: missing file");
        let e2 = e.context("load service");
        assert_eq!(format!("{e2:#}"), "load service: read config: missing file");
        assert_eq!(e2.chain().count(), 3);
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(format!("{e}"), "missing key");
        assert_eq!(Some(5u32).context("x").unwrap(), 5);
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(format!("{}", inner().unwrap_err()), "missing file");
    }

    #[test]
    fn macros() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", f(5).unwrap_err()), "five is right out");
        let e = anyhow!("coded {}", 7);
        assert_eq!(format!("{e}"), "coded 7");
    }
}
