//! Serving scenario: start the batched clustering service, fire concurrent
//! client requests at it, and report latency/throughput plus observed
//! batch sizes.
//!
//!     cargo run --release --example serve -- [--requests 24] [--clients 6]
//!         [--workers 0]   (0 = auto: min(4, cores/2) dispatch workers)

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use tmfg::coordinator::service::{serve, Client, ServiceConfig};
use tmfg::util::cli::Args;
use tmfg::util::json::Json;
use tmfg::util::timer::Timer;

fn main() {
    let args = Args::parse(&["requests", "clients", "scale", "workers"]).unwrap();
    let n_requests = args.get_usize("requests", 24);
    let n_clients = args.get_usize("clients", 6);
    let scale = args.get_f64("scale", 0.03);

    let cfg = ServiceConfig {
        addr: "127.0.0.1:0".into(), // ephemeral port
        dispatch_workers: args.get_usize("workers", 0),
        ..Default::default()
    };
    let workers = cfg.resolved_workers();
    let handle = serve(cfg).expect("start service");
    let addr = handle.addr.clone();
    println!(
        "service on {addr} ({workers} dispatch workers); {n_clients} clients × {} requests",
        n_requests / n_clients
    );

    let datasets = ["CBF", "ECG5000", "SonyAIBORobotSurface2", "Mallat"];
    let done = Arc::new(AtomicUsize::new(0));
    let latencies = Arc::new(std::sync::Mutex::new(Vec::<f64>::new()));
    let batches = Arc::new(std::sync::Mutex::new(Vec::<usize>::new()));

    let wall = Timer::start();
    let mut joins = Vec::new();
    for c in 0..n_clients {
        let addr = addr.clone();
        let done = done.clone();
        let latencies = latencies.clone();
        let batches = batches.clone();
        joins.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).expect("connect");
            let per = n_requests / n_clients;
            for r in 0..per {
                let ds = datasets[(c + r) % datasets.len()];
                let req = Json::obj(vec![
                    ("id", Json::Num((c * 1000 + r) as f64)),
                    ("dataset", Json::str(ds)),
                    ("scale", Json::Num(scale)),
                    ("seed", Json::Num((r + 1) as f64)),
                    ("algo", Json::str("opt")),
                ]);
                let t = Timer::start();
                let resp = client.call(&req).expect("call");
                let lat = t.elapsed();
                assert_eq!(resp.get("ok").as_bool(), Some(true), "{resp:?}");
                latencies.lock().unwrap().push(lat);
                batches
                    .lock()
                    .unwrap()
                    .push(resp.get("batch").as_usize().unwrap_or(1));
                done.fetch_add(1, Ordering::Relaxed);
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let total = wall.elapsed();

    let mut lats = latencies.lock().unwrap().clone();
    lats.sort_by(|a, b| a.total_cmp(b));
    let n = lats.len();
    let pct = |p: f64| lats[((n as f64 * p) as usize).min(n - 1)];
    let bs = batches.lock().unwrap();
    let mean_batch = bs.iter().sum::<usize>() as f64 / bs.len() as f64;
    println!("\ncompleted {} requests in {total:.2}s", done.load(Ordering::Relaxed));
    println!("throughput: {:.1} req/s", n as f64 / total);
    println!(
        "latency p50 {:.3}s  p90 {:.3}s  p99 {:.3}s  max {:.3}s",
        pct(0.50),
        pct(0.90),
        pct(0.99),
        lats[n - 1]
    );
    println!("mean observed batch size: {mean_batch:.2}");

    // live observability: worker pool + artifact-cache effectiveness
    let mut client = Client::connect(&addr).expect("connect");
    let stats = client
        .call(&Json::obj(vec![("cmd", Json::str("stats"))]))
        .expect("stats");
    println!(
        "stats: workers {}  jobs {}  cache hits {}  misses {}  hit ratio {:.2}",
        stats.get("workers").as_usize().unwrap_or(0),
        stats.get("jobs").as_usize().unwrap_or(0),
        stats.get("cache_hits").as_usize().unwrap_or(0),
        stats.get("cache_misses").as_usize().unwrap_or(0),
        stats.get("cache_hit_ratio").as_f64().unwrap_or(0.0),
    );
    // Serving front end: which readiness backend ran the connection
    // tier, and how busy it was (one OS thread regardless of clients).
    println!(
        "net: backend {}  conns accepted {}  active {}  loop wakeups {}",
        stats.get("net_backend").as_str().unwrap_or("?"),
        stats.get("conns_accepted").as_usize().unwrap_or(0),
        stats.get("conns_active").as_usize().unwrap_or(0),
        stats.get("loop_wakeups").as_usize().unwrap_or(0),
    );
    // Service-side latency percentiles (obs registry histograms) for the
    // TMFG stage and the dispatcher queue wait, from the same stats call.
    let lat = stats.get("latency");
    let pct = |node: &Json| (node.get("p50").as_f64(), node.get("p99").as_f64());
    if let (Some(p50), Some(p99)) = pct(lat.get("stages").get("tmfg")) {
        println!("server stage tmfg: p50 {:.1}ms  p99 {:.1}ms", p50 * 1e3, p99 * 1e3);
    }
    if let (Some(p50), Some(p99)) = pct(lat.get("queue_wait")) {
        println!("server queue wait: p50 {:.1}ms  p99 {:.1}ms", p50 * 1e3, p99 * 1e3);
    }
    // Prometheus scrape: `{"cmd": "metrics"}` returns the full text
    // exposition; print it so `--example serve` output can be grepped
    // for the per-stage histograms (CI does exactly that).
    let metrics = client
        .call(&Json::obj(vec![("cmd", Json::str("metrics"))]))
        .expect("metrics");
    if let Some(text) = metrics.get("metrics").as_str() {
        println!("\n--- metrics scrape ---\n{text}");
    }
    handle.stop();
}
