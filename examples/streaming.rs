//! Streaming scenario: replay a synthetic regime-shifting panel through
//! a StreamSession and watch the delta policy at work — cheap O(n²)
//! refreshes while the correlation structure is stable, full TMFG
//! rebuilds clustered right after the regime boundary where the sliding
//! window starts mixing in the new structure.
//!
//!     cargo run --release --example streaming -- \
//!         [--n 120] [--window 64] [--k 4] [--drift 0.1] [--report 32]

use tmfg::data::synth::SynthSpec;
use tmfg::metrics::adjusted_rand_index;
use tmfg::stream::{StreamConfig, StreamSession, TickDecision};
use tmfg::util::cli::Args;

fn main() {
    let args = Args::parse(&["n", "window", "k", "drift", "report"]).unwrap();
    let n = args.get_usize("n", 120);
    let window = args.get_usize("window", 64);
    let k = args.get_usize("k", 4);
    let report_every = args.get_usize("report", 32).max(1);

    // Two regimes: same series count and class count, but independently
    // drawn class structure — at the boundary every correlation block
    // changes, which is what the drift detector must catch.
    let regime_a = SynthSpec::new("regime-a", n, 256, k).generate(11);
    let regime_b = SynthSpec::new("regime-b", n, 256, k).generate(77);
    let boundary = regime_a.data.cols;
    let total = boundary + regime_b.data.cols;

    let mut cfg = StreamConfig::new(n, window, k);
    cfg.policy.drift_threshold = args.get_f64("drift", 0.1) as f32;
    let mut session = StreamSession::new(cfg).expect("stream config");
    println!(
        "replaying {total} ticks (regime shift at tick {boundary}), n={n}, window={window}, \
         k={k}, drift threshold {:.3}\n",
        session.config.policy.drift_threshold
    );

    let mut sample = vec![0.0f32; n];
    let mut rebuild_ticks: Vec<usize> = Vec::new();
    for t in 0..total {
        let (panel, truth, col) = if t < boundary {
            (&regime_a.data, &regime_a.labels, t)
        } else {
            (&regime_b.data, &regime_b.labels, t - boundary)
        };
        for (i, v) in sample.iter_mut().enumerate() {
            *v = panel.at(i, col);
        }
        let out = session.tick(&sample).expect("tick");
        let Some(pred) = &out.labels else { continue };
        if out.decision == TickDecision::Rebuilt {
            rebuild_ticks.push(t);
        }
        if out.decision == TickDecision::Rebuilt || t % report_every == 0 || t + 1 == total {
            let ari = adjusted_rand_index(truth, pred);
            println!(
                "tick {t:4}  gen {:4}  {:7}  drift {:.3}  ARI {ari:+.3}{}",
                out.generation,
                out.decision.name(),
                out.drift.map(|d| d.max_abs).unwrap_or(0.0),
                if t == boundary { "   <-- regime shift" } else { "" }
            );
        }
    }

    let st = session.stats();
    println!(
        "\nticks {}  emissions {}  rebuilds {}  refreshes {}",
        st.ticks, st.emissions, st.rebuilds, st.refreshes
    );
    let post_shift: Vec<&usize> =
        rebuild_ticks.iter().filter(|&&t| t >= boundary && t < boundary + window).collect();
    println!(
        "rebuild ticks: {rebuild_ticks:?}\n{} of them inside the {window}-tick window after \
         the regime shift",
        post_shift.len()
    );
}
