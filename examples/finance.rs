//! Finance scenario — the domain TMFG-DBHT was originally designed for
//! (Mantegna'99; Musmeci et al.'15): build a filtered correlation network
//! of synthetic equity returns with a sector factor structure, and check
//! that the DBHT clusters recover the sectors.
//!
//!     cargo run --release --example finance -- [--stocks 300] [--days 504]

use tmfg::coordinator::pipeline::{Pipeline, PipelineConfig, TmfgAlgo};
use tmfg::data::matrix::Matrix;
use tmfg::data::synth::Dataset;
use tmfg::metrics::adjusted_rand_index;
use tmfg::util::cli::Args;
use tmfg::util::rng::Rng;

/// Synthetic daily returns with a classic factor model:
/// r_i = beta_m·market + beta_s·sector(i) + idiosyncratic noise.
fn synth_returns(n_stocks: usize, n_days: usize, n_sectors: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let market: Vec<f64> = (0..n_days).map(|_| rng.next_gaussian() * 0.008).collect();
    let sectors: Vec<Vec<f64>> = (0..n_sectors)
        .map(|_| (0..n_days).map(|_| rng.next_gaussian() * 0.006).collect())
        .collect();
    let mut data = vec![0.0f32; n_stocks * n_days];
    let mut labels = vec![0usize; n_stocks];
    for i in 0..n_stocks {
        let sector = i % n_sectors;
        labels[i] = sector;
        let beta_m = rng.range_f64(0.6, 1.4);
        let beta_s = rng.range_f64(0.7, 1.3);
        let sigma = rng.range_f64(0.004, 0.012);
        for t in 0..n_days {
            let r = beta_m * market[t] + beta_s * sectors[sector][t] + sigma * rng.next_gaussian();
            data[i * n_days + t] = r as f32;
        }
    }
    Dataset {
        name: "synthetic-equities".into(),
        data: Matrix::from_vec(n_stocks, n_days, data),
        labels,
        n_classes: n_sectors,
    }
}

fn main() {
    let args = Args::parse(&["stocks", "days", "sectors", "seed"]).unwrap();
    let n = args.get_usize("stocks", 300);
    let days = args.get_usize("days", 504); // two trading years
    let sectors = args.get_usize("sectors", 8);
    let ds = synth_returns(n, days, sectors, args.get_u64("seed", 7));
    println!("{} stocks × {} days, {} sectors", n, days, sectors);

    let out = Pipeline::new(PipelineConfig { algo: TmfgAlgo::Opt, ..Default::default() })
        .run_dataset(&ds)
        .expect("pipeline run");
    println!("\nstage breakdown:\n{}", out.breakdown.table());
    println!(
        "TMFG: {} edges over {} stocks (3n-6 = {}); edge sum {:.2}",
        out.tmfg.edges.len(),
        n,
        3 * n - 6,
        out.edge_sum
    );

    // Sector recovery at the sector count.
    let pred = out.dbht.dendrogram.cut(sectors);
    let ari = adjusted_rand_index(&ds.labels, &pred);
    println!("sector recovery ARI @ k={sectors}: {ari:.3}");

    // The hierarchy above sector level: market-wide merges.
    for k in [2, 4, sectors, sectors * 2] {
        let l = out.dbht.dendrogram.cut(k);
        println!(
            "  cut k={:<3} ARI {:+.3}",
            k,
            adjusted_rand_index(&ds.labels, &l)
        );
    }

    // Strongest TMFG edges = the network backbone a portfolio analyst
    // would draw.
    let s = tmfg::data::corr::pearson_correlation(&ds.data);
    let mut edges = out.tmfg.edges.clone();
    edges.sort_by(|a, b| {
        s.at(b.0 as usize, b.1 as usize)
            .total_cmp(&s.at(a.0 as usize, a.1 as usize))
    });
    println!("\nstrongest filtered-graph edges (stock_i -- stock_j  ρ, same sector?):");
    for &(u, v) in edges.iter().take(8) {
        println!(
            "  {:>4} -- {:<4}  ρ={:.3}  {}",
            u,
            v,
            s.at(u as usize, v as usize),
            if ds.labels[u as usize] == ds.labels[v as usize] { "same" } else { "CROSS" }
        );
    }
}
