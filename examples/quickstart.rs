//! Quickstart: cluster a small synthetic time-series dataset end to end
//! through the typed staged API (`tmfg::api`).
//!
//!     cargo run --release --example quickstart

use tmfg::api::{ApspMode, ClusterRequest, TmfgAlgo, TmfgError};
use tmfg::data::synth::SynthSpec;

fn main() -> Result<(), TmfgError> {
    // 200 series of length 64 from 4 latent classes.
    let ds = SynthSpec::new("quickstart", 200, 64, 4).generate(42);

    // OPT-TDBHT: heap-based TMFG + radix sort + approximate APSP (the
    // paper's fastest configuration). The builder validates everything
    // up front and resolves into a staged plan.
    let mut plan = ClusterRequest::panel(ds.data.clone())
        .algo(TmfgAlgo::Opt)
        .labels(ds.labels.clone())
        .k(4)
        .build()?;

    // Stages run individually; each leaves an inspectable artifact.
    let tmfg = plan.run_tmfg()?;
    println!("TMFG: {} edges over {} series", tmfg.edges.len(), tmfg.n);

    // The same TMFG serves both APSP solvers: run the exact one for a
    // reference clustering, then switch back to OPT's approximate mode
    // (only the APSP/DBHT/cut artifacts are invalidated)...
    plan.set_apsp_mode(ApspMode::Exact);
    let exact_labels = plan.run_cut(4)?.to_vec();
    plan.set_apsp_mode(ApspMode::Approx);
    // ...and finish under the paper's fast configuration (cuts at k,
    // computes ARI, reports per-stage timings).
    let out = plan.finish()?;
    let exact_ari = tmfg::metrics::adjusted_rand_index(&ds.labels, &exact_labels);
    println!("exact-APSP reference ARI: {exact_ari:.3}");

    println!("\nstage breakdown:\n{}", out.breakdown.table());
    println!("edge sum {:.2}", out.edge_sum);
    println!("DBHT: {} converging bubbles", out.dbht.n_converging);
    println!("ARI vs ground truth (k=4): {:.3}", out.ari.unwrap_or(f64::NAN));

    // The dendrogram is a full hierarchy — cut it anywhere you like:
    for k in [2, 4, 8] {
        let labels = out.dbht.dendrogram.cut(k);
        let ari = tmfg::metrics::adjusted_rand_index(&ds.labels, &labels);
        println!("  cut at k={k}: ARI {ari:.3}");
    }
    Ok(())
}
