//! Quickstart: cluster a small synthetic time-series dataset end to end.
//!
//!     cargo run --release --example quickstart

use tmfg::coordinator::pipeline::{Pipeline, PipelineConfig, TmfgAlgo};
use tmfg::data::synth::SynthSpec;

fn main() {
    // 200 series of length 64 from 4 latent classes.
    let ds = SynthSpec::new("quickstart", 200, 64, 4).generate(42);

    // OPT-TDBHT: heap-based TMFG + radix sort + vectorized scans +
    // approximate APSP (the paper's fastest configuration).
    let cfg = PipelineConfig { algo: TmfgAlgo::Opt, ..Default::default() };
    let out = Pipeline::new(cfg).run_dataset(&ds);

    println!("stage breakdown:\n{}", out.breakdown.table());
    println!("TMFG: {} edges, edge sum {:.2}", out.tmfg.edges.len(), out.edge_sum);
    println!("DBHT: {} converging bubbles", out.dbht.n_converging);
    println!("ARI vs ground truth (k=4): {:.3}", out.ari.unwrap());

    // The dendrogram is a full hierarchy — cut it anywhere you like:
    for k in [2, 4, 8] {
        let labels = out.dbht.dendrogram.cut(k);
        let ari = tmfg::metrics::adjusted_rand_index(&ds.labels, &labels);
        println!("  cut at k={k}: ARI {ari:.3}");
    }
}
