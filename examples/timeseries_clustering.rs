//! End-to-end driver (the EXPERIMENTS.md headline run): the full paper
//! pipeline — similarity via the AOT-compiled XLA artifact where a shape
//! bucket fits, TMFG, APSP, DBHT — over the Table-1 mirror suite,
//! comparing the paper's methods on runtime and ARI.
//!
//!     cargo run --release --example timeseries_clustering -- \
//!         [--scale 0.1] [--seed N] [--datasets CBF,Crop] [--algos opt,par10]

use std::io::Write;
use tmfg::coordinator::pipeline::{Pipeline, PipelineConfig, TmfgAlgo};
use tmfg::coordinator::registry;
use tmfg::util::cli::Args;
use tmfg::util::timer::Timer;

fn main() {
    let args = Args::parse(&["scale", "seed", "datasets", "algos", "no-xla"]).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    let scale = args.get_f64("scale", 0.1);
    let seed = args.get_u64("seed", registry::DEFAULT_SEED);
    let names: Vec<String> = args
        .opt_str("datasets")
        .map(|s| s.split(',').map(|x| x.trim().to_string()).collect())
        .unwrap_or_else(registry::table1_names);
    let algos: Vec<TmfgAlgo> = args
        .opt_str("algos")
        .map(|s| {
            s.split(',')
                .filter_map(TmfgAlgo::parse)
                .collect::<Vec<_>>()
        })
        .unwrap_or_else(|| vec![TmfgAlgo::Par(10), TmfgAlgo::Opt]);
    let use_xla = !args.get_bool("no-xla", false);

    println!("== timeseries clustering e2e (scale {scale}, {} datasets) ==", names.len());
    std::fs::create_dir_all("results").ok();
    let mut csv = std::fs::File::create("results/e2e_timeseries.csv").unwrap();
    writeln!(csv, "dataset,n,L,k,algo,corr_path,total_s,similarity_s,tmfg_s,apsp_s,dbht_s,ari,edge_sum").unwrap();

    let mut ari_sums = vec![0.0f64; algos.len()];
    let mut time_sums = vec![0.0f64; algos.len()];
    for name in &names {
        let Some(ds) = registry::get_dataset(name, scale, seed) else {
            eprintln!("skipping unknown dataset {name}");
            continue;
        };
        for (ai, algo) in algos.iter().enumerate() {
            let cfg = PipelineConfig { algo: *algo, use_xla, ..Default::default() };
            let pipeline = Pipeline::new(cfg);
            let t = Timer::start();
            let out = pipeline.run_dataset(&ds).unwrap_or_else(|e| {
                eprintln!("pipeline failed on {}: {e}", ds.name);
                std::process::exit(1);
            });
            let total = t.elapsed();
            let g = |k: &str| out.breakdown.get(k).unwrap_or(0.0);
            let tmfg_s = g("tmfg:init-faces") + g("tmfg:sort") + g("tmfg:add-vertices");
            let ari = out.ari.unwrap();
            ari_sums[ai] += ari;
            time_sums[ai] += total;
            println!(
                "{:<28} n={:<6} {:<12} {:?}  total {:>8.3}s (sim {:>7.3} tmfg {:>7.3} apsp {:>7.3} dbht {:>7.3})  ARI {:+.3}",
                ds.name,
                ds.n(),
                algo.name(),
                out.corr_path.unwrap(),
                total,
                g("similarity"),
                tmfg_s,
                g("apsp"),
                g("dbht"),
                ari
            );
            writeln!(
                csv,
                "{},{},{},{},{},{:?},{:.6},{:.6},{:.6},{:.6},{:.6},{:.5},{:.4}",
                ds.name,
                ds.n(),
                ds.len(),
                ds.n_classes,
                algo.name(),
                out.corr_path.unwrap(),
                total,
                g("similarity"),
                tmfg_s,
                g("apsp"),
                g("dbht"),
                ari,
                out.edge_sum
            )
            .unwrap();
        }
    }
    println!("\n== summary over {} datasets ==", names.len());
    for (ai, algo) in algos.iter().enumerate() {
        println!(
            "{:<12} mean ARI {:.3}   total wall time {:.2}s",
            algo.name(),
            ari_sums[ai] / names.len() as f64,
            time_sums[ai]
        );
    }
    println!("wrote results/e2e_timeseries.csv");
}
