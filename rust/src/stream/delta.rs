//! Change detection between the correlation matrix backing the current
//! TMFG topology and the freshly updated one.
//!
//! After each tick the session diffs the new window correlation against
//! the matrix the standing TMFG was built from and picks between two
//! paths: *refresh* (keep the filtered-graph topology; re-derive edge
//! weights, APSP distances, and dendrogram heights from the new matrix)
//! and *rebuild* (full TMFG reconstruction). Refresh skips the most
//! expensive stages (initial sort + vertex insertion) and is correct as
//! long as the correlation ordering has not moved enough to change which
//! edges the TMFG would keep — the drift threshold is the knob trading
//! that staleness against per-tick cost, and `max_refreshes` bounds how
//! long a topology may persist under slow drift that never trips the
//! threshold.

use crate::data::matrix::Matrix;
use crate::parlay;

/// Elementwise drift summary between two same-shape matrices.
#[derive(Debug, Clone, Copy, Default)]
pub struct Drift {
    pub max_abs: f32,
    pub mean_abs: f32,
}

/// Parallel elementwise |old − new| reduction (max and mean).
pub fn corr_drift(old: &Matrix, new: &Matrix) -> Drift {
    assert_eq!(
        (old.rows, old.cols),
        (new.rows, new.cols),
        "drift requires same-shape matrices"
    );
    let m = old.data.len();
    if m == 0 {
        return Drift::default();
    }
    let (oa, na) = (&old.data, &new.data);
    let (sum, max) = parlay::par_reduce(
        m,
        4096,
        (0.0f64, 0.0f64),
        |i| {
            let d = (oa[i] - na[i]).abs() as f64;
            (d, d)
        },
        |a, b| (a.0 + b.0, a.1.max(b.1)),
    );
    Drift { max_abs: max as f32, mean_abs: (sum / m as f64) as f32 }
}

/// When to abandon the standing topology.
#[derive(Debug, Clone, Copy)]
pub struct DeltaPolicy {
    /// Rebuild when any correlation entry moved more than this since the
    /// matrix the current TMFG was built from.
    pub drift_threshold: f32,
    /// Rebuild after this many consecutive refreshes regardless of drift
    /// (0 = unlimited), so slow sub-threshold drift cannot keep a stale
    /// topology alive forever.
    pub max_refreshes: u32,
}

impl Default for DeltaPolicy {
    fn default() -> Self {
        DeltaPolicy { drift_threshold: 0.1, max_refreshes: 64 }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    /// Keep the TMFG topology; re-derive weights/APSP/dendrogram heights.
    Refresh,
    /// Rebuild the TMFG from the current correlation matrix.
    Rebuild,
}

impl DeltaPolicy {
    pub fn decide(&self, drift: Drift, refreshes_since_rebuild: u32) -> Decision {
        if drift.max_abs > self.drift_threshold {
            return Decision::Rebuild;
        }
        if self.max_refreshes > 0 && refreshes_since_rebuild >= self.max_refreshes {
            return Decision::Rebuild;
        }
        Decision::Refresh
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drift_max_and_mean() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 0.5, 0.5, 1.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 0.1, 0.9, 1.0]);
        let d = corr_drift(&a, &b);
        assert!((d.max_abs - 0.4).abs() < 1e-6);
        assert!((d.mean_abs - 0.2).abs() < 1e-6);
        let z = corr_drift(&a, &a);
        assert_eq!(z.max_abs, 0.0);
        assert_eq!(z.mean_abs, 0.0);
    }

    #[test]
    fn policy_thresholds() {
        let p = DeltaPolicy { drift_threshold: 0.25, max_refreshes: 3 };
        let small = Drift { max_abs: 0.2, mean_abs: 0.01 };
        let big = Drift { max_abs: 0.3, mean_abs: 0.01 };
        assert_eq!(p.decide(small, 0), Decision::Refresh);
        assert_eq!(p.decide(big, 0), Decision::Rebuild);
        // refresh budget exhaustion
        assert_eq!(p.decide(small, 2), Decision::Refresh);
        assert_eq!(p.decide(small, 3), Decision::Rebuild);
        // unlimited refreshes when max_refreshes = 0
        let p0 = DeltaPolicy { drift_threshold: 0.25, max_refreshes: 0 };
        assert_eq!(p0.decide(small, 1_000_000), Decision::Refresh);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let a = Matrix::zeros(2, 2);
        let b = Matrix::zeros(3, 3);
        corr_drift(&a, &b);
    }
}
