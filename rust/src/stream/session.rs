//! The streaming state machine: ingest → maybe-rebuild → emit.
//!
//! A [`StreamSession`] owns a [`SlidingWindow`] of per-series sufficient
//! statistics plus the TMFG topology (and the correlation matrix it was
//! built from). Each `tick` pushes one observation per series, updates
//! the Pearson matrix in O(n²), and — once the window is warm — either
//! *refreshes* the standing topology (new edge weights → APSP → DBHT
//! dendrogram heights) or *rebuilds* it from scratch, per the
//! [`DeltaPolicy`]. Every emission carries a monotonically increasing
//! generation counter; a bounded snapshot history keeps recent labelings
//! for clients that poll.

use crate::api::{build_apsp_oracle, build_tmfg_for, ApspMode, TmfgAlgo};
use crate::error::TmfgError;
use crate::apsp::{CsrGraph, HubConfig};
use crate::data::matrix::Matrix;
use crate::dbht::hierarchy::dbht_dendrogram;
use crate::dbht::Linkage;
use crate::stream::delta::{corr_drift, Decision, DeltaPolicy, Drift};
use crate::stream::window::SlidingWindow;
use crate::tmfg::TmfgResult;
use crate::util::timer::Timer;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};

/// Process-wide unique session ids. The service echoes the id in every
/// `open_stream`/`tick`/`close_stream` response so multi-tenant clients
/// (and the concurrency test suite) can verify that a tick was served by
/// the session their own connection opened — never a neighbor's.
static SESSION_SEQ: AtomicU64 = AtomicU64::new(1);

#[derive(Debug, Clone)]
pub struct StreamConfig {
    /// Number of series (TMFG needs ≥ 4).
    pub n: usize,
    /// Sliding-window length L (samples per series).
    pub window: usize,
    /// Clusters to cut the dendrogram into on each emission.
    pub k: usize,
    pub algo: TmfgAlgo,
    pub linkage: Linkage,
    /// None = algorithm default (Opt → approx, everything else → exact),
    /// mirroring `PipelineConfig`.
    pub apsp: Option<ApspMode>,
    pub hub: HubConfig,
    pub policy: DeltaPolicy,
    /// Minimum samples in the window before clusterings are emitted
    /// (clamped to [2, window]).
    pub warmup: usize,
    /// Exact sufficient-statistics rebuild period in ticks (0 = never);
    /// bounds floating-point drift on unbounded streams.
    pub refresh_stats_every: u64,
    /// Number of past emissions kept in the snapshot history.
    pub history: usize,
}

impl StreamConfig {
    pub fn new(n: usize, window: usize, k: usize) -> StreamConfig {
        StreamConfig {
            n,
            window,
            k,
            algo: TmfgAlgo::Opt,
            linkage: Linkage::Complete,
            apsp: None,
            hub: HubConfig::default(),
            policy: DeltaPolicy::default(),
            warmup: 8,
            refresh_stats_every: 4096,
            history: 16,
        }
    }
}

/// What a tick did.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TickDecision {
    /// Window not warm yet — no clustering emitted.
    Warming,
    /// Full TMFG reconstruction from the new correlation matrix.
    Rebuilt,
    /// Topology kept; weights + APSP + dendrogram heights re-derived.
    Refreshed,
}

impl TickDecision {
    pub fn name(&self) -> &'static str {
        match self {
            TickDecision::Warming => "warming",
            TickDecision::Rebuilt => "rebuild",
            TickDecision::Refreshed => "refresh",
        }
    }
}

/// Per-tick result. `labels`/`drift` are None while warming (and `drift`
/// also on the very first emission, which has no standing topology to
/// diff against).
#[derive(Debug, Clone)]
pub struct TickOutput {
    pub tick: u64,
    /// Per-tick trace id, echoed on the wire `tick` response (like
    /// batch responses) so flight-recorder wide events, trace exports,
    /// and client-side logs correlate.
    pub trace_id: String,
    pub generation: u64,
    pub decision: TickDecision,
    pub labels: Option<Vec<usize>>,
    pub drift: Option<Drift>,
    pub secs: f64,
}

#[derive(Debug, Clone, Default)]
pub struct StreamStats {
    pub ticks: u64,
    pub emissions: u64,
    pub rebuilds: u64,
    pub refreshes: u64,
}

/// One retained emission.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub tick: u64,
    pub generation: u64,
    pub decision: TickDecision,
    pub labels: Vec<usize>,
}

pub struct StreamSession {
    pub config: StreamConfig,
    /// Process-wide unique id (see [`StreamSession::id`]).
    id: u64,
    window: SlidingWindow,
    tmfg: Option<TmfgResult>,
    /// Correlation matrix backing the current TMFG topology (drift is
    /// measured against this, not against the previous tick).
    tmfg_corr: Option<Matrix>,
    generation: u64,
    refreshes_since_rebuild: u32,
    stats: StreamStats,
    history: VecDeque<Snapshot>,
}

impl StreamSession {
    pub fn new(config: StreamConfig) -> Result<StreamSession, TmfgError> {
        if config.n < 4 {
            return Err(TmfgError::invalid(format!(
                "streaming needs n >= 4 series for TMFG, got {}",
                config.n
            )));
        }
        if config.window < 2 {
            return Err(TmfgError::invalid("window must hold at least 2 samples"));
        }
        if config.k < 1 || config.k > config.n {
            return Err(TmfgError::invalid(format!(
                "k must be in 1..={}, got {}",
                config.n, config.k
            )));
        }
        let window = SlidingWindow::new(config.n, config.window, config.refresh_stats_every);
        Ok(StreamSession {
            id: SESSION_SEQ.fetch_add(1, Ordering::Relaxed),
            window,
            tmfg: None,
            tmfg_corr: None,
            generation: 0,
            refreshes_since_rebuild: 0,
            stats: StreamStats::default(),
            history: VecDeque::new(),
            config,
        })
    }

    fn warmup(&self) -> usize {
        self.config.warmup.clamp(2, self.config.window)
    }

    fn effective_apsp(&self) -> ApspMode {
        self.config.apsp.unwrap_or_else(|| self.config.algo.default_apsp())
    }

    /// Unique id of this session (process-wide, never reused).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Generation of the latest emission (0 until the first one).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    pub fn stats(&self) -> &StreamStats {
        &self.stats
    }

    /// Recent emissions, oldest first (bounded by `config.history`).
    pub fn history(&self) -> &VecDeque<Snapshot> {
        &self.history
    }

    pub fn window(&self) -> &SlidingWindow {
        &self.window
    }

    /// The standing TMFG topology, if one has been built.
    pub fn topology(&self) -> Option<&TmfgResult> {
        self.tmfg.as_ref()
    }

    /// Ingest one observation per series; returns what happened.
    pub fn tick(&mut self, sample: &[f32]) -> Result<TickOutput, TmfgError> {
        if sample.len() != self.config.n {
            return Err(TmfgError::invalid(format!(
                "sample length {} != n = {}",
                sample.len(),
                self.config.n
            )));
        }
        // A single NaN/inf would poison the incremental cross-products —
        // and keep poisoning them after eviction (NaN − NaN = NaN) until
        // the next exact stats rebuild — so reject it before it enters.
        if let Some(pos) = sample.iter().position(|v| !v.is_finite()) {
            return Err(TmfgError::invalid(format!(
                "non-finite sample value {} for series {pos}",
                sample[pos]
            )));
        }
        let t = Timer::start();
        let trace_id = crate::obs::next_trace_id();
        self.window.push(sample);
        self.stats.ticks += 1;
        let tick = self.stats.ticks;
        if self.window.len() < self.warmup() {
            return Ok(TickOutput {
                tick,
                trace_id,
                generation: self.generation,
                decision: TickDecision::Warming,
                labels: None,
                drift: None,
                secs: t.elapsed(),
            });
        }
        let s = self.window.corr_matrix();
        let (decision, drift) = match (&self.tmfg, &self.tmfg_corr) {
            (Some(_), Some(backing)) => {
                let d = corr_drift(backing, &s);
                let dec = match self.config.policy.decide(d, self.refreshes_since_rebuild) {
                    Decision::Rebuild => TickDecision::Rebuilt,
                    Decision::Refresh => TickDecision::Refreshed,
                };
                (dec, Some(d))
            }
            _ => (TickDecision::Rebuilt, None),
        };
        let labels = match decision {
            TickDecision::Rebuilt => self.rebuild(s)?,
            TickDecision::Refreshed => self.refresh(&s)?,
            TickDecision::Warming => {
                return Err(TmfgError::invariant("warming decision past the warmup gate"))
            }
        };
        self.generation += 1;
        self.stats.emissions += 1;
        if self.config.history > 0 {
            if self.history.len() == self.config.history {
                self.history.pop_front();
            }
            self.history.push_back(Snapshot {
                tick,
                generation: self.generation,
                decision,
                labels: labels.clone(),
            });
        }
        Ok(TickOutput {
            tick,
            trace_id,
            generation: self.generation,
            decision,
            labels: Some(labels),
            drift,
            secs: t.elapsed(),
        })
    }

    fn rebuild(&mut self, s: Matrix) -> Result<Vec<usize>, TmfgError> {
        let tmfg = build_tmfg_for(self.config.algo, &s)?;
        let labels = self.cluster(&tmfg, &s)?;
        self.tmfg = Some(tmfg);
        self.tmfg_corr = Some(s);
        self.refreshes_since_rebuild = 0;
        self.stats.rebuilds += 1;
        Ok(labels)
    }

    fn refresh(&mut self, s: &Matrix) -> Result<Vec<usize>, TmfgError> {
        let Some(tmfg) = self.tmfg.as_ref() else {
            return Err(TmfgError::invariant("refresh without a standing topology"));
        };
        let labels = self.cluster(tmfg, s)?;
        self.refreshes_since_rebuild += 1;
        self.stats.refreshes += 1;
        Ok(labels)
    }

    /// The downstream stages shared by both paths: edge weights from the
    /// current matrix → APSP oracle → DBHT dendrogram → cut at k. The
    /// oracle backend follows the session's APSP mode, so approximate
    /// sessions never allocate an n×n distance matrix per emission.
    fn cluster(&self, tmfg: &TmfgResult, s: &Matrix) -> Result<Vec<usize>, TmfgError> {
        let g = CsrGraph::from_tmfg(tmfg, s);
        let apsp = build_apsp_oracle(self.effective_apsp(), &g, &self.config.hub);
        let dbht = dbht_dendrogram(s, tmfg, &*apsp, self.config.linkage)?;
        Ok(dbht.dendrogram.cut(self.config.k))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn cfg(n: usize, window: usize, k: usize) -> StreamConfig {
        let mut c = StreamConfig::new(n, window, k);
        c.algo = TmfgAlgo::Heap; // exact APSP, deterministic
        c.warmup = 4;
        c
    }

    fn gaussian_sample(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.next_gaussian() as f32).collect()
    }

    #[test]
    fn session_ids_are_unique() {
        let a = StreamSession::new(cfg(8, 16, 2)).unwrap();
        let b = StreamSession::new(cfg(8, 16, 2)).unwrap();
        assert_ne!(a.id(), b.id());
        assert!(a.id() > 0 && b.id() > 0);
    }

    #[test]
    fn config_validation() {
        assert!(StreamSession::new(StreamConfig::new(3, 8, 1)).is_err());
        assert!(StreamSession::new(StreamConfig::new(8, 1, 1)).is_err());
        assert!(StreamSession::new(StreamConfig::new(8, 8, 0)).is_err());
        assert!(StreamSession::new(StreamConfig::new(8, 8, 9)).is_err());
        assert!(StreamSession::new(StreamConfig::new(8, 8, 3)).is_ok());
    }

    #[test]
    fn warms_then_emits_with_monotone_generations() {
        let mut s = StreamSession::new(cfg(8, 16, 2)).unwrap();
        let mut rng = Rng::new(1);
        let mut last_gen = 0u64;
        let mut last_trace = String::new();
        for t in 1..=20u64 {
            let out = s.tick(&gaussian_sample(&mut rng, 8)).unwrap();
            assert_eq!(out.tick, t);
            // Every tick — warming included — carries a fresh trace id.
            assert!(out.trace_id.starts_with('t'), "{}", out.trace_id);
            assert_ne!(out.trace_id, last_trace);
            last_trace = out.trace_id.clone();
            if t < 4 {
                assert_eq!(out.decision, TickDecision::Warming);
                assert!(out.labels.is_none());
                assert_eq!(out.generation, 0);
            } else {
                let labels = out.labels.expect("warm tick must emit");
                assert_eq!(labels.len(), 8);
                assert_eq!(out.generation, last_gen + 1, "generation must step by 1");
            }
            assert!(out.generation >= last_gen);
            last_gen = out.generation;
        }
        assert_eq!(s.generation(), 17);
        let st = s.stats();
        assert_eq!(st.ticks, 20);
        assert_eq!(st.emissions, 17);
        assert_eq!(st.rebuilds + st.refreshes, 17);
        assert!(st.rebuilds >= 1);
    }

    #[test]
    fn first_emission_rebuilds_without_drift() {
        let mut s = StreamSession::new(cfg(8, 16, 2)).unwrap();
        let mut rng = Rng::new(2);
        let mut first = None;
        for _ in 0..6 {
            let out = s.tick(&gaussian_sample(&mut rng, 8)).unwrap();
            if out.labels.is_some() && first.is_none() {
                first = Some(out);
            }
        }
        let first = first.unwrap();
        assert_eq!(first.decision, TickDecision::Rebuilt);
        assert!(first.drift.is_none());
    }

    #[test]
    fn max_refreshes_forces_rebuild_cadence() {
        let mut c = cfg(8, 16, 2);
        // threshold 10 can never trip (|Δρ| ≤ 2), so only the refresh
        // budget drives rebuilds: R, r, r, r, R, r, r, r, ...
        c.policy = DeltaPolicy { drift_threshold: 10.0, max_refreshes: 3 };
        let mut s = StreamSession::new(c).unwrap();
        let mut rng = Rng::new(3);
        let mut decisions = Vec::new();
        for _ in 0..20 {
            let out = s.tick(&gaussian_sample(&mut rng, 8)).unwrap();
            if out.labels.is_some() {
                decisions.push(out.decision);
            }
        }
        for (i, d) in decisions.iter().enumerate() {
            let expect = if i % 4 == 0 { TickDecision::Rebuilt } else { TickDecision::Refreshed };
            assert_eq!(*d, expect, "emission {i}");
        }
    }

    #[test]
    fn zero_threshold_always_rebuilds() {
        let mut c = cfg(8, 12, 2);
        c.policy = DeltaPolicy { drift_threshold: -1.0, max_refreshes: 0 };
        let mut s = StreamSession::new(c).unwrap();
        let mut rng = Rng::new(4);
        for _ in 0..10 {
            let out = s.tick(&gaussian_sample(&mut rng, 8)).unwrap();
            if out.labels.is_some() {
                assert_eq!(out.decision, TickDecision::Rebuilt);
            }
        }
        assert_eq!(s.stats().refreshes, 0);
    }

    #[test]
    fn history_is_bounded_and_ordered() {
        let mut c = cfg(8, 16, 2);
        c.history = 3;
        let mut s = StreamSession::new(c).unwrap();
        let mut rng = Rng::new(5);
        for _ in 0..12 {
            s.tick(&gaussian_sample(&mut rng, 8)).unwrap();
        }
        let h = s.history();
        assert_eq!(h.len(), 3);
        let gens: Vec<u64> = h.iter().map(|x| x.generation).collect();
        assert_eq!(gens, vec![s.generation() - 2, s.generation() - 1, s.generation()]);
    }

    #[test]
    fn wrong_length_sample_is_an_error() {
        let mut s = StreamSession::new(cfg(8, 16, 2)).unwrap();
        assert!(s.tick(&[1.0; 5]).is_err());
        // session still usable afterwards
        let mut rng = Rng::new(6);
        assert!(s.tick(&gaussian_sample(&mut rng, 8)).is_ok());
    }

    #[test]
    fn non_finite_samples_are_rejected_and_do_not_poison_stats() {
        let mut s = StreamSession::new(cfg(8, 16, 2)).unwrap();
        let mut rng = Rng::new(16);
        for _ in 0..6 {
            s.tick(&gaussian_sample(&mut rng, 8)).unwrap();
        }
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let mut sample = gaussian_sample(&mut rng, 8);
            sample[3] = bad;
            let err = s.tick(&sample).unwrap_err().to_string();
            assert!(err.contains("non-finite"), "{err}");
            assert!(err.contains("series 3"), "{err}");
        }
        // the rejected ticks never entered the window or the stats
        assert_eq!(s.stats().ticks, 6);
        let out = s.tick(&gaussian_sample(&mut rng, 8)).unwrap();
        let labels = out.labels.unwrap();
        assert!(labels.len() == 8);
        for row in s.window().corr_f64() {
            assert!(row.is_finite());
        }
    }

    #[test]
    fn cut_always_yields_k_clusters() {
        let mut s = StreamSession::new(cfg(12, 16, 4)).unwrap();
        let mut rng = Rng::new(7);
        for _ in 0..10 {
            let out = s.tick(&gaussian_sample(&mut rng, 12)).unwrap();
            if let Some(labels) = out.labels {
                let uniq: std::collections::HashSet<_> = labels.iter().collect();
                assert_eq!(uniq.len(), 4);
            }
        }
    }
}
