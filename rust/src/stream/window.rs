//! Sliding-window sufficient statistics for streaming Pearson correlation.
//!
//! Holds the last `cap` observations of `n` series in a ring buffer
//! together with the running sums Σxᵢ and the full n×n cross-product
//! matrix Σxᵢxⱼ. Appending one tick (and evicting the oldest sample once
//! the window is full) is a rank-2 update of the statistics costing
//! O(n²), versus the O(n²·L) full recompute in [`crate::data::corr`] —
//! the asymptotic win the streaming subsystem is built on. The update is
//! parallelized over the `parlay` pool with the same triangle-balanced
//! row pairing as `pearson_correlation`.
//!
//! All accumulators are f64, so the incremental correlations match a
//! two-pass f64 recompute ([`crate::data::corr::pearson_correlation_f64`])
//! to ~1e-12 over hundreds of ticks; an optional periodic exact rebuild
//! (`refresh_every`) bounds the drift on unbounded streams.

use crate::data::matrix::Matrix;
use crate::parlay::{self, SendPtr};

/// Zero-variance guard on the centered second moment (Σx² − (Σx)²/L);
/// below this a series is treated as constant and its correlations are
/// defined as 0, matching `data::corr::standardize_rows`.
const VAR_EPS: f64 = 1e-12;

#[derive(Debug, Clone)]
pub struct SlidingWindow {
    n: usize,
    cap: usize,
    len: usize,
    /// Slot holding the oldest sample (== the next write position once
    /// the window is full).
    head: usize,
    /// Ring storage, slot-major: `buf[slot * n + i]` = series `i` at slot.
    buf: Vec<f32>,
    /// Per-series running sum Σxᵢ over the window.
    sum: Vec<f64>,
    /// Row-major n×n cross-product matrix Σxᵢxⱼ over the window.
    cross: Vec<f64>,
    ticks: u64,
    /// Rebuild the statistics exactly from the ring every this many ticks
    /// (0 = never).
    refresh_every: u64,
}

impl SlidingWindow {
    /// A window over `n` series holding up to `cap` samples each.
    pub fn new(n: usize, cap: usize, refresh_every: u64) -> SlidingWindow {
        assert!(n > 0 && cap > 0, "window needs n > 0 and cap > 0");
        SlidingWindow {
            n,
            cap,
            len: 0,
            head: 0,
            buf: vec![0.0; n * cap],
            sum: vec![0.0; n],
            cross: vec![0.0; n * n],
            ticks: 0,
            refresh_every,
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Samples currently held (≤ capacity).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn is_full(&self) -> bool {
        self.len == self.cap
    }

    /// Total ticks pushed over the window's lifetime.
    pub fn ticks(&self) -> u64 {
        self.ticks
    }

    #[inline]
    fn slot_at(&self, s: usize) -> usize {
        (self.head + s) % self.cap
    }

    /// Append one observation per series, evicting the oldest sample when
    /// full. O(n²) parallel rank-2 update of the sufficient statistics.
    ///
    /// Values must be finite: a NaN/inf corrupts the running statistics
    /// beyond its own eviction (NaN − NaN = NaN) until the next exact
    /// rebuild. `StreamSession::tick` validates this; callers using the
    /// window directly must do the same (or call `rebuild_stats`).
    pub fn push(&mut self, sample: &[f32]) {
        assert_eq!(sample.len(), self.n, "sample length != n");
        let n = self.n;
        let slot = (self.head + self.len) % self.cap;
        // Copy the evicted column before overwriting its slot.
        let evicted: Option<Vec<f64>> = if self.len == self.cap {
            Some(self.buf[slot * n..(slot + 1) * n].iter().map(|&v| v as f64).collect())
        } else {
            None
        };
        let fresh: Vec<f64> = sample.iter().map(|&v| v as f64).collect();
        self.buf[slot * n..(slot + 1) * n].copy_from_slice(sample);
        {
            let cp = SendPtr(self.cross.as_mut_ptr());
            let sp = SendPtr(self.sum.as_mut_ptr());
            let old = evicted.as_deref();
            let new = &fresh;
            parlay::par_symmetric_rows(n, |i| {
                let di = new[i] - old.map_or(0.0, |o| o[i]);
                // SAFETY: par_symmetric_rows visits each row i exactly
                // once, so sum[i] and the (i,j≥i)/(j,i) cell pairs below
                // are written by a single task.
                unsafe { sp.write(i, sp.read(i) + di) };
                for j in i..n {
                    let delta = new[i] * new[j] - old.map_or(0.0, |o| o[i] * o[j]);
                    let a = i * n + j;
                    unsafe { cp.write(a, cp.read(a) + delta) };
                    if j != i {
                        let b = j * n + i;
                        unsafe { cp.write(b, cp.read(b) + delta) };
                    }
                }
            });
        }
        if evicted.is_some() {
            self.head = (self.head + 1) % self.cap;
        } else {
            self.len += 1;
        }
        self.ticks += 1;
        if self.refresh_every > 0 && self.ticks % self.refresh_every == 0 {
            self.rebuild_stats();
        }
    }

    /// Recompute Σxᵢ and Σxᵢxⱼ exactly from the ring contents (O(n²·L)),
    /// discarding any accumulated floating-point drift.
    pub fn rebuild_stats(&mut self) {
        let n = self.n;
        let len = self.len;
        let slots: Vec<usize> = (0..len).map(|s| self.slot_at(s)).collect();
        let buf = &self.buf;
        self.sum = parlay::par_map(n, 8, |i| {
            let mut acc = 0.0f64;
            for &sl in &slots {
                acc += buf[sl * n + i] as f64;
            }
            acc
        });
        let cp = SendPtr(self.cross.as_mut_ptr());
        parlay::par_symmetric_rows(n, |i| {
            for j in i..n {
                let mut acc = 0.0f64;
                for &sl in &slots {
                    acc += buf[sl * n + i] as f64 * buf[sl * n + j] as f64;
                }
                // SAFETY: par_symmetric_rows visits each row once; the
                // (i,j≥i)/(j,i) cell pairs belong to row i's task alone.
                unsafe {
                    cp.write(i * n + j, acc);
                    if j != i {
                        cp.write(j * n + i, acc);
                    }
                }
            }
        });
    }

    /// Window contents as an n×len panel, columns ordered oldest→newest
    /// (the input a full recompute would consume).
    pub fn contents(&self) -> Matrix {
        let n = self.n;
        let len = self.len;
        let mut m = Matrix::zeros(n, len);
        if len == 0 {
            return m;
        }
        let mp = SendPtr(m.data.as_mut_ptr());
        parlay::parallel_for(n, 8, |i| {
            for s in 0..len {
                // SAFETY: row i written only by iteration i.
                unsafe { mp.write(i * len + s, self.buf[self.slot_at(s) * n + i]) };
            }
        });
        m
    }

    /// Pearson correlation from the sufficient statistics, in f64:
    /// ρᵢⱼ = cᵢⱼ / √(cᵢᵢ·cⱼⱼ) with cᵢⱼ = Σxᵢxⱼ − ΣxᵢΣxⱼ/L. Rows with
    /// ~zero variance correlate 0 with everything; the diagonal is 1.
    pub fn corr_f64(&self) -> Vec<f64> {
        let n = self.n;
        let mut out = vec![0.0f64; n * n];
        if self.len < 2 {
            for i in 0..n {
                out[i * n + i] = 1.0;
            }
            return out;
        }
        let l = self.len as f64;
        let var: Vec<f64> = (0..n)
            .map(|i| self.cross[i * n + i] - self.sum[i] * self.sum[i] / l)
            .collect();
        let op = SendPtr(out.as_mut_ptr());
        let (cross, sum, varr) = (&self.cross, &self.sum, &var);
        parlay::par_symmetric_rows(n, |i| {
            for j in i..n {
                let v = if i == j {
                    1.0
                } else if varr[i] <= VAR_EPS || varr[j] <= VAR_EPS {
                    0.0
                } else {
                    let c = cross[i * n + j] - sum[i] * sum[j] / l;
                    (c / (varr[i] * varr[j]).sqrt()).clamp(-1.0, 1.0)
                };
                // SAFETY: par_symmetric_rows visits each row once; the
                // (i,j≥i)/(j,i) cell pairs belong to row i's task alone.
                unsafe {
                    op.write(i * n + j, v);
                    if j != i {
                        op.write(j * n + i, v);
                    }
                }
            }
        });
        out
    }

    /// f32 correlation matrix (the pipeline input shape).
    pub fn corr_matrix(&self) -> Matrix {
        let c = self.corr_f64();
        let n = self.n;
        let mut m = Matrix::zeros(n, n);
        let mp = SendPtr(m.data.as_mut_ptr());
        parlay::parallel_for_chunks(n * n, 4096, |a, b| {
            for idx in a..b {
                // SAFETY: disjoint chunks.
                unsafe { mp.write(idx, c[idx] as f32) };
            }
        });
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corr::{pearson_correlation, pearson_correlation_f64};
    use crate::util::rng::Rng;

    fn push_random(w: &mut SlidingWindow, rng: &mut Rng, ticks: usize) {
        let mut sample = vec![0.0f32; w.n()];
        for _ in 0..ticks {
            for v in sample.iter_mut() {
                *v = (rng.next_gaussian() * 1.5 + 0.3) as f32;
            }
            w.push(&sample);
        }
    }

    #[test]
    fn fills_then_slides() {
        let mut w = SlidingWindow::new(3, 4, 0);
        assert!(w.is_empty());
        for t in 0..6 {
            w.push(&[t as f32, 2.0 * t as f32, -(t as f32)]);
        }
        assert!(w.is_full());
        assert_eq!(w.len(), 4);
        assert_eq!(w.ticks(), 6);
        // contents are the last 4 ticks, oldest first
        let c = w.contents();
        assert_eq!(c.row(0), &[2.0, 3.0, 4.0, 5.0]);
        assert_eq!(c.row(2), &[-2.0, -3.0, -4.0, -5.0]);
    }

    #[test]
    fn incremental_matches_recompute_through_wraparound() {
        let mut rng = Rng::new(7);
        let mut w = SlidingWindow::new(11, 16, 0);
        push_random(&mut w, &mut rng, 50); // > 3 full wraps
        let inc = w.corr_f64();
        let full = pearson_correlation_f64(&w.contents());
        for (a, b) in inc.iter().zip(&full) {
            assert!((a - b).abs() < 1e-11, "{a} vs {b}");
        }
        // and against the f32 production path, loosely
        let f32_path = pearson_correlation(&w.contents());
        let m = w.corr_matrix();
        assert!(m.max_abs_diff(&f32_path) < 1e-4);
    }

    #[test]
    fn rebuild_stats_is_a_noop_within_tolerance() {
        let mut rng = Rng::new(9);
        let mut w = SlidingWindow::new(8, 12, 0);
        push_random(&mut w, &mut rng, 40);
        let before = w.corr_f64();
        w.rebuild_stats();
        let after = w.corr_f64();
        for (a, b) in before.iter().zip(&after) {
            assert!((a - b).abs() < 1e-11, "{a} vs {b}");
        }
    }

    #[test]
    fn periodic_refresh_fires() {
        let mut rng = Rng::new(3);
        let mut a = SlidingWindow::new(6, 8, 5); // refresh every 5 ticks
        let mut b = SlidingWindow::new(6, 8, 0);
        let mut sample = vec![0.0f32; 6];
        for _ in 0..23 {
            for v in sample.iter_mut() {
                *v = rng.next_f32() * 4.0 - 2.0;
            }
            a.push(&sample);
            b.push(&sample);
        }
        let (ca, cb) = (a.corr_f64(), b.corr_f64());
        for (x, y) in ca.iter().zip(&cb) {
            assert!((x - y).abs() < 1e-11);
        }
    }

    #[test]
    fn constant_series_correlates_zero() {
        let mut w = SlidingWindow::new(3, 8, 0);
        for t in 0..8 {
            w.push(&[5.0, t as f32, (t as f32).sin()]);
        }
        let c = w.corr_f64();
        assert_eq!(c[0], 1.0); // diagonal stays 1
        assert_eq!(c[1], 0.0); // constant row: 0 off-diagonal
        assert_eq!(c[2], 0.0);
        assert_eq!(c[3], 0.0); // symmetric counterpart
    }

    #[test]
    fn perfectly_correlated_pair() {
        let mut w = SlidingWindow::new(2, 6, 0);
        for t in 0..10 {
            let x = (t as f32 * 0.7).sin();
            w.push(&[x, 3.0 * x + 1.0]);
        }
        let c = w.corr_f64();
        assert!((c[1] - 1.0).abs() < 1e-12, "{}", c[1]);
    }

    #[test]
    fn underfilled_window_is_identity() {
        let mut w = SlidingWindow::new(3, 8, 0);
        w.push(&[1.0, 2.0, 3.0]);
        let c = w.corr_f64();
        assert_eq!(c[0], 1.0);
        assert_eq!(c[1], 0.0);
    }

    #[test]
    #[should_panic]
    fn wrong_sample_length_panics() {
        let mut w = SlidingWindow::new(3, 4, 0);
        w.push(&[1.0, 2.0]);
    }
}
