//! Streaming clustering subsystem: sliding-window incremental TMFG-DBHT.
//!
//! The batch pipeline (`coordinator::pipeline`) recomputes everything
//! from scratch per request — O(n²·L) correlation plus full TMFG / APSP /
//! DBHT. For live time-series traffic, where each tick shifts a sliding
//! window by one sample, this subsystem instead:
//!
//! 1. [`window`] — maintains per-series ring buffers with running sums
//!    Σxᵢ and the cross-product matrix Σxᵢxⱼ, updating the full n×n
//!    Pearson matrix in O(n²) per tick;
//! 2. [`delta`] — diffs the new matrix against the one backing the
//!    standing TMFG and chooses between *refresh* (keep topology,
//!    re-derive edge weights + dendrogram heights) and *rebuild*;
//! 3. [`session`] — a `StreamSession` state machine (ingest →
//!    maybe-rebuild → emit labeled clustering + generation counter) with
//!    bounded snapshot history.
//!
//! Entry points: [`StreamSession`] in-process,
//! [`crate::coordinator::pipeline::Pipeline::run_stream`] for replaying a
//! panel, the `open_stream`/`tick`/`close_stream` wire commands of
//! `coordinator::service`, and the `tmfg stream` CLI subcommand.

pub mod delta;
pub mod session;
pub mod window;

pub use delta::{corr_drift, Decision, DeltaPolicy, Drift};
pub use session::{
    Snapshot, StreamConfig, StreamSession, StreamStats, TickDecision, TickOutput,
};
pub use window::SlidingWindow;
