//! Named dataset registry: the 18 Table-1 UCR-mirror synthetic datasets
//! (plus small demo sets), generated deterministically on demand.

use crate::data::loader::load_ucr_csv;
use crate::data::synth::{table1_specs, Dataset, SynthSpec};
use std::path::Path;

pub const DEFAULT_SEED: u64 = 20240711;

/// Names of the Table-1 datasets in paper order.
pub fn table1_names() -> Vec<String> {
    table1_specs(1.0).into_iter().map(|s| s.name).collect()
}

/// The three largest datasets (used by the paper's Figs. 3/4 scaling study).
pub fn largest3_names() -> [&'static str; 3] {
    ["Crop", "ElectricDevices", "StarLightCurves"]
}

/// Resolve a dataset: a Table-1 name (at the given n-scale), `demo[-N]`,
/// or a path to a UCR-style CSV file.
pub fn get_dataset(name: &str, scale: f64, seed: u64) -> Option<Dataset> {
    if let Some(rest) = name.strip_prefix("demo") {
        let n = rest
            .strip_prefix('-')
            .and_then(|v| v.parse().ok())
            .unwrap_or(200);
        return Some(SynthSpec::new(name, n, 64, 4).generate(seed));
    }
    if name.ends_with(".csv") || name.contains('/') {
        return load_ucr_csv(Path::new(name)).ok();
    }
    let spec = table1_specs(scale)
        .into_iter()
        .find(|s| s.name.eq_ignore_ascii_case(name))?;
    // Per-dataset deterministic seed so different datasets differ.
    let ds_seed = seed ^ fxhash(name);
    Some(spec.generate(ds_seed))
}

/// The series count `get_dataset` would produce for a name, *without*
/// generating anything — lets the service reject oversized requests
/// before any allocation. None for unknown names and CSV paths.
pub fn dataset_size(name: &str, scale: f64) -> Option<usize> {
    if let Some(rest) = name.strip_prefix("demo") {
        let n = rest
            .strip_prefix('-')
            .and_then(|v| v.parse().ok())
            .unwrap_or(200);
        return Some(n);
    }
    if name.ends_with(".csv") || name.contains('/') {
        return None;
    }
    table1_specs(scale)
        .into_iter()
        .find(|s| s.name.eq_ignore_ascii_case(name))
        .map(|s| s.n)
}

/// Generate all Table-1 datasets at a scale.
pub fn all_table1(scale: f64, seed: u64) -> Vec<Dataset> {
    table1_specs(scale)
        .into_iter()
        .map(|spec| {
            let ds_seed = seed ^ fxhash(&spec.name);
            spec.generate(ds_seed)
        })
        .collect()
}

fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_names_complete() {
        let names = table1_names();
        assert_eq!(names.len(), 18);
        assert!(names.contains(&"Crop".to_string()));
        for l in largest3_names() {
            assert!(names.contains(&l.to_string()));
        }
    }

    #[test]
    fn get_by_name_scaled() {
        let ds = get_dataset("CBF", 0.1, DEFAULT_SEED).unwrap();
        assert_eq!(ds.n(), 93);
        assert_eq!(ds.n_classes, 3);
        assert!(get_dataset("NoSuchDataset", 1.0, 0).is_none());
    }

    #[test]
    fn demo_sizes() {
        assert_eq!(get_dataset("demo", 1.0, 1).unwrap().n(), 200);
        assert_eq!(get_dataset("demo-50", 1.0, 1).unwrap().n(), 50);
    }

    #[test]
    fn dataset_size_predicts_without_generating() {
        assert_eq!(dataset_size("demo-50", 1.0), Some(50));
        assert_eq!(dataset_size("demo-100000000", 1.0), Some(100_000_000));
        let predicted = dataset_size("CBF", 0.1).unwrap();
        assert_eq!(predicted, get_dataset("CBF", 0.1, 1).unwrap().n());
        assert_eq!(dataset_size("NoSuchDataset", 1.0), None);
        assert_eq!(dataset_size("some/path.csv", 1.0), None);
    }

    #[test]
    fn different_datasets_differ() {
        let a = get_dataset("CBF", 0.05, DEFAULT_SEED).unwrap();
        let b = get_dataset("ECG5000", 0.05, DEFAULT_SEED).unwrap();
        assert_ne!(a.data.data.len(), 0);
        assert_ne!(a.labels, b.labels[..a.n().min(b.n())].to_vec());
    }

    #[test]
    fn deterministic_across_calls() {
        let a = get_dataset("Mallat", 0.05, 7).unwrap();
        let b = get_dataset("Mallat", 0.05, 7).unwrap();
        assert_eq!(a.data, b.data);
    }
}
