//! Named dataset registry: the 18 Table-1 UCR-mirror synthetic datasets
//! (plus small demo sets), generated deterministically on demand.

use crate::data::loader::load_ucr_csv;
use crate::data::synth::{table1_specs, Dataset, SynthSpec};
use std::path::Path;

pub const DEFAULT_SEED: u64 = 20240711;

/// Names of the Table-1 datasets in paper order.
pub fn table1_names() -> Vec<String> {
    table1_specs(1.0).into_iter().map(|s| s.name).collect()
}

/// The three largest datasets (used by the paper's Figs. 3/4 scaling study).
pub fn largest3_names() -> [&'static str; 3] {
    ["Crop", "ElectricDevices", "StarLightCurves"]
}

/// Is this name a filesystem path rather than a registry name? One
/// definition shared by resolution, size prediction, and fingerprinting
/// so the three can never disagree.
fn is_path(name: &str) -> bool {
    name.ends_with(".csv") || name.contains('/') || name.contains('\\')
}

/// The series count a `demo[-N]` name encodes (`demo` and unparsable
/// suffixes mean 200, the historic default). `None` when the name is not
/// a demo name **or** encodes n < 4 — below the TMFG/generator minimum,
/// so such names resolve to no dataset instead of panicking inside the
/// generator (`SynthSpec::generate` asserts n ≥ k).
fn demo_size(name: &str) -> Option<usize> {
    let rest = name.strip_prefix("demo")?;
    let n = rest.strip_prefix('-').and_then(|v| v.parse().ok()).unwrap_or(200);
    (n >= 4).then_some(n)
}

/// Class count of the `synth-large-N` family.
const SYNTH_LARGE_CLASSES: usize = 16;

/// Series length of the `synth-large-N` family — short on purpose: the
/// family exists to exercise large *n* (the sparse pipeline's axis), and
/// both the generator and the k-NN stage cost O(n·L) / O(n²·d).
const SYNTH_LARGE_LEN: usize = 48;

/// The series count a `synth-large-N` name encodes — the large-n family
/// served by the sparse k-NN pipeline (`sparse_k` on the wire). `None`
/// for non-family names, n below the class minimum, or n past 2²⁰
/// (names are attacker-supplied over TCP; the generator is O(n·L) so an
/// absurd n must not reach it).
fn synth_large_size(name: &str) -> Option<usize> {
    let n: usize = name.strip_prefix("synth-large-")?.parse().ok()?;
    (SYNTH_LARGE_CLASSES * 4..=1 << 20).contains(&n).then_some(n)
}

/// Resolve a dataset: a Table-1 name (at the given n-scale), `demo[-N]`,
/// or a path to a UCR-style CSV file.
pub fn get_dataset(name: &str, scale: f64, seed: u64) -> Option<Dataset> {
    if name.starts_with("demo") {
        let n = demo_size(name)?;
        return Some(SynthSpec::new(name, n, 64, 4).generate(seed));
    }
    if name.starts_with("synth-large-") {
        let n = synth_large_size(name)?;
        return Some(
            SynthSpec::new(name, n, SYNTH_LARGE_LEN, SYNTH_LARGE_CLASSES).generate(seed),
        );
    }
    if is_path(name) {
        return load_ucr_csv(Path::new(name)).ok();
    }
    let spec = table1_specs(scale)
        .into_iter()
        .find(|s| s.name.eq_ignore_ascii_case(name))?;
    // Per-dataset deterministic seed so different datasets differ. Hash
    // the spec's canonical spelling (not the caller's): the lookup is
    // case-insensitive, so "cbf" and "CBF" must be the same dataset.
    let ds_seed = seed ^ fxhash(&spec.name);
    Some(spec.generate(ds_seed))
}

/// The canonical spelling of a dataset name — the one identity under
/// which `get_dataset` resolves it, whatever the caller's casing. `None`
/// for unknown names and CSV/file paths (whose content has no stable
/// identity). Used by the artifact cache so case variants of one dataset
/// share a fingerprint.
pub fn canonical_name(name: &str) -> Option<String> {
    if name.starts_with("demo") {
        // the generator ignores the name itself, so demo variants
        // canonicalize by size
        return demo_size(name).map(|n| format!("demo-{n}"));
    }
    if name.starts_with("synth-large-") {
        return synth_large_size(name).map(|n| format!("synth-large-{n}"));
    }
    if is_path(name) {
        return None;
    }
    table1_specs(1.0)
        .into_iter()
        .find(|s| s.name.eq_ignore_ascii_case(name))
        .map(|s| s.name)
}

/// The series count `get_dataset` would produce for a name, *without*
/// generating anything — lets the service reject oversized requests
/// before any allocation. None for unknown names and CSV paths.
pub fn dataset_size(name: &str, scale: f64) -> Option<usize> {
    if name.starts_with("demo") {
        return demo_size(name);
    }
    if name.starts_with("synth-large-") {
        return synth_large_size(name);
    }
    if is_path(name) {
        return None;
    }
    table1_specs(scale)
        .into_iter()
        .find(|s| s.name.eq_ignore_ascii_case(name))
        .map(|s| s.n)
}

/// Generate all Table-1 datasets at a scale.
pub fn all_table1(scale: f64, seed: u64) -> Vec<Dataset> {
    table1_specs(scale)
        .into_iter()
        .map(|spec| {
            let ds_seed = seed ^ fxhash(&spec.name);
            spec.generate(ds_seed)
        })
        .collect()
}

fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_names_complete() {
        let names = table1_names();
        assert_eq!(names.len(), 18);
        assert!(names.contains(&"Crop".to_string()));
        for l in largest3_names() {
            assert!(names.contains(&l.to_string()));
        }
    }

    #[test]
    fn get_by_name_scaled() {
        let ds = get_dataset("CBF", 0.1, DEFAULT_SEED).unwrap();
        assert_eq!(ds.n(), 93);
        assert_eq!(ds.n_classes, 3);
        assert!(get_dataset("NoSuchDataset", 1.0, 0).is_none());
    }

    #[test]
    fn demo_sizes() {
        assert_eq!(get_dataset("demo", 1.0, 1).unwrap().n(), 200);
        assert_eq!(get_dataset("demo-50", 1.0, 1).unwrap().n(), 50);
    }

    #[test]
    fn sub_minimum_demo_is_unknown_not_panic() {
        // demo-{0..3} would trip the generator's n >= k assert; a remote
        // request must get a clean dataset_not_found, never a panic in a
        // dispatch worker.
        for name in ["demo-0", "demo-1", "demo-2", "demo-3"] {
            assert!(get_dataset(name, 1.0, 1).is_none(), "{name}");
            assert_eq!(dataset_size(name, 1.0), None, "{name}");
            assert_eq!(canonical_name(name), None, "{name}");
        }
        assert!(get_dataset("demo-4", 1.0, 1).is_some());
    }

    #[test]
    fn dataset_size_predicts_without_generating() {
        assert_eq!(dataset_size("demo-50", 1.0), Some(50));
        assert_eq!(dataset_size("demo-100000000", 1.0), Some(100_000_000));
        let predicted = dataset_size("CBF", 0.1).unwrap();
        assert_eq!(predicted, get_dataset("CBF", 0.1, 1).unwrap().n());
        assert_eq!(dataset_size("NoSuchDataset", 1.0), None);
        assert_eq!(dataset_size("some/path.csv", 1.0), None);
    }

    #[test]
    fn synth_large_family() {
        let ds = get_dataset("synth-large-256", 1.0, 3).unwrap();
        assert_eq!(ds.n(), 256);
        assert_eq!(ds.n_classes, 16);
        assert_eq!(dataset_size("synth-large-16384", 1.0), Some(16384));
        assert_eq!(
            canonical_name("synth-large-256").as_deref(),
            Some("synth-large-256")
        );
        // deterministic per seed
        let again = get_dataset("synth-large-256", 1.0, 3).unwrap();
        assert_eq!(ds.data, again.data);
        // below the class minimum or absurdly large → unknown
        assert!(get_dataset("synth-large-10", 1.0, 1).is_none());
        assert!(get_dataset("synth-large-9999999999", 1.0, 1).is_none());
        assert_eq!(dataset_size("synth-large-x", 1.0), None);
    }

    #[test]
    fn different_datasets_differ() {
        let a = get_dataset("CBF", 0.05, DEFAULT_SEED).unwrap();
        let b = get_dataset("ECG5000", 0.05, DEFAULT_SEED).unwrap();
        assert_ne!(a.data.data.len(), 0);
        assert_ne!(a.labels, b.labels[..a.n().min(b.n())].to_vec());
    }

    #[test]
    fn canonical_name_folds_case_and_rejects_paths() {
        assert_eq!(canonical_name("CBF").as_deref(), Some("CBF"));
        assert_eq!(canonical_name("cbf").as_deref(), Some("CBF"));
        assert_eq!(canonical_name("demo").as_deref(), Some("demo-200"));
        assert_eq!(canonical_name("demo-50").as_deref(), Some("demo-50"));
        assert_eq!(canonical_name("NoSuchDataset"), None);
        assert_eq!(canonical_name("some/path.csv"), None);
        assert_eq!(canonical_name("x.csv"), None);
    }

    #[test]
    fn case_variants_are_the_same_dataset() {
        // The lookup is case-insensitive, so the generated content must
        // not depend on the caller's casing either.
        let a = get_dataset("CBF", 0.05, 7).unwrap();
        let b = get_dataset("cbf", 0.05, 7).unwrap();
        assert_eq!(a.data, b.data);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn deterministic_across_calls() {
        let a = get_dataset("Mallat", 0.05, 7).unwrap();
        let b = get_dataset("Mallat", 0.05, 7).unwrap();
        assert_eq!(a.data, b.data);
    }
}
