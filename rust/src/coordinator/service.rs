//! A batched TCP clustering service — the "deployment" face of the
//! coordinator. Wire protocol: one JSON object per line per request;
//! one JSON object per line back.
//!
//! Request fields:
//!   {"id": 7, "dataset": "CBF", "scale": 0.05, "seed": 1,
//!    "algo": "opt", "k": 3}
//! or inline data:
//!   {"id": 7, "n": 16, "l": 8, "data": [ ... n*l floats ... ], "k": 2}
//! Special: {"cmd": "ping"} → {"ok": true}, {"cmd": "shutdown"}.
//!
//! Response: {"id": 7, "ok": true, "labels": [...], "ari": 0.4,
//!            "secs": 0.01, "algo": "opt-tdbht", "batch": 3}
//!
//! Streaming (one session per connection, state lives in the dispatcher):
//!   {"cmd": "open_stream", "n": 16, "k": 2, "window": 64, "algo": "opt",
//!    "drift": 0.1, "warmup": 8, "max_refreshes": 64}
//!     → {"ok": true, "stream": true, ...}
//!   {"cmd": "tick", "data": [ ... n floats, one per series ... ]}
//!     → {"ok": true, "generation": 12, "decision": "refresh"|"rebuild"|
//!        "warming", "labels": [...], "drift": 0.03, "secs": ..., ...}
//!       (labels/drift absent while warming; generation increases
//!        monotonically, stepping on every emitted clustering)
//!   {"cmd": "close_stream"} → {"ok": true, "closed": true, "ticks": ...,
//!        "emissions": ..., "rebuilds": ..., "refreshes": ...}
//!   Sessions are freed automatically when the connection drops.
//!
//! Architecture: acceptor threads parse requests into a shared queue; a
//! single dispatcher drains the queue in small batches (batching window),
//! runs each batch's similarity computations through one shared engine
//! (amortizing executable-cache hits), then the graph stages per request
//! on the parallel pool, and replies. The batch size a request rode in on
//! is reported so clients/tests can observe batching. Stream sessions are
//! owned by the same dispatcher (keyed by connection), so per-tick state
//! never needs locking and rides the same batching queue.

use super::pipeline::{Pipeline, PipelineConfig, TmfgAlgo};
use super::registry;
use crate::data::matrix::Matrix;
use crate::data::synth::Dataset;
use crate::stream::{StreamConfig, StreamSession};
use crate::util::json::Json;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::Duration;

/// Distinguishes connections so the dispatcher can key stream sessions.
static CONN_SEQ: AtomicU64 = AtomicU64::new(1);

pub struct ServiceConfig {
    pub addr: String,
    /// Max requests per batch.
    pub max_batch: usize,
    /// Batching window: wait this long for more requests after the first.
    pub batch_window: Duration,
    pub default_algo: TmfgAlgo,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            addr: "127.0.0.1:7401".into(),
            max_batch: 8,
            batch_window: Duration::from_millis(5),
            default_algo: TmfgAlgo::Opt,
        }
    }
}

struct Job {
    request: Json,
    reply: Sender<String>,
    /// Originating connection (stream sessions are per-connection).
    conn: u64,
}

/// Handle to a running service (for tests and the `serve` example).
pub struct ServiceHandle {
    pub addr: String,
    shutdown: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ServiceHandle {
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::Release);
        // poke the acceptor so it notices
        let _ = TcpStream::connect(&self.addr);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn parse_dataset(req: &Json) -> Result<(Dataset, usize), String> {
    let k = req.get("k").as_usize().unwrap_or(0);
    if let Some(name) = req.get("dataset").as_str() {
        let scale = req.get("scale").as_f64().unwrap_or(0.05);
        let seed = req.get("seed").as_f64().unwrap_or(1.0) as u64;
        let ds = registry::get_dataset(name, scale, seed)
            .ok_or_else(|| format!("unknown dataset {name}"))?;
        let k = if k == 0 { ds.n_classes } else { k };
        return Ok((ds, k));
    }
    let n = req.get("n").as_usize().ok_or("missing n")?;
    let l = req.get("l").as_usize().ok_or("missing l")?;
    let arr = req.get("data").as_arr().ok_or("missing data")?;
    if arr.len() != n * l {
        return Err(format!("data length {} != n*l = {}", arr.len(), n * l));
    }
    let data: Vec<f32> = arr
        .iter()
        .map(|v| v.as_f64().unwrap_or(f64::NAN) as f32)
        .collect();
    if k == 0 {
        return Err("inline data requires k".into());
    }
    Ok((
        Dataset {
            name: "inline".into(),
            data: Matrix::from_vec(n, l, data),
            labels: vec![0; n],
            n_classes: k,
        },
        k,
    ))
}

fn process(req: &Json, pipeline: &Pipeline, batch_size: usize) -> Json {
    let id = req.get("id").clone();
    let t = crate::util::timer::Timer::start();
    match parse_dataset(req) {
        Ok((ds, k)) => {
            // run_dataset routes the similarity computation through the
            // shared engine (XLA artifact path when a bucket fits).
            let out = pipeline.run_dataset(&ds);
            let labels = out.dbht.dendrogram.cut(k);
            // Report ARI only for named datasets (which carry ground truth).
            let ari = if req.get("dataset").as_str().is_some() {
                Some(crate::metrics::adjusted_rand_index(&ds.labels, &labels))
            } else {
                None
            };
            Json::obj(vec![
                ("id", id),
                ("ok", Json::Bool(true)),
                ("labels", Json::arr_usize(&labels)),
                (
                    "ari",
                    ari.map(Json::Num).unwrap_or(Json::Null),
                ),
                ("secs", Json::Num(t.elapsed())),
                ("algo", Json::str(&pipeline.config.algo.name())),
                ("batch", Json::Num(batch_size as f64)),
            ])
        }
        Err(e) => Json::obj(vec![
            ("id", id),
            ("ok", Json::Bool(false)),
            ("error", Json::str(&e)),
        ]),
    }
}

fn error_json(id: Json, msg: &str) -> Json {
    Json::obj(vec![
        ("id", id),
        ("ok", Json::Bool(false)),
        ("error", Json::str(msg)),
    ])
}

/// Handle one streaming command against the dispatcher-owned session map.
fn stream_cmd(
    req: &Json,
    cmd: &str,
    streams: &mut HashMap<u64, StreamSession>,
    conn: u64,
    default_algo: TmfgAlgo,
    batch: usize,
) -> Json {
    let id = req.get("id").clone();
    match cmd {
        "open_stream" => {
            let Some(n) = req.get("n").as_usize() else {
                return error_json(id, "open_stream requires n (number of series)");
            };
            let window = req.get("window").as_usize().unwrap_or(64);
            let k = req.get("k").as_usize().unwrap_or(2);
            let algo = req
                .get("algo")
                .as_str()
                .and_then(TmfgAlgo::parse)
                .unwrap_or(default_algo);
            let mut scfg = StreamConfig::new(n, window, k);
            scfg.algo = algo;
            if let Some(d) = req.get("drift").as_f64() {
                scfg.policy.drift_threshold = d as f32;
            }
            if let Some(w) = req.get("warmup").as_usize() {
                scfg.warmup = w;
            }
            if let Some(m) = req.get("max_refreshes").as_usize() {
                scfg.policy.max_refreshes = m as u32;
            }
            match StreamSession::new(scfg) {
                Ok(session) => {
                    // replacing an existing session is allowed (re-open)
                    streams.insert(conn, session);
                    Json::obj(vec![
                        ("id", id),
                        ("ok", Json::Bool(true)),
                        ("stream", Json::Bool(true)),
                        ("n", Json::Num(n as f64)),
                        ("window", Json::Num(window as f64)),
                        ("k", Json::Num(k as f64)),
                        ("algo", Json::str(&algo.name())),
                    ])
                }
                Err(e) => error_json(id, &e),
            }
        }
        "tick" => {
            let Some(session) = streams.get_mut(&conn) else {
                return error_json(id, "no open stream on this connection");
            };
            let Some(arr) = req.get("data").as_arr() else {
                return error_json(id, "tick requires data (one value per series)");
            };
            let sample: Vec<f32> = arr
                .iter()
                .map(|v| v.as_f64().unwrap_or(f64::NAN) as f32)
                .collect();
            match session.tick(&sample) {
                Ok(out) => {
                    let mut pairs = vec![
                        ("id", id),
                        ("ok", Json::Bool(true)),
                        ("generation", Json::Num(out.generation as f64)),
                        ("tick", Json::Num(out.tick as f64)),
                        ("decision", Json::str(out.decision.name())),
                        ("secs", Json::Num(out.secs)),
                        ("batch", Json::Num(batch as f64)),
                    ];
                    if let Some(labels) = &out.labels {
                        pairs.push(("labels", Json::arr_usize(labels)));
                    }
                    if let Some(d) = out.drift {
                        pairs.push(("drift", Json::Num(d.max_abs as f64)));
                    }
                    Json::obj(pairs)
                }
                Err(e) => error_json(id, &e),
            }
        }
        // close_stream; also issued internally on disconnect (idempotent).
        _ => match streams.remove(&conn) {
            Some(session) => {
                let st = session.stats();
                Json::obj(vec![
                    ("id", id),
                    ("ok", Json::Bool(true)),
                    ("closed", Json::Bool(true)),
                    ("ticks", Json::Num(st.ticks as f64)),
                    ("emissions", Json::Num(st.emissions as f64)),
                    ("rebuilds", Json::Num(st.rebuilds as f64)),
                    ("refreshes", Json::Num(st.refreshes as f64)),
                    ("generation", Json::Num(session.generation() as f64)),
                ])
            }
            None => Json::obj(vec![
                ("id", id),
                ("ok", Json::Bool(true)),
                ("closed", Json::Bool(false)),
            ]),
        },
    }
}

fn dispatcher(rx: Receiver<Job>, cfg: &ServiceConfig, shutdown: Arc<AtomicBool>) {
    // One pipeline per algo, built lazily; engines (and their compiled
    // XLA executables) are shared across the whole service lifetime.
    let mut pipelines: std::collections::HashMap<String, Pipeline> = Default::default();
    // Per-connection streaming sessions, owned here so tick state needs
    // no locking.
    let mut streams: HashMap<u64, StreamSession> = Default::default();
    loop {
        let first = match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(j) => j,
            Err(RecvTimeoutError::Timeout) => {
                if shutdown.load(Ordering::Acquire) {
                    return;
                }
                continue;
            }
            Err(RecvTimeoutError::Disconnected) => return,
        };
        // batching window: gather more requests
        let mut batch = vec![first];
        let deadline = std::time::Instant::now() + cfg.batch_window;
        while batch.len() < cfg.max_batch {
            let left = deadline.saturating_duration_since(std::time::Instant::now());
            if left.is_zero() {
                break;
            }
            match rx.recv_timeout(left) {
                Ok(j) => batch.push(j),
                Err(_) => break,
            }
        }
        let bsize = batch.len();
        for job in batch {
            if let Some(cmd) = job.request.get("cmd").as_str() {
                if matches!(cmd, "open_stream" | "tick" | "close_stream") {
                    let resp =
                        stream_cmd(&job.request, cmd, &mut streams, job.conn, cfg.default_algo, bsize);
                    let _ = job.reply.send(resp.to_string());
                    continue;
                }
            }
            let algo = job
                .request
                .get("algo")
                .as_str()
                .and_then(TmfgAlgo::parse)
                .unwrap_or(cfg.default_algo);
            let pipeline = pipelines.entry(algo.name()).or_insert_with(|| {
                Pipeline::new(PipelineConfig { algo, ..Default::default() })
            });
            let resp = process(&job.request, pipeline, bsize);
            let _ = job.reply.send(resp.to_string());
        }
    }
}

/// Start the service; returns once the listener is bound.
pub fn serve(cfg: ServiceConfig) -> std::io::Result<ServiceHandle> {
    let listener = TcpListener::bind(&cfg.addr)?;
    let addr = listener.local_addr()?.to_string();
    let shutdown = Arc::new(AtomicBool::new(false));
    let (tx, rx) = channel::<Job>();
    let sd = shutdown.clone();
    let cfg2 = ServiceConfig { addr: addr.clone(), ..cfg };
    let join = std::thread::spawn(move || {
        let sd_dispatch = sd.clone();
        let dispatch = std::thread::spawn(move || dispatcher(rx, &cfg2, sd_dispatch));
        for stream in listener.incoming() {
            if sd.load(Ordering::Acquire) {
                break;
            }
            let Ok(stream) = stream else { continue };
            let tx = tx.clone();
            let sd_conn = sd.clone();
            std::thread::spawn(move || handle_conn(stream, tx, sd_conn));
        }
        drop(tx);
        let _ = dispatch.join();
    });
    Ok(ServiceHandle { addr, shutdown, join: Some(join) })
}

fn handle_conn(stream: TcpStream, tx: Sender<Job>, shutdown: Arc<AtomicBool>) {
    let conn = CONN_SEQ.fetch_add(1, Ordering::Relaxed);
    let peer = stream.try_clone();
    let reader = BufReader::new(stream);
    let Ok(mut writer) = peer else { return };
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let req = match Json::parse(&line) {
            Ok(j) => j,
            Err(e) => {
                let _ = writeln!(
                    writer,
                    "{}",
                    Json::obj(vec![
                        ("ok", Json::Bool(false)),
                        ("error", Json::str(&format!("bad json: {e}")))
                    ])
                    .to_string()
                );
                continue;
            }
        };
        match req.get("cmd").as_str() {
            Some("ping") => {
                let _ = writeln!(writer, "{}", Json::obj(vec![("ok", Json::Bool(true))]).to_string());
                continue;
            }
            Some("shutdown") => {
                shutdown.store(true, Ordering::Release);
                let _ = writeln!(writer, "{}", Json::obj(vec![("ok", Json::Bool(true))]).to_string());
                return;
            }
            _ => {}
        }
        let (rtx, rrx) = channel();
        if tx.send(Job { request: req, reply: rtx, conn }).is_err() {
            break;
        }
        match rrx.recv() {
            Ok(resp) => {
                if writeln!(writer, "{resp}").is_err() {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    // Connection gone: free any stream session it owned (idempotent; the
    // reply channel's receiver is dropped, so the response is discarded).
    let (rtx, _rrx) = channel();
    let _ = tx.send(Job {
        request: Json::obj(vec![("cmd", Json::str("close_stream"))]),
        reply: rtx,
        conn,
    });
}

/// Minimal blocking client used by tests and the serve example.
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    pub fn call(&mut self, req: &Json) -> std::io::Result<Json> {
        writeln!(self.stream, "{}", req.to_string())?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(&line)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string()))
    }
}
