//! A batched, concurrent multi-tenant TCP clustering service — the
//! "deployment" face of the coordinator. Wire protocol: one JSON object
//! per line per request; one JSON object per line back. Requests are
//! decoded through the single validated parse path in
//! [`crate::api::wire`] (versioned typed requests; malformed fields are
//! rejected with a stable error `code` instead of being silently
//! defaulted).
//!
//! Request fields:
//!   {"id": 7, "dataset": "CBF", "scale": 0.05, "seed": 1,
//!    "algo": "opt", "k": 3}
//! or inline data:
//!   {"id": 7, "n": 16, "l": 8, "data": [ ... n*l floats ... ], "k": 2}
//! Sparse k-NN mode (raises the batch cap from 4096 to 65536 series;
//! responses gain "sparse_k"/"sparse_nnz"/"sparse_fallbacks" plus the
//! effective ANN knobs "sparse_dims"/"sparse_pool"/"sparse_iters"):
//!   {"id": 7, "dataset": "synth-large-16384", "sparse_k": 32,
//!    "sparse_seed": 1, "k": 16}
//! ANN knob overrides (require "sparse_k"): {"sparse_dims": 16,
//! "sparse_pool": 4, "sparse_iters": 2} tune the projection
//! dimensionality, shortlist multiplier, and NN-descent refinement
//! rounds of the large-n k-NN front end.
//!
//! **Binary frames (protocol v2, unix event-loop front end):** a
//! request may arrive as `TMFB` + u32 LE header length + u64 LE payload
//! bytes + JSON header (same fields as a line request, minus "data") +
//! little-endian f32 payload, decoded incrementally by
//! [`crate::net::conn`] so the panel never exists as JSON text. Framed
//! sparse requests raise the batch cap to 2^20 series
//! ([`wire::MAX_BINARY_SPARSE_SERIES`]); responses are always JSON
//! lines, byte-identical to the line protocol's. See
//! [`crate::api::wire`] for the exact layout.
//! APSP control: {"apsp": "exact"|"approx"|"auto"} overrides the
//! algorithm's default mode; {"hub_n": 32, "hub_radius": 2.0,
//! "hub_q": 4} tune the streaming hub oracle (approx/auto modes run it
//! with O(n·h) memory — no n×n distance matrix on the worker).
//! Multi-tenant identity: {"tenant": "acme-1"} ([A-Za-z0-9._-]{1,64})
//! keys per-tenant admission control and metrics; absent = anonymous
//! (exempt from tenant quotas).
//! Special: {"cmd": "ping"} → {"ok": true}, {"cmd": "shutdown"},
//! {"cmd": "stats"} → {"ok": true, "workers": ..., "queue_depth": ...,
//! "max_queue": ..., "jobs": ..., "open_streams": ...,
//! "sparse_requests": ..., "dense_requests": ..., "oracle_dense": ...,
//! "oracle_hub": ..., "net_backend": "epoll"|"poll"|"threads",
//! "conns_accepted": ..., "conns_active": ..., "conns_rejected": ...,
//! "overload_rejected": ..., "reaped_idle": ..., "loop_wakeups": ...,
//! "admission_rejected": {"<tenant>": ...}, "cache_hits": ...,
//! "cache_misses": ..., "cache_hit_ratio": ..., "cache_bytes": ...,
//! "stages": {...}, "latency": {"stages": {"tmfg": {"p50": ...,
//! "p95": ..., "p99": ...}, ...}, "queue_wait": {...}},
//! "slo": {"windows": {...}, "series": {...}}, "shed": {"depth": ...,
//! "delay": ..., "tenant": ...}, "recorder": {...},
//! "target_queue_delay_ms": ...}, and
//! {"cmd": "metrics"} → {"ok": true, "metrics": "<Prometheus text
//! exposition>"} (see [`crate::obs`]).
//! {"cmd": "debug_dump"} → {"ok": true, "events": [...], "recorder":
//! {...}} replays the flight recorder's wide events (oldest first): one
//! canonical JSON object per completed request — trace id, tenant,
//! cache/oracle outcome, per-stage timings, queue delay, response
//! bytes, resource counters, and shed cause for rejected work.
//! Optional: {"v": 1, ...} pins the protocol version.
//! Every batch clustering response carries a "trace_id"; requests with
//! {"trace": true} run under an exclusive tracing session and their
//! response gains a "trace" object (Chrome trace-event JSON).
//!
//! Response: {"id": 7, "ok": true, "labels": [...], "ari": 0.4,
//!            "secs": 0.01, "algo": "opt-tdbht", "oracle":
//!            "dense"|"hub", "batch": 3, "cache": "hit"|"miss"}
//!   (`cache` is present when the artifact cache is enabled: "hit" means
//!   the Similarity→TMFG artifacts were served from the cross-request
//!   cache and only the cheap downstream stages ran.)
//! Errors:   {"id": 7, "ok": false, "error": "...", "code": "protocol"}
//!   `code: "overloaded"` means the request was *not* processed — the
//!   connection limit, dispatch-queue depth bound, or the sender's
//!   tenant quota rejected it; back off and retry.
//!
//! Streaming (one session per connection, pinned to one dispatch worker):
//!   {"cmd": "open_stream", "n": 16, "k": 2, "window": 64, "algo": "opt",
//!    "drift": 0.1, "warmup": 8, "max_refreshes": 64}
//!     → {"ok": true, "stream": true, "session": 3, ...}
//!   {"cmd": "tick", "data": [ ... n floats, one per series ... ]}
//!     → {"ok": true, "session": 3, "generation": 12, "decision":
//!        "refresh"|"rebuild"|"warming", "labels": [...], "drift": 0.03,
//!        "secs": ..., ...}
//!       (labels/drift absent while warming; generation increases
//!        monotonically, stepping on every emitted clustering; `session`
//!        echoes the id of the session this connection owns)
//!   {"cmd": "close_stream"} → {"ok": true, "closed": true, "ticks": ...,
//!        "emissions": ..., "rebuilds": ..., "refreshes": ...}
//!   Sessions are freed automatically when the connection drops — on
//!   *every* close path, including idle reaping and server shutdown.
//!
//! Architecture: on unix, the front end is a single-threaded readiness
//! event loop ([`crate::net`]: epoll on Linux, portable `poll(2)`
//! fallback) owning every connection — nonblocking accept with a hard
//! `--max-conns` limit, buffered line framing with a `--max-line-bytes`
//! cap, per-tenant admission control, dispatch-queue-depth backpressure
//! (typed `overloaded` errors), idle reaping on a deadline wheel, and
//! graceful drain. The connection tier is exactly one OS thread no
//! matter how many clients connect; responses are delivered back to the
//! loop via a completion mailbox + self-pipe waker and written under
//! write-interest, so a slow reader backpressures only itself. (The
//! pre-event-loop thread-per-connection front end remains as the
//! non-unix fallback.)
//!
//! Requests are routed into a **sharded dispatcher worker pool**
//! ([`ServiceConfig::dispatch_workers`] OS threads, default
//! `min(4, cores/2)`). Batch clustering jobs land in one shared MPMC
//! queue that any worker drains in small batches (batching window), so
//! concurrent clients no longer serialize behind a single dispatcher.
//! Stream sessions are *pinned*: a connection's `open_stream` / `tick` /
//! `close_stream` always route to shard `conn % workers`, and each
//! worker owns the session map for its shard — per-tick state never
//! needs locking and never crosses workers. The pinning tradeoff: a tick
//! can stall behind at most one in-flight batch clustering job on its
//! own shard (ticks are drained between batch items, but sessions cannot
//! migrate to idle workers); `dispatch_workers` and `max_batch` bound
//! that tail. All workers share one
//! similarity engine (compiled-executable reuse) and one cross-request
//! [`ArtifactCache`] memoizing Similarity→TMFG artifacts, so repeated
//! traffic on the same dataset skips the O(n²·l) correlation and the
//! O(n²) TMFG entirely. Workers may run the parallel pool concurrently —
//! `parlay::pool` partitions its workers across the concurrent jobs.
//! The batch size a request rode in on is reported so clients/tests can
//! observe batching.

use crate::api::cache::{ArtifactCache, CacheStatus};
use crate::api::wire::{self, ClusterSource, ClusterSpec, Command};
use crate::api::{ClusterOutput, ClusterRequest, TmfgAlgo, TmfgError};
use crate::data::matrix::Matrix;
use crate::net::server::LoopCtl;
use crate::runtime::engine::CorrEngine;
use crate::stream::{StreamConfig, StreamSession};
use crate::util::json::Json;
use crate::util::timer::Breakdown;
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
#[cfg(not(unix))]
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Distinguishes connections so stream sessions can be keyed and pinned
/// (legacy blocking front end; the event loop allocates its own tokens).
#[cfg(not(unix))]
static CONN_SEQ: AtomicU64 = AtomicU64::new(1);

pub struct ServiceConfig {
    pub addr: String,
    /// Max requests per batch.
    pub max_batch: usize,
    /// Batching window: wait this long for more requests after the first.
    pub batch_window: Duration,
    pub default_algo: TmfgAlgo,
    /// Dispatcher worker (shard) count. 0 = auto: `min(4, cores/2)`, at
    /// least 1. Batch jobs are pulled from a shared queue by any worker;
    /// stream sessions are pinned to shard `conn % workers`.
    pub dispatch_workers: usize,
    /// Cross-request artifact cache capacity in entries (0 disables it).
    pub cache_entries: usize,
    /// Artifact cache byte budget.
    pub cache_bytes: usize,
    /// Hard cap on simultaneously open connections; excess sockets get a
    /// best-effort `overloaded` line and are dropped at accept.
    pub max_conns: usize,
    /// Longest accepted request line in bytes; a newline-free prefix
    /// past this cap earns a typed `protocol` error and a close.
    pub max_line_bytes: usize,
    /// Reap connections idle this long (`Duration::ZERO` disables).
    pub idle_timeout: Duration,
    /// Per-tenant in-flight request cap (0 = unlimited). Requests over
    /// the cap get a typed `overloaded` error; anonymous requests and
    /// `close_stream` are exempt.
    pub tenant_quota: usize,
    /// Dispatch-queue depth bound for batch admission. 0 = auto:
    /// `workers * max_batch * 8`, at least 64.
    pub max_queue_depth: usize,
    /// Force the portable `poll(2)` readiness backend (diagnostics/CI;
    /// the default picks epoll where available).
    pub poll_backend: bool,
    /// CoDel-style queue-delay target for batch admission
    /// (`Duration::ZERO` disables the gate and keeps the pure
    /// depth-bound behavior). When set, new batch work is shed with a
    /// typed `overloaded` error (cause `delay`) once the dispatch
    /// queue's front job has been older than the target for a sustained
    /// interval; the depth bound stays on as the hard ceiling.
    pub target_queue_delay: Duration,
    /// Flight-recorder ring-buffer byte budget (0 disables recording).
    pub flight_recorder_bytes: usize,
    /// Dump the flight recorder to this JSONL path on graceful drain.
    pub flight_log: Option<String>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            addr: "127.0.0.1:7401".into(),
            max_batch: 8,
            batch_window: Duration::from_millis(5),
            default_algo: TmfgAlgo::Opt,
            dispatch_workers: 0,
            cache_entries: ArtifactCache::DEFAULT_ENTRIES,
            cache_bytes: ArtifactCache::DEFAULT_BYTES,
            max_conns: 1024,
            max_line_bytes: 16 << 20,
            idle_timeout: Duration::from_secs(300),
            tenant_quota: 0,
            max_queue_depth: 0,
            poll_backend: false,
            target_queue_delay: Duration::ZERO,
            flight_recorder_bytes: crate::obs::FlightRecorder::DEFAULT_BUDGET,
            flight_log: None,
        }
    }
}

impl ServiceConfig {
    /// The worker count `serve` will actually start.
    pub fn resolved_workers(&self) -> usize {
        if self.dispatch_workers > 0 {
            return self.dispatch_workers;
        }
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        (cores / 2).clamp(1, 4)
    }

    /// The dispatch-queue depth bound admission will actually enforce.
    pub fn resolved_max_queue(&self) -> usize {
        if self.max_queue_depth > 0 {
            return self.max_queue_depth;
        }
        (self.resolved_workers() * self.max_batch * 8).max(64)
    }
}

/// Where a finished job's response line goes.
enum Reply {
    /// Legacy blocking front end: per-request rendezvous channel.
    #[cfg(not(unix))]
    Channel(Sender<String>),
    /// Event-loop front end: the loop's completion mailbox (worker →
    /// waker → loop writes the line under write-interest).
    #[cfg(unix)]
    Net { conn: u64, ctl: Arc<LoopCtl> },
    /// Internal housekeeping job (disconnect cleanup): response dropped.
    Discard,
}

impl Reply {
    fn send(self, line: String) {
        match self {
            #[cfg(not(unix))]
            Reply::Channel(tx) => {
                let _ = tx.send(line);
            }
            #[cfg(unix)]
            Reply::Net { conn, ctl } => ctl.complete(conn, line),
            Reply::Discard => {
                let _ = line;
            }
        }
    }
}

struct Job {
    request: wire::Request,
    reply: Reply,
    /// Originating connection (stream sessions are per-connection).
    conn: u64,
    /// Synthetic housekeeping job (disconnect cleanup) — processed like
    /// any other but excluded from the `stats` request counter.
    internal: bool,
    /// Submit time — the dispatcher queue-wait (submit → dequeue) is
    /// observed into the obs registry when a worker picks the job up.
    enqueued: Instant,
}

/// Result of a timed pop from a [`JobQueue`].
enum Pop {
    Job(Job),
    /// Timed out with no job (queue still open).
    Empty,
    /// Queue closed and fully drained.
    Closed,
}

/// MPMC job queue: the front end pushes, dispatch workers pop.
/// Closing wakes every waiter, but pops keep returning queued jobs until
/// the queue is empty — shutdown never drops accepted work. A worker's
/// *pinned* queue doubles as its parking spot: `poke` marks shared-queue
/// activity so [`JobQueue::wait_work`] wakes without polling.
struct JobQueue {
    /// (jobs, closed, poked)
    q: Mutex<(VecDeque<Job>, bool, bool)>,
    cv: Condvar,
}

impl JobQueue {
    fn new() -> JobQueue {
        JobQueue { q: Mutex::new((VecDeque::new(), false, false)), cv: Condvar::new() }
    }

    /// Enqueue; false if the queue is closed (service shutting down).
    fn push(&self, job: Job) -> bool {
        let mut g = self.q.lock().unwrap();
        if g.1 {
            return false;
        }
        g.0.push_back(job);
        self.cv.notify_one();
        true
    }

    fn try_pop(&self) -> Option<Job> {
        self.q.lock().unwrap().0.pop_front()
    }

    fn pop_timeout(&self, d: Duration) -> Pop {
        let deadline = Instant::now() + d;
        let mut g = self.q.lock().unwrap();
        loop {
            if let Some(j) = g.0.pop_front() {
                return Pop::Job(j);
            }
            if g.1 {
                return Pop::Closed;
            }
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return Pop::Empty;
            }
            let (g2, _) = self.cv.wait_timeout(g, left).unwrap();
            g = g2;
        }
    }

    /// Flag external activity (a shared-queue push) and wake any waiter.
    /// Setting the flag under this queue's lock closes the check-then-
    /// sleep race in [`JobQueue::wait_work`].
    fn poke(&self) {
        let mut g = self.q.lock().unwrap();
        g.2 = true;
        self.cv.notify_all();
    }

    /// Park until this queue has work, is poked, or closes — with a
    /// fallback timeout bounding any wakeup this protocol might miss.
    /// Clears the poked flag on return.
    fn wait_work(&self, d: Duration) {
        let deadline = Instant::now() + d;
        let mut g = self.q.lock().unwrap();
        while g.0.is_empty() && !g.1 && !g.2 {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                break;
            }
            let (g2, _) = self.cv.wait_timeout(g, left).unwrap();
            g = g2;
        }
        g.2 = false;
    }

    fn close(&self) {
        let mut g = self.q.lock().unwrap();
        g.1 = true;
        self.cv.notify_all();
    }

    fn len(&self) -> usize {
        self.q.lock().unwrap().0.len()
    }

    /// Age of the front (oldest) job — the CoDel-style delay signal the
    /// admission gate and the `tmfg_admission_queue_delay_us` gauge
    /// sample. `None` when the queue is empty.
    fn oldest_wait(&self) -> Option<Duration> {
        self.q.lock().unwrap().0.front().map(|j| j.enqueued.elapsed())
    }
}

/// Shared live state: the queues, the artifact cache, and the counters
/// the `stats` command reports.
struct ServiceState {
    workers: usize,
    /// Resolved dispatch-queue depth bound (batch admission).
    max_queue: usize,
    /// Shared queue for batch clustering jobs (any worker pulls).
    global: Arc<JobQueue>,
    /// Per-shard queues for session-pinned stream jobs.
    pinned: Vec<Arc<JobQueue>>,
    cache: Option<Arc<ArtifactCache>>,
    /// Front-end identity reported by `stats`: "threads" until the event
    /// loop starts and reports its poller backend ("epoll"/"poll").
    net_backend: Mutex<&'static str>,
    /// Requests fully processed by the workers.
    jobs_done: AtomicU64,
    open_streams: AtomicUsize,
    /// Batch clustering requests that ran the sparse k-NN pipeline.
    sparse_requests: AtomicU64,
    /// Batch clustering requests that ran the dense pipeline.
    dense_requests: AtomicU64,
    /// Completed batch requests whose APSP stage used the dense oracle.
    oracle_dense: AtomicU64,
    /// Completed batch requests whose APSP stage used the streaming hub
    /// oracle (no n×n allocation).
    oracle_hub: AtomicU64,
    /// Connections accepted by the front end.
    conns_accepted: AtomicU64,
    /// Currently open connections.
    conns_active: AtomicU64,
    /// Connections refused at accept by the `max_conns` hard limit.
    conns_rejected: AtomicU64,
    /// Requests shed by dispatch-queue-depth backpressure.
    overload_rejected: AtomicU64,
    /// Idle connections reaped by the deadline wheel.
    reaped_idle: AtomicU64,
    /// Event-loop wakeups (readiness, completion poke, or timer).
    loop_wakeups: AtomicU64,
    /// tenant → requests rejected by per-tenant admission control.
    admission_rejected: Mutex<BTreeMap<String, u64>>,
    /// Cumulative per-stage wall-clock across every request.
    stages: Mutex<Breakdown>,
    /// Always-on request flight recorder (budget 0 = disabled).
    recorder: Arc<crate::obs::FlightRecorder>,
    /// Resolved queue-delay target (`ZERO` = adaptive admission off).
    target_queue_delay: Duration,
    /// Batch requests shed at the dispatch-queue depth ceiling.
    shed_depth: AtomicU64,
    /// Batch requests shed by the queue-delay gate.
    shed_delay: AtomicU64,
    /// Requests shed by per-tenant quota admission.
    shed_tenant: AtomicU64,
}

impl ServiceState {
    fn queue_depth(&self) -> usize {
        self.global.len() + self.pinned.iter().map(|q| q.len()).sum::<usize>()
    }

    /// Route a job: stream commands to their connection's pinned shard
    /// (its own queue wakes its worker), batch work to the shared queue
    /// (poking every parked worker so one picks it up without polling).
    fn submit(&self, is_stream: bool, shard: usize, job: Job) -> bool {
        if is_stream {
            self.pinned[shard].push(job)
        } else {
            let ok = self.global.push(job);
            if ok {
                for q in &self.pinned {
                    q.poke();
                }
            }
            ok
        }
    }

    fn stats_response(&self, id: &Json) -> Json {
        let mut fields = vec![
            ("workers", Json::Num(self.workers as f64)),
            ("queue_depth", Json::Num(self.queue_depth() as f64)),
            ("max_queue", Json::Num(self.max_queue as f64)),
            ("jobs", Json::Num(self.jobs_done.load(Ordering::Relaxed) as f64)),
            (
                "open_streams",
                Json::Num(self.open_streams.load(Ordering::Relaxed) as f64),
            ),
            (
                "sparse_requests",
                Json::Num(self.sparse_requests.load(Ordering::Relaxed) as f64),
            ),
            (
                "dense_requests",
                Json::Num(self.dense_requests.load(Ordering::Relaxed) as f64),
            ),
            (
                "oracle_dense",
                Json::Num(self.oracle_dense.load(Ordering::Relaxed) as f64),
            ),
            (
                "oracle_hub",
                Json::Num(self.oracle_hub.load(Ordering::Relaxed) as f64),
            ),
            ("net_backend", Json::str(*self.net_backend.lock().unwrap())),
            (
                "conns_accepted",
                Json::Num(self.conns_accepted.load(Ordering::Relaxed) as f64),
            ),
            (
                "conns_active",
                Json::Num(self.conns_active.load(Ordering::Relaxed) as f64),
            ),
            (
                "conns_rejected",
                Json::Num(self.conns_rejected.load(Ordering::Relaxed) as f64),
            ),
            (
                "overload_rejected",
                Json::Num(self.overload_rejected.load(Ordering::Relaxed) as f64),
            ),
            (
                "reaped_idle",
                Json::Num(self.reaped_idle.load(Ordering::Relaxed) as f64),
            ),
            (
                "loop_wakeups",
                Json::Num(self.loop_wakeups.load(Ordering::Relaxed) as f64),
            ),
        ];
        let admission = {
            let g = self.admission_rejected.lock().unwrap();
            Json::obj(g.iter().map(|(t, c)| (t.as_str(), Json::Num(*c as f64))).collect())
        };
        fields.push(("admission_rejected", admission));
        if let Some(cache) = &self.cache {
            let st = cache.stats();
            let total = st.hits + st.misses;
            let ratio = if total > 0 { st.hits as f64 / total as f64 } else { 0.0 };
            fields.push(("cache_hits", Json::Num(st.hits as f64)));
            fields.push(("cache_misses", Json::Num(st.misses as f64)));
            fields.push(("cache_hit_ratio", Json::Num(ratio)));
            fields.push(("cache_entries", Json::Num(st.entries as f64)));
            fields.push(("cache_bytes", Json::Num(st.bytes as f64)));
        }
        let stages_json = {
            let g = self.stages.lock().unwrap();
            Json::obj(g.stages().iter().map(|(s, t)| (s.as_str(), Json::Num(*t))).collect())
        };
        fields.push(("stages", stages_json));
        // Latency percentiles (seconds) read back from the obs registry's
        // log-linear histograms: one entry per observed stage, plus the
        // dispatcher queue-wait once any job has been dequeued.
        let reg = crate::obs::registry();
        let pcts = |p: [f64; 3]| {
            Json::obj(vec![
                ("p50", Json::Num(p[0])),
                ("p95", Json::Num(p[1])),
                ("p99", Json::Num(p[2])),
            ])
        };
        let stage_labels = reg.hist_labels(crate::obs::names::STAGE_SECONDS);
        let mut stage_pairs = Vec::with_capacity(stage_labels.len());
        for label in &stage_labels {
            if let Some(p) =
                reg.percentiles_secs(crate::obs::names::STAGE_SECONDS, Some(("stage", label)))
            {
                stage_pairs.push((label.as_str(), pcts(p)));
            }
        }
        let mut lat_pairs = vec![("stages", Json::obj(stage_pairs))];
        if let Some(p) = reg.percentiles_secs(crate::obs::names::QUEUE_WAIT_SECONDS, None) {
            lat_pairs.push(("queue_wait", pcts(p)));
        }
        fields.push(("latency", Json::obj(lat_pairs)));
        fields.push((
            "target_queue_delay_ms",
            Json::Num(self.target_queue_delay.as_secs_f64() * 1e3),
        ));
        fields.push((
            "shed",
            Json::obj(vec![
                ("depth", Json::Num(self.shed_depth.load(Ordering::Relaxed) as f64)),
                ("delay", Json::Num(self.shed_delay.load(Ordering::Relaxed) as f64)),
                ("tenant", Json::Num(self.shed_tenant.load(Ordering::Relaxed) as f64)),
            ]),
        ));
        fields.push(("recorder", recorder_stats_json(&self.recorder)));
        // Multi-window SLO attainment: short/long sliding windows over
        // the same log-linear histograms that back `latency`.
        let slo = crate::obs::slo_tracker().report();
        let win = |w: &crate::obs::slo::WindowStats| {
            Json::obj(vec![
                ("count", Json::Num(w.count as f64)),
                ("attainment", Json::Num(w.attainment)),
                ("burn_rate", Json::Num(w.burn_rate)),
            ])
        };
        let series = Json::obj(
            slo.series
                .iter()
                .map(|s| {
                    (
                        s.name.as_str(),
                        Json::obj(vec![
                            ("objective_ms", Json::Num(s.objective_ms)),
                            ("target", Json::Num(s.target)),
                            ("short", win(&s.short)),
                            ("long", win(&s.long)),
                        ]),
                    )
                })
                .collect(),
        );
        fields.push((
            "slo",
            Json::obj(vec![
                (
                    "windows",
                    Json::obj(vec![
                        ("short_secs", Json::Num(slo.short_secs as f64)),
                        ("long_secs", Json::Num(slo.long_secs as f64)),
                    ]),
                ),
                ("series", series),
            ]),
        ));
        wire::ok_response(id, fields)
    }
}

/// Handle to a running service (for tests, the `serve` example, and the
/// CLI's `tmfg serve`).
pub struct ServiceHandle {
    pub addr: String,
    ctl: Arc<LoopCtl>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ServiceHandle {
    /// Request a graceful drain and join the service threads: accepting
    /// stops, in-flight requests complete and flush, queued work drains.
    pub fn stop(mut self) {
        self.ctl.request_shutdown();
        // The legacy blocking front end parks in accept(); poke it so it
        // observes the flag. The event loop has its own waker.
        #[cfg(not(unix))]
        let _ = TcpStream::connect(&self.addr);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }

    /// Block until the service shuts down (a client sent
    /// {"cmd": "shutdown"}). Used by `tmfg serve` to exit cleanly
    /// instead of sleeping forever.
    pub fn wait(mut self) {
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Run one batch clustering request through the shared-engine API. Takes
/// the spec by value so inline payloads move straight into the panel
/// matrix (no second copy on the worker hot path).
fn run_cluster(
    spec: ClusterSpec,
    engine: &Arc<CorrEngine>,
    cache: Option<&Arc<ArtifactCache>>,
    default_algo: TmfgAlgo,
) -> Result<ClusterOutput, TmfgError> {
    let algo = spec.algo.unwrap_or(default_algo);
    let req = match spec.source {
        ClusterSource::Named { name, scale, seed } => {
            let mut r = ClusterRequest::dataset(name).scale(scale).seed(seed);
            if spec.k > 0 {
                r = r.k(spec.k);
            }
            r
        }
        ClusterSource::Inline { n, l, data } => {
            // decode() validated len == n*l and finiteness; k >= 1.
            let panel = Matrix::from_vec(n, l, data);
            ClusterRequest::panel(panel).k(spec.k)
        }
    };
    let mut req = req.algo(algo).engine(engine.clone());
    if let Some(sk) = spec.sparse_k {
        // decode() validated 1 <= sparse_k <= MAX_SPARSE_K and capped
        // the ANN knobs (dims/pool/iters; None = engine default).
        req = req.sparse_knn_tuned(
            sk,
            spec.sparse_seed.unwrap_or(crate::sparse::DEFAULT_KNN_SEED),
            spec.sparse_dims,
            spec.sparse_pool,
            spec.sparse_iters,
        );
    }
    if let Some(mode) = spec.apsp {
        req = req.apsp(mode);
    }
    if let Some(hub) = spec.hub {
        // decode() capped hub_n/hub_q <= MAX_HUBS, hub_radius finite.
        req = req.hub(hub);
    }
    if let Some(c) = cache {
        req = req.cache(c.clone());
    }
    req.run()
}

#[allow(clippy::too_many_arguments)]
fn process(
    id: &Json,
    spec: ClusterSpec,
    engine: &Arc<CorrEngine>,
    default_algo: TmfgAlgo,
    batch_size: usize,
    state: &ServiceState,
    enqueued: Instant,
    tenant: Option<&str>,
    conn: u64,
) -> Json {
    let t = crate::util::timer::Timer::start();
    // Queue delay as seen at processing start — stamped on this
    // request's wide event (the histogram observation happens in
    // `run_job`).
    let queue_delay = enqueued.elapsed();
    if spec.sparse_k.is_some() {
        state.sparse_requests.fetch_add(1, Ordering::Relaxed);
    } else {
        state.dense_requests.fetch_add(1, Ordering::Relaxed);
    }
    // Traced requests own the process-wide tracing session for their
    // duration (the session gate serializes them); everything else just
    // gets a fresh trace_id to echo for log correlation.
    let traced = spec.trace;
    let (session, trace_id) = if traced {
        let s = crate::obs::TraceSession::begin();
        let tid = s.id().to_string();
        (Some(s), tid)
    } else {
        (None, crate::obs::next_trace_id())
    };
    // Logs emitted while this request runs carry its trace id.
    let _trace = crate::obs::TraceCtx::enter(&trace_id);
    // Retroactive queue-wait span (submit → processing start). Its start
    // predates the session epoch, which the exporter clamps to ts=0.
    crate::obs::record_span(
        "queue_wait",
        String::new(),
        enqueued,
        enqueued.elapsed().as_nanos() as u64,
    );
    let result = run_cluster(spec, engine, state.cache.as_ref(), default_algo);
    let trace_json = session.map(|s| {
        let (tid, epoch, threads) = s.finish();
        crate::obs::chrome_trace(&tid, epoch, &threads)
    });
    let wall = t.elapsed();
    // End-to-end latency feeds the "request" SLO series for every
    // completed (ok or error) batch request.
    if wall.is_finite() && wall >= 0.0 {
        crate::obs::slo_tracker().record("request", Duration::from_secs_f64(wall));
    }
    match result {
        Ok(out) => {
            let Some(labels) = out.labels else {
                let resp = with_trace_id(
                    wire::error_response(id, &TmfgError::invariant("run produced no labels")),
                    &trace_id,
                );
                record_failure(
                    state, &trace_id, tenant, conn, "invariant", queue_delay, wall, &resp,
                );
                return resp;
            };
            match out.oracle {
                crate::apsp::OracleKind::Dense => {
                    state.oracle_dense.fetch_add(1, Ordering::Relaxed)
                }
                crate::apsp::OracleKind::Hub => {
                    state.oracle_hub.fetch_add(1, Ordering::Relaxed)
                }
            };
            state.stages.lock().unwrap().merge(&out.breakdown);
            let mut fields = vec![
                ("labels", Json::arr_usize(&labels)),
                ("ari", out.ari.map(Json::Num).unwrap_or(Json::Null)),
                ("secs", Json::Num(wall)),
                ("algo", Json::str(&out.algo.name())),
                ("oracle", Json::str(out.oracle.name())),
                ("batch", Json::Num(batch_size as f64)),
            ];
            if let Some(sp) = &out.sparse {
                fields.push(("sparse_k", Json::Num(sp.k as f64)));
                fields.push(("sparse_nnz", Json::Num(sp.nnz as f64)));
                fields.push(("sparse_fallbacks", Json::Num(sp.fallbacks as f64)));
                // Echo the effective ANN configuration so clients can
                // see what the engine actually ran with.
                fields.push(("sparse_dims", Json::Num(sp.dims as f64)));
                fields.push(("sparse_pool", Json::Num(sp.pool as f64)));
                fields.push(("sparse_iters", Json::Num(sp.iters as f64)));
            }
            match out.cache {
                CacheStatus::Hit => fields.push(("cache", Json::str("hit"))),
                CacheStatus::Miss => fields.push(("cache", Json::str("miss"))),
                CacheStatus::Bypass => {}
            }
            fields.push(("trace_id", Json::str(&trace_id)));
            let mut resp = wire::ok_response(id, fields);
            if let (Some(tj), Json::Obj(map)) = (trace_json, &mut resp) {
                map.insert("trace".to_string(), tj);
            }
            // The wide event is built only when the recorder is enabled,
            // strictly after the computation — it can never affect the
            // (deterministic) response bytes.
            state.recorder.record_with(|| {
                let stages = Json::obj(
                    out.breakdown
                        .stages()
                        .iter()
                        .map(|(s, v)| (s.as_str(), Json::Num(*v * 1e3)))
                        .collect(),
                );
                let sparse = out
                    .sparse
                    .as_ref()
                    .map(|sp| {
                        Json::obj(vec![
                            ("k", Json::Num(sp.k as f64)),
                            ("nnz", Json::Num(sp.nnz as f64)),
                            ("fallbacks", Json::Num(sp.fallbacks as f64)),
                            ("dims", Json::Num(sp.dims as f64)),
                            ("pool", Json::Num(sp.pool as f64)),
                            ("iters", Json::Num(sp.iters as f64)),
                        ])
                    })
                    .unwrap_or(Json::Null);
                let cache = match out.cache {
                    CacheStatus::Hit => "hit",
                    CacheStatus::Miss => "miss",
                    CacheStatus::Bypass => "bypass",
                };
                wide_event(
                    &trace_id,
                    "batch",
                    tenant,
                    conn,
                    "ok",
                    queue_delay,
                    wall,
                    stages,
                    vec![
                        ("response_bytes", Json::Num(resp.to_string().len() as f64)),
                        ("cache", Json::str(cache)),
                        ("oracle", Json::str(out.oracle.name())),
                        ("algo", Json::str(&out.algo.name())),
                        ("batch", Json::Num(batch_size as f64)),
                        ("sparse", sparse),
                        (
                            "resources",
                            Json::obj(vec![
                                ("oracle_rows", Json::Num(out.resources.oracle_rows as f64)),
                                (
                                    "knn_fallbacks",
                                    Json::Num(out.resources.knn_fallbacks as f64),
                                ),
                                ("cache_bytes", Json::Num(out.resources.cache_bytes as f64)),
                            ]),
                        ),
                    ],
                )
            });
            resp
        }
        Err(e) => {
            let resp = with_trace_id(wire::error_response(id, &e), &trace_id);
            record_failure(state, &trace_id, tenant, conn, e.code(), queue_delay, wall, &resp);
            resp
        }
    }
}

/// Stamp the request's trace id onto a wire response (ok or error).
fn with_trace_id(mut resp: Json, trace_id: &str) -> Json {
    if let Json::Obj(map) = &mut resp {
        map.insert("trace_id".to_string(), Json::str(trace_id));
    }
    resp
}

/// Render the flight recorder's live counters as a JSON object (embedded
/// by both `stats` and `debug_dump`).
fn recorder_stats_json(rec: &crate::obs::FlightRecorder) -> Json {
    let rs = rec.stats();
    Json::obj(vec![
        ("budget_bytes", Json::Num(rs.budget_bytes as f64)),
        ("events", Json::Num(rs.events as f64)),
        ("bytes", Json::Num(rs.bytes as f64)),
        ("recorded", Json::Num(rs.recorded as f64)),
        ("evicted", Json::Num(rs.evicted as f64)),
    ])
}

/// Answer `{"cmd": "debug_dump"}`: replay the flight recorder's wide
/// events (oldest first) plus its live counters.
fn debug_dump_response(id: &Json, state: &ServiceState) -> Json {
    let events: Vec<Json> =
        state.recorder.dump().iter().filter_map(|l| Json::parse(l).ok()).collect();
    wire::ok_response(
        id,
        vec![
            ("events", Json::Arr(events)),
            ("recorder", recorder_stats_json(&state.recorder)),
        ],
    )
}

/// Prometheus text for `{"cmd": "metrics"}`: refresh the recorder gauges
/// at scrape time, then append the `tmfg_slo_*` families (fractional
/// attainment/burn values live outside the u64-gauge registry).
fn metrics_text(state: &ServiceState) -> String {
    let reg = crate::obs::registry();
    let rs = state.recorder.stats();
    reg.gauge(crate::obs::names::RECORDER_EVENTS).store(rs.events as u64, Ordering::Relaxed);
    reg.gauge(crate::obs::names::RECORDER_BYTES).store(rs.bytes as u64, Ordering::Relaxed);
    format!("{}{}", reg.prometheus(), crate::obs::slo_tracker().prometheus())
}

/// One canonical flight-recorder wide event. The envelope keys
/// (`trace_id`, `kind`, `tenant`, `conn`, `outcome`, `ts_ms`,
/// `queue_delay_ms`, `wall_ms`, `stages`) appear on every event; callers
/// append per-kind extras. Stage timings are milliseconds and sum to at
/// most `wall_ms` — stages run sequentially within one request.
#[allow(clippy::too_many_arguments)]
fn wide_event(
    trace_id: &str,
    kind: &str,
    tenant: Option<&str>,
    conn: u64,
    outcome: &str,
    queue_delay: Duration,
    wall_secs: f64,
    stages: Json,
    extra: Vec<(&str, Json)>,
) -> Json {
    let ts_ms = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as f64)
        .unwrap_or(0.0);
    let mut fields = vec![
        ("trace_id", Json::str(trace_id)),
        ("kind", Json::str(kind)),
        ("tenant", tenant.map(Json::str).unwrap_or(Json::Null)),
        ("conn", Json::Num(conn as f64)),
        ("outcome", Json::str(outcome)),
        ("ts_ms", Json::Num(ts_ms)),
        ("queue_delay_ms", Json::Num(queue_delay.as_secs_f64() * 1e3)),
        ("wall_ms", Json::Num(wall_secs * 1e3)),
        ("stages", stages),
    ];
    fields.extend(extra);
    Json::obj(fields)
}

/// Record the wide event for a failed batch request.
#[allow(clippy::too_many_arguments)]
fn record_failure(
    state: &ServiceState,
    trace_id: &str,
    tenant: Option<&str>,
    conn: u64,
    code: &str,
    queue_delay: Duration,
    wall_secs: f64,
    resp: &Json,
) {
    state.recorder.record_with(|| {
        wide_event(
            trace_id,
            "batch",
            tenant,
            conn,
            "error",
            queue_delay,
            wall_secs,
            Json::obj(vec![]),
            vec![
                ("code", Json::str(code)),
                ("response_bytes", Json::Num(resp.to_string().len() as f64)),
            ],
        )
    });
}

/// Handle one streaming command against this worker's session map.
#[allow(clippy::too_many_arguments)]
fn stream_cmd(
    id: &Json,
    body: &Command,
    streams: &mut HashMap<u64, StreamSession>,
    conn: u64,
    default_algo: TmfgAlgo,
    batch: usize,
    state: &ServiceState,
    tenant: Option<&str>,
    enqueued: Instant,
) -> Json {
    match body {
        Command::OpenStream(open) => {
            let algo = open.algo.unwrap_or(default_algo);
            let mut scfg = StreamConfig::new(open.n, open.window, open.k);
            scfg.algo = algo;
            if let Some(d) = open.drift {
                scfg.policy.drift_threshold = d;
            }
            if let Some(w) = open.warmup {
                scfg.warmup = w;
            }
            if let Some(m) = open.max_refreshes {
                scfg.policy.max_refreshes = m;
            }
            match StreamSession::new(scfg) {
                Ok(session) => {
                    let sid = session.id();
                    // replacing an existing session is allowed (re-open)
                    if streams.insert(conn, session).is_none() {
                        state.open_streams.fetch_add(1, Ordering::Relaxed);
                    }
                    wire::ok_response(
                        id,
                        vec![
                            ("stream", Json::Bool(true)),
                            ("session", Json::Num(sid as f64)),
                            ("n", Json::Num(open.n as f64)),
                            ("window", Json::Num(open.window as f64)),
                            ("k", Json::Num(open.k as f64)),
                            ("algo", Json::str(&algo.name())),
                        ],
                    )
                }
                Err(e) => wire::error_response(id, &e),
            }
        }
        Command::Tick(sample) => {
            let queue_delay = enqueued.elapsed();
            let Some(session) = streams.get_mut(&conn) else {
                return wire::error_response(id, &TmfgError::StreamClosed);
            };
            let sid = session.id();
            match session.tick(sample) {
                Ok(out) => {
                    state.stages.lock().unwrap().add("stream_tick", out.secs);
                    if out.secs.is_finite() && out.secs >= 0.0 {
                        crate::obs::slo_tracker()
                            .record("stream_tick", Duration::from_secs_f64(out.secs));
                    }
                    let mut pairs = vec![
                        ("session", Json::Num(sid as f64)),
                        ("generation", Json::Num(out.generation as f64)),
                        ("tick", Json::Num(out.tick as f64)),
                        ("decision", Json::str(out.decision.name())),
                        ("secs", Json::Num(out.secs)),
                        ("batch", Json::Num(batch as f64)),
                        ("trace_id", Json::str(&out.trace_id)),
                    ];
                    if let Some(labels) = &out.labels {
                        pairs.push(("labels", Json::arr_usize(labels)));
                    }
                    if let Some(d) = out.drift {
                        pairs.push(("drift", Json::Num(d.max_abs as f64)));
                    }
                    let resp = wire::ok_response(id, pairs);
                    state.recorder.record_with(|| {
                        wide_event(
                            &out.trace_id,
                            "tick",
                            tenant,
                            conn,
                            "ok",
                            queue_delay,
                            out.secs,
                            Json::obj(vec![("stream_tick", Json::Num(out.secs * 1e3))]),
                            vec![
                                (
                                    "response_bytes",
                                    Json::Num(resp.to_string().len() as f64),
                                ),
                                ("session", Json::Num(sid as f64)),
                                ("generation", Json::Num(out.generation as f64)),
                                ("decision", Json::str(out.decision.name())),
                            ],
                        )
                    });
                    resp
                }
                Err(e) => wire::error_response(id, &e),
            }
        }
        // CloseStream; also issued internally on disconnect (idempotent).
        _ => match streams.remove(&conn) {
            Some(session) => {
                state.open_streams.fetch_sub(1, Ordering::Relaxed);
                let st = session.stats();
                wire::ok_response(
                    id,
                    vec![
                        ("closed", Json::Bool(true)),
                        ("session", Json::Num(session.id() as f64)),
                        ("ticks", Json::Num(st.ticks as f64)),
                        ("emissions", Json::Num(st.emissions as f64)),
                        ("rebuilds", Json::Num(st.rebuilds as f64)),
                        ("refreshes", Json::Num(st.refreshes as f64)),
                        ("generation", Json::Num(session.generation() as f64)),
                    ],
                )
            }
            None => wire::ok_response(id, vec![("closed", Json::Bool(false))]),
        },
    }
}

/// Process one job on a worker. `streams` is the worker's own shard of
/// the session map; stream jobs only ever arrive on their pinned shard.
fn run_job(
    job: Job,
    streams: &mut HashMap<u64, StreamSession>,
    cfg: &ServiceConfig,
    engine: &Arc<CorrEngine>,
    state: &ServiceState,
    batch_size: usize,
) {
    let Job { request, reply, conn, internal, enqueued } = job;
    let wire::Request { id, tenant, body, .. } = request;
    // Dispatcher queue-wait: submit → dequeue, into the metrics
    // histogram (stats/Prometheus percentiles) and the "queue_wait" SLO
    // series. The matching trace span is recorded in `process` once a
    // traced request's session is live.
    let wait = enqueued.elapsed();
    crate::obs::registry().observe_secs(
        crate::obs::names::QUEUE_WAIT_SECONDS,
        None,
        wait.as_secs_f64(),
    );
    crate::obs::slo_tracker().record("queue_wait", wait);
    // Contain panics to the one request: an unwinding worker thread would
    // otherwise die silently and permanently wedge its pinned shard
    // (queued jobs never drained, completions never delivered). The
    // library paths are de-panicked, so this only guards regressions.
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| match body {
        Command::Cluster(spec) => process(
            &id,
            spec,
            engine,
            cfg.default_algo,
            batch_size,
            state,
            enqueued,
            tenant.as_deref(),
            conn,
        ),
        body @ (Command::OpenStream(_) | Command::Tick(_) | Command::CloseStream) => stream_cmd(
            &id,
            &body,
            streams,
            conn,
            cfg.default_algo,
            batch_size,
            state,
            tenant.as_deref(),
            enqueued,
        ),
        // Ping/Shutdown/Stats/Metrics/DebugDump are answered in the
        // front end and never enqueued; answer defensively anyway.
        Command::Ping
        | Command::Shutdown
        | Command::Stats
        | Command::Metrics
        | Command::DebugDump => wire::ok_response(&id, vec![]),
    }));
    let resp = result.unwrap_or_else(|_| {
        wire::error_response(
            &id,
            &TmfgError::invariant("internal panic while processing request"),
        )
    });
    if !internal {
        state.jobs_done.fetch_add(1, Ordering::Relaxed);
    }
    reply.send(resp.to_string());
}

/// One dispatch worker: drains its pinned (stream) queue eagerly, then
/// pulls batches of clustering jobs from the shared queue. Exits when
/// both queues are closed and drained.
fn dispatch_worker(
    w: usize,
    cfg: Arc<ServiceConfig>,
    state: Arc<ServiceState>,
    engine: Arc<CorrEngine>,
) {
    let pinned = state.pinned[w].clone();
    let global = state.global.clone();
    let mut streams: HashMap<u64, StreamSession> = HashMap::new();
    loop {
        // Session-pinned jobs first: ticks are latency-sensitive and
        // cheap relative to batch clustering.
        while let Some(job) = pinned.try_pop() {
            run_job(job, &mut streams, &cfg, &engine, &state, 1);
        }
        // One batch from the shared queue, gathered over the batching
        // window (non-blocking first pop: idle waiting happens on the
        // pinned queue below, which shared-queue pushes poke).
        match global.pop_timeout(Duration::ZERO) {
            Pop::Job(first) => {
                let mut batch = vec![first];
                let deadline = Instant::now() + cfg.batch_window;
                while batch.len() < cfg.max_batch {
                    let left = deadline.saturating_duration_since(Instant::now());
                    if left.is_zero() {
                        break;
                    }
                    match global.pop_timeout(left) {
                        Pop::Job(j) => batch.push(j),
                        _ => break,
                    }
                }
                let bsize = batch.len();
                for job in batch {
                    // Between heavy clustering jobs, serve this shard's
                    // ticks — only this worker can, and a full batch of
                    // multi-hundred-ms runs would otherwise head-of-line
                    // block a session for the whole batch.
                    while let Some(tick) = pinned.try_pop() {
                        run_job(tick, &mut streams, &cfg, &engine, &state, 1);
                    }
                    run_job(job, &mut streams, &cfg, &engine, &state, bsize);
                }
            }
            Pop::Empty => {
                // Nothing anywhere: park on the pinned queue. Its own
                // pushes notify it directly; shared-queue pushes poke it;
                // close wakes it; the timeout bounds a missed wakeup.
                pinned.wait_work(Duration::from_millis(100));
            }
            Pop::Closed => {
                // Shared queue drained + closed: finish any pinned work,
                // then exit. Pinned queues were closed first, so nothing
                // new can arrive after this drain.
                while let Some(job) = pinned.try_pop() {
                    run_job(job, &mut streams, &cfg, &engine, &state, 1);
                }
                return;
            }
        }
    }
}

/// Serving policy for the event-loop front end: admission control,
/// backpressure, worker submission, and lifecycle accounting. All
/// callbacks run on the loop thread, so the maps need no locks.
#[cfg(unix)]
mod net_front {
    use super::*;
    use crate::net::server::{ConnId, Disposition, Handler};
    use std::collections::HashSet;

    /// CoDel-style admission gate over the dispatch queue's front-job
    /// age. The fixed depth bound answers "how much work is queued"; the
    /// gate answers "how *stale* is the queued work" — it arms once the
    /// oldest queued job has been older than the target for a sustained
    /// interval (target/4), then sheds new batch work until the delay
    /// drains back under the target. Loop-thread-only: no locks.
    struct DelayGate {
        target: Duration,
        /// When the front-job age first rose above the target (`None`
        /// while at/under it).
        above_since: Option<Instant>,
        dropping: bool,
    }

    impl DelayGate {
        fn new(target: Duration) -> DelayGate {
            DelayGate { target, above_since: None, dropping: false }
        }

        fn enabled(&self) -> bool {
            !self.target.is_zero()
        }

        /// Advance the gate with the current front-job age; returns
        /// whether new batch work should be shed.
        fn update(&mut self, oldest: Option<Duration>, now: Instant) -> bool {
            if !self.enabled() {
                return false;
            }
            match oldest {
                Some(age) if age > self.target => {
                    let since = *self.above_since.get_or_insert(now);
                    if now.duration_since(since) >= self.target / 4 {
                        self.dropping = true;
                    }
                }
                // Empty queue or age back under target: disarm fully.
                _ => {
                    self.above_since = None;
                    self.dropping = false;
                }
            }
            self.dropping
        }
    }

    pub(super) struct NetHandler {
        cfg: Arc<ServiceConfig>,
        state: Arc<ServiceState>,
        ctl: Arc<LoopCtl>,
        /// Queue-delay admission gate (ZERO target = disabled).
        gate: DelayGate,
        /// conn → tenant of its in-flight request (None = anonymous).
        inflight_tenant: HashMap<ConnId, Option<String>>,
        /// tenant → in-flight request count (quota admission).
        tenant_inflight: HashMap<String, usize>,
        /// Connections that ever opened a stream: on close they get an
        /// internal close_stream so the pinned worker frees the session.
        streamed: HashSet<ConnId>,
        // Cached global-registry handles mirroring the per-service
        // counters (the gauge sums across services in one process).
        m_accepted: Arc<AtomicU64>,
        m_active: Arc<AtomicU64>,
        m_rejected: Arc<AtomicU64>,
        m_overload: Arc<AtomicU64>,
        m_reaped: Arc<AtomicU64>,
        m_wakeups: Arc<AtomicU64>,
        /// Front-job age gauge, refreshed on every loop wakeup.
        m_queue_delay: Arc<AtomicU64>,
    }

    impl NetHandler {
        pub(super) fn new(
            cfg: Arc<ServiceConfig>,
            state: Arc<ServiceState>,
            ctl: Arc<LoopCtl>,
        ) -> NetHandler {
            use crate::obs::names;
            let reg = crate::obs::registry();
            let gate = DelayGate::new(cfg.target_queue_delay);
            NetHandler {
                cfg,
                state,
                ctl,
                gate,
                inflight_tenant: HashMap::new(),
                tenant_inflight: HashMap::new(),
                streamed: HashSet::new(),
                m_accepted: reg.counter(names::CONNS_ACCEPTED),
                m_active: reg.gauge(names::CONNS_ACTIVE),
                m_rejected: reg.counter(names::CONNS_REJECTED_LIMIT),
                m_overload: reg.counter(names::OVERLOAD_REJECTED),
                m_reaped: reg.counter(names::REAPED_IDLE),
                m_wakeups: reg.counter(names::LOOP_WAKEUPS),
                m_queue_delay: reg.gauge(names::ADMISSION_QUEUE_DELAY_US),
            }
        }

        /// Shed one request: count it under its cause (`depth`, `delay`,
        /// or `tenant`), write a `shed` wide event with a fresh trace
        /// id, and render the typed `overloaded` error line.
        fn shed(
            &self,
            id: &Json,
            tenant: Option<&str>,
            conn: ConnId,
            cause: &str,
            msg: String,
        ) -> String {
            match cause {
                "depth" => {
                    self.state.shed_depth.fetch_add(1, Ordering::Relaxed);
                    self.state.overload_rejected.fetch_add(1, Ordering::Relaxed);
                    self.m_overload.fetch_add(1, Ordering::Relaxed);
                }
                "delay" => {
                    self.state.shed_delay.fetch_add(1, Ordering::Relaxed);
                    self.state.overload_rejected.fetch_add(1, Ordering::Relaxed);
                    self.m_overload.fetch_add(1, Ordering::Relaxed);
                }
                _ => {
                    self.state.shed_tenant.fetch_add(1, Ordering::Relaxed);
                }
            }
            crate::obs::registry()
                .counter_labeled(crate::obs::names::SHED_TOTAL, "cause", cause)
                .fetch_add(1, Ordering::Relaxed);
            let trace_id = crate::obs::next_trace_id();
            self.state.recorder.record_with(|| {
                wide_event(
                    &trace_id,
                    "shed",
                    tenant,
                    conn,
                    "shed",
                    Duration::ZERO,
                    0.0,
                    Json::obj(vec![]),
                    vec![("shed_cause", Json::str(cause))],
                )
            });
            let err = TmfgError::overloaded(msg);
            with_trace_id(wire::error_response(id, &err), &trace_id).to_string()
        }

        /// Would admitting a request from `tenant` exceed the quota?
        /// Anonymous requests are exempt.
        fn tenant_over_quota(&self, tenant: &Option<String>) -> bool {
            if self.cfg.tenant_quota == 0 {
                return false;
            }
            match tenant {
                Some(t) => {
                    self.tenant_inflight.get(t).copied().unwrap_or(0) >= self.cfg.tenant_quota
                }
                None => false,
            }
        }

        fn note_admitted(&mut self, conn: ConnId, tenant: Option<String>) {
            if let Some(t) = &tenant {
                *self.tenant_inflight.entry(t.clone()).or_insert(0) += 1;
            }
            self.inflight_tenant.insert(conn, tenant);
        }

        /// The shared admission pipeline for decoded requests, line- or
        /// frame-borne: fast-path commands answer inline; everything
        /// else passes the tenant quota, the queue-depth bound, and the
        /// delay gate before being submitted to the dispatch tier.
        fn admit(&mut self, conn: ConnId, req: wire::Request) -> Disposition {
            match &req.body {
                Command::Ping => {
                    return Disposition::Respond(wire::ok_response(&req.id, vec![]).to_string())
                }
                Command::Stats => {
                    return Disposition::Respond(self.state.stats_response(&req.id).to_string())
                }
                Command::Metrics => {
                    let text = metrics_text(&self.state);
                    let resp = wire::ok_response(&req.id, vec![("metrics", Json::str(&text))]);
                    return Disposition::Respond(resp.to_string());
                }
                Command::DebugDump => {
                    return Disposition::Respond(
                        debug_dump_response(&req.id, &self.state).to_string(),
                    )
                }
                Command::Shutdown => {
                    return Disposition::RespondAndDrain(
                        wire::ok_response(&req.id, vec![]).to_string(),
                    )
                }
                _ => {}
            }
            let is_stream = matches!(
                req.body,
                Command::OpenStream(_) | Command::Tick(_) | Command::CloseStream
            );
            // close_stream only frees state — exempt from admission so a
            // throttled tenant can always release its sessions.
            let frees = matches!(req.body, Command::CloseStream);
            if !frees && self.tenant_over_quota(&req.tenant) {
                let t = req.tenant.as_deref().unwrap_or_default();
                *self
                    .state
                    .admission_rejected
                    .lock()
                    .unwrap()
                    .entry(t.to_string())
                    .or_insert(0) += 1;
                crate::obs::registry()
                    .counter_labeled(crate::obs::names::ADMISSION_REJECTED, "tenant", t)
                    .fetch_add(1, Ordering::Relaxed);
                let msg = format!(
                    "tenant '{t}' is at its in-flight quota ({}); retry after a response",
                    self.cfg.tenant_quota
                );
                return Disposition::Respond(self.shed(
                    &req.id,
                    req.tenant.as_deref(),
                    conn,
                    "tenant",
                    msg,
                ));
            }
            // Queue-depth backpressure for batch work: the hard ceiling.
            // This thread is the only batch submitter, so check-then-push
            // cannot overshoot.
            if !is_stream && self.state.global.len() >= self.state.max_queue {
                let msg = format!(
                    "dispatch queue full ({} queued); back off and retry",
                    self.state.max_queue
                );
                return Disposition::Respond(self.shed(
                    &req.id,
                    req.tenant.as_deref(),
                    conn,
                    "depth",
                    msg,
                ));
            }
            // Adaptive admission: shed new batch work while the dispatch
            // queue's front job has been older than the target for a
            // sustained interval. Pinned stream commands are exempt,
            // matching the depth check above.
            if !is_stream && self.gate.update(self.state.global.oldest_wait(), Instant::now()) {
                let msg = format!(
                    "dispatch queue delay above target ({} ms); back off and retry",
                    self.cfg.target_queue_delay.as_millis()
                );
                return Disposition::Respond(self.shed(
                    &req.id,
                    req.tenant.as_deref(),
                    conn,
                    "delay",
                    msg,
                ));
            }
            if matches!(req.body, Command::OpenStream(_)) {
                self.streamed.insert(conn);
            }
            let shard = (conn as usize) % self.state.workers;
            let tenant = req.tenant.clone();
            let id = req.id.clone();
            let job = Job {
                request: req,
                reply: Reply::Net { conn, ctl: self.ctl.clone() },
                conn,
                internal: false,
                enqueued: Instant::now(),
            };
            if !self.state.submit(is_stream, shard, job) {
                // Queues already closed — a drain won the race.
                let err = TmfgError::overloaded("service is shutting down");
                return Disposition::RespondAndClose(
                    wire::error_response(&id, &err).to_string(),
                );
            }
            self.note_admitted(conn, tenant);
            Disposition::Submitted
        }
    }

    impl Handler for NetHandler {
        fn on_start(&mut self, backend: &'static str) {
            *self.state.net_backend.lock().unwrap() = backend;
        }

        fn on_accept(&mut self, _conn: ConnId) {
            self.state.conns_accepted.fetch_add(1, Ordering::Relaxed);
            self.state.conns_active.fetch_add(1, Ordering::Relaxed);
            self.m_accepted.fetch_add(1, Ordering::Relaxed);
            self.m_active.fetch_add(1, Ordering::Relaxed);
        }

        fn on_line(&mut self, conn: ConnId, line: &str) -> Disposition {
            let raw = match Json::parse(line) {
                Ok(j) => j,
                Err(e) => {
                    let err = TmfgError::protocol(format!("bad json: {e}"));
                    return Disposition::Respond(
                        wire::error_response(&Json::Null, &err).to_string(),
                    );
                }
            };
            // The single validated parse path: typed command or typed
            // error.
            let req = match wire::Request::decode(&raw) {
                Ok(r) => r,
                Err(e) => {
                    return Disposition::Respond(
                        wire::error_response(raw.get("id"), &e).to_string(),
                    )
                }
            };
            self.admit(conn, req)
        }

        /// Binary frames share the JSON path's admission pipeline: the
        /// header decodes through [`wire::Request::decode_frame`] (which
        /// also absorbs the payload as the request panel), then the same
        /// quota/depth/delay gates apply. Responses are always JSON
        /// lines — byte-identical to the line protocol's.
        fn on_frame(
            &mut self,
            conn: ConnId,
            frame: crate::net::conn::FrameRequest,
        ) -> Disposition {
            let raw = match Json::parse(&frame.header) {
                Ok(j) => j,
                Err(e) => {
                    let err = TmfgError::protocol(format!("bad frame header json: {e}"));
                    return Disposition::Respond(
                        wire::error_response(&Json::Null, &err).to_string(),
                    );
                }
            };
            let req = match wire::Request::decode_frame(&raw, frame.payload) {
                Ok(r) => r,
                Err(e) => {
                    return Disposition::Respond(
                        wire::error_response(raw.get("id"), &e).to_string(),
                    )
                }
            };
            self.admit(conn, req)
        }

        /// The frame decoder rejected the byte stream itself (bad
        /// lengths, over-cap payload): typed `protocol` error, then the
        /// loop closes the connection.
        fn on_bad_frame(&mut self, _conn: ConnId, reason: &str) -> String {
            let err = TmfgError::protocol(format!("malformed frame: {reason}"));
            wire::error_response(&Json::Null, &err).to_string()
        }

        fn on_complete(&mut self, conn: ConnId) {
            // Fires exactly once per admitted request — even if the
            // connection died first — so quota accounting balances.
            if let Some(Some(t)) = self.inflight_tenant.remove(&conn) {
                match self.tenant_inflight.get_mut(&t) {
                    Some(n) if *n > 1 => *n -= 1,
                    _ => {
                        self.tenant_inflight.remove(&t);
                    }
                }
            }
        }

        fn on_close(&mut self, conn: ConnId) {
            self.state.conns_active.fetch_sub(1, Ordering::Relaxed);
            self.m_active.fetch_sub(1, Ordering::Relaxed);
            // A dying connection that opened a stream gets an internal
            // close_stream so the pinned worker frees the session and
            // `open_streams` returns to truth — on *every* close path
            // (EOF, error, idle reap, drain), which the old front end
            // missed for shutdown-triggered disconnects.
            if self.streamed.remove(&conn) {
                let shard = (conn as usize) % self.state.workers;
                let _ = self.state.submit(
                    true,
                    shard,
                    Job {
                        request: wire::Request {
                            id: Json::Null,
                            v: wire::PROTOCOL_VERSION,
                            tenant: None,
                            body: Command::CloseStream,
                        },
                        reply: Reply::Discard,
                        conn,
                        internal: true,
                        enqueued: Instant::now(),
                    },
                );
            }
        }

        fn on_conn_limit(&mut self) -> String {
            self.state.conns_rejected.fetch_add(1, Ordering::Relaxed);
            self.m_rejected.fetch_add(1, Ordering::Relaxed);
            let err = TmfgError::overloaded(format!(
                "connection limit reached ({}); retry later",
                self.cfg.max_conns
            ));
            wire::error_response(&Json::Null, &err).to_string()
        }

        fn on_overflow(&mut self, _conn: ConnId) -> String {
            let err = TmfgError::protocol(format!(
                "request line exceeds max_line_bytes ({})",
                self.cfg.max_line_bytes
            ));
            wire::error_response(&Json::Null, &err).to_string()
        }

        fn on_reaped(&mut self, _conn: ConnId) {
            self.state.reaped_idle.fetch_add(1, Ordering::Relaxed);
            self.m_reaped.fetch_add(1, Ordering::Relaxed);
        }

        fn on_wakeup(&mut self) {
            self.state.loop_wakeups.fetch_add(1, Ordering::Relaxed);
            self.m_wakeups.fetch_add(1, Ordering::Relaxed);
            // Sample the shared queue's front-job age on every loop
            // iteration: exported as the admission queue-delay gauge and
            // advanced through the delay gate so the drop state decays
            // once the backlog drains, even with no new arrivals.
            let oldest = self.state.global.oldest_wait();
            let us = oldest.map(|d| d.as_micros().min(u64::MAX as u128) as u64).unwrap_or(0);
            self.m_queue_delay.store(us, Ordering::Relaxed);
            self.gate.update(oldest, Instant::now());
        }
    }
}

/// Start the service; returns once the listener is bound.
pub fn serve(cfg: ServiceConfig) -> std::io::Result<ServiceHandle> {
    let listener = TcpListener::bind(&cfg.addr)?;
    let addr = listener.local_addr()?.to_string();
    let workers = cfg.resolved_workers();
    let max_queue = cfg.resolved_max_queue();
    crate::obs::registry()
        .gauge(crate::obs::names::DISPATCH_WORKERS)
        .store(workers as u64, Ordering::Relaxed);
    let cache = if cfg.cache_entries > 0 {
        Some(Arc::new(ArtifactCache::new(cfg.cache_entries, cfg.cache_bytes)))
    } else {
        None
    };
    let state = Arc::new(ServiceState {
        workers,
        max_queue,
        global: Arc::new(JobQueue::new()),
        pinned: (0..workers).map(|_| Arc::new(JobQueue::new())).collect(),
        cache,
        net_backend: Mutex::new("threads"),
        jobs_done: AtomicU64::new(0),
        open_streams: AtomicUsize::new(0),
        sparse_requests: AtomicU64::new(0),
        dense_requests: AtomicU64::new(0),
        oracle_dense: AtomicU64::new(0),
        oracle_hub: AtomicU64::new(0),
        conns_accepted: AtomicU64::new(0),
        conns_active: AtomicU64::new(0),
        conns_rejected: AtomicU64::new(0),
        overload_rejected: AtomicU64::new(0),
        reaped_idle: AtomicU64::new(0),
        loop_wakeups: AtomicU64::new(0),
        admission_rejected: Mutex::new(BTreeMap::new()),
        stages: Mutex::new(Breakdown::new()),
        recorder: Arc::new(crate::obs::FlightRecorder::new(cfg.flight_recorder_bytes)),
        target_queue_delay: cfg.target_queue_delay,
        shed_depth: AtomicU64::new(0),
        shed_delay: AtomicU64::new(0),
        shed_tenant: AtomicU64::new(0),
    });
    let cfg = Arc::new(ServiceConfig { addr: addr.clone(), ..cfg });
    #[cfg(unix)]
    let (ctl, wake_rx) = LoopCtl::new()?;
    #[cfg(not(unix))]
    let ctl = LoopCtl::new_detached();
    let loop_ctl = ctl.clone();
    let srv_cfg = cfg.clone();
    let st = state.clone();
    let join = std::thread::spawn(move || {
        // One similarity engine for the whole service lifetime: compiled
        // XLA executables are cached inside and shared across every
        // worker, request, and algorithm.
        let engine = Arc::new(CorrEngine::auto(std::path::Path::new("artifacts")));
        let mut worker_joins = Vec::with_capacity(st.workers);
        for w in 0..st.workers {
            let (cfg, st2, engine) = (srv_cfg.clone(), st.clone(), engine.clone());
            worker_joins.push(std::thread::spawn(move || dispatch_worker(w, cfg, st2, engine)));
        }
        // The front end runs on this thread until drain completes: the
        // event loop on unix (one OS thread for every connection), the
        // legacy thread-per-connection accept loop elsewhere.
        #[cfg(unix)]
        {
            let net_cfg = crate::net::server::ServerConfig {
                max_conns: srv_cfg.max_conns,
                max_line_bytes: srv_cfg.max_line_bytes,
                idle_timeout: srv_cfg.idle_timeout,
                backend: if srv_cfg.poll_backend {
                    crate::net::poller::Backend::Poll
                } else {
                    crate::net::poller::Backend::Auto
                },
            };
            let mut handler =
                net_front::NetHandler::new(srv_cfg.clone(), st.clone(), loop_ctl.clone());
            if let Err(e) =
                crate::net::server::run(listener, &net_cfg, &loop_ctl, wake_rx, &mut handler)
            {
                crate::log!(error, "service event loop failed: {e}");
            }
        }
        #[cfg(not(unix))]
        legacy_accept_loop(listener, &st, &loop_ctl);
        // Close pinned queues before the shared one: workers only exit on
        // shared-queue Closed, at which point the pinned drain sees a
        // queue that can no longer grow.
        for q in &st.pinned {
            q.close();
        }
        st.global.close();
        for j in worker_joins {
            let _ = j.join();
        }
        // Graceful drain finished: dump the flight recorder to the
        // configured JSONL path (one wide event per line, oldest first).
        if let Some(path) = &srv_cfg.flight_log {
            let mut out = String::new();
            for line in st.recorder.dump() {
                out.push_str(&line);
                out.push('\n');
            }
            if let Err(e) = std::fs::write(path, out) {
                crate::log!(error, "failed to write flight log {path}: {e}");
            }
        }
    });
    Ok(ServiceHandle { addr, ctl, join: Some(join) })
}

/// Legacy blocking front end: thread per connection (non-unix fallback).
#[cfg(not(unix))]
fn legacy_accept_loop(listener: TcpListener, state: &Arc<ServiceState>, ctl: &Arc<LoopCtl>) {
    for stream in listener.incoming() {
        if ctl.shutdown_requested() {
            break;
        }
        let Ok(stream) = stream else { continue };
        let st_conn = state.clone();
        let ctl_conn = ctl.clone();
        std::thread::spawn(move || handle_conn(stream, st_conn, ctl_conn));
    }
}

#[cfg(not(unix))]
fn handle_conn(stream: TcpStream, state: Arc<ServiceState>, ctl: Arc<LoopCtl>) {
    let conn = CONN_SEQ.fetch_add(1, Ordering::Relaxed);
    let shard = (conn as usize) % state.workers;
    state.conns_accepted.fetch_add(1, Ordering::Relaxed);
    state.conns_active.fetch_add(1, Ordering::Relaxed);
    let peer = stream.try_clone();
    let reader = BufReader::new(stream);
    if let Ok(mut writer) = peer {
        for line in reader.lines() {
            let Ok(line) = line else { break };
            if line.trim().is_empty() {
                continue;
            }
            let raw = match Json::parse(&line) {
                Ok(j) => j,
                Err(e) => {
                    let _ = writeln!(
                        writer,
                        "{}",
                        wire::error_response(
                            &Json::Null,
                            &TmfgError::protocol(format!("bad json: {e}"))
                        )
                        .to_string()
                    );
                    continue;
                }
            };
            // The single validated parse path: typed command or typed error.
            let req = match wire::Request::decode(&raw) {
                Ok(r) => r,
                Err(e) => {
                    let _ =
                        writeln!(writer, "{}", wire::error_response(raw.get("id"), &e).to_string());
                    continue;
                }
            };
            match &req.body {
                Command::Ping => {
                    let _ = writeln!(writer, "{}", wire::ok_response(&req.id, vec![]).to_string());
                    continue;
                }
                Command::Stats => {
                    let _ = writeln!(writer, "{}", state.stats_response(&req.id).to_string());
                    continue;
                }
                Command::Metrics => {
                    let text = metrics_text(&state);
                    let resp = wire::ok_response(&req.id, vec![("metrics", Json::str(&text))]);
                    let _ = writeln!(writer, "{}", resp.to_string());
                    continue;
                }
                Command::DebugDump => {
                    let _ = writeln!(
                        writer,
                        "{}",
                        debug_dump_response(&req.id, &state).to_string()
                    );
                    continue;
                }
                Command::Shutdown => {
                    ctl.request_shutdown();
                    let _ = writeln!(writer, "{}", wire::ok_response(&req.id, vec![]).to_string());
                    // Poke the acceptor (blocked in accept()) so it
                    // observes the flag; break (not return!) so the
                    // disconnect cleanup below still frees any session.
                    if let Ok(addr) = writer.local_addr() {
                        let _ = TcpStream::connect(addr);
                    }
                    break;
                }
                _ => {}
            }
            // Stream commands are pinned to this connection's shard so the
            // owning worker's session map serves every tick; batch work
            // goes through the shared queue.
            let is_stream = matches!(
                req.body,
                Command::OpenStream(_) | Command::Tick(_) | Command::CloseStream
            );
            let (rtx, rrx) = channel();
            let job = Job {
                request: req,
                reply: Reply::Channel(rtx),
                conn,
                internal: false,
                enqueued: Instant::now(),
            };
            if !state.submit(is_stream, shard, job) {
                break; // queues closed: service is shutting down
            }
            match rrx.recv() {
                Ok(resp) => {
                    if writeln!(writer, "{resp}").is_err() {
                        break;
                    }
                }
                Err(_) => break,
            }
        }
    }
    // Connection gone: free any stream session it owned (idempotent; the
    // response is discarded). Runs on every exit path, including
    // client-initiated shutdown.
    state.conns_active.fetch_sub(1, Ordering::Relaxed);
    let _ = state.submit(
        true,
        shard,
        Job {
            request: wire::Request {
                id: Json::Null,
                v: wire::PROTOCOL_VERSION,
                tenant: None,
                body: Command::CloseStream,
            },
            reply: Reply::Discard,
            conn,
            internal: true,
            enqueued: Instant::now(),
        },
    );
}

/// Minimal blocking client used by tests and the serve example.
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    pub fn call(&mut self, req: &Json) -> std::io::Result<Json> {
        writeln!(self.stream, "{}", req.to_string())?;
        self.read_response()
    }

    /// Send one request as a binary frame (protocol v2): `header` is the
    /// request object minus "data", `payload` the row-major panel. The
    /// response comes back as a JSON line, exactly like [`Self::call`].
    pub fn call_frame(&mut self, header: &Json, payload: &[f32]) -> std::io::Result<Json> {
        let bytes = wire::encode_frame(header, payload);
        self.stream.write_all(&bytes)?;
        self.stream.flush()?;
        self.read_response()
    }

    fn read_response(&mut self) -> std::io::Result<Json> {
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(&line)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string()))
    }
}
