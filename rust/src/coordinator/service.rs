//! A batched TCP clustering service — the "deployment" face of the
//! coordinator. Wire protocol: one JSON object per line per request;
//! one JSON object per line back.
//!
//! Request fields:
//!   {"id": 7, "dataset": "CBF", "scale": 0.05, "seed": 1,
//!    "algo": "opt", "k": 3}
//! or inline data:
//!   {"id": 7, "n": 16, "l": 8, "data": [ ... n*l floats ... ], "k": 2}
//! Special: {"cmd": "ping"} → {"ok": true}, {"cmd": "shutdown"}.
//!
//! Response: {"id": 7, "ok": true, "labels": [...], "ari": 0.4,
//!            "secs": 0.01, "algo": "opt-tdbht", "batch": 3}
//!
//! Architecture: acceptor threads parse requests into a shared queue; a
//! single dispatcher drains the queue in small batches (batching window),
//! runs each batch's similarity computations through one shared engine
//! (amortizing executable-cache hits), then the graph stages per request
//! on the parallel pool, and replies. The batch size a request rode in on
//! is reported so clients/tests can observe batching.

use super::pipeline::{Pipeline, PipelineConfig, TmfgAlgo};
use super::registry;
use crate::data::matrix::Matrix;
use crate::data::synth::Dataset;
use crate::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::Duration;

pub struct ServiceConfig {
    pub addr: String,
    /// Max requests per batch.
    pub max_batch: usize,
    /// Batching window: wait this long for more requests after the first.
    pub batch_window: Duration,
    pub default_algo: TmfgAlgo,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            addr: "127.0.0.1:7401".into(),
            max_batch: 8,
            batch_window: Duration::from_millis(5),
            default_algo: TmfgAlgo::Opt,
        }
    }
}

struct Job {
    request: Json,
    reply: Sender<String>,
}

/// Handle to a running service (for tests and the `serve` example).
pub struct ServiceHandle {
    pub addr: String,
    shutdown: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ServiceHandle {
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::Release);
        // poke the acceptor so it notices
        let _ = TcpStream::connect(&self.addr);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn parse_dataset(req: &Json) -> Result<(Dataset, usize), String> {
    let k = req.get("k").as_usize().unwrap_or(0);
    if let Some(name) = req.get("dataset").as_str() {
        let scale = req.get("scale").as_f64().unwrap_or(0.05);
        let seed = req.get("seed").as_f64().unwrap_or(1.0) as u64;
        let ds = registry::get_dataset(name, scale, seed)
            .ok_or_else(|| format!("unknown dataset {name}"))?;
        let k = if k == 0 { ds.n_classes } else { k };
        return Ok((ds, k));
    }
    let n = req.get("n").as_usize().ok_or("missing n")?;
    let l = req.get("l").as_usize().ok_or("missing l")?;
    let arr = req.get("data").as_arr().ok_or("missing data")?;
    if arr.len() != n * l {
        return Err(format!("data length {} != n*l = {}", arr.len(), n * l));
    }
    let data: Vec<f32> = arr
        .iter()
        .map(|v| v.as_f64().unwrap_or(f64::NAN) as f32)
        .collect();
    if k == 0 {
        return Err("inline data requires k".into());
    }
    Ok((
        Dataset {
            name: "inline".into(),
            data: Matrix::from_vec(n, l, data),
            labels: vec![0; n],
            n_classes: k,
        },
        k,
    ))
}

fn process(req: &Json, pipeline: &Pipeline, batch_size: usize) -> Json {
    let id = req.get("id").clone();
    let t = crate::util::timer::Timer::start();
    match parse_dataset(req) {
        Ok((ds, k)) => {
            // run_dataset routes the similarity computation through the
            // shared engine (XLA artifact path when a bucket fits).
            let out = pipeline.run_dataset(&ds);
            let labels = out.dbht.dendrogram.cut(k);
            // Report ARI only for named datasets (which carry ground truth).
            let ari = if req.get("dataset").as_str().is_some() {
                Some(crate::metrics::adjusted_rand_index(&ds.labels, &labels))
            } else {
                None
            };
            Json::obj(vec![
                ("id", id),
                ("ok", Json::Bool(true)),
                ("labels", Json::arr_usize(&labels)),
                (
                    "ari",
                    ari.map(Json::Num).unwrap_or(Json::Null),
                ),
                ("secs", Json::Num(t.elapsed())),
                ("algo", Json::str(&pipeline.config.algo.name())),
                ("batch", Json::Num(batch_size as f64)),
            ])
        }
        Err(e) => Json::obj(vec![
            ("id", id),
            ("ok", Json::Bool(false)),
            ("error", Json::str(&e)),
        ]),
    }
}

fn dispatcher(rx: Receiver<Job>, cfg: &ServiceConfig, shutdown: Arc<AtomicBool>) {
    // One pipeline per algo, built lazily; engines (and their compiled
    // XLA executables) are shared across the whole service lifetime.
    let mut pipelines: std::collections::HashMap<String, Pipeline> = Default::default();
    loop {
        let first = match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(j) => j,
            Err(RecvTimeoutError::Timeout) => {
                if shutdown.load(Ordering::Acquire) {
                    return;
                }
                continue;
            }
            Err(RecvTimeoutError::Disconnected) => return,
        };
        // batching window: gather more requests
        let mut batch = vec![first];
        let deadline = std::time::Instant::now() + cfg.batch_window;
        while batch.len() < cfg.max_batch {
            let left = deadline.saturating_duration_since(std::time::Instant::now());
            if left.is_zero() {
                break;
            }
            match rx.recv_timeout(left) {
                Ok(j) => batch.push(j),
                Err(_) => break,
            }
        }
        let bsize = batch.len();
        for job in batch {
            let algo = job
                .request
                .get("algo")
                .as_str()
                .and_then(TmfgAlgo::parse)
                .unwrap_or(cfg.default_algo);
            let pipeline = pipelines.entry(algo.name()).or_insert_with(|| {
                Pipeline::new(PipelineConfig { algo, ..Default::default() })
            });
            let resp = process(&job.request, pipeline, bsize);
            let _ = job.reply.send(resp.to_string());
        }
    }
}

/// Start the service; returns once the listener is bound.
pub fn serve(cfg: ServiceConfig) -> std::io::Result<ServiceHandle> {
    let listener = TcpListener::bind(&cfg.addr)?;
    let addr = listener.local_addr()?.to_string();
    let shutdown = Arc::new(AtomicBool::new(false));
    let (tx, rx) = channel::<Job>();
    let sd = shutdown.clone();
    let cfg2 = ServiceConfig { addr: addr.clone(), ..cfg };
    let join = std::thread::spawn(move || {
        let sd_dispatch = sd.clone();
        let dispatch = std::thread::spawn(move || dispatcher(rx, &cfg2, sd_dispatch));
        for stream in listener.incoming() {
            if sd.load(Ordering::Acquire) {
                break;
            }
            let Ok(stream) = stream else { continue };
            let tx = tx.clone();
            let sd_conn = sd.clone();
            std::thread::spawn(move || handle_conn(stream, tx, sd_conn));
        }
        drop(tx);
        let _ = dispatch.join();
    });
    Ok(ServiceHandle { addr, shutdown, join: Some(join) })
}

fn handle_conn(stream: TcpStream, tx: Sender<Job>, shutdown: Arc<AtomicBool>) {
    let peer = stream.try_clone();
    let reader = BufReader::new(stream);
    let Ok(mut writer) = peer else { return };
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let req = match Json::parse(&line) {
            Ok(j) => j,
            Err(e) => {
                let _ = writeln!(
                    writer,
                    "{}",
                    Json::obj(vec![
                        ("ok", Json::Bool(false)),
                        ("error", Json::str(&format!("bad json: {e}")))
                    ])
                    .to_string()
                );
                continue;
            }
        };
        match req.get("cmd").as_str() {
            Some("ping") => {
                let _ = writeln!(writer, "{}", Json::obj(vec![("ok", Json::Bool(true))]).to_string());
                continue;
            }
            Some("shutdown") => {
                shutdown.store(true, Ordering::Release);
                let _ = writeln!(writer, "{}", Json::obj(vec![("ok", Json::Bool(true))]).to_string());
                return;
            }
            _ => {}
        }
        let (rtx, rrx) = channel();
        if tx.send(Job { request: req, reply: rtx }).is_err() {
            break;
        }
        match rrx.recv() {
            Ok(resp) => {
                if writeln!(writer, "{resp}").is_err() {
                    break;
                }
            }
            Err(_) => break,
        }
    }
}

/// Minimal blocking client used by tests and the serve example.
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    pub fn call(&mut self, req: &Json) -> std::io::Result<Json> {
        writeln!(self.stream, "{}", req.to_string())?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(&line)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string()))
    }
}
