//! A batched TCP clustering service — the "deployment" face of the
//! coordinator. Wire protocol: one JSON object per line per request;
//! one JSON object per line back. Requests are decoded through the
//! single validated parse path in [`crate::api::wire`] (versioned typed
//! requests; malformed fields are rejected with a stable error `code`
//! instead of being silently defaulted).
//!
//! Request fields:
//!   {"id": 7, "dataset": "CBF", "scale": 0.05, "seed": 1,
//!    "algo": "opt", "k": 3}
//! or inline data:
//!   {"id": 7, "n": 16, "l": 8, "data": [ ... n*l floats ... ], "k": 2}
//! Special: {"cmd": "ping"} → {"ok": true}, {"cmd": "shutdown"}.
//! Optional: {"v": 1, ...} pins the protocol version.
//!
//! Response: {"id": 7, "ok": true, "labels": [...], "ari": 0.4,
//!            "secs": 0.01, "algo": "opt-tdbht", "batch": 3}
//! Errors:   {"id": 7, "ok": false, "error": "...", "code": "protocol"}
//!
//! Streaming (one session per connection, state lives in the dispatcher):
//!   {"cmd": "open_stream", "n": 16, "k": 2, "window": 64, "algo": "opt",
//!    "drift": 0.1, "warmup": 8, "max_refreshes": 64}
//!     → {"ok": true, "stream": true, ...}
//!   {"cmd": "tick", "data": [ ... n floats, one per series ... ]}
//!     → {"ok": true, "generation": 12, "decision": "refresh"|"rebuild"|
//!        "warming", "labels": [...], "drift": 0.03, "secs": ..., ...}
//!       (labels/drift absent while warming; generation increases
//!        monotonically, stepping on every emitted clustering)
//!   {"cmd": "close_stream"} → {"ok": true, "closed": true, "ticks": ...,
//!        "emissions": ..., "rebuilds": ..., "refreshes": ...}
//!   Sessions are freed automatically when the connection drops.
//!
//! Architecture: acceptor threads parse + decode requests into a shared
//! queue; a single dispatcher drains the queue in small batches (batching
//! window), runs each batch's similarity computations through one shared
//! engine (amortizing executable-cache hits), then the graph stages per
//! request on the parallel pool, and replies. The batch size a request
//! rode in on is reported so clients/tests can observe batching. Stream
//! sessions are owned by the same dispatcher (keyed by connection), so
//! per-tick state never needs locking and rides the same batching queue.

use crate::api::wire::{self, ClusterSource, ClusterSpec, Command};
use crate::api::{ClusterRequest, TmfgAlgo, TmfgError};
use crate::data::matrix::Matrix;
use crate::runtime::engine::CorrEngine;
use crate::stream::{StreamConfig, StreamSession};
use crate::util::json::Json;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::Duration;

/// Distinguishes connections so the dispatcher can key stream sessions.
static CONN_SEQ: AtomicU64 = AtomicU64::new(1);

pub struct ServiceConfig {
    pub addr: String,
    /// Max requests per batch.
    pub max_batch: usize,
    /// Batching window: wait this long for more requests after the first.
    pub batch_window: Duration,
    pub default_algo: TmfgAlgo,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            addr: "127.0.0.1:7401".into(),
            max_batch: 8,
            batch_window: Duration::from_millis(5),
            default_algo: TmfgAlgo::Opt,
        }
    }
}

struct Job {
    request: wire::Request,
    reply: Sender<String>,
    /// Originating connection (stream sessions are per-connection).
    conn: u64,
}

/// Handle to a running service (for tests, the `serve` example, and the
/// CLI's `tmfg serve`).
pub struct ServiceHandle {
    pub addr: String,
    shutdown: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ServiceHandle {
    /// Request shutdown and join the service threads.
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::Release);
        // poke the acceptor so it notices
        let _ = TcpStream::connect(&self.addr);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }

    /// Block until the service shuts down (a client sent
    /// {"cmd": "shutdown"}). Used by `tmfg serve` to exit cleanly
    /// instead of sleeping forever.
    pub fn wait(mut self) {
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Run one batch clustering request through the shared-engine API. Takes
/// the spec by value so inline payloads move straight into the panel
/// matrix (no second copy on the dispatcher hot path).
fn run_cluster(
    spec: ClusterSpec,
    engine: &Arc<CorrEngine>,
    default_algo: TmfgAlgo,
) -> Result<(Vec<usize>, Option<f64>, TmfgAlgo), TmfgError> {
    let algo = spec.algo.unwrap_or(default_algo);
    let req = match spec.source {
        ClusterSource::Named { name, scale, seed } => {
            let mut r = ClusterRequest::dataset(name).scale(scale).seed(seed);
            if spec.k > 0 {
                r = r.k(spec.k);
            }
            r
        }
        ClusterSource::Inline { n, l, data } => {
            // decode() validated len == n*l and finiteness; k >= 1.
            let panel = Matrix::from_vec(n, l, data);
            ClusterRequest::panel(panel).k(spec.k)
        }
    };
    let out = req.algo(algo).engine(engine.clone()).run()?;
    let labels = out.labels.ok_or_else(|| TmfgError::invariant("run produced no labels"))?;
    Ok((labels, out.ari, algo))
}

fn process(
    id: &Json,
    spec: ClusterSpec,
    engine: &Arc<CorrEngine>,
    default_algo: TmfgAlgo,
    batch_size: usize,
) -> Json {
    let t = crate::util::timer::Timer::start();
    match run_cluster(spec, engine, default_algo) {
        Ok((labels, ari, algo)) => wire::ok_response(
            id,
            vec![
                ("labels", Json::arr_usize(&labels)),
                ("ari", ari.map(Json::Num).unwrap_or(Json::Null)),
                ("secs", Json::Num(t.elapsed())),
                ("algo", Json::str(&algo.name())),
                ("batch", Json::Num(batch_size as f64)),
            ],
        ),
        Err(e) => wire::error_response(id, &e),
    }
}

/// Handle one streaming command against the dispatcher-owned session map.
fn stream_cmd(
    id: &Json,
    body: &Command,
    streams: &mut HashMap<u64, StreamSession>,
    conn: u64,
    default_algo: TmfgAlgo,
    batch: usize,
) -> Json {
    match body {
        Command::OpenStream(open) => {
            let algo = open.algo.unwrap_or(default_algo);
            let mut scfg = StreamConfig::new(open.n, open.window, open.k);
            scfg.algo = algo;
            if let Some(d) = open.drift {
                scfg.policy.drift_threshold = d;
            }
            if let Some(w) = open.warmup {
                scfg.warmup = w;
            }
            if let Some(m) = open.max_refreshes {
                scfg.policy.max_refreshes = m;
            }
            match StreamSession::new(scfg) {
                Ok(session) => {
                    // replacing an existing session is allowed (re-open)
                    streams.insert(conn, session);
                    wire::ok_response(
                        id,
                        vec![
                            ("stream", Json::Bool(true)),
                            ("n", Json::Num(open.n as f64)),
                            ("window", Json::Num(open.window as f64)),
                            ("k", Json::Num(open.k as f64)),
                            ("algo", Json::str(&algo.name())),
                        ],
                    )
                }
                Err(e) => wire::error_response(id, &e),
            }
        }
        Command::Tick(sample) => {
            let Some(session) = streams.get_mut(&conn) else {
                return wire::error_response(id, &TmfgError::StreamClosed);
            };
            match session.tick(sample) {
                Ok(out) => {
                    let mut pairs = vec![
                        ("generation", Json::Num(out.generation as f64)),
                        ("tick", Json::Num(out.tick as f64)),
                        ("decision", Json::str(out.decision.name())),
                        ("secs", Json::Num(out.secs)),
                        ("batch", Json::Num(batch as f64)),
                    ];
                    if let Some(labels) = &out.labels {
                        pairs.push(("labels", Json::arr_usize(labels)));
                    }
                    if let Some(d) = out.drift {
                        pairs.push(("drift", Json::Num(d.max_abs as f64)));
                    }
                    wire::ok_response(id, pairs)
                }
                Err(e) => wire::error_response(id, &e),
            }
        }
        // CloseStream; also issued internally on disconnect (idempotent).
        _ => match streams.remove(&conn) {
            Some(session) => {
                let st = session.stats();
                wire::ok_response(
                    id,
                    vec![
                        ("closed", Json::Bool(true)),
                        ("ticks", Json::Num(st.ticks as f64)),
                        ("emissions", Json::Num(st.emissions as f64)),
                        ("rebuilds", Json::Num(st.rebuilds as f64)),
                        ("refreshes", Json::Num(st.refreshes as f64)),
                        ("generation", Json::Num(session.generation() as f64)),
                    ],
                )
            }
            None => wire::ok_response(id, vec![("closed", Json::Bool(false))]),
        },
    }
}

fn dispatcher(rx: Receiver<Job>, cfg: &ServiceConfig, shutdown: Arc<AtomicBool>) {
    // One similarity engine for the whole service lifetime: compiled XLA
    // executables are cached inside and shared across every request and
    // algorithm.
    let engine = Arc::new(CorrEngine::auto(std::path::Path::new("artifacts")));
    // Per-connection streaming sessions, owned here so tick state needs
    // no locking.
    let mut streams: HashMap<u64, StreamSession> = Default::default();
    loop {
        let first = match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(j) => j,
            Err(RecvTimeoutError::Timeout) => {
                if shutdown.load(Ordering::Acquire) {
                    return;
                }
                continue;
            }
            Err(RecvTimeoutError::Disconnected) => return,
        };
        // batching window: gather more requests
        let mut batch = vec![first];
        let deadline = std::time::Instant::now() + cfg.batch_window;
        while batch.len() < cfg.max_batch {
            let left = deadline.saturating_duration_since(std::time::Instant::now());
            if left.is_zero() {
                break;
            }
            match rx.recv_timeout(left) {
                Ok(j) => batch.push(j),
                Err(_) => break,
            }
        }
        let bsize = batch.len();
        for job in batch {
            let Job { request, reply, conn } = job;
            let wire::Request { id, body, .. } = request;
            let resp = match body {
                Command::Cluster(spec) => {
                    process(&id, spec, &engine, cfg.default_algo, bsize)
                }
                body @ (Command::OpenStream(_) | Command::Tick(_) | Command::CloseStream) => {
                    stream_cmd(&id, &body, &mut streams, conn, cfg.default_algo, bsize)
                }
                // Ping/Shutdown are answered in the connection handler and
                // never enqueued; answer defensively anyway.
                Command::Ping | Command::Shutdown => wire::ok_response(&id, vec![]),
            };
            let _ = reply.send(resp.to_string());
        }
    }
}

/// Start the service; returns once the listener is bound.
pub fn serve(cfg: ServiceConfig) -> std::io::Result<ServiceHandle> {
    let listener = TcpListener::bind(&cfg.addr)?;
    let addr = listener.local_addr()?.to_string();
    let shutdown = Arc::new(AtomicBool::new(false));
    let (tx, rx) = channel::<Job>();
    let sd = shutdown.clone();
    let cfg2 = ServiceConfig { addr: addr.clone(), ..cfg };
    let join = std::thread::spawn(move || {
        let sd_dispatch = sd.clone();
        let dispatch = std::thread::spawn(move || dispatcher(rx, &cfg2, sd_dispatch));
        for stream in listener.incoming() {
            if sd.load(Ordering::Acquire) {
                break;
            }
            let Ok(stream) = stream else { continue };
            let tx = tx.clone();
            let sd_conn = sd.clone();
            std::thread::spawn(move || handle_conn(stream, tx, sd_conn));
        }
        drop(tx);
        let _ = dispatch.join();
    });
    Ok(ServiceHandle { addr, shutdown, join: Some(join) })
}

fn handle_conn(stream: TcpStream, tx: Sender<Job>, shutdown: Arc<AtomicBool>) {
    let conn = CONN_SEQ.fetch_add(1, Ordering::Relaxed);
    let peer = stream.try_clone();
    let reader = BufReader::new(stream);
    let Ok(mut writer) = peer else { return };
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let raw = match Json::parse(&line) {
            Ok(j) => j,
            Err(e) => {
                let _ = writeln!(
                    writer,
                    "{}",
                    wire::error_response(
                        &Json::Null,
                        &TmfgError::protocol(format!("bad json: {e}"))
                    )
                    .to_string()
                );
                continue;
            }
        };
        // The single validated parse path: typed command or typed error.
        let req = match wire::Request::decode(&raw) {
            Ok(r) => r,
            Err(e) => {
                let _ = writeln!(writer, "{}", wire::error_response(raw.get("id"), &e).to_string());
                continue;
            }
        };
        match &req.body {
            Command::Ping => {
                let _ = writeln!(writer, "{}", wire::ok_response(&req.id, vec![]).to_string());
                continue;
            }
            Command::Shutdown => {
                shutdown.store(true, Ordering::Release);
                let _ = writeln!(writer, "{}", wire::ok_response(&req.id, vec![]).to_string());
                // Poke the acceptor (blocked in accept()) so it observes
                // the flag and the whole service can exit cleanly.
                if let Ok(addr) = writer.local_addr() {
                    let _ = TcpStream::connect(addr);
                }
                return;
            }
            _ => {}
        }
        let (rtx, rrx) = channel();
        if tx.send(Job { request: req, reply: rtx, conn }).is_err() {
            break;
        }
        match rrx.recv() {
            Ok(resp) => {
                if writeln!(writer, "{resp}").is_err() {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    // Connection gone: free any stream session it owned (idempotent; the
    // reply channel's receiver is dropped, so the response is discarded).
    let (rtx, _rrx) = channel();
    let _ = tx.send(Job {
        request: wire::Request {
            id: Json::Null,
            v: wire::PROTOCOL_VERSION,
            body: Command::CloseStream,
        },
        reply: rtx,
        conn,
    });
}

/// Minimal blocking client used by tests and the serve example.
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    pub fn call(&mut self, req: &Json) -> std::io::Result<Json> {
        writeln!(self.stream, "{}", req.to_string())?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(&line)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string()))
    }
}
