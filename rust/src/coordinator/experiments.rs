//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation section (§5) on the synthetic Table-1 mirror datasets.
//! Each experiment prints the same rows/series the paper reports and
//! writes a CSV under `results/`.

use super::pipeline::{ApspMode, Pipeline, PipelineConfig, TmfgAlgo};
use super::registry;
use crate::data::corr::pearson_correlation;
use crate::data::matrix::Matrix;
use crate::data::synth::Dataset;
use crate::dbht::Linkage;
use crate::parlay;
use crate::util::timer::Timer;
use std::io::Write;

/// Shared experiment options.
#[derive(Debug, Clone)]
pub struct ExpOpts {
    /// n-scale applied to the Table-1 sizes (1.0 = paper sizes; the
    /// default keeps the full suite tractable on a laptop-class box).
    pub scale: f64,
    pub seed: u64,
    /// Thread counts for the scaling sweeps (empty = 1,2,4,...,max).
    pub threads: Vec<usize>,
    /// Restrict to these dataset names (empty = experiment default).
    pub datasets: Vec<String>,
    pub out_dir: String,
}

impl Default for ExpOpts {
    fn default() -> Self {
        ExpOpts {
            scale: 0.1,
            seed: registry::DEFAULT_SEED,
            threads: Vec::new(),
            datasets: Vec::new(),
            out_dir: "results".into(),
        }
    }
}

impl ExpOpts {
    fn thread_sweep(&self) -> Vec<usize> {
        if !self.threads.is_empty() {
            return self.threads.clone();
        }
        let max = parlay::num_threads();
        let mut t = 1;
        let mut out = vec![];
        while t < max {
            out.push(t);
            t *= 2;
        }
        out.push(max);
        out
    }

    fn dataset_names(&self, default: Vec<String>) -> Vec<String> {
        if self.datasets.is_empty() {
            default
        } else {
            self.datasets.clone()
        }
    }
}

fn write_csv(opts: &ExpOpts, name: &str, header: &str, rows: &[Vec<String>]) {
    std::fs::create_dir_all(&opts.out_dir).ok();
    let path = format!("{}/{}.csv", opts.out_dir, name);
    let mut f = std::fs::File::create(&path).expect("create csv");
    writeln!(f, "{header}").unwrap();
    for r in rows {
        writeln!(f, "{}", r.join(",")).unwrap();
    }
    println!("wrote {path}");
}

fn pipeline_for(algo: TmfgAlgo) -> Pipeline {
    Pipeline::new(PipelineConfig { algo, use_xla: false, ..Default::default() })
}

/// The methods compared in the runtime/quality figures.
fn fig2_algos() -> Vec<TmfgAlgo> {
    vec![
        TmfgAlgo::Par(1),
        TmfgAlgo::Par(10),
        TmfgAlgo::Corr,
        TmfgAlgo::Heap,
        TmfgAlgo::Opt,
    ]
}

fn load(opts: &ExpOpts, name: &str) -> Dataset {
    registry::get_dataset(name, opts.scale, opts.seed)
        .unwrap_or_else(|| panic!("unknown dataset {name}"))
}

/// Similarity matrices are the paper's *input*; compute once per dataset.
fn similarity(ds: &Dataset) -> Matrix {
    pearson_correlation(&ds.data)
}

// ---------------------------------------------------------------------------
// Table 1
// ---------------------------------------------------------------------------
pub fn table1(opts: &ExpOpts) {
    println!("\n== Table 1: datasets (scale {}) ==", opts.scale);
    println!("{:<4} {:<28} {:>7} {:>6} {:>8}", "ID", "Name", "n", "L", "classes");
    let mut rows = Vec::new();
    for (i, name) in registry::table1_names().iter().enumerate() {
        let ds = load(opts, name);
        println!(
            "{:<4} {:<28} {:>7} {:>6} {:>8}",
            i + 1,
            ds.name,
            ds.n(),
            ds.len(),
            ds.n_classes
        );
        rows.push(vec![
            (i + 1).to_string(),
            ds.name.clone(),
            ds.n().to_string(),
            ds.len().to_string(),
            ds.n_classes.to_string(),
        ]);
    }
    write_csv(opts, "table1", "id,name,n,L,classes", &rows);
}

// ---------------------------------------------------------------------------
// Fig 2: parallel runtime of all methods per dataset
// ---------------------------------------------------------------------------
pub fn fig2(opts: &ExpOpts) {
    println!("\n== Fig 2: parallel runtime (s) of TMFG-DBHT methods ==");
    let names = opts.dataset_names(registry::table1_names());
    let algos = fig2_algos();
    print!("{:<28}", "dataset");
    for a in &algos {
        print!(" {:>14}", a.name());
    }
    println!();
    let mut rows = Vec::new();
    for name in &names {
        let ds = load(opts, name);
        let s = similarity(&ds);
        print!("{:<28}", format!("{}(n={})", ds.name, ds.n()));
        let mut row = vec![ds.name.clone(), ds.n().to_string()];
        for algo in &algos {
            let p = pipeline_for(*algo);
            let t = Timer::start();
            let out = p.run_similarity(&s, Some(&ds.labels), ds.n_classes);
            let secs = t.elapsed();
            let _ = out;
            print!(" {:>14.4}", secs);
            use std::io::Write as _;
            std::io::stdout().flush().ok();
            row.push(format!("{secs:.6}"));
        }
        println!();
        rows.push(row);
    }
    let header = format!(
        "dataset,n,{}",
        algos.iter().map(|a| a.name()).collect::<Vec<_>>().join(",")
    );
    write_csv(opts, "fig2_runtime", &header, &rows);
}

// ---------------------------------------------------------------------------
// Figs 3 & 4: self-relative speedup on the three largest datasets
// ---------------------------------------------------------------------------
fn scaling(opts: &ExpOpts, algo: TmfgAlgo, csv: &str) {
    println!(
        "\n== Self-relative speedup of {} on the 3 largest datasets ==",
        algo.name()
    );
    let names = opts.dataset_names(
        registry::largest3_names().iter().map(|s| s.to_string()).collect(),
    );
    let sweep = opts.thread_sweep();
    println!("{:<28} {:>8} {:>10} {:>9}", "dataset", "threads", "secs", "speedup");
    let mut rows = Vec::new();
    for name in &names {
        let ds = load(opts, name);
        let s = similarity(&ds);
        let mut base = None;
        for &t in &sweep {
            let secs = parlay::with_threads(t, || {
                let p = pipeline_for(algo);
                let timer = Timer::start();
                let _ = p.run_similarity(&s, Some(&ds.labels), ds.n_classes);
                timer.elapsed()
            });
            let b = *base.get_or_insert(secs);
            println!("{:<28} {:>8} {:>10.4} {:>9.2}", ds.name, t, secs, b / secs);
            rows.push(vec![
                ds.name.clone(),
                t.to_string(),
                format!("{secs:.6}"),
                format!("{:.3}", b / secs),
            ]);
        }
    }
    write_csv(opts, csv, "dataset,threads,secs,speedup", &rows);
}

pub fn fig3(opts: &ExpOpts) {
    scaling(opts, TmfgAlgo::Opt, "fig3_scaling_opt");
}

pub fn fig4(opts: &ExpOpts) {
    scaling(opts, TmfgAlgo::Par(10), "fig4_scaling_par10");
}

// ---------------------------------------------------------------------------
// Fig 5: stage breakdown on Crop (max threads and 1 thread)
// ---------------------------------------------------------------------------
pub fn fig5(opts: &ExpOpts) {
    let names = opts.dataset_names(vec!["Crop".to_string()]);
    let name = &names[0];
    let ds = load(opts, name);
    let s = similarity(&ds);
    let algos = fig2_algos();
    let mut rows = Vec::new();
    for threads in [parlay::num_threads(), 1] {
        println!(
            "\n== Fig 5: stage breakdown on {} (n={}) with {} thread(s) ==",
            ds.name,
            ds.n(),
            threads
        );
        println!(
            "{:<16} {:>12} {:>12} {:>12} {:>10} {:>10} {:>10}",
            "method", "init-faces", "sort", "add-verts", "apsp", "dbht", "total"
        );
        for algo in &algos {
            let out = parlay::with_threads(threads, || {
                pipeline_for(*algo).run_similarity(&s, Some(&ds.labels), ds.n_classes)
            });
            let g = |k: &str| out.breakdown.get(k).unwrap_or(0.0);
            println!(
                "{:<16} {:>12.4} {:>12.4} {:>12.4} {:>10.4} {:>10.4} {:>10.4}",
                algo.name(),
                g("tmfg:init-faces"),
                g("tmfg:sort"),
                g("tmfg:add-vertices"),
                g("apsp"),
                g("dbht"),
                out.breakdown.total()
            );
            rows.push(vec![
                algo.name(),
                threads.to_string(),
                format!("{:.6}", g("tmfg:init-faces")),
                format!("{:.6}", g("tmfg:sort")),
                format!("{:.6}", g("tmfg:add-vertices")),
                format!("{:.6}", g("apsp")),
                format!("{:.6}", g("dbht")),
                format!("{:.6}", out.breakdown.total()),
            ]);
        }
    }
    write_csv(
        opts,
        "fig5_breakdown",
        "method,threads,init_faces,sort,add_vertices,apsp,dbht,total",
        &rows,
    );
}

// ---------------------------------------------------------------------------
// Fig 6: ARI of every method per dataset
// ---------------------------------------------------------------------------
pub fn fig6(opts: &ExpOpts) {
    println!("\n== Fig 6: ARI scores ==");
    let names = opts.dataset_names(registry::table1_names());
    let mut algos = fig2_algos();
    algos.insert(2, TmfgAlgo::Par(200));
    print!("{:<28}", "dataset");
    for a in &algos {
        print!(" {:>14}", a.name());
    }
    println!();
    let mut rows = Vec::new();
    let mut sums = vec![0.0f64; algos.len()];
    for name in &names {
        let ds = load(opts, name);
        let s = similarity(&ds);
        print!("{:<28}", ds.name);
        let mut row = vec![ds.name.clone()];
        for (i, algo) in algos.iter().enumerate() {
            let out = pipeline_for(*algo).run_similarity(&s, Some(&ds.labels), ds.n_classes);
            let ari = out.ari.unwrap();
            sums[i] += ari;
            print!(" {:>14.3}", ari);
            use std::io::Write as _;
            std::io::stdout().flush().ok();
            row.push(format!("{ari:.4}"));
        }
        println!();
        rows.push(row);
    }
    print!("{:<28}", "AVERAGE");
    let mut avg_row = vec!["AVERAGE".to_string()];
    for s in &sums {
        let avg = s / names.len() as f64;
        print!(" {:>14.3}", avg);
        avg_row.push(format!("{avg:.4}"));
    }
    println!();
    rows.push(avg_row);
    let header = format!(
        "dataset,{}",
        algos.iter().map(|a| a.name()).collect::<Vec<_>>().join(",")
    );
    write_csv(opts, "fig6_ari", &header, &rows);
}

// ---------------------------------------------------------------------------
// Fig 7: percent edge-sum reduction vs PAR-TDBHT-1
// ---------------------------------------------------------------------------
pub fn fig7(opts: &ExpOpts) {
    println!("\n== Fig 7: % edge-sum reduction vs par-tdbht-1 (lower = better) ==");
    let names = opts.dataset_names(registry::table1_names());
    let algos = vec![TmfgAlgo::Par(10), TmfgAlgo::Par(200), TmfgAlgo::Corr, TmfgAlgo::Heap];
    print!("{:<28}", "dataset");
    for a in &algos {
        print!(" {:>14}", a.name());
    }
    println!();
    let mut rows = Vec::new();
    for name in &names {
        let ds = load(opts, name);
        let s = similarity(&ds);
        let base = pipeline_for(TmfgAlgo::Par(1))
            .run_similarity(&s, Some(&ds.labels), ds.n_classes)
            .edge_sum;
        print!("{:<28}", ds.name);
        let mut row = vec![ds.name.clone()];
        for algo in &algos {
            let es = pipeline_for(*algo)
                .run_similarity(&s, Some(&ds.labels), ds.n_classes)
                .edge_sum;
            let pct = crate::metrics::edge_sum_reduction_pct(base, es);
            print!(" {:>14.3}", pct);
            row.push(format!("{pct:.5}"));
        }
        println!();
        rows.push(row);
    }
    let header = format!(
        "dataset,{}",
        algos.iter().map(|a| a.name()).collect::<Vec<_>>().join(",")
    );
    write_csv(opts, "fig7_edgesum", &header, &rows);
}

// ---------------------------------------------------------------------------
// §5.1 extra: exact vs approximate APSP
// ---------------------------------------------------------------------------
pub fn apsp_speedup(opts: &ExpOpts) {
    println!("\n== §5.1: exact vs approximate APSP (OPT pipeline) ==");
    let names = opts.dataset_names(registry::table1_names());
    println!("{:<28} {:>10} {:>10} {:>9} {:>9} {:>9}", "dataset", "exact_s", "approx_s", "speedup", "ari_ex", "ari_ap");
    let mut rows = Vec::new();
    for name in &names {
        let ds = load(opts, name);
        let s = similarity(&ds);
        let run = |mode: ApspMode| {
            let mut c = PipelineConfig {
                algo: TmfgAlgo::Opt,
                use_xla: false,
                ..Default::default()
            };
            c.apsp = Some(mode);
            let out = Pipeline::new(c).run_similarity(&s, Some(&ds.labels), ds.n_classes);
            (out.breakdown.get("apsp").unwrap_or(0.0), out.ari.unwrap())
        };
        let (te, ae) = run(ApspMode::Exact);
        let (ta, aa) = run(ApspMode::Approx);
        println!(
            "{:<28} {:>10.4} {:>10.4} {:>9.2} {:>9.3} {:>9.3}",
            ds.name,
            te,
            ta,
            te / ta.max(1e-12),
            ae,
            aa
        );
        rows.push(vec![
            ds.name.clone(),
            format!("{te:.6}"),
            format!("{ta:.6}"),
            format!("{:.3}", te / ta.max(1e-12)),
            format!("{ae:.4}"),
            format!("{aa:.4}"),
        ]);
    }
    write_csv(opts, "apsp_speedup", "dataset,exact_s,approx_s,speedup,ari_exact,ari_approx", &rows);
}

/// Linkage ablation (DESIGN.md calls this out as a design choice).
pub fn ablation_linkage(opts: &ExpOpts) {
    println!("\n== Ablation: linkage function in DBHT (OPT pipeline) ==");
    let names = opts.dataset_names(vec!["CBF".into(), "ECG5000".into(), "ShapesAll".into()]);
    println!("{:<28} {:>10} {:>10} {:>10}", "dataset", "complete", "average", "single");
    let mut rows = Vec::new();
    for name in &names {
        let ds = load(opts, name);
        let s = similarity(&ds);
        let mut aris = Vec::new();
        for linkage in [Linkage::Complete, Linkage::Average, Linkage::Single] {
            let c = PipelineConfig {
                algo: TmfgAlgo::Opt,
                linkage,
                use_xla: false,
                ..Default::default()
            };
            let out = Pipeline::new(c).run_similarity(&s, Some(&ds.labels), ds.n_classes);
            aris.push(out.ari.unwrap());
        }
        println!(
            "{:<28} {:>10.3} {:>10.3} {:>10.3}",
            ds.name, aris[0], aris[1], aris[2]
        );
        rows.push(vec![
            ds.name.clone(),
            format!("{:.4}", aris[0]),
            format!("{:.4}", aris[1]),
            format!("{:.4}", aris[2]),
        ]);
    }
    write_csv(opts, "ablation_linkage", "dataset,complete,average,single", &rows);
}

/// Run every experiment (the full evaluation section).
pub fn all(opts: &ExpOpts) {
    table1(opts);
    fig2(opts);
    fig3(opts);
    fig4(opts);
    fig5(opts);
    fig6(opts);
    fig7(opts);
    apsp_speedup(opts);
    ablation_linkage(opts);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts() -> ExpOpts {
        ExpOpts {
            scale: 0.02,
            threads: vec![1, 2],
            datasets: vec!["CBF".into()],
            out_dir: format!("{}/tmfg_exp_test", std::env::temp_dir().display()),
            ..Default::default()
        }
    }

    #[test]
    fn fig2_smoke() {
        let o = tiny_opts();
        fig2(&o);
        assert!(std::path::Path::new(&format!("{}/fig2_runtime.csv", o.out_dir)).exists());
    }

    #[test]
    fn fig3_smoke() {
        let o = tiny_opts();
        fig3(&o);
        let text = std::fs::read_to_string(format!("{}/fig3_scaling_opt.csv", o.out_dir)).unwrap();
        assert!(text.lines().count() >= 3, "{text}");
    }

    #[test]
    fn fig6_and_7_smoke() {
        let o = tiny_opts();
        fig6(&o);
        fig7(&o);
        let t6 = std::fs::read_to_string(format!("{}/fig6_ari.csv", o.out_dir)).unwrap();
        assert!(t6.contains("AVERAGE"));
        let t7 = std::fs::read_to_string(format!("{}/fig7_edgesum.csv", o.out_dir)).unwrap();
        assert!(t7.contains("CBF"));
    }
}
