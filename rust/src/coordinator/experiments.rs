//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation section (§5) on the synthetic Table-1 mirror datasets.
//! Each experiment prints the same rows/series the paper reports and
//! writes a CSV under `results/`. All experiments drive the typed staged
//! API ([`crate::api::ClusterRequest`] / [`crate::api::Plan`]) directly
//! and are fallible — unknown datasets and IO failures surface as
//! [`TmfgError`] instead of panics. Human-readable tables are emitted
//! through the leveled [`log!`](crate::log) macro (info level, so
//! `--quiet`/`TMFG_LOG` filter them); the CSV artifacts are written
//! unconditionally.

use super::registry;
use crate::api::{ApspMode, ClusterOutput, ClusterRequest, TmfgAlgo, TmfgError};
use crate::data::corr::pearson_correlation;
use crate::data::matrix::Matrix;
use crate::data::synth::Dataset;
use crate::dbht::Linkage;
use crate::metrics::adjusted_rand_index;
use crate::parlay;
use crate::util::timer::Timer;
use std::io::Write;
use std::sync::Arc;

/// Shared experiment options.
#[derive(Debug, Clone)]
pub struct ExpOpts {
    /// n-scale applied to the Table-1 sizes (1.0 = paper sizes; the
    /// default keeps the full suite tractable on a laptop-class box).
    pub scale: f64,
    pub seed: u64,
    /// Thread counts for the scaling sweeps (empty = 1,2,4,...,max).
    pub threads: Vec<usize>,
    /// Restrict to these dataset names (empty = experiment default).
    pub datasets: Vec<String>,
    pub out_dir: String,
    /// Experiments that support it (currently `speedup-table`) also
    /// write a machine-readable JSON document here (`--json-out`).
    pub json_out: Option<String>,
}

impl Default for ExpOpts {
    fn default() -> Self {
        ExpOpts {
            scale: 0.1,
            seed: registry::DEFAULT_SEED,
            threads: Vec::new(),
            datasets: Vec::new(),
            out_dir: "results".into(),
            json_out: None,
        }
    }
}

impl ExpOpts {
    fn thread_sweep(&self) -> Vec<usize> {
        if !self.threads.is_empty() {
            return self.threads.clone();
        }
        let max = parlay::num_threads();
        let mut t = 1;
        let mut out = vec![];
        while t < max {
            out.push(t);
            t *= 2;
        }
        out.push(max);
        out
    }

    fn dataset_names(&self, default: Vec<String>) -> Vec<String> {
        if self.datasets.is_empty() {
            default
        } else {
            self.datasets.clone()
        }
    }
}

fn write_csv(
    opts: &ExpOpts,
    name: &str,
    header: &str,
    rows: &[Vec<String>],
) -> Result<(), TmfgError> {
    std::fs::create_dir_all(&opts.out_dir).ok();
    let path = format!("{}/{}.csv", opts.out_dir, name);
    let mut f = std::fs::File::create(&path)?;
    writeln!(f, "{header}")?;
    for r in rows {
        writeln!(f, "{}", r.join(","))?;
    }
    crate::log!(info, "wrote {path}");
    Ok(())
}

/// The methods compared in the runtime/quality figures.
fn fig2_algos() -> Vec<TmfgAlgo> {
    vec![
        TmfgAlgo::Par(1),
        TmfgAlgo::Par(10),
        TmfgAlgo::Corr,
        TmfgAlgo::Heap,
        TmfgAlgo::Opt,
    ]
}

fn load(opts: &ExpOpts, name: &str) -> Result<Dataset, TmfgError> {
    registry::get_dataset(name, opts.scale, opts.seed)
        .ok_or_else(|| TmfgError::DatasetNotFound(name.to_string()))
}

/// Similarity matrices are the paper's *input*; compute once per dataset
/// and share (`Arc`) across every algorithm's request — no per-run copy.
fn similarity(ds: &Dataset) -> Arc<Matrix> {
    Arc::new(pearson_correlation(&ds.data))
}

/// One full run from a precomputed similarity through the staged API.
fn run_algo(algo: TmfgAlgo, s: &Arc<Matrix>, ds: &Dataset) -> Result<ClusterOutput, TmfgError> {
    run_algo_linkage(algo, s, ds, Linkage::Complete)
}

fn run_algo_linkage(
    algo: TmfgAlgo,
    s: &Arc<Matrix>,
    ds: &Dataset,
    linkage: Linkage,
) -> Result<ClusterOutput, TmfgError> {
    ClusterRequest::similarity(s.clone())
        .algo(algo)
        .linkage(linkage)
        .labels(ds.labels.clone())
        .k(ds.n_classes.max(1))
        .run()
}

/// Like [`run_algo`], but times only the pipeline stages: request
/// validation happens while building the plan, *before* the stopwatch
/// starts, so the runtime/scaling figures measure the same work the
/// paper's do.
fn run_algo_timed(
    algo: TmfgAlgo,
    s: &Arc<Matrix>,
    ds: &Dataset,
) -> Result<(ClusterOutput, f64), TmfgError> {
    let plan = ClusterRequest::similarity(s.clone())
        .algo(algo)
        .labels(ds.labels.clone())
        .k(ds.n_classes.max(1))
        .build()?;
    let t = Timer::start();
    let out = plan.finish()?;
    Ok((out, t.elapsed()))
}

// ---------------------------------------------------------------------------
// Table 1
// ---------------------------------------------------------------------------
pub fn table1(opts: &ExpOpts) -> Result<(), TmfgError> {
    crate::log!(info, "\n== Table 1: datasets (scale {}) ==", opts.scale);
    crate::log!(info, "{:<4} {:<28} {:>7} {:>6} {:>8}", "ID", "Name", "n", "L", "classes");
    let mut rows = Vec::new();
    for (i, name) in registry::table1_names().iter().enumerate() {
        let ds = load(opts, name)?;
        crate::log!(
            info,
            "{:<4} {:<28} {:>7} {:>6} {:>8}",
            i + 1,
            ds.name,
            ds.n(),
            ds.len(),
            ds.n_classes
        );
        rows.push(vec![
            (i + 1).to_string(),
            ds.name.clone(),
            ds.n().to_string(),
            ds.len().to_string(),
            ds.n_classes.to_string(),
        ]);
    }
    write_csv(opts, "table1", "id,name,n,L,classes", &rows)
}

// ---------------------------------------------------------------------------
// Fig 2: parallel runtime of all methods per dataset
// ---------------------------------------------------------------------------
pub fn fig2(opts: &ExpOpts) -> Result<(), TmfgError> {
    crate::log!(info, "\n== Fig 2: parallel runtime (s) of TMFG-DBHT methods ==");
    let names = opts.dataset_names(registry::table1_names());
    let algos = fig2_algos();
    let mut head = format!("{:<28}", "dataset");
    for a in &algos {
        head.push_str(&format!(" {:>14}", a.name()));
    }
    crate::log!(info, "{head}");
    let mut rows = Vec::new();
    for name in &names {
        let ds = load(opts, name)?;
        let s = similarity(&ds);
        let mut line = format!("{:<28}", format!("{}(n={})", ds.name, ds.n()));
        let mut row = vec![ds.name.clone(), ds.n().to_string()];
        for algo in &algos {
            let (_out, secs) = run_algo_timed(*algo, &s, &ds)?;
            line.push_str(&format!(" {secs:>14.4}"));
            row.push(format!("{secs:.6}"));
        }
        crate::log!(info, "{line}");
        rows.push(row);
    }
    let header = format!(
        "dataset,n,{}",
        algos.iter().map(|a| a.name()).collect::<Vec<_>>().join(",")
    );
    write_csv(opts, "fig2_runtime", &header, &rows)
}

// ---------------------------------------------------------------------------
// Figs 3 & 4: self-relative speedup on the three largest datasets
// ---------------------------------------------------------------------------
fn scaling(opts: &ExpOpts, algo: TmfgAlgo, csv: &str) -> Result<(), TmfgError> {
    crate::log!(
        info,
        "\n== Self-relative speedup of {} on the 3 largest datasets ==",
        algo.name()
    );
    let names = opts.dataset_names(
        registry::largest3_names().iter().map(|s| s.to_string()).collect(),
    );
    let sweep = opts.thread_sweep();
    crate::log!(info, "{:<28} {:>8} {:>10} {:>9}", "dataset", "threads", "secs", "speedup");
    let mut rows = Vec::new();
    for name in &names {
        let ds = load(opts, name)?;
        let s = similarity(&ds);
        let mut base = None;
        for &t in &sweep {
            let secs = parlay::with_threads(t, || -> Result<f64, TmfgError> {
                run_algo_timed(algo, &s, &ds).map(|(_, secs)| secs)
            })?;
            let b = *base.get_or_insert(secs);
            crate::log!(info, "{:<28} {:>8} {:>10.4} {:>9.2}", ds.name, t, secs, b / secs);
            rows.push(vec![
                ds.name.clone(),
                t.to_string(),
                format!("{secs:.6}"),
                format!("{:.3}", b / secs),
            ]);
        }
    }
    write_csv(opts, csv, "dataset,threads,secs,speedup", &rows)
}

pub fn fig3(opts: &ExpOpts) -> Result<(), TmfgError> {
    scaling(opts, TmfgAlgo::Opt, "fig3_scaling_opt")
}

pub fn fig4(opts: &ExpOpts) -> Result<(), TmfgError> {
    scaling(opts, TmfgAlgo::Par(10), "fig4_scaling_par10")
}

// ---------------------------------------------------------------------------
// Fig 5: stage breakdown on Crop (max threads and 1 thread)
// ---------------------------------------------------------------------------
pub fn fig5(opts: &ExpOpts) -> Result<(), TmfgError> {
    let names = opts.dataset_names(vec!["Crop".to_string()]);
    let name = &names[0];
    let ds = load(opts, name)?;
    let s = similarity(&ds);
    let algos = fig2_algos();
    let mut rows = Vec::new();
    for threads in [parlay::num_threads(), 1] {
        crate::log!(
            info,
            "\n== Fig 5: stage breakdown on {} (n={}) with {} thread(s) ==",
            ds.name,
            ds.n(),
            threads
        );
        crate::log!(
            info,
            "{:<16} {:>12} {:>12} {:>12} {:>10} {:>10} {:>10}",
            "method",
            "init-faces",
            "sort",
            "add-verts",
            "apsp",
            "dbht",
            "total"
        );
        for algo in &algos {
            let out =
                parlay::with_threads(threads, || run_algo(*algo, &s, &ds))?;
            let g = |k: &str| out.breakdown.get(k).unwrap_or(0.0);
            crate::log!(
                info,
                "{:<16} {:>12.4} {:>12.4} {:>12.4} {:>10.4} {:>10.4} {:>10.4}",
                algo.name(),
                g("tmfg:init-faces"),
                g("tmfg:sort"),
                g("tmfg:add-vertices"),
                g("apsp"),
                g("dbht"),
                out.breakdown.total()
            );
            rows.push(vec![
                algo.name(),
                threads.to_string(),
                format!("{:.6}", g("tmfg:init-faces")),
                format!("{:.6}", g("tmfg:sort")),
                format!("{:.6}", g("tmfg:add-vertices")),
                format!("{:.6}", g("apsp")),
                format!("{:.6}", g("dbht")),
                format!("{:.6}", out.breakdown.total()),
            ]);
        }
    }
    write_csv(
        opts,
        "fig5_breakdown",
        "method,threads,init_faces,sort,add_vertices,apsp,dbht,total",
        &rows,
    )
}

// ---------------------------------------------------------------------------
// Fig 6: ARI of every method per dataset
// ---------------------------------------------------------------------------
pub fn fig6(opts: &ExpOpts) -> Result<(), TmfgError> {
    crate::log!(info, "\n== Fig 6: ARI scores ==");
    let names = opts.dataset_names(registry::table1_names());
    let mut algos = fig2_algos();
    algos.insert(2, TmfgAlgo::Par(200));
    let mut head = format!("{:<28}", "dataset");
    for a in &algos {
        head.push_str(&format!(" {:>14}", a.name()));
    }
    crate::log!(info, "{head}");
    let mut rows = Vec::new();
    let mut sums = vec![0.0f64; algos.len()];
    for name in &names {
        let ds = load(opts, name)?;
        let s = similarity(&ds);
        let mut line = format!("{:<28}", ds.name);
        let mut row = vec![ds.name.clone()];
        for (i, algo) in algos.iter().enumerate() {
            let out = run_algo(*algo, &s, &ds)?;
            let ari = out.ari.unwrap_or(f64::NAN);
            sums[i] += ari;
            line.push_str(&format!(" {ari:>14.3}"));
            row.push(format!("{ari:.4}"));
        }
        crate::log!(info, "{line}");
        rows.push(row);
    }
    let mut avg_line = format!("{:<28}", "AVERAGE");
    let mut avg_row = vec!["AVERAGE".to_string()];
    for s in &sums {
        let avg = s / names.len() as f64;
        avg_line.push_str(&format!(" {avg:>14.3}"));
        avg_row.push(format!("{avg:.4}"));
    }
    crate::log!(info, "{avg_line}");
    rows.push(avg_row);
    let header = format!(
        "dataset,{}",
        algos.iter().map(|a| a.name()).collect::<Vec<_>>().join(",")
    );
    write_csv(opts, "fig6_ari", &header, &rows)
}

// ---------------------------------------------------------------------------
// Fig 7: percent edge-sum reduction vs PAR-TDBHT-1
// ---------------------------------------------------------------------------
pub fn fig7(opts: &ExpOpts) -> Result<(), TmfgError> {
    crate::log!(info, "\n== Fig 7: % edge-sum reduction vs par-tdbht-1 (lower = better) ==");
    let names = opts.dataset_names(registry::table1_names());
    let algos = vec![TmfgAlgo::Par(10), TmfgAlgo::Par(200), TmfgAlgo::Corr, TmfgAlgo::Heap];
    let mut head = format!("{:<28}", "dataset");
    for a in &algos {
        head.push_str(&format!(" {:>14}", a.name()));
    }
    crate::log!(info, "{head}");
    let mut rows = Vec::new();
    for name in &names {
        let ds = load(opts, name)?;
        let s = similarity(&ds);
        let base = run_algo(TmfgAlgo::Par(1), &s, &ds)?.edge_sum;
        let mut line = format!("{:<28}", ds.name);
        let mut row = vec![ds.name.clone()];
        for algo in &algos {
            let es = run_algo(*algo, &s, &ds)?.edge_sum;
            let pct = crate::metrics::edge_sum_reduction_pct(base, es);
            line.push_str(&format!(" {pct:>14.3}"));
            row.push(format!("{pct:.5}"));
        }
        crate::log!(info, "{line}");
        rows.push(row);
    }
    let header = format!(
        "dataset,{}",
        algos.iter().map(|a| a.name()).collect::<Vec<_>>().join(",")
    );
    write_csv(opts, "fig7_edgesum", &header, &rows)
}

// ---------------------------------------------------------------------------
// §5.1 extra: exact vs approximate APSP
// ---------------------------------------------------------------------------
/// Uses the staged [`crate::api::Plan`] executor: each dataset's TMFG is
/// constructed once and reused across both APSP modes via
/// [`crate::api::Plan::set_apsp_mode`] — exactly the stage reuse the
/// typed API exists for.
pub fn apsp_speedup(opts: &ExpOpts) -> Result<(), TmfgError> {
    crate::log!(info, "\n== §5.1: exact vs approximate APSP (OPT pipeline, shared TMFG) ==");
    let names = opts.dataset_names(registry::table1_names());
    crate::log!(
        info,
        "{:<28} {:>10} {:>10} {:>9} {:>9} {:>9}",
        "dataset",
        "exact_s",
        "approx_s",
        "speedup",
        "ari_ex",
        "ari_ap"
    );
    let mut rows = Vec::new();
    for name in &names {
        let ds = load(opts, name)?;
        let s = similarity(&ds);
        let k = ds.n_classes.max(1);
        let mut plan = ClusterRequest::similarity(s)
            .algo(TmfgAlgo::Opt)
            .k(k)
            .build()?;
        plan.run_tmfg()?; // built once, reused under both APSP modes
        let mut secs = [0.0f64; 2];
        let mut aris = [0.0f64; 2];
        for (i, mode) in [ApspMode::Exact, ApspMode::Approx].into_iter().enumerate() {
            plan.set_apsp_mode(mode);
            let t = Timer::start();
            plan.run_apsp()?;
            secs[i] = t.elapsed();
            let pred = plan.run_cut(k)?.to_vec();
            aris[i] = adjusted_rand_index(&ds.labels, &pred);
        }
        let (te, ta) = (secs[0], secs[1]);
        crate::log!(
            info,
            "{:<28} {:>10.4} {:>10.4} {:>9.2} {:>9.3} {:>9.3}",
            ds.name,
            te,
            ta,
            te / ta.max(1e-12),
            aris[0],
            aris[1]
        );
        rows.push(vec![
            ds.name.clone(),
            format!("{te:.6}"),
            format!("{ta:.6}"),
            format!("{:.3}", te / ta.max(1e-12)),
            format!("{:.4}", aris[0]),
            format!("{:.4}", aris[1]),
        ]);
    }
    write_csv(
        opts,
        "apsp_speedup",
        "dataset,exact_s,approx_s,speedup,ari_exact,ari_approx",
        &rows,
    )
}

// ---------------------------------------------------------------------------
// Headline speedup table: OPT construction vs the reference baselines
// ---------------------------------------------------------------------------
/// The paper's headline table: TMFG construction time of the optimized
/// configuration (heap + radix sort + wide scan — what `TmfgAlgo::Opt`
/// runs) against the Fast-TMFG-shaped reference `orig_tmfg` (prefix 10,
/// the original algorithm's parallel configuration) and the plain
/// `heap_tmfg` baseline, across the thread sweep on the three largest
/// datasets. Construction-only from a precomputed similarity matrix (the
/// paper's input convention). Always writes `speedup_table.csv`; when
/// `opts.json_out` is set, also writes a JSON document with the same
/// rows plus a min/max headline over the OPT-vs-orig speedups.
pub fn speedup_table(opts: &ExpOpts) -> Result<(), TmfgError> {
    use crate::tmfg::{heap_tmfg, orig_tmfg, ScanKind, SortKind, TmfgConfig};
    use crate::util::json::Json;
    crate::log!(info, "\n== Speedup table: OPT vs orig/heap TMFG construction ==");
    let names = opts.dataset_names(
        registry::largest3_names().iter().map(|s| s.to_string()).collect(),
    );
    let sweep = opts.thread_sweep();
    crate::log!(
        info,
        "{:<28} {:>7} {:>10} {:>10} {:>10} {:>9} {:>9}",
        "dataset",
        "threads",
        "orig_s",
        "heap_s",
        "opt_s",
        "vs_orig",
        "vs_heap"
    );
    let opt_cfg = TmfgConfig { prefix: 1, scan: ScanKind::Wide, sort: SortKind::Radix };
    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut json_rows: Vec<Json> = Vec::new();
    let mut vs_orig_all: Vec<f64> = Vec::new();
    for name in &names {
        let ds = load(opts, name)?;
        let s = similarity(&ds);
        for &t in &sweep {
            let (orig_s, heap_s, opt_s) =
                parlay::with_threads(t, || -> Result<(f64, f64, f64), TmfgError> {
                    let timer = Timer::start();
                    orig_tmfg(&s, 10)?;
                    let orig_s = timer.elapsed();
                    let timer = Timer::start();
                    heap_tmfg(&s, &TmfgConfig::default())?;
                    let heap_s = timer.elapsed();
                    let timer = Timer::start();
                    heap_tmfg(&s, &opt_cfg)?;
                    Ok((orig_s, heap_s, timer.elapsed()))
                })?;
            let vs_orig = orig_s / opt_s.max(1e-12);
            let vs_heap = heap_s / opt_s.max(1e-12);
            vs_orig_all.push(vs_orig);
            crate::log!(
                info,
                "{:<28} {:>7} {:>10.4} {:>10.4} {:>10.4} {:>9.2} {:>9.2}",
                ds.name,
                t,
                orig_s,
                heap_s,
                opt_s,
                vs_orig,
                vs_heap
            );
            rows.push(vec![
                ds.name.clone(),
                ds.n().to_string(),
                t.to_string(),
                format!("{orig_s:.6}"),
                format!("{heap_s:.6}"),
                format!("{opt_s:.6}"),
                format!("{vs_orig:.3}"),
                format!("{vs_heap:.3}"),
            ]);
            json_rows.push(Json::obj(vec![
                ("dataset", Json::str(&ds.name)),
                ("n", Json::Num(ds.n() as f64)),
                ("threads", Json::Num(t as f64)),
                ("orig_s", Json::Num(orig_s)),
                ("heap_s", Json::Num(heap_s)),
                ("opt_s", Json::Num(opt_s)),
                ("speedup_vs_orig", Json::Num(vs_orig)),
                ("speedup_vs_heap", Json::Num(vs_heap)),
            ]));
        }
    }
    write_csv(
        opts,
        "speedup_table",
        "dataset,n,threads,orig_s,heap_s,opt_s,speedup_vs_orig,speedup_vs_heap",
        &rows,
    )?;
    if let Some(path) = &opts.json_out {
        let (lo, hi) = vs_orig_all.iter().fold((f64::INFINITY, 0.0f64), |(lo, hi), &v| {
            (lo.min(v), hi.max(v))
        });
        let doc = Json::obj(vec![
            ("experiment", Json::str("speedup-table")),
            ("scale", Json::Num(opts.scale)),
            (
                "headline",
                Json::obj(vec![
                    ("min_speedup_vs_orig", Json::Num(lo)),
                    ("max_speedup_vs_orig", Json::Num(hi)),
                ]),
            ),
            ("rows", Json::Arr(json_rows)),
        ]);
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir).ok();
        }
        std::fs::write(path, doc.to_string())?;
        crate::log!(info, "wrote {path}");
    }
    Ok(())
}

/// Linkage ablation (DESIGN.md calls this out as a design choice).
pub fn ablation_linkage(opts: &ExpOpts) -> Result<(), TmfgError> {
    crate::log!(info, "\n== Ablation: linkage function in DBHT (OPT pipeline) ==");
    let names = opts.dataset_names(vec!["CBF".into(), "ECG5000".into(), "ShapesAll".into()]);
    crate::log!(info, "{:<28} {:>10} {:>10} {:>10}", "dataset", "complete", "average", "single");
    let mut rows = Vec::new();
    for name in &names {
        let ds = load(opts, name)?;
        let s = similarity(&ds);
        let mut aris = Vec::new();
        for linkage in [Linkage::Complete, Linkage::Average, Linkage::Single] {
            let out = run_algo_linkage(TmfgAlgo::Opt, &s, &ds, linkage)?;
            aris.push(out.ari.unwrap_or(f64::NAN));
        }
        crate::log!(
            info,
            "{:<28} {:>10.3} {:>10.3} {:>10.3}",
            ds.name,
            aris[0],
            aris[1],
            aris[2]
        );
        rows.push(vec![
            ds.name.clone(),
            format!("{:.4}", aris[0]),
            format!("{:.4}", aris[1]),
            format!("{:.4}", aris[2]),
        ]);
    }
    write_csv(opts, "ablation_linkage", "dataset,complete,average,single", &rows)
}

/// Run every experiment (the full evaluation section).
pub fn all(opts: &ExpOpts) -> Result<(), TmfgError> {
    table1(opts)?;
    fig2(opts)?;
    fig3(opts)?;
    fig4(opts)?;
    fig5(opts)?;
    fig6(opts)?;
    fig7(opts)?;
    apsp_speedup(opts)?;
    speedup_table(opts)?;
    ablation_linkage(opts)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts() -> ExpOpts {
        ExpOpts {
            scale: 0.02,
            threads: vec![1, 2],
            datasets: vec!["CBF".into()],
            out_dir: format!("{}/tmfg_exp_test", std::env::temp_dir().display()),
            ..Default::default()
        }
    }

    #[test]
    fn fig2_smoke() {
        let o = tiny_opts();
        fig2(&o).unwrap();
        assert!(std::path::Path::new(&format!("{}/fig2_runtime.csv", o.out_dir)).exists());
    }

    #[test]
    fn fig3_smoke() {
        let o = tiny_opts();
        fig3(&o).unwrap();
        let text = std::fs::read_to_string(format!("{}/fig3_scaling_opt.csv", o.out_dir)).unwrap();
        assert!(text.lines().count() >= 3, "{text}");
    }

    #[test]
    fn fig6_and_7_smoke() {
        let o = tiny_opts();
        fig6(&o).unwrap();
        fig7(&o).unwrap();
        let t6 = std::fs::read_to_string(format!("{}/fig6_ari.csv", o.out_dir)).unwrap();
        assert!(t6.contains("AVERAGE"));
        let t7 = std::fs::read_to_string(format!("{}/fig7_edgesum.csv", o.out_dir)).unwrap();
        assert!(t7.contains("CBF"));
    }

    #[test]
    fn apsp_speedup_shares_one_tmfg() {
        let o = tiny_opts();
        apsp_speedup(&o).unwrap();
        let t = std::fs::read_to_string(format!("{}/apsp_speedup.csv", o.out_dir)).unwrap();
        assert!(t.contains("CBF"));
    }

    #[test]
    fn speedup_table_smoke() {
        let mut o = tiny_opts();
        let json_path = format!("{}/speedup_table_test.json", o.out_dir);
        o.json_out = Some(json_path.clone());
        speedup_table(&o).unwrap();
        let csv = std::fs::read_to_string(format!("{}/speedup_table.csv", o.out_dir)).unwrap();
        assert!(csv.lines().count() >= 3, "{csv}"); // header + 2 thread counts
        assert!(csv.starts_with("dataset,n,threads,orig_s,heap_s,opt_s"));
        let doc = crate::util::json::Json::parse(
            &std::fs::read_to_string(&json_path).unwrap(),
        )
        .unwrap();
        assert_eq!(doc.get("experiment").as_str(), Some("speedup-table"));
        let rows = doc.get("rows").as_arr().unwrap();
        assert_eq!(rows.len(), 2); // 1 dataset × threads [1, 2]
        for r in rows {
            assert!(r.get("opt_s").as_f64().unwrap() > 0.0);
            assert!(r.get("speedup_vs_orig").as_f64().unwrap() > 0.0);
        }
        assert!(doc.get("headline").get("max_speedup_vs_orig").as_f64().unwrap() > 0.0);
    }

    #[test]
    fn unknown_dataset_is_err() {
        let mut o = tiny_opts();
        o.datasets = vec!["NoSuchDataset".into()];
        let e = fig2(&o).unwrap_err();
        assert_eq!(e.code(), "dataset_not_found");
    }
}
