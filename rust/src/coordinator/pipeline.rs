//! The TMFG-DBHT pipeline — now a thin compatibility facade over the
//! typed staged API in [`crate::api`].
//!
//! `Pipeline` predates the [`crate::api::ClusterRequest`] builder and is
//! kept for callers that configure once and run many datasets through a
//! shared similarity engine. Internally every run builds an
//! [`crate::api::Plan`] (the paper's Fig. 5 stage chain: finding initial
//! faces, initial sorting of correlations, TMFG vertex adding, APSP,
//! DBHT — plus our explicit similarity stage) and executes it to
//! completion; all methods are fallible and return [`TmfgError`] instead
//! of panicking. New code should prefer `ClusterRequest` directly.

pub use crate::api::plan::{build_tmfg_for, ApspMode, ClusterOutput, TmfgAlgo};
use crate::api::{ClusterRequest, TmfgError};
use crate::apsp::HubConfig;
use crate::data::matrix::Matrix;
use crate::data::synth::Dataset;
use crate::dbht::Linkage;
use crate::runtime::engine::CorrEngine;
use crate::stream::session::{StreamConfig, StreamSession, TickOutput};
use std::path::PathBuf;
use std::sync::Arc;

/// What a pipeline run returns — the owned output of a completed
/// [`crate::api::Plan`].
pub type PipelineOutput = ClusterOutput;

#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub algo: TmfgAlgo,
    /// None = algorithm default (Opt → approx, everything else → exact).
    pub apsp: Option<ApspMode>,
    pub linkage: Linkage,
    pub hub: HubConfig,
    /// Artifacts directory for the XLA similarity engine.
    pub artifacts_dir: PathBuf,
    /// false = always use the native Rust correlation path.
    pub use_xla: bool,
    /// Validate TMFG structural invariants after construction.
    pub check_invariants: bool,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            algo: TmfgAlgo::Opt,
            apsp: None,
            linkage: Linkage::Complete,
            hub: HubConfig::default(),
            artifacts_dir: PathBuf::from("artifacts"),
            use_xla: true,
            check_invariants: false,
        }
    }
}

pub struct Pipeline {
    pub config: PipelineConfig,
    /// Shared across runs so compiled XLA executables are reused.
    engine: Arc<CorrEngine>,
}

impl Pipeline {
    pub fn new(config: PipelineConfig) -> Pipeline {
        let engine = if config.use_xla {
            CorrEngine::auto(&config.artifacts_dir)
        } else {
            CorrEngine::native_only()
        };
        Pipeline { config, engine: Arc::new(engine) }
    }

    /// The APSP mode runs will use (config override or algorithm default).
    pub fn effective_apsp(&self) -> ApspMode {
        self.config.apsp.unwrap_or_else(|| self.config.algo.default_apsp())
    }

    /// Apply this pipeline's configuration to a request.
    fn configure(&self, req: ClusterRequest) -> ClusterRequest {
        let mut req = req
            .algo(self.config.algo)
            .linkage(self.config.linkage)
            .hub(self.config.hub.clone())
            .check_invariants(self.config.check_invariants)
            .engine(self.engine.clone());
        if let Some(mode) = self.config.apsp {
            req = req.apsp(mode);
        }
        req
    }

    /// Run from a raw dataset (computes the similarity matrix first).
    /// Cuts at the dataset's class count and reports ARI vs its labels.
    /// Copies the panel and labels into the request; throughput-sensitive
    /// callers should use [`ClusterRequest::panel`] with a shared
    /// `Arc<Matrix>` instead.
    pub fn run_dataset(&self, ds: &Dataset) -> Result<PipelineOutput, TmfgError> {
        self.configure(ClusterRequest::panel(ds.data.clone()))
            .labels(ds.labels.clone())
            .k(ds.n_classes.max(1))
            .run()
    }

    /// Run from a precomputed similarity matrix (the paper's setting).
    /// Copies the matrix into the request; throughput-sensitive callers
    /// should use [`ClusterRequest::similarity`] with a shared
    /// `Arc<Matrix>` instead.
    pub fn run_similarity(
        &self,
        s: &Matrix,
        labels: Option<&[usize]>,
        n_classes: usize,
    ) -> Result<PipelineOutput, TmfgError> {
        let mut req = self.configure(ClusterRequest::similarity(s.clone()));
        if let Some(truth) = labels {
            req = req.labels(truth.to_vec()).k(n_classes.max(1));
        }
        req.run()
    }

    /// Stream configuration inheriting this pipeline's algorithm,
    /// linkage, APSP mode, and hub parameters.
    pub fn stream_config(&self, n: usize, window: usize, k: usize) -> StreamConfig {
        let mut cfg = StreamConfig::new(n, window, k);
        cfg.algo = self.config.algo;
        cfg.linkage = self.config.linkage;
        cfg.apsp = self.config.apsp;
        cfg.hub = self.config.hub.clone();
        cfg
    }

    /// Streaming entry point: replay an n×T panel column-by-column
    /// through a [`StreamSession`] — each tick feeds one new observation
    /// per series, the window correlation updates in O(n²), and the
    /// session refreshes or rebuilds the topology per its drift policy.
    /// Returns the session (for stats/history/topology) and the per-tick
    /// outputs.
    pub fn run_stream(
        &self,
        panel: &Matrix,
        cfg: StreamConfig,
    ) -> Result<(StreamSession, Vec<TickOutput>), TmfgError> {
        let mut session = StreamSession::new(cfg)?;
        let mut outputs = Vec::with_capacity(panel.cols);
        let mut sample = vec![0.0f32; panel.rows];
        for t in 0..panel.cols {
            for (i, v) in sample.iter_mut().enumerate() {
                *v = panel.at(i, t);
            }
            outputs.push(session.tick(&sample)?);
        }
        Ok((session, outputs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    fn cfg(algo: TmfgAlgo) -> PipelineConfig {
        PipelineConfig { algo, use_xla: false, check_invariants: true, ..Default::default() }
    }

    #[test]
    fn algo_parse_roundtrip() {
        for a in [TmfgAlgo::Par(1), TmfgAlgo::Par(10), TmfgAlgo::Par(200), TmfgAlgo::Corr, TmfgAlgo::Heap, TmfgAlgo::Opt] {
            assert_eq!(TmfgAlgo::parse(&a.name()), Some(a));
        }
        assert_eq!(TmfgAlgo::parse("par10"), Some(TmfgAlgo::Par(10)));
        assert_eq!(TmfgAlgo::parse("bogus"), None);
    }

    #[test]
    fn all_algorithms_run_end_to_end() {
        let ds = SynthSpec::new("t", 80, 48, 3).generate(1);
        for algo in [TmfgAlgo::Par(1), TmfgAlgo::Par(10), TmfgAlgo::Corr, TmfgAlgo::Heap, TmfgAlgo::Opt] {
            let p = Pipeline::new(cfg(algo));
            let out = p.run_dataset(&ds).unwrap();
            assert!(out.dbht.dendrogram.is_complete(), "{algo:?}");
            let ari = out.ari.unwrap();
            assert!((-1.0..=1.0).contains(&ari), "{algo:?}: {ari}");
            assert!(out.edge_sum.is_finite());
            assert!(out.breakdown.total() > 0.0);
            assert!(out.breakdown.get("apsp").is_some());
            assert!(out.breakdown.get("dbht").is_some());
            assert_eq!(out.labels.as_ref().unwrap().len(), 80);
        }
    }

    #[test]
    fn default_apsp_mode_per_algo() {
        let p_opt = Pipeline::new(cfg(TmfgAlgo::Opt));
        assert_eq!(p_opt.effective_apsp(), ApspMode::Approx);
        let p_heap = Pipeline::new(cfg(TmfgAlgo::Heap));
        assert_eq!(p_heap.effective_apsp(), ApspMode::Exact);
        let mut c = cfg(TmfgAlgo::Opt);
        c.apsp = Some(ApspMode::Exact);
        assert_eq!(Pipeline::new(c).effective_apsp(), ApspMode::Exact);
    }

    #[test]
    fn reports_apsp_mode_in_output() {
        let ds = SynthSpec::new("t", 40, 32, 2).generate(7);
        let out = Pipeline::new(cfg(TmfgAlgo::Opt)).run_dataset(&ds).unwrap();
        assert_eq!(out.apsp_mode, ApspMode::Approx);
        let out = Pipeline::new(cfg(TmfgAlgo::Heap)).run_dataset(&ds).unwrap();
        assert_eq!(out.apsp_mode, ApspMode::Exact);
    }

    #[test]
    fn run_stream_replays_whole_panel() {
        let ds = SynthSpec::new("t", 30, 48, 3).generate(5);
        let p = Pipeline::new(cfg(TmfgAlgo::Heap));
        let scfg = p.stream_config(ds.n(), 24, 3);
        let warmup = scfg.warmup;
        let (session, outs) = p.run_stream(&ds.data, scfg).unwrap();
        assert_eq!(outs.len(), 48);
        let warming = outs.iter().filter(|o| o.labels.is_none()).count();
        assert_eq!(warming, warmup - 1);
        let st = session.stats();
        assert_eq!(st.ticks, 48);
        assert_eq!(st.emissions, 48 - (warmup as u64 - 1));
        assert_eq!(st.rebuilds + st.refreshes, st.emissions);
        assert_eq!(session.generation(), st.emissions);
        // stream config inherits the pipeline's algorithm
        assert_eq!(session.config.algo, TmfgAlgo::Heap);
    }

    #[test]
    fn run_stream_rejects_bad_config() {
        let ds = SynthSpec::new("t", 3, 16, 1).generate(6);
        let p = Pipeline::new(cfg(TmfgAlgo::Heap));
        let scfg = p.stream_config(3, 8, 1); // n < 4
        assert!(p.run_stream(&ds.data, scfg).is_err());
    }

    #[test]
    fn unlabeled_run() {
        let ds = SynthSpec::new("t", 40, 32, 2).generate(2);
        let p = Pipeline::new(cfg(TmfgAlgo::Heap));
        let out = p
            .run_similarity(
                &crate::data::corr::pearson_correlation(&ds.data),
                None,
                0,
            )
            .unwrap();
        assert!(out.ari.is_none());
        assert!(out.labels.is_none());
    }
}
