//! The TMFG-DBHT pipeline with stage timing (the paper's Fig. 5 stages:
//! finding initial faces, initial sorting of correlations, TMFG vertex
//! adding, APSP, DBHT — plus our explicit similarity stage, which the
//! paper assumes precomputed).

use crate::apsp::{apsp_exact, apsp_hub, CsrGraph, HubConfig};
use crate::data::matrix::Matrix;
use crate::data::synth::Dataset;
use crate::dbht::hierarchy::{dbht_dendrogram, DbhtResult};
use crate::dbht::Linkage;
use crate::metrics::adjusted_rand_index;
use crate::runtime::engine::{CorrEngine, CorrPath};
use crate::stream::session::{StreamConfig, StreamSession, TickOutput};
use crate::tmfg::{corr_tmfg, heap_tmfg, orig_tmfg, ScanKind, SortKind, TmfgConfig, TmfgResult};
use crate::util::timer::{Breakdown, Timer};
use std::path::PathBuf;

/// Which TMFG construction algorithm to run — mirrors the paper's
/// implementation list (§5 "Implementations").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TmfgAlgo {
    /// PAR-TDBHT-P (Yu & Shun) with the given prefix size.
    Par(usize),
    /// CORR-TDBHT (Alg. 1), prefix 1.
    Corr,
    /// HEAP-TDBHT (Alg. 2).
    Heap,
    /// OPT-TDBHT: HEAP + vectorized scan + radix sort + approximate APSP.
    Opt,
}

impl TmfgAlgo {
    pub fn name(&self) -> String {
        match self {
            TmfgAlgo::Par(p) => format!("par-tdbht-{p}"),
            TmfgAlgo::Corr => "corr-tdbht".into(),
            TmfgAlgo::Heap => "heap-tdbht".into(),
            TmfgAlgo::Opt => "opt-tdbht".into(),
        }
    }

    pub fn parse(s: &str) -> Option<TmfgAlgo> {
        match s.to_ascii_lowercase().as_str() {
            "corr" | "corr-tdbht" => Some(TmfgAlgo::Corr),
            "heap" | "heap-tdbht" => Some(TmfgAlgo::Heap),
            "opt" | "opt-tdbht" => Some(TmfgAlgo::Opt),
            other => {
                let p = other
                    .strip_prefix("par-tdbht-")
                    .or_else(|| other.strip_prefix("par"))?;
                p.parse().ok().map(TmfgAlgo::Par)
            }
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ApspMode {
    Exact,
    Approx,
}

#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub algo: TmfgAlgo,
    /// None = algorithm default (Opt → approx, everything else → exact).
    pub apsp: Option<ApspMode>,
    pub linkage: Linkage,
    pub hub: HubConfig,
    /// Artifacts directory for the XLA similarity engine.
    pub artifacts_dir: PathBuf,
    /// false = always use the native Rust correlation path.
    pub use_xla: bool,
    /// Validate TMFG structural invariants after construction.
    pub check_invariants: bool,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            algo: TmfgAlgo::Opt,
            apsp: None,
            linkage: Linkage::Complete,
            hub: HubConfig::default(),
            artifacts_dir: PathBuf::from("artifacts"),
            use_xla: true,
            check_invariants: false,
        }
    }
}

#[derive(Debug)]
pub struct PipelineOutput {
    pub algo: TmfgAlgo,
    pub breakdown: Breakdown,
    pub tmfg: TmfgResult,
    pub dbht: DbhtResult,
    /// Predicted labels from cutting at the ground-truth class count
    /// (None when the dataset has no labels).
    pub labels: Option<Vec<usize>>,
    pub ari: Option<f64>,
    pub edge_sum: f64,
    pub corr_path: Option<CorrPath>,
}

/// Build a TMFG with the given algorithm's standard configuration — the
/// mapping `Pipeline` uses internally, shared with the streaming
/// subsystem (which constructs topologies outside a `Pipeline`).
pub fn build_tmfg_for(algo: TmfgAlgo, s: &Matrix) -> TmfgResult {
    match algo {
        TmfgAlgo::Par(p) => orig_tmfg(s, p),
        TmfgAlgo::Corr => corr_tmfg(s, &TmfgConfig::default()),
        TmfgAlgo::Heap => heap_tmfg(s, &TmfgConfig::default()),
        // OPT = HEAP + radix sort (+ approximate APSP via
        // effective_apsp). The paper's manual-vectorization scan is
        // kept available as ScanKind::Chunked but measured a net
        // 0.9–1.0× on this host (the paper itself reports 0.97–1.07×),
        // so the default follows the perf-pass keep-if-it-helps rule
        // (EXPERIMENTS.md §Perf iter. 6).
        TmfgAlgo::Opt => heap_tmfg(
            s,
            &TmfgConfig { prefix: 1, scan: ScanKind::Scalar, sort: SortKind::Radix },
        ),
    }
}

pub struct Pipeline {
    pub config: PipelineConfig,
    engine: CorrEngine,
}

impl Pipeline {
    pub fn new(config: PipelineConfig) -> Pipeline {
        let engine = if config.use_xla {
            CorrEngine::auto(&config.artifacts_dir)
        } else {
            CorrEngine::native_only()
        };
        Pipeline { config, engine }
    }

    fn effective_apsp(&self) -> ApspMode {
        self.config.apsp.unwrap_or(match self.config.algo {
            TmfgAlgo::Opt => ApspMode::Approx,
            _ => ApspMode::Exact,
        })
    }

    fn build_tmfg(&self, s: &Matrix) -> TmfgResult {
        build_tmfg_for(self.config.algo, s)
    }

    /// Run from a raw dataset (computes the similarity matrix first).
    pub fn run_dataset(&self, ds: &Dataset) -> PipelineOutput {
        let mut timer = Timer::start();
        let (s, _rowsums, path) = self
            .engine
            .similarity(&ds.data)
            .expect("similarity computation failed");
        let sim_secs = timer.lap();
        let mut out = self.run_similarity(&s, Some(&ds.labels), ds.n_classes);
        out.corr_path = Some(path);
        out.breakdown.add("similarity", sim_secs);
        out
    }

    /// Run from a precomputed similarity matrix (the paper's setting).
    pub fn run_similarity(
        &self,
        s: &Matrix,
        labels: Option<&[usize]>,
        n_classes: usize,
    ) -> PipelineOutput {
        let mut breakdown = Breakdown::new();
        let mut timer = Timer::start();

        // ---- TMFG construction ---------------------------------------------
        let tmfg = self.build_tmfg(s);
        timer.reset();
        if self.config.check_invariants {
            crate::tmfg::common::check_invariants(&tmfg).expect("TMFG invariants");
        }
        breakdown.add("tmfg:init-faces", tmfg.timings.init);
        breakdown.add("tmfg:sort", tmfg.timings.sort);
        breakdown.add("tmfg:add-vertices", tmfg.timings.insert);

        // ---- APSP ------------------------------------------------------------
        timer.reset();
        let g = CsrGraph::from_tmfg(&tmfg, s);
        let apsp = match self.effective_apsp() {
            ApspMode::Exact => apsp_exact(&g),
            ApspMode::Approx => apsp_hub(&g, &self.config.hub),
        };
        breakdown.add("apsp", timer.lap());

        // ---- DBHT ------------------------------------------------------------
        let dbht = dbht_dendrogram(s, &tmfg, &apsp, self.config.linkage);
        breakdown.add("dbht", timer.lap());

        // ---- metrics ----------------------------------------------------------
        let edge_sum = tmfg.edge_sum(s);
        let (labels_pred, ari) = match labels {
            Some(truth) => {
                let pred = dbht.dendrogram.cut(n_classes.max(1));
                let ari = adjusted_rand_index(truth, &pred);
                (Some(pred), Some(ari))
            }
            None => (None, None),
        };

        PipelineOutput {
            algo: self.config.algo,
            breakdown,
            tmfg,
            dbht,
            labels: labels_pred,
            ari,
            edge_sum,
            corr_path: None,
        }
    }

    /// Stream configuration inheriting this pipeline's algorithm,
    /// linkage, APSP mode, and hub parameters.
    pub fn stream_config(&self, n: usize, window: usize, k: usize) -> StreamConfig {
        let mut cfg = StreamConfig::new(n, window, k);
        cfg.algo = self.config.algo;
        cfg.linkage = self.config.linkage;
        cfg.apsp = self.config.apsp;
        cfg.hub = self.config.hub.clone();
        cfg
    }

    /// Streaming entry point: replay an n×T panel column-by-column
    /// through a [`StreamSession`] — each tick feeds one new observation
    /// per series, the window correlation updates in O(n²), and the
    /// session refreshes or rebuilds the topology per its drift policy.
    /// Returns the session (for stats/history/topology) and the per-tick
    /// outputs.
    pub fn run_stream(
        &self,
        panel: &Matrix,
        cfg: StreamConfig,
    ) -> Result<(StreamSession, Vec<TickOutput>), String> {
        let mut session = StreamSession::new(cfg)?;
        let mut outputs = Vec::with_capacity(panel.cols);
        let mut sample = vec![0.0f32; panel.rows];
        for t in 0..panel.cols {
            for (i, v) in sample.iter_mut().enumerate() {
                *v = panel.at(i, t);
            }
            outputs.push(session.tick(&sample)?);
        }
        Ok((session, outputs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    fn cfg(algo: TmfgAlgo) -> PipelineConfig {
        PipelineConfig { algo, use_xla: false, check_invariants: true, ..Default::default() }
    }

    #[test]
    fn algo_parse_roundtrip() {
        for a in [TmfgAlgo::Par(1), TmfgAlgo::Par(10), TmfgAlgo::Par(200), TmfgAlgo::Corr, TmfgAlgo::Heap, TmfgAlgo::Opt] {
            assert_eq!(TmfgAlgo::parse(&a.name()), Some(a));
        }
        assert_eq!(TmfgAlgo::parse("par10"), Some(TmfgAlgo::Par(10)));
        assert_eq!(TmfgAlgo::parse("bogus"), None);
    }

    #[test]
    fn all_algorithms_run_end_to_end() {
        let ds = SynthSpec::new("t", 80, 48, 3).generate(1);
        for algo in [TmfgAlgo::Par(1), TmfgAlgo::Par(10), TmfgAlgo::Corr, TmfgAlgo::Heap, TmfgAlgo::Opt] {
            let p = Pipeline::new(cfg(algo));
            let out = p.run_dataset(&ds);
            assert!(out.dbht.dendrogram.is_complete(), "{algo:?}");
            let ari = out.ari.unwrap();
            assert!((-1.0..=1.0).contains(&ari), "{algo:?}: {ari}");
            assert!(out.edge_sum.is_finite());
            assert!(out.breakdown.total() > 0.0);
            assert!(out.breakdown.get("apsp").is_some());
            assert!(out.breakdown.get("dbht").is_some());
            assert_eq!(out.labels.as_ref().unwrap().len(), 80);
        }
    }

    #[test]
    fn default_apsp_mode_per_algo() {
        let p_opt = Pipeline::new(cfg(TmfgAlgo::Opt));
        assert_eq!(p_opt.effective_apsp(), ApspMode::Approx);
        let p_heap = Pipeline::new(cfg(TmfgAlgo::Heap));
        assert_eq!(p_heap.effective_apsp(), ApspMode::Exact);
        let mut c = cfg(TmfgAlgo::Opt);
        c.apsp = Some(ApspMode::Exact);
        assert_eq!(Pipeline::new(c).effective_apsp(), ApspMode::Exact);
    }

    #[test]
    fn run_stream_replays_whole_panel() {
        let ds = SynthSpec::new("t", 30, 48, 3).generate(5);
        let p = Pipeline::new(cfg(TmfgAlgo::Heap));
        let scfg = p.stream_config(ds.n(), 24, 3);
        let warmup = scfg.warmup;
        let (session, outs) = p.run_stream(&ds.data, scfg).unwrap();
        assert_eq!(outs.len(), 48);
        let warming = outs.iter().filter(|o| o.labels.is_none()).count();
        assert_eq!(warming, warmup - 1);
        let st = session.stats();
        assert_eq!(st.ticks, 48);
        assert_eq!(st.emissions, 48 - (warmup as u64 - 1));
        assert_eq!(st.rebuilds + st.refreshes, st.emissions);
        assert_eq!(session.generation(), st.emissions);
        // stream config inherits the pipeline's algorithm
        assert_eq!(session.config.algo, TmfgAlgo::Heap);
    }

    #[test]
    fn run_stream_rejects_bad_config() {
        let ds = SynthSpec::new("t", 3, 16, 1).generate(6);
        let p = Pipeline::new(cfg(TmfgAlgo::Heap));
        let scfg = p.stream_config(3, 8, 1); // n < 4
        assert!(p.run_stream(&ds.data, scfg).is_err());
    }

    #[test]
    fn unlabeled_run() {
        let ds = SynthSpec::new("t", 40, 32, 2).generate(2);
        let p = Pipeline::new(cfg(TmfgAlgo::Heap));
        let out = p.run_similarity(
            &crate::data::corr::pearson_correlation(&ds.data),
            None,
            0,
        );
        assert!(out.ari.is_none());
        assert!(out.labels.is_none());
    }
}
