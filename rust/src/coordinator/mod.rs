//! Layer-3 coordinator: the TDBHT pipeline (dataset → similarity via the
//! XLA engine → TMFG → APSP → DBHT → dendrogram → metrics) with per-stage
//! timing, the dataset registry, the experiment harness regenerating every
//! table/figure of the paper, and a batched TCP clustering service.

pub mod experiments;
pub mod pipeline;
pub mod registry;
pub mod service;

pub use pipeline::{ApspMode, Pipeline, PipelineConfig, PipelineOutput, TmfgAlgo};
