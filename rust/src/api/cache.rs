//! Cross-request artifact cache: memoizes the expensive Similarity→TMFG
//! prefix of the pipeline across requests.
//!
//! For repeated or batch traffic on the same dataset, the dominant cost
//! of a clustering request is recomputing the O(n²·l) correlation matrix
//! and the O(n²) TMFG construction. Both artifacts depend only on the
//! input content (dataset identity or raw panel/similarity bytes) and
//! the construction algorithm — **not** on the APSP mode, linkage, hub
//! parameters, or `k`, which the downstream stages recompute cheaply per
//! request. [`ArtifactCache`] is a bounded, byte-budgeted LRU keyed by a
//! stable content fingerprint ([`CacheKey`], produced by
//! [`crate::api::ClusterRequest::fingerprint`]).
//!
//! Attach a cache with [`crate::api::ClusterRequest::cache`]; on a hit
//! the plan is seeded with the shared artifacts (zero copies — they are
//! `Arc`s) so the similarity and TMFG stages are skipped entirely, and
//! [`crate::api::ClusterOutput::cache`] reports [`CacheStatus::Hit`].
//! Because every downstream stage is deterministic (see
//! `rust/tests/determinism.rs`), a hit produces a payload bit-identical
//! to the miss that populated the entry.
//!
//! Sharing one cache across engines with *different* similarity compute
//! paths (XLA vs native) can mix path-specific float rounding into
//! served artifacts; the `use_xla` preference is folded into panel keys
//! as a discriminator, and the TCP service uses a single engine for its
//! whole lifetime, so served traffic never mixes paths.

use crate::data::matrix::Matrix;
use crate::tmfg::TmfgResult;
use std::collections::hash_map::RandomState;
use std::hash::{BuildHasher, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// How a request interacted with the artifact cache.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheStatus {
    /// Served from cached artifacts (similarity + TMFG skipped).
    Hit,
    /// Computed fresh; the artifacts were published to the cache.
    Miss,
    /// No cache attached, or the source has no stable fingerprint
    /// (e.g. a CSV file path, whose bytes can change underneath us).
    Bypass,
}

/// Stable content fingerprint of a request's Similarity→TMFG inputs.
///
/// `desc` pins the structural identity (source kind, shape, dataset
/// name/scale/seed, algorithm); `content` is a 128-bit *keyed* hash
/// (two independently-seeded per-process SipHash halves) of the raw f32
/// bytes for inline panel/similarity sources (0 for named datasets,
/// which are deterministic functions of `desc` already). Keys are
/// stable only within one process — exactly the cache's lifetime.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct CacheKey {
    desc: String,
    content: (u64, u64),
}

/// Two independently-seeded keyed hashers (std SipHash via
/// `RandomState`, randomly keyed once per process). Content keys must be
/// *keyed*: the cache is shared across tenants, and with a public
/// unkeyed hash a client could construct a same-shape panel colliding
/// with another tenant's and poison their results with a bogus "hit".
/// Per-process keys are fine — the cache is in-memory only.
fn hashers() -> &'static (RandomState, RandomState) {
    static H: OnceLock<(RandomState, RandomState)> = OnceLock::new();
    H.get_or_init(|| (RandomState::new(), RandomState::new()))
}

/// 128-bit keyed content hash of a matrix's raw f32 bits (two
/// independently-keyed 64-bit halves must both collide).
fn matrix_hash(m: &Matrix) -> (u64, u64) {
    let (s1, s2) = hashers();
    let (mut h1, mut h2) = (s1.build_hasher(), s2.build_hasher());
    for v in &m.data {
        let bits = v.to_bits();
        h1.write_u32(bits);
        h2.write_u32(bits);
    }
    (h1.finish(), h2.finish())
}

impl CacheKey {
    /// Key for a registry dataset request. `canonical` must be the
    /// registry's canonical spelling so case variants share an entry.
    /// `use_xla` discriminates because named datasets resolve to a panel
    /// whose similarity is computed by the engine.
    pub fn named(canonical: &str, scale: f64, seed: u64, algo: &str, use_xla: bool) -> CacheKey {
        CacheKey {
            desc: format!(
                "dataset:{canonical}:scale={scale}:seed={seed}:algo={algo}:xla={use_xla}"
            ),
            content: (0, 0),
        }
    }

    /// Key for an inline n×l time-series panel (hashes the panel bytes).
    pub fn panel(m: &Matrix, algo: &str, use_xla: bool) -> CacheKey {
        CacheKey {
            desc: format!("panel:{}x{}:algo={algo}:xla={use_xla}", m.rows, m.cols),
            content: matrix_hash(m),
        }
    }

    /// Key for a precomputed similarity matrix (hashes the matrix bytes).
    pub fn similarity(s: &Matrix, algo: &str) -> CacheKey {
        CacheKey {
            desc: format!("similarity:{}:algo={algo}", s.rows),
            content: matrix_hash(s),
        }
    }
}

/// The cached Similarity→TMFG artifacts (plus the dataset-intrinsic
/// metadata needed to serve a named-dataset hit without regenerating the
/// dataset at all).
#[derive(Clone)]
pub struct CachedArtifacts {
    pub similarity: Arc<Matrix>,
    pub tmfg: Arc<TmfgResult>,
    /// Ground-truth labels carried by named-dataset sources (None for
    /// panel/similarity sources, which have no intrinsic labels).
    pub truth: Option<Vec<usize>>,
    /// The dataset's own class count (the `k` a named request defaults
    /// to when it does not set one).
    pub default_k: Option<usize>,
}

impl CachedArtifacts {
    /// Approximate resident size, used for the byte budget.
    pub fn bytes(&self) -> usize {
        let t = &self.tmfg;
        self.similarity.data.len() * 4
            + t.edges.len() * 8
            + t.faces.len() * 12
            + t.cliques.len() * 16
            + t.parent.len() * 4
            + t.order.len() * 4
            + self.truth.as_ref().map(|l| l.len() * 8).unwrap_or(0)
    }
}

struct Entry {
    key: CacheKey,
    artifacts: CachedArtifacts,
    bytes: usize,
    last_used: u64,
}

struct Inner {
    entries: Vec<Entry>,
    bytes_total: usize,
    tick: u64,
}

/// Observability snapshot (the service's `stats` command reports this).
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
    pub bytes: usize,
}

/// Bounded, byte-budgeted LRU over [`CachedArtifacts`]. All methods take
/// `&self`; the cache is shared across service workers behind an `Arc`.
pub struct ArtifactCache {
    inner: Mutex<Inner>,
    hits: AtomicU64,
    misses: AtomicU64,
    max_entries: usize,
    max_bytes: usize,
}

impl ArtifactCache {
    /// Default entry cap (the `--cache-entries` default).
    pub const DEFAULT_ENTRIES: usize = 32;
    /// Default byte budget: 256 MiB of artifacts.
    pub const DEFAULT_BYTES: usize = 256 << 20;

    pub fn new(max_entries: usize, max_bytes: usize) -> ArtifactCache {
        ArtifactCache {
            inner: Mutex::new(Inner { entries: Vec::new(), bytes_total: 0, tick: 0 }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            max_entries: max_entries.max(1),
            max_bytes: max_bytes.max(1),
        }
    }

    /// Look up artifacts, bumping recency and the hit/miss counters.
    pub fn get(&self, key: &CacheKey) -> Option<CachedArtifacts> {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        match inner.entries.iter_mut().find(|e| &e.key == key) {
            Some(e) => {
                e.last_used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(e.artifacts.clone())
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert (or refresh) an entry, evicting least-recently-used entries
    /// until both the entry cap and the byte budget hold. An artifact
    /// larger than the whole budget is not cached at all.
    pub fn put(&self, key: CacheKey, artifacts: CachedArtifacts) {
        let bytes = artifacts.bytes();
        if bytes > self.max_bytes {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(pos) = inner.entries.iter().position(|e| e.key == key) {
            let old = inner.entries.remove(pos);
            inner.bytes_total -= old.bytes;
        }
        inner.entries.push(Entry { key, artifacts, bytes, last_used: tick });
        inner.bytes_total += bytes;
        while inner.entries.len() > self.max_entries || inner.bytes_total > self.max_bytes {
            let lru = inner
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(i, _)| i);
            match lru {
                Some(i) => {
                    let gone = inner.entries.remove(i);
                    inner.bytes_total -= gone.bytes;
                }
                None => break,
            }
        }
    }

    pub fn stats(&self) -> CacheStats {
        let inner = self.inner.lock().unwrap();
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: inner.entries.len(),
            bytes: inner.bytes_total,
        }
    }
}

impl Default for ArtifactCache {
    fn default() -> Self {
        ArtifactCache::new(Self::DEFAULT_ENTRIES, Self::DEFAULT_BYTES)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tmfg::{heap_tmfg, TmfgConfig};

    fn artifacts(n: usize, seed: u64) -> CachedArtifacts {
        let ds = crate::data::synth::SynthSpec::new("t", n, 32, 2).generate(seed);
        let s = Arc::new(crate::data::corr::pearson_correlation(&ds.data));
        let tmfg = Arc::new(heap_tmfg(&s, &TmfgConfig::default()).unwrap());
        CachedArtifacts { similarity: s, tmfg, truth: Some(ds.labels), default_k: Some(2) }
    }

    fn key(tag: u64) -> CacheKey {
        CacheKey::named(&format!("ds{tag}"), 1.0, tag, "heap", true)
    }

    #[test]
    fn get_put_roundtrip_and_counters() {
        let c = ArtifactCache::new(4, usize::MAX >> 1);
        assert!(c.get(&key(1)).is_none());
        let a = artifacts(16, 1);
        c.put(key(1), a.clone());
        let got = c.get(&key(1)).expect("hit");
        assert!(Arc::ptr_eq(&got.similarity, &a.similarity), "no copies");
        assert!(Arc::ptr_eq(&got.tmfg, &a.tmfg));
        assert_eq!(got.truth, a.truth);
        let st = c.stats();
        assert_eq!((st.hits, st.misses, st.entries), (1, 1, 1));
        assert_eq!(st.bytes, a.bytes());
    }

    #[test]
    fn lru_eviction_order() {
        let c = ArtifactCache::new(2, usize::MAX >> 1);
        c.put(key(1), artifacts(16, 1));
        c.put(key(2), artifacts(16, 2));
        assert!(c.get(&key(1)).is_some()); // 1 is now most recent
        c.put(key(3), artifacts(16, 3)); // evicts 2
        assert!(c.get(&key(2)).is_none());
        assert!(c.get(&key(1)).is_some());
        assert!(c.get(&key(3)).is_some());
        assert_eq!(c.stats().entries, 2);
    }

    #[test]
    fn byte_budget_evicts_and_rejects_oversize() {
        let a = artifacts(16, 1);
        let unit = a.bytes();
        // Budget for ~1.5 entries: inserting a second evicts the first.
        let c = ArtifactCache::new(10, unit + unit / 2);
        c.put(key(1), a);
        c.put(key(2), artifacts(16, 2));
        assert!(c.get(&key(1)).is_none());
        assert!(c.get(&key(2)).is_some());
        assert!(c.stats().bytes <= unit + unit / 2);
        // An artifact bigger than the whole budget is skipped entirely.
        let tiny = ArtifactCache::new(10, 8);
        tiny.put(key(3), artifacts(16, 3));
        assert_eq!(tiny.stats().entries, 0);
    }

    #[test]
    fn replace_same_key_keeps_one_entry() {
        let c = ArtifactCache::new(4, usize::MAX >> 1);
        c.put(key(1), artifacts(16, 1));
        c.put(key(1), artifacts(16, 9));
        let st = c.stats();
        assert_eq!(st.entries, 1);
        let got = c.get(&key(1)).unwrap();
        // latest insert wins
        assert_eq!(got.truth, artifacts(16, 9).truth);
    }

    #[test]
    fn keys_discriminate_sources() {
        let ds = crate::data::synth::SynthSpec::new("t", 12, 16, 2).generate(4);
        let m = ds.data;
        let k1 = CacheKey::panel(&m, "heap", true);
        let k2 = CacheKey::panel(&m, "opt", true);
        let k3 = CacheKey::panel(&m, "heap", false);
        assert_ne!(k1, k2, "algo is part of the key");
        assert_ne!(k1, k3, "xla preference is part of the key");
        assert_eq!(k1, CacheKey::panel(&m.clone(), "heap", true), "content-addressed");
        let mut m2 = m.clone();
        m2.data[5] += 1.0;
        assert_ne!(k1, CacheKey::panel(&m2, "heap", true), "bytes are hashed");
        assert_ne!(
            CacheKey::named("CBF", 0.05, 1, "heap", true),
            CacheKey::named("CBF", 0.05, 2, "heap", true),
            "seed is part of the key"
        );
    }
}
