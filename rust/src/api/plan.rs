//! The staged plan executor: Similarity → Tmfg → Apsp → Dbht → Cut.
//!
//! A [`Plan`] is a resolved, validated clustering request (built by
//! [`crate::api::ClusterRequest`]) whose stages can be run individually.
//! Every stage is fallible, memoized, and leaves an inspectable artifact
//! (`similarity()`, `tmfg()`, `apsp()`, `dbht()`, `labels()`) plus a
//! wall-clock entry in [`Plan::timings`]. Running a stage implicitly runs
//! the stages it depends on; re-running a completed stage is free.
//!
//! Because artifacts are explicit, callers can reuse expensive work: for
//! example [`Plan::set_apsp_mode`] invalidates only the APSP/DBHT/cut
//! artifacts, so one TMFG construction can be measured under both exact
//! and approximate APSP (see `coordinator::experiments::apsp_speedup`).

use super::cache::{ArtifactCache, CacheKey, CacheStatus, CachedArtifacts};
use crate::apsp::{exact_oracle, ApspOracle, CsrGraph, HubConfig, HubOracle, OracleKind};
use crate::data::matrix::{Matrix, SimilarityLookup};
use crate::dbht::hierarchy::{dbht_dendrogram, DbhtResult};
use crate::dbht::Linkage;
use crate::error::TmfgError;
use crate::metrics::adjusted_rand_index;
use crate::runtime::engine::{CorrEngine, CorrPath};
use crate::sparse::{knn_candidates, sparse_tmfg, KnnConfig, SparseSimilarity};
use crate::tmfg::{corr_tmfg, heap_tmfg, orig_tmfg, ScanKind, SortKind, TmfgConfig, TmfgResult};
use crate::util::timer::{Breakdown, Timer};
use std::sync::Arc;
use std::time::Duration;

/// How the similarity stage reduces the input panel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimilaritySpec {
    /// The dense n×n Pearson matrix (the paper's setting). O(n²) memory.
    Dense,
    /// A sparse k-NN candidate graph over the standardized panel —
    /// O(n·k) memory, deterministic for a fixed `seed` (which drives the
    /// random-projection prefilter + NN-descent refinement on very
    /// large inputs). TMFG construction runs the sparse-gain path;
    /// APSP/DBHT run unchanged. The optional knobs override the
    /// [`KnnConfig`] defaults: `dims` = projection dimensionality,
    /// `pool` = shortlist multiplier, `iters` = refinement rounds
    /// (`Some(0)` disables refinement); `None` keeps the engine default.
    SparseKnn {
        k: usize,
        seed: u64,
        dims: Option<usize>,
        pool: Option<usize>,
        iters: Option<usize>,
    },
}

impl SimilaritySpec {
    /// Resolve a `SparseKnn` spec to the engine configuration it runs
    /// with (the one knob→config mapping, shared by the similarity
    /// stage and the report in [`Plan::finish`]).
    pub fn knn_config(&self) -> Option<KnnConfig> {
        let SimilaritySpec::SparseKnn { k, seed, dims, pool, iters } = *self else {
            return None;
        };
        let mut cfg = KnnConfig::new(k, seed);
        if let Some(d) = dims {
            cfg.projection_dims = d;
        }
        if let Some(p) = pool {
            cfg.pool_factor = p;
        }
        if let Some(i) = iters {
            cfg.ann_iters = i;
        }
        Some(cfg)
    }
}

/// What the sparse similarity stage produced (reported on
/// [`ClusterOutput`] and by the TCP service).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SparseReport {
    /// Requested neighbors per vertex.
    pub k: usize,
    /// Prefilter seed.
    pub seed: u64,
    /// Effective projection dimensionality of the prefilter.
    pub dims: usize,
    /// Effective shortlist multiplier (`pool_factor`).
    pub pool: usize,
    /// Effective NN-descent refinement rounds.
    pub iters: usize,
    /// Stored (directed) candidate entries after symmetrization.
    pub nnz: usize,
    /// Mean candidate degree.
    pub mean_degree: f64,
    /// TMFG rounds that fell back to a dense scan (candidates
    /// exhausted); high counts mean `k` was too small.
    pub fallbacks: usize,
}

/// Which TMFG construction algorithm to run — mirrors the paper's
/// implementation list (§5 "Implementations").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TmfgAlgo {
    /// PAR-TDBHT-P (Yu & Shun) with the given prefix size.
    Par(usize),
    /// CORR-TDBHT (Alg. 1), prefix 1.
    Corr,
    /// HEAP-TDBHT (Alg. 2).
    Heap,
    /// OPT-TDBHT: HEAP + vectorized scan + radix sort + approximate APSP.
    Opt,
}

impl TmfgAlgo {
    pub fn name(&self) -> String {
        match self {
            TmfgAlgo::Par(p) => format!("par-tdbht-{p}"),
            TmfgAlgo::Corr => "corr-tdbht".into(),
            TmfgAlgo::Heap => "heap-tdbht".into(),
            TmfgAlgo::Opt => "opt-tdbht".into(),
        }
    }

    pub fn parse(s: &str) -> Option<TmfgAlgo> {
        match s.to_ascii_lowercase().as_str() {
            "corr" | "corr-tdbht" => Some(TmfgAlgo::Corr),
            "heap" | "heap-tdbht" => Some(TmfgAlgo::Heap),
            "opt" | "opt-tdbht" => Some(TmfgAlgo::Opt),
            other => {
                let p = other
                    .strip_prefix("par-tdbht-")
                    .or_else(|| other.strip_prefix("par"))?;
                p.parse().ok().map(TmfgAlgo::Par)
            }
        }
    }

    /// The APSP mode this algorithm defaults to (OPT pairs with the
    /// approximate hub solver; everything else is exact).
    pub fn default_apsp(&self) -> ApspMode {
        match self {
            TmfgAlgo::Opt => ApspMode::Approx,
            _ => ApspMode::Exact,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ApspMode {
    /// Parallel Dijkstra from every source, materialized dense. O(n²)
    /// memory — the reference answer.
    Exact,
    /// The §4.3 hub scheme, served by a streaming [`HubOracle`] —
    /// O(n·h) memory, same numbers as the dense hub matrix.
    Approx,
    /// Exact below [`APSP_AUTO_DENSE_MAX`] vertices, hub oracle above —
    /// the size-aware default for mixed workloads.
    Auto,
}

impl ApspMode {
    pub fn name(&self) -> &'static str {
        match self {
            ApspMode::Exact => "exact",
            ApspMode::Approx => "approx",
            ApspMode::Auto => "auto",
        }
    }

    pub fn parse(s: &str) -> Option<ApspMode> {
        match s.to_ascii_lowercase().as_str() {
            "exact" => Some(ApspMode::Exact),
            "approx" | "approximate" | "hub" => Some(ApspMode::Approx),
            "auto" => Some(ApspMode::Auto),
            _ => None,
        }
    }
}

/// Largest n for which [`ApspMode::Auto`] still materializes the exact
/// dense matrix (64 MiB of f32 at the threshold). Above it, Auto runs
/// the O(n·h) [`HubOracle`] so DBHT memory scales with the sparse
/// pipeline instead of quadratically.
pub const APSP_AUTO_DENSE_MAX: usize = 4096;

/// The one mode→backend resolution point, shared by the batch [`Plan`]
/// and the streaming subsystem: Exact materializes the dense matrix,
/// Approx builds the streaming hub oracle (never an n×n buffer), Auto
/// picks by size.
pub fn build_apsp_oracle(
    mode: ApspMode,
    g: &CsrGraph,
    hub: &HubConfig,
) -> Arc<dyn ApspOracle> {
    match mode {
        ApspMode::Exact => Arc::new(exact_oracle(g)),
        ApspMode::Approx => Arc::new(HubOracle::build(g, hub)),
        ApspMode::Auto => {
            if g.n <= APSP_AUTO_DENSE_MAX {
                Arc::new(exact_oracle(g))
            } else {
                Arc::new(HubOracle::build(g, hub))
            }
        }
    }
}

/// Record one stage latency into the global obs registry — the source
/// for the service's `stats` p50/p95/p99 and the Prometheus
/// `{"cmd": "metrics"}` exposition — and into the per-stage SLO series
/// (`stage:<name>`) the multi-window tracker reports attainment for.
fn observe_stage(stage: &str, secs: f64) {
    crate::obs::registry().observe_secs(
        crate::obs::names::STAGE_SECONDS,
        Some(("stage", stage)),
        secs,
    );
    if secs.is_finite() && secs >= 0.0 {
        crate::obs::slo_tracker()
            .record(&format!("stage:{stage}"), Duration::from_secs_f64(secs));
    }
}

/// Build a TMFG with the given algorithm's standard configuration — the
/// mapping shared by the batch [`Plan`] and the streaming subsystem
/// (which constructs topologies outside a plan).
pub fn build_tmfg_for(algo: TmfgAlgo, s: &Matrix) -> Result<TmfgResult, TmfgError> {
    match algo {
        TmfgAlgo::Par(p) => orig_tmfg(s, p),
        TmfgAlgo::Corr => corr_tmfg(s, &TmfgConfig::default()),
        TmfgAlgo::Heap => heap_tmfg(s, &TmfgConfig::default()),
        // OPT = HEAP + radix sort + the 16-wide branch-light scan
        // (+ approximate APSP via the plan's apsp mode). The earlier
        // 8-wide ScanKind::Chunked measured a net 0.9–1.0× on this host
        // (the paper itself reports 0.97–1.07×) and stayed off; the Wide
        // scan hoists the bounds checks out of the flag gather and is
        // selection-identical to Scalar (pinned by the equivalence
        // suites), so OPT follows the perf-pass keep-if-it-helps rule
        // with the wider variant.
        TmfgAlgo::Opt => heap_tmfg(
            s,
            &TmfgConfig { prefix: 1, scan: ScanKind::Wide, sort: SortKind::Radix },
        ),
    }
}

/// The five pipeline stages in execution order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    Similarity,
    Tmfg,
    Apsp,
    Dbht,
    Cut,
}

/// Per-request resource accounting, threaded from the plan's artifacts
/// into the flight recorder's wide events — the "why was this request
/// expensive" counters the process-global totals can't attribute.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ResourceUsage {
    /// APSP rows materialized by this request's oracle instance
    /// (`row_into` calls across DBHT and the HAC layers).
    pub oracle_rows: u64,
    /// Sparse TMFG rounds that fell back to a dense scan (0 on dense
    /// plans); high counts mean the request's `k` was too small.
    pub knn_fallbacks: u64,
    /// Bytes of the Similarity+TMFG artifact pair this request served
    /// from or published to the artifact cache (0 on bypass).
    pub cache_bytes: u64,
}

/// Owned result of a completed plan (what [`Plan::finish`] returns and
/// what the legacy `Pipeline` facade hands back).
#[derive(Debug)]
pub struct ClusterOutput {
    pub algo: TmfgAlgo,
    pub apsp_mode: ApspMode,
    /// Per-stage wall-clock seconds (the Fig. 5 decomposition). Stages
    /// served from the artifact cache contribute no entry.
    pub breakdown: Breakdown,
    /// Shared when served from (or published to) an artifact cache.
    pub tmfg: Arc<TmfgResult>,
    pub dbht: DbhtResult,
    /// Predicted labels from cutting the dendrogram at `k` (None when no
    /// `k` was requested and none could be inferred).
    pub labels: Option<Vec<usize>>,
    /// Adjusted Rand index vs the ground-truth labels (None without
    /// ground truth or without a cut).
    pub ari: Option<f64>,
    /// Sum of similarity over the TMFG edges (the Fig. 7 quality metric).
    pub edge_sum: f64,
    /// Which APSP backend served DBHT: [`OracleKind::Dense`] (exact, or
    /// Auto below the size threshold) or [`OracleKind::Hub`] (the
    /// streaming O(n·h) oracle).
    pub oracle: OracleKind,
    /// Which compute path produced the similarity matrix (None when it
    /// was supplied precomputed, served from the artifact cache, or
    /// built sparse — the sparse path is always native).
    pub corr_path: Option<CorrPath>,
    /// How this run interacted with the artifact cache
    /// ([`CacheStatus::Bypass`] when none was attached).
    pub cache: CacheStatus,
    /// Sparse-mode statistics (None on the dense path).
    pub sparse: Option<SparseReport>,
    /// Per-request resource accounting (flight-recorder wide events).
    pub resources: ResourceUsage,
}

/// A plan's attachment to an [`ArtifactCache`]: where to publish freshly
/// computed artifacts (on a miss) and what to report.
pub(crate) struct CacheCtx {
    pub cache: Arc<ArtifactCache>,
    pub key: CacheKey,
    pub status: CacheStatus,
    /// Dataset-intrinsic labels/class-count to store alongside the
    /// artifacts so a future hit can serve a named dataset without
    /// regenerating it.
    pub truth: Option<Vec<usize>>,
    pub default_k: Option<usize>,
}

/// A resolved staged clustering request. See the module docs.
pub struct Plan {
    pub algo: TmfgAlgo,
    pub linkage: Linkage,
    pub hub: HubConfig,
    pub check_invariants: bool,
    spec: SimilaritySpec,
    apsp_mode: ApspMode,
    /// Cut size; None = no cut in [`Plan::finish`].
    k: Option<usize>,
    /// Ground-truth labels (length n) for ARI reporting.
    truth: Option<Vec<usize>>,
    n: usize,
    /// Raw n×L panel (absent when the similarity was supplied directly).
    /// Shared, so many plans can run over one panel without copying it.
    panel: Option<Arc<Matrix>>,
    /// Similarity engine; only present when a panel must be reduced.
    engine: Option<Arc<CorrEngine>>,
    // ---- per-stage artifacts -------------------------------------------
    similarity: Option<Arc<Matrix>>,
    /// Sparse candidate similarity (the [`SimilaritySpec::SparseKnn`]
    /// analog of `similarity`).
    sparse: Option<Arc<SparseSimilarity>>,
    /// Fallback count from the sparse TMFG construction.
    sparse_fallbacks: Option<usize>,
    corr_path: Option<CorrPath>,
    /// `Arc` so cached constructions are shared across plans zero-copy.
    tmfg: Option<Arc<TmfgResult>>,
    apsp: Option<Arc<dyn ApspOracle>>,
    dbht: Option<DbhtResult>,
    cut: Option<Vec<usize>>,
    /// The k the current `cut` artifact was made at.
    cut_k: Option<usize>,
    /// Per-stage wall-clock seconds, filled as stages run.
    pub timings: Breakdown,
    /// Artifact-cache attachment (None = no cache on the request).
    cache_ctx: Option<CacheCtx>,
    /// Bytes of the cached artifact pair this plan served from or
    /// published (resource accounting; 0 on bypass).
    cache_bytes: u64,
}

impl Plan {
    /// Internal constructor used by `ClusterRequest::build` (which has
    /// already validated shapes, labels, and `k`).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn new(
        algo: TmfgAlgo,
        spec: SimilaritySpec,
        apsp_mode: ApspMode,
        linkage: Linkage,
        hub: HubConfig,
        check_invariants: bool,
        k: Option<usize>,
        truth: Option<Vec<usize>>,
        n: usize,
        panel: Option<Arc<Matrix>>,
        similarity: Option<Arc<Matrix>>,
        engine: Option<Arc<CorrEngine>>,
    ) -> Plan {
        Plan {
            algo,
            linkage,
            hub,
            check_invariants,
            spec,
            apsp_mode,
            k,
            truth,
            n,
            panel,
            engine,
            similarity,
            sparse: None,
            sparse_fallbacks: None,
            corr_path: None,
            tmfg: None,
            apsp: None,
            dbht: None,
            cut: None,
            cut_k: None,
            timings: Breakdown::new(),
            cache_ctx: None,
            cache_bytes: 0,
        }
    }

    /// Attach an artifact-cache context (set by `ClusterRequest::build`).
    pub(crate) fn set_cache_ctx(&mut self, ctx: CacheCtx) {
        self.cache_ctx = Some(ctx);
    }

    /// Record the size of the cached artifacts this plan was served
    /// from (set by `ClusterRequest::build` on a hit).
    pub(crate) fn set_cache_bytes(&mut self, bytes: u64) {
        self.cache_bytes = bytes;
    }

    /// Seed the similarity + TMFG artifacts from a cache hit: the
    /// similarity and tmfg stages become no-ops (and contribute no
    /// timing entries, since no work ran).
    pub(crate) fn seed_artifacts(&mut self, similarity: Arc<Matrix>, tmfg: Arc<TmfgResult>) {
        self.similarity = Some(similarity);
        self.tmfg = Some(tmfg);
    }

    /// How this plan interacted with the artifact cache.
    pub fn cache_status(&self) -> CacheStatus {
        self.cache_ctx.as_ref().map(|c| c.status).unwrap_or(CacheStatus::Bypass)
    }

    /// Number of items being clustered.
    pub fn n(&self) -> usize {
        self.n
    }

    /// How the similarity stage reduces the input.
    pub fn similarity_spec(&self) -> SimilaritySpec {
        self.spec
    }

    /// The APSP mode the Apsp stage will run (or ran) with.
    pub fn apsp_mode(&self) -> ApspMode {
        self.apsp_mode
    }

    /// Switch the APSP mode, invalidating the APSP/DBHT/cut artifacts
    /// (and their timing entries, so the breakdown never double-counts)
    /// but keeping the similarity matrix and the TMFG — the idiomatic way
    /// to compare exact vs approximate APSP on one construction.
    pub fn set_apsp_mode(&mut self, mode: ApspMode) {
        if mode != self.apsp_mode {
            self.apsp_mode = mode;
            self.apsp = None;
            self.dbht = None;
            self.cut = None;
            self.cut_k = None;
            self.timings.remove("apsp");
            self.timings.remove("dbht");
            self.timings.remove("cut");
        }
    }

    // ---- artifact accessors -------------------------------------------
    pub fn similarity(&self) -> Option<&Matrix> {
        self.similarity.as_deref()
    }

    /// The sparse candidate similarity artifact (sparse plans only).
    pub fn sparse_similarity(&self) -> Option<&SparseSimilarity> {
        self.sparse.as_deref()
    }

    pub fn corr_path(&self) -> Option<CorrPath> {
        self.corr_path
    }

    pub fn tmfg(&self) -> Option<&TmfgResult> {
        self.tmfg.as_deref()
    }

    /// The dense APSP distance matrix, for inspection — present only
    /// when the stage ran on a dense backend (Exact mode, or Auto below
    /// [`APSP_AUTO_DENSE_MAX`]). Hub-backed plans never materialize it;
    /// read those through [`Plan::apsp_oracle`].
    pub fn apsp(&self) -> Option<&Matrix> {
        self.apsp.as_deref().and_then(|o| o.as_dense())
    }

    /// The APSP oracle artifact (whatever the backend).
    pub fn apsp_oracle(&self) -> Option<&dyn ApspOracle> {
        self.apsp.as_deref()
    }

    pub fn dbht(&self) -> Option<&DbhtResult> {
        self.dbht.as_ref()
    }

    /// The most recent cut's labels.
    pub fn labels(&self) -> Option<&[usize]> {
        self.cut.as_deref()
    }

    // ---- stages --------------------------------------------------------

    /// Stage 1 (dense): the n×n similarity matrix (computed from the
    /// panel via the engine, or supplied precomputed — the paper's
    /// setting). Sparse plans have no dense matrix; use
    /// [`Plan::run_sparse_similarity`] there.
    pub fn run_similarity(&mut self) -> Result<&Matrix, TmfgError> {
        if let SimilaritySpec::SparseKnn { .. } = self.spec {
            return Err(TmfgError::invalid(
                "sparse plan never materializes a dense similarity matrix; \
                 use run_sparse_similarity",
            ));
        }
        if self.similarity.is_none() {
            let panel = self.panel.as_ref().ok_or_else(|| {
                TmfgError::invariant("plan has neither a panel nor a similarity matrix")
            })?;
            let engine = self.engine.as_ref().ok_or_else(|| {
                TmfgError::invariant("plan with a panel input has no similarity engine")
            })?;
            let _span = crate::span!("stage", "similarity dense n={}", self.n);
            let t = Timer::start();
            let (s, _rowsums, path) = engine
                .similarity(panel)
                .map_err(|e| TmfgError::SimilarityFailed(format!("{e:#}")))?;
            let secs = t.elapsed();
            self.timings.add("similarity", secs);
            observe_stage("similarity", secs);
            self.similarity = Some(Arc::new(s));
            self.corr_path = Some(path);
        }
        self.similarity
            .as_deref()
            .ok_or_else(|| TmfgError::invariant("similarity artifact missing"))
    }

    /// Stage 1 (sparse): the k-NN candidate similarity graph, built from
    /// the panel with the plan's `SparseKnn` spec. Deterministic for a
    /// fixed seed, O(n·k) memory.
    pub fn run_sparse_similarity(&mut self) -> Result<&SparseSimilarity, TmfgError> {
        let Some(cfg) = self.spec.knn_config() else {
            return Err(TmfgError::invalid(
                "dense plan has no sparse similarity; use run_similarity",
            ));
        };
        if self.sparse.is_none() {
            let panel = self.panel.as_ref().ok_or_else(|| {
                TmfgError::invariant("sparse plan has no panel to build candidates from")
            })?;
            let _span =
                crate::span!("stage", "similarity sparse-knn n={} k={}", self.n, cfg.k);
            let t = Timer::start();
            let sp = knn_candidates(panel, &cfg)?;
            let secs = t.elapsed();
            self.timings.add("similarity", secs);
            observe_stage("similarity", secs);
            self.sparse = Some(Arc::new(sp));
        }
        self.sparse
            .as_deref()
            .ok_or_else(|| TmfgError::invariant("sparse similarity artifact missing"))
    }

    /// Run whichever similarity stage the spec calls for.
    fn ensure_similarity(&mut self) -> Result<(), TmfgError> {
        match self.spec {
            SimilaritySpec::Dense => self.run_similarity().map(|_| ()),
            SimilaritySpec::SparseKnn { .. } => self.run_sparse_similarity().map(|_| ()),
        }
    }

    /// The similarity store backing this plan (dense matrix or sparse
    /// candidate graph) — the one resolution point the downstream stages
    /// share.
    fn sim_store(&self) -> Result<&dyn SimilarityLookup, TmfgError> {
        if let Some(s) = &self.similarity {
            Ok(s.as_ref())
        } else if let Some(sp) = &self.sparse {
            Ok(sp.as_ref())
        } else {
            Err(TmfgError::invariant("similarity artifact missing"))
        }
    }

    /// Stage 2: TMFG construction with the plan's algorithm (sparse
    /// plans run the sparse-gain construction regardless of `algo`). On
    /// a cache hit the artifact was seeded at build time and this is a
    /// no-op; on a miss the freshly built Similarity→TMFG pair is
    /// published to the attached cache for future requests (dense plans
    /// only — sparse requests have no cache fingerprint).
    pub fn run_tmfg(&mut self) -> Result<&TmfgResult, TmfgError> {
        if self.tmfg.is_none() {
            self.ensure_similarity()?;
            let _span = crate::span!("stage", "tmfg {} n={}", self.algo.name(), self.n);
            let stage_timer = Timer::start();
            let tmfg = match self.spec {
                SimilaritySpec::Dense => {
                    let s = self
                        .similarity
                        .as_deref()
                        .ok_or_else(|| TmfgError::invariant("similarity artifact missing"))?;
                    Arc::new(build_tmfg_for(self.algo, s)?)
                }
                SimilaritySpec::SparseKnn { .. } => {
                    let sp = self
                        .sparse
                        .as_deref()
                        .ok_or_else(|| TmfgError::invariant("sparse artifact missing"))?;
                    let (r, report) = sparse_tmfg(sp)?;
                    self.sparse_fallbacks = Some(report.fallbacks);
                    Arc::new(r)
                }
            };
            if self.check_invariants {
                crate::tmfg::common::check_invariants(&tmfg)?;
            }
            observe_stage("tmfg", stage_timer.elapsed());
            self.timings.add("tmfg:init-faces", tmfg.timings.init);
            self.timings.add("tmfg:sort", tmfg.timings.sort);
            self.timings.add("tmfg:add-vertices", tmfg.timings.insert);
            let mut published_bytes = None;
            if let (Some(ctx), Some(sim)) = (&self.cache_ctx, &self.similarity) {
                if ctx.status == CacheStatus::Miss {
                    let art = CachedArtifacts {
                        similarity: sim.clone(),
                        tmfg: tmfg.clone(),
                        truth: ctx.truth.clone(),
                        default_k: ctx.default_k,
                    };
                    published_bytes = Some(art.bytes() as u64);
                    ctx.cache.put(ctx.key.clone(), art);
                }
            }
            if let Some(b) = published_bytes {
                self.cache_bytes = b;
            }
            self.tmfg = Some(tmfg);
        }
        self.tmfg
            .as_deref()
            .ok_or_else(|| TmfgError::invariant("tmfg artifact missing"))
    }

    /// Stage 3: all-pairs shortest paths on the filtered graph, as an
    /// [`ApspOracle`]. The TMFG is already sparse (3n−6 edges), so this
    /// stage is identical for dense and sparse plans — only the
    /// edge-weight lookup differs. Exact mode materializes the dense
    /// matrix; Approx builds the streaming hub oracle (O(n·h) memory,
    /// never an n×n buffer); Auto picks by size.
    pub fn run_apsp(&mut self) -> Result<&dyn ApspOracle, TmfgError> {
        if self.apsp.is_none() {
            self.run_tmfg()?;
            let tmfg = self
                .tmfg
                .as_deref()
                .ok_or_else(|| TmfgError::invariant("apsp stage missing inputs"))?;
            let _span = crate::span!("stage", "apsp {} n={}", self.apsp_mode.name(), self.n);
            let t = Timer::start();
            let g = CsrGraph::from_tmfg(tmfg, self.sim_store()?);
            let apsp = build_apsp_oracle(self.apsp_mode, &g, &self.hub);
            let secs = t.elapsed();
            self.timings.add("apsp", secs);
            observe_stage("apsp", secs);
            self.apsp = Some(apsp);
        }
        self.apsp
            .as_deref()
            .ok_or_else(|| TmfgError::invariant("apsp artifact missing"))
    }

    /// Stage 4: the DBHT dendrogram. DBHT reads similarities only at
    /// TMFG-edge pairs, so the sparse candidate store serves it exactly
    /// as the dense matrix does.
    pub fn run_dbht(&mut self) -> Result<&DbhtResult, TmfgError> {
        if self.dbht.is_none() {
            self.run_apsp()?;
            let (tmfg, apsp) = match (&self.tmfg, &self.apsp) {
                (Some(t), Some(a)) => (t.clone(), a.clone()),
                _ => return Err(TmfgError::invariant("dbht stage missing inputs")),
            };
            let _span = crate::span!("stage", "dbht n={}", self.n);
            let t = Timer::start();
            let dbht = dbht_dendrogram(self.sim_store()?, &tmfg, &*apsp, self.linkage)?;
            let secs = t.elapsed();
            self.timings.add("dbht", secs);
            observe_stage("dbht", secs);
            self.dbht = Some(dbht);
        }
        self.dbht
            .as_ref()
            .ok_or_else(|| TmfgError::invariant("dbht artifact missing"))
    }

    /// Stage 5: cut the dendrogram into `k` clusters. Memoized per `k`:
    /// repeating the same cut is free, a different `k` recomputes.
    pub fn run_cut(&mut self, k: usize) -> Result<&[usize], TmfgError> {
        if k < 1 || k > self.n {
            return Err(TmfgError::invalid(format!(
                "k must be in 1..={}, got {k}",
                self.n
            )));
        }
        if self.cut_k == Some(k) {
            return self
                .cut
                .as_deref()
                .ok_or_else(|| TmfgError::invariant("cut artifact missing"));
        }
        self.run_dbht()?;
        let dbht = self
            .dbht
            .as_ref()
            .ok_or_else(|| TmfgError::invariant("dbht artifact missing"))?;
        let _span = crate::span!("stage", "cut k={k}");
        let t = Timer::start();
        self.cut = Some(dbht.dendrogram.cut(k));
        self.cut_k = Some(k);
        let secs = t.elapsed();
        // replace rather than accumulate: a prior cut at another k was an
        // invalidated artifact, not part of this pipeline's cost
        self.timings.remove("cut");
        self.timings.add("cut", secs);
        observe_stage("cut", secs);
        self.cut
            .as_deref()
            .ok_or_else(|| TmfgError::invariant("cut artifact missing"))
    }

    /// Run one stage (and its prerequisites). `Stage::Cut` requires a `k`
    /// on the plan.
    pub fn run_stage(&mut self, stage: Stage) -> Result<(), TmfgError> {
        match stage {
            Stage::Similarity => self.ensure_similarity(),
            Stage::Tmfg => self.run_tmfg().map(|_| ()),
            Stage::Apsp => self.run_apsp().map(|_| ()),
            Stage::Dbht => self.run_dbht().map(|_| ()),
            Stage::Cut => {
                let k = self.k.ok_or_else(|| {
                    TmfgError::invalid("Stage::Cut requires a k on the request")
                })?;
                self.run_cut(k).map(|_| ())
            }
        }
    }

    /// Run every remaining stage and return the owned output. Cuts at the
    /// request's `k` when one was set (or inferred from the dataset),
    /// re-cutting if the standing cut was made at a different `k`.
    pub fn finish(mut self) -> Result<ClusterOutput, TmfgError> {
        self.run_dbht()?;
        if let Some(k) = self.k {
            if self.cut_k != Some(k) {
                self.run_cut(k)?;
            }
        }
        let tmfg = self
            .tmfg
            .take()
            .ok_or_else(|| TmfgError::invariant("tmfg artifact missing"))?;
        let dbht = self
            .dbht
            .take()
            .ok_or_else(|| TmfgError::invariant("dbht artifact missing"))?;
        let edge_sum = tmfg.edge_sum(self.sim_store()?);
        let sparse = match self.spec.knn_config() {
            None => None,
            Some(cfg) => {
                let sp = self
                    .sparse
                    .as_deref()
                    .ok_or_else(|| TmfgError::invariant("sparse artifact missing"))?;
                Some(SparseReport {
                    k: cfg.k,
                    seed: cfg.seed,
                    dims: cfg.projection_dims,
                    pool: cfg.pool_factor,
                    iters: cfg.ann_iters,
                    nnz: sp.nnz(),
                    mean_degree: sp.mean_degree(),
                    fallbacks: self.sparse_fallbacks.unwrap_or(0),
                })
            }
        };
        let ari = match (&self.truth, &self.cut) {
            (Some(truth), Some(pred)) => Some(adjusted_rand_index(truth, pred)),
            _ => None,
        };
        let oracle = self
            .apsp
            .as_deref()
            .map(|o| o.kind())
            .ok_or_else(|| TmfgError::invariant("apsp artifact missing"))?;
        let resources = ResourceUsage {
            oracle_rows: self.apsp.as_deref().map(|o| o.rows_served()).unwrap_or(0),
            knn_fallbacks: self.sparse_fallbacks.unwrap_or(0) as u64,
            cache_bytes: self.cache_bytes,
        };
        let cache = self.cache_status();
        match cache {
            CacheStatus::Hit => {
                crate::obs::registry()
                    .counter(crate::obs::names::CACHE_HITS)
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                crate::obs::event("cache", || "hit".to_string());
            }
            CacheStatus::Miss => {
                crate::obs::registry()
                    .counter(crate::obs::names::CACHE_MISSES)
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                crate::obs::event("cache", || "miss".to_string());
            }
            // No counter — bypass is not a hit-ratio event — but traced
            // runs still see that the request skipped the cache.
            CacheStatus::Bypass => crate::obs::event("cache", || "bypass".to_string()),
        }
        Ok(ClusterOutput {
            algo: self.algo,
            apsp_mode: self.apsp_mode,
            breakdown: self.timings,
            tmfg,
            dbht,
            labels: self.cut,
            ari,
            edge_sum,
            oracle,
            corr_path: self.corr_path,
            cache,
            sparse,
            resources,
        })
    }
}
