//! The typed, staged public clustering API — the one way to run
//! TMFG-DBHT clustering.
//!
//! The paper's pipeline is a fixed stage chain (initial faces → sort →
//! vertex adding → APSP → DBHT); this module exposes it as:
//!
//! * [`ClusterRequest`] — a builder over the three input shapes (dataset
//!   by name, inline time-series panel, precomputed similarity matrix)
//!   plus every knob (`algo`, `apsp`, `linkage`, `hub`, `k`, and the
//!   [`SimilaritySpec`] — dense n×n or sparse k-NN candidates — ...);
//! * [`Plan`] — a staged executor where Similarity → Tmfg → Apsp → Dbht
//!   → Cut are individually runnable, memoized, and inspectable (per
//!   stage artifacts and wall-clock timings), so callers can reuse a
//!   TMFG across APSP modes or stop after construction;
//! * [`TmfgError`] — the unified, typed error replacing every
//!   library-path panic and stringly-typed result;
//! * [`cache`] — the cross-request [`ArtifactCache`]: a bounded LRU over
//!   Similarity→TMFG artifacts keyed by a stable content fingerprint, so
//!   repeated traffic on the same input skips the expensive stages;
//! * [`wire`] — the versioned request/response types of the TCP service.
//!
//! One-shot:
//!
//! ```no_run
//! use tmfg::api::{ClusterRequest, TmfgAlgo};
//!
//! let out = ClusterRequest::dataset("CBF")
//!     .scale(0.05)
//!     .algo(TmfgAlgo::Opt)
//!     .run()?;
//! println!("ARI = {:.3}", out.ari.unwrap_or(f64::NAN));
//! # Ok::<(), tmfg::api::TmfgError>(())
//! ```
//!
//! Staged, reusing one TMFG under both APSP modes:
//!
//! ```no_run
//! use tmfg::api::{ApspMode, ClusterRequest, TmfgAlgo};
//! use tmfg::data::synth::SynthSpec;
//!
//! let ds = SynthSpec::new("demo", 200, 64, 4).generate(42);
//! let mut plan = ClusterRequest::panel(ds.data)
//!     .algo(TmfgAlgo::Heap)
//!     .k(4)
//!     .build()?;
//! plan.run_tmfg()?; // built once
//! for mode in [ApspMode::Exact, ApspMode::Approx] {
//!     plan.set_apsp_mode(mode); // keeps the TMFG artifact
//!     let labels = plan.run_cut(4)?;
//!     println!("{mode:?}: {} labels", labels.len());
//! }
//! # Ok::<(), tmfg::api::TmfgError>(())
//! ```

pub mod cache;
pub mod plan;
pub mod request;
pub mod wire;

pub use crate::apsp::{ApspOracle, OracleKind};
pub use crate::error::TmfgError;
pub use cache::{ArtifactCache, CacheKey, CacheStatus};
pub use plan::{
    build_apsp_oracle, build_tmfg_for, ApspMode, ClusterOutput, Plan, ResourceUsage,
    SimilaritySpec, SparseReport, Stage, TmfgAlgo, APSP_AUTO_DENSE_MAX,
};
pub use request::ClusterRequest;
