//! Versioned wire types for the TCP clustering service.
//!
//! One JSON object per line in, one per line out. [`Request::decode`] is
//! the single validated parse path: every field is type-checked (no
//! silent `unwrap_or` defaulting of malformed values), numeric payloads
//! must be finite, unknown commands and algorithms are rejected, and an
//! optional `v` field pins the protocol version. Error responses carry a
//! human-readable `error` plus the stable machine-readable `code` from
//! [`TmfgError::code`].
//!
//! ## Binary frames (protocol v2)
//!
//! Protocol v2 adds a length-prefixed binary frame for batch clustering
//! requests whose panel would be prohibitively large as a JSON array:
//!
//! ```text
//! [ FRAME_MAGIC (4 bytes) ]
//! [ header_len: u32 LE    ]
//! [ payload_len: u64 LE   ]  // bytes, must be a multiple of 4
//! [ header: JSON object   ]  // the usual request fields, minus "data"
//! [ payload: f32 LE array ]  // row-major n×l panel
//! ```
//!
//! The header is the same JSON object a line request would carry, with
//! `"v": 2` required and the `data` array replaced by the payload
//! (named-dataset frames carry an empty payload). Responses are always
//! JSON lines, byte-identical to the JSON path for the same request.
//! Sparse (`sparse_k`) requests arriving in a binary frame get the
//! raised [`MAX_BINARY_SPARSE_SERIES`] cap; everything else keeps the
//! line-protocol caps. The connection layer decodes the payload
//! incrementally ([`crate::net::conn`]), so a multi-hundred-MB panel
//! never exists as a JSON text buffer.
//!
//! ## Observability fields
//!
//! * Every batch-clustering response carries a `trace_id` string —
//!   unique per request, echoed so clients can correlate responses with
//!   server-side traces and logs.
//! * A batch request may set `"trace": true` to have the server run it
//!   under a tracing session; the response then also carries a `trace`
//!   object: Chrome trace-event JSON (load it in Perfetto /
//!   `chrome://tracing`) with one track per worker thread. Traced
//!   requests serialize against each other on the session gate, so this
//!   is a debugging tool, not a production default.
//! * `{"cmd": "metrics"}` returns `{"ok": true, "metrics": "..."}` where
//!   `metrics` is the process-wide Prometheus text exposition
//!   (per-stage latency histograms, queue-wait histogram, pool/cache/
//!   oracle counters — see [`crate::obs::names`]). `{"cmd": "stats"}`
//!   additionally reports per-stage and queue-wait p50/p95/p99 under a
//!   `latency` object.

use crate::error::TmfgError;
use super::plan::{ApspMode, TmfgAlgo};
use crate::apsp::HubConfig;
use crate::util::json::Json;

/// Highest protocol version this build speaks. Requests may pin a
/// version with `{"v": 1, ...}`; omitting it means "current". v1 is the
/// JSON line protocol; v2 adds the binary request frame (see the module
/// docs) — JSON-line requests are unchanged under either version.
pub const PROTOCOL_VERSION: u64 = 2;

/// First bytes of a binary-framed request. Deliberately distinct from
/// `{` (every JSON line's first byte) so the connection layer can tell
/// frames from lines by peeking at the stream.
pub const FRAME_MAGIC: [u8; 4] = *b"TMFB";

/// Upper bound on a binary frame's JSON header (the non-payload request
/// fields; a well-formed header is a few hundred bytes).
pub const MAX_FRAME_HEADER_BYTES: usize = 1 << 20;

/// Upper bound on a binary frame's f32 payload in bytes. 512 MiB —
/// comfortably above the 192 MiB a 2^20 × 48 panel needs, while still
/// bounding what one connection can make the server buffer.
pub const MAX_FRAME_PAYLOAD_BYTES: u64 = 512 << 20;

/// Upper bound on batch series count for **sparse** requests arriving in
/// a binary frame: the full `synth-large` registry ceiling. Only the
/// binary frame raises the cap this far — the JSON line protocol keeps
/// [`MAX_SPARSE_BATCH_SERIES`] (a 2^20-series panel as a JSON array
/// would be gigabytes of text).
pub const MAX_BINARY_SPARSE_SERIES: usize = 1 << 20;

/// Upper bound on the `sparse_dims` random-projection dimension knob
/// (projection storage is O(n·d)).
pub const MAX_PROJECTION_DIMS: usize = 256;

/// Upper bound on the `sparse_pool` shortlist multiplier (the prefilter
/// re-scores pool·k candidates per vertex).
pub const MAX_POOL_FACTOR: usize = 64;

/// Upper bound on the `sparse_iters` ANN refinement-iteration knob
/// (each iteration is an O(n·pool·L) re-score sweep).
pub const MAX_ANN_ITERS: usize = 16;

/// Upper bound on `open_stream` series count. A stream session keeps an
/// n×n f64 cross-product matrix, so an unbounded `n` in one short
/// request line would trigger an O(n²) allocation on the dispatcher
/// thread; 4096 caps that state at ~128 MiB.
pub const MAX_STREAM_SERIES: usize = 4096;

/// Upper bound on the named-dataset `scale` factor (1.0 = paper sizes);
/// keeps a one-line request from demanding an arbitrarily large
/// synthetic dataset.
pub const MAX_DATASET_SCALE: f64 = 10.0;

/// Upper bound on the `open_stream` sliding-window length (ring buffers
/// are O(n·window)).
pub const MAX_STREAM_WINDOW: usize = 65_536;

/// Upper bound on batch series count (inline panels *and* resolved
/// named datasets) — the pipeline allocates O(n²) similarity/APSP
/// matrices on the dispatcher thread. Larger workloads go through the
/// CLI or the library API.
pub const MAX_BATCH_SERIES: usize = MAX_STREAM_SERIES;

/// Upper bound on batch series count for **sparse** requests
/// (`sparse_k` present): the similarity stage is O(n·k) memory instead
/// of O(n²), so the cap is 16× the dense one. The dense n×n APSP
/// distance matrix remains the footprint to budget for (~16 GiB at the
/// cap in f32) — run very large n with the approximate APSP mode and
/// adequate RAM.
pub const MAX_SPARSE_BATCH_SERIES: usize = 65_536;

/// Upper bound on the `sparse_k` neighbors-per-vertex knob (candidate
/// storage is O(n·k); 512 neighbors is already far past the quality
/// plateau).
pub const MAX_SPARSE_K: usize = 512;

/// Upper bound on the `hub_n` hub-count knob (and on `hub_q`): the hub
/// oracle keeps h exact rows resident, O(n·h) memory — 512 hubs at the
/// sparse batch cap is already 128 MiB of hub rows.
pub const MAX_HUBS: usize = 512;

/// Upper bound on the `hub_radius` ball multiplier; balls grow with the
/// radius, and a huge multiplier turns every ball into the whole graph.
pub const MAX_HUB_RADIUS: f64 = 64.0;

/// Upper bound on the `tenant` identity length. Tenants key admission
/// counters and per-tenant metrics series, so the id is kept short and
/// restricted to `[A-Za-z0-9._-]` (safe inside Prometheus label values
/// without escaping).
pub const MAX_TENANT_LEN: usize = 64;

/// A decoded wire request: the echoed `id`, the (validated) protocol
/// version, the optional `tenant` identity (admission control /
/// per-tenant metrics), and the typed command body.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: Json,
    pub v: u64,
    pub tenant: Option<String>,
    pub body: Command,
}

/// The service's command set.
#[derive(Debug, Clone)]
pub enum Command {
    Ping,
    Shutdown,
    /// Service observability: worker count, queue depth, cache hit
    /// ratio, cumulative per-stage timings, latency percentiles.
    Stats,
    /// The Prometheus text exposition of the process-global metrics
    /// registry, returned as the `metrics` string field.
    Metrics,
    /// Dump the flight recorder: the ring of wide events (one JSON
    /// object per recently completed request) plus ring counters.
    DebugDump,
    /// A batch clustering request (no `cmd` field).
    Cluster(ClusterSpec),
    OpenStream(StreamOpen),
    /// One observation per series.
    Tick(Vec<f32>),
    CloseStream,
}

/// Where a batch request's data comes from.
#[derive(Debug, Clone)]
pub enum ClusterSource {
    /// A registry dataset by name.
    Named { name: String, scale: f64, seed: u64 },
    /// An inline n×l panel, row-major.
    Inline { n: usize, l: usize, data: Vec<f32> },
}

#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub source: ClusterSource,
    /// None = service default algorithm.
    pub algo: Option<TmfgAlgo>,
    /// 0 = the dataset's own class count (named sources only).
    pub k: usize,
    /// Sparse k-NN mode: neighbors per vertex (None = dense pipeline).
    /// Raises the batch cap to [`MAX_SPARSE_BATCH_SERIES`]
    /// ([`MAX_BINARY_SPARSE_SERIES`] in a binary frame).
    pub sparse_k: Option<usize>,
    /// Seed of the sparse prefilter (requires `sparse_k`).
    pub sparse_seed: Option<u64>,
    /// Random-projection dimensions for the k-NN prefilter (requires
    /// `sparse_k`; None = the engine default).
    pub sparse_dims: Option<usize>,
    /// Shortlist multiplier for the k-NN prefilter (requires `sparse_k`;
    /// None = the engine default).
    pub sparse_pool: Option<usize>,
    /// ANN neighbor-of-neighbor refinement iterations (requires
    /// `sparse_k`; 0 disables refinement, None = the engine default).
    pub sparse_iters: Option<usize>,
    /// APSP mode override ("exact" | "approx" | "auto"; None = the
    /// algorithm's default).
    pub apsp: Option<ApspMode>,
    /// Hub-oracle overrides (None = [`HubConfig`] defaults): hub count
    /// (0 = auto ⌈√n⌉), ball-radius multiplier, nearest hubs per vertex.
    pub hub: Option<HubConfig>,
    /// Run under a tracing session and attach the Chrome trace-event
    /// JSON to the response (`trace` field). See the module docs.
    pub trace: bool,
}

#[derive(Debug, Clone)]
pub struct StreamOpen {
    pub n: usize,
    pub window: usize,
    pub k: usize,
    pub algo: Option<TmfgAlgo>,
    pub drift: Option<f32>,
    pub warmup: Option<usize>,
    pub max_refreshes: Option<u32>,
}

// ---- typed field extraction ------------------------------------------------

fn opt_usize(j: &Json, key: &str) -> Result<Option<usize>, TmfgError> {
    match j.get(key) {
        Json::Null => Ok(None),
        v => match v.as_usize() {
            Some(x) => Ok(Some(x)),
            None => Err(TmfgError::protocol(format!(
                "field '{key}' must be a non-negative integer"
            ))),
        },
    }
}

fn opt_finite_f64(j: &Json, key: &str) -> Result<Option<f64>, TmfgError> {
    match j.get(key) {
        Json::Null => Ok(None),
        v => match v.as_f64() {
            Some(x) if x.is_finite() => Ok(Some(x)),
            _ => Err(TmfgError::protocol(format!(
                "field '{key}' must be a finite number"
            ))),
        },
    }
}

fn opt_algo(j: &Json) -> Result<Option<TmfgAlgo>, TmfgError> {
    match j.get("algo") {
        Json::Null => Ok(None),
        v => {
            let s = v
                .as_str()
                .ok_or_else(|| TmfgError::protocol("field 'algo' must be a string"))?;
            match TmfgAlgo::parse(s) {
                Some(a) => Ok(Some(a)),
                None => Err(TmfgError::protocol(format!("unknown algo '{s}'"))),
            }
        }
    }
}

/// A finite f64 that stays finite as an f32 (payloads are stored f32;
/// e.g. 1e300 is a finite f64 but casts to infinity).
fn opt_finite_f32(j: &Json, key: &str) -> Result<Option<f32>, TmfgError> {
    match opt_finite_f64(j, key)? {
        None => Ok(None),
        Some(x) => {
            let f = x as f32;
            if f.is_finite() {
                Ok(Some(f))
            } else {
                Err(TmfgError::protocol(format!(
                    "field '{key}' is non-finite in f32 (got {x})"
                )))
            }
        }
    }
}

/// `data` as finite f32s; rejects missing/non-array fields and any
/// element that is non-numeric or non-finite (before or after the f32
/// conversion).
fn finite_data(j: &Json, key: &str) -> Result<Vec<f32>, TmfgError> {
    let arr = j.get(key).as_arr().ok_or_else(|| {
        TmfgError::protocol(format!("field '{key}' must be an array of numbers"))
    })?;
    let mut out = Vec::with_capacity(arr.len());
    for (i, v) in arr.iter().enumerate() {
        match v.as_f64() {
            Some(x) if x.is_finite() && (x as f32).is_finite() => out.push(x as f32),
            _ => {
                return Err(TmfgError::protocol(format!(
                    "non-finite or non-numeric value at {key}[{i}]"
                )))
            }
        }
    }
    Ok(out)
}

// ---- decode ---------------------------------------------------------------

impl Request {
    /// The single validated parse path from a JSON line to a typed
    /// request.
    pub fn decode(j: &Json) -> Result<Request, TmfgError> {
        Self::decode_inner(j, None)
    }

    /// Decode a binary-framed request: the frame's JSON header plus its
    /// decoded f32 payload. Frames require `"v": 2`, carry only batch
    /// clustering requests (no `cmd`), and supply the panel through the
    /// payload instead of a `data` array (named-dataset frames carry an
    /// empty payload). Sparse framed requests get the raised
    /// [`MAX_BINARY_SPARSE_SERIES`] cap.
    pub fn decode_frame(j: &Json, payload: Vec<f32>) -> Result<Request, TmfgError> {
        if let Some(pos) = payload.iter().position(|v| !v.is_finite()) {
            return Err(TmfgError::protocol(format!(
                "non-finite value in frame payload at index {pos}"
            )));
        }
        Self::decode_inner(j, Some(payload))
    }

    fn decode_inner(j: &Json, payload: Option<Vec<f32>>) -> Result<Request, TmfgError> {
        let id = j.get("id").clone();
        let framed = payload.is_some();
        let v = opt_usize(j, "v")?.map(|x| x as u64).unwrap_or(PROTOCOL_VERSION);
        if v < 1 || v > PROTOCOL_VERSION {
            return Err(TmfgError::protocol(format!(
                "unsupported protocol version {v} (supported: 1..={PROTOCOL_VERSION})"
            )));
        }
        if framed && v < 2 {
            return Err(TmfgError::protocol(format!(
                "binary frames require protocol v >= 2, got {v}"
            )));
        }
        let tenant = decode_tenant(j)?;
        let body = match j.get("cmd") {
            Json::Null => Command::Cluster(decode_cluster(j, payload)?),
            _ if framed => {
                return Err(TmfgError::protocol(
                    "binary frames carry batch clustering requests only (no 'cmd')",
                ))
            }
            cmd => {
                let name = cmd
                    .as_str()
                    .ok_or_else(|| TmfgError::protocol("field 'cmd' must be a string"))?;
                match name {
                    "ping" => Command::Ping,
                    "shutdown" => Command::Shutdown,
                    "stats" => Command::Stats,
                    "metrics" => Command::Metrics,
                    "debug_dump" => Command::DebugDump,
                    "open_stream" => Command::OpenStream(decode_open_stream(j)?),
                    "tick" => Command::Tick(finite_data(j, "data")?),
                    "close_stream" => Command::CloseStream,
                    other => {
                        return Err(TmfgError::protocol(format!("unknown cmd '{other}'")))
                    }
                }
            }
        };
        Ok(Request { id, v, tenant, body })
    }
}

/// Optional `tenant` identity: a short `[A-Za-z0-9._-]` string. The
/// charset keeps tenant ids safe as Prometheus label values and as keys
/// of the per-tenant admission counters; absent means anonymous (exempt
/// from tenant quotas).
fn decode_tenant(j: &Json) -> Result<Option<String>, TmfgError> {
    match j.get("tenant") {
        Json::Null => Ok(None),
        v => {
            let s = v
                .as_str()
                .ok_or_else(|| TmfgError::protocol("field 'tenant' must be a string"))?;
            if s.is_empty() || s.len() > MAX_TENANT_LEN {
                return Err(TmfgError::protocol(format!(
                    "tenant must be 1..={MAX_TENANT_LEN} bytes, got {}",
                    s.len()
                )));
            }
            if !s
                .bytes()
                .all(|b| b.is_ascii_alphanumeric() || matches!(b, b'-' | b'_' | b'.'))
            {
                return Err(TmfgError::protocol(
                    "tenant must match [A-Za-z0-9._-]+".to_string(),
                ));
            }
            Ok(Some(s.to_string()))
        }
    }
}

fn decode_cluster(j: &Json, payload: Option<Vec<f32>>) -> Result<ClusterSpec, TmfgError> {
    let framed = payload.is_some();
    let algo = opt_algo(j)?;
    let k = opt_usize(j, "k")?.unwrap_or(0);
    let trace = match j.get("trace") {
        Json::Null => false,
        Json::Bool(b) => *b,
        _ => return Err(TmfgError::protocol("field 'trace' must be a boolean")),
    };
    // Sparse mode is opted into with sparse_k; it carries its own
    // resource caps (candidate storage is O(n·k), not O(n²)).
    let sparse_k = match opt_usize(j, "sparse_k")? {
        Some(0) => return Err(TmfgError::protocol("sparse_k must be >= 1")),
        Some(sk) if sk > MAX_SPARSE_K => {
            return Err(TmfgError::protocol(format!(
                "sparse_k must be <= {MAX_SPARSE_K}, got {sk}"
            )))
        }
        sk => sk,
    };
    let sparse_seed = opt_usize(j, "sparse_seed")?.map(|s| s as u64);
    if sparse_seed.is_some() && sparse_k.is_none() {
        return Err(TmfgError::protocol("sparse_seed requires sparse_k"));
    }
    // The remaining k-NN knobs: projection dims, shortlist multiplier,
    // ANN refinement iterations. Each is resource-capped and only
    // meaningful in sparse mode.
    let sparse_dims = match opt_usize(j, "sparse_dims")? {
        Some(0) => return Err(TmfgError::protocol("sparse_dims must be >= 1")),
        Some(d) if d > MAX_PROJECTION_DIMS => {
            return Err(TmfgError::protocol(format!(
                "sparse_dims must be <= {MAX_PROJECTION_DIMS}, got {d}"
            )))
        }
        d => d,
    };
    let sparse_pool = match opt_usize(j, "sparse_pool")? {
        Some(0) => return Err(TmfgError::protocol("sparse_pool must be >= 1")),
        Some(p) if p > MAX_POOL_FACTOR => {
            return Err(TmfgError::protocol(format!(
                "sparse_pool must be <= {MAX_POOL_FACTOR}, got {p}"
            )))
        }
        p => p,
    };
    // 0 is meaningful (refinement off), so only the upper bound binds.
    let sparse_iters = match opt_usize(j, "sparse_iters")? {
        Some(it) if it > MAX_ANN_ITERS => {
            return Err(TmfgError::protocol(format!(
                "sparse_iters must be <= {MAX_ANN_ITERS}, got {it}"
            )))
        }
        it => it,
    };
    if sparse_k.is_none()
        && (sparse_dims.is_some() || sparse_pool.is_some() || sparse_iters.is_some())
    {
        return Err(TmfgError::protocol(
            "sparse_dims/sparse_pool/sparse_iters require sparse_k",
        ));
    }
    let apsp = match j.get("apsp") {
        Json::Null => None,
        v => {
            let s = v
                .as_str()
                .ok_or_else(|| TmfgError::protocol("field 'apsp' must be a string"))?;
            Some(ApspMode::parse(s).ok_or_else(|| {
                TmfgError::protocol(format!(
                    "unknown apsp mode '{s}' (expected exact|approx|auto)"
                ))
            })?)
        }
    };
    // Hub-oracle knobs; each is resource-capped like sparse_k (hub rows
    // are O(n·hub_n) resident memory on the worker).
    let hub_n = match opt_usize(j, "hub_n")? {
        Some(h) if h > MAX_HUBS => {
            return Err(TmfgError::protocol(format!(
                "hub_n must be <= {MAX_HUBS}, got {h}"
            )))
        }
        h => h,
    };
    let hub_q = match opt_usize(j, "hub_q")? {
        Some(0) => return Err(TmfgError::protocol("hub_q must be >= 1")),
        Some(q) if q > MAX_HUBS => {
            return Err(TmfgError::protocol(format!(
                "hub_q must be <= {MAX_HUBS}, got {q}"
            )))
        }
        q => q,
    };
    let hub_radius = match opt_finite_f64(j, "hub_radius")? {
        Some(r) if !(0.0..=MAX_HUB_RADIUS).contains(&r) => {
            return Err(TmfgError::protocol(format!(
                "hub_radius must be in 0..={MAX_HUB_RADIUS}, got {r}"
            )))
        }
        r => r,
    };
    let hub = if hub_n.is_some() || hub_q.is_some() || hub_radius.is_some() {
        let mut cfg = HubConfig::default();
        if let Some(h) = hub_n {
            cfg.n_hubs = h;
        }
        if let Some(r) = hub_radius {
            cfg.radius_mult = r as f32;
        }
        if let Some(q) = hub_q {
            cfg.hubs_per_vertex = q;
        }
        Some(cfg)
    } else {
        None
    };
    // The binary frame raises the sparse cap to the registry ceiling;
    // the JSON line protocol keeps the text-sized caps.
    let max_series = match (sparse_k.is_some(), framed) {
        (true, true) => MAX_BINARY_SPARSE_SERIES,
        (true, false) => MAX_SPARSE_BATCH_SERIES,
        (false, _) => MAX_BATCH_SERIES,
    };
    let source = match j.get("dataset") {
        Json::Null => {
            let n = opt_usize(j, "n")?
                .ok_or_else(|| TmfgError::protocol("missing n (or dataset name)"))?;
            if n > max_series {
                return Err(TmfgError::protocol(format!(
                    "n must be <= {max_series} for inline data \
                     ({MAX_SPARSE_BATCH_SERIES} with sparse_k, \
                     {MAX_BINARY_SPARSE_SERIES} with sparse_k in a binary \
                     frame), got {n}"
                )));
            }
            let l = opt_usize(j, "l")?.ok_or_else(|| TmfgError::protocol("missing l"))?;
            let data = match payload {
                Some(p) => {
                    if !matches!(j.get("data"), Json::Null) {
                        return Err(TmfgError::protocol(
                            "binary-framed requests carry the panel in the \
                             frame payload, not a 'data' field",
                        ));
                    }
                    p
                }
                None => finite_data(j, "data")?,
            };
            // checked: a huge n must not wrap n*l past the length check
            // (in release the wrapped product could match data.len() and
            // reach allocation with absurd dimensions).
            let expected = n.checked_mul(l).ok_or_else(|| {
                TmfgError::protocol(format!("n*l overflows: n={n}, l={l}"))
            })?;
            if data.len() != expected {
                return Err(TmfgError::protocol(format!(
                    "data length {} != n*l = {expected}",
                    data.len(),
                )));
            }
            if k == 0 {
                return Err(TmfgError::protocol("inline data requires k"));
            }
            ClusterSource::Inline { n, l, data }
        }
        v => {
            if payload.as_ref().is_some_and(|p| !p.is_empty()) {
                return Err(TmfgError::protocol(
                    "named-dataset frames must carry an empty payload",
                ));
            }
            let name = v
                .as_str()
                .ok_or_else(|| TmfgError::protocol("field 'dataset' must be a string"))?;
            // Registry names only. The registry also resolves '/'-ish
            // names and '.csv' suffixes as filesystem paths — a remote
            // client must not be able to make the server read arbitrary
            // local files.
            if name.contains('/') || name.contains('\\') || name.ends_with(".csv") {
                return Err(TmfgError::protocol(format!(
                    "dataset must be a registry name, not a file path: '{name}'"
                )));
            }
            let scale = opt_finite_f64(j, "scale")?.unwrap_or(0.05);
            if !(0.0..=MAX_DATASET_SCALE).contains(&scale) {
                return Err(TmfgError::protocol(format!(
                    "scale must be in 0..={MAX_DATASET_SCALE}, got {scale}"
                )));
            }
            // Resolve the would-be series count without generating the
            // dataset: 'demo-N' encodes n in the name and big registry
            // datasets at large scales can exceed the service's O(n²)
            // budget even under the scale cap. Unknown names fall through
            // to a dataset_not_found error at run time.
            if let Some(n) = crate::coordinator::registry::dataset_size(name, scale) {
                if n > max_series {
                    return Err(TmfgError::protocol(format!(
                        "dataset '{name}' resolves to n={n} > {max_series}; \
                         reduce scale, request sparse mode (sparse_k, cap \
                         {MAX_SPARSE_BATCH_SERIES}; {MAX_BINARY_SPARSE_SERIES} \
                         via a binary frame), or use the CLI/library"
                    )));
                }
            }
            ClusterSource::Named {
                name: name.to_string(),
                scale,
                seed: opt_usize(j, "seed")?.unwrap_or(1) as u64,
            }
        }
    };
    Ok(ClusterSpec {
        source,
        algo,
        k,
        sparse_k,
        sparse_seed,
        sparse_dims,
        sparse_pool,
        sparse_iters,
        apsp,
        hub,
        trace,
    })
}

fn decode_open_stream(j: &Json) -> Result<StreamOpen, TmfgError> {
    let n = opt_usize(j, "n")?
        .ok_or_else(|| TmfgError::protocol("open_stream requires n (number of series)"))?;
    // Session state is O(n²); reject absurd n at the protocol boundary
    // before any allocation happens on the dispatcher thread.
    if n > MAX_STREAM_SERIES {
        return Err(TmfgError::protocol(format!(
            "n must be <= {MAX_STREAM_SERIES} for streaming, got {n}"
        )));
    }
    let window = opt_usize(j, "window")?.unwrap_or(64);
    if window > MAX_STREAM_WINDOW {
        return Err(TmfgError::protocol(format!(
            "window must be <= {MAX_STREAM_WINDOW}, got {window}"
        )));
    }
    Ok(StreamOpen {
        n,
        window,
        k: opt_usize(j, "k")?.unwrap_or(2),
        algo: opt_algo(j)?,
        drift: opt_finite_f32(j, "drift")?,
        warmup: opt_usize(j, "warmup")?,
        max_refreshes: match opt_usize(j, "max_refreshes")? {
            // checked: wrapping to u32 could flip the policy (0 means
            // "unlimited refreshes", the opposite of a cadence cap)
            Some(m) if m > u32::MAX as usize => {
                return Err(TmfgError::protocol(format!(
                    "max_refreshes must be <= {}, got {m}",
                    u32::MAX
                )))
            }
            m => m.map(|m| m as u32),
        },
    })
}

// ---- encode ---------------------------------------------------------------

/// Encode a binary request frame: magic, u32 LE header length, u64 LE
/// payload byte length, the JSON header, then the f32 LE payload. The
/// caller is responsible for putting `"v": 2` in the header (decode
/// rejects framed requests pinned below v2).
pub fn encode_frame(header: &Json, payload: &[f32]) -> Vec<u8> {
    let h = header.to_string();
    let mut out = Vec::with_capacity(16 + h.len() + payload.len() * 4);
    out.extend_from_slice(&FRAME_MAGIC);
    out.extend_from_slice(&(h.len() as u32).to_le_bytes());
    out.extend_from_slice(&((payload.len() as u64 * 4).to_le_bytes()));
    out.extend_from_slice(h.as_bytes());
    for v in payload {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// An `{"ok": true}` response echoing the request id, plus extra fields.
pub fn ok_response(id: &Json, fields: Vec<(&str, Json)>) -> Json {
    let mut pairs = vec![("id", id.clone()), ("ok", Json::Bool(true))];
    pairs.extend(fields);
    Json::obj(pairs)
}

/// An `{"ok": false}` response with the human-readable message and the
/// stable machine code.
pub fn error_response(id: &Json, err: &TmfgError) -> Json {
    Json::obj(vec![
        ("id", id.clone()),
        ("ok", Json::Bool(false)),
        ("error", Json::str(&err.to_string())),
        ("code", Json::str(err.code())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Json {
        Json::parse(s).unwrap()
    }

    #[test]
    fn decodes_named_cluster_request() {
        let r = Request::decode(&parse(
            r#"{"id": 7, "dataset": "CBF", "scale": 0.1, "seed": 3, "algo": "heap", "k": 2}"#,
        ))
        .unwrap();
        assert_eq!(r.v, PROTOCOL_VERSION);
        let Command::Cluster(spec) = r.body else { panic!("not a cluster") };
        assert_eq!(spec.k, 2);
        assert_eq!(spec.algo, Some(TmfgAlgo::Heap));
        let ClusterSource::Named { name, scale, seed } = spec.source else {
            panic!("not named")
        };
        assert_eq!(name, "CBF");
        assert_eq!(scale, 0.1);
        assert_eq!(seed, 3);
    }

    #[test]
    fn decodes_inline_cluster_request() {
        let r = Request::decode(&parse(
            r#"{"n": 2, "l": 2, "data": [1, 2, 3, 4], "k": 1}"#,
        ))
        .unwrap();
        let Command::Cluster(spec) = r.body else { panic!() };
        let ClusterSource::Inline { n, l, data } = spec.source else { panic!() };
        assert_eq!((n, l), (2, 2));
        assert_eq!(data, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn rejects_non_numeric_k() {
        let e = Request::decode(&parse(r#"{"dataset": "CBF", "k": "three"}"#)).unwrap_err();
        assert_eq!(e.code(), "protocol");
        assert!(e.to_string().contains("'k'"), "{e}");
    }

    #[test]
    fn rejects_wrong_data_length() {
        let e = Request::decode(&parse(r#"{"n": 2, "l": 3, "data": [1, 2], "k": 1}"#))
            .unwrap_err();
        assert!(e.to_string().contains("data length"), "{e}");
    }

    #[test]
    fn rejects_overflowing_n_times_l() {
        // A huge l would wrap n*l in release and could sneak a payload
        // past the length check (n itself is bounded by the inline cap,
        // so l is the only remaining overflow driver).
        let line = format!(
            r#"{{"n": 4096, "l": {}, "data": [], "k": 1}}"#,
            1u64 << 61
        );
        let e = Request::decode(&parse(&line)).unwrap_err();
        assert_eq!(e.code(), "protocol");
        assert!(e.to_string().contains("overflow"), "{e}");
    }

    #[test]
    fn rejects_non_finite_data() {
        // 1e999 overflows f64 parsing to infinity.
        let e = Request::decode(&parse(r#"{"n": 1, "l": 2, "data": [1, 1e999], "k": 1}"#))
            .unwrap_err();
        assert!(e.to_string().contains("non-finite"), "{e}");
        let e2 = Request::decode(&parse(
            r#"{"cmd": "tick", "data": [null, 1.0]}"#,
        ))
        .unwrap_err();
        assert!(e2.to_string().contains("non-finite"), "{e2}");
    }

    #[test]
    fn rejects_unknown_cmd_and_algo() {
        let e = Request::decode(&parse(r#"{"cmd": "bogus"}"#)).unwrap_err();
        assert!(e.to_string().contains("unknown cmd"), "{e}");
        let e2 = Request::decode(&parse(r#"{"dataset": "CBF", "algo": "quantum"}"#))
            .unwrap_err();
        assert!(e2.to_string().contains("unknown algo"), "{e2}");
    }

    #[test]
    fn decodes_stats_command() {
        let r = Request::decode(&parse(r#"{"id": 9, "cmd": "stats"}"#)).unwrap();
        assert!(matches!(r.body, Command::Stats));
        assert_eq!(r.id.as_usize(), Some(9));
    }

    #[test]
    fn decodes_metrics_command() {
        let r = Request::decode(&parse(r#"{"id": 2, "cmd": "metrics"}"#)).unwrap();
        assert!(matches!(r.body, Command::Metrics));
        assert_eq!(r.id.as_usize(), Some(2));
    }

    #[test]
    fn decodes_debug_dump_command() {
        let r = Request::decode(&parse(r#"{"id": 3, "cmd": "debug_dump"}"#)).unwrap();
        assert!(matches!(r.body, Command::DebugDump));
        assert_eq!(r.id.as_usize(), Some(3));
    }

    #[test]
    fn trace_flag_decodes_and_validates() {
        let r = Request::decode(&parse(r#"{"dataset": "CBF", "trace": true}"#)).unwrap();
        let Command::Cluster(spec) = r.body else { panic!() };
        assert!(spec.trace);
        // absent defaults to false
        let r = Request::decode(&parse(r#"{"dataset": "CBF"}"#)).unwrap();
        let Command::Cluster(spec) = r.body else { panic!() };
        assert!(!spec.trace);
        // non-boolean rejected
        let e = Request::decode(&parse(r#"{"dataset": "CBF", "trace": 1}"#)).unwrap_err();
        assert_eq!(e.code(), "protocol");
        assert!(e.to_string().contains("trace"), "{e}");
    }

    #[test]
    fn rejects_unsupported_version_accepts_current() {
        let e = Request::decode(&parse(r#"{"v": 99, "cmd": "ping"}"#)).unwrap_err();
        assert!(e.to_string().contains("version"), "{e}");
        let r = Request::decode(&parse(r#"{"v": 1, "cmd": "ping"}"#)).unwrap();
        assert!(matches!(r.body, Command::Ping));
    }

    #[test]
    fn inline_requires_k() {
        let e = Request::decode(&parse(r#"{"n": 2, "l": 2, "data": [1, 2, 3, 4]}"#))
            .unwrap_err();
        assert!(e.to_string().contains("requires k"), "{e}");
    }

    #[test]
    fn open_stream_decode_and_validation() {
        let r = Request::decode(&parse(
            r#"{"cmd": "open_stream", "n": 8, "window": 16, "k": 2, "drift": 0.2}"#,
        ))
        .unwrap();
        let Command::OpenStream(o) = r.body else { panic!() };
        assert_eq!((o.n, o.window, o.k), (8, 16, 2));
        assert_eq!(o.drift, Some(0.2));
        assert!(Request::decode(&parse(r#"{"cmd": "open_stream"}"#)).is_err());
    }

    #[test]
    fn file_path_dataset_names_rejected() {
        for name in ["/data/huge.csv", "../secrets.csv", "foo/bar", "x.csv", r"a\b"] {
            let line = format!(r#"{{"dataset": "{}"}}"#, name.replace('\\', "\\\\"));
            let e = Request::decode(&parse(&line)).unwrap_err();
            assert_eq!(e.code(), "protocol", "{name}");
            assert!(e.to_string().contains("registry name"), "{name}: {e}");
        }
        // plain registry names still pass
        assert!(Request::decode(&parse(r#"{"dataset": "CBF"}"#)).is_ok());
    }

    #[test]
    fn max_refreshes_overflow_rejected() {
        let e = Request::decode(&parse(
            r#"{"cmd": "open_stream", "n": 8, "max_refreshes": 4294967296}"#,
        ))
        .unwrap_err();
        assert_eq!(e.code(), "protocol");
        assert!(e.to_string().contains("max_refreshes"), "{e}");
    }

    #[test]
    fn resource_limits_rejected_at_decode() {
        // open_stream n is capped: session state is O(n²)
        let e = Request::decode(&parse(
            r#"{"cmd": "open_stream", "n": 100000000}"#,
        ))
        .unwrap_err();
        assert_eq!(e.code(), "protocol");
        let e = Request::decode(&parse(
            r#"{"cmd": "open_stream", "n": 8, "window": 10000000}"#,
        ))
        .unwrap_err();
        assert_eq!(e.code(), "protocol");
        // dataset scale is capped
        let e = Request::decode(&parse(r#"{"dataset": "CBF", "scale": 1000000.0}"#))
            .unwrap_err();
        assert!(e.to_string().contains("scale"), "{e}");
        // inline batch n is capped like the stream path (O(n²) pipeline
        // allocations on the dispatcher)
        let e = Request::decode(&parse(
            r#"{"n": 30000, "l": 2, "data": [], "k": 2}"#,
        ))
        .unwrap_err();
        assert_eq!(e.code(), "protocol");
        assert!(e.to_string().contains("inline"), "{e}");
    }

    #[test]
    fn f32_overflowing_values_rejected() {
        // 1e300 is a finite f64 but infinity as f32 — both the stream
        // drift knob and data payloads must reject it.
        let e = Request::decode(&parse(
            r#"{"cmd": "open_stream", "n": 8, "drift": 1e300}"#,
        ))
        .unwrap_err();
        assert_eq!(e.code(), "protocol");
        let e = Request::decode(&parse(
            r#"{"n": 4, "l": 1, "data": [1e300, 1, 2, 3], "k": 2}"#,
        ))
        .unwrap_err();
        assert!(e.to_string().contains("non-finite"), "{e}");
    }

    #[test]
    fn sparse_fields_decode() {
        let r = Request::decode(&parse(
            r#"{"dataset": "CBF", "sparse_k": 32, "sparse_seed": 7, "k": 3}"#,
        ))
        .unwrap();
        let Command::Cluster(spec) = r.body else { panic!() };
        assert_eq!(spec.sparse_k, Some(32));
        assert_eq!(spec.sparse_seed, Some(7));
        // absent means dense
        let r = Request::decode(&parse(r#"{"dataset": "CBF"}"#)).unwrap();
        let Command::Cluster(spec) = r.body else { panic!() };
        assert_eq!(spec.sparse_k, None);
        assert_eq!(spec.sparse_seed, None);
    }

    #[test]
    fn sparse_field_validation() {
        for line in [
            r#"{"dataset": "CBF", "sparse_k": 0}"#,
            r#"{"dataset": "CBF", "sparse_k": 100000}"#,
            r#"{"dataset": "CBF", "sparse_seed": 1}"#,
            r#"{"dataset": "CBF", "sparse_k": "many"}"#,
        ] {
            let e = Request::decode(&parse(line)).unwrap_err();
            assert_eq!(e.code(), "protocol", "{line}");
            assert!(e.to_string().contains("sparse"), "{line}: {e}");
        }
    }

    #[test]
    fn sparse_mode_raises_batch_cap() {
        // demo-16384 resolves past the dense cap but inside the sparse one
        let dense = Request::decode(&parse(r#"{"dataset": "demo-16384"}"#)).unwrap_err();
        assert_eq!(dense.code(), "protocol");
        assert!(dense.to_string().contains("sparse"), "{dense}");
        assert!(Request::decode(&parse(
            r#"{"dataset": "demo-16384", "sparse_k": 32}"#
        ))
        .is_ok());
        // and the sparse cap itself still binds
        let huge = Request::decode(&parse(
            r#"{"dataset": "demo-100000", "sparse_k": 32}"#,
        ))
        .unwrap_err();
        assert_eq!(huge.code(), "protocol");
    }

    #[test]
    fn apsp_and_hub_fields_decode() {
        let r = Request::decode(&parse(
            r#"{"dataset": "CBF", "apsp": "auto", "hub_n": 32, "hub_radius": 1.5, "hub_q": 8}"#,
        ))
        .unwrap();
        let Command::Cluster(spec) = r.body else { panic!() };
        assert_eq!(spec.apsp, Some(ApspMode::Auto));
        let hub = spec.hub.expect("hub config");
        assert_eq!(hub.n_hubs, 32);
        assert_eq!(hub.hubs_per_vertex, 8);
        assert!((hub.radius_mult - 1.5).abs() < 1e-6);
        // absent fields mean "no override"
        let r = Request::decode(&parse(r#"{"dataset": "CBF"}"#)).unwrap();
        let Command::Cluster(spec) = r.body else { panic!() };
        assert_eq!(spec.apsp, None);
        assert!(spec.hub.is_none());
        // partial hub overrides keep the other defaults
        let r = Request::decode(&parse(r#"{"dataset": "CBF", "hub_n": 16}"#)).unwrap();
        let Command::Cluster(spec) = r.body else { panic!() };
        let hub = spec.hub.expect("hub config");
        assert_eq!(hub.n_hubs, 16);
        assert_eq!(hub.hubs_per_vertex, HubConfig::default().hubs_per_vertex);
    }

    #[test]
    fn apsp_and_hub_field_validation() {
        for line in [
            r#"{"dataset": "CBF", "apsp": "quantum"}"#,
            r#"{"dataset": "CBF", "apsp": 3}"#,
            r#"{"dataset": "CBF", "hub_n": 100000}"#,
            r#"{"dataset": "CBF", "hub_q": 0}"#,
            r#"{"dataset": "CBF", "hub_q": 100000}"#,
            r#"{"dataset": "CBF", "hub_radius": -1.0}"#,
            r#"{"dataset": "CBF", "hub_radius": 1e9}"#,
            r#"{"dataset": "CBF", "hub_radius": 1e999}"#,
        ] {
            let e = Request::decode(&parse(line)).unwrap_err();
            assert_eq!(e.code(), "protocol", "{line}");
        }
    }

    #[test]
    fn tenant_field_decodes_and_validates() {
        let r = Request::decode(&parse(r#"{"cmd": "ping", "tenant": "acme-1.prod"}"#)).unwrap();
        assert_eq!(r.tenant.as_deref(), Some("acme-1.prod"));
        // absent means anonymous
        let r = Request::decode(&parse(r#"{"cmd": "ping"}"#)).unwrap();
        assert_eq!(r.tenant, None);
        for line in [
            r#"{"cmd": "ping", "tenant": 7}"#,
            r#"{"cmd": "ping", "tenant": ""}"#,
            r#"{"cmd": "ping", "tenant": "has space"}"#,
            r#"{"cmd": "ping", "tenant": "semi;colon"}"#,
            r#"{"cmd": "ping", "tenant": "quo\"te"}"#,
        ] {
            let e = Request::decode(&parse(line)).unwrap_err();
            assert_eq!(e.code(), "protocol", "{line}");
            assert!(e.to_string().contains("tenant"), "{line}: {e}");
        }
        // length cap
        let long = "a".repeat(MAX_TENANT_LEN + 1);
        let e = Request::decode(&parse(&format!(r#"{{"cmd": "ping", "tenant": "{long}"}}"#)))
            .unwrap_err();
        assert_eq!(e.code(), "protocol");
    }

    #[test]
    fn knob_fields_decode_and_validate() {
        let r = Request::decode(&parse(
            r#"{"dataset": "CBF", "sparse_k": 16, "sparse_dims": 24,
                "sparse_pool": 8, "sparse_iters": 3}"#,
        ))
        .unwrap();
        let Command::Cluster(spec) = r.body else { panic!() };
        assert_eq!(spec.sparse_dims, Some(24));
        assert_eq!(spec.sparse_pool, Some(8));
        assert_eq!(spec.sparse_iters, Some(3));
        // iters = 0 is a valid "refinement off" setting
        let r = Request::decode(&parse(
            r#"{"dataset": "CBF", "sparse_k": 16, "sparse_iters": 0}"#,
        ))
        .unwrap();
        let Command::Cluster(spec) = r.body else { panic!() };
        assert_eq!(spec.sparse_iters, Some(0));
        for line in [
            r#"{"dataset": "CBF", "sparse_k": 16, "sparse_dims": 0}"#,
            r#"{"dataset": "CBF", "sparse_k": 16, "sparse_dims": 100000}"#,
            r#"{"dataset": "CBF", "sparse_k": 16, "sparse_pool": 0}"#,
            r#"{"dataset": "CBF", "sparse_k": 16, "sparse_pool": 100000}"#,
            r#"{"dataset": "CBF", "sparse_k": 16, "sparse_iters": 100000}"#,
            r#"{"dataset": "CBF", "sparse_dims": 16}"#,
            r#"{"dataset": "CBF", "sparse_pool": 4}"#,
            r#"{"dataset": "CBF", "sparse_iters": 2}"#,
        ] {
            let e = Request::decode(&parse(line)).unwrap_err();
            assert_eq!(e.code(), "protocol", "{line}");
            assert!(e.to_string().contains("sparse"), "{line}: {e}");
        }
    }

    #[test]
    fn frame_decode_inline_payload() {
        let hdr = parse(r#"{"id": 4, "v": 2, "n": 2, "l": 2, "k": 1}"#);
        let r = Request::decode_frame(&hdr, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(r.v, 2);
        let Command::Cluster(spec) = r.body else { panic!() };
        let ClusterSource::Inline { n, l, data } = spec.source else { panic!() };
        assert_eq!((n, l), (2, 2));
        assert_eq!(data, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn frame_decode_named_empty_payload() {
        let hdr = parse(r#"{"v": 2, "dataset": "CBF", "sparse_k": 8}"#);
        let r = Request::decode_frame(&hdr, vec![]).unwrap();
        let Command::Cluster(spec) = r.body else { panic!() };
        assert!(matches!(spec.source, ClusterSource::Named { .. }));
        // a named frame with a non-empty payload is malformed
        let e = Request::decode_frame(&hdr, vec![1.0]).unwrap_err();
        assert_eq!(e.code(), "protocol");
        assert!(e.to_string().contains("empty payload"), "{e}");
    }

    #[test]
    fn frame_requires_v2_and_cluster_body() {
        let hdr = parse(r#"{"v": 1, "n": 2, "l": 2, "k": 1}"#);
        let e = Request::decode_frame(&hdr, vec![0.0; 4]).unwrap_err();
        assert_eq!(e.code(), "protocol");
        assert!(e.to_string().contains("v >= 2"), "{e}");
        // omitting v is fine: it defaults to the current version (2)
        let hdr = parse(r#"{"n": 2, "l": 2, "k": 1}"#);
        assert!(Request::decode_frame(&hdr, vec![0.0; 4]).is_ok());
        let e = Request::decode_frame(&parse(r#"{"v": 2, "cmd": "ping"}"#), vec![])
            .unwrap_err();
        assert_eq!(e.code(), "protocol");
        assert!(e.to_string().contains("clustering"), "{e}");
    }

    #[test]
    fn frame_rejects_data_field_and_non_finite_payload() {
        let hdr = parse(r#"{"v": 2, "n": 2, "l": 2, "data": [1,2,3,4], "k": 1}"#);
        let e = Request::decode_frame(&hdr, vec![0.0; 4]).unwrap_err();
        assert_eq!(e.code(), "protocol");
        assert!(e.to_string().contains("payload"), "{e}");
        let hdr = parse(r#"{"v": 2, "n": 2, "l": 2, "k": 1}"#);
        let e = Request::decode_frame(&hdr, vec![1.0, f32::NAN, 0.0, 0.0]).unwrap_err();
        assert_eq!(e.code(), "protocol");
        assert!(e.to_string().contains("non-finite"), "{e}");
    }

    #[test]
    fn frame_raises_sparse_cap_only() {
        // past the line-protocol sparse cap, inside the binary one
        let hdr = parse(r#"{"v": 2, "dataset": "synth-large-1048576", "sparse_k": 32}"#);
        assert!(Request::decode_frame(&hdr, vec![]).is_ok());
        // the same request over the line protocol stays rejected
        let e = Request::decode(&parse(
            r#"{"dataset": "synth-large-1048576", "sparse_k": 32}"#,
        ))
        .unwrap_err();
        assert_eq!(e.code(), "protocol");
        // dense framed requests keep the dense cap
        let hdr = parse(r#"{"v": 2, "dataset": "demo-16384"}"#);
        let e = Request::decode_frame(&hdr, vec![]).unwrap_err();
        assert_eq!(e.code(), "protocol");
    }

    #[test]
    fn frame_encode_layout() {
        let hdr = parse(r#"{"v": 2, "n": 1, "l": 2, "k": 1}"#);
        let bytes = encode_frame(&hdr, &[1.5, -2.0]);
        assert_eq!(&bytes[..4], &FRAME_MAGIC);
        let hlen = u32::from_le_bytes(bytes[4..8].try_into().unwrap()) as usize;
        let plen = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
        assert_eq!(plen, 8);
        assert_eq!(bytes.len(), 16 + hlen + plen);
        let hdr_str = std::str::from_utf8(&bytes[16..16 + hlen]).unwrap();
        assert_eq!(Json::parse(hdr_str).unwrap(), hdr);
        assert_eq!(
            f32::from_le_bytes(bytes[16 + hlen..16 + hlen + 4].try_into().unwrap()),
            1.5
        );
    }

    #[test]
    fn error_response_carries_code() {
        let j = error_response(&Json::Num(5.0), &TmfgError::StreamClosed);
        assert_eq!(j.get("ok").as_bool(), Some(false));
        assert_eq!(j.get("code").as_str(), Some("stream_closed"));
        assert_eq!(j.get("id").as_usize(), Some(5));
    }
}
