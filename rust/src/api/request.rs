//! The [`ClusterRequest`] builder — the one entry point for running a
//! clustering, whatever the input shape.
//!
//! Three sources are supported:
//! * [`ClusterRequest::dataset`] — a registry dataset by name (or a UCR
//!   CSV path), with optional `scale`/`seed`;
//! * [`ClusterRequest::panel`] — an inline n×L time-series panel (the
//!   similarity matrix is computed by the engine);
//! * [`ClusterRequest::similarity`] — a precomputed n×n similarity
//!   matrix (the paper's setting; no engine is constructed).
//!
//! [`ClusterRequest::build`] validates everything up front (shapes,
//! finiteness, label lengths, `k` range) and resolves the request into a
//! staged [`Plan`]; [`ClusterRequest::run`] is the one-shot convenience.

use crate::error::TmfgError;
use super::cache::{ArtifactCache, CacheKey, CacheStatus, CachedArtifacts};
use super::plan::{ApspMode, CacheCtx, ClusterOutput, Plan, SimilaritySpec, TmfgAlgo};
use crate::apsp::HubConfig;
use crate::coordinator::registry;
use crate::data::matrix::Matrix;
use crate::dbht::Linkage;
use crate::runtime::engine::CorrEngine;
use std::path::PathBuf;
use std::sync::Arc;

enum Source {
    Dataset(String),
    Panel(Arc<Matrix>),
    Similarity(Arc<Matrix>),
}

/// Builder for one clustering run. Construct with [`dataset`]
/// [`panel`], or [`similarity`]; chain option setters; then [`build`] a
/// staged [`Plan`] or [`run`] it to completion.
///
/// [`dataset`]: ClusterRequest::dataset
/// [`panel`]: ClusterRequest::panel
/// [`similarity`]: ClusterRequest::similarity
/// [`build`]: ClusterRequest::build
/// [`run`]: ClusterRequest::run
pub struct ClusterRequest {
    source: Source,
    algo: TmfgAlgo,
    spec: SimilaritySpec,
    apsp: Option<ApspMode>,
    linkage: Linkage,
    hub: HubConfig,
    k: Option<usize>,
    labels: Option<Vec<usize>>,
    scale: f64,
    seed: u64,
    use_xla: bool,
    check_invariants: bool,
    artifacts_dir: PathBuf,
    engine: Option<Arc<CorrEngine>>,
    cache: Option<Arc<ArtifactCache>>,
}

impl ClusterRequest {
    fn with_source(source: Source) -> ClusterRequest {
        ClusterRequest {
            source,
            algo: TmfgAlgo::Opt,
            spec: SimilaritySpec::Dense,
            apsp: None,
            linkage: Linkage::Complete,
            hub: HubConfig::default(),
            k: None,
            labels: None,
            scale: 1.0,
            seed: registry::DEFAULT_SEED,
            use_xla: true,
            check_invariants: false,
            artifacts_dir: PathBuf::from("artifacts"),
            engine: None,
            cache: None,
        }
    }

    /// Cluster a registry dataset (or UCR CSV path) by name.
    pub fn dataset(name: impl Into<String>) -> ClusterRequest {
        Self::with_source(Source::Dataset(name.into()))
    }

    /// Cluster an inline n×L time-series panel (one row per series).
    /// Accepts an owned `Matrix` or a shared `Arc<Matrix>` — pass the
    /// `Arc` to run many requests over one panel without copying it.
    pub fn panel(panel: impl Into<Arc<Matrix>>) -> ClusterRequest {
        Self::with_source(Source::Panel(panel.into()))
    }

    /// Cluster from a precomputed n×n similarity matrix (`Matrix` or
    /// shared `Arc<Matrix>`).
    pub fn similarity(s: impl Into<Arc<Matrix>>) -> ClusterRequest {
        Self::with_source(Source::Similarity(s.into()))
    }

    // ---- option setters ------------------------------------------------

    pub fn algo(mut self, algo: TmfgAlgo) -> Self {
        self.algo = algo;
        self
    }

    /// How the similarity stage reduces the panel (default:
    /// [`SimilaritySpec::Dense`]). Sparse mode requires a panel-bearing
    /// source (dataset or inline panel).
    pub fn similarity_spec(mut self, spec: SimilaritySpec) -> Self {
        self.spec = spec;
        self
    }

    /// Shorthand for [`SimilaritySpec::SparseKnn`]: build a k-NN
    /// candidate graph (k neighbors per series, `seed` driving the
    /// large-n projection prefilter + NN-descent refinement) instead of
    /// the dense O(n²) matrix, at the engine-default knob settings.
    pub fn sparse_knn(self, k: usize, seed: u64) -> Self {
        self.similarity_spec(SimilaritySpec::SparseKnn {
            k,
            seed,
            dims: None,
            pool: None,
            iters: None,
        })
    }

    /// [`Self::sparse_knn`] with explicit ANN knob overrides (`None`
    /// keeps the engine default for that knob; `iters == Some(0)`
    /// disables the NN-descent refinement).
    pub fn sparse_knn_tuned(
        self,
        k: usize,
        seed: u64,
        dims: Option<usize>,
        pool: Option<usize>,
        iters: Option<usize>,
    ) -> Self {
        self.similarity_spec(SimilaritySpec::SparseKnn { k, seed, dims, pool, iters })
    }

    /// Override the APSP mode (default: the algorithm's own default).
    pub fn apsp(mut self, mode: ApspMode) -> Self {
        self.apsp = Some(mode);
        self
    }

    pub fn linkage(mut self, linkage: Linkage) -> Self {
        self.linkage = linkage;
        self
    }

    pub fn hub(mut self, hub: HubConfig) -> Self {
        self.hub = hub;
        self
    }

    /// Cluster count to cut the dendrogram into. Defaults to the
    /// dataset's class count for dataset sources; without it, `finish`
    /// stops after the dendrogram.
    pub fn k(mut self, k: usize) -> Self {
        self.k = Some(k);
        self
    }

    /// Ground-truth labels (length n) for ARI reporting. Dataset sources
    /// carry their own; this overrides them.
    pub fn labels(mut self, labels: Vec<usize>) -> Self {
        self.labels = Some(labels);
        self
    }

    /// n-scale for dataset sources (1.0 = paper sizes).
    pub fn scale(mut self, scale: f64) -> Self {
        self.scale = scale;
        self
    }

    /// Generator seed for dataset sources.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// false = always use the native Rust correlation path.
    pub fn use_xla(mut self, use_xla: bool) -> Self {
        self.use_xla = use_xla;
        self
    }

    /// Validate TMFG structural invariants after construction.
    pub fn check_invariants(mut self, check: bool) -> Self {
        self.check_invariants = check;
        self
    }

    /// Artifacts directory for the XLA similarity engine.
    pub fn artifacts_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.artifacts_dir = dir.into();
        self
    }

    /// Reuse an existing similarity engine (services share one across
    /// requests to amortize executable-cache hits).
    pub fn engine(mut self, engine: Arc<CorrEngine>) -> Self {
        self.engine = Some(engine);
        self
    }

    /// Attach a cross-request artifact cache: if this request's
    /// [`fingerprint`](ClusterRequest::fingerprint) matches an entry, the
    /// plan is seeded with the cached Similarity→TMFG artifacts and the
    /// expensive stages are skipped; on a miss the freshly built
    /// artifacts are published for future requests.
    pub fn cache(mut self, cache: Arc<ArtifactCache>) -> Self {
        self.cache = Some(cache);
        self
    }

    /// The stable content fingerprint of this request's Similarity→TMFG
    /// inputs, or `None` when the source has no stable identity (CSV
    /// file paths and unknown dataset names — their content can change
    /// between requests). Two requests with equal fingerprints produce
    /// byte-identical similarity and TMFG artifacts; the APSP mode,
    /// linkage, hub parameters, `k`, and labels are deliberately
    /// excluded (they only affect the cheap downstream stages).
    pub fn fingerprint(&self) -> Option<CacheKey> {
        // Sparse requests produce CSR-shaped artifacts the dense-artifact
        // cache cannot hold; they bypass it (CacheStatus::Bypass).
        if !matches!(self.spec, SimilaritySpec::Dense) {
            return None;
        }
        let algo = self.algo.name();
        match &self.source {
            Source::Dataset(name) => {
                let canonical = registry::canonical_name(name)?;
                Some(CacheKey::named(&canonical, self.scale, self.seed, &algo, self.use_xla))
            }
            Source::Panel(m) => Some(CacheKey::panel(m, &algo, self.use_xla)),
            Source::Similarity(s) => Some(CacheKey::similarity(s, &algo)),
        }
    }

    // ---- resolution ----------------------------------------------------

    /// Validate the request and resolve it into a staged [`Plan`]. With a
    /// cache attached, a fingerprint hit seeds the plan with the shared
    /// Similarity→TMFG artifacts (skipping dataset generation, the
    /// finiteness scan, the similarity computation, and the TMFG build);
    /// a miss resolves normally and arranges publication of the fresh
    /// artifacts.
    pub fn build(self) -> Result<Plan, TmfgError> {
        // Hub parameters feed radius arithmetic and comparisons; a NaN
        // or negative multiplier would silently empty every ball.
        if !self.hub.radius_mult.is_finite() || self.hub.radius_mult < 0.0 {
            return Err(TmfgError::invalid(format!(
                "hub radius_mult must be finite and >= 0, got {}",
                self.hub.radius_mult
            )));
        }
        if let SimilaritySpec::SparseKnn { k, .. } = self.spec {
            if k < 1 {
                return Err(TmfgError::invalid("sparse k must be >= 1"));
            }
            if matches!(self.source, Source::Similarity(_)) {
                return Err(TmfgError::invalid(
                    "sparse k-NN mode needs a panel to build candidates from; \
                     it cannot apply to a precomputed similarity matrix",
                ));
            }
        }
        let fingerprint = if self.cache.is_some() { self.fingerprint() } else { None };
        if let (Some(cache), Some(key)) = (self.cache.clone(), fingerprint.clone()) {
            if let Some(art) = cache.get(&key) {
                return self.build_from_cached(cache, key, art);
            }
        }
        let (panel, similarity, mut truth, mut k) = match self.source {
            Source::Dataset(name) => {
                let ds = registry::get_dataset(&name, self.scale, self.seed)
                    .ok_or(TmfgError::DatasetNotFound(name))?;
                // Synthetic datasets are finite by construction, but this
                // path also loads arbitrary UCR CSV files.
                check_finite(&ds.data, "dataset panel")?;
                (
                    Some(Arc::new(ds.data)),
                    None,
                    Some(ds.labels),
                    Some(ds.n_classes.max(1)),
                )
            }
            Source::Panel(m) => {
                if m.rows < 4 {
                    return Err(TmfgError::invalid(format!(
                        "TMFG needs at least 4 series, got {}",
                        m.rows
                    )));
                }
                if m.cols < 2 {
                    return Err(TmfgError::invalid(format!(
                        "panel needs at least 2 samples per series, got {}",
                        m.cols
                    )));
                }
                check_finite(&m, "panel")?;
                (Some(m), None, None, None)
            }
            Source::Similarity(s) => {
                // Shape rules live in one place (square, n >= 4).
                crate::tmfg::common::validate_similarity(&s)?;
                check_finite(&s, "similarity matrix")?;
                (None, Some(s), None, None)
            }
        };
        // Dataset-intrinsic metadata (pre-override) rides along with the
        // cached artifacts so a future hit can serve the dataset without
        // regenerating it.
        let ds_truth = truth.clone();
        let ds_k = k;
        // Explicit options override what the dataset provided.
        if self.labels.is_some() {
            truth = self.labels;
        }
        if self.k.is_some() {
            k = self.k;
        }
        let n = panel
            .as_ref()
            .map(|m| m.rows)
            .or_else(|| similarity.as_ref().map(|s| s.rows))
            .ok_or_else(|| TmfgError::invariant("request resolved to no input"))?;
        validate_truth_k(&truth, k, n)?;
        // An engine is only needed when a panel must be reduced to the
        // dense matrix; the sparse k-NN stage is always native.
        let sparse_mode = !matches!(self.spec, SimilaritySpec::Dense);
        let engine = match (&panel, self.engine) {
            _ if sparse_mode => None,
            (_, Some(e)) => Some(e),
            (Some(_), None) if self.use_xla => {
                Some(Arc::new(CorrEngine::auto(&self.artifacts_dir)))
            }
            (Some(_), None) => Some(Arc::new(CorrEngine::native_only())),
            (None, None) => None,
        };
        let apsp_mode = self.apsp.unwrap_or_else(|| self.algo.default_apsp());
        let mut plan = Plan::new(
            self.algo,
            self.spec,
            apsp_mode,
            self.linkage,
            self.hub,
            self.check_invariants,
            k,
            truth,
            n,
            panel,
            similarity,
            engine,
        );
        if let (Some(cache), Some(key)) = (self.cache, fingerprint) {
            plan.set_cache_ctx(CacheCtx {
                cache,
                key,
                status: CacheStatus::Miss,
                truth: ds_truth,
                default_k: ds_k,
            });
        }
        Ok(plan)
    }

    /// Resolve a cache hit into a plan seeded with the shared artifacts.
    /// Request-level validation (labels length, `k` range) still runs
    /// against the cached dimensions.
    fn build_from_cached(
        self,
        cache: Arc<ArtifactCache>,
        key: CacheKey,
        art: CachedArtifacts,
    ) -> Result<Plan, TmfgError> {
        let n = art.similarity.rows;
        let truth = self.labels.or_else(|| art.truth.clone());
        let k = self.k.or(art.default_k);
        validate_truth_k(&truth, k, n)?;
        // A hit skips run_tmfg entirely, so honor the request's explicit
        // validation ask here (the entry may have been populated by a
        // request that never checked).
        if self.check_invariants {
            crate::tmfg::common::check_invariants(&art.tmfg)?;
        }
        let apsp_mode = self.apsp.unwrap_or_else(|| self.algo.default_apsp());
        // No panel and no engine: the similarity stage is pre-seeded, so
        // nothing downstream ever needs them. (Only dense requests carry
        // a fingerprint, so a hit is always a dense plan.)
        let mut plan = Plan::new(
            self.algo,
            SimilaritySpec::Dense,
            apsp_mode,
            self.linkage,
            self.hub,
            self.check_invariants,
            k,
            truth,
            n,
            None,
            None,
            None,
        );
        plan.set_cache_bytes(art.bytes() as u64);
        plan.seed_artifacts(art.similarity, art.tmfg);
        plan.set_cache_ctx(CacheCtx {
            cache,
            key,
            status: CacheStatus::Hit,
            truth: None,
            default_k: None,
        });
        Ok(plan)
    }

    /// Build the plan and run it to completion.
    pub fn run(self) -> Result<ClusterOutput, TmfgError> {
        self.build()?.finish()
    }
}

/// The request-level invariants shared by the fresh and cache-hit build
/// paths: labels must cover every item, `k` must be a valid cut size.
fn validate_truth_k(
    truth: &Option<Vec<usize>>,
    k: Option<usize>,
    n: usize,
) -> Result<(), TmfgError> {
    if let Some(t) = truth {
        if t.len() != n {
            return Err(TmfgError::invalid(format!(
                "labels length {} != n = {n}",
                t.len()
            )));
        }
    }
    if let Some(k) = k {
        if k < 1 || k > n {
            return Err(TmfgError::invalid(format!("k must be in 1..={n}, got {k}")));
        }
    }
    Ok(())
}

fn check_finite(m: &Matrix, what: &str) -> Result<(), TmfgError> {
    if let Some(pos) = m.data.iter().position(|v| !v.is_finite()) {
        return Err(TmfgError::invalid(format!(
            "non-finite value {} in {what} at row {} col {}",
            m.data[pos],
            pos / m.cols.max(1),
            pos % m.cols.max(1)
        )));
    }
    Ok(())
}
