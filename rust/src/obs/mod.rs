//! Dependency-free observability layer: spans, metrics, leveled
//! logging, and trace export.
//!
//! Three cooperating pieces, all in-repo (no external crates, matching
//! the offline `vendor/` policy):
//!
//! - **Spans/events** ([`spans`]): `span!(kind, ...)` returns an RAII
//!   guard that records into per-thread buffers, collected sequentially
//!   by an exclusive [`TraceSession`]. Disabled cost is one relaxed
//!   atomic load — the determinism suites run with tracing on and off
//!   and pin byte-identical results either way.
//! - **Metrics** ([`registry`]): log-linear latency [`Histogram`]s
//!   (p50/p95/p99 within 6.25%), counters, and gauges in a
//!   process-global [`Registry`] with a Prometheus text exposition —
//!   served by the TCP service as `{"cmd": "metrics"}`.
//! - **Export** ([`trace`]): finished sessions render as Chrome
//!   trace-event JSON (`tmfg run --trace out.json`, wire
//!   `"trace": true`), one track per thread.
//! - **SLOs** ([`slo`]): multi-window (1m/10m) latency-objective
//!   attainment and burn rate per series, rendered as the `"slo"`
//!   stats block and the Prometheus `tmfg_slo_*` families.
//! - **Flight recorder** ([`recorder`]): byte-budgeted ring of wide
//!   events (one per completed request), dumped as JSONL via
//!   `{"cmd": "debug_dump"}` or `tmfg serve --flight-log`.
//!
//! Span taxonomy (the `cat` field in exported traces):
//!
//! | kind         | emitted by                                        |
//! |--------------|---------------------------------------------------|
//! | `stage`      | `api::Plan` stage runs (similarity…cut)           |
//! | `tmfg_round` | lazy-gain scan rounds in CORR/HEAP/sparse TMFG    |
//! | `oracle_row` | `ApspOracle::row_into` derivations                |
//! | `knn_phase`  | sparse k-NN build phases                          |
//! | `pool_job`   | `parlay::pool` posted parallel jobs               |
//! | `queue_wait` | dispatcher submit→dequeue wait (retroactive)      |
//! | `cache`      | artifact-cache hit/miss/bypass instants           |
//!
//! The leveled [`log!`](crate::log) macro replaces scattered
//! `println!`/`eprintln!` sites: `error`/`warn` go to stderr,
//! `info`/`debug` to stdout, filtered by the `TMFG_LOG` env var
//! (`off|error|warn|info|debug`, default `info`) or programmatically
//! via [`set_max_level`] (the CLI's `--quiet` maps to `warn`). Machine
//! output (wire responses, `--json-out`, CSV artifacts) never goes
//! through `log!` and is unaffected by the filter.

pub mod hist;
pub mod recorder;
pub mod registry;
pub mod slo;
pub mod spans;
pub mod trace;

pub use hist::Histogram;
pub use recorder::{FlightRecorder, RecorderStats};
pub use registry::{names, registry, Registry};
pub use slo::{slo_tracker, SloReport, SloTracker};
pub use spans::{
    current_trace_id, event, next_trace_id, record_span, tracing_enabled, SpanGuard, SpanRecord,
    ThreadSpans, TraceCtx, TraceSession,
};
pub use trace::chrome_trace;

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity; messages pass the filter when `level <= max_level`.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
#[repr(u8)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
}

/// 0 suppresses everything ("off"); `UNSET` defers to `TMFG_LOG`.
const LEVEL_UNSET: u8 = u8::MAX;
static MAX_LEVEL: AtomicU8 = AtomicU8::new(LEVEL_UNSET);

fn level_from_env() -> u8 {
    match std::env::var("TMFG_LOG").as_deref() {
        Ok("off") => 0,
        Ok("error") => Level::Error as u8,
        Ok("warn") => Level::Warn as u8,
        Ok("debug") => Level::Debug as u8,
        _ => Level::Info as u8,
    }
}

fn max_level() -> u8 {
    let v = MAX_LEVEL.load(Ordering::Relaxed);
    if v != LEVEL_UNSET {
        return v;
    }
    let v = level_from_env();
    MAX_LEVEL.store(v, Ordering::Relaxed);
    v
}

/// Override the log filter (wins over `TMFG_LOG`); `None` restores the
/// env-derived default. The CLI's `--quiet` calls
/// `set_max_level(Some(Level::Warn))`.
pub fn set_max_level(level: Option<Level>) {
    MAX_LEVEL.store(level.map_or(LEVEL_UNSET, |l| l as u8), Ordering::Relaxed);
}

/// Sink for the [`log!`](crate::log) macro — don't call directly.
/// Lines emitted while a request [`TraceCtx`] is active on this thread
/// are prefixed with `[<trace_id>]` so server logs correlate with
/// trace exports and flight-recorder wide events.
pub fn log_emit(level: Level, args: std::fmt::Arguments<'_>) {
    if (level as u8) > max_level() {
        return;
    }
    match (current_trace_id(), level) {
        (Some(id), Level::Error | Level::Warn) => eprintln!("[{id}] {args}"),
        (Some(id), Level::Info | Level::Debug) => println!("[{id}] {args}"),
        (None, Level::Error | Level::Warn) => eprintln!("{args}"),
        (None, Level::Info | Level::Debug) => println!("{args}"),
    }
}

/// Leveled logging: `log!(info, "wrote {path}")`. Levels: `error`,
/// `warn` (stderr), `info`, `debug` (stdout). Filtered by `TMFG_LOG` /
/// [`obs::set_max_level`](set_max_level); formatting is skipped for
/// filtered-out messages.
#[macro_export]
macro_rules! log {
    (error, $($arg:tt)+) => {
        $crate::obs::log_emit($crate::obs::Level::Error, format_args!($($arg)+))
    };
    (warn, $($arg:tt)+) => {
        $crate::obs::log_emit($crate::obs::Level::Warn, format_args!($($arg)+))
    };
    (info, $($arg:tt)+) => {
        $crate::obs::log_emit($crate::obs::Level::Info, format_args!($($arg)+))
    };
    (debug, $($arg:tt)+) => {
        $crate::obs::log_emit($crate::obs::Level::Debug, format_args!($($arg)+))
    };
}

/// RAII tracing span: `let _s = span!("stage", "similarity n={n}");`.
/// The kind must be a `&'static str`; the label format is only
/// evaluated when a trace session is collecting (disabled cost: one
/// relaxed atomic load).
#[macro_export]
macro_rules! span {
    ($kind:expr) => {
        $crate::obs::SpanGuard::enter($kind, String::new)
    };
    ($kind:expr, $($arg:tt)+) => {
        $crate::obs::SpanGuard::enter($kind, || format!($($arg)+))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_filtering_is_programmable() {
        // The macro itself must compile at every level; emission goes
        // through the filter (asserted via max_level transitions, since
        // capturing stdout is not worth a dependency).
        set_max_level(Some(Level::Warn));
        assert_eq!(max_level(), Level::Warn as u8);
        crate::log!(debug, "filtered out {}", 1);
        set_max_level(Some(Level::Debug));
        assert_eq!(max_level(), Level::Debug as u8);
        set_max_level(None);
        let env_default = max_level();
        assert!(env_default <= Level::Debug as u8);
    }

    #[test]
    fn span_macro_compiles_in_both_arities() {
        let _bare = crate::span!("stage");
        let n = 3;
        let _labeled = crate::span!("stage", "similarity n={n}");
    }
}
