//! Multi-window SLO tracker: latency-objective attainment and burn rate
//! over sliding short/long windows.
//!
//! Every tracked series (end-to-end `request`, `queue_wait`,
//! `stream_tick`, and the per-stage `stage:<name>` family fed by
//! [`crate::api::plan`]) owns two slot-ring windows of the existing
//! log-linear [`Histogram`]s: a short window for paging-grade signals
//! ([`SHORT_WINDOW_SECS`] = 60 s in 10 s slots) and a long window for
//! trend-grade ones ([`LONG_WINDOW_SECS`] = 600 s in 60 s slots).
//! Recording is one histogram increment into each ring's current slot;
//! reporting merges the live slots, so a sample ages out when its slot
//! is overwritten — a sliding window with slot-granularity expiry and
//! no per-sample timestamps.
//!
//! **Attainment** is `good / count` where a sample is good when it is
//! ≤ the series objective; the straddling histogram bucket is counted
//! bad, so attainment is conservative by at most one bucket width
//! (≤ 6.25%). An empty window reports attainment 1.0 (no traffic means
//! no violated objective). **Burn rate** is the SRE definition:
//! `(1 − attainment) / (1 − target)` — 1.0 means the error budget is
//! being consumed exactly at the sustainable rate, N means N× too fast.
//!
//! The process-global tracker ([`slo_tracker`]) is exported two ways:
//! the service's `{"cmd":"stats"}` renders [`SloTracker::report`] as the
//! `"slo"` block, and [`SloTracker::prometheus`] emits the
//! `tmfg_slo_objective_seconds` / `tmfg_slo_attainment_ratio` /
//! `tmfg_slo_burn_rate` gauge families appended to the registry
//! exposition (attainment is fractional, which the u64 registry gauges
//! cannot carry). Recording is purely observational — it never feeds
//! back into any computation, so results stay byte-identical with the
//! tracker hot (same contract as spans and the flight recorder).

use super::hist::Histogram;
use std::collections::BTreeMap;
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

/// Short-window span in seconds (6 slots of 10 s).
pub const SHORT_WINDOW_SECS: u64 = 60;
/// Long-window span in seconds (10 slots of 60 s).
pub const LONG_WINDOW_SECS: u64 = 600;

const SHORT_SLOTS: usize = 6;
const LONG_SLOTS: usize = 10;

/// A sliding window as a ring of per-slot histograms. Advancing to a
/// new slot clears everything the wall clock skipped, so a slot only
/// ever holds samples from its own time span.
struct WindowRing {
    slot_len: Duration,
    slots: Vec<Histogram>,
    epoch: Instant,
    /// Absolute (monotone, non-wrapping) index of the current slot.
    cur_slot: u64,
}

impl WindowRing {
    fn new(slot_len: Duration, n_slots: usize, epoch: Instant) -> WindowRing {
        WindowRing {
            slot_len,
            slots: (0..n_slots).map(|_| Histogram::new()).collect(),
            epoch,
            cur_slot: 0,
        }
    }

    fn abs_slot(&self, now: Instant) -> u64 {
        (now.saturating_duration_since(self.epoch).as_nanos() / self.slot_len.as_nanos().max(1))
            as u64
    }

    /// Rotate to `now`'s slot, clearing every slot the clock skipped
    /// (bounded by the ring length — a long idle clears everything).
    fn advance(&mut self, now: Instant) {
        let abs = self.abs_slot(now);
        if abs <= self.cur_slot {
            return;
        }
        let len = self.slots.len() as u64;
        let skipped = (abs - self.cur_slot).min(len);
        for i in 0..skipped {
            let idx = ((self.cur_slot + 1 + i) % len) as usize;
            self.slots[idx] = Histogram::new();
        }
        self.cur_slot = abs;
    }

    fn record_at(&mut self, v: u64, now: Instant) {
        self.advance(now);
        let len = self.slots.len() as u64;
        self.slots[(self.cur_slot % len) as usize].record(v);
    }

    /// The whole window merged into one histogram.
    fn merged(&mut self, now: Instant) -> Histogram {
        self.advance(now);
        let mut all = Histogram::new();
        for h in &self.slots {
            all.merge(h);
        }
        all
    }
}

/// Attainment/burn snapshot of one window of one series.
#[derive(Debug, Clone, Copy)]
pub struct WindowStats {
    pub count: u64,
    /// Fraction of samples at or under the objective; 1.0 when empty.
    pub attainment: f64,
    /// `(1 − attainment) / (1 − target)` — error-budget consumption
    /// rate; 0.0 when empty or fully attained.
    pub burn_rate: f64,
}

fn window_stats(h: &Histogram, objective_ns: u64, target: f64) -> WindowStats {
    let count = h.count();
    if count == 0 {
        return WindowStats { count: 0, attainment: 1.0, burn_rate: 0.0 };
    }
    // Cumulative count of whole buckets whose upper edge is within the
    // objective — the straddling bucket counts as bad (conservative).
    let mut good = 0u64;
    for (edge, cum) in h.cumulative_buckets() {
        if edge <= objective_ns {
            good = cum;
        } else {
            break;
        }
    }
    let attainment = good as f64 / count as f64;
    WindowStats {
        count,
        attainment,
        burn_rate: (1.0 - attainment) / (1.0 - target).max(1e-9),
    }
}

/// One tracked latency series: an objective, a target attainment
/// fraction, and the two windows.
struct SloSeries {
    objective: Duration,
    target: f64,
    short: WindowRing,
    long: WindowRing,
}

impl SloSeries {
    fn new(objective: Duration, target: f64, epoch: Instant) -> SloSeries {
        SloSeries {
            objective,
            target,
            short: WindowRing::new(
                Duration::from_secs(SHORT_WINDOW_SECS / SHORT_SLOTS as u64),
                SHORT_SLOTS,
                epoch,
            ),
            long: WindowRing::new(
                Duration::from_secs(LONG_WINDOW_SECS / LONG_SLOTS as u64),
                LONG_SLOTS,
                epoch,
            ),
        }
    }

    fn record_at(&mut self, d: Duration, now: Instant) {
        let ns = d.as_nanos().min(u64::MAX as u128) as u64;
        self.short.record_at(ns, now);
        self.long.record_at(ns, now);
    }

    fn report_at(&mut self, name: &str, now: Instant) -> SeriesReport {
        let objective_ns = self.objective.as_nanos().min(u64::MAX as u128) as u64;
        SeriesReport {
            name: name.to_string(),
            objective_ms: self.objective.as_secs_f64() * 1e3,
            target: self.target,
            short: window_stats(&self.short.merged(now), objective_ns, self.target),
            long: window_stats(&self.long.merged(now), objective_ns, self.target),
        }
    }
}

/// Snapshot of one series, both windows.
#[derive(Debug, Clone)]
pub struct SeriesReport {
    pub name: String,
    pub objective_ms: f64,
    pub target: f64,
    pub short: WindowStats,
    pub long: WindowStats,
}

/// Snapshot of the whole tracker — what `stats` renders as `"slo"`.
#[derive(Debug, Clone)]
pub struct SloReport {
    pub short_secs: u64,
    pub long_secs: u64,
    pub series: Vec<SeriesReport>,
}

/// Default objective/target per series name; `set_objective` overrides.
fn default_objective(name: &str) -> (Duration, f64) {
    match name {
        "request" => (Duration::from_millis(500), 0.99),
        "queue_wait" => (Duration::from_millis(100), 0.99),
        "stream_tick" => (Duration::from_millis(100), 0.99),
        _ if name.starts_with("stage:") => (Duration::from_millis(250), 0.99),
        _ => (Duration::from_millis(500), 0.99),
    }
}

/// The multi-window SLO tracker. All methods take `&self`; series are
/// created lazily on first record with [`default_objective`]s.
pub struct SloTracker {
    inner: Mutex<BTreeMap<String, SloSeries>>,
}

impl SloTracker {
    pub fn new() -> SloTracker {
        SloTracker { inner: Mutex::new(BTreeMap::new()) }
    }

    /// Record one latency sample for `name` at the current instant.
    pub fn record(&self, name: &str, d: Duration) {
        self.record_at(name, d, Instant::now());
    }

    /// Record with an explicit clock (tests inject time for rotation).
    pub fn record_at(&self, name: &str, d: Duration, now: Instant) {
        let mut inner = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        let series = inner.entry(name.to_string()).or_insert_with(|| {
            let (objective, target) = default_objective(name);
            SloSeries::new(objective, target, now)
        });
        series.record_at(d, now);
    }

    /// Override (or pre-create) a series' objective and target.
    pub fn set_objective(&self, name: &str, objective: Duration, target: f64) {
        let now = Instant::now();
        let mut inner = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        match inner.get_mut(name) {
            Some(s) => {
                s.objective = objective;
                s.target = target.clamp(0.0, 1.0);
            }
            None => {
                inner.insert(
                    name.to_string(),
                    SloSeries::new(objective, target.clamp(0.0, 1.0), now),
                );
            }
        }
    }

    pub fn report(&self) -> SloReport {
        self.report_at(Instant::now())
    }

    pub fn report_at(&self, now: Instant) -> SloReport {
        let mut inner = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        SloReport {
            short_secs: SHORT_WINDOW_SECS,
            long_secs: LONG_WINDOW_SECS,
            series: inner
                .iter_mut()
                .map(|(name, s)| s.report_at(name, now))
                .collect(),
        }
    }

    /// The `tmfg_slo_*` gauge families as Prometheus text exposition —
    /// appended to the registry's by the service's `metrics` handlers.
    pub fn prometheus(&self) -> String {
        let report = self.report();
        if report.series.is_empty() {
            return String::new();
        }
        let mut out = String::new();
        out.push_str("# TYPE tmfg_slo_objective_seconds gauge\n");
        for s in &report.series {
            out.push_str(&format!(
                "tmfg_slo_objective_seconds{{series=\"{}\"}} {}\n",
                s.name,
                s.objective_ms / 1e3
            ));
        }
        out.push_str("# TYPE tmfg_slo_attainment_ratio gauge\n");
        for s in &report.series {
            for (window, w) in [("short", &s.short), ("long", &s.long)] {
                out.push_str(&format!(
                    "tmfg_slo_attainment_ratio{{series=\"{}\",window=\"{window}\"}} {}\n",
                    s.name, w.attainment
                ));
            }
        }
        out.push_str("# TYPE tmfg_slo_burn_rate gauge\n");
        for s in &report.series {
            for (window, w) in [("short", &s.short), ("long", &s.long)] {
                out.push_str(&format!(
                    "tmfg_slo_burn_rate{{series=\"{}\",window=\"{window}\"}} {}\n",
                    s.name, w.burn_rate
                ));
            }
        }
        out
    }
}

impl Default for SloTracker {
    fn default() -> Self {
        SloTracker::new()
    }
}

/// The process-global tracker every producer records into.
pub fn slo_tracker() -> &'static SloTracker {
    static T: OnceLock<SloTracker> = OnceLock::new();
    T.get_or_init(SloTracker::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_rotation_expires_old_samples() {
        let epoch = Instant::now();
        let mut ring = WindowRing::new(Duration::from_secs(10), 6, epoch);
        ring.record_at(100, epoch);
        assert_eq!(ring.merged(epoch).count(), 1);
        // Still inside the 60 s window at +55 s.
        let t55 = epoch + Duration::from_secs(55);
        ring.record_at(200, t55);
        assert_eq!(ring.merged(t55).count(), 2);
        // At +65 s the epoch slot has been overwritten: only the +55 s
        // sample remains.
        let t65 = epoch + Duration::from_secs(65);
        assert_eq!(ring.merged(t65).count(), 1);
        // A jump far past the horizon clears everything in one advance.
        let later = epoch + Duration::from_secs(10_000);
        assert_eq!(ring.merged(later).count(), 0);
    }

    #[test]
    fn rotation_clears_exactly_the_skipped_slots() {
        let epoch = Instant::now();
        let mut ring = WindowRing::new(Duration::from_secs(10), 6, epoch);
        // One sample per slot across the first window.
        for slot in 0..6u64 {
            ring.record_at(slot + 1, epoch + Duration::from_secs(slot * 10));
        }
        assert_eq!(ring.merged(epoch + Duration::from_secs(59)).count(), 6);
        // Each subsequent slot expires exactly one old sample.
        for (i, slot) in (6..10u64).enumerate() {
            let now = epoch + Duration::from_secs(slot * 10);
            assert_eq!(ring.merged(now).count(), 5 - i as u64, "slot {slot}");
        }
    }

    #[test]
    fn attainment_and_burn_rate() {
        let now = Instant::now();
        let t = SloTracker::new();
        t.set_objective("request", Duration::from_millis(1), 0.99);
        for _ in 0..10 {
            t.record_at("request", Duration::from_micros(500), now);
            t.record_at("request", Duration::from_millis(100), now);
        }
        let report = t.report_at(now);
        assert_eq!(report.short_secs, SHORT_WINDOW_SECS);
        assert_eq!(report.long_secs, LONG_WINDOW_SECS);
        let s = &report.series[0];
        assert_eq!(s.name, "request");
        assert_eq!(s.short.count, 20);
        assert!((s.short.attainment - 0.5).abs() < 1e-9, "{}", s.short.attainment);
        // (1 - 0.5) / (1 - 0.99) = 50× budget burn.
        assert!((s.short.burn_rate - 50.0).abs() < 1e-6, "{}", s.short.burn_rate);
        assert_eq!(s.long.count, 20);
    }

    #[test]
    fn empty_series_attains_fully() {
        let t = SloTracker::new();
        t.set_objective("idle", Duration::from_millis(5), 0.999);
        let r = t.report();
        let s = &r.series[0];
        assert_eq!(s.short.count, 0);
        assert_eq!(s.short.attainment, 1.0);
        assert_eq!(s.short.burn_rate, 0.0);
    }

    #[test]
    fn default_objectives_and_prometheus_shape() {
        let t = SloTracker::new();
        t.record("stage:similarity", Duration::from_millis(1));
        t.record("request", Duration::from_millis(1));
        let text = t.prometheus();
        for needle in [
            "# TYPE tmfg_slo_objective_seconds gauge",
            "# TYPE tmfg_slo_attainment_ratio gauge",
            "# TYPE tmfg_slo_burn_rate gauge",
            "tmfg_slo_objective_seconds{series=\"request\"} 0.5",
            "tmfg_slo_attainment_ratio{series=\"stage:similarity\",window=\"short\"} 1",
            "tmfg_slo_burn_rate{series=\"request\",window=\"long\"} 0",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
        assert!(SloTracker::new().prometheus().is_empty(), "no series, no families");
    }
}
