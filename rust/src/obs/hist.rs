//! Log-linear (HDR-style) latency histogram.
//!
//! Values (u64, typically nanoseconds) land in one of 976 fixed buckets:
//! 16 unit-width buckets for `v < 16`, then 16 linear sub-buckets per
//! power of two above that — so the relative quantization error is
//! bounded by 1/16 (6.25%) everywhere, while the whole u64 range fits in
//! ~8 KiB of counts. Recording is one index computation plus one
//! increment; percentiles walk the cumulative counts. Histograms merge
//! by elementwise addition, which is what lets per-worker instances be
//! combined into one process view without locking on the hot path.

/// log2 of the sub-bucket count per octave.
const SUB_BITS: u32 = 4;
/// Linear sub-buckets per octave (and the width of the unit range).
const SUB: u64 = 1 << SUB_BITS;
/// Total bucket count: the unit range plus 16 sub-buckets for each
/// most-significant-bit position 4..=63.
pub const BUCKETS: usize = (SUB as usize) * 61;

/// Fixed-layout log-linear histogram with running count/sum/min/max.
#[derive(Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    count: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram { counts: vec![0; BUCKETS], count: 0, sum: 0, min: u64::MAX, max: 0 }
    }

    /// Bucket index for a value; total order over values is preserved
    /// (monotone in `v`).
    pub fn index_of(v: u64) -> usize {
        if v < SUB {
            return v as usize;
        }
        let msb = 63 - v.leading_zeros() as usize;
        let sub = ((v >> (msb as u32 - SUB_BITS)) - SUB) as usize;
        (msb - SUB_BITS as usize + 1) * SUB as usize + sub
    }

    /// Smallest value mapping to bucket `i`.
    pub fn bucket_low(i: usize) -> u64 {
        if i < SUB as usize {
            return i as u64;
        }
        let msb = i / SUB as usize + SUB_BITS as usize - 1;
        let sub = (i % SUB as usize) as u64;
        (SUB + sub) << (msb as u32 - SUB_BITS)
    }

    /// Largest value mapping to bucket `i`.
    pub fn bucket_high(i: usize) -> u64 {
        if i + 1 < BUCKETS {
            Self::bucket_low(i + 1) - 1
        } else {
            u64::MAX
        }
    }

    pub fn record(&mut self, v: u64) {
        self.counts[Self::index_of(v)] += 1;
        self.count += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> u128 {
        self.sum
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Value at quantile `q` ∈ [0, 1]: the upper edge of the bucket
    /// holding the ceil(q·count)-th observation, clamped to the observed
    /// max — so the reported value is within one bucket width (≤ 6.25%
    /// relative) of the true order statistic, and monotone in `q`.
    pub fn percentile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return Self::bucket_high(i).min(self.max);
            }
        }
        self.max
    }

    /// Non-empty buckets as `(upper_edge, cumulative_count)` pairs —
    /// the shape a Prometheus histogram exposition needs.
    pub fn cumulative_buckets(&self) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c > 0 {
                cum += c;
                out.push((Self::bucket_high(i), cum));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn bucket_boundaries_are_exact_at_octave_edges() {
        // Unit range: identity.
        for v in 0..16u64 {
            assert_eq!(Histogram::index_of(v), v as usize);
            assert_eq!(Histogram::bucket_low(v as usize), v);
        }
        // Octave edges land on fresh buckets, last sub-bucket just below.
        assert_eq!(Histogram::index_of(16), 16);
        assert_eq!(Histogram::index_of(31), 31);
        assert_eq!(Histogram::index_of(32), 32);
        assert_eq!(Histogram::index_of(33), 32); // 33 shares 32's sub-bucket
        assert_eq!(Histogram::index_of(u64::MAX), BUCKETS - 1);
        // bucket_low/bucket_high tile the axis with no gaps or overlaps.
        for i in 1..BUCKETS {
            assert_eq!(Histogram::bucket_high(i - 1) + 1, Histogram::bucket_low(i), "bucket {i}");
        }
        // Round trip: every bucket's low and high map back to it.
        for i in 0..BUCKETS {
            assert_eq!(Histogram::index_of(Histogram::bucket_low(i)), i);
            assert_eq!(Histogram::index_of(Histogram::bucket_high(i)), i);
        }
    }

    #[test]
    fn relative_error_bounded() {
        let mut v = 1u64;
        while v < u64::MAX / 3 {
            let i = Histogram::index_of(v);
            let (lo, hi) = (Histogram::bucket_low(i), Histogram::bucket_high(i));
            assert!(lo <= v && v <= hi);
            // Bucket width ≤ lo/16 above the unit range.
            if lo >= 16 {
                assert!(hi - lo + 1 <= lo / 16 + 1, "v={v} lo={lo} hi={hi}");
            }
            v = v.wrapping_mul(3).max(v + 1);
        }
    }

    #[test]
    fn merge_matches_single_histogram() {
        let mut rng = Rng::new(7);
        let mut all = Histogram::new();
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for i in 0..2000u64 {
            let v = (rng.next_u64() % 1_000_000).max(1);
            all.record(v);
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert_eq!(a.sum(), all.sum());
        assert_eq!(a.min(), all.min());
        assert_eq!(a.max(), all.max());
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(a.percentile(q), all.percentile(q), "q={q}");
        }
    }

    #[test]
    fn percentiles_monotone_and_bounded() {
        let mut rng = Rng::new(42);
        let mut h = Histogram::new();
        for _ in 0..5000 {
            h.record(rng.next_u64() % 10_000_000);
        }
        let mut prev = 0u64;
        for i in 0..=100 {
            let p = h.percentile(i as f64 / 100.0);
            assert!(p >= prev, "p{i}={p} < {prev}");
            assert!(p <= h.max());
            prev = p;
        }
        assert_eq!(h.percentile(1.0), h.max());
    }

    #[test]
    fn exact_small_values_and_empty() {
        let h = Histogram::new();
        assert_eq!(h.percentile(0.5), 0);
        assert!(h.is_empty());
        let mut h = Histogram::new();
        for v in [3u64, 3, 7, 9] {
            h.record(v);
        }
        // All below 16: buckets are exact.
        assert_eq!(h.percentile(0.5), 3);
        assert_eq!(h.percentile(1.0), 9);
        assert_eq!(h.cumulative_buckets(), vec![(3, 2), (7, 3), (9, 4)]);
    }
}
