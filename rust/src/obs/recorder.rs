//! Request flight recorder: an always-on, fixed-byte-budget ring
//! buffer holding one canonical *wide event* per completed request.
//!
//! Each event is a single pre-serialized JSON line (trace id, tenant,
//! outcome, cache status, oracle kind, per-stage timings, queue delay,
//! response bytes, resource usage — assembled by the service layer).
//! The ring evicts oldest-first the moment the byte budget is
//! exceeded, so memory stays bounded no matter the traffic shape and a
//! dump always replays the most recent window of requests as JSONL.
//!
//! Three consumers share the same [`FlightRecorder::dump`]: the
//! `{"cmd":"debug_dump"}` wire command, the graceful-drain flush to
//! `--flight-log <path>`, and tests. Like spans and the SLO tracker,
//! recording is strictly post-computation and observational — a budget
//! of 0 disables the recorder entirely and [`FlightRecorder::record_with`]
//! never evaluates its closure, so the disabled path costs one branch
//! (the `obs/wide_event_1M` bench scenarios pin both modes).

use crate::util::json::Json;
use std::collections::VecDeque;
use std::sync::Mutex;

/// Counters describing the ring's lifetime and current occupancy.
#[derive(Debug, Clone, Copy, Default)]
pub struct RecorderStats {
    /// Configured byte budget (0 = disabled).
    pub budget_bytes: usize,
    /// Events currently held.
    pub events: usize,
    /// Bytes currently held (serialized line lengths).
    pub bytes: usize,
    /// Total events ever recorded.
    pub recorded: u64,
    /// Total events evicted to stay within the budget.
    pub evicted: u64,
}

#[derive(Default)]
struct Inner {
    events: VecDeque<String>,
    bytes: usize,
    recorded: u64,
    evicted: u64,
}

/// Fixed-byte-budget ring buffer of serialized wide events.
pub struct FlightRecorder {
    budget: usize,
    inner: Mutex<Inner>,
}

impl FlightRecorder {
    /// Default ring budget: 1 MiB of serialized events (~thousands of
    /// requests at typical event sizes).
    pub const DEFAULT_BUDGET: usize = 1 << 20;

    pub fn new(budget_bytes: usize) -> FlightRecorder {
        FlightRecorder { budget: budget_bytes, inner: Mutex::new(Inner::default()) }
    }

    /// False when constructed with budget 0 — every record is a no-op.
    pub fn enabled(&self) -> bool {
        self.budget > 0
    }

    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Record one wide event. The closure builds the event and is only
    /// evaluated when the recorder is enabled, so a disabled recorder
    /// costs one branch (same contract as `span!`).
    pub fn record_with<F: FnOnce() -> Json>(&self, build: F) {
        if !self.enabled() {
            return;
        }
        let line = build().to_string();
        let mut g = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        g.recorded += 1;
        if line.len() > self.budget {
            // A single event larger than the whole ring would evict
            // everything and still not fit; drop it instead.
            g.evicted += 1;
            return;
        }
        g.bytes += line.len();
        g.events.push_back(line);
        while g.bytes > self.budget {
            match g.events.pop_front() {
                Some(old) => {
                    g.bytes -= old.len();
                    g.evicted += 1;
                }
                None => break,
            }
        }
    }

    /// Snapshot the ring oldest-first, one JSON line per event.
    pub fn dump(&self) -> Vec<String> {
        let g = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        g.events.iter().cloned().collect()
    }

    pub fn stats(&self) -> RecorderStats {
        let g = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        RecorderStats {
            budget_bytes: self.budget,
            events: g.events.len(),
            bytes: g.bytes,
            recorded: g.recorded,
            evicted: g.evicted,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::Cell;

    fn event(tag: usize) -> Json {
        Json::obj(vec![
            ("trace_id", Json::str(&format!("t{tag:08}"))),
            ("outcome", Json::str("ok")),
        ])
    }

    #[test]
    fn evicts_oldest_first_at_the_byte_budget() {
        let line_len = event(0).to_string().len();
        // Room for exactly three events.
        let rec = FlightRecorder::new(line_len * 3);
        for i in 0..10 {
            rec.record_with(|| event(i));
        }
        let lines = rec.dump();
        assert_eq!(lines.len(), 3, "ring holds exactly the budget");
        // Oldest-first dump of the three most recent events.
        for (i, line) in lines.iter().enumerate() {
            assert!(line.contains(&format!("t{:08}", 7 + i)), "line {i}: {line}");
            let parsed = Json::parse(line).expect("dump lines are valid JSON");
            assert_eq!(parsed.get("outcome").as_str(), Some("ok"));
        }
        let s = rec.stats();
        assert_eq!(s.events, 3);
        assert_eq!(s.bytes, line_len * 3);
        assert_eq!(s.recorded, 10);
        assert_eq!(s.evicted, 7);
        assert!(s.bytes <= s.budget_bytes);
    }

    #[test]
    fn disabled_recorder_never_evaluates_the_closure() {
        let rec = FlightRecorder::new(0);
        assert!(!rec.enabled());
        let evaluated = Cell::new(false);
        rec.record_with(|| {
            evaluated.set(true);
            event(0)
        });
        assert!(!evaluated.get());
        assert!(rec.dump().is_empty());
        assert_eq!(rec.stats().recorded, 0);
    }

    #[test]
    fn oversized_event_is_dropped_not_wedged() {
        let rec = FlightRecorder::new(8);
        rec.record_with(|| event(1));
        let s = rec.stats();
        assert_eq!(s.events, 0);
        assert_eq!(s.recorded, 1);
        assert_eq!(s.evicted, 1);
        // The ring still accepts events that do fit.
        let rec2 = FlightRecorder::new(4096);
        rec2.record_with(|| event(2));
        assert_eq!(rec2.stats().events, 1);
    }
}
