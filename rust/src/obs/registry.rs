//! Process-global metrics registry: counters, gauges, and log-linear
//! latency histograms, with a Prometheus text exposition.
//!
//! Counters and gauges are plain relaxed `AtomicU64`s handed out as
//! `Arc`s — call sites cache the `Arc` in a `OnceLock` so the hot path
//! is a single `fetch_add`. Histograms record nanoseconds and live
//! behind per-instance mutexes; the stage/queue-wait observation sites
//! are coarse (one lock per pipeline stage or dequeued job), so the
//! locks are uncontended in practice.

use super::hist::Histogram;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

/// Identifies one histogram series: a metric family plus an optional
/// single label (e.g. `stage="tmfg"`).
#[derive(Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct HistKey {
    pub metric: &'static str,
    pub label: Option<(&'static str, String)>,
}

/// Identifies one labeled counter series: a metric family plus a single
/// `key="value"` label pair (e.g. `tenant="acme"`).
pub type LabeledKey = (&'static str, &'static str, String);

#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<&'static str, Arc<AtomicU64>>>,
    labeled_counters: Mutex<BTreeMap<LabeledKey, Arc<AtomicU64>>>,
    gauges: Mutex<BTreeMap<&'static str, Arc<AtomicU64>>>,
    hists: Mutex<BTreeMap<HistKey, Arc<Mutex<Histogram>>>>,
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

impl Registry {
    /// Get or create a monotone counter.
    pub fn counter(&self, name: &'static str) -> Arc<AtomicU64> {
        lock(&self.counters).entry(name).or_default().clone()
    }

    /// Get or create a monotone counter carrying one label pair
    /// (per-series cardinality is bounded by the caller — the service
    /// caps tenant-id length and charset at decode time).
    pub fn counter_labeled(
        &self,
        metric: &'static str,
        key: &'static str,
        value: &str,
    ) -> Arc<AtomicU64> {
        lock(&self.labeled_counters)
            .entry((metric, key, value.to_string()))
            .or_default()
            .clone()
    }

    /// `(label value, count)` pairs for one labeled counter family, in
    /// sorted (BTreeMap) order — deterministic for wire responses.
    pub fn labeled_counter_values(&self, metric: &'static str) -> Vec<(String, u64)> {
        lock(&self.labeled_counters)
            .iter()
            .filter(|((m, _, _), _)| *m == metric)
            .map(|((_, _, v), c)| (v.clone(), c.load(Ordering::Relaxed)))
            .collect()
    }

    /// Get or create a gauge (stored as a u64 set with `store`).
    pub fn gauge(&self, name: &'static str) -> Arc<AtomicU64> {
        lock(&self.gauges).entry(name).or_default().clone()
    }

    /// Get or create a histogram series.
    pub fn hist(
        &self,
        metric: &'static str,
        label: Option<(&'static str, &str)>,
    ) -> Arc<Mutex<Histogram>> {
        let key = HistKey { metric, label: label.map(|(k, v)| (k, v.to_string())) };
        lock(&self.hists).entry(key).or_default().clone()
    }

    /// Record one latency observation in nanoseconds.
    pub fn observe_ns(&self, metric: &'static str, label: Option<(&'static str, &str)>, ns: u64) {
        let h = self.hist(metric, label);
        lock(&h).record(ns);
    }

    /// Record one latency observation in seconds (negative/NaN ignored).
    pub fn observe_secs(
        &self,
        metric: &'static str,
        label: Option<(&'static str, &str)>,
        secs: f64,
    ) {
        if secs.is_finite() && secs >= 0.0 {
            self.observe_ns(metric, label, (secs * 1e9).round() as u64);
        }
    }

    /// p50/p95/p99 in seconds for one series, `None` if it has no data.
    pub fn percentiles_secs(
        &self,
        metric: &'static str,
        label: Option<(&'static str, &str)>,
    ) -> Option<[f64; 3]> {
        let key = HistKey { metric, label: label.map(|(k, v)| (k, v.to_string())) };
        let h = lock(&self.hists).get(&key)?.clone();
        let h = lock(&h);
        if h.is_empty() {
            return None;
        }
        Some([0.50, 0.95, 0.99].map(|q| h.percentile(q) as f64 / 1e9))
    }

    /// Label values present for a labeled histogram family, in sorted
    /// (BTreeMap) order — deterministic for wire responses.
    pub fn hist_labels(&self, metric: &'static str) -> Vec<String> {
        lock(&self.hists)
            .keys()
            .filter(|k| k.metric == metric)
            .filter_map(|k| k.label.as_ref().map(|(_, v)| v.clone()))
            .collect()
    }

    /// Render the whole registry as Prometheus text exposition
    /// (version 0.0.4). Histogram series emit only their non-empty
    /// buckets (cumulative, ascending `le`) plus `+Inf`, `_sum`, and
    /// `_count`; values are seconds.
    pub fn prometheus(&self) -> String {
        let mut out = String::new();
        for (name, c) in lock(&self.counters).iter() {
            out.push_str(&format!("# TYPE {name} counter\n"));
            out.push_str(&format!("{name} {}\n", c.load(Ordering::Relaxed)));
        }
        // BTreeMap tuple keys group series by metric family, so one TYPE
        // line precedes each family's series.
        let mut last_labeled = "";
        for ((metric, key, value), c) in lock(&self.labeled_counters).iter() {
            if *metric != last_labeled {
                out.push_str(&format!("# TYPE {metric} counter\n"));
                last_labeled = metric;
            }
            out.push_str(&format!(
                "{metric}{{{key}=\"{value}\"}} {}\n",
                c.load(Ordering::Relaxed)
            ));
        }
        for (name, g) in lock(&self.gauges).iter() {
            out.push_str(&format!("# TYPE {name} gauge\n"));
            out.push_str(&format!("{name} {}\n", g.load(Ordering::Relaxed)));
        }
        let hists: Vec<(HistKey, Arc<Mutex<Histogram>>)> =
            lock(&self.hists).iter().map(|(k, v)| (k.clone(), v.clone())).collect();
        let mut last_family = "";
        for (key, h) in hists {
            let h = lock(&h).clone();
            if key.metric != last_family {
                out.push_str(&format!("# TYPE {} histogram\n", key.metric));
                last_family = key.metric;
            }
            let label = |extra: &str| match (&key.label, extra.is_empty()) {
                (Some((k, v)), true) => format!("{{{k}=\"{v}\"}}"),
                (Some((k, v)), false) => format!("{{{k}=\"{v}\",{extra}}}"),
                (None, true) => String::new(),
                (None, false) => format!("{{{extra}}}"),
            };
            for (edge, cum) in h.cumulative_buckets() {
                let le = format!("le=\"{}\"", edge as f64 / 1e9);
                out.push_str(&format!("{}_bucket{} {cum}\n", key.metric, label(&le)));
            }
            out.push_str(&format!(
                "{}_bucket{} {}\n",
                key.metric,
                label("le=\"+Inf\""),
                h.count()
            ));
            out.push_str(&format!(
                "{}_sum{} {}\n",
                key.metric,
                label(""),
                h.sum() as f64 / 1e9
            ));
            out.push_str(&format!("{}_count{} {}\n", key.metric, label(""), h.count()));
        }
        out
    }
}

/// The process-global registry.
pub fn registry() -> &'static Registry {
    static REG: OnceLock<Registry> = OnceLock::new();
    REG.get_or_init(Registry::default)
}

/// Metric-family names used across the crate (one place to keep the
/// wire docs, README, and call sites in sync).
pub mod names {
    /// Per-pipeline-stage latency histogram, label `stage`.
    pub const STAGE_SECONDS: &str = "tmfg_stage_duration_seconds";
    /// Dispatcher queue-wait histogram (submit → dequeue).
    pub const QUEUE_WAIT_SECONDS: &str = "tmfg_queue_wait_seconds";
    /// Parallel jobs posted to the `parlay` pool.
    pub const POOL_JOBS: &str = "tmfg_pool_jobs_posted_total";
    /// `run_chunked` calls that ran inline (nested / tiny / 1 thread).
    pub const POOL_SELF_EXEC: &str = "tmfg_pool_self_execute_total";
    /// Total workers (incl. the poster) that participated in pool jobs.
    pub const POOL_WORKERS_GRANTED: &str = "tmfg_pool_workers_granted_total";
    /// APSP oracle rows derived on demand, by backend.
    pub const ORACLE_ROWS_DENSE: &str = "tmfg_oracle_rows_dense_total";
    pub const ORACLE_ROWS_HUB: &str = "tmfg_oracle_rows_hub_total";
    /// Exact truncated-ball entries applied during hub row derivations.
    pub const ORACLE_BALL_ENTRIES: &str = "tmfg_oracle_ball_entries_total";
    /// Artifact-cache outcomes observed by plan executions.
    pub const CACHE_HITS: &str = "tmfg_artifact_cache_hits_total";
    pub const CACHE_MISSES: &str = "tmfg_artifact_cache_misses_total";
    /// Dispatch workers configured for the running service.
    pub const DISPATCH_WORKERS: &str = "tmfg_dispatch_workers";
    /// Connections accepted by the serving event loop.
    pub const CONNS_ACCEPTED: &str = "tmfg_conns_accepted_total";
    /// Currently open connections (gauge; summed across services).
    pub const CONNS_ACTIVE: &str = "tmfg_conns_active";
    /// Connections refused at accept by the `--max-conns` hard limit.
    pub const CONNS_REJECTED_LIMIT: &str = "tmfg_conns_rejected_limit_total";
    /// Requests rejected by per-tenant admission control, label `tenant`.
    pub const ADMISSION_REJECTED: &str = "tmfg_admission_rejected_total";
    /// Requests shed by dispatch-queue-depth backpressure.
    pub const OVERLOAD_REJECTED: &str = "tmfg_overload_rejected_total";
    /// Idle connections reaped by the deadline wheel.
    pub const REAPED_IDLE: &str = "tmfg_conns_reaped_idle_total";
    /// Event-loop wakeups (readiness, completion, or timer).
    pub const LOOP_WAKEUPS: &str = "tmfg_event_loop_wakeups_total";
    /// Requests shed at admission, label `cause` (`depth`/`delay`/`tenant`).
    pub const SHED_TOTAL: &str = "tmfg_shed_total";
    /// Latest sampled age of the oldest queued job, in microseconds
    /// (the CoDel-style admission signal; 0 when the queue is empty).
    pub const ADMISSION_QUEUE_DELAY_US: &str = "tmfg_admission_queue_delay_us";
    /// Flight-recorder ring occupancy (gauges, refreshed at scrape).
    pub const RECORDER_EVENTS: &str = "tmfg_flight_recorder_events";
    pub const RECORDER_BYTES: &str = "tmfg_flight_recorder_bytes";
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prometheus_text_shape() {
        let reg = Registry::default();
        reg.counter("tmfg_test_events_total").fetch_add(3, Ordering::Relaxed);
        reg.gauge("tmfg_test_workers").store(4, Ordering::Relaxed);
        reg.observe_secs(names::STAGE_SECONDS, Some(("stage", "tmfg")), 0.5);
        reg.observe_secs(names::STAGE_SECONDS, Some(("stage", "tmfg")), 1.0);
        reg.observe_secs(names::QUEUE_WAIT_SECONDS, None, 0.001);
        let text = reg.prometheus();
        assert!(text.contains("# TYPE tmfg_test_events_total counter"));
        assert!(text.contains("tmfg_test_events_total 3"));
        assert!(text.contains("tmfg_test_workers 4"));
        assert!(text.contains("# TYPE tmfg_stage_duration_seconds histogram"));
        assert!(text.contains("tmfg_stage_duration_seconds_bucket{stage=\"tmfg\",le=\"+Inf\"} 2"));
        assert!(text.contains("tmfg_stage_duration_seconds_count{stage=\"tmfg\"} 2"));
        assert!(text.contains("tmfg_queue_wait_seconds_bucket{le=\"+Inf\"} 1"));
        // ascending le edges within a series
        let edges: Vec<f64> = text
            .lines()
            .filter(|l| l.starts_with("tmfg_stage_duration_seconds_bucket") && !l.contains("+Inf"))
            .map(|l| {
                let s = l.split("le=\"").nth(1).unwrap();
                s.split('"').next().unwrap().parse::<f64>().unwrap()
            })
            .collect();
        assert!(edges.windows(2).all(|w| w[0] < w[1]), "{edges:?}");
    }

    #[test]
    fn percentiles_and_labels() {
        let reg = Registry::default();
        assert!(reg.percentiles_secs(names::STAGE_SECONDS, Some(("stage", "apsp"))).is_none());
        for ms in 1..=100u64 {
            reg.observe_ns(names::STAGE_SECONDS, Some(("stage", "apsp")), ms * 1_000_000);
        }
        let [p50, p95, p99] =
            reg.percentiles_secs(names::STAGE_SECONDS, Some(("stage", "apsp"))).unwrap();
        assert!(p50 <= p95 && p95 <= p99);
        assert!((0.04..=0.06).contains(&p50), "{p50}");
        assert!((0.09..=0.11).contains(&p99), "{p99}");
        assert_eq!(reg.hist_labels(names::STAGE_SECONDS), vec!["apsp".to_string()]);
    }

    #[test]
    fn labeled_counters_expose_per_series_values() {
        let reg = Registry::default();
        reg.counter_labeled(names::ADMISSION_REJECTED, "tenant", "acme")
            .fetch_add(2, Ordering::Relaxed);
        reg.counter_labeled(names::ADMISSION_REJECTED, "tenant", "beta")
            .fetch_add(1, Ordering::Relaxed);
        // Same series again → same underlying atomic.
        reg.counter_labeled(names::ADMISSION_REJECTED, "tenant", "acme")
            .fetch_add(3, Ordering::Relaxed);
        assert_eq!(
            reg.labeled_counter_values(names::ADMISSION_REJECTED),
            vec![("acme".to_string(), 5), ("beta".to_string(), 1)]
        );
        let text = reg.prometheus();
        assert!(text.contains("# TYPE tmfg_admission_rejected_total counter"));
        assert!(text.contains("tmfg_admission_rejected_total{tenant=\"acme\"} 5"));
        assert!(text.contains("tmfg_admission_rejected_total{tenant=\"beta\"} 1"));
        // One TYPE line per family, not per series.
        let type_lines = text
            .lines()
            .filter(|l| *l == "# TYPE tmfg_admission_rejected_total counter")
            .count();
        assert_eq!(type_lines, 1);
    }
}
