//! Chrome trace-event JSON export (Perfetto / `chrome://tracing`
//! loadable).
//!
//! Spans become balanced `"B"`/`"E"` duration-event pairs and instant
//! records become `"i"` events; each collecting thread gets its own
//! track (a `thread_name` metadata event per tid), so pool workers show
//! up as parallel lanes. Timestamps are microseconds relative to the
//! session epoch. The session's `trace_id` rides in `otherData`
//! together with the total dropped-record count.

use super::spans::ThreadSpans;
use crate::util::json::Json;
use std::time::Instant;

fn ev(ph: &str, kind: &str, name: &str, tid: u64, ts_us: f64) -> Vec<(&'static str, Json)> {
    vec![
        ("ph", Json::str(ph)),
        ("cat", Json::str(kind)),
        ("name", Json::str(if name.is_empty() { kind } else { name })),
        ("pid", Json::Num(1.0)),
        ("tid", Json::Num(tid as f64)),
        ("ts", Json::Num(ts_us)),
    ]
}

/// Build the Chrome trace-event document for one finished session.
pub fn chrome_trace(trace_id: &str, epoch: Instant, threads: &[ThreadSpans]) -> Json {
    let mut events = Vec::new();
    let mut dropped = 0u64;
    for t in threads {
        events.push(Json::obj(vec![
            ("ph", Json::str("M")),
            ("name", Json::str("thread_name")),
            ("pid", Json::Num(1.0)),
            ("tid", Json::Num(t.tid as f64)),
            (
                "args",
                Json::obj(vec![("name", Json::str(&format!("{} (tid {})", t.name, t.tid)))]),
            ),
        ]));
        dropped += t.dropped;
        for r in &t.records {
            let ts_us = r
                .start
                .checked_duration_since(epoch)
                .map(|d| d.as_nanos() as f64 / 1000.0)
                .unwrap_or(0.0);
            if r.instant {
                let mut e = ev("i", r.kind, &r.label, t.tid, ts_us);
                e.push(("s", Json::str("t")));
                events.push(Json::obj(e));
            } else {
                events.push(Json::obj(ev("B", r.kind, &r.label, t.tid, ts_us)));
                events.push(Json::obj(ev(
                    "E",
                    r.kind,
                    &r.label,
                    t.tid,
                    ts_us + r.dur_ns as f64 / 1000.0,
                )));
            }
        }
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ms")),
        (
            "otherData",
            Json::obj(vec![
                ("trace_id", Json::str(trace_id)),
                ("dropped", Json::Num(dropped as f64)),
            ]),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::spans::SpanRecord;

    fn fake_threads(epoch: Instant) -> Vec<ThreadSpans> {
        let rec = |kind, label: &str, off_ns: u64, dur_ns, instant| SpanRecord {
            kind,
            label: label.to_string(),
            start: epoch + std::time::Duration::from_nanos(off_ns),
            dur_ns,
            instant,
        };
        vec![
            ThreadSpans {
                tid: 1,
                name: "main".into(),
                records: vec![
                    rec("stage", "similarity", 0, 5_000, false),
                    rec("stage", "tmfg", 6_000, 9_000, false),
                    rec("cache", "miss", 100, 0, true),
                ],
                dropped: 0,
            },
            ThreadSpans {
                tid: 2,
                name: "parlay-0".into(),
                records: vec![rec("pool_job", "chunks=4", 6_500, 2_000, false)],
                dropped: 3,
            },
        ]
    }

    #[test]
    fn export_round_trips_and_balances() {
        let epoch = Instant::now();
        let doc = chrome_trace("t-test-1", epoch, &fake_threads(epoch));
        // Valid JSON round trip through the writer + parser.
        let text = doc.to_string();
        let back = Json::parse(&text).expect("trace JSON parses");
        assert_eq!(back.get("otherData").get("trace_id").as_str(), Some("t-test-1"));
        assert_eq!(back.get("otherData").get("dropped").as_usize(), Some(3));
        let events = back.get("traceEvents").as_arr().unwrap();
        // Balanced begin/end per tid, E never before its B.
        let mut depth = std::collections::BTreeMap::new();
        for e in events {
            let tid = e.get("tid").as_usize().unwrap();
            match e.get("ph").as_str().unwrap() {
                "B" => *depth.entry(tid).or_insert(0i64) += 1,
                "E" => {
                    let d = depth.entry(tid).or_insert(0i64);
                    *d -= 1;
                    assert!(*d >= 0, "E without B on tid {tid}");
                }
                "i" | "M" => {}
                other => panic!("unexpected ph {other}"),
            }
        }
        assert!(depth.values().all(|&d| d == 0), "unbalanced spans: {depth:?}");
        // One thread_name track per collecting thread.
        let tracks: Vec<&Json> =
            events.iter().filter(|e| e.get("ph").as_str() == Some("M")).collect();
        assert_eq!(tracks.len(), 2);
        // E timestamps trail their B by the span duration.
        let b = events
            .iter()
            .find(|e| e.get("ph").as_str() == Some("B") && e.get("name").as_str() == Some("tmfg"))
            .unwrap();
        let e = events
            .iter()
            .find(|e| e.get("ph").as_str() == Some("E") && e.get("name").as_str() == Some("tmfg"))
            .unwrap();
        let dt = e.get("ts").as_f64().unwrap() - b.get("ts").as_f64().unwrap();
        assert!((dt - 9.0).abs() < 1e-6, "{dt}");
    }
}
