//! Span collection: per-thread buffers, RAII guards, and exclusive
//! trace sessions.
//!
//! Recording is designed to never perturb the computation it observes
//! (the determinism suites run with tracing on):
//!
//! - The enabled check is one relaxed atomic load; when tracing is off
//!   (the default) `span!` costs that load and nothing else — no
//!   allocation, no clock read.
//! - When tracing is on, each thread appends to its **own** buffer.
//!   The buffer sits behind a mutex, but the owning thread is the only
//!   writer while a session runs — collection happens sequentially at
//!   `finish()`, after the traced workload has quiesced — so the fast
//!   path is an uncontended lock (no cross-thread ordering is ever
//!   introduced between workers).
//! - Buffers are bounded ([`MAX_SPANS_PER_THREAD`]); overflow drops
//!   records and counts the drops rather than growing or blocking.
//!
//! Sessions are exclusive: [`TraceSession::begin`] holds a global gate
//! for the session's lifetime, so concurrent `"trace":true` service
//! requests serialize instead of interleaving their collections. A
//! session captures *process-wide* activity between `begin` and
//! `finish` — in a busy service that includes spans from other
//! in-flight requests, which is exactly what the per-worker tracks are
//! for.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::Instant;

/// Per-thread span cap; beyond it records are dropped (and counted).
pub const MAX_SPANS_PER_THREAD: usize = 1 << 16;

static TRACING: AtomicBool = AtomicBool::new(false);

/// Is a trace session currently collecting? One relaxed load.
#[inline]
pub fn tracing_enabled() -> bool {
    TRACING.load(Ordering::Relaxed)
}

/// One recorded span or instant event.
#[derive(Clone)]
pub struct SpanRecord {
    /// Span kind — the Chrome trace `cat` (e.g. `"stage"`,
    /// `"tmfg_round"`, `"oracle_row"`, `"pool_job"`, `"queue_wait"`,
    /// `"cache"`, `"knn_phase"`).
    pub kind: &'static str,
    /// Human label; empty means "use the kind".
    pub label: String,
    pub start: Instant,
    pub dur_ns: u64,
    /// Instant event (a point in time) rather than a duration span.
    pub instant: bool,
}

/// All records collected on one thread, plus its identity.
pub struct ThreadSpans {
    pub tid: u64,
    pub name: String,
    pub records: Vec<SpanRecord>,
    pub dropped: u64,
}

struct ThreadBuf {
    tid: u64,
    name: String,
    inner: Mutex<(Vec<SpanRecord>, u64)>,
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

fn buffers() -> &'static Mutex<Vec<Arc<ThreadBuf>>> {
    static BUFS: OnceLock<Mutex<Vec<Arc<ThreadBuf>>>> = OnceLock::new();
    BUFS.get_or_init(|| Mutex::new(Vec::new()))
}

thread_local! {
    static LOCAL: Arc<ThreadBuf> = {
        static NEXT_TID: AtomicU64 = AtomicU64::new(1);
        let buf = Arc::new(ThreadBuf {
            tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
            name: std::thread::current().name().unwrap_or("main").to_string(),
            inner: Mutex::new((Vec::new(), 0)),
        });
        lock(buffers()).push(buf.clone());
        buf
    };
}

fn push(rec: SpanRecord) {
    LOCAL.with(|b| {
        let mut inner = lock(&b.inner);
        if inner.0.len() < MAX_SPANS_PER_THREAD {
            inner.0.push(rec);
        } else {
            inner.1 += 1;
        }
    });
}

/// Record a completed span with an explicit start and duration — for
/// retroactive measurements like dispatcher queue wait, where the
/// duration is only known at the end.
pub fn record_span(kind: &'static str, label: String, start: Instant, dur_ns: u64) {
    if tracing_enabled() {
        push(SpanRecord { kind, label, start, dur_ns, instant: false });
    }
}

/// Record an instant event (e.g. a cache hit).
pub fn event(kind: &'static str, label: impl FnOnce() -> String) {
    if tracing_enabled() {
        push(SpanRecord { kind, label: label(), start: Instant::now(), dur_ns: 0, instant: true });
    }
}

/// RAII span guard — create via the [`span!`](crate::span) macro. When
/// tracing is disabled construction is a no-op (the label closure is
/// never called).
pub struct SpanGuard {
    active: Option<(&'static str, String, Instant)>,
}

impl SpanGuard {
    #[inline]
    pub fn enter(kind: &'static str, label: impl FnOnce() -> String) -> SpanGuard {
        if !tracing_enabled() {
            return SpanGuard { active: None };
        }
        SpanGuard { active: Some((kind, label(), Instant::now())) }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some((kind, label, start)) = self.active.take() {
            let dur_ns = start.elapsed().as_nanos() as u64;
            push(SpanRecord { kind, label, start, dur_ns, instant: false });
        }
    }
}

/// Process-unique id for correlating a request with its trace; echoed
/// on every wire clustering response as `trace_id`.
pub fn next_trace_id() -> String {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    let wall = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_micros() as u64)
        .unwrap_or(0);
    format!("t{:012x}-{seq:04x}", wall & 0xffff_ffff_ffff)
}

thread_local! {
    /// The trace id of the request this thread is currently serving,
    /// if any — set by the service via [`TraceCtx::enter`] and read by
    /// `log_emit` to prefix log lines.
    static CURRENT_TRACE: RefCell<Option<String>> = const { RefCell::new(None) };
}

/// The active request trace id on this thread, if inside a
/// [`TraceCtx`] scope.
pub fn current_trace_id() -> Option<String> {
    CURRENT_TRACE.with(|c| c.borrow().clone())
}

/// RAII request-trace context: while alive, `log!` lines emitted from
/// this thread carry `[<trace_id>]` so server logs join against trace
/// and flight-log artifacts. Nests safely — dropping restores the
/// previous id.
pub struct TraceCtx {
    prev: Option<String>,
}

impl TraceCtx {
    pub fn enter(trace_id: &str) -> TraceCtx {
        let prev = CURRENT_TRACE.with(|c| c.replace(Some(trace_id.to_string())));
        TraceCtx { prev }
    }
}

impl Drop for TraceCtx {
    fn drop(&mut self) {
        let prev = self.prev.take();
        CURRENT_TRACE.with(|c| *c.borrow_mut() = prev);
    }
}

static SESSION_GATE: Mutex<()> = Mutex::new(());

/// An exclusive span-collection window. Construction clears all thread
/// buffers and enables recording; [`finish`](TraceSession::finish)
/// disables recording and returns everything collected, grouped by
/// thread.
pub struct TraceSession {
    id: String,
    epoch: Instant,
    _gate: MutexGuard<'static, ()>,
}

impl TraceSession {
    pub fn begin() -> TraceSession {
        let gate = SESSION_GATE.lock().unwrap_or_else(|p| p.into_inner());
        // No session is active (the gate serializes them), so no thread
        // is recording — clearing here cannot race a push.
        for buf in lock(buffers()).iter() {
            let mut inner = lock(&buf.inner);
            inner.0.clear();
            inner.1 = 0;
        }
        let session = TraceSession { id: next_trace_id(), epoch: Instant::now(), _gate: gate };
        TRACING.store(true, Ordering::SeqCst);
        session
    }

    pub fn id(&self) -> &str {
        &self.id
    }

    /// The instant recording started; event timestamps in the export
    /// are offsets from this.
    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    /// Stop recording and collect all spans, sequentially per thread.
    pub fn finish(self) -> (String, Instant, Vec<ThreadSpans>) {
        TRACING.store(false, Ordering::SeqCst);
        let mut out = Vec::new();
        for buf in lock(buffers()).iter() {
            let mut inner = lock(&buf.inner);
            let records = std::mem::take(&mut inner.0);
            let dropped = inner.1;
            inner.1 = 0;
            if !records.is_empty() || dropped > 0 {
                out.push(ThreadSpans {
                    tid: buf.tid,
                    name: buf.name.clone(),
                    records,
                    dropped,
                });
            }
        }
        out.sort_by_key(|t| t.tid);
        (self.id, self.epoch, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Serializes the tests in this module that depend on the global
    /// tracing flag (libtest runs them on concurrent threads).
    fn test_lock() -> MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().unwrap_or_else(|p| p.into_inner())
    }

    #[test]
    fn disabled_spans_record_nothing_and_skip_labels() {
        let _serial = test_lock();
        // Not under a session: the label closure must not run.
        let _g = SpanGuard::enter("stage", || panic!("label evaluated while disabled"));
        event("cache", || panic!("event label evaluated while disabled"));
        assert!(!tracing_enabled());
    }

    #[test]
    fn session_collects_balanced_spans_across_threads() {
        let _serial = test_lock();
        let session = TraceSession::begin();
        assert!(tracing_enabled());
        {
            let _outer = SpanGuard::enter("stage", || "outer".to_string());
            let _inner = SpanGuard::enter("tmfg_round", || "round 0".to_string());
        }
        event("cache", || "hit".to_string());
        let t = std::thread::Builder::new()
            .name("obs-test-worker".into())
            .spawn(|| {
                let _s = SpanGuard::enter("pool_job", || "job".to_string());
            })
            .unwrap();
        t.join().unwrap();
        let (id, _epoch, threads) = session.finish();
        assert!(!tracing_enabled());
        assert!(id.starts_with('t'));
        assert!(threads
            .iter()
            .any(|t| t.name == "obs-test-worker"
                && t.records.first().is_some_and(|r| r.kind == "pool_job")));
        // This thread's buffer holds exactly this test's records, in
        // RAII order: the inner span is recorded before the outer.
        let me = std::thread::current().name().unwrap_or("main").to_string();
        let mine = &threads.iter().find(|t| t.name == me).expect("own thread").records;
        assert_eq!(mine.len(), 3);
        assert_eq!(mine[0].kind, "tmfg_round");
        assert_eq!(mine[1].kind, "stage");
        assert_eq!(mine[1].label, "outer");
        assert!(mine[2].instant && mine[2].kind == "cache");
        assert!(mine[1].dur_ns >= mine[0].dur_ns);
    }

    #[test]
    fn trace_ids_unique() {
        let a = next_trace_id();
        let b = next_trace_id();
        assert_ne!(a, b);
    }

    #[test]
    fn trace_ctx_nests_and_restores() {
        assert_eq!(current_trace_id(), None);
        {
            let _outer = TraceCtx::enter("t-outer");
            assert_eq!(current_trace_id().as_deref(), Some("t-outer"));
            {
                let _inner = TraceCtx::enter("t-inner");
                assert_eq!(current_trace_id().as_deref(), Some("t-inner"));
            }
            assert_eq!(current_trace_id().as_deref(), Some("t-outer"));
        }
        assert_eq!(current_trace_id(), None);
    }

    #[test]
    fn trace_ctx_is_thread_local() {
        let _ctx = TraceCtx::enter("t-main");
        let other = std::thread::spawn(current_trace_id).join().unwrap();
        assert_eq!(other, None);
        assert_eq!(current_trace_id().as_deref(), Some("t-main"));
    }
}
