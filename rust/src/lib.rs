//! # tmfg — Faster Parallel Triangular Maximally Filtered Graphs and
//! # Hierarchical Clustering
//!
//! A full reproduction of Raphael & Shun (2024): parallel TMFG
//! construction (the PAR-TMFG baseline of Yu & Shun plus the paper's
//! CORR-TMFG and HEAP-TMFG), DBHT hierarchical clustering, exact and
//! approximate all-pairs shortest paths, and the complete evaluation
//! harness — organized as a three-layer system where the dense
//! similarity-matrix computation is AOT-compiled from JAX/Pallas to an
//! XLA executable driven from Rust via PJRT, and all graph algorithms run
//! on a from-scratch parallel-primitives substrate (`parlay`). On top of
//! the batch pipeline, the [`stream`] subsystem serves live time-series
//! traffic with O(n²) per-tick incremental correlation updates and
//! drift-gated topology reuse, and the [`sparse`] subsystem opens the
//! large-n workload with deterministic k-NN candidate graphs and
//! sparse-gain TMFG construction (O(n·k) memory instead of O(n²)).
//!
//! The public surface is the typed staged API in [`api`]: a
//! [`api::ClusterRequest`] builder over every input shape, a staged
//! [`api::Plan`] executor (Similarity → Tmfg → Apsp → Dbht → Cut, each
//! individually runnable with inspectable artifacts and timings), the
//! unified [`api::TmfgError`], and the versioned [`api::wire`] types of
//! the TCP service.
//!
//! Cross-cutting observability lives in [`obs`]: RAII tracing spans
//! (`span!`) collected into Chrome trace-event JSON, log-linear latency
//! histograms with a Prometheus exposition (`{"cmd": "metrics"}` on the
//! wire), and the leveled `log!` macro — all gated to a single relaxed
//! atomic load when disabled.
//!
//! The top-level `README.md` documents the three-layer architecture, the
//! streaming subsystem and its wire protocol, and how to run the
//! examples, benches, and experiments.
//!
//! Quick start:
//! ```no_run
//! use tmfg::api::{ClusterRequest, TmfgAlgo};
//!
//! let out = ClusterRequest::dataset("CBF")
//!     .scale(0.05)
//!     .algo(TmfgAlgo::Heap)
//!     .run()?;
//! println!("ARI = {:.3}", out.ari.unwrap_or(f64::NAN));
//! # Ok::<(), tmfg::api::TmfgError>(())
//! ```
//!
//! The original `Pipeline` remains as a thin compatibility facade
//! (legacy; prefer [`api::ClusterRequest`] in new code):
//! ```no_run
//! use tmfg::coordinator::pipeline::{Pipeline, PipelineConfig, TmfgAlgo};
//! use tmfg::data::synth::SynthSpec;
//!
//! let ds = SynthSpec::new("demo", 200, 64, 4).generate(42);
//! let cfg = PipelineConfig { algo: TmfgAlgo::Heap, ..Default::default() };
//! let out = Pipeline::new(cfg).run_dataset(&ds)?;
//! println!("ARI = {:.3}", out.ari.unwrap_or(f64::NAN));
//! # Ok::<(), tmfg::api::TmfgError>(())
//! ```

pub mod api;
pub mod apsp;
pub mod coordinator;
pub mod data;
pub mod dbht;
pub mod error;
pub mod metrics;
pub mod net;
pub mod obs;
pub mod parlay;
pub mod runtime;
pub mod sparse;
pub mod stream;
pub mod tmfg;
pub mod util;
