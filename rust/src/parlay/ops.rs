//! Flat data-parallel operations built on the pool: map, reduce, scan,
//! filter, pack, min/max location. These mirror the ParlayLib primitives
//! the paper's implementation uses.

use super::pool::{num_threads, parallel_for_chunks};
use super::SendPtr;

/// Parallel map: `out[i] = f(i)`.
pub fn par_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, grain: usize, f: F) -> Vec<T> {
    let mut out: Vec<T> = Vec::with_capacity(n);
    let ptr = SendPtr(out.as_mut_ptr());
    parallel_for_chunks(n, grain, |s, e| {
        for i in s..e {
            // SAFETY: each index written exactly once, buffer has capacity n.
            unsafe { ptr.write(i, f(i)) };
        }
    });
    unsafe { out.set_len(n) };
    out
}

/// Parallel map with per-chunk scratch: `out[i] = f(i, &mut scratch)`,
/// where `scratch` is default-constructed once per chunk and reused
/// across that chunk's iterations (no per-item allocation — the k-NN
/// builder's candidate buffers are the motivating user). Deterministic:
/// each slot is a pure function of its index, written exactly once.
pub fn par_map_scratch<T, S, F>(n: usize, grain: usize, f: F) -> Vec<T>
where
    T: Send,
    S: Default,
    F: Fn(usize, &mut S) -> T + Sync,
{
    let mut out: Vec<T> = Vec::with_capacity(n);
    let ptr = SendPtr(out.as_mut_ptr());
    parallel_for_chunks(n, grain, |s, e| {
        let mut scratch = S::default();
        for i in s..e {
            // SAFETY: each index written exactly once, buffer has capacity n.
            unsafe { ptr.write(i, f(i, &mut scratch)) };
        }
    });
    unsafe { out.set_len(n) };
    out
}

/// Triangle-balanced parallel iteration over the rows of an n×n symmetric
/// matrix: `f(i)` runs exactly once for every `i in 0..n`, with task h
/// covering rows h and n−1−h so long (early) and short (late)
/// upper-triangle rows pair up for load balance. Because each row is
/// visited exactly once, a body that writes cells (i, j≥i) — plus their
/// (j, i) mirrors — touches disjoint memory across calls, which is the
/// safety contract the `SendPtr` users of this helper rely on.
pub fn par_symmetric_rows<F: Fn(usize) + Sync>(n: usize, f: F) {
    super::pool::parallel_for(n.div_ceil(2), 1, |half| {
        f(half);
        let hi = n - 1 - half;
        if hi != half {
            f(hi);
        }
    });
}

/// Triangle-balanced parallel iteration over fixed row-*blocks* of an
/// n×n symmetric matrix: `f(lo, hi)` runs exactly once for every block
/// `[lo, hi)` of up to `block` consecutive rows (the last block may be
/// ragged), with task h covering blocks h and nb−1−h so long (early) and
/// short (late) upper-triangle blocks pair up for load balance — the
/// block-granular sibling of [`par_symmetric_rows`], for kernels that
/// amortize loads across several rows at once (the cache-blocked Gram
/// kernel is the motivating user). The block layout depends only on `n`
/// and `block` — never on the thread count — so a body with a fixed
/// intra-block order writes bit-identical output at every
/// `set_num_threads` setting. Each row belongs to exactly one block, so
/// a body writing cells (i, j≥i) for its rows plus their (j, i) mirrors
/// touches disjoint memory across calls (the `SendPtr` safety contract).
pub fn par_symmetric_blocks<F: Fn(usize, usize) + Sync>(n: usize, block: usize, f: F) {
    let b = block.max(1);
    let nb = n.div_ceil(b);
    super::pool::parallel_for(nb.div_ceil(2), 1, |half| {
        let run = |bi: usize| f(bi * b, ((bi + 1) * b).min(n));
        run(half);
        let hi = nb - 1 - half;
        if hi != half {
            run(hi);
        }
    });
}

/// Parallel reduce with an associative combiner. `id` must be the identity.
///
/// **Deterministic by construction**: items are folded left-to-right
/// inside fixed blocks of `grain` items (the block layout depends only on
/// `n` and `grain`, never on the thread count), and the block partials
/// are folded in block order. The result is therefore bit-identical for
/// every `set_num_threads` setting — including for combiners that are
/// only approximately associative, like floating-point addition — which
/// is the contract the determinism test suite pins down.
pub fn par_reduce<T, F, G>(n: usize, grain: usize, id: T, f: F, combine: G) -> T
where
    T: Send + Sync + Clone,
    F: Fn(usize) -> T + Sync,
    G: Fn(T, T) -> T + Sync + Send,
{
    if n == 0 {
        return id;
    }
    let bsize = grain.max(1);
    let nb = n.div_ceil(bsize);
    let idr = &id;
    let partials: Vec<T> = par_map(nb, 1, |b| {
        let lo = b * bsize;
        let hi = (lo + bsize).min(n);
        let mut acc = idr.clone();
        for i in lo..hi {
            acc = combine(acc, f(i));
        }
        acc
    });
    partials.into_iter().fold(id, combine)
}

/// Parallel sum of f64 values.
pub fn par_sum_f64<F: Fn(usize) -> f64 + Sync>(n: usize, f: F) -> f64 {
    par_reduce(n, 2048, 0.0f64, f, |a, b| a + b)
}

/// Index of the maximum value by `key` (ties → lowest index).
pub fn par_argmax<K: PartialOrd + Send + Sync + Clone, F: Fn(usize) -> K + Sync>(
    n: usize,
    grain: usize,
    key: F,
) -> Option<usize> {
    if n == 0 {
        return None;
    }
    let best = par_reduce(
        n,
        grain,
        None::<(usize, K)>,
        |i| Some((i, key(i))),
        |a, b| match (a, b) {
            (None, x) => x,
            (x, None) => x,
            (Some((ia, ka)), Some((ib, kb))) => {
                if kb > ka || (kb == ka && ib < ia) {
                    Some((ib, kb))
                } else {
                    Some((ia, ka))
                }
            }
        },
    );
    best.map(|(i, _)| i)
}

/// Exclusive prefix sum of `xs`; returns (scanned vector, total).
pub fn par_scan_usize(xs: &[usize]) -> (Vec<usize>, usize) {
    let n = xs.len();
    if n == 0 {
        return (Vec::new(), 0);
    }
    // Two-pass block scan.
    let nb = (num_threads() * 4).clamp(1, n);
    let bsize = n.div_ceil(nb);
    let nb = n.div_ceil(bsize);
    let mut block_sums = vec![0usize; nb];
    {
        let bs = SendPtr(block_sums.as_mut_ptr());
        parallel_for_chunks(nb, 1, |s, e| {
            for b in s..e {
                let lo = b * bsize;
                let hi = ((b + 1) * bsize).min(n);
                let sum: usize = xs[lo..hi].iter().sum();
                unsafe { bs.write(b, sum) };
            }
        });
    }
    let mut offsets = vec![0usize; nb];
    let mut acc = 0usize;
    for b in 0..nb {
        offsets[b] = acc;
        acc += block_sums[b];
    }
    let total = acc;
    let mut out: Vec<usize> = Vec::with_capacity(n);
    {
        let op = SendPtr(out.as_mut_ptr());
        parallel_for_chunks(nb, 1, |s, e| {
            for b in s..e {
                let lo = b * bsize;
                let hi = ((b + 1) * bsize).min(n);
                let mut running = offsets[b];
                for i in lo..hi {
                    unsafe { op.write(i, running) };
                    running += xs[i];
                }
            }
        });
    }
    unsafe { out.set_len(n) };
    (out, total)
}

/// Parallel filter: keep `i` where `pred(i)`, materialized via `f(i)`,
/// preserving index order.
pub fn par_filter<T, P, F>(n: usize, pred: P, f: F) -> Vec<T>
where
    T: Send,
    P: Fn(usize) -> bool + Sync,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let nb = (num_threads() * 4).clamp(1, n);
    let bsize = n.div_ceil(nb);
    let nb = n.div_ceil(bsize);
    let mut counts = vec![0usize; nb];
    {
        let cp = SendPtr(counts.as_mut_ptr());
        parallel_for_chunks(nb, 1, |s, e| {
            for b in s..e {
                let lo = b * bsize;
                let hi = ((b + 1) * bsize).min(n);
                let c = (lo..hi).filter(|&i| pred(i)).count();
                unsafe { cp.write(b, c) };
            }
        });
    }
    let (offsets, total) = par_scan_usize(&counts);
    let mut out: Vec<T> = Vec::with_capacity(total);
    {
        let op = SendPtr(out.as_mut_ptr());
        parallel_for_chunks(nb, 1, |s, e| {
            for b in s..e {
                let lo = b * bsize;
                let hi = ((b + 1) * bsize).min(n);
                let mut w = offsets[b];
                for i in lo..hi {
                    if pred(i) {
                        unsafe { op.write(w, f(i)) };
                        w += 1;
                    }
                }
            }
        });
    }
    unsafe { out.set_len(total) };
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_identity() {
        let v = par_map(10_000, 64, |i| i * 2);
        assert_eq!(v.len(), 10_000);
        assert!(v.iter().enumerate().all(|(i, &x)| x == i * 2));
    }

    #[test]
    fn map_scratch_matches_map() {
        let v = par_map_scratch(5_000, 16, |i, scratch: &mut Vec<usize>| {
            scratch.clear();
            scratch.extend(0..i % 7);
            i * 2 + scratch.len()
        });
        assert!(v.iter().enumerate().all(|(i, &x)| x == i * 2 + i % 7));
        assert!(par_map_scratch(0, 1, |i, _: &mut Vec<u8>| i).is_empty());
    }

    #[test]
    fn symmetric_rows_visit_each_exactly_once() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        for n in [0usize, 1, 2, 7, 8, 101] {
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            par_symmetric_rows(n, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "n={n}"
            );
        }
    }

    #[test]
    fn symmetric_blocks_cover_rows_exactly_once() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        for (n, block) in [(0usize, 4usize), (1, 4), (3, 4), (4, 4), (5, 4), (101, 4), (64, 8)] {
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            par_symmetric_blocks(n, block, |lo, hi| {
                assert!(lo < hi && hi <= n && hi - lo <= block, "[{lo},{hi}) n={n}");
                assert_eq!(lo % block, 0, "blocks start on fixed boundaries");
                for i in lo..hi {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "n={n} block={block}"
            );
        }
    }

    #[test]
    fn reduce_sum() {
        let n = 100_000usize;
        let s = par_reduce(n, 1024, 0usize, |i| i, |a, b| a + b);
        assert_eq!(s, n * (n - 1) / 2);
    }

    #[test]
    fn sum_f64_matches() {
        let xs: Vec<f64> = (0..50_000).map(|i| (i as f64).sin()).collect();
        let p = par_sum_f64(xs.len(), |i| xs[i]);
        let s: f64 = xs.iter().sum();
        assert!((p - s).abs() < 1e-6 * s.abs().max(1.0));
    }

    #[test]
    fn reduce_is_bit_identical_across_thread_counts() {
        // Float addition is not associative: the fold must use a fixed
        // block layout + block-order combine so the thread count can never
        // change the rounding. Pinned bit-for-bit here; the end-to-end
        // counterpart lives in rust/tests/determinism.rs.
        let xs: Vec<f64> = (0..37_123).map(|i| ((i as f64) * 0.73).sin() / 3.0).collect();
        let base = crate::parlay::with_threads(1, || par_sum_f64(xs.len(), |i| xs[i]));
        for t in [2usize, 3, 4, 8] {
            let s = crate::parlay::with_threads(t, || par_sum_f64(xs.len(), |i| xs[i]));
            assert_eq!(s.to_bits(), base.to_bits(), "t={t}");
        }
        // and repeated runs at the same count are identical too
        let again = par_sum_f64(xs.len(), |i| xs[i]);
        assert_eq!(again.to_bits(), base.to_bits());
    }

    #[test]
    fn argmax_finds_max_and_breaks_ties_low() {
        let mut xs = vec![1.0f64; 10_000];
        xs[7777] = 5.0;
        assert_eq!(par_argmax(xs.len(), 64, |i| xs[i]), Some(7777));
        let ys = vec![3.0f64; 1000];
        assert_eq!(par_argmax(ys.len(), 16, |i| ys[i]), Some(0));
        assert_eq!(par_argmax(0, 16, |_: usize| 0.0f64), None);
    }

    #[test]
    fn scan_exclusive() {
        let xs: Vec<usize> = (0..12_345).map(|i| i % 7).collect();
        let (sc, total) = par_scan_usize(&xs);
        let mut acc = 0;
        for i in 0..xs.len() {
            assert_eq!(sc[i], acc, "at {i}");
            acc += xs[i];
        }
        assert_eq!(total, acc);
        let (e, t) = par_scan_usize(&[]);
        assert!(e.is_empty() && t == 0);
    }

    #[test]
    fn filter_preserves_order() {
        let n = 54_321;
        let v = par_filter(n, |i| i % 3 == 0, |i| i);
        let expect: Vec<usize> = (0..n).filter(|i| i % 3 == 0).collect();
        assert_eq!(v, expect);
    }

    #[test]
    fn filter_all_none() {
        assert_eq!(par_filter(1000, |_| false, |i| i), Vec::<usize>::new());
        assert_eq!(par_filter(100, |_| true, |i| i).len(), 100);
    }
}
