//! Parallel LSD radix sort for `(f32, u32)` pairs — our stand-in for the
//! Google Highway vectorized sort (vqsort) used by OPT-TDBHT's "initial
//! sorting of correlations" step. Radix sort plays the same role: beat
//! comparison sorting on large arrays of f32 keys by using the key bits
//! directly, with word-level (rather than lane-level) data parallelism.
//!
//! The f32 keys are mapped to order-preserving u32s, inverted for
//! descending order, then sorted with 4 passes of 8-bit counting sort.
//! Each pass is two flat parallel phases (histogram, scatter) plus a small
//! sequential prefix over `nblocks × 256` counters.

use super::pool::{num_threads, parallel_for_chunks};
use super::SendPtr;

/// Map f32 to u32 such that u32 ascending order == f32 **descending**
/// order. NaNs map below every real number (sort last). Total order.
#[inline]
pub fn radix_key_desc(x: f32) -> u32 {
    if x.is_nan() {
        return u32::MAX; // last in ascending u32 order
    }
    let b = x.to_bits();
    // Standard order-preserving transform for ascending: flip sign bit for
    // positives, flip all bits for negatives. Then invert for descending.
    let asc = if b & 0x8000_0000 != 0 { !b } else { b ^ 0x8000_0000 };
    !asc
}

const RADIX_BITS: usize = 8;
const BUCKETS: usize = 1 << RADIX_BITS;

/// Sequential 4-pass counting sort of `(key, payload)` items ascending by
/// key, with a caller-provided scratch buffer (resized as needed) so hot
/// loops can sort many rows without reallocating (§Perf L3 iter. 5).
/// Stable. Result ends in `src`.
pub fn radix_sort_keyed_scratch(src: &mut Vec<(u32, u32)>, scratch: &mut Vec<(u32, u32)>) {
    let n = src.len();
    if n < 2 {
        return;
    }
    scratch.clear();
    scratch.resize(n, (0, 0));
    for pass in 0..(32 / RADIX_BITS) {
        let shift = pass * RADIX_BITS;
        let mut counts = [0usize; BUCKETS];
        for &(k, _) in src.iter() {
            counts[(k as usize >> shift) & (BUCKETS - 1)] += 1;
        }
        let mut acc = 0;
        let mut offsets = [0usize; BUCKETS];
        for b in 0..BUCKETS {
            offsets[b] = acc;
            acc += counts[b];
        }
        for &(k, p) in src.iter() {
            let b = (k as usize >> shift) & (BUCKETS - 1);
            scratch[offsets[b]] = (k, p);
            offsets[b] += 1;
        }
        std::mem::swap(src, scratch);
    }
    // 4 passes = even number of swaps → result is back in `src`.
}

/// Sort `pairs` in place by `radix_key_desc(pair.0)` ascending, i.e. by the
/// f32 key **descending**, NaNs last. Stable.
pub fn par_radix_sort_pairs_desc(pairs: &mut [(f32, u32)]) {
    let n = pairs.len();
    if n < 2 {
        return;
    }
    // Precompute (key, payload-index-into-original) tuples to avoid
    // re-deriving keys each pass.
    let mut src: Vec<(u32, (f32, u32))> = pairs.iter().map(|&p| (radix_key_desc(p.0), p)).collect();
    let mut dst: Vec<(u32, (f32, u32))> = Vec::with_capacity(n);
    unsafe { dst.set_len(n) };

    if n < 1 << 14 || num_threads() == 1 {
        // Sequential counting sort passes for small inputs.
        for pass in 0..(32 / RADIX_BITS) {
            let shift = pass * RADIX_BITS;
            let mut counts = [0usize; BUCKETS];
            for &(k, _) in src.iter() {
                counts[(k as usize >> shift) & (BUCKETS - 1)] += 1;
            }
            let mut acc = 0;
            let mut offsets = [0usize; BUCKETS];
            for b in 0..BUCKETS {
                offsets[b] = acc;
                acc += counts[b];
            }
            for &(k, p) in src.iter() {
                let b = (k as usize >> shift) & (BUCKETS - 1);
                dst[offsets[b]] = (k, p);
                offsets[b] += 1;
            }
            std::mem::swap(&mut src, &mut dst);
        }
    } else {
        let nblocks = (num_threads() * 4).min(n / 4096).max(1);
        let bsize = n.div_ceil(nblocks);
        let nblocks = n.div_ceil(bsize);
        let mut hist = vec![0usize; nblocks * BUCKETS];
        for pass in 0..(32 / RADIX_BITS) {
            let shift = pass * RADIX_BITS;
            // Phase 1: per-block histograms.
            {
                let hp = SendPtr(hist.as_mut_ptr());
                let sr = &src;
                parallel_for_chunks(nblocks, 1, |s, e| {
                    for blk in s..e {
                        let lo = blk * bsize;
                        let hi = ((blk + 1) * bsize).min(n);
                        let mut local = [0usize; BUCKETS];
                        for &(k, _) in &sr[lo..hi] {
                            local[(k as usize >> shift) & (BUCKETS - 1)] += 1;
                        }
                        for b in 0..BUCKETS {
                            // SAFETY: each block writes its own row.
                            unsafe { hp.write(blk * BUCKETS + b, local[b]) };
                        }
                    }
                });
            }
            // Phase 2: sequential prefix over buckets-major order (bucket 0
            // of all blocks, then bucket 1 of all blocks, …) — gives each
            // (block, bucket) its global write offset. Stable.
            let mut acc = 0usize;
            let mut offsets = vec![0usize; nblocks * BUCKETS];
            for b in 0..BUCKETS {
                for blk in 0..nblocks {
                    offsets[blk * BUCKETS + b] = acc;
                    acc += hist[blk * BUCKETS + b];
                }
            }
            // Phase 3: parallel scatter.
            {
                let dp = SendPtr(dst.as_mut_ptr());
                let sr = &src;
                let off = &offsets;
                parallel_for_chunks(nblocks, 1, |s, e| {
                    for blk in s..e {
                        let lo = blk * bsize;
                        let hi = ((blk + 1) * bsize).min(n);
                        let mut local = [0usize; BUCKETS];
                        local.copy_from_slice(&off[blk * BUCKETS..(blk + 1) * BUCKETS]);
                        for &(k, p) in &sr[lo..hi] {
                            let b = (k as usize >> shift) & (BUCKETS - 1);
                            // SAFETY: offset ranges are disjoint by construction.
                            unsafe { dp.write(local[b], (k, p)) };
                            local[b] += 1;
                        }
                    }
                });
            }
            std::mem::swap(&mut src, &mut dst);
        }
    }
    // 4 passes of 8 bits = even number of swaps → result is in `src`.
    for (out, (_, p)) in pairs.iter_mut().zip(src.into_iter()) {
        *out = p;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn key_order_preserving() {
        let vals = [
            f32::NEG_INFINITY,
            -1e30,
            -2.5,
            -0.0,
            0.0,
            1e-20,
            2.5,
            1e30,
            f32::INFINITY,
        ];
        // descending f32 order == ascending key order
        for w in vals.windows(2) {
            assert!(
                radix_key_desc(w[0]) >= radix_key_desc(w[1]),
                "{} vs {}",
                w[0],
                w[1]
            );
        }
        assert_eq!(radix_key_desc(f32::NAN), u32::MAX);
    }

    fn check_sorted_desc(v: &[(f32, u32)]) {
        let non_nan: Vec<_> = v.iter().take_while(|p| !p.0.is_nan()).collect();
        for w in non_nan.windows(2) {
            assert!(w[0].0 >= w[1].0, "{:?} before {:?}", w[0], w[1]);
        }
        for p in &v[non_nan.len()..] {
            assert!(p.0.is_nan());
        }
    }

    #[test]
    fn radix_matches_comparison_sort() {
        let mut r = Rng::new(4);
        for &n in &[0usize, 1, 2, 100, 5000, 60_000] {
            let mut v: Vec<(f32, u32)> = (0..n)
                .map(|i| ((r.next_f32() * 4.0 - 2.0), i as u32))
                .collect();
            let mut expect = v.clone();
            crate::parlay::sort::par_sort_pairs_desc(&mut expect);
            par_radix_sort_pairs_desc(&mut v);
            check_sorted_desc(&v);
            // keys must match exactly (payload order may differ only on ties;
            // both sorts are stable so full equality must hold)
            assert_eq!(v, expect, "n={n}");
        }
    }

    #[test]
    fn radix_handles_negatives_zeros_nan() {
        let mut v = vec![
            (0.5, 0),
            (-0.5, 1),
            (f32::NAN, 2),
            (0.0, 3),
            (-0.0, 4),
            (2.0, 5),
            (-3.0, 6),
        ];
        par_radix_sort_pairs_desc(&mut v);
        let keys: Vec<f32> = v.iter().map(|p| p.0).collect();
        assert_eq!(&keys[..5], &[2.0, 0.5, 0.0, -0.0, -0.5]);
        assert_eq!(keys[5], -3.0);
        assert!(keys[6].is_nan());
    }

    #[test]
    fn radix_stability() {
        let mut v: Vec<(f32, u32)> = (0..40_000).map(|i| (((i / 64) % 5) as f32, i as u32)).collect();
        par_radix_sort_pairs_desc(&mut v);
        for w in v.windows(2) {
            if w[0].0 == w[1].0 {
                assert!(w[0].1 < w[1].1);
            }
        }
    }

    #[test]
    fn radix_large_parallel_path() {
        let mut r = Rng::new(9);
        let n = 300_000;
        let mut v: Vec<(f32, u32)> = (0..n).map(|i| (r.next_f32() * 100.0 - 50.0, i as u32)).collect();
        par_radix_sort_pairs_desc(&mut v);
        check_sorted_desc(&v);
        assert_eq!(v.len(), n);
        let mut payloads: Vec<u32> = v.iter().map(|p| p.1).collect();
        payloads.sort_unstable();
        assert!(payloads.iter().enumerate().all(|(i, &p)| p == i as u32));
    }
}
