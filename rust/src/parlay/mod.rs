//! `parlay` — a from-scratch shared-memory parallel-primitives substrate
//! standing in for ParlayLib [Blelloch et al., SPAA'20], which the paper
//! uses for all of its parallelism. Provides a persistent fork-join thread
//! pool with a runtime-adjustable active-thread count (needed for the
//! paper's Fig. 3/4 core-count sweeps), flat data-parallel operations
//! (map/reduce/scan/filter), a parallel comparison sort (chunk sort +
//! merge-path parallel merging), and a parallel LSD radix sort for f32
//! keys (our stand-in for Google Highway's vqsort, used by OPT-TDBHT).

pub mod ops;
pub mod pool;
pub mod radix;
pub mod sort;

pub use ops::*;
pub use pool::{num_threads, parallel_for, parallel_for_chunks, set_num_threads, with_threads};
pub use radix::{par_radix_sort_pairs_desc, radix_key_desc};
pub use sort::{par_sort_by, par_sort_pairs_desc};

/// Wrapper making a raw mutable pointer Send+Sync so disjoint regions of a
/// buffer can be written from pool workers. Safety contract: callers must
/// guarantee the regions written by different chunks never overlap.
#[derive(Clone, Copy)]
pub struct SendPtr<T>(pub *mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    /// Raw pointer accessor — use this (not `.0`) inside closures so the
    /// edition-2021 disjoint-capture rules capture the `SendPtr` wrapper
    /// (which is Sync) rather than the bare `*mut T` (which is not).
    #[inline]
    pub fn ptr(&self) -> *mut T {
        self.0
    }

    /// # Safety
    /// `idx` must be in bounds and not concurrently written by another chunk.
    #[inline]
    pub unsafe fn write(&self, idx: usize, val: T) {
        self.0.add(idx).write(val);
    }

    /// # Safety
    /// `idx` must be in bounds; concurrent reads only.
    #[inline]
    pub unsafe fn read(&self, idx: usize) -> T
    where
        T: Copy,
    {
        self.0.add(idx).read()
    }
}
