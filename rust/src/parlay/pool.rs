//! Persistent fork-join thread pool.
//!
//! Design: one global pool of `P-1` workers (plus each calling thread).
//! A parallel-for posts a `Job` — a lifetime-erased chunk function plus an
//! atomic chunk cursor — into a shared job list, bumps an epoch, and wakes
//! workers. **Multiple OS threads may post jobs concurrently** (the
//! clustering service's dispatcher workers do exactly this): every active
//! job sits in the list and the pool's workers partition themselves
//! across the concurrent jobs, each picking the unfinished job with the
//! fewest participants. A posting thread always executes its own job too,
//! so every job makes progress even when it is granted zero workers — the
//! pool is deadlock-free by construction. Workers (and callers) grab
//! chunks with `fetch_add` until the cursor is exhausted; the last
//! finisher signals completion. Workers spin briefly before parking so
//! back-to-back parallel loops (the TMFG insertion loop!) pay
//! sub-microsecond dispatch instead of a futex round-trip.
//!
//! The *active thread count* is adjustable at runtime (`set_num_threads`)
//! — only workers with id < active-1 participate — which is how the
//! experiment harness reproduces the paper's core-count sweeps (Figs 3/4).
//!
//! Nested parallel calls from inside a worker (or from a chunk the caller
//! runs itself) execute sequentially (ParlayLib would fork; our
//! algorithms only use flat outer-level parallelism, and sequential
//! nesting keeps the chunk closures panic- and deadlock-free).

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Cached obs counters (one registry lookup per process; the accounting
/// itself is a relaxed `fetch_add` per posted job or fast-path call —
/// never per chunk, so the work loop is untouched).
fn jobs_posted_counter() -> &'static Arc<AtomicU64> {
    static C: OnceLock<Arc<AtomicU64>> = OnceLock::new();
    C.get_or_init(|| crate::obs::registry().counter(crate::obs::names::POOL_JOBS))
}

fn self_exec_counter() -> &'static Arc<AtomicU64> {
    static C: OnceLock<Arc<AtomicU64>> = OnceLock::new();
    C.get_or_init(|| crate::obs::registry().counter(crate::obs::names::POOL_SELF_EXEC))
}

fn workers_granted_counter() -> &'static Arc<AtomicU64> {
    static C: OnceLock<Arc<AtomicU64>> = OnceLock::new();
    C.get_or_init(|| crate::obs::registry().counter(crate::obs::names::POOL_WORKERS_GRANTED))
}

/// One posted parallel job: `func` processes chunk `[start, end)`.
struct Job {
    /// Lifetime-erased chunk closure. Valid until `completed == nchunks`
    /// is observed by the posting thread (which owns the real closure and
    /// blocks until then).
    func: *const (dyn Fn(usize, usize) + Sync),
    n: usize,
    chunk: usize,
    nchunks: usize,
    next: AtomicUsize,
    completed: AtomicUsize,
    /// Number of pool workers allowed to participate (callers always do).
    worker_limit: usize,
    /// Threads currently working this job — used to spread workers across
    /// concurrent jobs (least-loaded job first). Purely advisory.
    participants: AtomicUsize,
    /// Pool workers that ever joined this job (monotone; the poster is
    /// not counted). Read once at retirement for the obs
    /// workers-granted counter.
    joined: AtomicUsize,
    done_lock: Mutex<bool>,
    done_cv: Condvar,
}

unsafe impl Send for Job {}
unsafe impl Sync for Job {}

impl Job {
    /// Pull chunks until the cursor is exhausted. Returns when no work is left.
    fn work(&self) {
        loop {
            let c = self.next.fetch_add(1, Ordering::Relaxed);
            if c >= self.nchunks {
                return;
            }
            let start = c * self.chunk;
            let end = (start + self.chunk).min(self.n);
            // SAFETY: the posting thread keeps the closure alive until all
            // chunks complete; we only run chunks we claimed (and claiming
            // a chunk forbids `completed` from reaching `nchunks` before we
            // finish it, so the poster cannot have returned yet).
            unsafe { (*self.func)(start, end) };
            let fin = self.completed.fetch_add(1, Ordering::AcqRel) + 1;
            if fin == self.nchunks {
                let mut done = self.done_lock.lock().unwrap();
                *done = true;
                self.done_cv.notify_all();
            }
        }
    }

    /// Does this job still have unclaimed chunks?
    fn has_work(&self) -> bool {
        self.next.load(Ordering::Relaxed) < self.nchunks
    }
}

struct Shared {
    /// Epoch counter; bumped on every post. Workers spin on this.
    epoch: AtomicU64,
    /// Active jobs from (possibly concurrent) posting threads. Posters
    /// push on post and remove their own entry after completion.
    jobs: Mutex<Vec<Arc<Job>>>,
    cv: Condvar,
    shutdown: AtomicBool,
    active: AtomicUsize,
}

pub struct Pool {
    shared: Arc<Shared>,
    n_workers: usize,
}

thread_local! {
    /// True while executing inside a pool worker (or inside a chunk run by
    /// the caller) — makes nested parallel calls sequential.
    static IN_PARALLEL: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

const SPIN_ROUNDS: u32 = 20_000;

fn worker_loop(shared: Arc<Shared>, id: usize) {
    let mut seen_epoch: u64 = 0;
    loop {
        // Work phase: keep helping jobs until none we are eligible for
        // remain. `seen_epoch` is read under the jobs lock, so a job
        // posted after our scan is guaranteed to have bumped the epoch
        // past it (posters bump while holding the lock) — no lost wakeup.
        loop {
            if shared.shutdown.load(Ordering::Acquire) {
                return;
            }
            let job = {
                let guard = shared.jobs.lock().unwrap();
                seen_epoch = shared.epoch.load(Ordering::Acquire);
                guard
                    .iter()
                    .filter(|j| id < j.worker_limit && j.has_work())
                    .min_by_key(|j| j.participants.load(Ordering::Relaxed))
                    .cloned()
            };
            let Some(job) = job else { break };
            job.participants.fetch_add(1, Ordering::Relaxed);
            job.joined.fetch_add(1, Ordering::Relaxed);
            IN_PARALLEL.with(|f| f.set(true));
            job.work();
            IN_PARALLEL.with(|f| f.set(false));
            job.participants.fetch_sub(1, Ordering::Relaxed);
        }
        // Idle phase: spin briefly waiting for a new epoch, then park.
        let mut spins = 0u32;
        loop {
            if shared.shutdown.load(Ordering::Acquire) {
                return;
            }
            if shared.epoch.load(Ordering::Acquire) != seen_epoch {
                break;
            }
            spins += 1;
            if spins < SPIN_ROUNDS {
                std::hint::spin_loop();
            } else {
                let mut guard = shared.jobs.lock().unwrap();
                while shared.epoch.load(Ordering::Acquire) == seen_epoch
                    && !shared.shutdown.load(Ordering::Acquire)
                {
                    guard = shared.cv.wait(guard).unwrap();
                }
                break;
            }
        }
    }
}

impl Pool {
    fn new(n_workers: usize) -> Pool {
        let shared = Arc::new(Shared {
            epoch: AtomicU64::new(0),
            jobs: Mutex::new(Vec::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            active: AtomicUsize::new(1),
        });
        // Degrade gracefully if the OS refuses a thread: stop spawning
        // (worker ids must stay contiguous for `worker_limit`) and run
        // with whatever came up — never panic from pool initialization.
        let mut spawned = 0usize;
        for id in 0..n_workers {
            let sh = shared.clone();
            let spawn = std::thread::Builder::new()
                .name(format!("parlay-{id}"))
                .spawn(move || worker_loop(sh, id));
            match spawn {
                Ok(_) => spawned += 1,
                Err(_) => break,
            }
        }
        shared.active.store(spawned + 1, Ordering::Relaxed);
        Pool { shared, n_workers: spawned }
    }

    fn global() -> &'static Pool {
        static POOL: OnceLock<Pool> = OnceLock::new();
        POOL.get_or_init(|| {
            let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            let n = std::env::var("PARLAY_NUM_THREADS")
                .ok()
                .and_then(|v| v.parse::<usize>().ok())
                .unwrap_or(hw)
                .max(1);
            Pool::new(n.saturating_sub(1))
        })
    }

    /// Run `f(start, end)` over chunks of `[0, n)` on the active threads.
    /// Safe to call from multiple OS threads at once: each call posts its
    /// own job, executes it itself (guaranteed progress), and workers
    /// spread across whatever jobs are in flight.
    fn run_chunked<F: Fn(usize, usize) + Sync>(&self, n: usize, grain: usize, f: F) {
        if n == 0 {
            return;
        }
        let active = self.shared.active.load(Ordering::Relaxed).min(self.n_workers + 1);
        let nested = IN_PARALLEL.with(|fl| fl.get());
        if active <= 1 || n <= grain || nested {
            self_exec_counter().fetch_add(1, Ordering::Relaxed);
            f(0, n);
            return;
        }
        // ~8 chunks per active thread for load balance, but ≥ grain each.
        let chunk = grain.max(n.div_ceil(active * 8)).max(1);
        let nchunks = n.div_ceil(chunk);
        if nchunks <= 1 {
            self_exec_counter().fetch_add(1, Ordering::Relaxed);
            f(0, n);
            return;
        }
        jobs_posted_counter().fetch_add(1, Ordering::Relaxed);
        let _span = crate::span!("pool_job", "n={n} chunks={nchunks}");

        // Erase the closure's lifetime: we guarantee below that we do not
        // return until every chunk has completed.
        let func: &(dyn Fn(usize, usize) + Sync) = &f;
        let func: *const (dyn Fn(usize, usize) + Sync) =
            unsafe { std::mem::transmute(func) };
        let job = Arc::new(Job {
            func,
            n,
            chunk,
            nchunks,
            next: AtomicUsize::new(0),
            completed: AtomicUsize::new(0),
            worker_limit: active - 1,
            participants: AtomicUsize::new(1), // the caller
            joined: AtomicUsize::new(0),
            done_lock: Mutex::new(false),
            done_cv: Condvar::new(),
        });
        {
            let mut jobs = self.shared.jobs.lock().unwrap();
            jobs.push(job.clone());
            self.shared.epoch.fetch_add(1, Ordering::Release);
            self.shared.cv.notify_all();
        }
        // The caller participates too (and alone suffices for progress).
        IN_PARALLEL.with(|fl| fl.set(true));
        job.work();
        IN_PARALLEL.with(|fl| fl.set(false));
        // Wait for stragglers: spin a little, then block on the condvar.
        let mut spins = 0u32;
        while job.completed.load(Ordering::Acquire) < nchunks {
            spins += 1;
            if spins < SPIN_ROUNDS {
                std::hint::spin_loop();
            } else {
                let mut done = job.done_lock.lock().unwrap();
                while !*done {
                    done = job.done_cv.wait(done).unwrap();
                }
                break;
            }
        }
        // Retire the job so workers stop scanning it.
        {
            let mut jobs = self.shared.jobs.lock().unwrap();
            if let Some(pos) = jobs.iter().position(|j| Arc::ptr_eq(j, &job)) {
                jobs.remove(pos);
            }
        }
        // Poster + every pool worker that ever joined.
        workers_granted_counter()
            .fetch_add(1 + job.joined.load(Ordering::Relaxed) as u64, Ordering::Relaxed);
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        let _g = self.shared.jobs.lock().unwrap();
        self.shared.cv.notify_all();
    }
}

/// Number of active threads (including the caller).
pub fn num_threads() -> usize {
    let p = Pool::global();
    p.shared.active.load(Ordering::Relaxed).min(p.n_workers + 1)
}

/// Set the number of active threads (including the caller); clamped to
/// [1, hardware]. Used by the core-count sweep experiments.
pub fn set_num_threads(t: usize) {
    let p = Pool::global();
    p.shared.active.store(t.clamp(1, p.n_workers + 1), Ordering::Relaxed);
}

/// Run `f` with the active-thread count temporarily set to `t`.
pub fn with_threads<R>(t: usize, f: impl FnOnce() -> R) -> R {
    let prev = num_threads();
    set_num_threads(t);
    let r = f();
    set_num_threads(prev);
    r
}

/// Parallel for over `i in [0, n)` with a grain-size hint.
pub fn parallel_for<F: Fn(usize) + Sync>(n: usize, grain: usize, f: F) {
    Pool::global().run_chunked(n, grain.max(1), |s, e| {
        for i in s..e {
            f(i);
        }
    });
}

/// Parallel for over chunks `[start, end)` of `[0, n)` — use when per-chunk
/// setup (buffers, local accumulators) matters.
pub fn parallel_for_chunks<F: Fn(usize, usize) + Sync>(n: usize, grain: usize, f: F) {
    Pool::global().run_chunked(n, grain.max(1), f);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64 as TestAtomic;

    #[test]
    fn covers_all_indices_once() {
        let n = 100_000;
        let hits: Vec<TestAtomic> = (0..n).map(|_| TestAtomic::new(0)).collect();
        parallel_for(n, 64, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn chunks_partition_range() {
        let n = 12_345;
        let total = TestAtomic::new(0);
        parallel_for_chunks(n, 10, |s, e| {
            assert!(s < e && e <= n);
            total.fetch_add((e - s) as u64, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), n as u64);
    }

    #[test]
    fn empty_and_tiny() {
        parallel_for(0, 1, |_| panic!("should not run"));
        let c = TestAtomic::new(0);
        parallel_for(1, 1024, |_| {
            c.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(c.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn nested_runs_sequentially() {
        // Regression: nested parallel calls must run inline, not deadlock.
        let n = 1000;
        let c = TestAtomic::new(0);
        parallel_for(n, 1, |_| {
            parallel_for(10, 1, |_| {
                c.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(c.load(Ordering::Relaxed), (n * 10) as u64);
    }

    #[test]
    fn with_threads_restores() {
        let before = num_threads();
        let inside = with_threads(1, num_threads);
        assert_eq!(inside, 1);
        assert_eq!(num_threads(), before);
    }

    #[test]
    fn single_thread_mode_works() {
        with_threads(1, || {
            let n = 10_000;
            let c = TestAtomic::new(0);
            parallel_for(n, 16, |_| {
                c.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(c.load(Ordering::Relaxed), n as u64);
        });
    }

    #[test]
    fn many_consecutive_small_jobs() {
        // Stress the spin/park dispatch path.
        for round in 0..2000 {
            let c = TestAtomic::new(0);
            parallel_for(257, 16, |_| {
                c.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(c.load(Ordering::Relaxed), 257, "round {round}");
        }
    }

    #[test]
    fn concurrent_posters_all_complete() {
        // Multiple OS threads issuing parallel sections simultaneously:
        // every poster's job must cover its full range exactly once.
        let handles: Vec<_> = (0..4)
            .map(|_| {
                std::thread::spawn(|| {
                    let c = TestAtomic::new(0);
                    parallel_for(50_000, 64, |_| {
                        c.fetch_add(1, Ordering::Relaxed);
                    });
                    c.load(Ordering::Relaxed)
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 50_000);
        }
    }

    #[test]
    fn overlapping_posters_observe_full_chunk_coverage() {
        // Two OS threads posting overlapping parallel_fors (a barrier
        // forces the overlap): both must complete, and each must observe
        // every index of its own range exactly once — the concurrent-
        // caller contract the service's dispatcher workers rely on.
        let barrier = std::sync::Arc::new(std::sync::Barrier::new(2));
        let handles: Vec<_> = (0..2)
            .map(|t| {
                let barrier = barrier.clone();
                std::thread::spawn(move || {
                    let n = 80_000 + t * 1000; // distinct ranges
                    let hits: Vec<TestAtomic> = (0..n).map(|_| TestAtomic::new(0)).collect();
                    for round in 0..20u64 {
                        barrier.wait();
                        parallel_for(n, 32, |i| {
                            hits[i].fetch_add(1, Ordering::Relaxed);
                        });
                        for (i, h) in hits.iter().enumerate() {
                            assert_eq!(
                                h.load(Ordering::Relaxed),
                                round + 1,
                                "thread {t} round {round} index {i}"
                            );
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn concurrent_posters_progress_with_few_active_workers() {
        // With the active count pinned to 2 (at most 1 pool worker
        // participates), three simultaneous posters can each be granted
        // zero workers — self-execution must still complete all of them.
        with_threads(2, || {
            let barrier = std::sync::Arc::new(std::sync::Barrier::new(3));
            let handles: Vec<_> = (0..3)
                .map(|_| {
                    let barrier = barrier.clone();
                    std::thread::spawn(move || {
                        barrier.wait();
                        let c = TestAtomic::new(0);
                        parallel_for(30_000, 16, |_| {
                            c.fetch_add(1, Ordering::Relaxed);
                        });
                        c.load(Ordering::Relaxed)
                    })
                })
                .collect();
            for h in handles {
                assert_eq!(h.join().unwrap(), 30_000);
            }
        });
    }
}
