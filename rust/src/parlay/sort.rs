//! Parallel comparison sort: per-chunk pdqsort (std unstable sort) followed
//! by log(chunks) rounds of pairwise merging, where each merge is itself
//! parallelized by merge-path (co-rank) splitting — so every round is a
//! flat parallel-for, compatible with the pool's flat execution model.

use super::pool::{num_threads, parallel_for_chunks};
use super::SendPtr;
use std::cmp::Ordering;

/// Find split point for merging: the number of elements of `a` that go
/// before position `k` of the merged output (co-rank). Stable: elements of
/// `a` win ties (a-before-b ordering is preserved).
fn co_rank<T, C: Fn(&T, &T) -> Ordering>(k: usize, a: &[T], b: &[T], cmp: &C) -> (usize, usize) {
    let mut lo = k.saturating_sub(b.len());
    let mut hi = k.min(a.len());
    while lo < hi {
        let i = (lo + hi) / 2; // elements taken from a
        let j = k - i - 1;
        // a[i] vs b[j]: if a[i] <= b[j] (stable), we can take more from a.
        if j < b.len() && cmp(&a[i], &b[j]) != Ordering::Greater {
            lo = i + 1;
        } else {
            hi = i;
        }
    }
    // Validate boundary: ensure b side doesn't violate order.
    let mut i = lo;
    while i > 0 {
        let j = k - i;
        if j < b.len() && cmp(&b[j], &a[i - 1]) == Ordering::Less {
            i -= 1;
        } else {
            break;
        }
    }
    (i, k - i)
}

/// Sequential stable merge of `a` and `b` into `out` (len = a.len()+b.len()).
fn seq_merge<T: Copy, C: Fn(&T, &T) -> Ordering>(a: &[T], b: &[T], out: &mut [T], cmp: &C) {
    debug_assert_eq!(out.len(), a.len() + b.len());
    let (mut i, mut j, mut k) = (0, 0, 0);
    while i < a.len() && j < b.len() {
        if cmp(&a[i], &b[j]) != Ordering::Greater {
            out[k] = a[i];
            i += 1;
        } else {
            out[k] = b[j];
            j += 1;
        }
        k += 1;
    }
    while i < a.len() {
        out[k] = a[i];
        i += 1;
        k += 1;
    }
    while j < b.len() {
        out[k] = b[j];
        j += 1;
        k += 1;
    }
}

/// Parallel merge of `a` and `b` into `out` using merge-path splitting.
fn par_merge<T: Copy + Send + Sync, C: Fn(&T, &T) -> Ordering + Sync>(
    a: &[T],
    b: &[T],
    out: &mut [T],
    grain: usize,
    cmp: &C,
) {
    let total = a.len() + b.len();
    if total <= grain.max(1) * 2 {
        seq_merge(a, b, out, cmp);
        return;
    }
    let nseg = (total.div_ceil(grain)).min(num_threads() * 4).max(1);
    let seg = total.div_ceil(nseg);
    let optr = SendPtr(out.as_mut_ptr());
    parallel_for_chunks(nseg, 1, |ss, se| {
        for s in ss..se {
            let k0 = s * seg;
            let k1 = ((s + 1) * seg).min(total);
            if k0 >= k1 {
                continue;
            }
            let (i0, j0) = co_rank(k0, a, b, cmp);
            let (i1, j1) = co_rank(k1, a, b, cmp);
            // SAFETY: segments [k0,k1) are disjoint across s.
            let dst =
                unsafe { std::slice::from_raw_parts_mut(optr.ptr().add(k0), k1 - k0) };
            seq_merge(&a[i0..i1], &b[j0..j1], dst, cmp);
        }
    });
}

/// Parallel stable sort by comparator.
pub fn par_sort_by<T: Copy + Send + Sync, C: Fn(&T, &T) -> Ordering + Sync>(v: &mut [T], cmp: C) {
    let n = v.len();
    if n < 4096 || num_threads() == 1 {
        v.sort_by(&cmp);
        return;
    }
    let nchunks = (num_threads() * 2).min(n / 2048).max(2);
    let csize = n.div_ceil(nchunks);
    let nchunks = n.div_ceil(csize);
    // Sort chunks in parallel (in place).
    {
        let vptr = SendPtr(v.as_mut_ptr());
        parallel_for_chunks(nchunks, 1, |s, e| {
            for c in s..e {
                let lo = c * csize;
                let hi = ((c + 1) * csize).min(n);
                // SAFETY: chunks are disjoint.
                let chunk = unsafe { std::slice::from_raw_parts_mut(vptr.ptr().add(lo), hi - lo) };
                chunk.sort_by(&cmp);
            }
        });
    }
    // Merge rounds, ping-ponging between v and a buffer.
    let mut buf: Vec<T> = Vec::with_capacity(n);
    unsafe { buf.set_len(n) };
    let mut width = csize;
    let mut src_is_v = true;
    while width < n {
        {
            let (src, dst): (&[T], &mut [T]) = if src_is_v {
                (unsafe { std::slice::from_raw_parts(v.as_ptr(), n) }, &mut buf[..])
            } else {
                (unsafe { std::slice::from_raw_parts(buf.as_ptr(), n) }, &mut *v)
            };
            let npairs = n.div_ceil(2 * width);
            // Each pair merge is internally parallel; do pairs one at a time
            // when few, or let outer loop be sequential (merges are parallel).
            for p in 0..npairs {
                let lo = p * 2 * width;
                let mid = (lo + width).min(n);
                let hi = (lo + 2 * width).min(n);
                par_merge(&src[lo..mid], &src[mid..hi], &mut dst[lo..hi], 4096, &cmp);
            }
        }
        src_is_v = !src_is_v;
        width *= 2;
    }
    if !src_is_v {
        v.copy_from_slice(&buf);
    }
}

/// Sort `(f32 key, u32 payload)` pairs by key **descending** (the order
/// CORR-TMFG needs: most-similar first). NaN keys sort last. Stable.
pub fn par_sort_pairs_desc(pairs: &mut [(f32, u32)]) {
    par_sort_by(pairs, |a, b| {
        // descending by key; total order with NaN last
        match (a.0.is_nan(), b.0.is_nan()) {
            (true, true) => Ordering::Equal,
            (true, false) => Ordering::Greater,
            (false, true) => Ordering::Less,
            (false, false) => b.0.partial_cmp(&a.0).unwrap(),
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn co_rank_boundaries() {
        let a = [1, 3, 5, 7];
        let b = [2, 4, 6, 8];
        let cmp = |x: &i32, y: &i32| x.cmp(y);
        for k in 0..=8 {
            let (i, j) = co_rank(k, &a, &b, &cmp);
            assert_eq!(i + j, k);
            // merged prefix of length k must contain the k smallest
            let mut all: Vec<i32> = a.iter().chain(b.iter()).cloned().collect();
            all.sort();
            let mut pre: Vec<i32> = a[..i].iter().chain(b[..j].iter()).cloned().collect();
            pre.sort();
            assert_eq!(pre, all[..k].to_vec(), "k={k}");
        }
    }

    #[test]
    fn merge_correct() {
        let mut r = Rng::new(1);
        for _ in 0..50 {
            let la = r.next_below(200);
            let lb = r.next_below(200);
            let mut a: Vec<i32> = (0..la).map(|_| r.next_below(100) as i32).collect();
            let mut b: Vec<i32> = (0..lb).map(|_| r.next_below(100) as i32).collect();
            a.sort();
            b.sort();
            let mut out = vec![0; la + lb];
            par_merge(&a, &b, &mut out, 16, &|x, y| x.cmp(y));
            let mut expect = [a, b].concat();
            expect.sort();
            assert_eq!(out, expect);
        }
    }

    #[test]
    fn sort_random_large() {
        let mut r = Rng::new(2);
        let mut v: Vec<u32> = (0..100_000).map(|_| r.next_u64() as u32).collect();
        let mut expect = v.clone();
        expect.sort_unstable();
        par_sort_by(&mut v, |a, b| a.cmp(b));
        assert_eq!(v, expect);
    }

    #[test]
    fn sort_already_sorted_and_reverse() {
        let mut v: Vec<u32> = (0..50_000).collect();
        let expect = v.clone();
        par_sort_by(&mut v, |a, b| a.cmp(b));
        assert_eq!(v, expect);
        let mut w: Vec<u32> = (0..50_000).rev().collect();
        par_sort_by(&mut w, |a, b| a.cmp(b));
        assert_eq!(w, expect);
    }

    #[test]
    fn sort_pairs_desc_with_nan() {
        let mut r = Rng::new(3);
        let mut v: Vec<(f32, u32)> = (0..20_000)
            .map(|i| (r.next_f32() * 2.0 - 1.0, i as u32))
            .collect();
        v[7] = (f32::NAN, 7);
        v[19_999] = (f32::NAN, 19_999);
        par_sort_pairs_desc(&mut v);
        // non-NaN prefix is non-increasing; NaNs at the end
        let non_nan = v.iter().take_while(|p| !p.0.is_nan()).collect::<Vec<_>>();
        assert_eq!(non_nan.len(), v.len() - 2);
        for w in non_nan.windows(2) {
            assert!(w[0].0 >= w[1].0);
        }
    }

    #[test]
    fn sort_small_sizes() {
        for n in [0usize, 1, 2, 3, 17, 100] {
            let mut r = Rng::new(n as u64);
            let mut v: Vec<u32> = (0..n).map(|_| r.next_u64() as u32).collect();
            let mut expect = v.clone();
            expect.sort_unstable();
            par_sort_by(&mut v, |a, b| a.cmp(b));
            assert_eq!(v, expect);
        }
    }

    #[test]
    fn sort_stability() {
        // pairs with equal keys must keep payload order
        let mut v: Vec<(f32, u32)> = (0..30_000).map(|i| (((i / 100) % 7) as f32, i as u32)).collect();
        par_sort_by(&mut v, |a, b| a.0.partial_cmp(&b.0).unwrap());
        for w in v.windows(2) {
            if w[0].0 == w[1].0 {
                assert!(w[0].1 < w[1].1, "stability violated: {:?} {:?}", w[0], w[1]);
            }
        }
    }
}
