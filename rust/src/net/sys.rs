//! Raw readiness syscalls: `epoll(7)` on Linux, portable `poll(2)`
//! everywhere else.
//!
//! std links libc, so plain `extern "C"` declarations resolve at link
//! time — no external crate needed (the repo's offline `vendor/`
//! policy). Only the handful of calls the event loop needs are
//! declared, with the constants copied from the Linux/POSIX ABI.

use std::collections::HashMap;
use std::io;
use std::os::unix::io::RawFd;
use std::time::Duration;

/// Readable-interest bit for [`register`](PollBackend::register) masks.
pub const INTEREST_READ: u8 = 0b01;
/// Writable-interest bit.
pub const INTEREST_WRITE: u8 = 0b10;

/// One readiness notification, translated out of the backend's ABI.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    /// The caller-chosen token the fd was registered under.
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
    /// Error or hangup: read the socket (to observe the EOF/error) and
    /// close it. Reported even when the registered interest mask is
    /// empty — both facilities always deliver failure conditions.
    pub failed: bool,
}

/// `Option<Duration>` → the millisecond timeout both syscalls take
/// (`None` = block forever). Nonzero sub-millisecond waits round up so
/// a near deadline can't spin at timeout 0.
fn timeout_ms(timeout: Option<Duration>) -> i32 {
    match timeout {
        None => -1,
        Some(d) if d.is_zero() => 0,
        Some(d) => d.as_micros().div_ceil(1000).min(i32::MAX as u128) as i32,
    }
}

fn cvt(r: i32) -> io::Result<i32> {
    if r < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(r)
    }
}

// ---- poll(2): the portable fallback ---------------------------------------

#[repr(C)]
#[derive(Clone, Copy)]
#[allow(non_camel_case_types)]
struct pollfd {
    fd: i32,
    events: i16,
    revents: i16,
}

#[cfg(target_os = "linux")]
#[allow(non_camel_case_types)]
type nfds_t = u64;
#[cfg(not(target_os = "linux"))]
#[allow(non_camel_case_types)]
type nfds_t = u32;

const POLLIN: i16 = 0x001;
const POLLOUT: i16 = 0x004;
const POLLERR: i16 = 0x008;
const POLLHUP: i16 = 0x010;
const POLLNVAL: i16 = 0x020;

extern "C" {
    fn poll(fds: *mut pollfd, nfds: nfds_t, timeout: i32) -> i32;
}

/// `poll(2)` backend: the registration set is rebuilt into a `pollfd`
/// array on every wait — O(conns) per call, the portable fallback's
/// price. Fine up to a few thousand connections.
pub struct PollBackend {
    /// `(fd, token, interest)` in insertion order.
    entries: Vec<(RawFd, u64, u8)>,
    /// token → index into `entries`.
    index: HashMap<u64, usize>,
}

impl PollBackend {
    pub fn new() -> PollBackend {
        PollBackend { entries: Vec::new(), index: HashMap::new() }
    }

    pub fn register(&mut self, fd: RawFd, token: u64, interest: u8) -> io::Result<()> {
        if self.index.contains_key(&token) {
            return Err(io::Error::new(
                io::ErrorKind::AlreadyExists,
                "token already registered",
            ));
        }
        self.index.insert(token, self.entries.len());
        self.entries.push((fd, token, interest));
        Ok(())
    }

    pub fn reregister(&mut self, _fd: RawFd, token: u64, interest: u8) -> io::Result<()> {
        let &i = self
            .index
            .get(&token)
            .ok_or_else(|| io::Error::new(io::ErrorKind::NotFound, "unknown token"))?;
        self.entries[i].2 = interest;
        Ok(())
    }

    pub fn deregister(&mut self, _fd: RawFd, token: u64) -> io::Result<()> {
        let i = self
            .index
            .remove(&token)
            .ok_or_else(|| io::Error::new(io::ErrorKind::NotFound, "unknown token"))?;
        self.entries.swap_remove(i);
        if let Some(&(_, moved, _)) = self.entries.get(i) {
            self.index.insert(moved, i);
        }
        Ok(())
    }

    pub fn wait(&mut self, events: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
        events.clear();
        let mut fds: Vec<pollfd> = self
            .entries
            .iter()
            .map(|&(fd, _, interest)| {
                let mut mask = 0i16;
                if interest & INTEREST_READ != 0 {
                    mask |= POLLIN;
                }
                if interest & INTEREST_WRITE != 0 {
                    mask |= POLLOUT;
                }
                pollfd { fd, events: mask, revents: 0 }
            })
            .collect();
        let n = unsafe { poll(fds.as_mut_ptr(), fds.len() as nfds_t, timeout_ms(timeout)) };
        if n < 0 {
            let e = io::Error::last_os_error();
            if e.kind() == io::ErrorKind::Interrupted {
                return Ok(()); // EINTR: surface zero events, caller re-loops
            }
            return Err(e);
        }
        for (pfd, &(_, token, _)) in fds.iter().zip(self.entries.iter()) {
            if pfd.revents == 0 {
                continue;
            }
            events.push(Event {
                token,
                readable: pfd.revents & POLLIN != 0,
                writable: pfd.revents & POLLOUT != 0,
                failed: pfd.revents & (POLLERR | POLLHUP | POLLNVAL) != 0,
            });
        }
        Ok(())
    }
}

// ---- epoll(7): the Linux fast path ----------------------------------------

#[cfg(target_os = "linux")]
mod epoll {
    use super::{cvt, timeout_ms, Event, INTEREST_READ, INTEREST_WRITE};
    use std::collections::HashMap;
    use std::io;
    use std::os::unix::io::RawFd;
    use std::time::Duration;

    // The kernel ABI packs the struct on x86-64 (12 bytes); other
    // architectures use natural alignment.
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    #[allow(non_camel_case_types)]
    struct epoll_event {
        events: u32,
        data: u64,
    }

    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;
    const EPOLL_CLOEXEC: i32 = 0x80000;

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut epoll_event) -> i32;
        fn epoll_wait(epfd: i32, events: *mut epoll_event, maxevents: i32, timeout: i32) -> i32;
        fn close(fd: i32) -> i32;
    }

    fn mask(interest: u8) -> u32 {
        let mut m = 0;
        if interest & INTEREST_READ != 0 {
            m |= EPOLLIN;
        }
        if interest & INTEREST_WRITE != 0 {
            m |= EPOLLOUT;
        }
        m // level-triggered (no EPOLLET): simplest correct mode
    }

    /// `epoll(7)` backend: O(ready) per wait, O(1) interest updates.
    pub struct EpollBackend {
        epfd: RawFd,
        /// token → fd: `epoll_ctl` MOD/DEL need the original fd.
        fds: HashMap<u64, RawFd>,
        buf: Vec<epoll_event>,
    }

    impl EpollBackend {
        pub fn new() -> io::Result<EpollBackend> {
            let epfd = cvt(unsafe { epoll_create1(EPOLL_CLOEXEC) })?;
            Ok(EpollBackend {
                epfd,
                fds: HashMap::new(),
                buf: vec![epoll_event { events: 0, data: 0 }; 256],
            })
        }

        fn ctl(&self, op: i32, fd: RawFd, token: u64, interest: u8) -> io::Result<()> {
            let mut ev = epoll_event { events: mask(interest), data: token };
            cvt(unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) })?;
            Ok(())
        }

        pub fn register(&mut self, fd: RawFd, token: u64, interest: u8) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, token, interest)?;
            self.fds.insert(token, fd);
            Ok(())
        }

        pub fn reregister(&mut self, fd: RawFd, token: u64, interest: u8) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, token, interest)
        }

        pub fn deregister(&mut self, fd: RawFd, token: u64) -> io::Result<()> {
            self.fds.remove(&token);
            self.ctl(EPOLL_CTL_DEL, fd, token, 0)
        }

        pub fn wait(
            &mut self,
            events: &mut Vec<Event>,
            timeout: Option<Duration>,
        ) -> io::Result<()> {
            events.clear();
            let n = unsafe {
                epoll_wait(
                    self.epfd,
                    self.buf.as_mut_ptr(),
                    self.buf.len() as i32,
                    timeout_ms(timeout),
                )
            };
            if n < 0 {
                let e = io::Error::last_os_error();
                if e.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(e);
            }
            for ev in &self.buf[..n as usize] {
                // copy out of the (possibly packed) struct before use
                let bits = ev.events;
                let token = ev.data;
                events.push(Event {
                    token,
                    readable: bits & EPOLLIN != 0,
                    writable: bits & EPOLLOUT != 0,
                    failed: bits & (EPOLLERR | EPOLLHUP) != 0,
                });
            }
            Ok(())
        }
    }

    impl Drop for EpollBackend {
        fn drop(&mut self) {
            unsafe {
                close(self.epfd);
            }
        }
    }
}

#[cfg(target_os = "linux")]
pub use epoll::EpollBackend;

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::os::unix::io::AsRawFd;
    use std::os::unix::net::UnixStream;

    #[test]
    fn timeout_rounding() {
        assert_eq!(timeout_ms(None), -1);
        assert_eq!(timeout_ms(Some(Duration::ZERO)), 0);
        // sub-millisecond rounds up, never to a spin at 0
        assert_eq!(timeout_ms(Some(Duration::from_micros(1))), 1);
        assert_eq!(timeout_ms(Some(Duration::from_millis(250))), 250);
        assert_eq!(timeout_ms(Some(Duration::from_secs(1 << 40))), i32::MAX);
    }

    /// Drive one backend through register → wait → reregister →
    /// deregister against a socketpair.
    fn exercise_backend(
        mut register: impl FnMut(RawFd, u64, u8) -> io::Result<()>,
        mut reregister: impl FnMut(RawFd, u64, u8) -> io::Result<()>,
        mut deregister: impl FnMut(RawFd, u64) -> io::Result<()>,
        mut wait: impl FnMut(&mut Vec<Event>, Option<Duration>) -> io::Result<()>,
    ) {
        let (mut a, b) = UnixStream::pair().unwrap();
        b.set_nonblocking(true).unwrap();
        let fd = b.as_raw_fd();
        register(fd, 42, INTEREST_READ).unwrap();

        // nothing readable yet
        let mut events = Vec::new();
        wait(&mut events, Some(Duration::from_millis(1))).unwrap();
        assert!(events.iter().all(|e| !e.readable));

        a.write_all(b"x").unwrap();
        wait(&mut events, Some(Duration::from_millis(500))).unwrap();
        let ev = events.iter().find(|e| e.token == 42).expect("readable event");
        assert!(ev.readable);

        // drain, then switch to write interest: an idle socket is writable
        let mut buf = [0u8; 8];
        let _ = (&b).read(&mut buf);
        reregister(fd, 42, INTEREST_WRITE).unwrap();
        wait(&mut events, Some(Duration::from_millis(500))).unwrap();
        let ev = events.iter().find(|e| e.token == 42).expect("writable event");
        assert!(ev.writable);

        deregister(fd, 42).unwrap();
        wait(&mut events, Some(Duration::from_millis(1))).unwrap();
        assert!(events.is_empty());
    }

    #[test]
    fn poll_backend_readiness_cycle() {
        let mut p = PollBackend::new();
        // Split borrows via RefCell so the closures can share the backend.
        let p = std::cell::RefCell::new(&mut p);
        exercise_backend(
            |fd, t, i| p.borrow_mut().register(fd, t, i),
            |fd, t, i| p.borrow_mut().reregister(fd, t, i),
            |fd, t| p.borrow_mut().deregister(fd, t),
            |ev, to| p.borrow_mut().wait(ev, to),
        );
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn epoll_backend_readiness_cycle() {
        let mut e = EpollBackend::new().unwrap();
        let e = std::cell::RefCell::new(&mut e);
        exercise_backend(
            |fd, t, i| e.borrow_mut().register(fd, t, i),
            |fd, t, i| e.borrow_mut().reregister(fd, t, i),
            |fd, t| e.borrow_mut().deregister(fd, t),
            |ev, to| e.borrow_mut().wait(ev, to),
        );
    }

    #[test]
    fn poll_backend_duplicate_token_rejected() {
        let (_a, b) = UnixStream::pair().unwrap();
        let mut p = PollBackend::new();
        p.register(b.as_raw_fd(), 1, INTEREST_READ).unwrap();
        assert!(p.register(b.as_raw_fd(), 1, INTEREST_READ).is_err());
        assert!(p.deregister(b.as_raw_fd(), 9).is_err());
    }
}
