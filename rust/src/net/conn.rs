//! Per-connection state machine: buffered newline framing on the read
//! side, incremental binary-frame decoding, a pending-write buffer on
//! the write side, and the interest computation that ties the three to
//! the poller.
//!
//! Invariants the server loop relies on:
//!
//! - At most one request per connection is in flight at a time
//!   (`in_flight`); read interest is dropped while it runs, so a
//!   flooding client is backpressured by TCP instead of ballooning the
//!   dispatch queue. This also preserves the old front end's per-
//!   connection serial ordering.
//! - The read buffer never exceeds `max_line_bytes` without containing
//!   a newline — [`Conn::line_overflow`] catches the excess and the
//!   loop answers with a typed `protocol` error, then closes.
//! - A binary frame's payload never accumulates as raw bytes: each read
//!   chunk is folded straight into the decoder's `Vec<f32>`
//!   ([`Conn::pump_frame`]), so the transient text/byte buffering stays
//!   O(read chunk) however large the panel is.
//! - Responses go through `queue_line` + `flush`; whatever the socket
//!   won't take stays buffered and the poller watches for writability,
//!   so a slow reader never blocks the loop (or a dispatch worker).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Instant;

use crate::api::wire::{FRAME_MAGIC, MAX_FRAME_HEADER_BYTES, MAX_FRAME_PAYLOAD_BYTES};

use super::poller::{INTEREST_READ, INTEREST_WRITE};

/// Pause line parsing (and reading) while a connection has this many
/// response bytes still unflushed: a reader that never drains its
/// socket gets bounded per-connection memory, not an unbounded queue.
pub const WRITE_HIGH_WATERMARK: usize = 256 * 1024;

/// Outcome of one nonblocking `read` into the frame buffer.
pub enum Fill {
    /// Bytes arrived (frame buffer extended).
    Data,
    /// Nothing to read right now.
    WouldBlock,
    /// Orderly EOF from the peer.
    Eof,
    /// Hard socket error (connection reset, ...).
    Err(std::io::Error),
}

/// A fully decoded binary request frame: the JSON header text plus the
/// payload already converted to little-endian f32s.
#[derive(Debug, PartialEq)]
pub struct FrameRequest {
    pub header: String,
    pub payload: Vec<f32>,
}

/// One parsed input unit from a connection: a JSON line or a complete
/// binary frame.
#[derive(Debug, PartialEq)]
pub enum Event {
    Line(String),
    Frame(FrameRequest),
}

/// Incremental binary-frame decoder. Raw bytes are consumed as they
/// arrive: the 12-byte length prefix (the magic was consumed at
/// detection), then the JSON header, then the payload folded four bytes
/// at a time into `Vec<f32>` — at most 3 payload bytes are ever held
/// un-decoded, so a multi-hundred-MB panel costs O(read chunk) beyond
/// its own final storage.
struct FrameDecoder {
    /// `(header_len, payload_len_bytes)` once the length prefix arrived.
    lens: Option<(usize, u64)>,
    /// Header bytes collected so far (≤ header_len).
    header: Vec<u8>,
    payload: Vec<f32>,
    /// Payload bytes still expected.
    payload_left: u64,
    /// A little-endian f32 straddling two reads.
    partial: [u8; 4],
    partial_len: usize,
}

impl FrameDecoder {
    fn new() -> FrameDecoder {
        FrameDecoder {
            lens: None,
            header: Vec::new(),
            payload: Vec::new(),
            payload_left: 0,
            partial: [0; 4],
            partial_len: 0,
        }
    }
}

/// Extract the next `\n`-terminated line from `buf`, resuming the
/// newline scan at `*scan_from` (bytes before it are known
/// newline-free, so repeated calls over a growing buffer stay linear).
/// Strips the terminator and an optional trailing `\r`; invalid UTF-8
/// is replaced (the JSON parse will reject it with a typed error
/// rather than killing the connection).
pub(crate) fn split_line(buf: &mut Vec<u8>, scan_from: &mut usize) -> Option<String> {
    match buf[*scan_from..].iter().position(|&b| b == b'\n') {
        Some(rel) => {
            let end = *scan_from + rel;
            let mut line = &buf[..end];
            if line.last() == Some(&b'\r') {
                line = &line[..line.len() - 1];
            }
            let s = String::from_utf8_lossy(line).into_owned();
            buf.drain(..=end);
            *scan_from = 0;
            Some(s)
        }
        None => {
            *scan_from = buf.len();
            None
        }
    }
}

pub struct Conn {
    pub stream: TcpStream,
    /// Incoming bytes not yet split into lines (or folded into a frame).
    read_buf: Vec<u8>,
    /// Newline-scan resume offset into `read_buf`.
    scan_from: usize,
    /// In-progress binary frame, if the stream is mid-frame.
    frame: Option<FrameDecoder>,
    /// A completed frame waiting for the loop to pick it up.
    ready_frame: Option<FrameRequest>,
    /// Outgoing bytes not yet accepted by the socket.
    write_buf: Vec<u8>,
    write_pos: usize,
    /// A request from this connection is being processed by a worker.
    pub in_flight: bool,
    /// Peer sent EOF; no more lines will arrive.
    pub peer_closed: bool,
    /// Close once the write buffer flushes (fatal protocol error, or
    /// server-initiated close).
    pub closing: bool,
    /// Last accept/read/completion on this connection — the idle-reap
    /// clock.
    pub last_activity: Instant,
    /// Interest mask currently registered with the poller.
    pub registered: u8,
}

impl Conn {
    pub fn new(stream: TcpStream, now: Instant) -> Conn {
        Conn {
            stream,
            read_buf: Vec::new(),
            scan_from: 0,
            frame: None,
            ready_frame: None,
            write_buf: Vec::new(),
            write_pos: 0,
            in_flight: false,
            peer_closed: false,
            closing: false,
            last_activity: now,
            registered: INTEREST_READ,
        }
    }

    pub fn touch(&mut self, now: Instant) {
        self.last_activity = now;
    }

    /// One nonblocking read through `scratch` into the frame buffer.
    pub fn fill(&mut self, scratch: &mut [u8]) -> Fill {
        match self.stream.read(scratch) {
            Ok(0) => Fill::Eof,
            Ok(n) => {
                self.read_buf.extend_from_slice(&scratch[..n]);
                Fill::Data
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => Fill::WouldBlock,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => Fill::WouldBlock,
            Err(e) => Fill::Err(e),
        }
    }

    /// Next complete line, if any (see [`split_line`]).
    pub fn next_line(&mut self) -> Option<String> {
        split_line(&mut self.read_buf, &mut self.scan_from)
    }

    /// Advance any in-progress binary frame with the buffered bytes,
    /// detecting a new frame by its magic. Call after every fill so a
    /// frame payload is folded into f32s chunk by chunk instead of
    /// accumulating as raw bytes. `Err` carries a human-readable reason
    /// for a malformed frame (the caller answers with a typed `protocol`
    /// error and closes).
    pub fn pump_frame(&mut self) -> Result<(), String> {
        loop {
            if self.frame.is_none() {
                // A completed frame must be picked up before the next
                // message starts decoding (one request in flight per
                // connection keeps this from buffering unboundedly).
                if self.ready_frame.is_some() || self.read_buf.is_empty() {
                    return Ok(());
                }
                let probe = self.read_buf.len().min(FRAME_MAGIC.len());
                if self.read_buf[..probe] != FRAME_MAGIC[..probe] {
                    return Ok(()); // line traffic
                }
                if probe < FRAME_MAGIC.len() {
                    return Ok(()); // could be a frame; wait for more bytes
                }
                self.read_buf.drain(..FRAME_MAGIC.len());
                self.scan_from = 0;
                self.frame = Some(FrameDecoder::new());
            }
            // Length prefix: u32 LE header bytes + u64 LE payload bytes.
            if self.frame.as_ref().is_some_and(|fd| fd.lens.is_none()) {
                if self.read_buf.len() < 12 {
                    return Ok(());
                }
                let hlen =
                    u32::from_le_bytes(self.read_buf[..4].try_into().unwrap()) as usize;
                let plen = u64::from_le_bytes(self.read_buf[4..12].try_into().unwrap());
                if hlen == 0 || hlen > MAX_FRAME_HEADER_BYTES {
                    return Err(format!(
                        "frame header length {hlen} out of range 1..={MAX_FRAME_HEADER_BYTES}"
                    ));
                }
                if plen > MAX_FRAME_PAYLOAD_BYTES {
                    return Err(format!(
                        "frame payload {plen} bytes exceeds cap {MAX_FRAME_PAYLOAD_BYTES}"
                    ));
                }
                if plen % 4 != 0 {
                    return Err(format!(
                        "frame payload {plen} bytes is not a whole number of f32s"
                    ));
                }
                let fd = self.frame.as_mut().unwrap();
                fd.lens = Some((hlen, plen));
                fd.payload_left = plen;
                self.read_buf.drain(..12);
                self.scan_from = 0;
            }
            let fd = self.frame.as_mut().unwrap();
            let (hlen, _) = fd.lens.unwrap();
            // JSON header bytes.
            if fd.header.len() < hlen {
                let take = (hlen - fd.header.len()).min(self.read_buf.len());
                fd.header.extend_from_slice(&self.read_buf[..take]);
                self.read_buf.drain(..take);
                self.scan_from = 0;
                if fd.header.len() < hlen {
                    return Ok(());
                }
                // Header complete: reserve the payload exactly once (the
                // sender has already produced a full header, so this is
                // not a free memory claim from a bare length prefix).
                fd.payload.reserve_exact((fd.payload_left / 4) as usize);
            }
            // Payload bytes → f32s, four at a time; at most 3 bytes of a
            // straddling value are carried between reads.
            if fd.payload_left > 0 {
                let take = fd.payload_left.min(self.read_buf.len() as u64) as usize;
                for i in 0..take {
                    fd.partial[fd.partial_len] = self.read_buf[i];
                    fd.partial_len += 1;
                    if fd.partial_len == 4 {
                        fd.payload.push(f32::from_le_bytes(fd.partial));
                        fd.partial_len = 0;
                    }
                }
                fd.payload_left -= take as u64;
                self.read_buf.drain(..take);
                self.scan_from = 0;
                if self.frame.as_ref().unwrap().payload_left > 0 {
                    return Ok(());
                }
            }
            let fd = self.frame.take().unwrap();
            debug_assert_eq!(fd.partial_len, 0);
            let header = String::from_utf8_lossy(&fd.header).into_owned();
            self.ready_frame = Some(FrameRequest { header, payload: fd.payload });
            // Loop: trailing buffered bytes may already belong to the
            // next message (the ready-frame guard returns at the top).
        }
    }

    /// Next complete input event — a JSON line or a binary frame — if
    /// any. `Err` means the stream is unrecoverably mis-framed.
    pub fn next_event(&mut self) -> Result<Option<Event>, String> {
        self.pump_frame()?;
        if let Some(f) = self.ready_frame.take() {
            return Ok(Some(Event::Frame(f)));
        }
        if self.frame.is_some() {
            return Ok(None); // mid-frame: no line can be extracted
        }
        Ok(split_line(&mut self.read_buf, &mut self.scan_from).map(Event::Line))
    }

    /// Is the stream mid-frame (or holding a decoded frame)? Used by the
    /// loop to skip line-overflow accounting that only applies to line
    /// traffic.
    pub fn in_frame(&self) -> bool {
        self.frame.is_some() || self.ready_frame.is_some()
    }

    /// True when the frame buffer holds a newline-free prefix past the
    /// cap. Only meaningful right after `next_line` returned `None`
    /// (the scan is then complete).
    pub fn line_overflow(&self, max_line_bytes: usize) -> bool {
        self.read_buf.len() > max_line_bytes && self.scan_from == self.read_buf.len()
    }

    pub fn read_buffered(&self) -> usize {
        self.read_buf.len()
    }

    /// Non-consuming peek: is a complete line still buffered? (Bytes
    /// before `scan_from` are known newline-free, so only the suffix
    /// needs scanning.)
    pub fn has_complete_line(&self) -> bool {
        self.read_buf[self.scan_from..].contains(&b'\n')
    }

    /// Queue one response line (terminator appended here).
    pub fn queue_line(&mut self, line: &str) {
        self.write_buf.extend_from_slice(line.as_bytes());
        self.write_buf.push(b'\n');
    }

    pub fn write_pending(&self) -> usize {
        self.write_buf.len() - self.write_pos
    }

    /// Push buffered bytes until done or the socket blocks. `Ok(true)`
    /// means fully flushed.
    pub fn flush(&mut self) -> std::io::Result<bool> {
        while self.write_pos < self.write_buf.len() {
            match self.stream.write(&self.write_buf[self.write_pos..]) {
                Ok(0) => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::WriteZero,
                        "socket accepted 0 bytes",
                    ))
                }
                Ok(n) => self.write_pos += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Ok(false),
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        self.write_buf.clear();
        self.write_pos = 0;
        Ok(true)
    }

    /// The interest mask this connection should be registered with:
    /// readable while it can accept a new request, writable while
    /// responses are buffered.
    pub fn desired_interest(&self, draining: bool) -> u8 {
        let mut interest = 0;
        if !self.in_flight
            && !self.closing
            && !self.peer_closed
            && !draining
            && self.write_pending() < WRITE_HIGH_WATERMARK
        {
            interest |= INTEREST_READ;
        }
        if self.write_pending() > 0 {
            interest |= INTEREST_WRITE;
        }
        interest
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_line_basic_and_crlf() {
        let mut buf = b"{\"a\":1}\r\nnext".to_vec();
        let mut scan = 0;
        assert_eq!(split_line(&mut buf, &mut scan).as_deref(), Some("{\"a\":1}"));
        assert_eq!(buf, b"next");
        assert_eq!(scan, 0);
        assert_eq!(split_line(&mut buf, &mut scan), None);
        assert_eq!(scan, 4); // scan resumes past the partial
    }

    #[test]
    fn split_line_resumes_scan_linearly() {
        let mut buf = vec![b'x'; 1000];
        let mut scan = 0;
        assert_eq!(split_line(&mut buf, &mut scan), None);
        assert_eq!(scan, 1000);
        buf.extend_from_slice(b"tail\n");
        let line = split_line(&mut buf, &mut scan).unwrap();
        assert_eq!(line.len(), 1004);
        assert!(line.ends_with("tail"));
        assert!(buf.is_empty());
    }

    #[test]
    fn split_line_handles_pipelined_lines_and_empties() {
        let mut buf = b"one\n\ntwo\n".to_vec();
        let mut scan = 0;
        assert_eq!(split_line(&mut buf, &mut scan).as_deref(), Some("one"));
        assert_eq!(split_line(&mut buf, &mut scan).as_deref(), Some(""));
        assert_eq!(split_line(&mut buf, &mut scan).as_deref(), Some("two"));
        assert_eq!(split_line(&mut buf, &mut scan), None);
    }

    #[test]
    fn split_line_lossy_on_invalid_utf8() {
        let mut buf = vec![0xff, 0xfe, b'\n'];
        let mut scan = 0;
        let line = split_line(&mut buf, &mut scan).unwrap();
        assert!(!line.is_empty()); // replacement chars, not a panic
    }

    #[test]
    fn overflow_detection_via_conn_state() {
        // line_overflow is pure state — exercise it through a real
        // (loopback) Conn so the struct invariants hold.
        let mut c = loopback_conn();
        c.read_buf = vec![b'x'; 100];
        assert_eq!(c.next_line(), None);
        assert!(c.line_overflow(64));
        assert!(!c.line_overflow(100));
    }

    fn loopback_conn() -> Conn {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        drop(client);
        Conn::new(server_side, Instant::now())
    }

    #[test]
    fn frame_decodes_incrementally_byte_by_byte() {
        let hdr = crate::util::json::Json::parse(r#"{"v": 2, "n": 1, "l": 3, "k": 1}"#).unwrap();
        let payload = [0.5f32, -1.25, 3.0];
        let bytes = crate::api::wire::encode_frame(&hdr, &payload);
        let mut c = loopback_conn();
        for (i, b) in bytes.iter().enumerate() {
            c.read_buf.push(*b);
            match c.next_event().unwrap() {
                None => assert!(i + 1 < bytes.len(), "frame completed early at byte {i}"),
                Some(Event::Frame(f)) => {
                    assert_eq!(i + 1, bytes.len(), "frame completed early at byte {i}");
                    assert_eq!(f.payload, payload);
                    assert_eq!(
                        crate::util::json::Json::parse(&f.header).unwrap(),
                        hdr
                    );
                }
                Some(Event::Line(l)) => panic!("unexpected line {l:?} at byte {i}"),
            }
        }
        assert_eq!(c.read_buffered(), 0);
        assert!(!c.in_frame());
    }

    #[test]
    fn lines_and_frames_interleave() {
        let hdr = crate::util::json::Json::parse(r#"{"v": 2, "n": 1, "l": 1, "k": 1}"#).unwrap();
        let frame = crate::api::wire::encode_frame(&hdr, &[7.0]);
        let mut c = loopback_conn();
        c.read_buf.extend_from_slice(b"{\"cmd\":\"ping\"}\n");
        c.read_buf.extend_from_slice(&frame);
        c.read_buf.extend_from_slice(b"{\"cmd\":\"stats\"}\n");
        let Some(Event::Line(l1)) = c.next_event().unwrap() else { panic!() };
        assert_eq!(l1, "{\"cmd\":\"ping\"}");
        let Some(Event::Frame(f)) = c.next_event().unwrap() else { panic!() };
        assert_eq!(f.payload, vec![7.0]);
        let Some(Event::Line(l2)) = c.next_event().unwrap() else { panic!() };
        assert_eq!(l2, "{\"cmd\":\"stats\"}");
        assert_eq!(c.next_event().unwrap(), None);
    }

    #[test]
    fn malformed_frames_are_typed_errors() {
        // zero-length header
        let mut c = loopback_conn();
        c.read_buf.extend_from_slice(b"TMFB");
        c.read_buf.extend_from_slice(&0u32.to_le_bytes());
        c.read_buf.extend_from_slice(&0u64.to_le_bytes());
        assert!(c.next_event().unwrap_err().contains("header length"));
        // payload over the byte cap
        let mut c = loopback_conn();
        c.read_buf.extend_from_slice(b"TMFB");
        c.read_buf.extend_from_slice(&8u32.to_le_bytes());
        c.read_buf.extend_from_slice(&(MAX_FRAME_PAYLOAD_BYTES + 4).to_le_bytes());
        assert!(c.next_event().unwrap_err().contains("exceeds cap"));
        // payload not a multiple of 4
        let mut c = loopback_conn();
        c.read_buf.extend_from_slice(b"TMFB");
        c.read_buf.extend_from_slice(&8u32.to_le_bytes());
        c.read_buf.extend_from_slice(&7u64.to_le_bytes());
        assert!(c.next_event().unwrap_err().contains("whole number"));
    }

    #[test]
    fn partial_magic_waits_but_non_magic_prefix_stays_line_traffic() {
        let mut c = loopback_conn();
        c.read_buf.extend_from_slice(b"TMF");
        assert_eq!(c.next_event().unwrap(), None);
        assert!(!c.in_frame());
        // the fourth byte disambiguates: not a frame after all
        c.read_buf.extend_from_slice(b"oo\n");
        let Some(Event::Line(l)) = c.next_event().unwrap() else { panic!() };
        assert_eq!(l, "TMFoo");
    }
}
