//! Per-connection state machine: buffered newline framing on the read
//! side, a pending-write buffer on the write side, and the interest
//! computation that ties the two to the poller.
//!
//! Invariants the server loop relies on:
//!
//! - At most one request per connection is in flight at a time
//!   (`in_flight`); read interest is dropped while it runs, so a
//!   flooding client is backpressured by TCP instead of ballooning the
//!   dispatch queue. This also preserves the old front end's per-
//!   connection serial ordering.
//! - The read buffer never exceeds `max_line_bytes` without containing
//!   a newline — [`Conn::line_overflow`] catches the excess and the
//!   loop answers with a typed `protocol` error, then closes.
//! - Responses go through `queue_line` + `flush`; whatever the socket
//!   won't take stays buffered and the poller watches for writability,
//!   so a slow reader never blocks the loop (or a dispatch worker).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Instant;

use super::poller::{INTEREST_READ, INTEREST_WRITE};

/// Pause line parsing (and reading) while a connection has this many
/// response bytes still unflushed: a reader that never drains its
/// socket gets bounded per-connection memory, not an unbounded queue.
pub const WRITE_HIGH_WATERMARK: usize = 256 * 1024;

/// Outcome of one nonblocking `read` into the frame buffer.
pub enum Fill {
    /// Bytes arrived (frame buffer extended).
    Data,
    /// Nothing to read right now.
    WouldBlock,
    /// Orderly EOF from the peer.
    Eof,
    /// Hard socket error (connection reset, ...).
    Err(std::io::Error),
}

/// Extract the next `\n`-terminated line from `buf`, resuming the
/// newline scan at `*scan_from` (bytes before it are known
/// newline-free, so repeated calls over a growing buffer stay linear).
/// Strips the terminator and an optional trailing `\r`; invalid UTF-8
/// is replaced (the JSON parse will reject it with a typed error
/// rather than killing the connection).
pub(crate) fn split_line(buf: &mut Vec<u8>, scan_from: &mut usize) -> Option<String> {
    match buf[*scan_from..].iter().position(|&b| b == b'\n') {
        Some(rel) => {
            let end = *scan_from + rel;
            let mut line = &buf[..end];
            if line.last() == Some(&b'\r') {
                line = &line[..line.len() - 1];
            }
            let s = String::from_utf8_lossy(line).into_owned();
            buf.drain(..=end);
            *scan_from = 0;
            Some(s)
        }
        None => {
            *scan_from = buf.len();
            None
        }
    }
}

pub struct Conn {
    pub stream: TcpStream,
    /// Incoming bytes not yet split into lines.
    read_buf: Vec<u8>,
    /// Newline-scan resume offset into `read_buf`.
    scan_from: usize,
    /// Outgoing bytes not yet accepted by the socket.
    write_buf: Vec<u8>,
    write_pos: usize,
    /// A request from this connection is being processed by a worker.
    pub in_flight: bool,
    /// Peer sent EOF; no more lines will arrive.
    pub peer_closed: bool,
    /// Close once the write buffer flushes (fatal protocol error, or
    /// server-initiated close).
    pub closing: bool,
    /// Last accept/read/completion on this connection — the idle-reap
    /// clock.
    pub last_activity: Instant,
    /// Interest mask currently registered with the poller.
    pub registered: u8,
}

impl Conn {
    pub fn new(stream: TcpStream, now: Instant) -> Conn {
        Conn {
            stream,
            read_buf: Vec::new(),
            scan_from: 0,
            write_buf: Vec::new(),
            write_pos: 0,
            in_flight: false,
            peer_closed: false,
            closing: false,
            last_activity: now,
            registered: INTEREST_READ,
        }
    }

    pub fn touch(&mut self, now: Instant) {
        self.last_activity = now;
    }

    /// One nonblocking read through `scratch` into the frame buffer.
    pub fn fill(&mut self, scratch: &mut [u8]) -> Fill {
        match self.stream.read(scratch) {
            Ok(0) => Fill::Eof,
            Ok(n) => {
                self.read_buf.extend_from_slice(&scratch[..n]);
                Fill::Data
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => Fill::WouldBlock,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => Fill::WouldBlock,
            Err(e) => Fill::Err(e),
        }
    }

    /// Next complete line, if any (see [`split_line`]).
    pub fn next_line(&mut self) -> Option<String> {
        split_line(&mut self.read_buf, &mut self.scan_from)
    }

    /// True when the frame buffer holds a newline-free prefix past the
    /// cap. Only meaningful right after `next_line` returned `None`
    /// (the scan is then complete).
    pub fn line_overflow(&self, max_line_bytes: usize) -> bool {
        self.read_buf.len() > max_line_bytes && self.scan_from == self.read_buf.len()
    }

    pub fn read_buffered(&self) -> usize {
        self.read_buf.len()
    }

    /// Non-consuming peek: is a complete line still buffered? (Bytes
    /// before `scan_from` are known newline-free, so only the suffix
    /// needs scanning.)
    pub fn has_complete_line(&self) -> bool {
        self.read_buf[self.scan_from..].contains(&b'\n')
    }

    /// Queue one response line (terminator appended here).
    pub fn queue_line(&mut self, line: &str) {
        self.write_buf.extend_from_slice(line.as_bytes());
        self.write_buf.push(b'\n');
    }

    pub fn write_pending(&self) -> usize {
        self.write_buf.len() - self.write_pos
    }

    /// Push buffered bytes until done or the socket blocks. `Ok(true)`
    /// means fully flushed.
    pub fn flush(&mut self) -> std::io::Result<bool> {
        while self.write_pos < self.write_buf.len() {
            match self.stream.write(&self.write_buf[self.write_pos..]) {
                Ok(0) => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::WriteZero,
                        "socket accepted 0 bytes",
                    ))
                }
                Ok(n) => self.write_pos += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Ok(false),
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        self.write_buf.clear();
        self.write_pos = 0;
        Ok(true)
    }

    /// The interest mask this connection should be registered with:
    /// readable while it can accept a new request, writable while
    /// responses are buffered.
    pub fn desired_interest(&self, draining: bool) -> u8 {
        let mut interest = 0;
        if !self.in_flight
            && !self.closing
            && !self.peer_closed
            && !draining
            && self.write_pending() < WRITE_HIGH_WATERMARK
        {
            interest |= INTEREST_READ;
        }
        if self.write_pending() > 0 {
            interest |= INTEREST_WRITE;
        }
        interest
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_line_basic_and_crlf() {
        let mut buf = b"{\"a\":1}\r\nnext".to_vec();
        let mut scan = 0;
        assert_eq!(split_line(&mut buf, &mut scan).as_deref(), Some("{\"a\":1}"));
        assert_eq!(buf, b"next");
        assert_eq!(scan, 0);
        assert_eq!(split_line(&mut buf, &mut scan), None);
        assert_eq!(scan, 4); // scan resumes past the partial
    }

    #[test]
    fn split_line_resumes_scan_linearly() {
        let mut buf = vec![b'x'; 1000];
        let mut scan = 0;
        assert_eq!(split_line(&mut buf, &mut scan), None);
        assert_eq!(scan, 1000);
        buf.extend_from_slice(b"tail\n");
        let line = split_line(&mut buf, &mut scan).unwrap();
        assert_eq!(line.len(), 1004);
        assert!(line.ends_with("tail"));
        assert!(buf.is_empty());
    }

    #[test]
    fn split_line_handles_pipelined_lines_and_empties() {
        let mut buf = b"one\n\ntwo\n".to_vec();
        let mut scan = 0;
        assert_eq!(split_line(&mut buf, &mut scan).as_deref(), Some("one"));
        assert_eq!(split_line(&mut buf, &mut scan).as_deref(), Some(""));
        assert_eq!(split_line(&mut buf, &mut scan).as_deref(), Some("two"));
        assert_eq!(split_line(&mut buf, &mut scan), None);
    }

    #[test]
    fn split_line_lossy_on_invalid_utf8() {
        let mut buf = vec![0xff, 0xfe, b'\n'];
        let mut scan = 0;
        let line = split_line(&mut buf, &mut scan).unwrap();
        assert!(!line.is_empty()); // replacement chars, not a panic
    }

    #[test]
    fn overflow_detection_via_conn_state() {
        // line_overflow is pure state — exercise it through a real
        // (loopback) Conn so the struct invariants hold.
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        drop(client);
        let mut c = Conn::new(server_side, Instant::now());
        c.read_buf = vec![b'x'; 100];
        assert_eq!(c.next_line(), None);
        assert!(c.line_overflow(64));
        assert!(!c.line_overflow(100));
    }
}
