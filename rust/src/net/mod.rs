//! Dependency-free readiness-driven serving tier.
//!
//! The event loop that fronts [`crate::coordinator::service`]: one OS
//! thread multiplexes every client connection with nonblocking
//! accept/read/write over an OS readiness facility — `epoll(7)` on
//! Linux, with a portable `poll(2)` fallback — replacing the old
//! thread-per-connection front end. Like `vendor/anyhow`, everything is
//! in-repo: the syscall surface is a handful of `extern "C"`
//! declarations in [`sys`] (std already links libc, so they resolve at
//! link time without adding a crate).
//!
//! Layering, bottom up:
//!
//! - [`sys`] — raw `epoll`/`poll` FFI plus the two backend structs.
//! - [`poller`] — the unified [`poller::Poller`] facade; backend chosen
//!   at runtime (`TMFG_NET_BACKEND=poll` forces the fallback).
//! - [`conn`] — per-connection state machine: buffered newline framing
//!   with a hard line-length cap, pending-write buffer, interest
//!   computation, activity timestamps.
//! - [`wheel`] — hashed deadline wheel for idle-session reaping
//!   (schedule is O(1); expiry revalidates lazily against the
//!   connection's real last-activity time).
//! - [`server`] — the loop itself: accept with a hard connection
//!   limit, dispatch to a [`server::Handler`] (the policy layer that
//!   the coordinator implements: admission control, backpressure,
//!   submit-to-workers), completion delivery via [`server::LoopCtl`]
//!   (worker threads push finished responses and poke a self-pipe
//!   waker), and graceful drain on shutdown.
//!
//! The split keeps mechanism and policy separate: this module knows
//! nothing about TMFG, JSON, tenants, or queues — it moves bytes and
//! surfaces events. All serving policy lives in the coordinator's
//! `Handler` implementation.
//!
//! Unix-only (the readiness syscalls); on other targets the coordinator
//! falls back to the legacy blocking front end and only [`server::LoopCtl`]
//! (the completion mailbox) is compiled.

#[cfg(unix)]
pub mod conn;
#[cfg(unix)]
pub mod poller;
pub mod server;
#[cfg(unix)]
pub mod sys;
#[cfg(unix)]
pub mod wheel;
