//! Hashed deadline wheel for idle-connection reaping.
//!
//! Time is bucketed into fixed-width slots over a circular array;
//! scheduling is `O(1)` (push the token into `due / granularity mod
//! slots`), expiry drains every slot the cursor has passed. Deadlines
//! beyond the horizon clamp to the last slot — harmless, because the
//! server *revalidates lazily*: an expired token is checked against the
//! connection's real `last_activity` and rescheduled if it was touched
//! (or clamped) since. A connection therefore carries at most one live
//! wheel entry, scheduled at accept and re-scheduled only on expiry —
//! no per-request wheel traffic and no entry removal on close (stale
//! tokens fall out of the map lookup).

use std::time::{Duration, Instant};

pub struct DeadlineWheel {
    slots: Vec<Vec<u64>>,
    granularity: Duration,
    epoch: Instant,
    /// Next absolute slot index to expire (monotone).
    cursor: u64,
}

impl DeadlineWheel {
    /// `granularity` is floored to 1ms (slot math divides by it).
    pub fn new(granularity: Duration, nslots: usize) -> DeadlineWheel {
        DeadlineWheel {
            slots: vec![Vec::new(); nslots.max(2)],
            granularity: granularity.max(Duration::from_millis(1)),
            epoch: Instant::now(),
            cursor: 0,
        }
    }

    fn abs_slot(&self, t: Instant) -> u64 {
        let since = t.saturating_duration_since(self.epoch);
        (since.as_nanos() / self.granularity.as_nanos()) as u64
    }

    /// Schedule `token` to surface from [`expire`](Self::expire) once
    /// `due` has passed (up to one slot late; clamped into the wheel's
    /// horizon — lazy revalidation reschedules the remainder).
    pub fn schedule(&mut self, token: u64, due: Instant) {
        let horizon = self.cursor + self.slots.len() as u64 - 1;
        let s = self.abs_slot(due).clamp(self.cursor, horizon);
        let idx = (s % self.slots.len() as u64) as usize;
        self.slots[idx].push(token);
    }

    /// Time until the earliest scheduled slot fully elapses, `None` if
    /// the wheel is empty — the event loop's wait timeout.
    pub fn next_due(&self, now: Instant) -> Option<Duration> {
        let nslots = self.slots.len() as u64;
        for off in 0..nslots {
            let s = self.cursor + off;
            if !self.slots[(s % nslots) as usize].is_empty() {
                // u64 nanosecond math: `Duration * u32` would wrap the
                // slot index on a long-lived server.
                let offset =
                    Duration::from_nanos(self.granularity.as_nanos() as u64 * (s + 1));
                let boundary = self.epoch + offset;
                return Some(boundary.saturating_duration_since(now));
            }
        }
        None
    }

    /// Drain every slot that has fully elapsed by `now`, invoking `f`
    /// per token. Callers revalidate each token (still alive? actually
    /// idle?) and reschedule survivors.
    pub fn expire(&mut self, now: Instant, mut f: impl FnMut(u64)) {
        let current = self.abs_slot(now);
        let nslots = self.slots.len() as u64;
        // Bound the sweep to one lap: after a long sleep every slot has
        // elapsed at least once and extra laps would revisit them.
        let target = current.min(self.cursor + nslots);
        while self.cursor < target {
            let idx = (self.cursor % nslots) as usize;
            for token in std::mem::take(&mut self.slots[idx]) {
                f(token);
            }
            self.cursor += 1;
        }
        if self.cursor < current {
            self.cursor = current;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const G: Duration = Duration::from_millis(10);

    fn drain(w: &mut DeadlineWheel, at: Instant) -> Vec<u64> {
        let mut out = Vec::new();
        w.expire(at, |t| out.push(t));
        out.sort_unstable();
        out
    }

    #[test]
    fn expires_after_deadline_not_before() {
        let mut w = DeadlineWheel::new(G, 8);
        let t0 = w.epoch;
        w.schedule(1, t0 + G * 2);
        assert_eq!(drain(&mut w, t0 + G), Vec::<u64>::new());
        // slot 2 fully elapses at t0 + 3G
        let fired = drain(&mut w, t0 + G * 4);
        assert_eq!(fired, vec![1]);
        // one-shot: nothing fires twice
        assert_eq!(drain(&mut w, t0 + G * 20), Vec::<u64>::new());
    }

    #[test]
    fn beyond_horizon_clamps_and_still_fires() {
        let mut w = DeadlineWheel::new(G, 4);
        let t0 = w.epoch;
        w.schedule(7, t0 + G * 100); // far past the 4-slot horizon
        let fired = drain(&mut w, t0 + G * 10);
        assert_eq!(fired, vec![7]); // early — caller revalidates + reschedules
    }

    #[test]
    fn next_due_tracks_earliest_entry() {
        let mut w = DeadlineWheel::new(G, 8);
        let t0 = w.epoch;
        assert_eq!(w.next_due(t0), None);
        w.schedule(1, t0 + G * 3);
        w.schedule(2, t0 + G * 5);
        let due = w.next_due(t0).unwrap();
        assert!(due <= G * 4 && due >= G * 2, "{due:?}");
        // elapsed deadlines report zero-ish, never panic
        w.schedule(3, t0);
        assert!(w.next_due(t0 + G * 50).unwrap() == Duration::ZERO);
    }

    #[test]
    fn long_sleep_drains_in_one_lap() {
        let mut w = DeadlineWheel::new(G, 4);
        let t0 = w.epoch;
        for tok in 0..4u64 {
            w.schedule(tok, t0 + G * (tok as u32 + 1));
        }
        // A sleep far past every deadline drains everything exactly once.
        let fired = drain(&mut w, t0 + G * 1000);
        assert_eq!(fired, vec![0, 1, 2, 3]);
        // cursor caught up: new schedules land in the future
        w.schedule(9, t0 + G * 1001);
        assert_eq!(drain(&mut w, t0 + G * 1000), Vec::<u64>::new());
        assert_eq!(drain(&mut w, t0 + G * 1003), vec![9]);
    }
}
