//! The unified readiness facade over the [`sys`] backends.
//!
//! The event loop talks to [`Poller`] only; the backend is picked once
//! at startup — `epoll(7)` where available, the portable `poll(2)`
//! rebuild-the-array fallback otherwise. `TMFG_NET_BACKEND=poll` (or
//! [`Backend::Poll`]) forces the fallback, which is how CI and the
//! concurrency suite exercise both paths on Linux.

use super::sys;
pub use super::sys::{Event, INTEREST_READ, INTEREST_WRITE};
use std::io;
use std::os::unix::io::RawFd;
use std::time::Duration;

/// Backend selection for [`Poller::new`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Backend {
    /// Best available: epoll on Linux, poll elsewhere.
    #[default]
    Auto,
    /// Force the portable `poll(2)` fallback.
    Poll,
}

enum Imp {
    #[cfg(target_os = "linux")]
    Epoll(sys::EpollBackend),
    Poll(sys::PollBackend),
}

/// One readiness multiplexer owning the backend state. Registration is
/// keyed by caller-chosen `u64` tokens; fds are only needed again for
/// `reregister`/`deregister` because the poll fallback and `epoll_ctl`
/// both want them.
pub struct Poller {
    imp: Imp,
}

impl Poller {
    pub fn new(choice: Backend) -> io::Result<Poller> {
        let force_poll = choice == Backend::Poll
            || std::env::var("TMFG_NET_BACKEND").map(|v| v == "poll").unwrap_or(false);
        #[cfg(target_os = "linux")]
        {
            if !force_poll {
                // A failed epoll_create1 (e.g. fd exhaustion) falls back
                // to poll rather than refusing to serve.
                if let Ok(ep) = sys::EpollBackend::new() {
                    return Ok(Poller { imp: Imp::Epoll(ep) });
                }
            }
        }
        #[cfg(not(target_os = "linux"))]
        let _ = force_poll;
        Ok(Poller { imp: Imp::Poll(sys::PollBackend::new()) })
    }

    /// The active backend's name (`"epoll"` / `"poll"`), surfaced in
    /// `{"cmd": "stats"}` as `net_backend`.
    pub fn name(&self) -> &'static str {
        match &self.imp {
            #[cfg(target_os = "linux")]
            Imp::Epoll(_) => "epoll",
            Imp::Poll(_) => "poll",
        }
    }

    pub fn register(&mut self, fd: RawFd, token: u64, interest: u8) -> io::Result<()> {
        match &mut self.imp {
            #[cfg(target_os = "linux")]
            Imp::Epoll(b) => b.register(fd, token, interest),
            Imp::Poll(b) => b.register(fd, token, interest),
        }
    }

    pub fn reregister(&mut self, fd: RawFd, token: u64, interest: u8) -> io::Result<()> {
        match &mut self.imp {
            #[cfg(target_os = "linux")]
            Imp::Epoll(b) => b.reregister(fd, token, interest),
            Imp::Poll(b) => b.reregister(fd, token, interest),
        }
    }

    pub fn deregister(&mut self, fd: RawFd, token: u64) -> io::Result<()> {
        match &mut self.imp {
            #[cfg(target_os = "linux")]
            Imp::Epoll(b) => b.deregister(fd, token),
            Imp::Poll(b) => b.deregister(fd, token),
        }
    }

    /// Block for readiness (up to `timeout`; `None` = forever), filling
    /// `events`. EINTR surfaces as zero events.
    pub fn wait(&mut self, events: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
        match &mut self.imp {
            #[cfg(target_os = "linux")]
            Imp::Epoll(b) => b.wait(events, timeout),
            Imp::Poll(b) => b.wait(events, timeout),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::os::unix::io::AsRawFd;
    use std::os::unix::net::UnixStream;

    #[test]
    fn forced_poll_backend_reports_name_and_works() {
        let mut p = Poller::new(Backend::Poll).unwrap();
        assert_eq!(p.name(), "poll");
        let (mut a, b) = UnixStream::pair().unwrap();
        b.set_nonblocking(true).unwrap();
        p.register(b.as_raw_fd(), 5, INTEREST_READ).unwrap();
        a.write_all(b"hello").unwrap();
        let mut events = Vec::new();
        p.wait(&mut events, Some(Duration::from_millis(500))).unwrap();
        assert!(events.iter().any(|e| e.token == 5 && e.readable));
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn auto_prefers_epoll_on_linux() {
        // TMFG_NET_BACKEND could legitimately force poll in a dedicated
        // CI job; only assert epoll when the env var isn't set.
        if std::env::var("TMFG_NET_BACKEND").is_err() {
            let p = Poller::new(Backend::Auto).unwrap();
            assert_eq!(p.name(), "epoll");
        }
    }
}
