//! The readiness event loop: accept, frame, dispatch, complete, drain.
//!
//! One OS thread runs [`run`]; it owns every connection and all serving
//! state, so no per-connection locks exist anywhere in this module.
//! Policy is delegated to a [`Handler`] (the coordinator implements
//! admission control, backpressure, and worker submission there); the
//! loop supplies mechanism:
//!
//! - **Accept** with a hard connection limit (over-limit sockets get a
//!   best-effort rejection line and are dropped).
//! - **Framing** via [`super::conn::Conn`]: JSON lines and binary
//!   frames share one ordered input stream; at most one in-flight
//!   request per connection, read interest parked while it runs. A
//!   mis-framed binary stream gets a typed `protocol` error and a
//!   close (the byte stream can no longer be trusted).
//! - **Completions**: worker threads finish a job and call
//!   [`LoopCtl::complete`], which mails the response line and pokes a
//!   self-pipe waker; the loop queues the line and re-registers write
//!   interest, so a slow reader blocks only its own connection.
//! - **Idle reaping** on a [`super::wheel::DeadlineWheel`] with lazy
//!   revalidation against `last_activity`.
//! - **Graceful drain** ([`LoopCtl::request_shutdown`] or a handler
//!   [`Disposition::RespondAndDrain`]): stop accepting, stop parsing,
//!   let in-flight requests complete and flush, then close everything —
//!   with a hard flush-grace deadline so one dead reader cannot wedge
//!   shutdown.
//!
//! The waker is deliberately flag-free: every `complete`/shutdown
//! writes one byte and ignores `WouldBlock` (a full pipe already has a
//! readable event pending), and the loop drains the completion mailbox
//! every iteration — no lost-wakeup window.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::conn::FrameRequest;

/// Identifies one live connection within a server instance. Tokens are
/// monotone and never reused, so a stale token (in the deadline wheel,
/// or a completion for a closed connection) can never alias a newer
/// connection.
pub type ConnId = u64;

/// What the [`Handler`] wants done with a parsed request line.
pub enum Disposition {
    /// Write this response line; keep parsing.
    Respond(String),
    /// The request was handed to the dispatch tier; a
    /// [`LoopCtl::complete`] call will deliver the response. The loop
    /// parks read interest on the connection until then.
    Submitted,
    /// Write the line, then close the connection once it flushes.
    RespondAndClose(String),
    /// Write the line, then begin graceful drain of the whole server.
    RespondAndDrain(String),
}

/// Serving policy callbacks, all invoked on the loop thread (so a
/// handler needs no internal locking for its own state).
pub trait Handler {
    /// The loop is up, with the named poller backend ("epoll"/"poll").
    fn on_start(&mut self, _backend: &'static str) {}
    /// A connection was accepted and registered.
    fn on_accept(&mut self, _conn: ConnId) {}
    /// One complete request line arrived.
    fn on_line(&mut self, conn: ConnId, line: &str) -> Disposition;
    /// One complete binary frame arrived (JSON header + f32 payload).
    /// The default rejects frames with a typed `protocol` error and
    /// closes — a handler that serves binary traffic overrides this.
    fn on_frame(&mut self, _conn: ConnId, _frame: FrameRequest) -> Disposition {
        Disposition::RespondAndClose(
            "{\"ok\": false, \"error\": {\"code\": \"protocol\", \
             \"message\": \"binary frames not supported\"}}"
                .into(),
        )
    }
    /// The frame decoder rejected the byte stream (bad magic lengths,
    /// over-cap payload, ...). The returned line is sent and the
    /// connection closed; `reason` is human-readable.
    fn on_bad_frame(&mut self, _conn: ConnId, reason: &str) -> String {
        format!(
            "{{\"ok\": false, \"error\": {{\"code\": \"protocol\", \
             \"message\": \"malformed frame: {reason}\"}}}}"
        )
    }
    /// A completion was delivered for `conn`. Fires exactly once per
    /// [`Disposition::Submitted`] — even if the connection died first
    /// (accounting must balance regardless).
    fn on_complete(&mut self, _conn: ConnId) {}
    /// The connection was removed: EOF, socket error, idle reap, drain,
    /// or close-after-response. Fires exactly once per accepted
    /// connection.
    fn on_close(&mut self, _conn: ConnId) {}
    /// Accept hit the hard connection limit; the returned line is
    /// written best-effort to the rejected socket before dropping it.
    fn on_conn_limit(&mut self) -> String;
    /// A newline-free read prefix exceeded the line cap; the returned
    /// line is sent and the connection closed.
    fn on_overflow(&mut self, _conn: ConnId) -> String;
    /// `conn` is about to be closed by the idle reaper (`on_close`
    /// still follows).
    fn on_reaped(&mut self, _conn: ConnId) {}
    /// The poller returned (readiness, completion poke, or timer).
    /// Called once per loop iteration, which makes it the natural
    /// periodic telemetry hook: the coordinator samples its dispatch
    /// queue's front-job age here (queue-delay gauge + adaptive
    /// admission gate) so the signal advances even when no new request
    /// lines arrive. Keep implementations cheap — this runs on the loop
    /// thread between every batch of readiness events.
    fn on_wakeup(&mut self) {}
}

/// Loop configuration (the coordinator derives it from `ServiceConfig`).
pub struct ServerConfig {
    /// Hard cap on simultaneously open connections.
    pub max_conns: usize,
    /// Reject (typed `protocol` error) any newline-free line prefix
    /// longer than this.
    pub max_line_bytes: usize,
    /// Reap connections idle this long; `Duration::ZERO` disables.
    pub idle_timeout: Duration,
    /// Force the portable poll backend.
    #[cfg(unix)]
    pub backend: super::poller::Backend,
}

/// The cross-thread handle into a running loop: worker threads deliver
/// completions, any thread can request shutdown. Compiled on every
/// platform (the non-unix legacy front end shares the shutdown flag);
/// the waker pipe exists only on unix.
pub struct LoopCtl {
    shutdown: AtomicBool,
    completions: Mutex<Vec<(ConnId, String)>>,
    #[cfg(unix)]
    wake_tx: std::os::unix::net::UnixStream,
}

impl LoopCtl {
    /// Build the control handle plus the loop's receive half of the
    /// waker pipe.
    #[cfg(unix)]
    pub fn new() -> std::io::Result<(Arc<LoopCtl>, std::os::unix::net::UnixStream)> {
        let (wake_tx, wake_rx) = std::os::unix::net::UnixStream::pair()?;
        wake_tx.set_nonblocking(true)?;
        wake_rx.set_nonblocking(true)?;
        let ctl = Arc::new(LoopCtl {
            shutdown: AtomicBool::new(false),
            completions: Mutex::new(Vec::new()),
            wake_tx,
        });
        Ok((ctl, wake_rx))
    }

    /// Control handle without a waker — the legacy (non-unix) blocking
    /// front end only uses the shutdown flag.
    #[cfg(not(unix))]
    pub fn new_detached() -> Arc<LoopCtl> {
        Arc::new(LoopCtl {
            shutdown: AtomicBool::new(false),
            completions: Mutex::new(Vec::new()),
        })
    }

    fn wake(&self) {
        #[cfg(unix)]
        {
            use std::io::Write;
            // Flag-free: always write; a full pipe means the loop
            // already has a pending readable event, so WouldBlock (and
            // any other failure) is safely ignorable.
            let _ = (&self.wake_tx).write(&[1u8]);
        }
    }

    /// Deliver a finished response line for `conn` and poke the loop.
    /// Called from dispatch-worker threads.
    pub fn complete(&self, conn: ConnId, line: String) {
        self.completions.lock().unwrap_or_else(|p| p.into_inner()).push((conn, line));
        self.wake();
    }

    /// Ask the loop to drain gracefully and exit.
    pub fn request_shutdown(&self) {
        self.shutdown.store(true, Ordering::Release);
        self.wake();
    }

    pub fn shutdown_requested(&self) -> bool {
        self.shutdown.load(Ordering::Acquire)
    }

    fn take_completions(&self) -> Vec<(ConnId, String)> {
        std::mem::take(&mut *self.completions.lock().unwrap_or_else(|p| p.into_inner()))
    }
}

#[cfg(unix)]
pub use unix_loop::run;

#[cfg(unix)]
mod unix_loop {
    use super::*;
    use crate::net::conn::{Conn, Event as ConnEvent, Fill, WRITE_HIGH_WATERMARK};
    use crate::net::poller::{Event, Poller, INTEREST_READ};
    use crate::net::wheel::DeadlineWheel;
    use std::collections::HashMap;
    use std::io::{self, Read, Write};
    use std::net::TcpListener;
    use std::os::unix::io::AsRawFd;
    use std::os::unix::net::UnixStream;
    use std::time::Instant;

    const TOK_LISTENER: ConnId = 0;
    const TOK_WAKER: ConnId = 1;
    const FIRST_CONN: ConnId = 2;

    /// Read chunk size per nonblocking `read` call.
    const READ_CHUNK: usize = 16 * 1024;

    /// During drain, connections that are neither in flight nor flushed
    /// get this long before being force-closed.
    const DRAIN_FLUSH_GRACE: Duration = Duration::from_secs(5);

    /// Poll cadence while draining (bounds the sweep latency).
    const DRAIN_TICK: Duration = Duration::from_millis(50);

    /// Run the event loop until drain completes. Consumes the listener;
    /// returns only fatal setup/poll errors (per-connection errors just
    /// close that connection).
    pub fn run<H: Handler>(
        listener: TcpListener,
        cfg: &ServerConfig,
        ctl: &Arc<LoopCtl>,
        wake_rx: UnixStream,
        handler: &mut H,
    ) -> io::Result<()> {
        listener.set_nonblocking(true)?;
        let mut poller = Poller::new(cfg.backend)?;
        handler.on_start(poller.name());
        poller.register(listener.as_raw_fd(), TOK_LISTENER, INTEREST_READ)?;
        poller.register(wake_rx.as_raw_fd(), TOK_WAKER, INTEREST_READ)?;
        let wheel = (!cfg.idle_timeout.is_zero()).then(|| {
            // ~8 slots per timeout keeps reap latency near timeout/8
            // while one entry per connection bounds wheel memory.
            DeadlineWheel::new(cfg.idle_timeout / 8, 64)
        });
        let mut el = EventLoop {
            cfg,
            ctl,
            poller,
            conns: HashMap::new(),
            next_token: FIRST_CONN,
            wheel,
            draining: false,
            drain_since: None,
            listener: Some(listener),
            wake_rx,
            handler,
            scratch: vec![0u8; READ_CHUNK],
        };
        el.run()
    }

    struct EventLoop<'a, H: Handler> {
        cfg: &'a ServerConfig,
        ctl: &'a Arc<LoopCtl>,
        poller: Poller,
        conns: HashMap<ConnId, Conn>,
        next_token: ConnId,
        wheel: Option<DeadlineWheel>,
        draining: bool,
        drain_since: Option<Instant>,
        listener: Option<TcpListener>,
        wake_rx: UnixStream,
        handler: &'a mut H,
        scratch: Vec<u8>,
    }

    impl<H: Handler> EventLoop<'_, H> {
        fn run(&mut self) -> io::Result<()> {
            let mut events: Vec<Event> = Vec::new();
            loop {
                let timeout = if self.draining {
                    Some(DRAIN_TICK)
                } else {
                    self.wheel.as_ref().and_then(|w| w.next_due(Instant::now()))
                };
                self.poller.wait(&mut events, timeout)?;
                self.handler.on_wakeup();
                if self.ctl.shutdown_requested() {
                    self.begin_drain();
                }
                for ev in events.iter().copied() {
                    match ev.token {
                        TOK_LISTENER => self.accept_ready(),
                        TOK_WAKER => self.drain_waker(),
                        _ => self.conn_event(ev),
                    }
                }
                // Unconditional drain: completions may land between the
                // mailbox check and the next wait, but the paired waker
                // byte guarantees the next iteration sees them.
                for (token, line) in self.ctl.take_completions() {
                    self.handler.on_complete(token);
                    if let Some(conn) = self.conns.get_mut(&token) {
                        conn.in_flight = false;
                        conn.touch(Instant::now());
                        conn.queue_line(&line);
                        self.advance(token);
                    }
                }
                if !self.draining {
                    self.reap(Instant::now());
                }
                if self.draining && self.drain_sweep() {
                    return Ok(());
                }
            }
        }

        fn drain_waker(&mut self) {
            let mut buf = [0u8; 64];
            loop {
                match (&self.wake_rx).read(&mut buf) {
                    Ok(0) => break, // write half dropped — shutting down
                    Ok(_) => continue,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => break, // WouldBlock: fully drained
                }
            }
        }

        fn accept_ready(&mut self) {
            loop {
                let Some(listener) = &self.listener else { return };
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        if self.draining {
                            continue; // raced a drain: drop silently
                        }
                        if self.conns.len() >= self.cfg.max_conns {
                            let line = self.handler.on_conn_limit();
                            let _ = stream.set_nonblocking(true);
                            let mut bytes = line.into_bytes();
                            bytes.push(b'\n');
                            let _ = (&stream).write(&bytes); // best effort
                            continue;
                        }
                        if stream.set_nonblocking(true).is_err() {
                            continue;
                        }
                        let _ = stream.set_nodelay(true);
                        let token = self.next_token;
                        self.next_token += 1;
                        if self
                            .poller
                            .register(stream.as_raw_fd(), token, INTEREST_READ)
                            .is_err()
                        {
                            continue;
                        }
                        let now = Instant::now();
                        self.conns.insert(token, Conn::new(stream, now));
                        if let Some(w) = self.wheel.as_mut() {
                            w.schedule(token, now + self.cfg.idle_timeout);
                        }
                        self.handler.on_accept(token);
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => return, // transient (EMFILE, ...): retry on next event
                }
            }
        }

        fn conn_event(&mut self, ev: Event) {
            if ev.failed && self.conns.get(&ev.token).is_some_and(|c| c.in_flight) {
                // Peer hung up while its request runs: the response is
                // undeliverable, and a level-triggered poller would
                // re-report HUP on every wait until the worker
                // finishes. Close now; on_complete still fires at
                // completion.
                self.close_conn(ev.token);
                return;
            }
            if ev.readable || ev.failed {
                self.read_ready(ev.token);
            }
            if ev.writable {
                self.advance(ev.token);
            }
        }

        /// Pull bytes and parse lines until the socket blocks or the
        /// connection stops accepting input (in-flight, closing,
        /// backpressured, or draining).
        fn read_ready(&mut self, token: ConnId) {
            loop {
                let Some(conn) = self.conns.get_mut(&token) else { return };
                if conn.in_flight
                    || conn.closing
                    || conn.peer_closed
                    || self.draining
                    || conn.write_pending() >= WRITE_HIGH_WATERMARK
                {
                    break;
                }
                match conn.fill(&mut self.scratch) {
                    Fill::Data => {
                        conn.touch(Instant::now());
                        // Dispatch per chunk: a frame payload is folded
                        // into f32s here, so `read_buf` stays O(chunk)
                        // however large the panel being received is.
                        if self.process_events(token) {
                            return; // connection gone
                        }
                    }
                    Fill::WouldBlock => break,
                    Fill::Eof => {
                        conn.peer_closed = true;
                        break;
                    }
                    Fill::Err(_) => {
                        self.close_conn(token);
                        return;
                    }
                }
            }
            self.advance(token);
        }

        /// Split and dispatch complete input events — request lines and
        /// binary frames, in arrival order. Returns true if the
        /// connection no longer exists.
        fn process_events(&mut self, token: ConnId) -> bool {
            loop {
                let Some(conn) = self.conns.get_mut(&token) else { return true };
                if conn.in_flight
                    || conn.closing
                    || self.draining
                    || conn.write_pending() >= WRITE_HIGH_WATERMARK
                {
                    return false;
                }
                let event = match conn.next_event() {
                    Ok(Some(ev)) => ev,
                    Ok(None) => {
                        // Line-overflow accounting only applies to line
                        // traffic: a frame drains its bytes as they
                        // arrive, so mid-frame the buffer is tiny.
                        if !conn.in_frame() && conn.line_overflow(self.cfg.max_line_bytes) {
                            let msg = self.handler.on_overflow(token);
                            let conn = self.conns.get_mut(&token).expect("conn alive");
                            conn.queue_line(&msg);
                            conn.closing = true;
                        }
                        return false;
                    }
                    Err(reason) => {
                        let msg = self.handler.on_bad_frame(token, &reason);
                        let conn = self.conns.get_mut(&token).expect("conn alive");
                        conn.queue_line(&msg);
                        conn.closing = true;
                        return false;
                    }
                };
                let disposition = match event {
                    ConnEvent::Line(line) => {
                        if line.trim().is_empty() {
                            continue;
                        }
                        self.handler.on_line(token, &line)
                    }
                    ConnEvent::Frame(frame) => self.handler.on_frame(token, frame),
                };
                match disposition {
                    Disposition::Respond(resp) => {
                        let conn = self.conns.get_mut(&token).expect("conn alive");
                        conn.queue_line(&resp);
                    }
                    Disposition::Submitted => {
                        let conn = self.conns.get_mut(&token).expect("conn alive");
                        conn.in_flight = true;
                    }
                    Disposition::RespondAndClose(resp) => {
                        let conn = self.conns.get_mut(&token).expect("conn alive");
                        conn.queue_line(&resp);
                        conn.closing = true;
                        return false;
                    }
                    Disposition::RespondAndDrain(resp) => {
                        let conn = self.conns.get_mut(&token).expect("conn alive");
                        conn.queue_line(&resp);
                        self.begin_drain();
                        return false;
                    }
                }
            }
        }

        /// The single convergence point after any progress on a
        /// connection (bytes read, completion delivered, socket became
        /// writable): flush, parse anything newly parseable — e.g.
        /// pipelined requests that were parked behind an in-flight one,
        /// which a level-triggered poller will NOT re-report because
        /// the bytes already left the socket — then close or resync
        /// poller interest.
        fn advance(&mut self, token: ConnId) {
            {
                let Some(conn) = self.conns.get_mut(&token) else { return };
                if conn.flush().is_err() {
                    self.close_conn(token);
                    return;
                }
            }
            if self.process_events(token) {
                return;
            }
            let Some(conn) = self.conns.get_mut(&token) else { return };
            if conn.flush().is_err() {
                self.close_conn(token);
                return;
            }
            let flushed = conn.write_pending() == 0;
            if flushed && conn.closing {
                self.close_conn(token);
                return;
            }
            if flushed && conn.peer_closed && !conn.in_flight && !conn.has_complete_line() {
                // EOF seen, everything owed delivered, nothing left to
                // parse (a trailing partial line is discarded, like the
                // old front end).
                self.close_conn(token);
                return;
            }
            self.sync_interest(token);
        }

        fn sync_interest(&mut self, token: ConnId) {
            let Some(conn) = self.conns.get(&token) else { return };
            let desired = conn.desired_interest(self.draining);
            if desired != conn.registered {
                let fd = conn.stream.as_raw_fd();
                if self.poller.reregister(fd, token, desired).is_err() {
                    self.close_conn(token);
                    return;
                }
                self.conns.get_mut(&token).expect("conn alive").registered = desired;
            }
        }

        fn close_conn(&mut self, token: ConnId) {
            if let Some(conn) = self.conns.remove(&token) {
                let _ = self.poller.deregister(conn.stream.as_raw_fd(), token);
                self.handler.on_close(token);
                // conn drops here, closing the socket
            }
        }

        fn begin_drain(&mut self) {
            if self.draining {
                return;
            }
            self.draining = true;
            self.drain_since = Some(Instant::now());
            if let Some(listener) = self.listener.take() {
                let _ = self.poller.deregister(listener.as_raw_fd(), TOK_LISTENER);
            }
        }

        /// Expire wheel entries, lazily revalidating each candidate:
        /// still-active or in-flight connections are rescheduled, truly
        /// idle ones are reaped.
        fn reap(&mut self, now: Instant) {
            let Some(wheel) = self.wheel.as_mut() else { return };
            let mut due = Vec::new();
            wheel.expire(now, |t| due.push(t));
            for token in due {
                let Some(conn) = self.conns.get(&token) else { continue };
                let idle = now.saturating_duration_since(conn.last_activity);
                if idle >= self.cfg.idle_timeout && !conn.in_flight {
                    self.handler.on_reaped(token);
                    self.close_conn(token);
                } else {
                    // Touched since scheduling (or still working):
                    // reschedule for the remaining idle budget.
                    let due_at = (conn.last_activity + self.cfg.idle_timeout).max(now);
                    if let Some(w) = self.wheel.as_mut() {
                        w.schedule(token, due_at);
                    }
                }
            }
        }

        /// Close every connection that is finished (flushed, nothing in
        /// flight) — or everything still lingering once the flush grace
        /// expires. Returns true when the loop can exit.
        fn drain_sweep(&mut self) -> bool {
            let force = self
                .drain_since
                .map(|t| t.elapsed() >= DRAIN_FLUSH_GRACE)
                .unwrap_or(false);
            let victims: Vec<ConnId> = self
                .conns
                .iter()
                .filter(|(_, c)| {
                    if c.in_flight && !force {
                        return false; // completion still owed
                    }
                    c.write_pending() == 0 || force
                })
                .map(|(t, _)| *t)
                .collect();
            for token in victims {
                // One last flush so a just-queued response isn't
                // dropped when the socket would have taken it.
                if let Some(conn) = self.conns.get_mut(&token) {
                    let _ = conn.flush();
                }
                self.close_conn(token);
            }
            self.conns.is_empty()
        }
    }
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader, Write};
    use std::net::{TcpListener, TcpStream};
    use std::sync::atomic::AtomicUsize;

    /// Toy policy: "ping"→pong, "work"→async completion after a short
    /// delay, "bye"→close, "stop"→drain. Counts lifecycle callbacks.
    struct TestHandler {
        ctl: Arc<LoopCtl>,
        stats: Arc<Stats>,
    }

    #[derive(Default)]
    struct Stats {
        accepted: AtomicUsize,
        closed: AtomicUsize,
        completed: AtomicUsize,
        reaped: AtomicUsize,
        limited: AtomicUsize,
    }

    impl Handler for TestHandler {
        fn on_accept(&mut self, _conn: ConnId) {
            self.stats.accepted.fetch_add(1, Ordering::Relaxed);
        }
        fn on_line(&mut self, conn: ConnId, line: &str) -> Disposition {
            match line {
                "ping" => Disposition::Respond("pong".into()),
                "work" => {
                    let ctl = self.ctl.clone();
                    std::thread::spawn(move || {
                        std::thread::sleep(Duration::from_millis(30));
                        ctl.complete(conn, "done".into());
                    });
                    Disposition::Submitted
                }
                "bye" => Disposition::RespondAndClose("bye".into()),
                "stop" => Disposition::RespondAndDrain("stopping".into()),
                other => Disposition::Respond(format!("echo {other}")),
            }
        }
        fn on_frame(&mut self, _conn: ConnId, frame: crate::net::conn::FrameRequest) -> Disposition {
            let sum: f32 = frame.payload.iter().sum();
            Disposition::Respond(format!("frame {} {}", frame.payload.len(), sum))
        }
        fn on_complete(&mut self, _conn: ConnId) {
            self.stats.completed.fetch_add(1, Ordering::Relaxed);
        }
        fn on_close(&mut self, _conn: ConnId) {
            self.stats.closed.fetch_add(1, Ordering::Relaxed);
        }
        fn on_conn_limit(&mut self) -> String {
            self.stats.limited.fetch_add(1, Ordering::Relaxed);
            "full".into()
        }
        fn on_overflow(&mut self, _conn: ConnId) -> String {
            "toolong".into()
        }
        fn on_reaped(&mut self, _conn: ConnId) {
            self.stats.reaped.fetch_add(1, Ordering::Relaxed);
        }
    }

    struct TestServer {
        addr: String,
        ctl: Arc<LoopCtl>,
        stats: Arc<Stats>,
        join: std::thread::JoinHandle<std::io::Result<()>>,
    }

    fn start(cfg: ServerConfig) -> TestServer {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let (ctl, wake_rx) = LoopCtl::new().unwrap();
        let stats = Arc::new(Stats::default());
        let ctl2 = ctl.clone();
        let stats2 = stats.clone();
        let join = std::thread::spawn(move || {
            let mut handler = TestHandler { ctl: ctl2.clone(), stats: stats2 };
            run(listener, &cfg, &ctl2, wake_rx, &mut handler)
        });
        TestServer { addr, ctl, stats, join }
    }

    fn cfg() -> ServerConfig {
        ServerConfig {
            max_conns: 64,
            max_line_bytes: 1 << 20,
            idle_timeout: Duration::ZERO,
            backend: crate::net::poller::Backend::Auto,
        }
    }

    fn roundtrip(stream: &TcpStream, reader: &mut impl BufRead, line: &str) -> String {
        let mut s = stream;
        s.write_all(format!("{line}\n").as_bytes()).unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        resp.trim_end().to_string()
    }

    #[test]
    fn inline_async_and_close_dispositions() {
        let srv = start(cfg());
        let stream = TcpStream::connect(&srv.addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        assert_eq!(roundtrip(&stream, &mut reader, "ping"), "pong");
        assert_eq!(roundtrip(&stream, &mut reader, "work"), "done");
        // pipelined: a request queued behind an async one still gets
        // answered, in order, once the completion lands
        (&stream).write_all(b"work\nping\n").unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        assert_eq!(resp.trim_end(), "done");
        resp.clear();
        reader.read_line(&mut resp).unwrap();
        assert_eq!(resp.trim_end(), "pong");
        assert_eq!(roundtrip(&stream, &mut reader, "bye"), "bye");
        let mut eof = String::new();
        assert_eq!(reader.read_line(&mut eof).unwrap(), 0); // server closed
        srv.ctl.request_shutdown();
        srv.join.join().unwrap().unwrap();
        assert_eq!(srv.stats.completed.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn connection_limit_rejects_with_line() {
        let mut c = cfg();
        c.max_conns = 1;
        let srv = start(c);
        let keep = TcpStream::connect(&srv.addr).unwrap();
        let mut keep_reader = BufReader::new(keep.try_clone().unwrap());
        assert_eq!(roundtrip(&keep, &mut keep_reader, "ping"), "pong");
        let reject = TcpStream::connect(&srv.addr).unwrap();
        let mut line = String::new();
        BufReader::new(&reject).read_line(&mut line).unwrap();
        assert_eq!(line.trim_end(), "full");
        assert_eq!(srv.stats.limited.load(Ordering::Relaxed), 1);
        srv.ctl.request_shutdown();
        srv.join.join().unwrap().unwrap();
    }

    #[test]
    fn overflow_line_rejected_then_closed() {
        let mut c = cfg();
        c.max_line_bytes = 32;
        let srv = start(c);
        let stream = TcpStream::connect(&srv.addr).unwrap();
        (&stream).write_all(&[b'x'; 128]).unwrap(); // no newline
        let mut reader = BufReader::new(&stream);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim_end(), "toolong");
        line.clear();
        assert_eq!(reader.read_line(&mut line).unwrap(), 0);
        srv.ctl.request_shutdown();
        srv.join.join().unwrap().unwrap();
    }

    #[test]
    fn drain_completes_in_flight_work() {
        let srv = start(cfg());
        let worker = TcpStream::connect(&srv.addr).unwrap();
        let mut worker_reader = BufReader::new(worker.try_clone().unwrap());
        (&worker).write_all(b"work\n").unwrap();
        std::thread::sleep(Duration::from_millis(5)); // let it submit
        let stopper = TcpStream::connect(&srv.addr).unwrap();
        let mut stop_reader = BufReader::new(stopper.try_clone().unwrap());
        assert_eq!(roundtrip(&stopper, &mut stop_reader, "stop"), "stopping");
        // the in-flight job still completes and is delivered
        let mut resp = String::new();
        worker_reader.read_line(&mut resp).unwrap();
        assert_eq!(resp.trim_end(), "done");
        srv.join.join().unwrap().unwrap();
        assert_eq!(
            srv.stats.closed.load(Ordering::Relaxed),
            srv.stats.accepted.load(Ordering::Relaxed)
        );
    }

    #[test]
    fn binary_frames_dispatch_and_malformed_frames_close() {
        let srv = start(cfg());
        let stream = TcpStream::connect(&srv.addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let hdr = crate::util::json::Json::parse(r#"{"v": 2}"#).unwrap();
        let bytes = crate::api::wire::encode_frame(&hdr, &[1.0, 2.5]);
        (&stream).write_all(&bytes).unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        assert_eq!(resp.trim_end(), "frame 2 3.5");
        // lines still work on the same connection after a frame
        assert_eq!(roundtrip(&stream, &mut reader, "ping"), "pong");
        // mis-framed stream: payload not a multiple of 4 -> the default
        // typed protocol error, then close
        let bad = TcpStream::connect(&srv.addr).unwrap();
        (&bad).write_all(b"TMFB").unwrap();
        (&bad).write_all(&8u32.to_le_bytes()).unwrap();
        (&bad).write_all(&7u64.to_le_bytes()).unwrap();
        let mut bad_reader = BufReader::new(&bad);
        let mut line = String::new();
        bad_reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"protocol\""), "unexpected: {line}");
        line.clear();
        assert_eq!(bad_reader.read_line(&mut line).unwrap(), 0); // closed
        srv.ctl.request_shutdown();
        srv.join.join().unwrap().unwrap();
    }

    #[test]
    fn idle_connections_reaped() {
        let mut c = cfg();
        c.idle_timeout = Duration::from_millis(60);
        let srv = start(c);
        let idle = TcpStream::connect(&srv.addr).unwrap();
        idle.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut reader = BufReader::new(&idle);
        let mut line = String::new();
        // blocking read: returns 0 when the reaper closes us
        assert_eq!(reader.read_line(&mut line).unwrap(), 0);
        assert!(srv.stats.reaped.load(Ordering::Relaxed) >= 1);
        srv.ctl.request_shutdown();
        srv.join.join().unwrap().unwrap();
    }
}
