//! Sparse large-n subsystem: deterministic k-NN candidate graphs and
//! sparse-gain TMFG construction.
//!
//! The dense pipeline materializes an O(n²) similarity matrix before the
//! graph stages start, which caps practical inputs at a few thousand
//! series. This subsystem opens the large-n workload:
//!
//! * [`knn::knn_candidates`] — a parallel, thread-count-deterministic
//!   k-NN builder over the standardized panel (exact blocked top-k, with
//!   a seeded random-projection prefilter for very large n);
//! * [`csr::SparseSimilarity`] — CSR storage with per-vertex sorted
//!   neighbor lists and an explicit missing-entry semantic (similarity
//!   0 / distance ∞);
//! * [`tmfg::sparse_tmfg`] — CORR-TMFG's lazy-gain machinery restricted
//!   to candidate neighbors, with a counted dense-scan fallback, byte-
//!   identical to the dense construction when the candidate set is
//!   complete.
//!
//! Downstream, APSP and DBHT run unchanged: the TMFG is already sparse
//! (3n−6 edges), and DBHT reads similarities only at TMFG-edge /
//! clique-co-member pairs, which
//! [`crate::data::matrix::SimilarityLookup`] serves straight from the
//! CSR store. Memory over the whole sparse prefix is O(n·k) instead of
//! O(n²); the dense n×n APSP distance matrix remains the large-n
//! footprint to budget for (≈1 GiB at n = 16384 in f32).
//!
//! Entry points: `ClusterRequest::sparse_knn(k, seed)` in the typed API,
//! `{"sparse_k": …}` on the wire, `--sparse-k` on the CLI.

pub mod csr;
pub mod knn;
pub mod tmfg;

pub use csr::SparseSimilarity;
pub use knn::{knn_candidates, KnnConfig, DEFAULT_KNN_SEED};
pub use tmfg::{sparse_tmfg, SparseTmfgReport};
