//! Sparse-gain TMFG construction over a k-NN candidate graph.
//!
//! Structurally this is CORR-TMFG (Alg. 1, prefix 1) with the paper's
//! lazy-gain bookkeeping applied to sparse candidate lists: each
//! vertex's pre-sorted row holds only its stored candidates (by
//! similarity descending, index ascending — the dense row order), the
//! per-vertex `MaxCorrs` pointer advances over that list, and a face's
//! best pair is recomputed only when its chosen candidate was just
//! inserted. Missing pairs contribute **gain 0** (the
//! [`SparseSimilarity`] missing-entry semantic), so gains of candidate
//! vertices remain exact sums over the stored entries.
//!
//! When every alive face has exhausted its candidates while vertices
//! remain, one round falls back to a dense scan: the lowest-id alive
//! face takes the uninserted vertex with the highest sparse gain (ties →
//! lowest index). Fallbacks are counted and reported — a high count
//! means `k` is too small for the panel's structure.
//!
//! **Equivalence**: with a complete candidate set (k = n−1) every
//! decision point — seed-clique selection, row order, scan, gain fold
//! order, argmax tie-breaking, face bookkeeping — reproduces the dense
//! [`crate::tmfg::corr_tmfg`] byte-for-byte (pinned by the determinism
//! suite).

use super::csr::SparseSimilarity;
use crate::data::matrix::SimilarityLookup;
use crate::error::TmfgError;
use crate::parlay;
use crate::tmfg::common::{Builder, Faces, TmfgResult, TmfgTimings};

/// Construction statistics specific to the sparse path.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SparseTmfgReport {
    /// Rounds that had to fall back to a dense scan because every alive
    /// face had exhausted its candidate list.
    pub fallbacks: usize,
}

/// Sentinel gain entry for a face whose candidate lists are exhausted.
const EXHAUSTED: (f32, u32) = (f32::NEG_INFINITY, u32::MAX);

/// Per-vertex candidate rows sorted by (similarity desc, index asc) with
/// `MaxCorrs` pointers — the sparse analog of `CorrState`.
struct SparseState {
    offsets: Vec<usize>,
    /// Concatenated candidate rows, each sorted by sim desc / idx asc.
    sorted: Vec<u32>,
    ptr: Vec<u32>,
    inserted: Vec<u8>,
    n_rem: usize,
}

impl SparseState {
    fn build(s: &SparseSimilarity) -> SparseState {
        let n = s.n();
        let mut offsets = vec![0usize; n + 1];
        for v in 0..n {
            offsets[v + 1] = offsets[v] + s.degree(v);
        }
        let mut sorted: Vec<u32> = Vec::with_capacity(offsets[n]);
        let sp = parlay::SendPtr(sorted.as_mut_ptr());
        let offs = &offsets;
        parlay::par_map_scratch(n, 4, |v, scratch: &mut Vec<(f32, u32)>| {
            let (cols, vals) = s.row(v);
            scratch.clear();
            for (i, &u) in cols.iter().enumerate() {
                scratch.push((vals[i], u));
            }
            // the dense CorrState row order (shared sparse comparator)
            super::csr::sort_by_sim_desc(scratch);
            for (i, &(_, u)) in scratch.iter().enumerate() {
                // SAFETY: row v writes only its own [offsets[v], offsets[v+1])
                // segment.
                unsafe { sp.write(offs[v] + i, u) };
            }
        });
        unsafe { sorted.set_len(offsets[n]) };
        SparseState { offsets, sorted, ptr: vec![0; n], inserted: vec![0; n], n_rem: n }
    }

    #[inline]
    fn mark_inserted(&mut self, v: u32) {
        debug_assert_eq!(self.inserted[v as usize], 0, "double insertion of {v}");
        self.inserted[v as usize] = 1;
        self.n_rem -= 1;
    }

    /// First uninserted candidate of `v`'s sorted row (the scalar
    /// `MaxCorrs` scan); `None` when the row is exhausted.
    #[inline]
    fn maxcorr(&mut self, v: u32) -> Option<u32> {
        let row = &self.sorted[self.offsets[v as usize]..self.offsets[v as usize + 1]];
        let mut p = self.ptr[v as usize] as usize;
        while p < row.len() && self.inserted[row[p] as usize] != 0 {
            p += 1;
        }
        self.ptr[v as usize] = p as u32;
        row.get(p).copied()
    }

    /// Best (gain, vertex) pair for face `f` among the up-to-3 per-vertex
    /// candidates — the dense `best_pair` with sparse gains. `None` when
    /// all three candidate lists are exhausted.
    fn best_pair(&mut self, s: &SparseSimilarity, f: &[u32; 3]) -> Option<(f32, u32)> {
        let mut best: Option<(f32, u32)> = None;
        for &w in f {
            if let Some(cand) = self.maxcorr(w) {
                let g = gain(s, f, cand);
                match best {
                    Some((bg, bv)) if bg > g || (bg == g && bv <= cand) => {}
                    _ => best = Some((g, cand)),
                }
            }
        }
        best
    }
}

/// Sparse gain: Σ_{u ∈ f} S[v,u], missing entries contributing 0, added
/// in face-vertex order (the dense fold order).
#[inline]
fn gain(s: &SparseSimilarity, f: &[u32; 3], v: u32) -> f32 {
    let r = v as usize;
    s.sim(r, f[0] as usize) + s.sim(r, f[1] as usize) + s.sim(r, f[2] as usize)
}

/// Seed clique: top-4 vertices by candidate-row sum (implicit unit
/// diagonal included, terms folded in ascending column order) — the
/// dense `initial_clique` selection, bit-for-bit when the candidate set
/// is complete.
fn initial_clique_sparse(s: &SparseSimilarity) -> [u32; 4] {
    let n = s.n();
    let sums = parlay::par_map(n, 8, |v| s.row_sum_with_diag(v));
    let mut best: Vec<(f64, u32)> = Vec::with_capacity(5);
    for (i, &v) in sums.iter().enumerate() {
        best.push((v, i as u32));
        best.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
        best.truncate(4);
    }
    [best[0].1, best[1].1, best[2].1, best[3].1]
}

/// Dense-scan fallback: the uninserted vertex with the highest sparse
/// gain for face `f` (ties → lowest index). O(n · log k).
fn fallback_vertex(s: &SparseSimilarity, state: &SparseState, f: &[u32; 3]) -> u32 {
    let mut best = (f32::NEG_INFINITY, u32::MAX);
    for u in 0..state.inserted.len() as u32 {
        if state.inserted[u as usize] == 0 {
            let g = gain(s, f, u);
            if g > best.0 || (g == best.0 && u < best.1) {
                best = (g, u);
            }
        }
    }
    debug_assert_ne!(best.1, u32::MAX, "fallback with no uninserted vertex");
    best.1
}

/// Run sparse-gain TMFG construction (prefix 1) over a candidate graph.
/// The result satisfies every structural TMFG invariant
/// ([`crate::tmfg::common::check_invariants`]); quality depends on the
/// candidate set's k.
pub fn sparse_tmfg(
    s: &SparseSimilarity,
) -> Result<(TmfgResult, SparseTmfgReport), TmfgError> {
    let n = s.n();
    if n < 4 {
        return Err(TmfgError::invalid(format!(
            "TMFG needs at least 4 vertices, got {n}"
        )));
    }
    let mut timer = crate::util::timer::Timer::start();
    let mut timings = TmfgTimings::default();
    let mut report = SparseTmfgReport::default();
    let seed = initial_clique_sparse(s);
    timings.init = timer.lap();
    let mut builder = Builder::new(seed, n);
    let mut faces = Faces::new(&seed);
    let mut state = SparseState::build(s);
    timings.sort = timer.lap();
    for &v in &seed {
        state.mark_inserted(v);
    }

    if n == 4 {
        let mut r = builder.finish(n, faces.alive_faces());
        r.timings = timings;
        return Ok((r, report));
    }

    // gains[f] = best (gain, vertex) pair for face f (EXHAUSTED when the
    // face's candidate lists have run dry).
    let mut gains: Vec<(f32, u32)> = Vec::with_capacity(6 * n);
    for fid in 0..4 {
        let fv = faces.verts[fid];
        gains.push(state.best_pair(s, &fv).unwrap_or(EXHAUSTED));
    }

    let mut round: u64 = 0;
    while state.n_rem > 0 {
        let _round_span = crate::span!("tmfg_round", "sparse round {round} rem={}", state.n_rem);
        round += 1;
        // ---- selection: argmax gain over alive faces -----------------------
        let ids = faces.alive_ids();
        let g = &gains;
        let best = parlay::par_argmax(ids.len(), 256, |k| g[ids[k] as usize].0)
            .ok_or_else(|| TmfgError::invariant("no alive faces while vertices remain"))?;
        let (fid, v) = {
            let fid = ids[best];
            let (_, v) = gains[fid as usize];
            if v == u32::MAX {
                // Every alive face is exhausted: dense-scan fallback on
                // the lowest-id alive face.
                report.fallbacks += 1;
                let fb = ids[0];
                (fb, fallback_vertex(s, &state, &faces.verts[fb as usize]))
            } else {
                (fid, v)
            }
        };

        // ---- insertion -----------------------------------------------------
        debug_assert!(faces.alive[fid as usize]);
        debug_assert_eq!(state.inserted[v as usize], 0);
        let fv = faces.verts[fid as usize];
        let owner = builder.insert(v, fv, faces.owner[fid as usize]);
        let new_faces = faces.split(fid, v, owner);
        state.mark_inserted(v);

        if state.n_rem == 0 {
            break;
        }

        // ---- update: the three new faces, plus alive faces whose chosen
        // candidate was just inserted -----------------------------------------
        gains.resize(faces.len(), EXHAUSTED);
        let mut to_update: Vec<u32> = new_faces.to_vec();
        for f in faces.alive_ids() {
            if gains.get(f as usize).map(|p| p.1 == v).unwrap_or(false) {
                to_update.push(f);
            }
        }
        to_update.sort_unstable();
        to_update.dedup();
        // Sequential: the maxcorr pointer advance mutates state; total
        // scan work is amortized O(nnz) over the whole construction.
        for f in to_update {
            let fv = faces.verts[f as usize];
            gains[f as usize] = state.best_pair(s, &fv).unwrap_or(EXHAUSTED);
        }
    }

    timings.insert = timer.lap();
    let mut r = builder.finish(n, faces.alive_faces());
    r.timings = timings;
    debug_assert!(crate::tmfg::common::check_invariants(&r).is_ok());
    Ok((r, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::data::Matrix;
    use crate::tmfg::common::check_invariants;
    use crate::tmfg::{corr_tmfg, TmfgConfig};

    fn random_corr(n: usize, seed: u64) -> Matrix {
        let ds = SynthSpec::new("t", n, 48, 3).generate(seed);
        crate::data::corr::pearson_correlation(&ds.data)
    }

    #[test]
    fn valid_tmfg_across_sizes_and_k() {
        for (n, k) in [(4usize, 3usize), (5, 2), (10, 4), (50, 8), (200, 16), (120, 3)] {
            let s = random_corr(n, n as u64);
            let sp = SparseSimilarity::from_dense(&s, k).unwrap();
            let (r, _) = sparse_tmfg(&sp).unwrap();
            check_invariants(&r).unwrap_or_else(|e| panic!("n={n} k={k}: {e}"));
        }
    }

    #[test]
    fn complete_candidates_byte_identical_to_dense_corr() {
        for seed in [1u64, 2, 3] {
            let s = random_corr(60, seed);
            let sp = SparseSimilarity::from_dense(&s, 59).unwrap();
            let (sparse, report) = sparse_tmfg(&sp).unwrap();
            let dense = corr_tmfg(&s, &TmfgConfig::default()).unwrap();
            assert_eq!(sparse.edges, dense.edges, "seed {seed}");
            assert_eq!(sparse.cliques, dense.cliques, "seed {seed}");
            assert_eq!(sparse.faces, dense.faces, "seed {seed}");
            assert_eq!(sparse.order, dense.order, "seed {seed}");
            assert_eq!(report.fallbacks, 0, "complete set never falls back");
        }
    }

    #[test]
    fn small_k_falls_back_but_stays_valid() {
        // k=1 starves the candidate lists quickly; the construction must
        // complete via fallbacks and still be a structurally valid TMFG.
        let s = random_corr(40, 9);
        let sp = SparseSimilarity::from_dense(&s, 1).unwrap();
        let (r, report) = sparse_tmfg(&sp).unwrap();
        check_invariants(&r).unwrap();
        assert!(report.fallbacks > 0, "k=1 should exhaust candidates");
    }

    #[test]
    fn deterministic_across_thread_counts() {
        let s = random_corr(80, 4);
        let sp = SparseSimilarity::from_dense(&s, 12).unwrap();
        let base = crate::parlay::with_threads(1, || sparse_tmfg(&sp).unwrap());
        for t in [2usize, 4] {
            let got = crate::parlay::with_threads(t, || sparse_tmfg(&sp).unwrap());
            assert_eq!(got.0.edges, base.0.edges, "threads={t}");
            assert_eq!(got.0.cliques, base.0.cliques, "threads={t}");
            assert_eq!(got.1, base.1, "threads={t}");
        }
    }

    #[test]
    fn larger_k_no_worse_edge_sum() {
        // More candidates ⇒ the greedy search sees a superset of options
        // each round; quality (edge sum under the full similarity) should
        // not degrade. Not a theorem for greedy, so allow slack.
        let s = random_corr(150, 6);
        let e_small = {
            let sp = SparseSimilarity::from_dense(&s, 4).unwrap();
            sparse_tmfg(&sp).unwrap().0.edge_sum(&s)
        };
        let e_full = {
            let sp = SparseSimilarity::from_dense(&s, 149).unwrap();
            sparse_tmfg(&sp).unwrap().0.edge_sum(&s)
        };
        assert!(
            e_full >= e_small - 0.05 * e_small.abs(),
            "complete-candidate edge sum {e_full} far below k=4 sum {e_small}"
        );
    }

    #[test]
    fn n4_early_return() {
        let s = random_corr(4, 1);
        let sp = SparseSimilarity::from_dense(&s, 3).unwrap();
        let (r, _) = sparse_tmfg(&sp).unwrap();
        check_invariants(&r).unwrap();
        assert_eq!(r.edges.len(), 6);
    }
}
