//! [`SparseSimilarity`] — CSR storage for a sparse candidate similarity
//! graph with per-vertex sorted neighbor lists.
//!
//! Missing-entry semantic: a pair (i, j) that is not a stored candidate
//! has **similarity 0** (equivalently: gain contribution 0 in TMFG
//! construction) and **distance ∞** under the correlation metric — the
//! two views of "we never measured this pair, assume uncorrelated". The
//! diagonal is implicit: `sim(v, v) = 1`, `distance(v, v) = 0`.

use crate::data::corr::corr_to_distance;
use crate::data::matrix::{Matrix, SimilarityLookup};
use crate::error::TmfgError;
use crate::parlay;

/// The one candidate total order of the sparse subsystem: similarity
/// descending, index ascending — exactly the comparator dense
/// `CorrState::build` sorts its rows with. Every sparse site (k-NN
/// top-k selection, `from_dense`, the sparse TMFG's candidate rows)
/// must use this helper, or the k = n−1 byte-identity with the dense
/// construction (pinned in `rust/tests/determinism.rs`) silently breaks.
pub(crate) fn sort_by_sim_desc(pairs: &mut [(f32, u32)]) {
    pairs.sort_unstable_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
}

/// Keep the top k pairs under [`sort_by_sim_desc`]'s order.
pub(crate) fn top_k(pairs: &mut Vec<(f32, u32)>, k: usize) {
    sort_by_sim_desc(pairs);
    pairs.truncate(k);
}

/// Symmetric n×n sparse similarity in CSR form. Each row's columns are
/// sorted ascending (binary-searchable); the matrix is structurally
/// symmetric (entry (i,j) present ⇔ (j,i) present, with equal values).
#[derive(Debug, Clone, PartialEq)]
pub struct SparseSimilarity {
    n: usize,
    /// Row start offsets, length n+1.
    row_ptr: Vec<usize>,
    /// Column indices, sorted ascending within each row.
    cols: Vec<u32>,
    /// Similarity values, parallel to `cols`.
    vals: Vec<f32>,
}

impl SparseSimilarity {
    /// Build from an undirected edge list `(u, v, sim)` with `u != v`.
    /// Duplicate pairs (in either orientation) are rejected — the k-NN
    /// builder dedupes before constructing, so a duplicate here is a
    /// logic error upstream.
    pub fn from_edges(n: usize, edges: &[(u32, u32, f32)]) -> Result<SparseSimilarity, TmfgError> {
        let mut deg = vec![0usize; n];
        for &(u, v, _) in edges {
            if u == v || u as usize >= n || v as usize >= n {
                return Err(TmfgError::invalid(format!(
                    "sparse edge ({u},{v}) invalid for n={n}"
                )));
            }
            deg[u as usize] += 1;
            deg[v as usize] += 1;
        }
        let mut row_ptr = vec![0usize; n + 1];
        for i in 0..n {
            row_ptr[i + 1] = row_ptr[i] + deg[i];
        }
        let nnz = row_ptr[n];
        let mut cols = vec![0u32; nnz];
        let mut vals = vec![0f32; nnz];
        let mut cursor = row_ptr[..n].to_vec();
        for &(u, v, w) in edges {
            let cu = cursor[u as usize];
            cols[cu] = v;
            vals[cu] = w;
            cursor[u as usize] += 1;
            let cv = cursor[v as usize];
            cols[cv] = u;
            vals[cv] = w;
            cursor[v as usize] += 1;
        }
        // Sort each row by column (parallel over rows; key order is a
        // function of the input alone, so this is thread-count
        // deterministic).
        {
            use crate::parlay::SendPtr;
            let cp = SendPtr(cols.as_mut_ptr());
            let vp = SendPtr(vals.as_mut_ptr());
            let rp = &row_ptr;
            parlay::parallel_for_chunks(n, 4, |lo, hi| {
                let mut scratch: Vec<(u32, f32)> = Vec::new();
                for r in lo..hi {
                    let (a, b) = (rp[r], rp[r + 1]);
                    scratch.clear();
                    for i in a..b {
                        // SAFETY: row r's [a, b) segment is touched only
                        // by iteration r.
                        unsafe { scratch.push((cp.read(i), vp.read(i))) };
                    }
                    scratch.sort_unstable_by_key(|&(c, _)| c);
                    for (off, &(c, v)) in scratch.iter().enumerate() {
                        unsafe {
                            cp.write(a + off, c);
                            vp.write(a + off, v);
                        }
                    }
                }
            });
        }
        let s = SparseSimilarity { n, row_ptr, cols, vals };
        for v in 0..n {
            let (c, _) = s.row(v);
            if c.windows(2).any(|w| w[0] == w[1]) {
                return Err(TmfgError::invalid(format!(
                    "duplicate sparse entry in row {v}"
                )));
            }
        }
        Ok(s)
    }

    /// The top-k sparsification of a dense similarity matrix: for every
    /// vertex keep its k most similar partners (ties → lower index),
    /// then symmetrize by union. With `k >= n - 1` this keeps every
    /// off-diagonal entry, which is how the equivalence tests reduce
    /// `sparse_tmfg` to the dense construction.
    pub fn from_dense(s: &Matrix, k: usize) -> Result<SparseSimilarity, TmfgError> {
        let n = crate::tmfg::common::validate_similarity(s)?;
        let k = k.clamp(1, n - 1);
        let picks: Vec<Vec<(u32, f32)>> = parlay::par_map(n, 4, |v| {
            let row = s.row(v);
            let mut pairs: Vec<(f32, u32)> = (0..n)
                .filter(|&u| u != v)
                .map(|u| (row[u], u as u32))
                .collect();
            top_k(&mut pairs, k);
            pairs.into_iter().map(|(w, u)| (u, w)).collect()
        });
        Self::from_directed_picks(n, &picks)
    }

    /// Symmetrize per-vertex directed candidate picks into the CSR form:
    /// the undirected union, one value per pair. Values for (u,v) and
    /// (v,u) are assumed equal when both directions picked the pair (the
    /// builders compute them with the same commutative kernel).
    pub(crate) fn from_directed_picks(
        n: usize,
        picks: &[Vec<(u32, f32)>],
    ) -> Result<SparseSimilarity, TmfgError> {
        let mut edges: Vec<(u32, u32, f32)> = Vec::with_capacity(picks.iter().map(Vec::len).sum());
        for (v, list) in picks.iter().enumerate() {
            for &(u, w) in list {
                let (a, b) = (u.min(v as u32), u.max(v as u32));
                edges.push((a, b, w));
            }
        }
        edges.sort_unstable_by(|x, y| (x.0, x.1).cmp(&(y.0, y.1)));
        edges.dedup_by_key(|e| (e.0, e.1));
        Self::from_edges(n, &edges)
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Stored (directed) entry count — twice the undirected pair count.
    pub fn nnz(&self) -> usize {
        self.cols.len()
    }

    /// Approximate resident bytes (for resource reporting).
    pub fn bytes(&self) -> usize {
        self.row_ptr.len() * 8 + self.cols.len() * 4 + self.vals.len() * 4
    }

    pub fn degree(&self, v: usize) -> usize {
        self.row_ptr[v + 1] - self.row_ptr[v]
    }

    pub fn mean_degree(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        self.nnz() as f64 / self.n as f64
    }

    /// Row v's neighbor columns (sorted ascending) and values.
    pub fn row(&self, v: usize) -> (&[u32], &[f32]) {
        let (a, b) = (self.row_ptr[v], self.row_ptr[v + 1]);
        (&self.cols[a..b], &self.vals[a..b])
    }

    /// The stored similarity for (i, j), `None` when the pair is not a
    /// candidate. The diagonal is implicit (`Some(1.0)`).
    #[inline]
    pub fn lookup(&self, i: usize, j: usize) -> Option<f32> {
        if i == j {
            return Some(1.0);
        }
        let (c, v) = self.row(i);
        c.binary_search(&(j as u32)).ok().map(|p| v[p])
    }

    /// Correlation distance d = √(2(1−ρ)); ∞ for missing pairs.
    #[inline]
    pub fn distance(&self, i: usize, j: usize) -> f32 {
        match self.lookup(i, j) {
            Some(rho) => corr_to_distance(rho),
            None => f32::INFINITY,
        }
    }

    /// Row sum Σ_u S[v,u] including the implicit unit diagonal, with the
    /// terms added in ascending column order — exactly the fold order of
    /// the dense `initial_clique` row sums, so a complete candidate set
    /// reproduces the dense seed selection bit-for-bit.
    pub fn row_sum_with_diag(&self, v: usize) -> f64 {
        let (c, w) = self.row(v);
        let mut acc = 0.0f64;
        let mut diag_added = false;
        for (i, &u) in c.iter().enumerate() {
            if !diag_added && (u as usize) > v {
                acc += 1.0;
                diag_added = true;
            }
            acc += w[i] as f64;
        }
        if !diag_added {
            acc += 1.0;
        }
        acc
    }
}

impl SimilarityLookup for SparseSimilarity {
    fn n_items(&self) -> usize {
        self.n
    }

    /// Missing pairs read as similarity 0 (the gain-0 semantic).
    #[inline]
    fn sim(&self, i: usize, j: usize) -> f32 {
        self.lookup(i, j).unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dense4() -> Matrix {
        let w = [
            [1.0, 0.9, 0.2, 0.4],
            [0.9, 1.0, 0.3, 0.1],
            [0.2, 0.3, 1.0, 0.8],
            [0.4, 0.1, 0.8, 1.0],
        ];
        let mut m = Matrix::zeros(4, 4);
        for i in 0..4 {
            for j in 0..4 {
                m.set(i, j, w[i][j]);
            }
        }
        m
    }

    #[test]
    fn from_edges_roundtrip() {
        let s = SparseSimilarity::from_edges(4, &[(0, 1, 0.9), (2, 3, 0.8), (0, 3, 0.4)]).unwrap();
        assert_eq!(s.n(), 4);
        assert_eq!(s.nnz(), 6);
        assert_eq!(s.lookup(0, 1), Some(0.9));
        assert_eq!(s.lookup(1, 0), Some(0.9));
        assert_eq!(s.lookup(0, 2), None);
        assert_eq!(s.sim(0, 2), 0.0);
        assert_eq!(s.sim(2, 2), 1.0);
        assert_eq!(s.distance(0, 0), 0.0);
        assert!(s.distance(0, 2).is_infinite());
        let (c, _) = s.row(0);
        assert_eq!(c, &[1, 3]);
        assert_eq!(s.degree(0), 2);
        assert_eq!(s.degree(1), 1);
    }

    #[test]
    fn from_edges_rejects_bad_input() {
        assert!(SparseSimilarity::from_edges(3, &[(0, 0, 1.0)]).is_err());
        assert!(SparseSimilarity::from_edges(3, &[(0, 5, 1.0)]).is_err());
        assert!(SparseSimilarity::from_edges(3, &[(0, 1, 0.5), (1, 0, 0.5)]).is_err());
    }

    #[test]
    fn from_dense_complete_keeps_everything() {
        let m = dense4();
        let s = SparseSimilarity::from_dense(&m, 3).unwrap();
        assert_eq!(s.nnz(), 12); // all off-diagonal pairs
        for i in 0..4 {
            for j in 0..4 {
                assert_eq!(s.sim(i, j), m.at(i, j), "({i},{j})");
            }
        }
    }

    #[test]
    fn from_dense_topk_symmetrizes_by_union() {
        let m = dense4();
        let s = SparseSimilarity::from_dense(&m, 1).unwrap();
        // vertex 0 picks 1 (0.9), vertex 2 picks 3 (0.8), and the
        // reverse directions pick the same pairs; union = {01, 23}.
        assert_eq!(s.lookup(0, 1), Some(0.9));
        assert_eq!(s.lookup(2, 3), Some(0.8));
        assert_eq!(s.lookup(0, 3), None);
        assert_eq!(s.nnz(), 4);
    }

    #[test]
    fn row_sum_with_diag_matches_dense_order() {
        let m = dense4();
        let s = SparseSimilarity::from_dense(&m, 3).unwrap();
        for v in 0..4 {
            // dense fold in ascending column order, diagonal included
            let mut expect = 0.0f64;
            for u in 0..4 {
                expect += m.at(v, u) as f64;
            }
            assert_eq!(s.row_sum_with_diag(v), expect, "row {v}");
        }
    }

    #[test]
    fn bytes_and_mean_degree_sane() {
        let s = SparseSimilarity::from_edges(4, &[(0, 1, 0.9), (2, 3, 0.8)]).unwrap();
        assert!(s.bytes() > 0);
        assert!((s.mean_degree() - 1.0).abs() < 1e-12);
    }
}
