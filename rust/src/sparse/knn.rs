//! Deterministic parallel k-NN candidate-graph construction over a
//! standardized time-series panel.
//!
//! For every series the k most-correlated partners are found and the
//! per-vertex picks are symmetrized by union into a
//! [`SparseSimilarity`]. Two regimes:
//!
//! * **Exact blocked top-k** (n ≤ `prefilter_above`): each vertex's
//!   correlations against all others are computed with the shared f32
//!   dot kernel and the top k kept — O(n²·L) work but only O(n·k)
//!   memory, parallelized over vertices with `parlay` chunking.
//! * **Random-projection prefilter + graph refinement**
//!   (n > `prefilter_above`): rows are projected through a seeded
//!   Gaussian matrix to `projection_dims` dimensions; each vertex
//!   shortlists `pool_factor · k` candidates by projected dot product
//!   and only the shortlist is re-scored exactly — O(n²·d + n·pool·L).
//!   The shortlist graph is then improved by `ann_iters` rounds of
//!   NN-descent-style refinement ([`refine_picks`]): each vertex
//!   re-scores its neighbors-of-neighbors and reverse neighbors (the
//!   "a neighbor of my neighbor is probably my neighbor" closure) and
//!   keeps the best k, O(n·pool·L) per round. A couple of rounds
//!   recover most of the recall the one-shot projection loses — the
//!   a-TMFG observation that TMFG quality survives ANN candidate
//!   restriction, with the graph-based search sharpening the
//!   candidates it survives on.
//!
//! **Determinism**: every per-vertex computation is a pure function of
//! the panel, `k`, and `seed` (the projection matrix is drawn from a
//! sequential seeded RNG before any parallel work; the refinement's
//! reverse adjacency is a sequential CSR transpose of the previous
//! round's picks), and per-vertex results are written to disjoint
//! slots — so the output is byte-identical for every thread count and
//! across reruns.

use super::csr::{top_k, SparseSimilarity};
use crate::data::corr::{standardize_rows_generic, CorrScalar};
use crate::data::matrix::Matrix;
use crate::error::TmfgError;
use crate::parlay;
use crate::util::rng::Rng;

/// Default seed for the projection prefilter when a request does not
/// pick one.
pub const DEFAULT_KNN_SEED: u64 = 0x5EED_CA2D;

/// Configuration for [`knn_candidates`].
#[derive(Debug, Clone)]
pub struct KnnConfig {
    /// Neighbors kept per vertex (clamped to n−1).
    pub k: usize,
    /// Seed for the random-projection prefilter. Changing it changes
    /// which candidates survive the prefilter on large inputs; on the
    /// exact path it has no effect.
    pub seed: u64,
    /// Projection dimensionality of the prefilter.
    pub projection_dims: usize,
    /// Inputs with more series than this use the prefilter; smaller
    /// inputs are scored exactly.
    pub prefilter_above: usize,
    /// Shortlist size multiplier: the prefilter keeps `pool_factor · k`
    /// candidates per vertex for exact re-scoring, and each refinement
    /// round examines at most `pool_factor · k` fresh candidates per
    /// vertex.
    pub pool_factor: usize,
    /// NN-descent refinement rounds over the prefilter shortlist
    /// (0 = one-shot prefilter only; no effect on the exact path).
    pub ann_iters: usize,
}

impl KnnConfig {
    pub fn new(k: usize, seed: u64) -> KnnConfig {
        KnnConfig {
            k,
            seed,
            projection_dims: 16,
            prefilter_above: 8192,
            pool_factor: 4,
            ann_iters: 2,
        }
    }
}

/// Build the symmetrized k-NN candidate similarity graph for a panel
/// (one series per row, ≥ 4 rows). See the module docs for the two
/// regimes and the determinism contract.
pub fn knn_candidates(panel: &Matrix, cfg: &KnnConfig) -> Result<SparseSimilarity, TmfgError> {
    let (n, l) = (panel.rows, panel.cols);
    if n < 4 {
        return Err(TmfgError::invalid(format!(
            "sparse k-NN needs at least 4 series, got {n}"
        )));
    }
    if l < 2 {
        return Err(TmfgError::invalid(format!(
            "sparse k-NN needs at least 2 samples per series, got {l}"
        )));
    }
    if cfg.k == 0 {
        return Err(TmfgError::invalid("sparse k must be >= 1"));
    }
    let k = cfg.k.min(n - 1);
    let z = {
        let _span = crate::span!("knn_phase", "standardize n={n} l={l}");
        standardize_rows_generic::<f32>(panel)
    };
    let picks: Vec<Vec<(u32, f32)>> = if n <= cfg.prefilter_above {
        let _span = crate::span!("knn_phase", "exact picks n={n} k={k}");
        exact_picks(&z, n, l, k)
    } else {
        let mut picks = {
            let _span = crate::span!("knn_phase", "prefiltered picks n={n} k={k}");
            prefiltered_picks(&z, n, l, k, cfg)
        };
        for round in 0..cfg.ann_iters {
            let _span = crate::span!("knn_phase", "nn-descent round={round} n={n} k={k}");
            picks = refine_picks(&z, n, l, k, cfg, &picks);
        }
        picks
    };
    let _span = crate::span!("knn_phase", "assemble csr n={n}");
    SparseSimilarity::from_directed_picks(n, &picks)
}

/// Exact regime: score every pair with the shared f32 dot kernel.
///
/// Each pair is scored twice (once per direction): per-vertex
/// independence is what makes thread-count determinism free, and the
/// values agree bit-for-bit (commutative products, same fold order), so
/// symmetrization needs no value reconciliation. Halving the work with
/// upper-triangle block scoring + a deterministic per-vertex merge is
/// the known follow-up if this kernel shows up in `bench_sparse`.
fn exact_picks(z: &[f32], n: usize, l: usize, k: usize) -> Vec<Vec<(u32, f32)>> {
    parlay::par_map_scratch(n, 2, |v, scratch: &mut Vec<(f32, u32)>| {
        let zv = &z[v * l..(v + 1) * l];
        scratch.clear();
        for u in 0..n {
            if u != v {
                let sim = f32::dot(zv, &z[u * l..(u + 1) * l]).clamp(-1.0, 1.0);
                scratch.push((sim, u as u32));
            }
        }
        top_k(scratch, k);
        scratch.iter().map(|&(w, u)| (u, w)).collect()
    })
}

/// Prefilter regime: shortlist by seeded random projection, re-score the
/// shortlist exactly.
fn prefiltered_picks(
    z: &[f32],
    n: usize,
    l: usize,
    k: usize,
    cfg: &KnnConfig,
) -> Vec<Vec<(u32, f32)>> {
    let d = cfg.projection_dims.clamp(4, l.max(4));
    let pool = (cfg.pool_factor.max(1) * k).clamp(k, n - 1);
    // The projection matrix is drawn sequentially from the seed before
    // any parallel work — the one place randomness enters, and it is
    // identical for every thread count.
    let mut rng = Rng::new(cfg.seed ^ 0x5A11_E27);
    let proj: Vec<f32> = (0..l * d).map(|_| rng.next_gaussian() as f32).collect();
    // p[v] = z[v] · P, parallel over vertices.
    let p: Vec<f32> = {
        let mut p: Vec<f32> = Vec::with_capacity(n * d);
        let pp = parlay::SendPtr(p.as_mut_ptr());
        let (zr, pr) = (&z, &proj);
        parlay::parallel_for(n, 8, |v| {
            let zv = &zr[v * l..(v + 1) * l];
            for c in 0..d {
                let mut acc = 0.0f32;
                for t in 0..l {
                    acc += zv[t] * pr[t * d + c];
                }
                // SAFETY: slot (v, c) written only by iteration v.
                unsafe { pp.write(v * d + c, acc) };
            }
        });
        unsafe { p.set_len(n * d) };
        p
    };
    let pref = &p;
    parlay::par_map_scratch(n, 1, |v, scratch: &mut Vec<(f32, u32)>| {
        let pv = &pref[v * d..(v + 1) * d];
        scratch.clear();
        for u in 0..n {
            if u != v {
                let score = f32::dot(pv, &pref[u * d..(u + 1) * d]);
                scratch.push((score, u as u32));
            }
        }
        top_k(scratch, pool);
        // exact re-scoring of the shortlist
        let zv = &z[v * l..(v + 1) * l];
        let mut exact: Vec<(f32, u32)> = scratch
            .iter()
            .map(|&(_, u)| {
                let sim =
                    f32::dot(zv, &z[u as usize * l..(u as usize + 1) * l]).clamp(-1.0, 1.0);
                (sim, u)
            })
            .collect();
        top_k(&mut exact, k);
        exact.into_iter().map(|(w, u)| (u, w)).collect()
    })
}

/// One NN-descent round: for every vertex, exactly re-score a bounded,
/// deterministically ordered set of fresh candidates — its
/// neighbors-of-neighbors, then its reverse neighbors from the previous
/// round — and keep the best k of (current ∪ fresh).
///
/// Current picks keep their already-exact scores (no re-scoring), fresh
/// candidates are capped at `pool_factor · k` per vertex, so one round
/// is O(n·pool·L) work. The reverse adjacency is a sequential CSR
/// transpose of the previous picks and each vertex's output is a pure
/// function of (`z`, previous picks), so the round is byte-identical
/// across thread counts.
fn refine_picks(
    z: &[f32],
    n: usize,
    l: usize,
    k: usize,
    cfg: &KnnConfig,
    picks: &[Vec<(u32, f32)>],
) -> Vec<Vec<(u32, f32)>> {
    let fresh_cap = (cfg.pool_factor.max(1) * k).clamp(k, n - 1);
    // Reverse adjacency (who picked v?) as a CSR transpose, built
    // sequentially in pick order — deterministic by construction.
    let mut rev_ptr = vec![0u32; n + 1];
    for row in picks {
        for &(u, _) in row {
            rev_ptr[u as usize + 1] += 1;
        }
    }
    for i in 0..n {
        rev_ptr[i + 1] += rev_ptr[i];
    }
    let mut rev = vec![0u32; rev_ptr[n] as usize];
    let mut cursor: Vec<u32> = rev_ptr[..n].to_vec();
    for (v, row) in picks.iter().enumerate() {
        for &(u, _) in row {
            rev[cursor[u as usize] as usize] = v as u32;
            cursor[u as usize] += 1;
        }
    }
    // Per-vertex scratch: the candidate list plus a stamp array marking
    // vertices already considered for the current v (stamps are vertex
    // ids, unique per v, so the array never needs clearing).
    type Scratch = (Vec<(f32, u32)>, Vec<u32>);
    parlay::par_map_scratch(n, 1, |v, scratch: &mut Scratch| {
        let (cand, mark) = scratch;
        if mark.len() < n {
            mark.resize(n, u32::MAX);
        }
        let stamp = v as u32;
        let zv = &z[v * l..(v + 1) * l];
        cand.clear();
        mark[v] = stamp;
        for &(u, w) in &picks[v] {
            mark[u as usize] = stamp;
            cand.push((w, u));
        }
        let mut budget = fresh_cap;
        let mut consider = |u: u32, cand: &mut Vec<(f32, u32)>, budget: &mut usize| {
            if *budget == 0 || mark[u as usize] == stamp {
                return;
            }
            mark[u as usize] = stamp;
            let sim =
                f32::dot(zv, &z[u as usize * l..(u as usize + 1) * l]).clamp(-1.0, 1.0);
            cand.push((sim, u));
            *budget -= 1;
        };
        'outer: for &(u, _) in &picks[v] {
            for &(w, _) in &picks[u as usize] {
                if budget == 0 {
                    break 'outer;
                }
                consider(w, cand, &mut budget);
            }
        }
        for &r in &rev[rev_ptr[v] as usize..rev_ptr[v + 1] as usize] {
            if budget == 0 {
                break;
            }
            consider(r, cand, &mut budget);
        }
        top_k(cand, k);
        cand.iter().map(|&(w, u)| (u, w)).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corr::pearson_correlation;
    use crate::data::synth::SynthSpec;

    fn panel(n: usize, seed: u64) -> Matrix {
        SynthSpec::new("t", n, 48, 4).generate(seed).data
    }

    #[test]
    fn exact_matches_dense_topk() {
        let x = panel(40, 1);
        let sp = knn_candidates(&x, &KnnConfig::new(5, 7)).unwrap();
        let dense = pearson_correlation(&x);
        let from_dense = SparseSimilarity::from_dense(&dense, 5).unwrap();
        // both pick the top 5 partners per vertex from the same
        // standardized dot products, so the structures must agree
        for v in 0..40 {
            let (a, _) = sp.row(v);
            let (b, _) = from_dense.row(v);
            assert_eq!(a, b, "row {v}");
            for &u in a {
                let got = sp.lookup(v, u as usize).unwrap();
                let want = dense.at(v, u as usize);
                assert!((got - want).abs() < 1e-5, "({v},{u}): {got} vs {want}");
            }
        }
    }

    #[test]
    fn complete_k_keeps_all_pairs() {
        let x = panel(12, 2);
        let sp = knn_candidates(&x, &KnnConfig::new(11, 1)).unwrap();
        assert_eq!(sp.nnz(), 12 * 11);
    }

    #[test]
    fn deterministic_across_thread_counts_and_reruns() {
        let x = panel(60, 3);
        let mut cfg = KnnConfig::new(8, 5);
        // force the prefilter path so its determinism is covered too
        cfg.prefilter_above = 16;
        let base = crate::parlay::with_threads(1, || knn_candidates(&x, &cfg).unwrap());
        for t in [2usize, 4] {
            let got = crate::parlay::with_threads(t, || knn_candidates(&x, &cfg).unwrap());
            assert_eq!(got, base, "threads={t}");
        }
        assert_eq!(knn_candidates(&x, &cfg).unwrap(), base, "rerun");
    }

    #[test]
    fn prefilter_recall_reasonable() {
        // The shortlist is approximate, but on class-structured panels
        // most true top-k partners must survive it.
        let x = panel(300, 4);
        let exact = knn_candidates(&x, &KnnConfig::new(8, 9)).unwrap();
        let mut cfg = KnnConfig::new(8, 9);
        cfg.prefilter_above = 64;
        let approx = knn_candidates(&x, &cfg).unwrap();
        let mut hit = 0usize;
        let mut total = 0usize;
        for v in 0..300 {
            let (a, _) = exact.row(v);
            for &u in a {
                total += 1;
                if approx.lookup(v, u as usize).is_some() {
                    hit += 1;
                }
            }
        }
        let recall = hit as f64 / total as f64;
        assert!(recall > 0.5, "prefilter recall too low: {recall}");
    }

    #[test]
    fn iters_zero_reproduces_the_one_shot_prefilter() {
        // `ann_iters: 0` must be exactly the one-shot projection
        // shortlist — refinement is strictly additive machinery.
        let x = panel(200, 8);
        let mut cfg = KnnConfig::new(6, 11);
        cfg.prefilter_above = 32;
        cfg.ann_iters = 0;
        let via_api = knn_candidates(&x, &cfg).unwrap();
        let z = standardize_rows_generic::<f32>(&x);
        let picks = prefiltered_picks(&z, 200, 48, 6, &cfg);
        let manual = SparseSimilarity::from_directed_picks(200, &picks).unwrap();
        assert_eq!(via_api, manual);
    }

    #[test]
    fn nn_descent_refinement_recovers_starved_prefilter_recall() {
        // A deliberately starved prefilter (4 projection dims, minimal
        // pool) loses recall; NN-descent rounds must claw it back —
        // and must never make the candidate graph meaningfully worse,
        // since each round keeps the best-k of (current ∪ fresh) by
        // exact similarity.
        let x = panel(300, 4);
        let exact = knn_candidates(&x, &KnnConfig::new(8, 9)).unwrap();
        let recall = |approx: &SparseSimilarity| {
            let mut hit = 0usize;
            let mut total = 0usize;
            for v in 0..300 {
                let (a, _) = exact.row(v);
                for &u in a {
                    total += 1;
                    if approx.lookup(v, u as usize).is_some() {
                        hit += 1;
                    }
                }
            }
            hit as f64 / total as f64
        };
        let mut cfg = KnnConfig::new(8, 9);
        cfg.prefilter_above = 64;
        cfg.projection_dims = 4;
        cfg.pool_factor = 2;
        cfg.ann_iters = 0;
        let r0 = recall(&knn_candidates(&x, &cfg).unwrap());
        cfg.ann_iters = 2;
        let r2 = recall(&knn_candidates(&x, &cfg).unwrap());
        assert!(
            r2 + 0.02 >= r0,
            "refinement must not lose recall: {r0:.3} -> {r2:.3}"
        );
        assert!(r2 >= 0.5, "refined recall too low: {r2:.3} (one-shot {r0:.3})");
    }

    #[test]
    fn seed_changes_prefilter_not_exact() {
        let x = panel(50, 6);
        let a = knn_candidates(&x, &KnnConfig::new(6, 1)).unwrap();
        let b = knn_candidates(&x, &KnnConfig::new(6, 2)).unwrap();
        assert_eq!(a, b, "exact path ignores the seed");
    }

    #[test]
    fn rejects_degenerate_inputs() {
        assert!(knn_candidates(&Matrix::zeros(3, 8), &KnnConfig::new(2, 1)).is_err());
        assert!(knn_candidates(&Matrix::zeros(8, 1), &KnnConfig::new(2, 1)).is_err());
        assert!(knn_candidates(&Matrix::zeros(8, 8), &KnnConfig::new(0, 1)).is_err());
    }
}
