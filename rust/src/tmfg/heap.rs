//! HEAP-TMFG (Algorithm 2): lazy heap-based TMFG construction.
//!
//! Face-vertex pairs live in a max-heap ordered by gain. A pair is only
//! re-validated when it is popped: if its face has died it is discarded
//! (the face's replacement pairs were pushed when the face was split); if
//! its vertex has been inserted the pair is recomputed from the current
//! `MaxCorrs` candidates and re-pushed. Otherwise it is the winner and is
//! inserted. This removes both the per-round argmax over all faces and
//! most candidate recomputations of CORR-TMFG.
//!
//! As the paper notes, the lazy strategy is exact unless an update would
//! *increase* a face's gain (impossible when updates always pick the best
//! remaining candidate, rare in practice) — we quantify the edge-sum gap
//! in tests and in the Fig. 7 experiment.

use super::common::{initial_clique, validate_similarity, Builder, Faces, TmfgConfig, TmfgResult};
use super::corrbased::CorrState;
use crate::error::TmfgError;
use crate::data::matrix::Matrix;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Heap entry: a candidate face-vertex pair. Ordered by gain (then by
/// face/vertex id for determinism).
#[derive(Debug, Clone, Copy, PartialEq)]
struct Pair {
    gain: f32,
    face: u32,
    vertex: u32,
}

impl Eq for Pair {}

impl Ord for Pair {
    fn cmp(&self, other: &Self) -> Ordering {
        self.gain
            .total_cmp(&other.gain)
            .then_with(|| other.face.cmp(&self.face))
            .then_with(|| other.vertex.cmp(&self.vertex))
    }
}

impl PartialOrd for Pair {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Run HEAP-TMFG. Inserts exactly one vertex per round (the algorithm
/// does not support prefix > 1); `cfg.prefix` is ignored.
pub fn heap_tmfg(s: &Matrix, cfg: &TmfgConfig) -> Result<TmfgResult, TmfgError> {
    let n = validate_similarity(s)?;
    let mut timer = crate::util::timer::Timer::start();
    let mut timings = super::common::TmfgTimings::default();
    let seed = initial_clique(s);
    timings.init = timer.lap();
    let mut builder = Builder::new(seed, n);
    let mut faces = Faces::new(&seed);
    let mut state = CorrState::build(s, cfg.sort, cfg.scan);
    timings.sort = timer.lap();
    for &v in &seed {
        state.mark_inserted(v);
    }

    // Initialize the heap with the best pair of each seed face
    // (Alg. 2 lines 8–12).
    let mut heap: BinaryHeap<Pair> = BinaryHeap::with_capacity(8 * n);
    if n > 4 {
        for fid in 0..4u32 {
            let fv = faces.verts[fid as usize];
            let (g, v) = state
                .best_pair(s, &fv)
                .ok_or_else(|| TmfgError::invariant("n > 4 seed face has no candidate"))?;
            heap.push(Pair { gain: g, face: fid, vertex: v });
        }
    }

    let mut round: u64 = 0;
    while state.n_rem > 0 {
        let _round_span = crate::span!("tmfg_round", "heap round {round} rem={}", state.n_rem);
        round += 1;
        let Some(top) = heap.pop() else {
            return Err(TmfgError::invariant(
                "heap exhausted while vertices remain uninserted",
            ));
        };
        if !faces.alive[top.face as usize] {
            // Face died since this pair was pushed — its successors carry
            // the candidates now.
            continue;
        }
        if state.inserted[top.vertex as usize] != 0 {
            // Stale vertex: recompute this face's best pair and re-insert
            // (Alg. 2 lines 26–31).
            let fv = faces.verts[top.face as usize];
            let (g, v) = state.best_pair(s, &fv).ok_or_else(|| {
                TmfgError::invariant("no candidate pair while vertices remain")
            })?;
            heap.push(Pair { gain: g, face: top.face, vertex: v });
            continue;
        }
        // Winner: insert vertex into face (lines 17–25).
        let fv = faces.verts[top.face as usize];
        let owner = builder.insert(top.vertex, fv, faces.owner[top.face as usize]);
        let new_faces = faces.split(top.face, top.vertex, owner);
        state.mark_inserted(top.vertex);
        if state.n_rem == 0 {
            break;
        }
        for nf in new_faces {
            let nfv = faces.verts[nf as usize];
            let (g, v) = state.best_pair(s, &nfv).ok_or_else(|| {
                TmfgError::invariant("no candidate pair while vertices remain")
            })?;
            heap.push(Pair { gain: g, face: nf, vertex: v });
        }
    }

    timings.insert = timer.lap();
    let mut r = builder.finish(n, faces.alive_faces());
    r.timings = timings;
    debug_assert!(super::common::check_invariants(&r).is_ok());
    Ok(r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::tmfg::common::check_invariants;
    use crate::tmfg::corrbased::corr_tmfg;

    fn random_corr(n: usize, seed: u64) -> Matrix {
        let ds = SynthSpec::new("t", n, 48, 3).generate(seed);
        crate::data::corr::pearson_correlation(&ds.data)
    }

    #[test]
    fn pair_ordering() {
        let a = Pair { gain: 1.0, face: 0, vertex: 0 };
        let b = Pair { gain: 2.0, face: 1, vertex: 1 };
        assert!(b > a);
        // deterministic tie-break: lower face id wins
        let c = Pair { gain: 1.0, face: 5, vertex: 0 };
        assert!(a > c);
    }

    #[test]
    fn builds_valid_tmfg() {
        for n in [4usize, 5, 6, 10, 50, 200] {
            let s = random_corr(n, 100 + n as u64);
            let r = heap_tmfg(&s, &TmfgConfig::default()).unwrap();
            check_invariants(&r).unwrap_or_else(|e| panic!("n={n}: {e}"));
        }
    }

    #[test]
    fn deterministic() {
        let s = random_corr(70, 11);
        let a = heap_tmfg(&s, &TmfgConfig::default()).unwrap();
        let b = heap_tmfg(&s, &TmfgConfig::default()).unwrap();
        assert_eq!(a.edges, b.edges);
    }

    #[test]
    fn edge_sum_close_to_corr_tmfg() {
        // Paper §4.2: heap-based result quality is "only slightly
        // different" from CORR-TMFG; Fig. 7 shows <1% differences.
        for seed in [1u64, 2, 3] {
            let s = random_corr(120, seed);
            let ec = corr_tmfg(&s, &TmfgConfig::default()).unwrap().edge_sum(&s);
            let eh = heap_tmfg(&s, &TmfgConfig::default()).unwrap().edge_sum(&s);
            let rel = (ec - eh).abs() / ec.abs().max(1e-9);
            assert!(rel < 0.02, "seed {seed}: corr {ec} vs heap {eh} (rel {rel})");
        }
    }

    #[test]
    fn tiny_n() {
        let s = random_corr(4, 1);
        let r = heap_tmfg(&s, &TmfgConfig::default()).unwrap();
        assert_eq!(r.edges.len(), 6);
        assert_eq!(r.cliques.len(), 1);
    }

    #[test]
    fn too_small_or_non_square_is_err_not_panic() {
        let s = random_corr(4, 2);
        let mut rect = s.clone();
        rect.rows = 2;
        rect.data.truncate(8);
        assert!(heap_tmfg(&rect, &TmfgConfig::default()).is_err());
        let tiny = random_corr(4, 3);
        let mut tiny3 = Matrix::zeros(3, 3);
        for i in 0..3 {
            for j in 0..3 {
                tiny3.set(i, j, tiny.at(i, j));
            }
        }
        assert!(heap_tmfg(&tiny3, &TmfgConfig::default()).is_err());
    }
}
