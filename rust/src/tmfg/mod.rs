//! Triangular Maximally Filtered Graph construction.
//!
//! Three algorithms, as in the paper:
//! * [`orig::orig_tmfg`] — PAR-TMFG of Yu & Shun (ICDE'23) with prefix
//!   size P: per-face sorted gain arrays created (and sorted) at face
//!   creation time; each round sorts the face-best pairs and inserts the
//!   top P non-conflicting face-vertex pairs. This is the baseline whose
//!   per-insertion sorting the paper eliminates.
//! * [`corrbased::corr_tmfg`] — CORR-TMFG (Alg. 1): one up-front parallel
//!   sort of every similarity row; per-face candidates come from
//!   per-vertex `MaxCorrs` pointers into the pre-sorted rows.
//! * [`heap::heap_tmfg`] — HEAP-TMFG (Alg. 2): lazy max-heap over
//!   face-vertex pairs; pairs are recomputed only when they surface at the
//!   root with a stale (already-inserted) vertex.
//!
//! All three produce a [`common::TmfgResult`] carrying the edges, the
//! 4-clique list with parent links (the bubble tree DBHT consumes), and
//! the final triangular faces.

pub mod common;
pub mod corrbased;
pub mod heap;
pub mod orig;
pub mod scan;

pub use common::{ScanKind, SortKind, TmfgConfig, TmfgResult};
pub use corrbased::corr_tmfg;
pub use heap::heap_tmfg;
pub use orig::orig_tmfg;
