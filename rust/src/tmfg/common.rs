//! Shared TMFG machinery: gains, the initial 4-clique, face bookkeeping
//! with bubble-tree tracking, and the result type.

use crate::error::TmfgError;
use crate::data::matrix::Matrix;
use crate::parlay;

/// How the `MaxCorrs` forward scan over a pre-sorted row is executed
/// (§4.3 "manual vectorization for AVX2 and AVX512" — see `scan.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ScanKind {
    #[default]
    Scalar,
    /// 8-wide unrolled scan over a u8 inserted-flag array (the portable
    /// analog of the paper's AVX2 gather+movemask scan).
    Chunked,
    /// 16-wide branch-light scan with the bounds checks hoisted out of
    /// the flag gather — the widest portable analog of the paper's
    /// AVX512 gather+movemask scan, and what `TmfgAlgo::Opt` uses.
    Wide,
}

/// How the initial per-row correlation sort is executed
/// (§4.3 "vectorized sorting algorithm from Google Highway").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SortKind {
    /// std pdqsort per row (rows sorted in parallel).
    #[default]
    Comparison,
    /// LSD radix sort on order-preserving f32 key bits per row — our
    /// vqsort stand-in.
    Radix,
}

/// Construction parameters shared by the TMFG variants.
#[derive(Debug, Clone)]
pub struct TmfgConfig {
    /// Vertices inserted per round (the paper's prefix size). CORR-TMFG
    /// defaults to 1 (its best configuration); ORIG-TMFG uses 1/10/200 in
    /// the paper's experiments. HEAP-TMFG always inserts one at a time.
    pub prefix: usize,
    pub scan: ScanKind,
    pub sort: SortKind,
}

impl Default for TmfgConfig {
    fn default() -> Self {
        TmfgConfig { prefix: 1, scan: ScanKind::Scalar, sort: SortKind::Comparison }
    }
}

/// Wall-clock seconds per construction phase — the Fig. 5 decomposition
/// ("finding initial faces" / "initial sorting of correlations" (or the
/// baseline's interleaved per-face sorts) / "adding vertices").
#[derive(Debug, Clone, Default)]
pub struct TmfgTimings {
    pub init: f64,
    pub sort: f64,
    pub insert: f64,
}

/// Output of TMFG construction. Besides the filtered graph itself it
/// carries the 4-clique insertion structure ("bubbles") that DBHT consumes.
#[derive(Debug, Clone)]
pub struct TmfgResult {
    pub n: usize,
    /// Undirected edges; exactly `3n − 6` for n ≥ 4.
    pub edges: Vec<(u32, u32)>,
    /// Triangular faces alive at the end; exactly `2n − 4`.
    pub faces: Vec<[u32; 3]>,
    /// Bubbles: cliques[0] is the seed 4-clique `[v1,v2,v3,v4]`; every
    /// later entry is `[x, y, z, v]` where vertex `v` was inserted into
    /// face `{x,y,z}`.
    pub cliques: Vec<[u32; 4]>,
    /// Bubble-tree parent: `parent[0] = -1`; `parent[b]` is the bubble
    /// that owned the face `cliques[b][0..3]` when `cliques[b][3]` was
    /// inserted.
    pub parent: Vec<i32>,
    /// Vertex insertion order (the 4 seed vertices first).
    pub order: Vec<u32>,
    /// Per-phase construction timings.
    pub timings: TmfgTimings,
}

impl TmfgResult {
    /// Sum of similarity over all edges (the Fig. 7 quality metric).
    /// Generic over the similarity store (dense or sparse).
    pub fn edge_sum<S: crate::data::matrix::SimilarityLookup + ?Sized>(&self, s: &S) -> f64 {
        crate::metrics::edge_sum(s, &self.edges)
    }

    /// Adjacency lists (sorted) of the filtered graph.
    pub fn adjacency(&self) -> Vec<Vec<u32>> {
        let mut adj = vec![Vec::new(); self.n];
        for &(u, v) in &self.edges {
            adj[u as usize].push(v);
            adj[v as usize].push(u);
        }
        for a in adj.iter_mut() {
            a.sort_unstable();
            a.dedup();
        }
        adj
    }
}

/// Gain of pairing vertex `v` with face `f`: Σ_{u ∈ f} S[v, u].
#[inline]
pub fn gain(s: &Matrix, f: &[u32; 3], v: u32) -> f32 {
    let r = v as usize;
    s.at(r, f[0] as usize) + s.at(r, f[1] as usize) + s.at(r, f[2] as usize)
}

/// Gains of up to three candidate vertices against the same face in one
/// branch-light pass: `out[k] = gain(s, f, cands[k])`. The face columns
/// are hoisted and each candidate's three loads are issued back-to-back
/// with the same left-to-right add order as [`gain`], so the results are
/// bit-identical to three separate `gain` calls — the fold `best_pair`
/// runs after gathering its `MaxCorrs` candidates.
#[inline]
pub fn gain3(s: &Matrix, f: &[u32; 3], cands: &[u32]) -> [f32; 3] {
    debug_assert!(cands.len() <= 3);
    let (c0, c1, c2) = (f[0] as usize, f[1] as usize, f[2] as usize);
    let mut out = [f32::NEG_INFINITY; 3];
    for (o, &v) in out.iter_mut().zip(cands.iter()) {
        let r = v as usize * s.cols;
        *o = s.data[r + c0] + s.data[r + c1] + s.data[r + c2];
    }
    out
}

/// Validate a similarity matrix for TMFG construction: square with
/// n ≥ 4. Returns n. All construction entry points call this before any
/// work, so the deeper machinery can assume a usable shape.
pub fn validate_similarity(s: &Matrix) -> Result<usize, TmfgError> {
    if s.rows != s.cols {
        return Err(TmfgError::invalid(format!(
            "similarity matrix must be square, got {}x{}",
            s.rows, s.cols
        )));
    }
    if s.rows < 4 {
        return Err(TmfgError::invalid(format!(
            "TMFG needs at least 4 vertices, got {}",
            s.rows
        )));
    }
    Ok(s.rows)
}

/// The four seed vertices: largest total similarity row sums (Alg. 1/2,
/// line 1). Row sums are computed in parallel. Callers have validated
/// n ≥ 4 via [`validate_similarity`].
pub fn initial_clique(s: &Matrix) -> [u32; 4] {
    let n = s.rows;
    debug_assert!(n >= 4, "TMFG needs at least 4 vertices");
    let sums = parlay::par_map(n, 8, |i| {
        let mut acc = 0.0f64;
        for &v in s.row(i) {
            acc += v as f64;
        }
        acc
    });
    // top-4 by sum (ties → lower index), selection in one pass
    let mut best: Vec<(f64, u32)> = Vec::with_capacity(5);
    for (i, &v) in sums.iter().enumerate() {
        best.push((v, i as u32));
        best.sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
        best.truncate(4);
    }
    [best[0].1, best[1].1, best[2].1, best[3].1]
}

/// Face table with bubble ownership and a compacting alive-list.
pub struct Faces {
    pub verts: Vec<[u32; 3]>,
    pub owner: Vec<u32>,
    pub alive: Vec<bool>,
    alive_list: Vec<u32>,
    dead_in_list: usize,
}

impl Faces {
    /// Initialize with the 4 faces of the seed clique, all owned by bubble 0.
    pub fn new(c: &[u32; 4]) -> Faces {
        let verts = vec![
            [c[0], c[1], c[2]],
            [c[0], c[1], c[3]],
            [c[0], c[2], c[3]],
            [c[1], c[2], c[3]],
        ];
        Faces {
            owner: vec![0; 4],
            alive: vec![true; 4],
            alive_list: vec![0, 1, 2, 3],
            verts,
            dead_in_list: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.verts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.verts.is_empty()
    }

    pub fn n_alive(&self) -> usize {
        self.alive_list.len() - self.dead_in_list
    }

    /// Kill face `f` and create the three faces of the new bubble `owner`
    /// formed by inserting `v` into `f`. Returns the three new face ids.
    pub fn split(&mut self, f: u32, v: u32, owner: u32) -> [u32; 3] {
        debug_assert!(self.alive[f as usize], "splitting a dead face");
        let [x, y, z] = self.verts[f as usize];
        self.alive[f as usize] = false;
        self.dead_in_list += 1;
        let base = self.verts.len() as u32;
        for tri in [[v, x, y], [v, y, z], [v, x, z]] {
            self.verts.push(tri);
            self.owner.push(owner);
            self.alive.push(true);
            self.alive_list.push(self.verts.len() as u32 - 1);
        }
        [base, base + 1, base + 2]
    }

    /// Snapshot of the alive face ids. The internal list is compacted
    /// lazily when more than half of it is dead; the returned snapshot is
    /// fully filtered.
    pub fn alive_ids(&mut self) -> Vec<u32> {
        if self.dead_in_list * 2 > self.alive_list.len() {
            self.alive_list.retain(|&f| self.alive[f as usize]);
            self.dead_in_list = 0;
        }
        self.alive_list
            .iter()
            .copied()
            .filter(|&f| self.alive[f as usize])
            .collect()
    }

    /// Final triangular faces.
    pub fn alive_faces(&self) -> Vec<[u32; 3]> {
        (0..self.verts.len())
            .filter(|&i| self.alive[i])
            .map(|i| self.verts[i])
            .collect()
    }
}

/// Incremental result builder shared by all construction algorithms.
pub struct Builder {
    pub edges: Vec<(u32, u32)>,
    pub cliques: Vec<[u32; 4]>,
    pub parent: Vec<i32>,
    pub order: Vec<u32>,
}

impl Builder {
    pub fn new(seed: [u32; 4], n: usize) -> Builder {
        let mut edges = Vec::with_capacity(3 * n);
        for i in 0..4 {
            for j in (i + 1)..4 {
                edges.push((seed[i], seed[j]));
            }
        }
        Builder {
            edges,
            cliques: vec![seed],
            parent: vec![-1],
            order: seed.to_vec(),
        }
    }

    /// Record insertion of `v` into face `f` (id `fid`, owner `owner`);
    /// returns the new bubble id.
    pub fn insert(&mut self, v: u32, fverts: [u32; 3], owner: u32) -> u32 {
        let [x, y, z] = fverts;
        self.edges.push((v, x));
        self.edges.push((v, y));
        self.edges.push((v, z));
        self.cliques.push([x, y, z, v]);
        self.parent.push(owner as i32);
        self.order.push(v);
        (self.cliques.len() - 1) as u32
    }

    pub fn finish(self, n: usize, faces: Vec<[u32; 3]>) -> TmfgResult {
        TmfgResult {
            n,
            edges: self.edges,
            faces,
            cliques: self.cliques,
            parent: self.parent,
            order: self.order,
            timings: TmfgTimings::default(),
        }
    }
}

/// Structural invariant checks used by tests and (on request) by the
/// pipeline: maximal-planar edge/face counts, single insertion, parent
/// validity, and that every clique is a genuine 4-clique of the edge set.
/// Violations surface as [`TmfgError::InvariantViolation`], never a panic.
pub fn check_invariants(r: &TmfgResult) -> Result<(), TmfgError> {
    let n = r.n;
    if n < 4 {
        return Err(TmfgError::invariant("n < 4"));
    }
    if r.edges.len() != 3 * n - 6 {
        return Err(TmfgError::invariant(format!(
            "edge count {} != 3n-6 = {}",
            r.edges.len(),
            3 * n - 6
        )));
    }
    if r.faces.len() != 2 * n - 4 {
        return Err(TmfgError::invariant(format!(
            "face count {} != 2n-4 = {}",
            r.faces.len(),
            2 * n - 4
        )));
    }
    if r.cliques.len() != n - 3 {
        return Err(TmfgError::invariant(format!(
            "clique count {} != n-3 = {}",
            r.cliques.len(),
            n - 3
        )));
    }
    if r.order.len() != n {
        return Err(TmfgError::invariant("order must contain every vertex"));
    }
    let mut seen = vec![false; n];
    for &v in &r.order {
        if seen[v as usize] {
            return Err(TmfgError::invariant(format!("vertex {v} inserted twice")));
        }
        seen[v as usize] = true;
    }
    if !seen.iter().all(|&b| b) {
        return Err(TmfgError::invariant("some vertex never inserted"));
    }
    // no duplicate / self edges
    let mut es: Vec<(u32, u32)> = r
        .edges
        .iter()
        .map(|&(u, v)| (u.min(v), u.max(v)))
        .collect();
    es.sort_unstable();
    for w in es.windows(2) {
        if w[0] == w[1] {
            return Err(TmfgError::invariant(format!("duplicate edge {:?}", w[0])));
        }
    }
    if es.iter().any(|&(u, v)| u == v) {
        return Err(TmfgError::invariant("self edge"));
    }
    let has_edge = |a: u32, b: u32| es.binary_search(&(a.min(b), a.max(b))).is_ok();
    // cliques are 4-cliques; parent links valid
    for (b, c) in r.cliques.iter().enumerate() {
        for i in 0..4 {
            for j in (i + 1)..4 {
                if !has_edge(c[i], c[j]) {
                    return Err(TmfgError::invariant(format!(
                        "clique {b} not a 4-clique: missing ({},{})",
                        c[i], c[j]
                    )));
                }
            }
        }
        let p = r.parent[b];
        if b == 0 {
            if p != -1 {
                return Err(TmfgError::invariant("root parent must be -1"));
            }
        } else {
            if p < 0 || p as usize >= b {
                return Err(TmfgError::invariant(format!(
                    "parent[{b}] = {p} invalid (must precede child)"
                )));
            }
            // shared face: first three vertices of clique b must all belong
            // to the parent clique
            let pc = r.cliques[p as usize];
            for k in 0..3 {
                if !pc.contains(&c[k]) {
                    return Err(TmfgError::invariant(format!(
                        "clique {b} face vertex {} not in parent",
                        c[k]
                    )));
                }
            }
        }
    }
    // faces are triangles of the edge set
    for f in &r.faces {
        if !(has_edge(f[0], f[1]) && has_edge(f[1], f[2]) && has_edge(f[0], f[2])) {
            return Err(TmfgError::invariant(format!("face {f:?} is not a triangle of E")));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_s() -> Matrix {
        // 6 vertices; vertex 0 is strongly connected to everything.
        let n = 6;
        let mut s = Matrix::zeros(n, n);
        let w = [
            [1.0, 0.9, 0.8, 0.7, 0.2, 0.1],
            [0.9, 1.0, 0.6, 0.5, 0.3, 0.2],
            [0.8, 0.6, 1.0, 0.4, 0.2, 0.3],
            [0.7, 0.5, 0.4, 1.0, 0.1, 0.2],
            [0.2, 0.3, 0.2, 0.1, 1.0, 0.6],
            [0.1, 0.2, 0.3, 0.2, 0.6, 1.0],
        ];
        for i in 0..n {
            for j in 0..n {
                s.set(i, j, w[i][j]);
            }
        }
        s
    }

    #[test]
    fn initial_clique_picks_top_row_sums() {
        let s = small_s();
        let c = initial_clique(&s);
        // row sums: v0 largest, then v1, v2, v3
        assert_eq!(c, [0, 1, 2, 3]);
    }

    #[test]
    fn gain_is_sum_of_three() {
        let s = small_s();
        let g = gain(&s, &[0, 1, 2], 4);
        assert!((g - (0.2 + 0.3 + 0.2)).abs() < 1e-6);
    }

    #[test]
    fn faces_split_bookkeeping() {
        let mut f = Faces::new(&[0, 1, 2, 3]);
        assert_eq!(f.n_alive(), 4);
        let new = f.split(0, 4, 1);
        assert_eq!(f.n_alive(), 6);
        assert!(!f.alive[0]);
        assert_eq!(f.verts[new[0] as usize], [4, 0, 1]);
        assert_eq!(f.verts[new[1] as usize], [4, 1, 2]);
        assert_eq!(f.verts[new[2] as usize], [4, 0, 2]);
        assert!(new.iter().all(|&i| f.owner[i as usize] == 1));
        // alive ids contain only live faces after compaction trigger
        for _ in 0..4 {
            let id = f.alive_ids()[0];
            f.split(id, 5, 2);
        }
        // 4 initial faces, 5 splits total, each split is net +2 alive.
        assert_eq!(f.n_alive(), 4 + 2 * 5);
    }

    #[test]
    fn builder_structure() {
        let mut b = Builder::new([0, 1, 2, 3], 6);
        assert_eq!(b.edges.len(), 6);
        let id = b.insert(4, [0, 1, 2], 0);
        assert_eq!(id, 1);
        assert_eq!(b.edges.len(), 9);
        assert_eq!(b.cliques[1], [0, 1, 2, 4]);
        assert_eq!(b.parent[1], 0);
    }

    #[test]
    fn invariants_accept_manual_tmfg() {
        // Build a valid TMFG by hand for n=5: seed {0,1,2,3}, insert 4
        // into face {0,1,2}.
        let mut b = Builder::new([0, 1, 2, 3], 5);
        let mut f = Faces::new(&[0, 1, 2, 3]);
        let owner = b.insert(4, f.verts[0], f.owner[0]);
        f.split(0, 4, owner);
        let r = b.finish(5, f.alive_faces());
        check_invariants(&r).unwrap();
    }

    #[test]
    fn invariants_reject_bad() {
        let mut b = Builder::new([0, 1, 2, 3], 5);
        let mut f = Faces::new(&[0, 1, 2, 3]);
        let owner = b.insert(4, f.verts[0], f.owner[0]);
        f.split(0, 4, owner);
        let mut r = b.finish(5, f.alive_faces());
        r.edges.pop();
        assert!(check_invariants(&r).is_err());
    }
}
