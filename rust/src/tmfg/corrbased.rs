//! CORR-TMFG (Algorithm 1): correlation-based TMFG construction.
//!
//! The key idea: replace the per-face-creation gain sorts of the original
//! algorithm with **one** up-front parallel sort of every similarity row.
//! Afterwards, the best candidate vertex for a face is derived from the
//! per-vertex `MaxCorrs` pointers (first uninserted entry of each face
//! vertex's pre-sorted row) — up to three candidates per face, of which
//! the max-gain one is kept. Only faces whose chosen candidate was just
//! inserted (plus the three new faces) are recomputed per round.

use super::common::{
    gain3, initial_clique, validate_similarity, Builder, Faces, ScanKind, SortKind, TmfgConfig,
    TmfgResult,
};
use super::scan::scan;
use crate::error::TmfgError;
use crate::data::matrix::Matrix;
use crate::parlay::{self, SendPtr};

/// Pre-sorted similarity rows + insertion flags + `MaxCorrs` pointers.
/// Shared by CORR-TMFG and HEAP-TMFG.
pub struct CorrState {
    pub n: usize,
    stride: usize,
    /// Flat n × (n−1) matrix: row v lists all u ≠ v by S[v,u] descending.
    sorted: Vec<u32>,
    /// Per-vertex scan pointer into its sorted row.
    ptr: Vec<u32>,
    /// 1 = inserted into the TMFG. u8 (not a bitset) so the chunked scan
    /// can vector-load flags.
    pub inserted: Vec<u8>,
    pub n_rem: usize,
    scan_kind: ScanKind,
}

impl CorrState {
    /// The "initial sorting of correlations" step (Alg. 1 lines 6–7): sort
    /// every row in parallel. `sort` picks comparison sort vs radix sort
    /// (the §4.3 Highway-vqsort analog).
    pub fn build(s: &Matrix, sort: SortKind, scan_kind: ScanKind) -> CorrState {
        let n = s.rows;
        let stride = n - 1;
        let mut sorted: Vec<u32> = Vec::with_capacity(n * stride);
        let sp = SendPtr(sorted.as_mut_ptr());
        // Chunked so sort scratch buffers are reused across rows in a chunk
        // (no per-row allocation — §Perf L3 iter. 5).
        parlay::parallel_for_chunks(n, 1, |lo, hi| {
            let mut pairs: Vec<(f32, u32)> = Vec::with_capacity(stride);
            let mut keyed: Vec<(u32, u32)> = Vec::with_capacity(stride);
            let mut scratch: Vec<(u32, u32)> = Vec::with_capacity(stride);
            for v in lo..hi {
                let row = s.row(v);
                match sort {
                    // Nested inside a parallel loop these run sequentially
                    // per row (rows are the parallel dimension).
                    SortKind::Comparison => {
                        pairs.clear();
                        for (u, &sim) in row.iter().enumerate() {
                            if u != v {
                                pairs.push((sim, u as u32));
                            }
                        }
                        pairs.sort_unstable_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
                        for (k, &(_, u)) in pairs.iter().enumerate() {
                            // SAFETY: row v writes only its own stride segment.
                            unsafe { sp.write(v * stride + k, u) };
                        }
                    }
                    SortKind::Radix => {
                        keyed.clear();
                        for (u, &sim) in row.iter().enumerate() {
                            if u != v {
                                keyed.push((crate::parlay::radix_key_desc(sim), u as u32));
                            }
                        }
                        crate::parlay::radix::radix_sort_keyed_scratch(&mut keyed, &mut scratch);
                        for (k, &(_, u)) in keyed.iter().enumerate() {
                            // SAFETY: row v writes only its own stride segment.
                            unsafe { sp.write(v * stride + k, u) };
                        }
                    }
                }
            }
        });
        unsafe { sorted.set_len(n * stride) };
        CorrState {
            n,
            stride,
            sorted,
            ptr: vec![0; n],
            inserted: vec![0; n],
            n_rem: n,
            scan_kind,
        }
    }

    #[inline]
    pub fn mark_inserted(&mut self, v: u32) {
        debug_assert_eq!(self.inserted[v as usize], 0, "double insertion of {v}");
        self.inserted[v as usize] = 1;
        self.n_rem -= 1;
    }

    /// `MaxCorrs[v]`: the uninserted vertex most similar to `v`, advancing
    /// the cached pointer past inserted entries (the §4.3 scan).
    /// Returns `None` only when every other vertex is inserted.
    #[inline]
    pub fn maxcorr(&mut self, v: u32) -> Option<u32> {
        let row = &self.sorted[v as usize * self.stride..(v as usize + 1) * self.stride];
        let p = scan(self.scan_kind, row, &self.inserted, self.ptr[v as usize] as usize);
        self.ptr[v as usize] = p as u32;
        row.get(p).copied()
    }

    /// Best (gain, vertex) face-vertex pair for face `f` from the up-to-3
    /// `MaxCorrs` candidates (Alg. 1 lines 9–11 / 23–25). The pointer
    /// scans (which mutate state) gather the candidates first; the gains
    /// are then computed in one branch-light [`gain3`] pass. The keep
    /// rule — higher gain wins, ties keep the earlier face vertex's
    /// candidate unless the later candidate id is larger — is unchanged
    /// from the per-candidate formulation, so selection is bit-identical.
    pub fn best_pair(&mut self, s: &Matrix, f: &[u32; 3]) -> Option<(f32, u32)> {
        let mut cands = [0u32; 3];
        let mut nc = 0usize;
        for &w in f {
            if let Some(cand) = self.maxcorr(w) {
                cands[nc] = cand;
                nc += 1;
            }
        }
        let gains = gain3(s, f, &cands[..nc]);
        let mut best: Option<(f32, u32)> = None;
        for (&g, &cand) in gains.iter().zip(cands.iter()).take(nc) {
            match best {
                Some((bg, bv)) if bg > g || (bg == g && bv <= cand) => {}
                _ => best = Some((g, cand)),
            }
        }
        best
    }
}

/// Run CORR-TMFG. `cfg.prefix` ≥ 1 vertices are inserted per round
/// (1 is the paper's best-performing configuration).
pub fn corr_tmfg(s: &Matrix, cfg: &TmfgConfig) -> Result<TmfgResult, TmfgError> {
    let n = validate_similarity(s)?;
    if cfg.prefix < 1 {
        return Err(TmfgError::invalid("prefix must be >= 1"));
    }
    let mut timer = crate::util::timer::Timer::start();
    let mut timings = super::common::TmfgTimings::default();
    let seed = initial_clique(s);
    timings.init = timer.lap();
    let mut builder = Builder::new(seed, n);
    let mut faces = Faces::new(&seed);
    // The single up-front sorting step (the paper's headline change).
    let mut state = CorrState::build(s, cfg.sort, cfg.scan);
    timings.sort = timer.lap();
    for &v in &seed {
        state.mark_inserted(v);
    }

    if n == 4 {
        let mut r = builder.finish(n, faces.alive_faces());
        r.timings = timings;
        return Ok(r);
    }

    // gains[f] = best (gain, vertex) pair for face f; f indexes `faces`.
    let mut gains: Vec<(f32, u32)> = Vec::with_capacity(6 * n);
    for fid in 0..4 {
        let fv = faces.verts[fid];
        let p = state
            .best_pair(s, &fv)
            .ok_or_else(|| TmfgError::invariant("n >= 5 seed face has no candidate"))?;
        gains.push(p);
    }

    let mut round: u64 = 0;
    while state.n_rem > 0 {
        let _round_span = crate::span!("tmfg_round", "corr round {round} rem={}", state.n_rem);
        round += 1;
        // ---- selection (Alg. 1 lines 13–14) --------------------------------
        // Collect the winning face-vertex pairs for this round.
        let selected: Vec<(f32, u32, u32)> = if cfg.prefix == 1 {
            // argmax over alive faces
            let ids = faces.alive_ids();
            let g = &gains;
            let best = parlay::par_argmax(ids.len(), 256, |k| g[ids[k] as usize].0)
                .ok_or_else(|| TmfgError::invariant("no alive faces while vertices remain"))?;
            let fid = ids[best];
            let (gg, v) = gains[fid as usize];
            vec![(gg, fid, v)]
        } else {
            // top-P by gain via parallel sort, then dedupe by vertex.
            let ids = faces.alive_ids();
            let mut pairs: Vec<(f32, u32)> = Vec::with_capacity(ids.len());
            for &f in &ids {
                pairs.push((gains[f as usize].0, f));
            }
            parlay::par_sort_pairs_desc(&mut pairs);
            let mut taken_v = std::collections::HashSet::new();
            let mut sel = Vec::with_capacity(cfg.prefix);
            for (g, f) in pairs {
                let v = gains[f as usize].1;
                if taken_v.insert(v) {
                    sel.push((g, f, v));
                    if sel.len() == cfg.prefix {
                        break;
                    }
                }
            }
            sel
        };
        if selected.is_empty() {
            return Err(TmfgError::invariant(
                "no insertable face-vertex pair while vertices remain",
            ));
        }

        // ---- insertion (lines 15–18) ---------------------------------------
        let mut new_faces: Vec<u32> = Vec::with_capacity(3 * selected.len());
        let mut inserted_now: Vec<u32> = Vec::with_capacity(selected.len());
        for &(_, fid, v) in &selected {
            debug_assert!(faces.alive[fid as usize]);
            debug_assert_eq!(state.inserted[v as usize], 0);
            let fv = faces.verts[fid as usize];
            let owner = builder.insert(v, fv, faces.owner[fid as usize]);
            let nf = faces.split(fid, v, owner);
            new_faces.extend_from_slice(&nf);
            inserted_now.push(v);
            state.mark_inserted(v);
        }

        if state.n_rem == 0 {
            break;
        }

        // ---- update (lines 19–25) -------------------------------------------
        // Faces needing recomputation: the new faces, plus alive faces whose
        // chosen candidate was just inserted.
        gains.resize(faces.len(), (f32::NEG_INFINITY, u32::MAX));
        let just: std::collections::HashSet<u32> = inserted_now.iter().copied().collect();
        let mut to_update: Vec<u32> = new_faces;
        for f in faces.alive_ids() {
            if gains.get(f as usize).map(|p| just.contains(&p.1)).unwrap_or(false) {
                to_update.push(f);
            }
        }
        to_update.sort_unstable();
        to_update.dedup();
        // Recompute best pairs. The maxcorr pointer advance mutates state,
        // so this loop is sequential; each recompute is O(candidates) with
        // the amortized pointer scan (total scan work is O(n²/rounds)).
        for f in to_update {
            let fv = faces.verts[f as usize];
            let p = state
                .best_pair(s, &fv)
                .ok_or_else(|| TmfgError::invariant("no candidate pair while vertices remain"))?;
            gains[f as usize] = p;
        }
    }

    timings.insert = timer.lap();
    let mut r = builder.finish(n, faces.alive_faces());
    r.timings = timings;
    debug_assert!(super::common::check_invariants(&r).is_ok());
    Ok(r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::tmfg::common::check_invariants;

    fn random_corr(n: usize, seed: u64) -> Matrix {
        let ds = SynthSpec::new("t", n, 48, 3).generate(seed);
        crate::data::corr::pearson_correlation(&ds.data)
    }

    #[test]
    fn corrstate_maxcorr_is_true_argmax() {
        let s = random_corr(30, 1);
        let mut st = CorrState::build(&s, SortKind::Comparison, ScanKind::Scalar);
        // insert a few vertices
        for v in [3u32, 7, 20] {
            st.mark_inserted(v);
        }
        for v in 0..30u32 {
            let got = st.maxcorr(v).unwrap();
            // brute-force argmax over uninserted u != v
            let mut best = (f32::NEG_INFINITY, u32::MAX);
            for u in 0..30u32 {
                if u != v && st.inserted[u as usize] == 0 {
                    let sim = s.at(v as usize, u as usize);
                    if sim > best.0 {
                        best = (sim, u);
                    }
                }
            }
            assert_eq!(
                s.at(v as usize, got as usize),
                best.0,
                "v={v}: got {got}, expect {}",
                best.1
            );
        }
    }

    #[test]
    fn corrstate_radix_equals_comparison() {
        let s = random_corr(40, 2);
        let a = CorrState::build(&s, SortKind::Comparison, ScanKind::Scalar);
        let b = CorrState::build(&s, SortKind::Radix, ScanKind::Scalar);
        // the sorted orders must produce identical similarity sequences
        for v in 0..40usize {
            let ka: Vec<f32> = a.sorted[v * 39..(v + 1) * 39]
                .iter()
                .map(|&u| s.at(v, u as usize))
                .collect();
            let kb: Vec<f32> = b.sorted[v * 39..(v + 1) * 39]
                .iter()
                .map(|&u| s.at(v, u as usize))
                .collect();
            assert_eq!(ka, kb, "row {v}");
        }
    }

    #[test]
    fn builds_valid_tmfg() {
        for n in [4usize, 5, 6, 10, 50, 200] {
            let s = random_corr(n, n as u64);
            let r = corr_tmfg(&s, &TmfgConfig::default()).unwrap();
            check_invariants(&r).unwrap_or_else(|e| panic!("n={n}: {e}"));
        }
    }

    #[test]
    fn prefix_variants_valid() {
        let s = random_corr(100, 9);
        for p in [1usize, 5, 10, 50] {
            let cfg = TmfgConfig { prefix: p, ..Default::default() };
            let r = corr_tmfg(&s, &cfg).unwrap();
            check_invariants(&r).unwrap_or_else(|e| panic!("prefix={p}: {e}"));
        }
    }

    #[test]
    fn scan_and_sort_variants_give_same_graph() {
        let s = random_corr(80, 4);
        let base = corr_tmfg(&s, &TmfgConfig::default()).unwrap();
        for (scan, sort) in [
            (ScanKind::Chunked, SortKind::Comparison),
            (ScanKind::Scalar, SortKind::Radix),
            (ScanKind::Chunked, SortKind::Radix),
            (ScanKind::Wide, SortKind::Comparison),
            (ScanKind::Wide, SortKind::Radix),
        ] {
            let r = corr_tmfg(&s, &TmfgConfig { prefix: 1, scan, sort }).unwrap();
            assert_eq!(r.edges, base.edges, "scan={scan:?} sort={sort:?}");
        }
    }

    #[test]
    fn deterministic() {
        let s = random_corr(60, 5);
        let a = corr_tmfg(&s, &TmfgConfig::default()).unwrap();
        let b = corr_tmfg(&s, &TmfgConfig::default()).unwrap();
        assert_eq!(a.edges, b.edges);
        assert_eq!(a.cliques, b.cliques);
    }

    #[test]
    fn larger_prefix_no_better_edge_sum() {
        // A bigger prefix inserts greedier batches → edge sum should not
        // improve (paper: large prefixes reduce quality).
        let s = random_corr(150, 6);
        let e1 = corr_tmfg(&s, &TmfgConfig { prefix: 1, ..Default::default() })
            .unwrap()
            .edge_sum(&s);
        let e50 = corr_tmfg(&s, &TmfgConfig { prefix: 50, ..Default::default() })
            .unwrap()
            .edge_sum(&s);
        assert!(
            e50 <= e1 + 1e-3,
            "prefix-50 edge sum {e50} unexpectedly beats prefix-1 {e1}"
        );
    }
}
