//! PAR-TMFG (Yu & Shun, ICDE'23) — the baseline the paper improves on.
//!
//! For every face, a *gain array* of (gain, vertex) over all
//! then-uninserted vertices is created and sorted **when the face is
//! created** (gains of a fixed face never change, so the array stays
//! valid; inserted vertices are skipped at peek time). Each round, the
//! best pair of every alive face is collected, the pairs are sorted by
//! gain, and the top `prefix` non-conflicting pairs are inserted — each
//! insertion creating three new faces and therefore three fresh O(|V_rem|
//! log |V_rem|) sorts. Those interleaved sorts are the bottleneck the
//! paper's Fig. 5 shows dominating the runtime, especially with small
//! prefixes where only 3·P sorts are available to parallelize per round.

use super::common::{
    gain, initial_clique, validate_similarity, Builder, Faces, TmfgConfig, TmfgResult,
};
use crate::error::TmfgError;
use crate::data::matrix::Matrix;
use crate::parlay;
use std::sync::Mutex;

/// Sorted gain array for one face + a skip pointer.
struct FaceArr {
    /// (gain, vertex) sorted by gain descending; built at face creation.
    pairs: Vec<(f32, u32)>,
    ptr: usize,
}

impl FaceArr {
    fn build(s: &Matrix, fv: &[u32; 3], inserted: &[u8]) -> FaceArr {
        let n = s.rows;
        let mut pairs: Vec<(f32, u32)> = Vec::with_capacity(n);
        for v in 0..n as u32 {
            if inserted[v as usize] == 0 {
                pairs.push((gain(s, fv, v), v));
            }
        }
        // This is "the sorting step" of the baseline.
        pairs.sort_unstable_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
        FaceArr { pairs, ptr: 0 }
    }

    /// Best still-uninserted pair, advancing the skip pointer.
    fn peek(&mut self, inserted: &[u8]) -> Option<(f32, u32)> {
        while self.ptr < self.pairs.len() {
            let (g, v) = self.pairs[self.ptr];
            if inserted[v as usize] == 0 {
                return Some((g, v));
            }
            self.ptr += 1;
        }
        None
    }
}

/// Run PAR-TMFG with the given prefix size (1, 10, and 200 in the paper's
/// experiments). With prefix 1 this reproduces the serial algorithm of
/// Massara et al. exactly (always the globally best pair).
pub fn orig_tmfg(s: &Matrix, prefix: usize) -> Result<TmfgResult, TmfgError> {
    let cfg = TmfgConfig { prefix, ..Default::default() };
    orig_tmfg_cfg(s, &cfg)
}

pub fn orig_tmfg_cfg(s: &Matrix, cfg: &TmfgConfig) -> Result<TmfgResult, TmfgError> {
    let n = validate_similarity(s)?;
    let prefix = cfg.prefix.max(1);
    let mut timer = crate::util::timer::Timer::start();
    let mut timings = super::common::TmfgTimings::default();
    let seed = initial_clique(s);
    timings.init = timer.lap();
    let mut builder = Builder::new(seed, n);
    let mut faces = Faces::new(&seed);
    let mut inserted = vec![0u8; n];
    for &v in &seed {
        inserted[v as usize] = 1;
    }
    let mut n_rem = n - 4;

    // arrs[f] = Some(gain array) while face f is alive.
    let mut arrs: Vec<Option<Mutex<FaceArr>>> = Vec::with_capacity(6 * n);
    {
        let init: Vec<FaceArr> = parlay::par_map(4, 1, |i| FaceArr::build(s, &faces.verts[i], &inserted));
        for a in init {
            arrs.push(Some(Mutex::new(a)));
        }
    }
    timings.sort += timer.lap();

    while n_rem > 0 {
        // ---- peek the best pair of every alive face (parallel) ------------
        let ids: Vec<u32> = faces.alive_ids();
        let ins = &inserted;
        let arrs_ref = &arrs;
        let best: Vec<(f32, u32, u32)> = parlay::par_map(ids.len(), 64, |k| {
            let f = ids[k];
            // A missing array for an alive face is an internal bug; report
            // it as an unpeekable face (NEG_INFINITY) so selection skips it
            // and the empty-selection check below surfaces the error —
            // closures on the parallel pool must not panic.
            let Some(m) = arrs_ref[f as usize].as_ref() else {
                return (f32::NEG_INFINITY, f, u32::MAX);
            };
            let mut arr = match m.lock() {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
            match arr.peek(ins) {
                Some((g, v)) => (g, f, v),
                None => (f32::NEG_INFINITY, f, u32::MAX),
            }
        });

        // ---- sort pairs by gain, take top-P non-conflicting ----------------
        let mut keyed: Vec<(f32, u32)> = best.iter().map(|&(g, f, _)| (g, f)).collect();
        parlay::par_sort_pairs_desc(&mut keyed);
        let by_face: std::collections::HashMap<u32, u32> =
            best.iter().map(|&(_, f, v)| (f, v)).collect();
        let mut taken = std::collections::HashSet::new();
        let mut selected: Vec<(u32, u32)> = Vec::with_capacity(prefix);
        for &(g, f) in &keyed {
            if g == f32::NEG_INFINITY {
                break;
            }
            let v = by_face[&f];
            if v != u32::MAX && taken.insert(v) {
                selected.push((f, v));
                if selected.len() == prefix {
                    break;
                }
            }
        }
        if selected.is_empty() {
            return Err(TmfgError::invariant(
                "no insertable face-vertex pair while vertices remain",
            ));
        }

        // ---- insert the batch ----------------------------------------------
        let mut new_faces: Vec<u32> = Vec::with_capacity(3 * selected.len());
        for &(f, v) in &selected {
            let fv = faces.verts[f as usize];
            let owner = builder.insert(v, fv, faces.owner[f as usize]);
            let nf = faces.split(f, v, owner);
            arrs[f as usize] = None; // free the dead face's array
            new_faces.extend_from_slice(&nf);
            inserted[v as usize] = 1;
            n_rem -= 1;
        }
        if n_rem == 0 {
            break;
        }

        // ---- create + sort the new faces' gain arrays (parallel) -----------
        // This is the step whose limited width (3·P sorts) caps the
        // baseline's parallelism — accounted to `timings.sort`.
        timings.insert += timer.lap();
        let ins2 = &inserted;
        let fverts = &faces.verts;
        let built: Vec<FaceArr> =
            parlay::par_map(new_faces.len(), 1, |k| FaceArr::build(s, &fverts[new_faces[k] as usize], ins2));
        arrs.resize_with(faces.len(), || None);
        for (nf, arr) in new_faces.into_iter().zip(built) {
            arrs[nf as usize] = Some(Mutex::new(arr));
        }
        timings.sort += timer.lap();
    }

    timings.insert += timer.lap();
    let mut r = builder.finish(n, faces.alive_faces());
    r.timings = timings;
    debug_assert!(super::common::check_invariants(&r).is_ok());
    Ok(r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::tmfg::common::check_invariants;
    use crate::tmfg::{corr_tmfg, heap_tmfg};

    fn random_corr(n: usize, seed: u64) -> Matrix {
        let ds = SynthSpec::new("t", n, 48, 4).generate(seed);
        crate::data::corr::pearson_correlation(&ds.data)
    }

    #[test]
    fn builds_valid_tmfg() {
        for n in [4usize, 5, 10, 60, 150] {
            let s = random_corr(n, n as u64);
            let r = orig_tmfg(&s, 1).unwrap();
            check_invariants(&r).unwrap_or_else(|e| panic!("n={n}: {e}"));
        }
    }

    #[test]
    fn prefix_sizes_valid() {
        let s = random_corr(120, 3);
        for p in [1usize, 10, 200] {
            let r = orig_tmfg(&s, p).unwrap();
            check_invariants(&r).unwrap_or_else(|e| panic!("p={p}: {e}"));
        }
    }

    #[test]
    fn prefix1_is_greedy_optimal_step() {
        // With prefix 1, every round inserts the globally max-gain pair:
        // its edge sum must be >= the prefix-10 and prefix-200 runs
        // (greedy dominance on the same instance, as in the paper's Fig 7).
        let s = random_corr(150, 7);
        let e1 = orig_tmfg(&s, 1).unwrap().edge_sum(&s);
        let e10 = orig_tmfg(&s, 10).unwrap().edge_sum(&s);
        let e200 = orig_tmfg(&s, 200).unwrap().edge_sum(&s);
        assert!(e1 >= e10 - 1e-3, "e1={e1} e10={e10}");
        assert!(e10 >= e200 - 1e-3, "e10={e10} e200={e200}");
    }

    #[test]
    fn corr_and_heap_match_orig_quality_closely() {
        // Fig. 7: CORR/HEAP edge sums are within ~1% of PAR-TDBHT-1.
        for seed in [4u64, 5] {
            let s = random_corr(150, seed);
            let e1 = orig_tmfg(&s, 1).unwrap().edge_sum(&s);
            let ec = corr_tmfg(&s, &TmfgConfig::default()).unwrap().edge_sum(&s);
            let eh = heap_tmfg(&s, &TmfgConfig::default()).unwrap().edge_sum(&s);
            assert!((e1 - ec) / e1.abs().max(1e-9) < 0.03, "corr too far: {e1} vs {ec}");
            assert!((e1 - eh) / e1.abs().max(1e-9) < 0.03, "heap too far: {e1} vs {eh}");
            // and greedy prefix-1 dominates the approximations
            assert!(ec <= e1 + 1e-3);
            assert!(eh <= e1 + 1e-3);
        }
    }

    #[test]
    fn deterministic() {
        let s = random_corr(80, 9);
        assert_eq!(orig_tmfg(&s, 10).unwrap().edges, orig_tmfg(&s, 10).unwrap().edges);
    }
}
