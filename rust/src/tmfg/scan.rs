//! `MaxCorrs` forward scans: find the first *uninserted* vertex in a
//! similarity-sorted row, starting from a cached pointer.
//!
//! This is the §4.3 "manual vectorization" optimization. The paper uses
//! AVX2/AVX512 gathers over the inserted flags; portable Rust gets the
//! same effect with an 8-wide manually-unrolled loop over a `u8` flag
//! array that LLVM lowers to vector loads + compares (the flags are
//! gathered at indices `row[p..p+8]`, so the win is bounded by the gather
//! cost — the paper itself reports only a 0.97–1.07× change).

use super::common::ScanKind;

/// Scalar scan: advance `p` until `row[p]` is uninserted. Returns the new
/// pointer (== `row.len()` when exhausted).
#[inline]
pub fn scan_scalar(row: &[u32], inserted: &[u8], mut p: usize) -> usize {
    while p < row.len() && inserted[row[p] as usize] != 0 {
        p += 1;
    }
    p
}

/// 8-wide unrolled scan.
#[inline]
pub fn scan_chunked(row: &[u32], inserted: &[u8], mut p: usize) -> usize {
    let n = row.len();
    while p + 8 <= n {
        // Gather 8 flags; LLVM vectorizes the flag loads + compare.
        let mut mask = 0u32;
        for k in 0..8 {
            // flag is 0 or 1
            mask |= (inserted[row[p + k] as usize] as u32) << k;
        }
        if mask != 0xFF {
            // first zero bit = first uninserted
            return p + (!mask).trailing_zeros() as usize;
        }
        p += 8;
    }
    scan_scalar(row, inserted, p)
}

/// 16-wide branch-light scan: one 16-flag gather fused into a single
/// mask per iteration, with the bounds checks hoisted out of the gather
/// so LLVM sees a straight-line load/shift/or body. Falls back to the
/// 8-wide scan (and from there the scalar scan) for the tail.
#[inline]
pub fn scan_wide(row: &[u32], inserted: &[u8], mut p: usize) -> usize {
    let n = row.len();
    while p + 16 <= n {
        let mut mask = 0u32;
        for k in 0..16 {
            // SAFETY: `p + k < n` by the loop bound, and row entries are
            // vertex ids `< inserted.len()` — the `CorrState::sorted`
            // layout invariant, re-checked here in debug builds.
            let u = unsafe { *row.get_unchecked(p + k) } as usize;
            debug_assert!(u < inserted.len());
            let flag = unsafe { *inserted.get_unchecked(u) } as u32;
            mask |= flag << k;
        }
        if mask != 0xFFFF {
            // first zero bit = first uninserted
            return p + (!mask).trailing_zeros() as usize;
        }
        p += 16;
    }
    scan_chunked(row, inserted, p)
}

/// Dispatch on the configured kind.
#[inline]
pub fn scan(kind: ScanKind, row: &[u32], inserted: &[u8], p: usize) -> usize {
    match kind {
        ScanKind::Scalar => scan_scalar(row, inserted, p),
        ScanKind::Chunked => scan_chunked(row, inserted, p),
        ScanKind::Wide => scan_wide(row, inserted, p),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn scans_agree_on_random_inputs() {
        let mut r = Rng::new(21);
        for _ in 0..200 {
            let n = 1 + r.next_below(200);
            let row: Vec<u32> = {
                let mut v: Vec<u32> = (0..n as u32).collect();
                r.shuffle(&mut v);
                v
            };
            let inserted: Vec<u8> = (0..n).map(|_| (r.next_below(3) == 0) as u8).collect();
            for start in [0usize, n / 3, n.saturating_sub(1)] {
                let a = scan_scalar(&row, &inserted, start);
                let b = scan_chunked(&row, &inserted, start);
                let c = scan_wide(&row, &inserted, start);
                assert_eq!(a, b, "n={n} start={start}");
                assert_eq!(a, c, "wide: n={n} start={start}");
                if a < n {
                    assert_eq!(inserted[row[a] as usize], 0);
                    for q in start..a {
                        assert_eq!(inserted[row[q] as usize], 1);
                    }
                }
            }
        }
    }

    #[test]
    fn exhausted_row() {
        let row = vec![0u32, 1, 2];
        let inserted = vec![1u8, 1, 1];
        assert_eq!(scan_scalar(&row, &inserted, 0), 3);
        assert_eq!(scan_chunked(&row, &inserted, 0), 3);
        assert_eq!(scan_wide(&row, &inserted, 0), 3);
    }

    #[test]
    fn all_clear() {
        let row: Vec<u32> = (0..64).collect();
        let inserted = vec![0u8; 64];
        assert_eq!(scan_chunked(&row, &inserted, 5), 5);
        assert_eq!(scan_wide(&row, &inserted, 5), 5);
    }

    #[test]
    fn boundary_at_chunk_edges() {
        // first uninserted exactly at positions around the 8-wide boundary
        for hole in [7usize, 8, 9, 15, 16, 17] {
            let row: Vec<u32> = (0..32).collect();
            let mut inserted = vec![1u8; 32];
            inserted[hole] = 0;
            assert_eq!(scan_chunked(&row, &inserted, 0), hole);
        }
    }

    #[test]
    fn boundary_at_wide_edges() {
        // first uninserted around the 16-wide boundary, plus tail shapes
        // (row lengths that leave 0 / <8 / 8..16 entries after the last
        // full 16-block) so every fallback path is exercised.
        for len in [16usize, 17, 23, 24, 31, 32, 48] {
            for hole in [0usize, 14, 15, 16, 17, 30, 31, 32, 33, 47] {
                if hole >= len {
                    continue;
                }
                let row: Vec<u32> = (0..len as u32).collect();
                let mut inserted = vec![1u8; len];
                inserted[hole] = 0;
                assert_eq!(scan_wide(&row, &inserted, 0), hole, "len={len} hole={hole}");
            }
        }
    }
}
