//! `tmfg` CLI — the leader entrypoint of the coordinator.
//!
//! Subcommands:
//!   run         run the pipeline once on a dataset and report metrics
//!   experiment  regenerate the paper's tables/figures (table1, fig2..fig7,
//!               apsp, ablation, all)
//!   gen         generate a synthetic dataset to CSV
//!   serve       start the TCP clustering service
//!   stream      replay a dataset tick-by-tick through the incremental
//!               streaming session (sliding-window TMFG-DBHT)
//!   info        print artifact/runtime/pool information

use tmfg::api::TmfgError;
use tmfg::coordinator::experiments::{self, ExpOpts};
use tmfg::coordinator::pipeline::{ApspMode, Pipeline, PipelineConfig, TmfgAlgo};
use tmfg::coordinator::registry;
use tmfg::coordinator::service::{serve, ServiceConfig};
use tmfg::dbht::Linkage;
use tmfg::log;
use tmfg::parlay;
use tmfg::util::cli::Args;
use tmfg::util::json::Json;

const USAGE: &str = "usage: tmfg <run|experiment|gen|serve|stream|info> [flags]

  global: [--quiet]  (suppress info output; TMFG_LOG=off|error|warn|info|debug
          also filters -- machine output like --json-out is unaffected)

  tmfg run --dataset <name|csv> [--algo par1|par10|par200|corr|heap|opt]
           [--scale 0.1] [--seed N] [--threads N]
           [--apsp exact|approx|auto]
           [--hub-n H] [--hub-radius X] [--hub-q Q]
           [--linkage complete|average|single] [--no-xla] [--check]
           [--sparse-k K] [--sparse-seed N]
           [--sparse-dims D] [--sparse-pool P] [--sparse-iters I]
           [--newick out.nwk] [--json-out out.json] [--trace out.json]
           (--sparse-k runs the sparse k-NN pipeline: O(n*K) candidate
            memory instead of the dense O(n^2) similarity matrix.
            --sparse-dims/--sparse-pool/--sparse-iters tune the ANN
            k-NN stage above the exact cutoff: random-projection
            dimensions, candidate pool factor, and NN-descent
            refinement rounds (defaults 16/4/2).
            --apsp approx|auto serves DBHT through the streaming hub
            oracle -- O(n*H) memory, no n^2 distance matrix; --hub-n 0
            means auto (~sqrt(n) hubs). Try
            --dataset synth-large-131072 --sparse-k 32 --apsp approx.
            --trace writes a Chrome trace-event JSON of the run --
            load it in Perfetto or chrome://tracing)
  tmfg experiment <table1|fig2|fig3|fig4|fig5|fig6|fig7|apsp|speedup-table|
           ablation|all>
           [--scale 0.1] [--seed N] [--datasets a,b,c] [--threads 1,2,4]
           [--out-dir results] [--json-out file.json]
           (speedup-table reproduces the paper's headline table: OPT
            construction vs the orig/heap baselines across threads;
            --json-out adds a machine-readable document)
  tmfg gen --dataset <name> --out <file.csv> [--scale 0.1] [--seed N]
  tmfg serve [--addr 127.0.0.1:7401] [--algo opt] [--max-batch 8]
           [--dispatch-workers N] [--cache-entries 32]
           [--max-conns 1024] [--max-line-bytes 16777216]
           [--idle-timeout 300] [--tenant-quota N] [--max-queue N]
           [--target-queue-delay-ms M] [--recorder-budget BYTES]
           [--flight-log out.jsonl] [--poll-backend]
           (event-loop front end: one OS thread serves every connection;
            accepts JSON lines and length-prefixed binary frames
            (protocol v2) on the same connection -- framed sparse
            requests may carry up to 2^20 series, past the JSON cap;
            requests over --max-queue or a tenant's --tenant-quota get a
            typed \"overloaded\" error; idle connections are reaped after
            --idle-timeout seconds, 0 disables.
            --target-queue-delay-ms enables CoDel-style adaptive
            admission: batch work is shed with cause \"delay\" while the
            dispatch queue's oldest job exceeds the target, with
            --max-queue kept as the hard depth ceiling; 0 disables.
            every completed request lands in an in-memory flight
            recorder ring (--recorder-budget bytes, 0 disables; dump it
            live with {\"cmd\":\"debug_dump\"} or to --flight-log as
            JSONL on graceful shutdown))
  tmfg stream --dataset <name|csv> [--window 64] [--k N] [--algo opt]
           [--drift 0.1] [--scale 0.1] [--seed N] [--threads N]
  tmfg info
";

fn main() {
    let args = match Args::parse(&[]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    if args.get_bool("quiet", false) {
        tmfg::obs::set_max_level(Some(tmfg::obs::Level::Warn));
    }
    match args.subcommand().unwrap_or_default() {
        "run" => cmd_run(&args),
        "experiment" => cmd_experiment(&args),
        "gen" => cmd_gen(&args),
        "serve" => cmd_serve(&args),
        "stream" => cmd_stream(&args),
        "info" => cmd_info(),
        _ => {
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
    }
}

/// CLI boundary: the library reports `TmfgError`; the binary prints it
/// and exits (the one place where exiting is the right response).
fn fail(e: TmfgError) -> ! {
    log!(error, "error: {e}");
    std::process::exit(1);
}

fn parse_algo(args: &Args) -> TmfgAlgo {
    let s = args.get_str("algo", "opt");
    TmfgAlgo::parse(&s).unwrap_or_else(|| {
        log!(error, "unknown algo {s}");
        std::process::exit(2);
    })
}

fn cmd_run(args: &Args) {
    let name = args.get_str("dataset", "demo");
    let scale = args.get_f64("scale", 0.1);
    let seed = args.get_u64("seed", registry::DEFAULT_SEED);
    if let Some(t) = args.opt_str("threads") {
        parlay::set_num_threads(t.parse().unwrap_or(1));
    }
    let mut ds = registry::get_dataset(&name, scale, seed).unwrap_or_else(|| {
        log!(error, "unknown dataset {name}");
        std::process::exit(2);
    });
    let apsp = args.opt_str("apsp").and_then(ApspMode::parse);
    let linkage = match args.get_str("linkage", "complete").as_str() {
        "single" => Linkage::Single,
        "average" => Linkage::Average,
        _ => Linkage::Complete,
    };
    let hub_default = tmfg::apsp::HubConfig::default();
    let hub = tmfg::apsp::HubConfig {
        n_hubs: args.get_usize("hub-n", hub_default.n_hubs),
        radius_mult: args.get_f64("hub-radius", hub_default.radius_mult as f64) as f32,
        hubs_per_vertex: args.get_usize("hub-q", hub_default.hubs_per_vertex),
    };
    let cfg = PipelineConfig {
        algo: parse_algo(args),
        apsp,
        linkage,
        hub: hub.clone(),
        use_xla: !args.get_bool("no-xla", false),
        check_invariants: args.get_bool("check", false),
        ..Default::default()
    };
    log!(
        info,
        "dataset {} (n={}, L={}, k={}), algo {}, {} threads{}",
        ds.name,
        ds.n(),
        ds.len(),
        ds.n_classes,
        cfg.algo.name(),
        parlay::num_threads(),
        if args.has("sparse-k") {
            format!(", sparse k-NN k={}", args.get_usize("sparse-k", 32))
        } else {
            String::new()
        }
    );
    // An exclusive tracing session spanning the whole pipeline run; the
    // per-thread span buffers render as Chrome trace-event JSON below.
    let trace_path = args.opt_str("trace");
    let trace_session = trace_path.as_ref().map(|_| tmfg::obs::TraceSession::begin());
    let out = if args.has("sparse-k") {
        // Sparse mode goes through the typed API directly: the legacy
        // Pipeline facade is dense-only. The panel and labels move into
        // the request — at n=2^20 a clone here would be a second full
        // panel resident for the whole run.
        let panel = std::mem::replace(&mut ds.data, tmfg::data::matrix::Matrix::zeros(0, 0));
        let labels = std::mem::take(&mut ds.labels);
        let opt_usize = |key: &str| args.opt_str(key).and_then(|s| s.parse::<usize>().ok());
        let mut req = tmfg::api::ClusterRequest::panel(panel)
            .labels(labels)
            .k(ds.n_classes)
            .algo(cfg.algo)
            .linkage(cfg.linkage)
            .hub(hub.clone())
            .check_invariants(cfg.check_invariants)
            .sparse_knn_tuned(
                args.get_usize("sparse-k", 32),
                args.get_u64("sparse-seed", tmfg::sparse::DEFAULT_KNN_SEED),
                opt_usize("sparse-dims"),
                opt_usize("sparse-pool"),
                opt_usize("sparse-iters"),
            );
        if let Some(mode) = apsp {
            req = req.apsp(mode);
        }
        req.run().unwrap_or_else(|e| fail(e))
    } else {
        Pipeline::new(cfg).run_dataset(&ds).unwrap_or_else(|e| fail(e))
    };
    if let (Some(session), Some(path)) = (trace_session, trace_path.as_deref()) {
        let (trace_id, epoch, threads) = session.finish();
        let trace = tmfg::obs::chrome_trace(&trace_id, epoch, &threads);
        std::fs::write(path, trace.to_string()).unwrap_or_else(|e| fail(e.into()));
        log!(info, "wrote Chrome trace {trace_id} to {path} (open in Perfetto)");
    }
    log!(info, "\nstage breakdown:\n{}", out.breakdown.table());
    if let Some(sp) = &out.sparse {
        log!(
            info,
            "sparse candidates: k={} (dims={} pool={} iters={}) nnz={} mean degree {:.1}, {} dense-fallback rounds",
            sp.k,
            sp.dims,
            sp.pool,
            sp.iters,
            sp.nnz,
            sp.mean_degree,
            sp.fallbacks
        );
    }
    if let Some(p) = out.corr_path {
        log!(info, "similarity path: {p:?}");
    }
    log!(info, "apsp oracle: {}", out.oracle.name());
    log!(info, "TMFG edges: {} (edge sum {:.3})", out.tmfg.edges.len(), out.edge_sum);
    log!(info, "converging bubbles: {}", out.dbht.n_converging);
    if let Some(ari) = out.ari {
        log!(info, "ARI @ k={}: {ari:.4}", ds.n_classes);
    }
    if let Some(path) = args.opt_str("newick") {
        std::fs::write(path, out.dbht.dendrogram.to_newick(None))
            .unwrap_or_else(|e| fail(e.into()));
        log!(info, "wrote dendrogram (Newick) to {path}");
    }
    if let Some(path) = args.opt_str("json-out") {
        // Machine output: dendrogram plus the per-stage timings in one
        // document (stages serialized via Breakdown::to_json, the same
        // form the trace exporter uses).
        let doc = Json::obj(vec![
            ("dendrogram", out.dbht.dendrogram.to_json()),
            ("breakdown", out.breakdown.to_json()),
        ]);
        std::fs::write(path, doc.to_string()).unwrap_or_else(|e| fail(e.into()));
        log!(info, "wrote dendrogram + breakdown (JSON) to {path}");
    }
}

fn cmd_experiment(args: &Args) {
    let which = args.positional.get(1).cloned().unwrap_or_else(|| "all".into());
    let opts = ExpOpts {
        scale: args.get_f64("scale", 0.1),
        seed: args.get_u64("seed", registry::DEFAULT_SEED),
        threads: args.get_usize_list("threads", &[]),
        datasets: args
            .opt_str("datasets")
            .map(|s| s.split(',').map(|x| x.trim().to_string()).collect())
            .unwrap_or_default(),
        out_dir: args.get_str("out-dir", "results"),
        json_out: args.opt_str("json-out"),
    };
    let result = match which.as_str() {
        "table1" => experiments::table1(&opts),
        "fig2" => experiments::fig2(&opts),
        "fig3" => experiments::fig3(&opts),
        "fig4" => experiments::fig4(&opts),
        "fig5" => experiments::fig5(&opts),
        "fig6" => experiments::fig6(&opts),
        "fig7" => experiments::fig7(&opts),
        "apsp" => experiments::apsp_speedup(&opts),
        "speedup-table" => experiments::speedup_table(&opts),
        "ablation" => experiments::ablation_linkage(&opts),
        "all" => experiments::all(&opts),
        other => {
            log!(error, "unknown experiment {other}\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        fail(e);
    }
}

fn cmd_gen(args: &Args) {
    let name = args.get_str("dataset", "demo");
    let out = args.get_str("out", "dataset.csv");
    let ds = registry::get_dataset(&name, args.get_f64("scale", 0.1), args.get_u64("seed", 1))
        .unwrap_or_else(|| {
            log!(error, "unknown dataset {name}");
            std::process::exit(2);
        });
    tmfg::data::loader::save_ucr_csv(&ds, std::path::Path::new(&out))
        .unwrap_or_else(|e| fail(e.into()));
    log!(info, "wrote {} (n={}, L={}, k={})", out, ds.n(), ds.len(), ds.n_classes);
}

fn cmd_serve(args: &Args) {
    // Idle timeout in (fractional) seconds; <= 0 disables reaping.
    let idle_secs = args.get_f64("idle-timeout", 300.0);
    let cfg = ServiceConfig {
        addr: args.get_str("addr", "127.0.0.1:7401"),
        max_batch: args.get_usize("max-batch", 8),
        default_algo: parse_algo(args),
        // 0 = auto (min(4, cores/2)); sharded dispatcher worker pool
        dispatch_workers: args.get_usize("dispatch-workers", 0),
        // 0 disables the cross-request artifact cache
        cache_entries: args.get_usize("cache-entries", 32),
        max_conns: args.get_usize("max-conns", 1024),
        max_line_bytes: args.get_usize("max-line-bytes", 16 << 20),
        idle_timeout: if idle_secs > 0.0 {
            std::time::Duration::from_secs_f64(idle_secs)
        } else {
            std::time::Duration::ZERO
        },
        // 0 = unlimited: per-tenant in-flight request quota
        tenant_quota: args.get_usize("tenant-quota", 0),
        // 0 = auto (workers * max_batch * 8): batch admission bound
        max_queue_depth: args.get_usize("max-queue", 0),
        poll_backend: args.get_bool("poll-backend", false),
        // 0 disables the CoDel-style queue-delay admission gate
        target_queue_delay: std::time::Duration::from_millis(
            args.get_u64("target-queue-delay-ms", 0),
        ),
        // 0 disables the flight recorder entirely
        flight_recorder_bytes: args.get_usize(
            "recorder-budget",
            tmfg::obs::FlightRecorder::DEFAULT_BUDGET,
        ),
        flight_log: args.opt_str("flight-log"),
        ..Default::default()
    };
    let workers = cfg.resolved_workers();
    let max_queue = cfg.resolved_max_queue();
    let (max_conns, quota) = (cfg.max_conns, cfg.tenant_quota);
    let target_delay = cfg.target_queue_delay;
    let cache_entries = cfg.cache_entries;
    let h = serve(cfg).unwrap_or_else(|e| fail(e.into()));
    log!(info, "tmfg clustering service listening on {}", h.addr);
    log!(
        info,
        "dispatch workers: {workers}; artifact cache: {}",
        if cache_entries > 0 { format!("{cache_entries} entries") } else { "disabled".into() }
    );
    log!(
        info,
        "admission: max {max_conns} conns, queue bound {max_queue}, tenant quota {}, queue-delay target {}",
        if quota > 0 { quota.to_string() } else { "unlimited".into() },
        if target_delay.is_zero() {
            "off".into()
        } else {
            format!("{}ms", target_delay.as_millis())
        }
    );
    log!(
        info,
        "protocol: one JSON request per line, or length-prefixed binary frames (v2); \
         see api::wire + coordinator/service.rs"
    );
    // Block on the service itself: when a client sends {"cmd":"shutdown"}
    // the acceptor and dispatcher wind down and wait() returns.
    h.wait();
    log!(info, "tmfg clustering service shut down cleanly");
}

fn cmd_stream(args: &Args) {
    let name = args.get_str("dataset", "demo");
    let scale = args.get_f64("scale", 0.1);
    let seed = args.get_u64("seed", registry::DEFAULT_SEED);
    if let Some(t) = args.opt_str("threads") {
        parlay::set_num_threads(t.parse().unwrap_or(1));
    }
    let ds = registry::get_dataset(&name, scale, seed).unwrap_or_else(|| {
        log!(error, "unknown dataset {name}");
        std::process::exit(2);
    });
    let window = args.get_usize("window", 64);
    let k = args.get_usize("k", ds.n_classes);
    // The streaming path recomputes similarity incrementally itself; the
    // XLA batch engine never runs, so skip its initialization.
    let cfg = PipelineConfig { algo: parse_algo(args), use_xla: false, ..Default::default() };
    let pipeline = Pipeline::new(cfg);
    let mut scfg = pipeline.stream_config(ds.n(), window, k);
    scfg.policy.drift_threshold =
        args.get_f64("drift", scfg.policy.drift_threshold as f64) as f32;
    log!(
        info,
        "streaming {} (n={}, {} ticks), window {}, k {}, algo {}, drift threshold {:.3}, {} threads",
        ds.name,
        ds.n(),
        ds.len(),
        window,
        k,
        pipeline.config.algo.name(),
        scfg.policy.drift_threshold,
        parlay::num_threads()
    );
    let (session, outputs) = pipeline.run_stream(&ds.data, scfg).unwrap_or_else(|e| fail(e));
    let st = session.stats();
    log!(
        info,
        "ticks {}  emissions {}  rebuilds {}  refreshes {}  (final generation {})",
        st.ticks,
        st.emissions,
        st.rebuilds,
        st.refreshes,
        session.generation()
    );
    let emitted: Vec<f64> =
        outputs.iter().filter(|o| o.labels.is_some()).map(|o| o.secs).collect();
    if !emitted.is_empty() {
        let mean = emitted.iter().sum::<f64>() / emitted.len() as f64;
        let max = emitted.iter().cloned().fold(0.0f64, f64::max);
        log!(info, "per-tick latency (emitting ticks): mean {mean:.5}s  max {max:.5}s");
    }
    if let Some(last) = outputs.iter().rev().find_map(|o| o.labels.as_ref()) {
        let ari = tmfg::metrics::adjusted_rand_index(&ds.labels, last);
        log!(info, "final clustering ARI vs ground truth @ k={k}: {ari:.4}");
    }
}

fn cmd_info() {
    log!(
        info,
        "tmfg — parallel TMFG-DBHT hierarchical clustering (Raphael & Shun 2024 reproduction)"
    );
    log!(info, "pool threads: {}", parlay::num_threads());
    match tmfg::runtime::Manifest::load(std::path::Path::new("artifacts")) {
        Ok(m) => {
            log!(info, "XLA artifacts ({} buckets):", m.buckets.len());
            for b in &m.buckets {
                log!(
                    info,
                    "  {}x{}  block_rows={} vmem/step={}KiB  {}",
                    b.n,
                    b.l,
                    b.block_rows,
                    b.vmem_bytes_per_step / 1024,
                    b.file.display()
                );
            }
            match tmfg::runtime::client::XlaRuntime::new() {
                Ok(rt) => log!(info, "PJRT platform: {}", rt.platform()),
                Err(e) => log!(info, "PJRT unavailable: {e:#}"),
            }
        }
        Err(e) => log!(info, "no artifacts ({e:#}); run `make artifacts`"),
    }
    log!(info, "datasets: {}", registry::table1_names().join(", "));
}
