//! Micro/macro-benchmark harness (replaces criterion, unavailable offline).
//!
//! Each `cargo bench` target (declared `harness = false`) builds a
//! `BenchSuite`, registers named cases, and gets warmup, repeated timed
//! runs, summary statistics, and CSV output under `results/`.

use super::timer::Timer;
use std::io::Write;

#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub reps: usize,
    pub mean: f64,
    /// Median of the timed samples (the robust central estimate the
    /// machine-readable perf trajectory tracks).
    pub median: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    /// Histogram percentiles of the samples (seconds), via the obs
    /// log-linear histogram — exact order statistics only down to its
    /// 6.25% bucket resolution, which is what the JSON artifact tracks.
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    /// Process peak RSS (VmHWM, KiB) read right after the last rep —
    /// `None` off Linux.
    pub peak_rss_kb: Option<u64>,
}

impl Stats {
    pub fn from_samples(name: &str, samples: &[f64]) -> Stats {
        // Empty-slice guard: a fold over no samples would yield
        // min = +inf / max = -inf, which `write_json` can only serialize
        // as null (JSON has no Inf) — silently breaking every JSON
        // consumer downstream (check_bench.py rejects non-finite fields
        // loudly for exactly this reason). Define the empty summary as
        // all-zeros instead, like `median` already does.
        if samples.is_empty() {
            return Stats {
                name: name.to_string(),
                reps: 0,
                mean: 0.0,
                median: 0.0,
                stddev: 0.0,
                min: 0.0,
                max: 0.0,
                p50: 0.0,
                p95: 0.0,
                p99: 0.0,
                peak_rss_kb: peak_rss_kb(),
            };
        }
        let n = samples.len().max(1) as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        let median = {
            let mut sorted = samples.to_vec();
            sorted.sort_by(f64::total_cmp);
            match sorted.len() {
                0 => 0.0,
                m if m % 2 == 1 => sorted[m / 2],
                m => (sorted[m / 2 - 1] + sorted[m / 2]) / 2.0,
            }
        };
        let mut hist = crate::obs::Histogram::new();
        for &s in samples {
            if s.is_finite() && s >= 0.0 {
                hist.record((s * 1e9).round() as u64);
            }
        }
        let pct = |q: f64| hist.percentile(q) as f64 / 1e9;
        Stats {
            name: name.to_string(),
            reps: samples.len(),
            mean,
            median,
            stddev: var.sqrt(),
            min: samples.iter().cloned().fold(f64::INFINITY, f64::min),
            max: samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
            p50: pct(0.50),
            p95: pct(0.95),
            p99: pct(0.99),
            peak_rss_kb: peak_rss_kb(),
        }
    }
}

/// Process peak resident set size in KiB (Linux `VmHWM`), the ad-hoc
/// reading bench_apsp pioneered, now recorded by every suite entry.
pub fn peak_rss_kb() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find(|l| l.starts_with("VmHWM:"))?
        .split_whitespace()
        .nth(1)?
        .parse()
        .ok()
}

/// Time a single invocation of `f` in seconds.
pub fn time_once<F: FnOnce()>(f: F) -> f64 {
    let t = Timer::start();
    f();
    t.elapsed()
}

/// Configuration for a suite; tuned via env vars so CI can shrink runs:
/// `BENCH_REPS`, `BENCH_WARMUP`, `BENCH_MIN_SECS`.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup: usize,
    pub reps: usize,
    /// Keep repeating *past* `reps` until this much total time has been
    /// measured, so fast cases collect enough samples for a stable
    /// median (the regression gate compares medians). Bounded by
    /// [`MIN_SECS_REP_CEILING`] so a mis-set `BENCH_MIN_SECS` on a
    /// sub-microsecond case can't spin forever.
    pub min_secs: f64,
}

/// Hard ceiling on the number of timed reps when `min_secs` extends
/// sampling — generous (a ~0-cost case still finishes in well under a
/// second) but finite.
pub const MIN_SECS_REP_CEILING: usize = 10_000;

impl Default for BenchConfig {
    fn default() -> Self {
        let envu = |k: &str, d: usize| {
            std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
        };
        let envf = |k: &str, d: f64| {
            std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
        };
        BenchConfig {
            warmup: envu("BENCH_WARMUP", 1),
            reps: envu("BENCH_REPS", 3),
            min_secs: envf("BENCH_MIN_SECS", 0.0),
        }
    }
}

pub struct BenchSuite {
    pub suite: String,
    pub config: BenchConfig,
    pub results: Vec<Stats>,
    /// Extra (key, value) columns attached to the next `run` call.
    pending_meta: Vec<(String, String)>,
    meta_rows: Vec<Vec<(String, String)>>,
}

impl BenchSuite {
    pub fn new(suite: &str) -> BenchSuite {
        println!("== bench suite: {suite} ==");
        BenchSuite {
            suite: suite.to_string(),
            config: BenchConfig::default(),
            results: Vec::new(),
            pending_meta: Vec::new(),
            meta_rows: Vec::new(),
        }
    }

    /// Attach metadata columns (dataset, algo, threads, …) to the next case.
    pub fn meta(&mut self, key: &str, value: &str) -> &mut Self {
        self.pending_meta.push((key.to_string(), value.to_string()));
        self
    }

    /// Run a case: warmups then timed reps; prints and records stats.
    /// `f` receives the rep index. At least `config.reps` reps always
    /// run; when `config.min_secs > 0` sampling keeps extending past
    /// `reps` until that much total time has been measured (or the
    /// [`MIN_SECS_REP_CEILING`] hard cap is hit), so near-zero-cost
    /// cases still produce a usable sample population.
    pub fn run<F: FnMut(usize)>(&mut self, name: &str, mut f: F) -> &Stats {
        for w in 0..self.config.warmup {
            f(w);
        }
        let reps = self.config.reps.max(1);
        let ceiling = if self.config.min_secs > 0.0 {
            reps.max(MIN_SECS_REP_CEILING)
        } else {
            reps
        };
        let mut samples = Vec::with_capacity(reps);
        let mut spent = 0.0;
        loop {
            let t = Timer::start();
            f(samples.len());
            let dt = t.elapsed();
            samples.push(dt);
            spent += dt;
            if (samples.len() >= reps && spent >= self.config.min_secs)
                || samples.len() >= ceiling
            {
                break;
            }
        }
        let s = Stats::from_samples(name, &samples);
        println!(
            "{:<48} mean {:>10.4}s  sd {:>8.4}s  min {:>10.4}s  (n={})",
            s.name, s.mean, s.stddev, s.min, s.reps
        );
        self.results.push(s);
        self.meta_rows.push(std::mem::take(&mut self.pending_meta));
        self.results.last().unwrap()
    }

    /// Write all results as machine-readable JSON under
    /// `results/BENCH_<suite>.json` — the perf-trajectory artifact CI
    /// smoke-runs on every push and `scripts/check_bench.py` gates
    /// against the committed baselines. Suites use the canonical short
    /// names (`apsp`, `parlay`, `pipeline`, `sparse`, `stream`, `tmfg`)
    /// so all six artifacts follow one `BENCH_<name>.json` shape.
    /// One entry per scenario: `name`,
    /// `median_ns` (plus mean/min for context), histogram percentiles
    /// (`p50_ns`/`p95_ns`/`p99_ns`), the peak RSS observed after the
    /// case ran (`peak_rss_kb`, Linux), `reps`, and every metadata
    /// column (numeric where parseable, e.g. `n`, `threads`).
    pub fn write_json(&self) -> std::io::Result<String> {
        use crate::util::json::Json;
        std::fs::create_dir_all("results")?;
        let path = format!("results/BENCH_{}.json", self.suite);
        let entries: Vec<Json> = self
            .results
            .iter()
            .zip(&self.meta_rows)
            .map(|(s, row)| {
                let mut pairs = vec![
                    ("name", Json::str(&s.name)),
                    ("median_ns", Json::Num((s.median * 1e9).round())),
                    ("mean_ns", Json::Num((s.mean * 1e9).round())),
                    ("min_ns", Json::Num((s.min * 1e9).round())),
                    ("p50_ns", Json::Num((s.p50 * 1e9).round())),
                    ("p95_ns", Json::Num((s.p95 * 1e9).round())),
                    ("p99_ns", Json::Num((s.p99 * 1e9).round())),
                    (
                        "peak_rss_kb",
                        s.peak_rss_kb.map_or(Json::Null, |kb| Json::Num(kb as f64)),
                    ),
                    ("reps", Json::Num(s.reps as f64)),
                ];
                for (k, v) in row {
                    pairs.push((
                        k.as_str(),
                        match v.parse::<f64>() {
                            Ok(num) if num.is_finite() => Json::Num(num),
                            _ => Json::str(v),
                        },
                    ));
                }
                Json::obj(pairs)
            })
            .collect();
        let doc = Json::obj(vec![
            ("suite", Json::str(&self.suite)),
            ("results", Json::Arr(entries)),
        ]);
        std::fs::write(&path, doc.to_string())?;
        println!("wrote {path}");
        Ok(path)
    }

    /// Write all results as CSV under `results/<suite>.csv`.
    pub fn write_csv(&self) -> std::io::Result<String> {
        std::fs::create_dir_all("results")?;
        let path = format!("results/{}.csv", self.suite);
        let mut f = std::fs::File::create(&path)?;
        // union of metadata keys, in first-seen order
        let mut keys: Vec<String> = Vec::new();
        for row in &self.meta_rows {
            for (k, _) in row {
                if !keys.contains(k) {
                    keys.push(k.clone());
                }
            }
        }
        write!(f, "name")?;
        for k in &keys {
            write!(f, ",{k}")?;
        }
        writeln!(f, ",reps,mean_s,stddev_s,min_s,max_s")?;
        for (s, row) in self.results.iter().zip(&self.meta_rows) {
            write!(f, "{}", s.name.replace(',', ";"))?;
            for k in &keys {
                let v = row.iter().find(|(rk, _)| rk == k).map(|(_, v)| v.as_str()).unwrap_or("");
                write!(f, ",{v}")?;
            }
            writeln!(f, ",{},{:.6},{:.6},{:.6},{:.6}", s.reps, s.mean, s.stddev, s.min, s.max)?;
        }
        println!("wrote {path}");
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = Stats::from_samples("x", &[1.0, 2.0, 3.0]);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert!((s.stddev - (2.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn median_odd_even_and_unsorted() {
        assert_eq!(Stats::from_samples("x", &[3.0, 1.0, 2.0]).median, 2.0);
        assert_eq!(Stats::from_samples("x", &[4.0, 1.0, 3.0, 2.0]).median, 2.5);
        assert_eq!(Stats::from_samples("x", &[7.0]).median, 7.0);
        assert_eq!(Stats::from_samples("x", &[]).median, 0.0);
    }

    #[test]
    fn run_counts() {
        let mut suite = BenchSuite::new("test_suite_tmp");
        suite.config = BenchConfig { warmup: 2, reps: 3, min_secs: 0.0 };
        let mut calls = 0;
        suite.meta("k", "v").run("case", |_| calls += 1);
        assert_eq!(calls, 5); // 2 warmup + 3 reps
        assert_eq!(suite.results.len(), 1);
        assert_eq!(suite.results[0].reps, 3);
    }

    #[test]
    fn min_secs_extends_sampling_for_fast_cases() {
        // A ~0-cost case under min_secs > 0 must collect more than
        // `reps` samples (the historical bug: the break condition could
        // only fire on the final of `reps` iterations, so BENCH_MIN_SECS
        // was dead code and fast cases got 3 noisy samples).
        let mut suite = BenchSuite::new("test_min_secs_tmp");
        suite.config = BenchConfig { warmup: 0, reps: 3, min_secs: 0.005 };
        let mut calls = 0usize;
        let s = suite.run("noop", |_| calls += 1).clone();
        assert!(
            s.reps > 3,
            "min_secs should extend past reps, got {} samples",
            s.reps
        );
        assert!(s.reps <= MIN_SECS_REP_CEILING);
        assert_eq!(calls, s.reps);
        // rep indices were passed in order: the closure ran once per sample
        // and the recorded stats are finite.
        assert!(s.mean.is_finite() && s.min.is_finite() && s.max.is_finite());
    }

    #[test]
    fn min_secs_already_satisfied_stays_at_reps() {
        // A case slower than min_secs/reps must not over-sample.
        let mut suite = BenchSuite::new("test_min_secs_slow_tmp");
        suite.config = BenchConfig { warmup: 0, reps: 2, min_secs: 0.002 };
        let s = suite
            .run("slow", |_| std::thread::sleep(std::time::Duration::from_millis(3)))
            .clone();
        assert_eq!(s.reps, 2);
    }

    #[test]
    fn empty_samples_yield_finite_stats() {
        // min/max folds over an empty slice would give +inf/-inf, which
        // serialize as JSON null and break every artifact consumer.
        let s = Stats::from_samples("empty", &[]);
        assert_eq!(s.reps, 0);
        assert_eq!((s.min, s.max, s.mean, s.median), (0.0, 0.0, 0.0, 0.0));
        assert!(s.stddev.is_finite() && s.p50.is_finite() && s.p99.is_finite());
    }

    #[test]
    fn stats_percentiles_and_rss() {
        let samples: Vec<f64> = (1..=100).map(|i| i as f64 * 1e-3).collect();
        let s = Stats::from_samples("p", &samples);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99);
        // within histogram bucket resolution (6.25%) of the true order stats
        assert!((s.p50 - 0.050).abs() < 0.004, "{}", s.p50);
        assert!((s.p99 - 0.099).abs() < 0.007, "{}", s.p99);
        // VmHWM is available on Linux CI; just sanity-check when present
        if let Some(kb) = s.peak_rss_kb {
            assert!(kb > 0);
        }
    }

    #[test]
    fn time_once_positive() {
        let t = time_once(|| std::thread::sleep(std::time::Duration::from_millis(2)));
        assert!(t >= 0.001);
    }
}
