//! Minimal JSON parser + writer (replaces serde_json, unavailable offline).
//!
//! Used for the AOT artifact manifest, experiment configs, result dumps,
//! and the clustering-service wire protocol. Supports the full JSON value
//! model; numbers are kept as f64 (adequate for all our payloads).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ----- accessors ------------------------------------------------------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= usize::MAX as f64 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` convenience; returns Null for missing keys/non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // ----- constructors ---------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // ----- serialization --------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else if n.is_finite() {
                    out.push_str(&format!("{n}"));
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // ----- parsing --------------------------------------------------------
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).map_err(|_| self.err("bad utf8"))?;
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u escape"))?;
                            // NOTE: surrogate pairs are not needed for our payloads;
                            // unpaired surrogates map to U+FFFD.
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 char.
                    let rest = std::str::from_utf8(&self.b[self.i..]).map_err(|_| self.err("bad utf8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut o = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(o));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            o.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(o));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-2e3").unwrap(), Json::Num(-2000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c\nd"}], "e": null}"#).unwrap();
        assert_eq!(j.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(j.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("c\nd"));
        assert_eq!(*j.get("e"), Json::Null);
        assert_eq!(*j.get("missing"), Json::Null);
    }

    #[test]
    fn roundtrip() {
        let cases = [
            r#"{"a":[1,2.5,-3],"b":{"c":true,"d":"x\"y"},"e":null}"#,
            "[]",
            "{}",
            r#"[1,[2,[3,[4]]]]"#,
            r#""unicode: café""#,
        ];
        for c in cases {
            let v = Json::parse(c).unwrap();
            let s = v.to_string();
            let v2 = Json::parse(&s).unwrap();
            assert_eq!(v, v2, "case {c}");
        }
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "tru", "{\"a\" 1}", "1 2", "{'a':1}"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn accessors() {
        let j = Json::parse(r#"{"n": 42, "s": "x", "b": false, "a": [1]}"#).unwrap();
        assert_eq!(j.get("n").as_usize(), Some(42));
        assert_eq!(j.get("s").as_str(), Some("x"));
        assert_eq!(j.get("b").as_bool(), Some(false));
        assert_eq!(j.get("a").as_arr().unwrap().len(), 1);
        assert_eq!(j.get("n").as_str(), None);
        assert_eq!(Json::Num(1.5).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
    }

    #[test]
    fn obj_builder() {
        let j = Json::obj(vec![("x", Json::Num(1.0)), ("y", Json::str("z"))]);
        let s = j.to_string();
        assert_eq!(s, r#"{"x":1,"y":"z"}"#);
    }
}
