//! Wall-clock timing helpers and the per-stage `Breakdown` used to
//! reproduce the paper's Figure 5 (time breakdown by pipeline stage).

use std::time::Instant;

/// A simple wall-clock stopwatch.
#[derive(Debug, Clone)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Seconds elapsed since `start` (or the last `reset`).
    pub fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn reset(&mut self) {
        self.start = Instant::now();
    }

    /// Elapsed seconds, then reset — convenient for sequential stages.
    pub fn lap(&mut self) -> f64 {
        let e = self.elapsed();
        self.reset();
        e
    }
}

impl Default for Timer {
    fn default() -> Self {
        Self::start()
    }
}

/// Named stage timings for one pipeline run (the Fig. 5 artifact).
#[derive(Debug, Clone, Default)]
pub struct Breakdown {
    entries: Vec<(String, f64)>,
}

impl Breakdown {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record `secs` under `stage`, accumulating if the stage repeats.
    pub fn add(&mut self, stage: &str, secs: f64) {
        if let Some(e) = self.entries.iter_mut().find(|(s, _)| s == stage) {
            e.1 += secs;
        } else {
            self.entries.push((stage.to_string(), secs));
        }
    }

    pub fn get(&self, stage: &str) -> Option<f64> {
        self.entries.iter().find(|(s, _)| s == stage).map(|(_, t)| *t)
    }

    /// Drop a stage's accumulated time — used when a stage artifact is
    /// invalidated and will be re-run, so the breakdown never
    /// double-counts.
    pub fn remove(&mut self, stage: &str) {
        self.entries.retain(|(s, _)| s != stage);
    }

    pub fn total(&self) -> f64 {
        self.entries.iter().map(|(_, t)| t).sum()
    }

    pub fn stages(&self) -> &[(String, f64)] {
        &self.entries
    }

    /// Merge another breakdown into this one (stage-wise sum).
    pub fn merge(&mut self, other: &Breakdown) {
        for (s, t) in &other.entries {
            self.add(s, *t);
        }
    }

    /// Render as an aligned two-column table with a total row.
    pub fn table(&self) -> String {
        let width = self.entries.iter().map(|(s, _)| s.len()).max().unwrap_or(5).max(5);
        // One pass for the total; the per-row percentage divides by it
        // (recomputing total() per row made this O(stages²)).
        let total = self.total();
        let denom = total.max(1e-12);
        let mut out = String::new();
        for (s, t) in &self.entries {
            out.push_str(&format!("{s:width$}  {t:10.4}s  ({:5.1}%)\n", 100.0 * t / denom));
        }
        out.push_str(&format!("{:width$}  {total:10.4}s\n", "TOTAL"));
        out
    }

    /// The one JSON serialization of stage timings, shared by the CLI's
    /// `--json-out` and the trace exporter: stage → seconds plus a
    /// `"total"` key.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut pairs: Vec<(&str, Json)> =
            self.entries.iter().map(|(s, t)| (s.as_str(), Json::Num(*t))).collect();
        pairs.push(("total", Json::Num(self.total())));
        Json::obj(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        let a = t.elapsed();
        let b = t.elapsed();
        assert!(b >= a);
        assert!(a >= 0.0);
    }

    #[test]
    fn breakdown_accumulates() {
        let mut b = Breakdown::new();
        b.add("tmfg", 1.0);
        b.add("apsp", 2.0);
        b.add("tmfg", 0.5);
        assert_eq!(b.get("tmfg"), Some(1.5));
        assert!((b.total() - 3.5).abs() < 1e-12);
        assert_eq!(b.stages().len(), 2);
    }

    #[test]
    fn breakdown_merge_and_table() {
        let mut a = Breakdown::new();
        a.add("x", 1.0);
        let mut b = Breakdown::new();
        b.add("x", 1.0);
        b.add("y", 2.0);
        a.merge(&b);
        assert_eq!(a.get("x"), Some(2.0));
        assert_eq!(a.get("y"), Some(2.0));
        let t = a.table();
        assert!(t.contains("TOTAL"));
        assert!(t.contains('x'));
    }

    #[test]
    fn breakdown_to_json_includes_stages_and_total() {
        let mut b = Breakdown::new();
        b.add("similarity", 1.25);
        b.add("tmfg", 0.75);
        let j = b.to_json();
        assert_eq!(j.get("similarity").as_f64(), Some(1.25));
        assert_eq!(j.get("tmfg").as_f64(), Some(0.75));
        assert_eq!(j.get("total").as_f64(), Some(2.0));
        // Serializes cleanly (the --json-out / trace-export path).
        let text = j.to_string();
        assert!(text.contains("\"total\""));
    }
}
