//! Deterministic pseudo-random number generation (SplitMix64 seeding +
//! Xoshiro256**), plus Gaussian sampling. Replaces the `rand` crate, which
//! is unavailable offline. All dataset generators and property tests seed
//! explicitly so every run is reproducible.

/// SplitMix64 — used to expand a single `u64` seed into a full
/// Xoshiro256** state (the construction recommended by the Xoshiro
/// authors).
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Xoshiro256** PRNG. Fast, high quality, 2^256-1 period.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Gaussian from the Box–Muller pair.
    gauss_spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits → exactly representable uniform double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n). Uses rejection to avoid modulo bias.
    pub fn next_below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (with spare caching).
    pub fn next_gaussian(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.next_below(i + 1);
            v.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k ≤ n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // Partial Fisher–Yates over an index map for small k, else full shuffle.
        if k * 4 < n {
            let mut chosen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let v = self.next_below(n);
                if chosen.insert(v) {
                    out.push(v);
                }
            }
            out
        } else {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx
        }
    }

    /// Derive an independent stream (for per-thread / per-row generators).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn next_below_unbiased_small() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.next_below(5)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        for &(n, k) in &[(100, 5), (100, 90), (10, 10)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }
}
