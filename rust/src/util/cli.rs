//! Tiny command-line argument parser (replaces clap, unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments, with typed accessors and defaults. Each binary declares its
//! own usage string; unknown flags are an error so typos fail fast.

use crate::error::TmfgError;
use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
    known: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list. `known` lists accepted flag names
    /// (without the `--`); pass an empty list to accept anything.
    pub fn parse_from<I: IntoIterator<Item = String>>(
        tokens: I,
        known: &[&str],
    ) -> Result<Args, TmfgError> {
        let mut a = Args {
            known: known.iter().map(|s| s.to_string()).collect(),
            ..Default::default()
        };
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                if !a.known.is_empty() && !a.known.contains(&key) {
                    return Err(TmfgError::invalid(format!("unknown flag --{key}")));
                }
                let val = match inline_val {
                    Some(v) => v,
                    None => {
                        // Consume the next token as the value unless it is
                        // another flag — then this is a boolean flag.
                        match it.peek() {
                            Some(next) if !next.starts_with("--") => it.next().unwrap(),
                            _ => "true".to_string(),
                        }
                    }
                };
                a.flags.insert(key, val);
            } else {
                a.positional.push(tok);
            }
        }
        Ok(a)
    }

    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn parse(known: &[&str]) -> Result<Args, TmfgError> {
        Self::parse_from(std::env::args().skip(1), known)
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// First positional token — the subcommand in `tmfg <cmd> [flags]`.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        match self.flags.get(key).map(|s| s.as_str()) {
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(_) => default,
            None => default,
        }
    }

    /// Comma-separated list of usizes, e.g. `--threads 1,2,4,8`.
    pub fn get_usize_list(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.flags.get(key) {
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .filter_map(|s| s.trim().parse().ok())
                .collect(),
            None => default.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn basic_flags() {
        let a = Args::parse_from(toks("run --algo heap --threads 8 --verbose"), &[]).unwrap();
        assert_eq!(a.positional, vec!["run"]);
        assert_eq!(a.subcommand(), Some("run"));
        assert_eq!(Args::parse_from(toks("--x 1"), &[]).unwrap().subcommand(), None);
        assert_eq!(a.get_str("algo", "x"), "heap");
        assert_eq!(a.get_usize("threads", 1), 8);
        assert!(a.get_bool("verbose", false));
        assert!(!a.get_bool("quiet", false));
    }

    #[test]
    fn equals_form_and_lists() {
        let a = Args::parse_from(toks("--scale=0.5 --threads=1,2,4"), &[]).unwrap();
        assert_eq!(a.get_f64("scale", 1.0), 0.5);
        assert_eq!(a.get_usize_list("threads", &[]), vec![1, 2, 4]);
        assert_eq!(a.get_usize_list("missing", &[7]), vec![7]);
    }

    #[test]
    fn bool_flag_before_flag() {
        let a = Args::parse_from(toks("--approx --out x.csv"), &[]).unwrap();
        assert!(a.get_bool("approx", false));
        assert_eq!(a.get_str("out", ""), "x.csv");
    }

    #[test]
    fn unknown_flag_rejected() {
        let r = Args::parse_from(toks("--bogus 1"), &["real"]);
        assert!(r.is_err());
        let r2 = Args::parse_from(toks("--real 1"), &["real"]);
        assert!(r2.is_ok());
    }

    #[test]
    fn defaults() {
        let a = Args::parse_from(toks(""), &[]).unwrap();
        assert_eq!(a.get_usize("n", 42), 42);
        assert_eq!(a.get_str("s", "d"), "d");
        assert_eq!(a.get_f64("f", 1.5), 1.5);
        assert!(a.opt_str("s").is_none());
    }
}
