//! Small self-contained substrates that replace crates unavailable in the
//! offline build environment (serde, clap, rand, criterion).

pub mod bench;
pub mod cli;
pub mod json;
pub mod rng;
pub mod timer;
