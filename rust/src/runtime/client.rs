//! PJRT client wrapper: compile HLO-text artifacts once, cache the loaded
//! executables keyed by artifact path.

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// A lazily-compiling executable cache over one PJRT CPU client.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, Arc<xla::PjRtLoadedExecutable>>>,
}

impl XlaRuntime {
    pub fn new() -> Result<XlaRuntime> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(XlaRuntime { client, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the HLO-text artifact at `path`.
    pub fn load(&self, path: &Path) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.lock().unwrap().get(path) {
            return Ok(e.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))?;
        let exe = Arc::new(exe);
        self.cache
            .lock()
            .unwrap()
            .insert(path.to_path_buf(), exe.clone());
        Ok(exe)
    }

    /// Execute a 1-input → tuple-output executable with a dense f32 input
    /// of shape `dims`, returning the tuple elements as f32 vectors.
    pub fn execute_f32(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        input: &[f32],
        dims: &[i64],
    ) -> Result<Vec<Vec<f32>>> {
        let lit = xla::Literal::vec1(input)
            .reshape(dims)
            .context("reshape input literal")?;
        let result = exe.execute::<xla::Literal>(&[lit]).context("execute")?;
        let out = result[0][0]
            .to_literal_sync()
            .context("fetch result literal")?;
        let parts = out.to_tuple().context("untuple result")?;
        parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().context("read f32 output"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    #[ignore = "requires the native PJRT/XLA runtime; vendor/xla is an offline stub"]
    fn client_starts() {
        let rt = XlaRuntime::new().unwrap();
        assert_eq!(rt.platform(), "cpu");
    }

    #[test]
    #[ignore = "requires the native PJRT/XLA runtime; vendor/xla is an offline stub"]
    fn load_caches() {
        let dir = artifacts_dir();
        let art = dir.join("corr_128x64.hlo.txt");
        if !art.exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = XlaRuntime::new().unwrap();
        let a = rt.load(&art).unwrap();
        let b = rt.load(&art).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    #[ignore = "requires the native PJRT/XLA runtime; vendor/xla is an offline stub"]
    fn load_missing_fails() {
        let rt = XlaRuntime::new().unwrap();
        assert!(rt.load(Path::new("/nonexistent.hlo.txt")).is_err());
    }
}
