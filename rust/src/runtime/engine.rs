//! The similarity engine: computes the n×n Pearson matrix either through
//! the AOT-compiled XLA artifact (padding the panel to the smallest shape
//! bucket, executing via PJRT, slicing the result) or through the native
//! Rust parallel path (fallback for shapes above the largest bucket, and
//! the baseline the XLA path is validated against).
//!
//! Padding scheme (proved sound in python/tests/test_model.py): extra
//! rows are zero (their correlations are sliced away); extra *columns* of
//! real rows are filled with the row's mean, which leaves the row mean and
//! centered norm unchanged so the real correlations are exact.

use super::client::XlaRuntime;
use super::manifest::Manifest;
use crate::data::corr::pearson_correlation;
use crate::data::matrix::Matrix;
use crate::parlay;
use anyhow::Result;
use std::path::Path;

/// Which compute path produced a result.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CorrPath {
    Xla,
    Native,
}

pub struct CorrEngine {
    runtime: Option<XlaRuntime>,
    manifest: Option<Manifest>,
    /// Force the native path even when a bucket fits.
    pub force_native: bool,
}

impl CorrEngine {
    /// Engine with the XLA path enabled from an artifacts directory.
    pub fn with_artifacts(dir: &Path) -> Result<CorrEngine> {
        let manifest = Manifest::load(dir)?;
        let runtime = XlaRuntime::new()?;
        Ok(CorrEngine {
            runtime: Some(runtime),
            manifest: Some(manifest),
            force_native: false,
        })
    }

    /// Native-only engine (no artifacts required).
    pub fn native_only() -> CorrEngine {
        CorrEngine { runtime: None, manifest: None, force_native: true }
    }

    /// Try the default artifacts dir; fall back to native-only.
    pub fn auto(dir: &Path) -> CorrEngine {
        match Self::with_artifacts(dir) {
            Ok(e) => e,
            Err(err) => {
                crate::log!(
                    warn,
                    "note: XLA artifacts unavailable ({err:#}); using native correlation path"
                );
                Self::native_only()
            }
        }
    }

    /// Compute the similarity matrix + row sums; reports which path ran.
    pub fn similarity(&self, x: &Matrix) -> Result<(Matrix, Vec<f64>, CorrPath)> {
        let (n, l) = (x.rows, x.cols);
        if !self.force_native {
            if let (Some(rt), Some(man)) = (&self.runtime, &self.manifest) {
                if let Some(bucket) = man.pick(n, l) {
                    let s = self.run_xla(rt, &bucket.file, x, bucket.n, bucket.l)?;
                    let rowsums = row_sums(&s);
                    return Ok((s, rowsums, CorrPath::Xla));
                }
            }
        }
        let s = pearson_correlation(x);
        let rowsums = row_sums(&s);
        Ok((s, rowsums, CorrPath::Native))
    }

    fn run_xla(
        &self,
        rt: &XlaRuntime,
        artifact: &Path,
        x: &Matrix,
        bn: usize,
        bl: usize,
    ) -> Result<Matrix> {
        let (n, l) = (x.rows, x.cols);
        let exe = rt.load(artifact)?;
        // Pad: rows 0..n get real data + mean-padding columns; rows n..bn zero.
        let mut padded = vec![0.0f32; bn * bl];
        {
            use crate::parlay::SendPtr;
            let pp = SendPtr(padded.as_mut_ptr());
            parlay::parallel_for(n, 8, |i| {
                let row = x.row(i);
                let mean =
                    (row.iter().map(|&v| v as f64).sum::<f64>() / l as f64) as f32;
                for (j, &v) in row.iter().enumerate() {
                    unsafe { pp.write(i * bl + j, v) };
                }
                for j in l..bl {
                    unsafe { pp.write(i * bl + j, mean) };
                }
            });
        }
        let outs = rt.execute_f32(&exe, &padded, &[bn as i64, bl as i64])?;
        anyhow::ensure!(outs.len() == 2, "expected (similarity, rowsums) tuple");
        let big = &outs[0];
        anyhow::ensure!(big.len() == bn * bn, "bad output size");
        // Slice the top-left n×n block.
        let mut s = Matrix::zeros(n, n);
        {
            use crate::parlay::SendPtr;
            let sp = SendPtr(s.data.as_mut_ptr());
            parlay::parallel_for(n, 16, |i| {
                for j in 0..n {
                    unsafe { sp.write(i * n + j, big[i * bn + j]) };
                }
            });
        }
        Ok(s)
    }
}

fn row_sums(s: &Matrix) -> Vec<f64> {
    parlay::par_map(s.rows, 8, |i| s.row(i).iter().map(|&v| v as f64).sum::<f64>())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    fn artifacts() -> std::path::PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn native_path_works() {
        let ds = SynthSpec::new("t", 30, 20, 3).generate(1);
        let e = CorrEngine::native_only();
        let (s, rowsums, path) = e.similarity(&ds.data).unwrap();
        assert_eq!(path, CorrPath::Native);
        assert_eq!(s.rows, 30);
        assert_eq!(rowsums.len(), 30);
        assert!(s.is_symmetric(1e-5));
    }

    #[test]
    fn xla_matches_native() {
        if !artifacts().join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        // Deliberately off-bucket shape to exercise padding + slicing.
        let ds = SynthSpec::new("t", 100, 46, 4).generate(2);
        let engine = CorrEngine::with_artifacts(&artifacts()).unwrap();
        let (sx, rx, path) = engine.similarity(&ds.data).unwrap();
        assert_eq!(path, CorrPath::Xla);
        let (sn, rn, _) = CorrEngine::native_only().similarity(&ds.data).unwrap();
        assert!(
            sx.max_abs_diff(&sn) < 1e-4,
            "XLA vs native mismatch: {}",
            sx.max_abs_diff(&sn)
        );
        for (a, b) in rx.iter().zip(&rn) {
            assert!((a - b).abs() < 1e-2, "{a} vs {b}");
        }
    }

    #[test]
    fn oversized_falls_back_to_native() {
        if !artifacts().join("manifest.json").exists() {
            return;
        }
        let engine = CorrEngine::with_artifacts(&artifacts()).unwrap();
        // L larger than the largest bucket forces the native path.
        let ds = SynthSpec::new("t", 16, 2048, 2).generate(3);
        let (_, _, path) = engine.similarity(&ds.data).unwrap();
        assert_eq!(path, CorrPath::Native);
    }
}
