//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//! Python never runs here — the artifacts are self-contained HLO text.

pub mod client;
pub mod engine;
pub mod manifest;

pub use engine::CorrEngine;
pub use manifest::Manifest;
