//! The artifact manifest written by `python -m compile.aot`.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

#[derive(Debug, Clone, PartialEq)]
pub struct Bucket {
    pub n: usize,
    pub l: usize,
    pub file: PathBuf,
    pub block_rows: usize,
    pub vmem_bytes_per_step: usize,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub buckets: Vec<Bucket>,
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts` first)", path.display()))?;
        let j = Json::parse(&text).with_context(|| format!("parse {}", path.display()))?;
        if j.get("version").as_usize() != Some(1) {
            bail!("unsupported manifest version");
        }
        if j.get("interchange").as_str() != Some("hlo-text") {
            bail!("unsupported interchange format");
        }
        let arts = j
            .get("artifacts")
            .as_arr()
            .context("manifest.artifacts missing")?;
        let mut buckets = Vec::with_capacity(arts.len());
        for a in arts {
            let file = dir.join(a.get("file").as_str().context("artifact.file")?);
            if !file.exists() {
                bail!("artifact file missing: {}", file.display());
            }
            buckets.push(Bucket {
                n: a.get("n").as_usize().context("artifact.n")?,
                l: a.get("l").as_usize().context("artifact.l")?,
                file,
                block_rows: a.get("block_rows").as_usize().unwrap_or(128),
                vmem_bytes_per_step: a.get("vmem_bytes_per_step").as_usize().unwrap_or(0),
            });
        }
        buckets.sort_by_key(|b| (b.n, b.l));
        if buckets.is_empty() {
            bail!("manifest has no artifacts");
        }
        Ok(Manifest { dir: dir.to_path_buf(), buckets })
    }

    /// Smallest bucket covering (n, l), if any.
    pub fn pick(&self, n: usize, l: usize) -> Option<&Bucket> {
        self.buckets
            .iter()
            .filter(|b| b.n >= n && b.l >= l)
            .min_by_key(|b| (b.n * b.l, b.n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_manifest(entries: &[(usize, usize)]) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "tmfg_manifest_{}_{}",
            std::process::id(),
            entries.len()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let arts: Vec<String> = entries
            .iter()
            .map(|(n, l)| {
                let f = format!("corr_{n}x{l}.hlo.txt");
                std::fs::write(dir.join(&f), "HloModule fake").unwrap();
                format!(
                    r#"{{"n":{n},"l":{l},"file":"{f}","block_rows":128,"vmem_bytes_per_step":1,"outputs":["similarity","rowsums"]}}"#
                )
            })
            .collect();
        let manifest = format!(
            r#"{{"version":1,"interchange":"hlo-text","model":"similarity_graph_inputs","dtype":"f32","artifacts":[{}]}}"#,
            arts.join(",")
        );
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        dir
    }

    #[test]
    fn loads_and_picks() {
        let dir = tmp_manifest(&[(128, 64), (256, 128), (1024, 512)]);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.buckets.len(), 3);
        assert_eq!(m.pick(100, 50).unwrap().n, 128);
        assert_eq!(m.pick(128, 64).unwrap().n, 128);
        assert_eq!(m.pick(129, 64).unwrap().n, 256);
        assert_eq!(m.pick(300, 500).unwrap().n, 1024);
        assert!(m.pick(5000, 64).is_none());
    }

    #[test]
    fn rejects_missing_file() {
        let dir = tmp_manifest(&[(64, 32)]);
        std::fs::remove_file(dir.join("corr_64x32.hlo.txt")).unwrap();
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn rejects_missing_dir() {
        assert!(Manifest::load(Path::new("/nonexistent/xyz")).is_err());
    }

    #[test]
    fn real_artifacts_if_present() {
        // When `make artifacts` has run, validate the real manifest too.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(!m.buckets.is_empty());
            assert!(m.pick(100, 60).is_some());
        }
    }
}
