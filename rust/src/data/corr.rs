//! Native parallel Pearson correlation and correlation→distance transforms.
//!
//! This is the Rust fallback/baseline for the AOT-compiled XLA path in
//! `runtime::engine` (which runs the same computation as a Pallas kernel
//! lowered to HLO). Both paths implement S[i,j] = pearson(X[i,:], X[j,:]).
//! The paper assumes the n×n correlation matrix as the pipeline input; we
//! treat its computation as the dense L1/L2 hot-spot (see DESIGN.md §2).

use super::matrix::Matrix;
use crate::parlay::{self, SendPtr};

/// Standardize each row to zero mean and unit ℓ2 norm. Rows with ~zero
/// variance become all-zero (their correlations are defined as 0).
pub fn standardize_rows(x: &Matrix) -> Matrix {
    let (n, l) = (x.rows, x.cols);
    let mut z = Matrix::zeros(n, l);
    let zp = SendPtr(z.data.as_mut_ptr());
    parlay::parallel_for(n, 1, |i| {
        let row = x.row(i);
        let mean = row.iter().map(|&v| v as f64).sum::<f64>() / l as f64;
        let mut ss = 0.0f64;
        for &v in row {
            let d = v as f64 - mean;
            ss += d * d;
        }
        let norm = ss.sqrt();
        let inv = if norm > 1e-12 { 1.0 / norm } else { 0.0 };
        for (j, &v) in row.iter().enumerate() {
            // SAFETY: row i is written only by iteration i.
            unsafe { zp.write(i * l + j, ((v as f64 - mean) * inv) as f32) };
        }
    });
    z
}

/// Pearson correlation matrix: S = Ẑ Ẑᵀ with Ẑ = standardized rows.
/// Exploits symmetry (computes the upper triangle, mirrors it) and
/// parallelizes across rows. Inner kernel is a blocked dot product that
/// LLVM auto-vectorizes.
pub fn pearson_correlation(x: &Matrix) -> Matrix {
    let n = x.rows;
    let z = standardize_rows(x);
    let l = z.cols;
    let mut s = Matrix::zeros(n, n);
    let sp = SendPtr(s.data.as_mut_ptr());
    let zref = &z;
    // Row-parallel upper triangle. Chunked so each task does similar work:
    // pair row i with row n-1-i (triangle balancing).
    parlay::parallel_for(n.div_ceil(2), 1, |half| {
        for &i in &[half, n - 1 - half] {
            if half == n - 1 - half && i != half {
                continue;
            }
            let zi = zref.row(i);
            for j in i..n {
                let zj = zref.row(j);
                let mut acc = 0.0f32;
                // simple blocked dot; LLVM vectorizes this loop
                let mut k = 0;
                let mut acc4 = [0.0f32; 4];
                while k + 4 <= l {
                    acc4[0] += zi[k] * zj[k];
                    acc4[1] += zi[k + 1] * zj[k + 1];
                    acc4[2] += zi[k + 2] * zj[k + 2];
                    acc4[3] += zi[k + 3] * zj[k + 3];
                    k += 4;
                }
                while k < l {
                    acc += zi[k] * zj[k];
                    k += 1;
                }
                let v = (acc + acc4[0] + acc4[1] + acc4[2] + acc4[3]).clamp(-1.0, 1.0);
                let v = if i == j { 1.0 } else { v };
                // SAFETY: (i,j) and (j,i) are written only by index pair (i,j),
                // which belongs to exactly one `half` iteration.
                unsafe {
                    sp.write(i * n + j, v);
                    sp.write(j * n + i, v);
                }
            }
        }
    });
    s
}

/// Two-pass f64 Pearson reference: the row-major n×n correlation matrix
/// with f64 accumulation end to end (centered rows, then normalized dot
/// products). The f32 output of [`pearson_correlation`] carries ~1e-5
/// rounding, which is too coarse to validate the streaming subsystem's
/// incremental sufficient-statistics path — that property test compares
/// against this function at 1e-10 instead.
pub fn pearson_correlation_f64(x: &Matrix) -> Vec<f64> {
    let (n, l) = (x.rows, x.cols);
    let centered: Vec<Vec<f64>> = parlay::par_map(n, 1, |i| {
        let row = x.row(i);
        let mean = row.iter().map(|&v| v as f64).sum::<f64>() / l.max(1) as f64;
        row.iter().map(|&v| v as f64 - mean).collect()
    });
    let sqnorms: Vec<f64> = parlay::par_map(n, 8, |i| centered[i].iter().map(|d| d * d).sum());
    let mut s = vec![0.0f64; n * n];
    let sp = SendPtr(s.as_mut_ptr());
    let (cref, nref) = (&centered, &sqnorms);
    parlay::par_symmetric_rows(n, |i| {
        for j in i..n {
            let v = if i == j {
                1.0
            } else if nref[i] <= 1e-12 || nref[j] <= 1e-12 {
                0.0
            } else {
                let dot: f64 = cref[i].iter().zip(&cref[j]).map(|(a, b)| a * b).sum();
                (dot / (nref[i] * nref[j]).sqrt()).clamp(-1.0, 1.0)
            };
            // SAFETY: par_symmetric_rows visits each row i exactly once,
            // so the (i,j≥i)/(j,i) cell pairs are written by one task.
            unsafe {
                sp.write(i * n + j, v);
                if j != i {
                    sp.write(j * n + i, v);
                }
            }
        }
    });
    s
}

/// The standard correlation→metric transform used throughout the
/// PMFG/TMFG/DBHT literature: d(i,j) = sqrt(2·(1 − ρ(i,j))) ∈ [0, 2].
#[inline]
pub fn corr_to_distance(rho: f32) -> f32 {
    (2.0 * (1.0 - rho.clamp(-1.0, 1.0))).max(0.0).sqrt()
}

/// Elementwise distance matrix from a similarity (correlation) matrix.
pub fn distance_matrix(s: &Matrix) -> Matrix {
    let mut d = Matrix::zeros(s.rows, s.cols);
    let dp = SendPtr(d.data.as_mut_ptr());
    let n = s.rows * s.cols;
    let sref = &s.data;
    parlay::parallel_for_chunks(n, 4096, |a, b| {
        for i in a..b {
            unsafe { dp.write(i, corr_to_distance(sref[i])) };
        }
    });
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_pearson(x: &Matrix, i: usize, j: usize) -> f64 {
        let (a, b) = (x.row(i), x.row(j));
        let n = a.len() as f64;
        let ma = a.iter().map(|&v| v as f64).sum::<f64>() / n;
        let mb = b.iter().map(|&v| v as f64).sum::<f64>() / n;
        let mut num = 0.0;
        let mut da = 0.0;
        let mut db = 0.0;
        for k in 0..a.len() {
            let xa = a[k] as f64 - ma;
            let xb = b[k] as f64 - mb;
            num += xa * xb;
            da += xa * xa;
            db += xb * xb;
        }
        num / (da.sqrt() * db.sqrt()).max(1e-30)
    }

    #[test]
    fn standardize_properties() {
        let mut r = Rng::new(1);
        let x = Matrix::from_vec(5, 50, (0..250).map(|_| r.next_f32() * 10.0 - 5.0).collect());
        let z = standardize_rows(&x);
        for i in 0..5 {
            let row = z.row(i);
            let mean: f64 = row.iter().map(|&v| v as f64).sum::<f64>() / 50.0;
            let norm: f64 = row.iter().map(|&v| (v as f64).powi(2)).sum::<f64>();
            assert!(mean.abs() < 1e-6, "mean={mean}");
            assert!((norm - 1.0).abs() < 1e-5, "norm={norm}");
        }
    }

    #[test]
    fn standardize_constant_row_is_zero() {
        let x = Matrix::from_vec(1, 10, vec![3.0; 10]);
        let z = standardize_rows(&x);
        assert!(z.row(0).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn correlation_matches_naive() {
        let mut r = Rng::new(2);
        let n = 40;
        let l = 64;
        let x = Matrix::from_vec(n, l, (0..n * l).map(|_| r.next_gaussian() as f32).collect());
        let s = pearson_correlation(&x);
        assert!(s.is_symmetric(1e-6));
        for i in 0..n {
            assert!((s.at(i, i) - 1.0).abs() < 1e-6);
            for j in (i + 1)..n {
                let expect = naive_pearson(&x, i, j);
                assert!(
                    (s.at(i, j) as f64 - expect).abs() < 1e-4,
                    "({i},{j}): {} vs {expect}",
                    s.at(i, j)
                );
            }
        }
    }

    #[test]
    fn correlation_perfect_and_anti() {
        // row1 = 2*row0 + 1 (ρ=1); row2 = -row0 (ρ=-1)
        let base: Vec<f32> = (0..32).map(|i| (i as f32).sin()).collect();
        let mut data = base.clone();
        data.extend(base.iter().map(|&v| 2.0 * v + 1.0));
        data.extend(base.iter().map(|&v| -v));
        let x = Matrix::from_vec(3, 32, data);
        let s = pearson_correlation(&x);
        assert!((s.at(0, 1) - 1.0).abs() < 1e-5);
        assert!((s.at(0, 2) + 1.0).abs() < 1e-5);
    }

    #[test]
    fn f64_reference_matches_f32_path() {
        let mut r = Rng::new(5);
        let n = 30;
        let l = 48;
        let x = Matrix::from_vec(n, l, (0..n * l).map(|_| r.next_gaussian() as f32).collect());
        let s32 = pearson_correlation(&x);
        let s64 = pearson_correlation_f64(&x);
        for i in 0..n {
            assert_eq!(s64[i * n + i], 1.0);
            for j in 0..n {
                assert!(
                    (s32.at(i, j) as f64 - s64[i * n + j]).abs() < 1e-4,
                    "({i},{j}): {} vs {}",
                    s32.at(i, j),
                    s64[i * n + j]
                );
            }
        }
        // constant row convention matches (0 off-diagonal, 1 on)
        let c = Matrix::from_vec(2, 8, vec![3.0; 8].into_iter().chain((0..8).map(|t| t as f32)).collect());
        let sc = pearson_correlation_f64(&c);
        assert_eq!(sc, vec![1.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn distance_transform_metricish() {
        assert!((corr_to_distance(1.0) - 0.0).abs() < 1e-7);
        assert!((corr_to_distance(-1.0) - 2.0).abs() < 1e-6);
        assert!((corr_to_distance(0.0) - std::f32::consts::SQRT_2).abs() < 1e-6);
        // monotone decreasing in rho
        let mut prev = f32::INFINITY;
        for k in 0..=20 {
            let rho = -1.0 + 0.1 * k as f32;
            let d = corr_to_distance(rho);
            assert!(d <= prev);
            prev = d;
        }
    }

    #[test]
    fn distance_matrix_elementwise() {
        let s = Matrix::from_vec(2, 2, vec![1.0, 0.5, 0.5, 1.0]);
        let d = distance_matrix(&s);
        assert!((d.at(0, 0)).abs() < 1e-7);
        assert!((d.at(0, 1) - 1.0).abs() < 1e-6);
    }
}
