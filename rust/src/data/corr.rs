//! Native parallel Pearson correlation and correlation→distance transforms.
//!
//! This is the Rust fallback/baseline for the AOT-compiled XLA path in
//! `runtime::engine` (which runs the same computation as a Pallas kernel
//! lowered to HLO). Both paths implement S[i,j] = pearson(X[i,:], X[j,:]).
//! The paper assumes the n×n correlation matrix as the pipeline input; we
//! treat its computation as the dense L1/L2 hot-spot (see DESIGN.md §2).

use super::matrix::Matrix;
use crate::parlay::{self, SendPtr};

/// Precision policy of the shared Pearson core: the element type the
/// standardized rows are stored at and the width the dot products are
/// accumulated at. The f32 and f64 correlation paths are the same
/// algorithm — standardize every row to zero mean / unit ℓ2 norm, then
/// S = Ẑ Ẑᵀ over the symmetric upper triangle — differing only in this
/// policy, so both run through one generic core
/// (property-tested to agree within 1e-5 in `rust/tests/properties.rs`).
pub trait CorrScalar: Copy + Send + Sync + 'static {
    const ONE: Self;
    fn from_f64(v: f64) -> Self;
    /// Dot product of two equal-length standardized rows, accumulated at
    /// the scalar's native width.
    fn dot(a: &[Self], b: &[Self]) -> Self;
    fn clamp_unit(self) -> Self;
    /// Is a row with this sum of squared deviations degenerate (treated
    /// as constant, correlations defined as 0)? Each precision keeps its
    /// historical cutoff: the f32 path tests the ℓ2 norm against 1e-12,
    /// the f64 reference tests the squared norm against 1e-12 — the same
    /// statistic and threshold as the streaming window's `VAR_EPS`, so
    /// the 1e-10 agreement contract with `stream::window` holds on
    /// near-constant series too.
    fn degenerate_row(ss: f64) -> bool;
}

impl CorrScalar for f32 {
    const ONE: f32 = 1.0;

    #[inline]
    fn from_f64(v: f64) -> f32 {
        v as f32
    }

    /// 4-accumulator blocked dot that LLVM auto-vectorizes (the dense
    /// L1/L2 hot-spot kernel).
    #[inline]
    fn dot(a: &[f32], b: &[f32]) -> f32 {
        let l = a.len();
        let mut acc = 0.0f32;
        let mut acc4 = [0.0f32; 4];
        let mut k = 0;
        while k + 4 <= l {
            acc4[0] += a[k] * b[k];
            acc4[1] += a[k + 1] * b[k + 1];
            acc4[2] += a[k + 2] * b[k + 2];
            acc4[3] += a[k + 3] * b[k + 3];
            k += 4;
        }
        while k < l {
            acc += a[k] * b[k];
            k += 1;
        }
        acc + acc4[0] + acc4[1] + acc4[2] + acc4[3]
    }

    #[inline]
    fn clamp_unit(self) -> f32 {
        self.clamp(-1.0, 1.0)
    }

    #[inline]
    fn degenerate_row(ss: f64) -> bool {
        ss.sqrt() <= 1e-12
    }
}

impl CorrScalar for f64 {
    const ONE: f64 = 1.0;

    #[inline]
    fn from_f64(v: f64) -> f64 {
        v
    }

    /// Plain sequential f64 fold — the reference accumulation the
    /// streaming property tests compare against at 1e-10.
    #[inline]
    fn dot(a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| x * y).sum()
    }

    #[inline]
    fn clamp_unit(self) -> f64 {
        self.clamp(-1.0, 1.0)
    }

    #[inline]
    fn degenerate_row(ss: f64) -> bool {
        ss <= 1e-12
    }
}

/// The shared standardization core: each row to zero mean and unit ℓ2
/// norm (means/norms always computed in f64, stored at `T`). Rows with
/// ~zero variance become all-zero — their correlations are defined as 0.
pub fn standardize_rows_generic<T: CorrScalar>(x: &Matrix) -> Vec<T> {
    let (n, l) = (x.rows, x.cols);
    let mut z: Vec<T> = Vec::with_capacity(n * l);
    let zp = SendPtr(z.as_mut_ptr());
    parlay::parallel_for(n, 1, |i| {
        let row = x.row(i);
        let mean = row.iter().map(|&v| v as f64).sum::<f64>() / l.max(1) as f64;
        let mut ss = 0.0f64;
        for &v in row {
            let d = v as f64 - mean;
            ss += d * d;
        }
        let inv = if T::degenerate_row(ss) { 0.0 } else { 1.0 / ss.sqrt() };
        for (j, &v) in row.iter().enumerate() {
            // SAFETY: row i is written only by iteration i.
            unsafe { zp.write(i * l + j, T::from_f64((v as f64 - mean) * inv)) };
        }
    });
    unsafe { z.set_len(n * l) };
    z
}

/// The shared accumulation core: the row-major n×n Gram matrix of the
/// standardized rows, symmetric (upper triangle computed, mirrored) with
/// a forced unit diagonal, parallelized with triangle balancing.
fn correlation_from_standardized<T: CorrScalar>(z: &[T], n: usize, l: usize) -> Vec<T> {
    let mut s: Vec<T> = Vec::with_capacity(n * n);
    let sp = SendPtr(s.as_mut_ptr());
    parlay::par_symmetric_rows(n, |i| {
        let zi = &z[i * l..(i + 1) * l];
        for j in i..n {
            let v = if i == j {
                T::ONE
            } else {
                T::dot(zi, &z[j * l..(j + 1) * l]).clamp_unit()
            };
            // SAFETY: par_symmetric_rows visits each row i exactly once,
            // so the (i,j≥i)/(j,i) cell pairs are written by one task.
            unsafe {
                sp.write(i * n + j, v);
                sp.write(j * n + i, v);
            }
        }
    });
    unsafe { s.set_len(n * n) };
    s
}

/// Which micro-kernel the f32 Gram accumulation dispatches to on this
/// host (runtime CPU detection). The scalar core is both the portable
/// fallback and the reference the SIMD path is property-tested against.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GramKernel {
    /// The generic [`CorrScalar`] core (4-accumulator auto-vectorized dot).
    Scalar,
    /// Cache-blocked explicit AVX2+FMA kernel (x86_64 only).
    Avx2,
}

/// Rows per block of the cache-blocked kernel: 4 standardized rows stay
/// register/L1-resident while every `j` row is streamed past them once,
/// so each streamed load feeds 4 dot products instead of 1 — the O(n²·l)
/// kernel's read traffic drops ~4× before the 8-lane FMAs even start.
/// (The AVX2 micro-kernel hard-codes this width; change both together.)
const GRAM_BLOCK_ROWS: usize = 4;

/// Runtime kernel selection for the f32 Gram path.
pub fn gram_kernel() -> GramKernel {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            return GramKernel::Avx2;
        }
    }
    GramKernel::Scalar
}

/// Kernel name for logs and bench-artifact metadata: "avx2" or "scalar".
pub fn gram_kernel_name() -> &'static str {
    match gram_kernel() {
        GramKernel::Avx2 => "avx2",
        GramKernel::Scalar => "scalar",
    }
}

/// f32 Gram dispatch: explicit SIMD where the host supports it, the
/// generic scalar core otherwise. Both kernels write each (i, j≥i) cell
/// (plus its mirror) from exactly one task with a fixed accumulation
/// order, so output is byte-identical across thread counts either way —
/// the invariant the determinism suites pin. The two kernels differ from
/// *each other* only by float-association rounding (~1e-6 on unit rows;
/// property-tested in `rust/tests/properties.rs`).
fn gram_f32(z: &[f32], n: usize, l: usize) -> Vec<f32> {
    #[cfg(target_arch = "x86_64")]
    {
        if gram_kernel() == GramKernel::Avx2 {
            let mut s: Vec<f32> = Vec::with_capacity(n * n);
            let sp = SendPtr(s.as_mut_ptr());
            parlay::par_symmetric_blocks(n, GRAM_BLOCK_ROWS, |lo, hi| {
                // SAFETY: AVX2+FMA presence verified above;
                // par_symmetric_blocks hands every row to exactly one
                // task, so the (i, j≥i) cells plus (j, i) mirrors written
                // per call are disjoint across calls.
                unsafe { avx2::gram_block(z, n, l, lo, hi, sp) };
            });
            unsafe { s.set_len(n * n) };
            return s;
        }
    }
    correlation_from_standardized::<f32>(z, n, l)
}

/// AVX2+FMA micro-kernels for the blocked f32 Gram accumulation — the
/// §4.3-style manual vectorization of the dense L1/L2 hot spot. All
/// horizontal reductions use a fixed lane order (store + left-to-right
/// fold), so for a given host the result is a pure function of the
/// inputs: reproducible run-to-run and across thread counts.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::GRAM_BLOCK_ROWS;
    use crate::parlay::SendPtr;
    use std::arch::x86_64::*;

    /// Fixed-order horizontal sum of 8 lanes.
    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    unsafe fn hsum(v: __m256) -> f32 {
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), v);
        let mut acc = 0.0f32;
        for &x in &lanes {
            acc += x;
        }
        acc
    }

    /// One dot product over length `l`, two 8-lane FMA accumulator chains.
    ///
    /// # Safety
    /// `a` and `b` must be valid for reads of `l` f32s; AVX2+FMA must be
    /// available.
    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    unsafe fn dot1(a: *const f32, b: *const f32, l: usize) -> f32 {
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut k = 0usize;
        while k + 16 <= l {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(a.add(k)), _mm256_loadu_ps(b.add(k)), acc0);
            acc1 = _mm256_fmadd_ps(
                _mm256_loadu_ps(a.add(k + 8)),
                _mm256_loadu_ps(b.add(k + 8)),
                acc1,
            );
            k += 16;
        }
        if k + 8 <= l {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(a.add(k)), _mm256_loadu_ps(b.add(k)), acc0);
            k += 8;
        }
        let mut out = hsum(_mm256_add_ps(acc0, acc1));
        while k < l {
            out += *a.add(k) * *b.add(k);
            k += 1;
        }
        out
    }

    /// Four dot products sharing every load of `b` — the register
    /// blocking that makes the Gram kernel compute-bound.
    ///
    /// # Safety
    /// All four `a` pointers and `b` must be valid for reads of `l`
    /// f32s; AVX2+FMA must be available.
    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    unsafe fn dot4(a: [*const f32; 4], b: *const f32, l: usize) -> [f32; 4] {
        let mut acc = [_mm256_setzero_ps(); 4];
        let mut k = 0usize;
        while k + 8 <= l {
            let vb = _mm256_loadu_ps(b.add(k));
            acc[0] = _mm256_fmadd_ps(_mm256_loadu_ps(a[0].add(k)), vb, acc[0]);
            acc[1] = _mm256_fmadd_ps(_mm256_loadu_ps(a[1].add(k)), vb, acc[1]);
            acc[2] = _mm256_fmadd_ps(_mm256_loadu_ps(a[2].add(k)), vb, acc[2]);
            acc[3] = _mm256_fmadd_ps(_mm256_loadu_ps(a[3].add(k)), vb, acc[3]);
            k += 8;
        }
        let mut out = [hsum(acc[0]), hsum(acc[1]), hsum(acc[2]), hsum(acc[3])];
        while k < l {
            let vb = *b.add(k);
            for r in 0..4 {
                out[r] += *a[r].add(k) * vb;
            }
            k += 1;
        }
        out
    }

    /// Fill rows `[lo, hi)` of the n×n Gram matrix (upper-triangle cells
    /// plus their mirrors, forced unit diagonal, values clamped to
    /// [−1, 1] exactly like the scalar core).
    ///
    /// # Safety
    /// AVX2+FMA must be available; `z` must hold `n * l` f32s;
    /// `lo < hi <= n`; no other task may write these rows' cells or
    /// their mirrors concurrently (guaranteed by `par_symmetric_blocks`).
    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    pub unsafe fn gram_block(
        z: &[f32],
        n: usize,
        l: usize,
        lo: usize,
        hi: usize,
        sp: SendPtr<f32>,
    ) {
        debug_assert!(z.len() == n * l && lo < hi && hi <= n);
        // SAFETY (closure body): i < n throughout, so i * l + l <= z.len().
        // A closure does not inherit the surrounding unsafe-fn context,
        // hence the explicit block.
        let row = |i: usize| unsafe { z.as_ptr().add(i * l) };
        // Diagonal and the small within-block triangle.
        for i in lo..hi {
            sp.write(i * n + i, 1.0);
            for j in (i + 1)..hi {
                let v = dot1(row(i), row(j), l).clamp(-1.0, 1.0);
                sp.write(i * n + j, v);
                sp.write(j * n + i, v);
            }
        }
        // Columns past the block: the 4-row kernel when the block is
        // full, the single-row kernel for the ragged tail block.
        if hi - lo == GRAM_BLOCK_ROWS {
            let a = [row(lo), row(lo + 1), row(lo + 2), row(lo + 3)];
            for j in hi..n {
                let d = dot4(a, row(j), l);
                for (r, &raw) in d.iter().enumerate() {
                    let i = lo + r;
                    let v = raw.clamp(-1.0, 1.0);
                    sp.write(i * n + j, v);
                    sp.write(j * n + i, v);
                }
            }
        } else {
            for i in lo..hi {
                for j in hi..n {
                    let v = dot1(row(i), row(j), l).clamp(-1.0, 1.0);
                    sp.write(i * n + j, v);
                    sp.write(j * n + i, v);
                }
            }
        }
    }
}

/// Standardize each row to zero mean and unit ℓ2 norm (f32 storage).
/// Rows with ~zero variance become all-zero (their correlations are
/// defined as 0).
pub fn standardize_rows(x: &Matrix) -> Matrix {
    Matrix { rows: x.rows, cols: x.cols, data: standardize_rows_generic::<f32>(x) }
}

/// [`standardize_rows`] in place — the large-panel companion: callers
/// that own the panel and no longer need the raw values pay zero extra
/// allocation instead of a second n·L copy. Bit-identical to the
/// out-of-place f32 path (same f64 statistics, same per-row fold).
pub fn standardize_rows_inplace(x: &mut Matrix) {
    let (n, l) = (x.rows, x.cols);
    let p = SendPtr(x.data.as_mut_ptr());
    parlay::parallel_for(n, 1, |i| {
        // SAFETY: row i is read and written only by iteration i; the
        // read-only stats slice is dropped before the writes begin.
        let (mean, ss) = {
            let row = unsafe { std::slice::from_raw_parts(p.0.add(i * l), l) };
            let mean = row.iter().map(|&v| v as f64).sum::<f64>() / l.max(1) as f64;
            let mut ss = 0.0f64;
            for &v in row {
                let d = v as f64 - mean;
                ss += d * d;
            }
            (mean, ss)
        };
        let inv = if <f32 as CorrScalar>::degenerate_row(ss) { 0.0 } else { 1.0 / ss.sqrt() };
        for j in 0..l {
            unsafe {
                let v = *p.0.add(i * l + j) as f64;
                p.write(i * l + j, ((v - mean) * inv) as f32);
            }
        }
    });
}

/// Pearson correlation matrix: S = Ẑ Ẑᵀ with Ẑ = standardized rows, f32
/// storage and accumulation throughout (the production path). The Gram
/// accumulation dispatches per-host ([`gram_kernel`]): the cache-blocked
/// explicit AVX2+FMA kernel on capable x86_64, the generic scalar core
/// everywhere else.
pub fn pearson_correlation(x: &Matrix) -> Matrix {
    let n = x.rows;
    let z = standardize_rows_generic::<f32>(x);
    Matrix { rows: n, cols: n, data: gram_f32(&z, n, x.cols) }
}

/// [`pearson_correlation`] with the portable scalar Gram core forced —
/// the ablation/reference entry point the SIMD property tests and the
/// `corr_kernel_scalar` bench scenarios compare against.
pub fn pearson_correlation_scalar(x: &Matrix) -> Matrix {
    let n = x.rows;
    let z = standardize_rows_generic::<f32>(x);
    Matrix { rows: n, cols: n, data: correlation_from_standardized::<f32>(&z, n, x.cols) }
}

/// f64 Pearson reference: the same standardize→Gram core as
/// [`pearson_correlation`] run entirely at f64. The f32 path carries
/// ~1e-5 rounding, which is too coarse to validate the streaming
/// subsystem's incremental sufficient-statistics path — that property
/// test compares against this function at 1e-10 instead.
pub fn pearson_correlation_f64(x: &Matrix) -> Vec<f64> {
    let z = standardize_rows_generic::<f64>(x);
    correlation_from_standardized(&z, x.rows, x.cols)
}

/// The standard correlation→metric transform used throughout the
/// PMFG/TMFG/DBHT literature: d(i,j) = sqrt(2·(1 − ρ(i,j))) ∈ [0, 2].
#[inline]
pub fn corr_to_distance(rho: f32) -> f32 {
    (2.0 * (1.0 - rho.clamp(-1.0, 1.0))).max(0.0).sqrt()
}

/// Elementwise distance matrix from a similarity (correlation) matrix.
pub fn distance_matrix(s: &Matrix) -> Matrix {
    let mut d = Matrix::zeros(s.rows, s.cols);
    let dp = SendPtr(d.data.as_mut_ptr());
    let n = s.rows * s.cols;
    let sref = &s.data;
    parlay::parallel_for_chunks(n, 4096, |a, b| {
        for i in a..b {
            unsafe { dp.write(i, corr_to_distance(sref[i])) };
        }
    });
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_pearson(x: &Matrix, i: usize, j: usize) -> f64 {
        let (a, b) = (x.row(i), x.row(j));
        let n = a.len() as f64;
        let ma = a.iter().map(|&v| v as f64).sum::<f64>() / n;
        let mb = b.iter().map(|&v| v as f64).sum::<f64>() / n;
        let mut num = 0.0;
        let mut da = 0.0;
        let mut db = 0.0;
        for k in 0..a.len() {
            let xa = a[k] as f64 - ma;
            let xb = b[k] as f64 - mb;
            num += xa * xb;
            da += xa * xa;
            db += xb * xb;
        }
        num / (da.sqrt() * db.sqrt()).max(1e-30)
    }

    #[test]
    fn standardize_properties() {
        let mut r = Rng::new(1);
        let x = Matrix::from_vec(5, 50, (0..250).map(|_| r.next_f32() * 10.0 - 5.0).collect());
        let z = standardize_rows(&x);
        for i in 0..5 {
            let row = z.row(i);
            let mean: f64 = row.iter().map(|&v| v as f64).sum::<f64>() / 50.0;
            let norm: f64 = row.iter().map(|&v| (v as f64).powi(2)).sum::<f64>();
            assert!(mean.abs() < 1e-6, "mean={mean}");
            assert!((norm - 1.0).abs() < 1e-5, "norm={norm}");
        }
    }

    #[test]
    fn standardize_inplace_bit_identical_to_out_of_place() {
        let mut r = Rng::new(3);
        let x = Matrix::from_vec(7, 33, (0..7 * 33).map(|_| r.next_f32() * 4.0 - 2.0).collect());
        let z = standardize_rows(&x);
        let mut y = x.clone();
        standardize_rows_inplace(&mut y);
        assert!(z.data.iter().zip(&y.data).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn standardize_constant_row_is_zero() {
        let x = Matrix::from_vec(1, 10, vec![3.0; 10]);
        let z = standardize_rows(&x);
        assert!(z.row(0).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn correlation_matches_naive() {
        let mut r = Rng::new(2);
        let n = 40;
        let l = 64;
        let x = Matrix::from_vec(n, l, (0..n * l).map(|_| r.next_gaussian() as f32).collect());
        let s = pearson_correlation(&x);
        assert!(s.is_symmetric(1e-6));
        for i in 0..n {
            assert!((s.at(i, i) - 1.0).abs() < 1e-6);
            for j in (i + 1)..n {
                let expect = naive_pearson(&x, i, j);
                assert!(
                    (s.at(i, j) as f64 - expect).abs() < 1e-4,
                    "({i},{j}): {} vs {expect}",
                    s.at(i, j)
                );
            }
        }
    }

    #[test]
    fn correlation_perfect_and_anti() {
        // row1 = 2*row0 + 1 (ρ=1); row2 = -row0 (ρ=-1)
        let base: Vec<f32> = (0..32).map(|i| (i as f32).sin()).collect();
        let mut data = base.clone();
        data.extend(base.iter().map(|&v| 2.0 * v + 1.0));
        data.extend(base.iter().map(|&v| -v));
        let x = Matrix::from_vec(3, 32, data);
        let s = pearson_correlation(&x);
        assert!((s.at(0, 1) - 1.0).abs() < 1e-5);
        assert!((s.at(0, 2) + 1.0).abs() < 1e-5);
    }

    #[test]
    fn f64_reference_matches_f32_path() {
        let mut r = Rng::new(5);
        let n = 30;
        let l = 48;
        let x = Matrix::from_vec(n, l, (0..n * l).map(|_| r.next_gaussian() as f32).collect());
        let s32 = pearson_correlation(&x);
        let s64 = pearson_correlation_f64(&x);
        for i in 0..n {
            assert_eq!(s64[i * n + i], 1.0);
            for j in 0..n {
                assert!(
                    (s32.at(i, j) as f64 - s64[i * n + j]).abs() < 1e-4,
                    "({i},{j}): {} vs {}",
                    s32.at(i, j),
                    s64[i * n + j]
                );
            }
        }
        // constant row convention matches (0 off-diagonal, 1 on)
        let c = Matrix::from_vec(2, 8, vec![3.0; 8].into_iter().chain((0..8).map(|t| t as f32)).collect());
        let sc = pearson_correlation_f64(&c);
        assert_eq!(sc, vec![1.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn f64_near_constant_row_uses_stream_var_eps_cutoff() {
        // Sum of squared deviations ≈ 4e-14 — under the 1e-12 cutoff the
        // streaming window's VAR_EPS uses on the same statistic, so the
        // f64 reference must treat the row as constant (correlations 0),
        // keeping the 1e-10 stream-vs-reference contract on
        // near-constant series.
        let mut data = vec![1.0f32; 16];
        data[0] = 1.0 + 2e-7;
        let other: Vec<f32> = (0..16).map(|t| (t as f32).sin()).collect();
        let m = Matrix::from_vec(2, 16, data.into_iter().chain(other).collect());
        let s = pearson_correlation_f64(&m);
        assert_eq!(s, vec![1.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn distance_transform_metricish() {
        assert!((corr_to_distance(1.0) - 0.0).abs() < 1e-7);
        assert!((corr_to_distance(-1.0) - 2.0).abs() < 1e-6);
        assert!((corr_to_distance(0.0) - std::f32::consts::SQRT_2).abs() < 1e-6);
        // monotone decreasing in rho
        let mut prev = f32::INFINITY;
        for k in 0..=20 {
            let rho = -1.0 + 0.1 * k as f32;
            let d = corr_to_distance(rho);
            assert!(d <= prev);
            prev = d;
        }
    }

    #[test]
    fn distance_matrix_elementwise() {
        let s = Matrix::from_vec(2, 2, vec![1.0, 0.5, 0.5, 1.0]);
        let d = distance_matrix(&s);
        assert!((d.at(0, 0)).abs() < 1e-7);
        assert!((d.at(0, 1) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn dispatched_gram_agrees_with_scalar_core() {
        // On AVX2 hosts this pins the SIMD kernel against the scalar
        // core; elsewhere both sides run the scalar core and it's a
        // no-op. Shapes straddle the 4-row block edge and the 8/16-lane
        // vector edges; row 0 is exactly constant (degenerate → zeros).
        let mut r = Rng::new(11);
        for &(n, l) in
            &[(1usize, 5usize), (3, 7), (4, 8), (5, 9), (8, 16), (9, 17), (13, 31), (20, 33)]
        {
            let mut data: Vec<f32> =
                (0..n * l).map(|_| r.next_gaussian() as f32).collect();
            for v in data.iter_mut().take(l) {
                *v = 2.5;
            }
            let x = Matrix::from_vec(n, l, data);
            let a = pearson_correlation(&x);
            let b = pearson_correlation_scalar(&x);
            for i in 0..n {
                for j in 0..n {
                    let (va, vb) = (a.at(i, j), b.at(i, j));
                    assert!(
                        (va - vb).abs() < 1e-5,
                        "n={n} l={l} ({i},{j}): {va} vs {vb}"
                    );
                    assert!(va.abs() <= 1.0);
                }
                assert_eq!(a.at(i, i), 1.0);
            }
        }
    }

    #[test]
    fn dispatched_gram_byte_identical_across_thread_counts() {
        let mut r = Rng::new(12);
        let x = Matrix::from_vec(
            37,
            29,
            (0..37 * 29).map(|_| r.next_gaussian() as f32).collect(),
        );
        let base = parlay::with_threads(1, || pearson_correlation(&x));
        for t in [2, 3, 8] {
            let s = parlay::with_threads(t, || pearson_correlation(&x));
            assert!(
                s.data.iter().zip(&base.data).all(|(a, b)| a.to_bits() == b.to_bits()),
                "gram output differs between 1 and {t} threads"
            );
        }
    }

    #[test]
    fn gram_kernel_name_matches_dispatch() {
        let name = gram_kernel_name();
        assert!(name == "avx2" || name == "scalar");
        assert_eq!(name == "avx2", gram_kernel() == GramKernel::Avx2);
    }
}
