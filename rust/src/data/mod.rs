//! Datasets and similarity matrices: dense matrix storage, native parallel
//! Pearson correlation (the fallback / baseline for the XLA path),
//! synthetic UCR-mirror time-series generators, and CSV/binary IO.

pub mod corr;
pub mod loader;
pub mod matrix;
pub mod synth;

pub use matrix::{Matrix, SimilarityLookup};
pub use synth::Dataset;
