//! Dense row-major f32 matrix — the storage for time-series panels
//! (n series × L samples) and n×n similarity matrices — plus the
//! [`SimilarityLookup`] abstraction that lets the graph stages read
//! pairwise similarities without caring whether the backing store is a
//! dense matrix or a sparse candidate graph.

/// Read access to an n×n similarity. The DBHT stages (edge directioning,
/// basin assignment) and the edge-sum metric only ever query pairs that
/// are TMFG edges or clique co-members, so a sparse store with a
/// missing-entry convention (similarity 0) serves them exactly as well
/// as a dense matrix — which is what makes the large-n sparse pipeline
/// possible without materializing O(n²) floats.
pub trait SimilarityLookup: Sync {
    /// Number of items (the similarity is `n_items` × `n_items`).
    fn n_items(&self) -> usize;
    /// S[i,j]. Implementations define their own missing-entry semantic
    /// (a sparse store returns 0.0 for absent pairs, 1.0 on the
    /// diagonal).
    fn sim(&self, i: usize, j: usize) -> f32;
}

#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "matrix shape mismatch");
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Maximum absolute elementwise difference (for test tolerance checks).
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Is the matrix symmetric within `tol`?
    pub fn is_symmetric(&self, tol: f32) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for r in 0..self.rows {
            for c in (r + 1)..self.cols {
                if (self.at(r, c) - self.at(c, r)).abs() > tol {
                    return false;
                }
            }
        }
        true
    }
}

impl SimilarityLookup for Matrix {
    fn n_items(&self) -> usize {
        self.rows
    }

    #[inline]
    fn sim(&self, i: usize, j: usize) -> f32 {
        self.at(i, j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_access() {
        let mut m = Matrix::zeros(2, 3);
        m.set(1, 2, 5.0);
        assert_eq!(m.at(1, 2), 5.0);
        assert_eq!(m.at(0, 0), 0.0);
        assert_eq!(m.row(1), &[0.0, 0.0, 5.0]);
    }

    #[test]
    fn from_vec_and_diff() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 2.5, 3.0, 4.0]);
        assert!((a.max_abs_diff(&b) - 0.5).abs() < 1e-6);
    }

    #[test]
    #[should_panic]
    fn from_vec_shape_mismatch() {
        let _ = Matrix::from_vec(2, 2, vec![1.0]);
    }

    #[test]
    fn symmetry() {
        let s = Matrix::from_vec(2, 2, vec![1.0, 0.3, 0.3, 1.0]);
        assert!(s.is_symmetric(1e-6));
        let ns = Matrix::from_vec(2, 2, vec![1.0, 0.3, 0.4, 1.0]);
        assert!(!ns.is_symmetric(1e-6));
        assert!(!Matrix::zeros(2, 3).is_symmetric(1e-6));
    }
}
