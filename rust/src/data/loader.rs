//! Dataset IO: UCR-style CSV (label, v1, v2, …, vL per line) and a fast
//! little-endian binary matrix format for caching similarity matrices.

use super::matrix::Matrix;
use super::synth::Dataset;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

/// Load a UCR-style CSV/TSV: each line `label,v1,...,vL` (comma or tab
/// separated). Labels may be arbitrary integers; they are re-indexed to
/// 0..k densely.
pub fn load_ucr_csv(path: &Path) -> Result<Dataset> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let reader = BufReader::new(f);
    let mut raw_labels: Vec<i64> = Vec::new();
    let mut rows: Vec<Vec<f32>> = Vec::new();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let sep = if t.contains('\t') { '\t' } else { ',' };
        let mut it = t.split(sep);
        let label: i64 = it
            .next()
            .context("empty line")?
            .trim()
            .parse::<f64>()
            .map(|v| v as i64)
            .with_context(|| format!("line {}: bad label", lineno + 1))?;
        let vals: Vec<f32> = it
            .map(|s| s.trim().parse::<f32>())
            .collect::<std::result::Result<_, _>>()
            .with_context(|| format!("line {}: bad value", lineno + 1))?;
        if let Some(first) = rows.first() {
            if vals.len() != first.len() {
                bail!(
                    "line {}: length {} != {}",
                    lineno + 1,
                    vals.len(),
                    first.len()
                );
            }
        }
        raw_labels.push(label);
        rows.push(vals);
    }
    if rows.is_empty() {
        bail!("no data rows in {}", path.display());
    }
    // dense re-indexing of labels
    let mut uniq: Vec<i64> = raw_labels.clone();
    uniq.sort_unstable();
    uniq.dedup();
    let labels: Vec<usize> = raw_labels
        .iter()
        .map(|l| uniq.binary_search(l).unwrap())
        .collect();
    let (n, l) = (rows.len(), rows[0].len());
    let mut data = Vec::with_capacity(n * l);
    for r in rows {
        data.extend(r);
    }
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "dataset".into());
    Ok(Dataset {
        name,
        data: Matrix::from_vec(n, l, data),
        labels,
        n_classes: uniq.len(),
    })
}

/// Write a dataset back to UCR-style CSV.
pub fn save_ucr_csv(ds: &Dataset, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    for i in 0..ds.n() {
        write!(w, "{}", ds.labels[i])?;
        for &v in ds.data.row(i) {
            write!(w, ",{v}")?;
        }
        writeln!(w)?;
    }
    Ok(())
}

const MAGIC: &[u8; 8] = b"TMFGMAT1";

/// Save a matrix in a simple binary format (magic, rows, cols, f32 LE data).
pub fn save_matrix_bin(m: &Matrix, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&(m.rows as u64).to_le_bytes())?;
    w.write_all(&(m.cols as u64).to_le_bytes())?;
    for &v in &m.data {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Load a matrix written by [`save_matrix_bin`].
pub fn load_matrix_bin(path: &Path) -> Result<Matrix> {
    let mut f = std::fs::File::open(path)?;
    let mut header = [0u8; 24];
    f.read_exact(&mut header)?;
    if &header[..8] != MAGIC {
        bail!("bad magic in {}", path.display());
    }
    let rows = u64::from_le_bytes(header[8..16].try_into().unwrap()) as usize;
    let cols = u64::from_le_bytes(header[16..24].try_into().unwrap()) as usize;
    let mut buf = vec![0u8; rows * cols * 4];
    f.read_exact(&mut buf)?;
    let data: Vec<f32> = buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok(Matrix::from_vec(rows, cols, data))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    fn tmpdir() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("tmfg_test_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn csv_roundtrip() {
        let ds = SynthSpec::new("rt", 20, 16, 3).generate(5);
        let p = tmpdir().join("rt.csv");
        save_ucr_csv(&ds, &p).unwrap();
        let back = load_ucr_csv(&p).unwrap();
        assert_eq!(back.n(), 20);
        assert_eq!(back.len(), 16);
        assert_eq!(back.n_classes, 3);
        assert_eq!(back.labels, ds.labels);
        assert!(back.data.max_abs_diff(&ds.data) < 1e-5);
    }

    #[test]
    fn csv_rejects_ragged() {
        let p = tmpdir().join("ragged.csv");
        std::fs::write(&p, "0,1,2,3\n1,4,5\n").unwrap();
        assert!(load_ucr_csv(&p).is_err());
    }

    #[test]
    fn csv_reindexes_labels() {
        let p = tmpdir().join("lbl.csv");
        std::fs::write(&p, "5,1.0,2.0\n-3,3.0,4.0\n5,5.0,6.0\n").unwrap();
        let ds = load_ucr_csv(&p).unwrap();
        assert_eq!(ds.n_classes, 2);
        assert_eq!(ds.labels, vec![1, 0, 1]);
    }

    #[test]
    fn matrix_bin_roundtrip() {
        let m = Matrix::from_vec(3, 2, vec![1.5, -2.0, 0.0, 3.25, f32::MIN, f32::MAX]);
        let p = tmpdir().join("m.bin");
        save_matrix_bin(&m, &p).unwrap();
        let back = load_matrix_bin(&p).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn matrix_bin_bad_magic() {
        let p = tmpdir().join("bad.bin");
        std::fs::write(&p, b"NOTMAGIC________________").unwrap();
        assert!(load_matrix_bin(&p).is_err());
    }
}
