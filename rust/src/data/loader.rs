//! Dataset IO: UCR-style CSV (label, v1, v2, …, vL per line) and a fast
//! little-endian binary matrix format for caching similarity matrices.
//!
//! Both readers are written for the n=2^20 regime: ingestion is
//! chunked/streaming, so peak memory is the destination buffer plus one
//! IO chunk — never a second full-panel copy (the CSV path used to hold
//! `Vec<Vec<f32>>` rows *and* the flat matrix; the binary path used to
//! hold the full byte image *and* the f32 vec).

use super::matrix::Matrix;
use super::synth::Dataset;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

/// IO chunk for the binary matrix format (multiple of 4 bytes). Bounds
/// the transient byte buffer while reading/writing matrices of any size.
const BIN_CHUNK_BYTES: usize = 1 << 20;

/// Load a UCR-style CSV/TSV: each line `label,v1,...,vL` (comma or tab
/// separated). Labels may be arbitrary integers; they are re-indexed to
/// 0..k densely.
///
/// Values stream straight into the flat row-major panel buffer — no
/// per-row vectors, no second copy: peak memory is the panel itself
/// plus one line of text.
pub fn load_ucr_csv(path: &Path) -> Result<Dataset> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let reader = BufReader::new(f);
    let mut raw_labels: Vec<i64> = Vec::new();
    let mut data: Vec<f32> = Vec::new();
    let mut row_len: Option<usize> = None;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let sep = if t.contains('\t') { '\t' } else { ',' };
        let mut it = t.split(sep);
        let label: i64 = it
            .next()
            .context("empty line")?
            .trim()
            .parse::<f64>()
            .map(|v| v as i64)
            .with_context(|| format!("line {}: bad label", lineno + 1))?;
        let start = data.len();
        for s in it {
            let v = s
                .trim()
                .parse::<f32>()
                .with_context(|| format!("line {}: bad value", lineno + 1))?;
            data.push(v);
        }
        let got = data.len() - start;
        match row_len {
            None => row_len = Some(got),
            Some(l) if got != l => {
                bail!("line {}: length {got} != {l}", lineno + 1)
            }
            Some(_) => {}
        }
        raw_labels.push(label);
    }
    let Some(l) = row_len else {
        bail!("no data rows in {}", path.display());
    };
    // dense re-indexing of labels
    let mut uniq: Vec<i64> = raw_labels.clone();
    uniq.sort_unstable();
    uniq.dedup();
    let labels: Vec<usize> = raw_labels
        .iter()
        .map(|l| uniq.binary_search(l).unwrap())
        .collect();
    let n = raw_labels.len();
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "dataset".into());
    Ok(Dataset {
        name,
        data: Matrix::from_vec(n, l, data),
        labels,
        n_classes: uniq.len(),
    })
}

/// Write a dataset back to UCR-style CSV.
pub fn save_ucr_csv(ds: &Dataset, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    for i in 0..ds.n() {
        write!(w, "{}", ds.labels[i])?;
        for &v in ds.data.row(i) {
            write!(w, ",{v}")?;
        }
        writeln!(w)?;
    }
    Ok(())
}

const MAGIC: &[u8; 8] = b"TMFGMAT1";

/// Save a matrix in a simple binary format (magic, rows, cols, f32 LE
/// data), serialized through one reusable [`BIN_CHUNK_BYTES`] buffer.
pub fn save_matrix_bin(m: &Matrix, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&(m.rows as u64).to_le_bytes())?;
    w.write_all(&(m.cols as u64).to_le_bytes())?;
    let mut buf: Vec<u8> = Vec::with_capacity(BIN_CHUNK_BYTES);
    for chunk in m.data.chunks(BIN_CHUNK_BYTES / 4) {
        buf.clear();
        for &v in chunk {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        w.write_all(&buf)?;
    }
    Ok(())
}

/// Load a matrix written by [`save_matrix_bin`], decoding through a
/// fixed [`BIN_CHUNK_BYTES`] buffer straight into the f32 vec — peak
/// memory is the matrix itself plus one chunk, never a full byte image.
pub fn load_matrix_bin(path: &Path) -> Result<Matrix> {
    let mut f = std::fs::File::open(path)?;
    let mut header = [0u8; 24];
    f.read_exact(&mut header)?;
    if &header[..8] != MAGIC {
        bail!("bad magic in {}", path.display());
    }
    let rows = u64::from_le_bytes(header[8..16].try_into().unwrap()) as usize;
    let cols = u64::from_le_bytes(header[16..24].try_into().unwrap()) as usize;
    let total = rows
        .checked_mul(cols)
        .and_then(|t| t.checked_mul(4))
        .with_context(|| format!("matrix dims overflow in {}", path.display()))?;
    let mut data: Vec<f32> = Vec::with_capacity(total / 4);
    let mut buf = vec![0u8; BIN_CHUNK_BYTES.min(total.max(4))];
    let mut left = total;
    while left > 0 {
        // Both `left` and the buffer are multiples of 4, so every chunk
        // decodes to whole f32s.
        let take = left.min(buf.len());
        f.read_exact(&mut buf[..take])
            .with_context(|| format!("truncated matrix data in {}", path.display()))?;
        for c in buf[..take].chunks_exact(4) {
            data.push(f32::from_le_bytes(c.try_into().unwrap()));
        }
        left -= take;
    }
    Ok(Matrix::from_vec(rows, cols, data))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    fn tmpdir() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("tmfg_test_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn csv_roundtrip() {
        let ds = SynthSpec::new("rt", 20, 16, 3).generate(5);
        let p = tmpdir().join("rt.csv");
        save_ucr_csv(&ds, &p).unwrap();
        let back = load_ucr_csv(&p).unwrap();
        assert_eq!(back.n(), 20);
        assert_eq!(back.len(), 16);
        assert_eq!(back.n_classes, 3);
        assert_eq!(back.labels, ds.labels);
        assert!(back.data.max_abs_diff(&ds.data) < 1e-5);
    }

    #[test]
    fn csv_rejects_ragged() {
        let p = tmpdir().join("ragged.csv");
        std::fs::write(&p, "0,1,2,3\n1,4,5\n").unwrap();
        assert!(load_ucr_csv(&p).is_err());
    }

    #[test]
    fn csv_reindexes_labels() {
        let p = tmpdir().join("lbl.csv");
        std::fs::write(&p, "5,1.0,2.0\n-3,3.0,4.0\n5,5.0,6.0\n").unwrap();
        let ds = load_ucr_csv(&p).unwrap();
        assert_eq!(ds.n_classes, 2);
        assert_eq!(ds.labels, vec![1, 0, 1]);
    }

    #[test]
    fn matrix_bin_roundtrip() {
        let m = Matrix::from_vec(3, 2, vec![1.5, -2.0, 0.0, 3.25, f32::MIN, f32::MAX]);
        let p = tmpdir().join("m.bin");
        save_matrix_bin(&m, &p).unwrap();
        let back = load_matrix_bin(&p).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn matrix_bin_roundtrip_across_chunk_boundary() {
        // > BIN_CHUNK_BYTES of payload so the chunked reader/writer
        // cross at least one buffer boundary (and a ragged final chunk).
        let total = BIN_CHUNK_BYTES / 4 + 1234;
        let m = Matrix::from_vec(1, total, (0..total).map(|i| i as f32 * 0.5 - 7.0).collect());
        let p = tmpdir().join("big.bin");
        save_matrix_bin(&m, &p).unwrap();
        let back = load_matrix_bin(&p).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn matrix_bin_truncated_data_errors() {
        let m = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let p = tmpdir().join("trunc.bin");
        save_matrix_bin(&m, &p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 4]).unwrap();
        let err = load_matrix_bin(&p).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
    }

    #[test]
    fn matrix_bin_bad_magic() {
        let p = tmpdir().join("bad.bin");
        std::fs::write(&p, b"NOTMAGIC________________").unwrap();
        assert!(load_matrix_bin(&p).is_err());
    }
}
