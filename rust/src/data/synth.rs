//! Synthetic class-structured time-series datasets mirroring the UCR
//! archive sets in the paper's Table 1 (the archive is not redistributable
//! and unavailable offline — see DESIGN.md §6 for the substitution
//! argument).
//!
//! Generator model: each class k has a smooth base curve built from a few
//! random Fourier components; each instance is an amplitude-scaled,
//! time-shifted copy of its class base plus AR(1) noise. This produces the
//! statistical object the pipeline actually consumes — an n×n Pearson
//! matrix with strong intra-class and weak inter-class correlation blocks,
//! corrupted by noise — which is what drives the relative behaviour of the
//! TMFG/DBHT variants.

use super::matrix::Matrix;
use crate::parlay::{self, SendPtr};
use crate::util::rng::Rng;

/// A labelled time-series dataset.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    /// n × L panel: one series per row.
    pub data: Matrix,
    /// Ground-truth class per series (0..n_classes).
    pub labels: Vec<usize>,
    pub n_classes: usize,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.data.rows
    }

    pub fn len(&self) -> usize {
        self.data.cols
    }

    pub fn is_empty(&self) -> bool {
        self.data.rows == 0
    }
}

/// Specification for one synthetic dataset.
#[derive(Debug, Clone)]
pub struct SynthSpec {
    pub name: String,
    /// number of series
    pub n: usize,
    /// series length
    pub len: usize,
    /// number of classes
    pub k: usize,
    /// AR(1) noise amplitude relative to signal (higher = harder)
    pub noise: f64,
    /// number of Fourier components per class base curve
    pub components: usize,
}

impl SynthSpec {
    pub fn new(name: &str, n: usize, len: usize, k: usize) -> SynthSpec {
        SynthSpec {
            name: name.to_string(),
            n,
            len,
            k,
            noise: 0.6,
            components: 6,
        }
    }

    pub fn with_noise(mut self, noise: f64) -> SynthSpec {
        self.noise = noise;
        self
    }

    /// Generate the dataset deterministically from `seed`.
    pub fn generate(&self, seed: u64) -> Dataset {
        assert!(self.k >= 1 && self.n >= self.k, "need n >= k >= 1");
        assert!(self.len >= 8, "series too short");
        let mut rng = Rng::new(seed ^ 0xD1F7_0000);

        // Class base curves: sum of `components` random sinusoids, plus a
        // slow random-walk trend to decorrelate classes further.
        let mut bases = Vec::with_capacity(self.k);
        for _ in 0..self.k {
            let mut curve = vec![0.0f64; self.len];
            for _ in 0..self.components {
                let freq = rng.range_f64(1.0, 12.0);
                let phase = rng.range_f64(0.0, std::f64::consts::TAU);
                let amp = rng.range_f64(0.4, 1.0);
                for (t, c) in curve.iter_mut().enumerate() {
                    *c += amp
                        * (std::f64::consts::TAU * freq * t as f64 / self.len as f64 + phase).sin();
                }
            }
            // normalize base to unit variance so `noise` is comparable
            let mean = curve.iter().sum::<f64>() / self.len as f64;
            let var =
                curve.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / self.len as f64;
            let inv = 1.0 / var.sqrt().max(1e-9);
            for c in curve.iter_mut() {
                *c = (*c - mean) * inv;
            }
            bases.push(curve);
        }

        // Class sizes: balanced with a mild random imbalance.
        let mut labels = Vec::with_capacity(self.n);
        for i in 0..self.n {
            labels.push(i % self.k);
        }
        rng.shuffle(&mut labels);

        // Instances, generated in parallel with per-row forked RNG streams.
        let mut data = vec![0.0f32; self.n * self.len];
        let dp = SendPtr(data.as_mut_ptr());
        let master = rng.clone();
        let bases_ref = &bases;
        let labels_ref = &labels;
        let (len, noise) = (self.len, self.noise);
        parlay::parallel_for(self.n, 8, |i| {
            let mut r = master.clone().fork(i as u64 + 1);
            let base = &bases_ref[labels_ref[i]];
            let scale = r.range_f64(0.7, 1.3);
            let shift = r.next_below(len / 8 + 1);
            // AR(1) noise
            let rho = 0.6;
            let mut eps = 0.0f64;
            for t in 0..len {
                eps = rho * eps + (1.0 - rho * rho).sqrt() * r.next_gaussian();
                let sig = base[(t + shift) % len] * scale;
                // SAFETY: row i written only by iteration i.
                unsafe { dp.write(i * len + t, (sig + noise * eps) as f32) };
            }
        });

        Dataset {
            name: self.name.clone(),
            data: Matrix::from_vec(self.n, self.len, data),
            labels,
            n_classes: self.k,
        }
    }
}

/// The 18 UCR datasets of Table 1, mirrored as synthetic specs with the
/// same (n, L, #classes). `scale` shrinks n (and caps L) for CI-speed
/// runs; scale=1.0 reproduces the paper's sizes.
pub fn table1_specs(scale: f64) -> Vec<SynthSpec> {
    let raw: &[(&str, usize, usize, usize)] = &[
        ("CBF", 930, 128, 3),
        ("ECG5000", 5000, 140, 5),
        ("Crop", 19412, 46, 24),
        ("ElectricDevices", 16160, 96, 7),
        ("FreezerSmallTrain", 2878, 301, 2),
        ("HandOutlines", 1370, 2709, 2),
        ("InsectWingbeatSound", 2200, 256, 11),
        ("Mallat", 2400, 1024, 8),
        ("MixedShapesRegularTrain", 2925, 1024, 5),
        ("MixedShapesSmallTrain", 2525, 1024, 5),
        ("NonInvasiveFetalECGThorax1", 3765, 750, 42),
        ("NonInvasiveFetalECGThorax2", 3765, 750, 42),
        ("ShapesAll", 1200, 512, 60),
        ("SonyAIBORobotSurface2", 980, 65, 2),
        ("StarLightCurves", 9236, 84, 2),
        ("UWaveGestureLibraryAll", 4478, 945, 8),
        ("UWaveGestureLibraryX", 4478, 315, 8),
        ("UWaveGestureLibraryY", 4478, 315, 8),
    ];
    raw.iter()
        .map(|&(name, n, l, k)| {
            let n_scaled = ((n as f64 * scale).round() as usize).max(k.max(8) * 4);
            // Cap very long series when scaling down — correlation cost is
            // n²L and the paper's behaviour is driven by n.
            let l_scaled = if scale < 1.0 { l.min(1024) } else { l };
            SynthSpec::new(name, n_scaled, l_scaled.max(16), k)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corr::pearson_correlation;

    #[test]
    fn generate_shapes_and_labels() {
        let ds = SynthSpec::new("t", 100, 64, 5).generate(1);
        assert_eq!(ds.n(), 100);
        assert_eq!(ds.len(), 64);
        assert_eq!(ds.labels.len(), 100);
        assert_eq!(ds.n_classes, 5);
        assert!(ds.labels.iter().all(|&l| l < 5));
        // every class non-empty
        for c in 0..5 {
            assert!(ds.labels.iter().any(|&l| l == c));
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = SynthSpec::new("t", 50, 32, 3).generate(7);
        let b = SynthSpec::new("t", 50, 32, 3).generate(7);
        assert_eq!(a.data, b.data);
        assert_eq!(a.labels, b.labels);
        let c = SynthSpec::new("t", 50, 32, 3).generate(8);
        assert_ne!(a.data, c.data);
    }

    #[test]
    fn intra_class_correlation_exceeds_inter() {
        let ds = SynthSpec::new("t", 60, 128, 3).generate(3);
        let s = pearson_correlation(&ds.data);
        let mut intra = (0.0f64, 0usize);
        let mut inter = (0.0f64, 0usize);
        for i in 0..ds.n() {
            for j in (i + 1)..ds.n() {
                let v = s.at(i, j) as f64;
                if ds.labels[i] == ds.labels[j] {
                    intra = (intra.0 + v, intra.1 + 1);
                } else {
                    inter = (inter.0 + v, inter.1 + 1);
                }
            }
        }
        let mi = intra.0 / intra.1 as f64;
        let mo = inter.0 / inter.1 as f64;
        assert!(
            mi > mo + 0.2,
            "intra-class mean corr {mi:.3} should exceed inter-class {mo:.3}"
        );
    }

    #[test]
    fn table1_mirrors_paper_sizes() {
        let specs = table1_specs(1.0);
        assert_eq!(specs.len(), 18);
        let crop = specs.iter().find(|s| s.name == "Crop").unwrap();
        assert_eq!((crop.n, crop.len, crop.k), (19412, 46, 24));
        let scaled = table1_specs(0.1);
        let crop_s = scaled.iter().find(|s| s.name == "Crop").unwrap();
        assert_eq!(crop_s.n, 1941);
        assert!(scaled.iter().all(|s| s.n >= s.k));
    }
}
