//! Hierarchical agglomerative clustering by the nearest-neighbour-chain
//! algorithm (as in Yu et al.'s ParChain, which the baseline uses for its
//! complete-linkage step). Supports single, complete, and average linkage
//! — all reducible, so NN-chain produces the exact HAC result in O(m²).

use crate::data::matrix::Matrix;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Linkage {
    Single,
    #[default]
    Complete,
    Average,
}

/// One merge step between the clusters containing representative leaves
/// `a` and `b`, at the given linkage height.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Merge {
    pub a: u32,
    pub b: u32,
    pub height: f32,
}

/// Exact HAC over a dense m×m distance matrix (consumed as working
/// space). `sizes` are initial cluster sizes (for average linkage over
/// pre-grouped items); pass all-1s for plain points. Returns m−1 merges
/// sorted by height ascending, each identified by representative leaves.
///
/// Never panics: shape misuse (non-square `dist`, wrong `sizes` length)
/// is a debug assertion, and in release it returns *fewer than m−1
/// merges* — callers that require a complete hierarchy must check the
/// merge count (as `dbht_dendrogram` does, turning a short list into
/// [`crate::error::TmfgError::InvariantViolation`]).
pub fn nn_chain_hac(dist: &Matrix, sizes: &[f64], linkage: Linkage) -> Vec<Merge> {
    let m = dist.rows;
    // Shape misuse returns an incomplete merge list instead of panicking;
    // dbht_dendrogram's completeness check turns that into a typed
    // InvariantViolation.
    debug_assert_eq!(dist.cols, m);
    debug_assert_eq!(sizes.len(), m);
    if m <= 1 || dist.cols != m || sizes.len() != m {
        return Vec::new();
    }
    // Working distance matrix (f64 to keep Lance-Williams updates stable).
    let mut d: Vec<f64> = dist.data.iter().map(|&x| x as f64).collect();
    let idx = |i: usize, j: usize| i * m + j;
    let mut active: Vec<bool> = vec![true; m];
    let mut size: Vec<f64> = sizes.to_vec();
    // representative leaf of the cluster currently stored at slot i
    let rep: Vec<u32> = (0..m as u32).collect();
    let mut n_active = m;
    let mut chain: Vec<usize> = Vec::with_capacity(m);
    let mut merges: Vec<Merge> = Vec::with_capacity(m - 1);

    'outer: while n_active > 1 {
        if chain.is_empty() {
            // n_active > 1 guarantees an active slot; bail out (instead of
            // panicking) if the bookkeeping is ever inconsistent — the
            // short merge list surfaces as a typed error downstream.
            let Some(first) = (0..m).find(|&i| active[i]) else { break 'outer };
            chain.push(first);
        }
        loop {
            let Some(&c) = chain.last() else { break 'outer };
            // nearest active neighbour of c (tie-break: previous chain
            // element first — guarantees termination — then lowest index)
            let prev = if chain.len() >= 2 { Some(chain[chain.len() - 2]) } else { None };
            let mut best = f64::INFINITY;
            let mut who = usize::MAX;
            for x in 0..m {
                if x != c && active[x] {
                    let dx = d[idx(c, x)];
                    if dx < best || (dx == best && Some(x) == prev) {
                        best = dx;
                        who = x;
                    }
                }
            }
            if who == usize::MAX {
                // no active neighbour found — inconsistent state; bail
                break 'outer;
            }
            if Some(who) == prev {
                // reciprocal nearest neighbours → merge c and who
                chain.pop();
                chain.pop();
                let (a, b) = (c.min(who), c.max(who));
                merges.push(Merge { a: rep[a], b: rep[b], height: best as f32 });
                // Lance-Williams update into slot a
                let (sa, sb) = (size[a], size[b]);
                for x in 0..m {
                    if x != a && x != b && active[x] {
                        let dax = d[idx(a, x)];
                        let dbx = d[idx(b, x)];
                        let nd = match linkage {
                            Linkage::Single => dax.min(dbx),
                            Linkage::Complete => dax.max(dbx),
                            Linkage::Average => (sa * dax + sb * dbx) / (sa + sb),
                        };
                        d[idx(a, x)] = nd;
                        d[idx(x, a)] = nd;
                    }
                }
                active[b] = false;
                size[a] += size[b];
                n_active -= 1;
                break;
            }
            chain.push(who);
        }
    }
    merges.sort_by(|x, y| x.height.total_cmp(&y.height).then(x.a.cmp(&y.a)));
    merges
}

/// Brute-force HAC (for testing): repeatedly merge the closest pair.
#[cfg(test)]
pub fn brute_force_hac(dist: &Matrix, linkage: Linkage) -> Vec<Merge> {
    let m = dist.rows;
    let mut d: Vec<Vec<f64>> = (0..m)
        .map(|i| (0..m).map(|j| dist.at(i, j) as f64).collect())
        .collect();
    let mut active: Vec<bool> = vec![true; m];
    let mut size: Vec<f64> = vec![1.0; m];
    let mut rep: Vec<u32> = (0..m as u32).collect();
    let mut merges = Vec::new();
    for _ in 0..m.saturating_sub(1) {
        let mut best = (f64::INFINITY, usize::MAX, usize::MAX);
        for i in 0..m {
            if !active[i] {
                continue;
            }
            for j in (i + 1)..m {
                if active[j] && d[i][j] < best.0 {
                    best = (d[i][j], i, j);
                }
            }
        }
        let (h, a, b) = best;
        merges.push(Merge { a: rep[a], b: rep[b], height: h as f32 });
        for x in 0..m {
            if x != a && x != b && active[x] {
                let nd = match linkage {
                    Linkage::Single => d[a][x].min(d[b][x]),
                    Linkage::Complete => d[a][x].max(d[b][x]),
                    Linkage::Average => (size[a] * d[a][x] + size[b] * d[b][x]) / (size[a] + size[b]),
                };
                d[a][x] = nd;
                d[x][a] = nd;
            }
        }
        active[b] = false;
        size[a] += size[b];
    }
    merges.sort_by(|x, y| x.height.total_cmp(&y.height).then(x.a.cmp(&y.a)));
    merges
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_dist(m: usize, seed: u64) -> Matrix {
        let mut r = Rng::new(seed);
        let mut d = Matrix::zeros(m, m);
        for i in 0..m {
            for j in (i + 1)..m {
                let v = r.next_f32() + 0.01;
                d.set(i, j, v);
                d.set(j, i, v);
            }
        }
        d
    }

    #[test]
    fn matches_brute_force_heights() {
        for &linkage in &[Linkage::Single, Linkage::Complete, Linkage::Average] {
            for seed in 0..5u64 {
                let m = 12 + (seed as usize % 8);
                let d = random_dist(m, seed * 7 + 1);
                let sizes = vec![1.0; m];
                let a = nn_chain_hac(&d, &sizes, linkage);
                let b = brute_force_hac(&d, linkage);
                assert_eq!(a.len(), b.len());
                // Height multisets must match (tree shapes equal up to ties).
                let ha: Vec<f32> = a.iter().map(|x| x.height).collect();
                let hb: Vec<f32> = b.iter().map(|x| x.height).collect();
                for (x, y) in ha.iter().zip(&hb) {
                    assert!(
                        (x - y).abs() < 1e-5,
                        "{linkage:?} seed {seed}: {ha:?} vs {hb:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn heights_sorted_and_count() {
        let d = random_dist(30, 9);
        let merges = nn_chain_hac(&d, &vec![1.0; 30], Linkage::Complete);
        assert_eq!(merges.len(), 29);
        for w in merges.windows(2) {
            assert!(w[0].height <= w[1].height);
        }
    }

    #[test]
    fn single_linkage_is_mst_heights() {
        // single-linkage merge heights = MST edge weights (Kruskal)
        let d = random_dist(15, 3);
        let merges = nn_chain_hac(&d, &vec![1.0; 15], Linkage::Single);
        // Kruskal
        let m = 15;
        let mut edges: Vec<(f32, usize, usize)> = Vec::new();
        for i in 0..m {
            for j in (i + 1)..m {
                edges.push((d.at(i, j), i, j));
            }
        }
        edges.sort_by(|a, b| a.0.total_cmp(&b.0));
        let mut parent: Vec<usize> = (0..m).collect();
        fn find(p: &mut Vec<usize>, x: usize) -> usize {
            if p[x] != x {
                let r = find(p, p[x]);
                p[x] = r;
            }
            p[x]
        }
        let mut mst = Vec::new();
        for (w, a, b) in edges {
            let (ra, rb) = (find(&mut parent, a), find(&mut parent, b));
            if ra != rb {
                parent[ra] = rb;
                mst.push(w);
            }
        }
        for (x, y) in merges.iter().map(|m| m.height).zip(mst) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn trivial_sizes() {
        let d = Matrix::zeros(1, 1);
        assert!(nn_chain_hac(&d, &[1.0], Linkage::Complete).is_empty());
        let d2 = random_dist(2, 1);
        let m = nn_chain_hac(&d2, &[1.0, 1.0], Linkage::Complete);
        assert_eq!(m.len(), 1);
        assert_eq!((m[0].a, m[0].b), (0, 1));
    }

    #[test]
    fn merges_reference_distinct_clusters() {
        let d = random_dist(20, 11);
        let merges = nn_chain_hac(&d, &vec![1.0; 20], Linkage::Average);
        // each leaf id appears as representative; every merge pairs two
        // distinct reps; overall forms a full binary tree over 20 leaves
        let mut uf: Vec<u32> = (0..20).collect();
        fn find(uf: &mut Vec<u32>, x: u32) -> u32 {
            if uf[x as usize] != x {
                let r = find(uf, uf[x as usize]);
                uf[x as usize] = r;
            }
            uf[x as usize]
        }
        for mg in &merges {
            let (ra, rb) = (find(&mut uf, mg.a), find(&mut uf, mg.b));
            assert_ne!(ra, rb, "merge joins same cluster");
            uf[ra as usize] = rb;
        }
    }
}
