//! The bubble tree: one node per TMFG 4-clique, edges between cliques
//! sharing a triangular face. TMFG construction already tracks the parent
//! relation (`TmfgResult::parent`); this module adds children lists,
//! Euler-tour intervals for O(1) subtree tests, and vertex↔bubble maps.

use crate::tmfg::TmfgResult;

#[derive(Debug, Clone)]
pub struct BubbleTree {
    pub n_bubbles: usize,
    pub n_vertices: usize,
    pub cliques: Vec<[u32; 4]>,
    pub parent: Vec<i32>,
    pub children: Vec<Vec<u32>>,
    /// Euler-tour entry/exit times (subtree(b) ⇔ tin[b] ≤ tin[x] < tout[b]).
    pub tin: Vec<u32>,
    pub tout: Vec<u32>,
    /// Bubble that *introduced* each vertex (the root introduces the 4
    /// seed vertices; every other bubble introduces exactly one).
    pub intro_bubble: Vec<u32>,
    /// All bubbles whose clique contains the vertex.
    pub vertex_bubbles: Vec<Vec<u32>>,
}

impl BubbleTree {
    pub fn new(t: &TmfgResult) -> BubbleTree {
        let nb = t.cliques.len();
        let n = t.n;
        let mut children: Vec<Vec<u32>> = vec![Vec::new(); nb];
        for b in 1..nb {
            children[t.parent[b] as usize].push(b as u32);
        }
        // Iterative Euler tour (the tree can be path-shaped → no recursion).
        let mut tin = vec![0u32; nb];
        let mut tout = vec![0u32; nb];
        let mut clock = 0u32;
        let mut stack: Vec<(u32, usize)> = vec![(0, 0)];
        tin[0] = clock;
        clock += 1;
        while let Some(&mut (b, ref mut ci)) = stack.last_mut() {
            if *ci < children[b as usize].len() {
                let c = children[b as usize][*ci];
                *ci += 1;
                tin[c as usize] = clock;
                clock += 1;
                stack.push((c, 0));
            } else {
                tout[b as usize] = clock;
                stack.pop();
            }
        }

        let mut intro_bubble = vec![0u32; n];
        let mut vertex_bubbles: Vec<Vec<u32>> = vec![Vec::new(); n];
        for (b, c) in t.cliques.iter().enumerate() {
            for &v in c {
                vertex_bubbles[v as usize].push(b as u32);
            }
            if b > 0 {
                intro_bubble[c[3] as usize] = b as u32;
            }
        }
        for &v in &t.cliques[0] {
            intro_bubble[v as usize] = 0;
        }

        BubbleTree {
            n_bubbles: nb,
            n_vertices: n,
            cliques: t.cliques.clone(),
            parent: t.parent.clone(),
            children,
            tin,
            tout,
            intro_bubble,
            vertex_bubbles,
        }
    }

    /// Is bubble `x` inside the subtree rooted at `b`?
    #[inline]
    pub fn in_subtree(&self, x: u32, b: u32) -> bool {
        self.tin[b as usize] <= self.tin[x as usize]
            && self.tin[x as usize] < self.tout[b as usize]
    }

    /// Is vertex `v` introduced inside the subtree rooted at bubble `b`?
    #[inline]
    pub fn vertex_in_subtree(&self, v: u32, b: u32) -> bool {
        self.in_subtree(self.intro_bubble[v as usize], b)
    }

    /// The triangular face bubble `b > 0` shares with its parent.
    #[inline]
    pub fn shared_face(&self, b: u32) -> [u32; 3] {
        debug_assert!(b > 0);
        let c = self.cliques[b as usize];
        [c[0], c[1], c[2]]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    fn tree(n: usize, seed: u64) -> BubbleTree {
        let ds = SynthSpec::new("t", n, 48, 3).generate(seed);
        let s = crate::data::corr::pearson_correlation(&ds.data);
        let r = crate::tmfg::heap_tmfg(&s, &Default::default()).unwrap();
        BubbleTree::new(&r)
    }

    #[test]
    fn structure_counts() {
        let bt = tree(60, 1);
        assert_eq!(bt.n_bubbles, 60 - 3);
        // children counts sum to nb - 1
        let total: usize = bt.children.iter().map(|c| c.len()).sum();
        assert_eq!(total, bt.n_bubbles - 1);
        // every vertex in >= 1 bubble; every bubble has 4 distinct vertices
        assert!(bt.vertex_bubbles.iter().all(|b| !b.is_empty()));
        for c in &bt.cliques {
            let mut d = c.to_vec();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), 4);
        }
    }

    #[test]
    fn euler_intervals_consistent() {
        let bt = tree(80, 2);
        for b in 0..bt.n_bubbles as u32 {
            assert!(bt.in_subtree(b, b));
            assert!(bt.in_subtree(b, 0), "root contains all");
            if b > 0 {
                let p = bt.parent[b as usize] as u32;
                assert!(bt.in_subtree(b, p));
                assert!(!bt.in_subtree(p, b));
            }
        }
        // siblings are disjoint
        for b in 0..bt.n_bubbles {
            let ch = &bt.children[b];
            for i in 0..ch.len() {
                for j in (i + 1)..ch.len() {
                    assert!(!bt.in_subtree(ch[i], ch[j]));
                    assert!(!bt.in_subtree(ch[j], ch[i]));
                }
            }
        }
    }

    #[test]
    fn intro_partition() {
        let bt = tree(50, 3);
        // introduced counts: root 4, everyone else 1 → total = n
        let mut count = vec![0usize; bt.n_bubbles];
        for v in 0..bt.n_vertices {
            count[bt.intro_bubble[v] as usize] += 1;
        }
        assert_eq!(count[0], 4);
        assert!(count[1..].iter().all(|&c| c == 1));
    }

    #[test]
    fn shared_face_belongs_to_parent() {
        let bt = tree(40, 4);
        for b in 1..bt.n_bubbles as u32 {
            let f = bt.shared_face(b);
            let pc = bt.cliques[bt.parent[b as usize] as usize];
            for v in f {
                assert!(pc.contains(&v), "face vertex {v} of bubble {b} not in parent");
            }
        }
    }
}
