//! Bubble-tree edge directioning (DESIGN.md §7.2).
//!
//! For the tree edge between bubble `b` and its parent, sharing face `t`:
//! removing the edge splits the tree into the subtree under `b` and the
//! rest. The *connection strength* of each side is
//! χ(t, side) = Σ_{v ∈ t} Σ_{u: (v,u) ∈ TMFG, u ∉ t, u introduced on side} S[v,u].
//! The edge is directed toward the stronger side (ties → toward the
//! parent side, which keeps degenerate flat-similarity inputs converging
//! at the root).

use super::bubble::BubbleTree;
use crate::data::matrix::SimilarityLookup;
use crate::parlay;

/// Directions for every non-root bubble's parent edge.
#[derive(Debug, Clone)]
pub struct Directions {
    /// For bubble b > 0: is the parent edge directed *into* b's subtree?
    pub to_child: Vec<bool>,
    /// χ toward the child side / parent side, per bubble (index 0 unused).
    pub strength_child: Vec<f64>,
    pub strength_parent: Vec<f64>,
    /// Out-degree of each bubble under these directions.
    pub out_degree: Vec<u32>,
}

/// Compute edge directions. `adj` is the TMFG adjacency (from
/// [`crate::tmfg::TmfgResult::adjacency`]); `s` any similarity store —
/// only TMFG-edge pairs are ever read, so a sparse candidate graph
/// serves here without densification.
pub fn direct_edges<S: SimilarityLookup + ?Sized>(
    bt: &BubbleTree,
    adj: &[Vec<u32>],
    s: &S,
) -> Directions {
    let nb = bt.n_bubbles;
    let mut to_child = vec![false; nb];
    let mut strength_child = vec![0.0f64; nb];
    let mut strength_parent = vec![0.0f64; nb];
    if nb > 1 {
        let results: Vec<(bool, f64, f64)> = parlay::par_map(nb - 1, 16, |i| {
            let b = (i + 1) as u32;
            let t = bt.shared_face(b);
            let mut chi_child = 0.0f64;
            let mut chi_parent = 0.0f64;
            for &v in &t {
                for &u in &adj[v as usize] {
                    if t.contains(&u) {
                        continue;
                    }
                    let w = s.sim(v as usize, u as usize) as f64;
                    if bt.vertex_in_subtree(u, b) {
                        chi_child += w;
                    } else {
                        chi_parent += w;
                    }
                }
            }
            (chi_child > chi_parent, chi_child, chi_parent)
        });
        for (i, (tc, cc, cp)) in results.into_iter().enumerate() {
            to_child[i + 1] = tc;
            strength_child[i + 1] = cc;
            strength_parent[i + 1] = cp;
        }
    }
    let mut out_degree = vec![0u32; nb];
    for b in 1..nb {
        if to_child[b] {
            // edge points into b's subtree → outgoing for the parent
            out_degree[bt.parent[b] as usize] += 1;
        } else {
            out_degree[b] += 1;
        }
    }
    Directions { to_child, strength_child, strength_parent, out_degree }
}

impl Directions {
    /// Converging bubbles: only incoming edges.
    pub fn converging(&self) -> Vec<u32> {
        let conv: Vec<u32> = (0..self.out_degree.len() as u32)
            .filter(|&b| self.out_degree[b as usize] == 0)
            .collect();
        debug_assert!(!conv.is_empty(), "a finite directed tree has a sink");
        conv
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::matrix::Matrix;
    use crate::data::synth::SynthSpec;
    use crate::tmfg::TmfgResult;

    fn setup(n: usize, seed: u64) -> (Matrix, TmfgResult, BubbleTree) {
        let ds = SynthSpec::new("t", n, 48, 3).generate(seed);
        let s = crate::data::corr::pearson_correlation(&ds.data);
        let r = crate::tmfg::heap_tmfg(&s, &Default::default()).unwrap();
        let bt = BubbleTree::new(&r);
        (s, r, bt)
    }

    #[test]
    fn out_degrees_consistent() {
        let (s, r, bt) = setup(80, 1);
        let d = direct_edges(&bt, &r.adjacency(), &s);
        // each of nb-1 edges contributes exactly one out-degree
        let total: u32 = d.out_degree.iter().sum();
        assert_eq!(total as usize, bt.n_bubbles - 1);
    }

    #[test]
    fn converging_exists_and_has_no_outgoing() {
        for seed in [2u64, 3, 4] {
            let (s, r, bt) = setup(100, seed);
            let d = direct_edges(&bt, &r.adjacency(), &s);
            let conv = d.converging();
            assert!(!conv.is_empty());
            for &c in &conv {
                assert_eq!(d.out_degree[c as usize], 0);
            }
        }
    }

    #[test]
    fn strengths_nonnegative_for_positive_similarity() {
        let (mut s, r, bt) = setup(60, 5);
        // force all similarities positive
        for v in s.data.iter_mut() {
            *v = v.abs();
        }
        let d = direct_edges(&bt, &r.adjacency(), &s);
        for b in 1..bt.n_bubbles {
            assert!(d.strength_child[b] >= 0.0);
            assert!(d.strength_parent[b] >= 0.0);
        }
    }

    #[test]
    fn single_bubble_tree() {
        let (s, r, bt) = setup(4, 6);
        assert_eq!(bt.n_bubbles, 1);
        let d = direct_edges(&bt, &r.adjacency(), &s);
        assert_eq!(d.converging(), vec![0]);
    }
}
