//! Directed Bubble Hierarchy Tree (DBHT) hierarchical clustering
//! [Song, Di Matteo, Aste 2012], as used by the paper on top of the TMFG.
//!
//! Pipeline: the TMFG's 4-cliques form a tree of "bubbles" (nodes =
//! cliques, edges = shared triangular faces). Each bubble-tree edge is
//! directed toward the side with stronger similarity to the shared face;
//! bubbles with no outgoing edge are *converging* and seed the coarsest
//! clusters. Vertices are assigned to converging bubbles (basins) and to
//! individual bubbles within each basin; complete-linkage agglomeration
//! over APSP distances then builds a dendrogram at three layers
//! (within-bubble, between bubbles of a basin, between basins).
//! DESIGN.md §7 documents the exact rules used where the papers leave
//! freedom.

pub mod bubble;
pub mod converging;
pub mod dendrogram;
pub mod direction;
pub mod hierarchy;
pub mod linkage;

pub use bubble::BubbleTree;
pub use converging::Assignment;
pub use dendrogram::Dendrogram;
pub use hierarchy::dbht_dendrogram;
pub use linkage::Linkage;
