//! Converging-bubble basins and vertex assignments (DESIGN.md §7.3–7.5).
//!
//! * Every bubble flows to a converging bubble by repeatedly following its
//!   strongest outgoing edge (strength = χ of the side the edge points
//!   to); the map is memoized.
//! * Every vertex is assigned to a converging bubble: among the basins of
//!   the bubbles containing it, the one with the largest total similarity
//!   from the vertex to those bubbles' clique vertices.
//! * Within its basin, every vertex is assigned to the bubble with the
//!   smallest mean APSP distance to the bubble's clique vertices (the
//!   paper: connection strength "determined by shortest-path distances in
//!   the TMFG").

use super::bubble::BubbleTree;
use super::direction::Directions;
use crate::apsp::ApspOracle;
use crate::data::matrix::SimilarityLookup;
use crate::error::TmfgError;
use crate::parlay;

#[derive(Debug, Clone)]
pub struct Assignment {
    /// Converging bubble ids (sorted).
    pub converging: Vec<u32>,
    /// basin[b] = converging bubble that bubble b flows to.
    pub bubble_basin: Vec<u32>,
    /// Converging bubble assigned to each vertex.
    pub vertex_basin: Vec<u32>,
    /// Bubble (within its basin) assigned to each vertex.
    pub vertex_bubble: Vec<u32>,
}

/// Follow strongest outgoing edges to a converging bubble, memoized.
fn compute_basins(bt: &BubbleTree, dir: &Directions) -> Result<Vec<u32>, TmfgError> {
    let nb = bt.n_bubbles;
    let mut basin: Vec<u32> = vec![u32::MAX; nb];
    for start in 0..nb as u32 {
        if basin[start as usize] != u32::MAX {
            continue;
        }
        let mut path = Vec::new();
        let mut cur = start;
        loop {
            if basin[cur as usize] != u32::MAX {
                break;
            }
            if dir.out_degree[cur as usize] == 0 {
                basin[cur as usize] = cur;
                break;
            }
            path.push(cur);
            // strongest outgoing edge: candidates are the parent edge (if
            // it points away from cur) and child edges pointing into the
            // child's subtree.
            let mut best: Option<(f64, u32)> = None;
            if cur != 0 && !dir.to_child[cur as usize] {
                let st = dir.strength_parent[cur as usize];
                best = Some((st, bt.parent[cur as usize] as u32));
            }
            for &c in &bt.children[cur as usize] {
                if dir.to_child[c as usize] {
                    let st = dir.strength_child[c as usize];
                    if best.map(|(bs, bt_)| st > bs || (st == bs && c < bt_)).unwrap_or(true) {
                        best = Some((st, c));
                    }
                }
            }
            cur = best
                .ok_or_else(|| {
                    TmfgError::invariant(
                        "bubble with out_degree > 0 has no outgoing edge",
                    )
                })?
                .1;
        }
        let sink = basin[cur as usize];
        for p in path {
            basin[p as usize] = sink;
        }
    }
    Ok(basin)
}

/// Full assignment: basins, vertex→basin, vertex→bubble.
/// `apsp` is the (exact or approximate) shortest-path oracle; `s` any
/// similarity store — only clique-co-member pairs (TMFG edges) are
/// read, so a sparse candidate graph serves without densification.
pub fn assign<S: SimilarityLookup + ?Sized>(
    bt: &BubbleTree,
    dir: &Directions,
    s: &S,
    apsp: &dyn ApspOracle,
) -> Result<Assignment, TmfgError> {
    let bubble_basin = compute_basins(bt, dir)?;
    let mut converging: Vec<u32> = dir.converging();
    converging.sort_unstable();

    // vertex → basin: strongest attachment among the basins of the
    // vertex's own bubbles.
    let bb = &bubble_basin;
    let vertex_basin: Vec<u32> = parlay::par_map(bt.n_vertices, 64, |v| {
        let mut strength: std::collections::HashMap<u32, f64> = std::collections::HashMap::new();
        for &b in &bt.vertex_bubbles[v] {
            let cb = bb[b as usize];
            let e = strength.entry(cb).or_insert(0.0);
            for &u in &bt.cliques[b as usize] {
                if u as usize != v {
                    *e += s.sim(v, u as usize) as f64;
                }
            }
        }
        let mut best = (f64::NEG_INFINITY, u32::MAX);
        for (&cb, &st) in &strength {
            if st > best.0 || (st == best.0 && cb < best.1) {
                best = (st, cb);
            }
        }
        best.1
    });

    // Bubbles per basin (for the within-basin bubble assignment).
    let mut basin_bubbles: std::collections::HashMap<u32, Vec<u32>> =
        std::collections::HashMap::new();
    for b in 0..bt.n_bubbles as u32 {
        basin_bubbles.entry(bubble_basin[b as usize]).or_default().push(b);
    }

    // vertex → bubble within its basin: min mean APSP distance to the
    // bubble's clique vertices. Dense oracles are read in place; on a
    // streaming oracle a vertex that must touch a large share of its
    // APSP row (many candidate bubbles) materializes the row once into
    // per-chunk O(n) scratch instead of paying a structured lookup per
    // clique vertex. Either path reads identical values.
    let n = apsp.n();
    let vb = &vertex_basin;
    let bbs = &basin_bubbles;
    let vertex_bubble: Vec<u32> =
        parlay::par_map_scratch(bt.n_vertices, 16, |v, scratch: &mut Vec<f32>| {
            let basin = vb[v];
            let candidates = &bbs[&basin];
            let row: Option<&[f32]> = if let Some(m) = apsp.as_dense() {
                Some(m.row(v))
            } else if candidates.len() * 4 * 2 >= n {
                if scratch.len() != n {
                    scratch.resize(n, 0.0);
                }
                apsp.row_into(v, scratch);
                Some(scratch.as_slice())
            } else {
                None
            };
            let mut best = (f64::INFINITY, u32::MAX);
            for &b in candidates {
                let mut d = 0.0f64;
                for &u in &bt.cliques[b as usize] {
                    d += match row {
                        Some(r) => r[u as usize] as f64,
                        None => apsp.at(v, u as usize) as f64,
                    };
                }
                d /= 4.0;
                if d < best.0 || (d == best.0 && b < best.1) {
                    best = (d, b);
                }
            }
            best.1
        });

    Ok(Assignment { converging, bubble_basin, vertex_basin, vertex_bubble })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apsp::{exact_oracle, CsrGraph, DenseOracle};
    use crate::data::matrix::Matrix;
    use crate::data::synth::SynthSpec;
    use crate::dbht::direction::direct_edges;

    fn setup(n: usize, seed: u64) -> (Matrix, BubbleTree, Directions, DenseOracle) {
        let ds = SynthSpec::new("t", n, 48, 3).generate(seed);
        let s = crate::data::corr::pearson_correlation(&ds.data);
        let r = crate::tmfg::heap_tmfg(&s, &Default::default()).unwrap();
        let bt = BubbleTree::new(&r);
        let dir = direct_edges(&bt, &r.adjacency(), &s);
        let apsp = exact_oracle(&CsrGraph::from_tmfg(&r, &s));
        (s, bt, dir, apsp)
    }

    #[test]
    fn basins_map_to_converging() {
        let (s, bt, dir, apsp) = setup(90, 1);
        let a = assign(&bt, &dir, &s, &apsp).unwrap();
        let conv: std::collections::HashSet<u32> = a.converging.iter().copied().collect();
        for b in 0..bt.n_bubbles {
            assert!(conv.contains(&a.bubble_basin[b]), "bubble {b} basin not converging");
        }
        // converging bubbles are their own basin
        for &c in &a.converging {
            assert_eq!(a.bubble_basin[c as usize], c);
        }
    }

    #[test]
    fn vertex_assignments_consistent() {
        let (s, bt, dir, apsp) = setup(120, 2);
        let a = assign(&bt, &dir, &s, &apsp).unwrap();
        let conv: std::collections::HashSet<u32> = a.converging.iter().copied().collect();
        for v in 0..bt.n_vertices {
            // basin must be converging
            assert!(conv.contains(&a.vertex_basin[v]));
            // assigned bubble must flow to the assigned basin
            assert_eq!(
                a.bubble_basin[a.vertex_bubble[v] as usize],
                a.vertex_basin[v],
                "vertex {v}"
            );
        }
    }

    #[test]
    fn all_vertices_covered_small() {
        let (s, bt, dir, apsp) = setup(10, 3);
        let a = assign(&bt, &dir, &s, &apsp).unwrap();
        assert_eq!(a.vertex_basin.len(), 10);
        assert_eq!(a.vertex_bubble.len(), 10);
        assert!(a.vertex_bubble.iter().all(|&b| (b as usize) < bt.n_bubbles));
    }

    #[test]
    fn basin_partition_covers_all_bubbles() {
        let (s, bt, dir, apsp) = setup(70, 4);
        let a = assign(&bt, &dir, &s, &apsp).unwrap();
        // group bubbles by basin; sizes sum to n_bubbles
        let mut count = 0usize;
        for &c in &a.converging {
            count += (0..bt.n_bubbles).filter(|&b| a.bubble_basin[b] == c).count();
        }
        assert_eq!(count, bt.n_bubbles);
    }
}
