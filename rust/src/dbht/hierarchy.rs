//! Stitch the DBHT hierarchy (DESIGN.md §7.6): complete-linkage HAC on
//! APSP distances at three layers — inside each bubble group, between the
//! bubble groups of a converging basin, and between basins — combined into
//! one dendrogram over all vertices.
//!
//! Distances come through the [`ApspOracle`], never an n×n matrix held
//! by this module: the group-distance layers stream one APSP row at a
//! time into O(n) per-chunk scratch (zero-copy on a dense oracle), and
//! every basin's group-distance matrix is built in one parallel pass
//! over (basin, row) tasks — the per-basin loop is only the cheap,
//! deterministic NN-chain + merge application.
//!
//! Two mechanisms bound the agglomeration past the dense-era sizes
//! (n ≫ 16384), both deterministic and both identity below their
//! thresholds:
//!
//! * **Representative sampling** ([`REP_CAP`]): a group with more than
//!   `REP_CAP` members contributes an evenly-spaced sample of its
//!   (sorted) member list to every group-to-group distance, capping the
//!   member-pair work per pair at `REP_CAP²` instead of |g|·|h| — at
//!   n=2^20 the basin layer would otherwise fold O(n²) APSP entries.
//! * **Chunked coarsening** ([`GROUP_CHUNK`]): a layer with more than
//!   `GROUP_CHUNK` groups never materializes its m×m distance matrix.
//!   Contiguous blocks of `GROUP_CHUNK` groups are fully agglomerated,
//!   each block's union becomes one coarse group, and the coarse level
//!   recurses — matrix memory stays ≤ `GROUP_CHUNK²` f32s while the
//!   merge count per layer is unchanged (every block of c groups emits
//!   c−1 merges). Block-local heights are exact under the sampled
//!   metric; cross-block heights are computed between block unions, the
//!   documented approximation that makes this regime runnable at all.

use super::bubble::BubbleTree;
use super::converging::{assign, Assignment};
use super::dendrogram::{DendroBuilder, Dendrogram};
use super::direction::direct_edges;
use super::linkage::{nn_chain_hac, Linkage};
use crate::apsp::ApspOracle;
use crate::data::matrix::{Matrix, SimilarityLookup};
use crate::error::TmfgError;
use crate::parlay;
use crate::tmfg::TmfgResult;

/// Groups larger than this contribute an evenly-spaced member sample to
/// group-distance aggregation (identity for smaller groups, so every
/// sub-threshold result is byte-identical to the unsampled code).
pub const REP_CAP: usize = 128;

/// Layers with more than this many groups agglomerate through chunked
/// coarsening instead of one m×m distance matrix (4096² f32 = 64 MiB,
/// the same ceiling the dense-APSP auto mode uses).
pub const GROUP_CHUNK: usize = 4096;

/// Number of representatives a group of `len` members contributes to
/// group-distance aggregation.
#[inline]
fn rep_take(len: usize) -> usize {
    len.min(REP_CAP)
}

/// The `t`-th evenly-spaced representative of `g` (`t < rep_take(len)`).
/// Identity (`g[t]`) whenever the group is at or under [`REP_CAP`]; the
/// spacing `t·len/take` is strictly increasing, so representatives are
/// distinct and the sample order follows the member order.
#[inline]
fn rep_pick(g: &[u32], t: usize) -> u32 {
    g[t * g.len() / rep_take(g.len())]
}

/// Group-level distances from group `i` to every later group of one
/// basin, under the pointwise APSP metric: returns d(i, j) for j > i.
///
/// Groups larger than [`REP_CAP`] are represented by an evenly-spaced
/// member sample on both sides (identity below the cap), so a pair of
/// groups costs at most `REP_CAP²` APSP reads regardless of group size.
/// Each representative's APSP row is visited once, x-major / y-minor —
/// the same fold order (and therefore the same f64 accumulation bits)
/// as a pairwise `at` loop. Dense oracles expose rows zero-copy; a
/// streaming oracle materializes the row into `scratch` when the later
/// groups will read a large share of it, and falls back to point
/// lookups otherwise.
fn group_row_distances(
    apsp: &dyn ApspOracle,
    groups: &[Vec<u32>],
    i: usize,
    linkage: Linkage,
    scratch: &mut Vec<f32>,
) -> Vec<f32> {
    let m = groups.len();
    let n = apsp.n();
    let init = match linkage {
        Linkage::Single => f64::INFINITY,
        _ => 0.0,
    };
    let mut agg = vec![init; m - i - 1];
    let dense = apsp.as_dense();
    // Row entries the later groups will read (per representative).
    let reads: usize = groups[i + 1..].iter().map(|g| rep_take(g.len())).sum();
    let xi = &groups[i];
    for t in 0..rep_take(xi.len()) {
        let x = rep_pick(xi, t);
        let row: Option<&[f32]> = if let Some(mat) = dense {
            Some(mat.row(x as usize))
        } else if reads * 2 >= n {
            if scratch.len() != n {
                scratch.resize(n, 0.0);
            }
            apsp.row_into(x as usize, scratch);
            Some(&scratch[..])
        } else {
            None
        };
        for (jj, g) in groups[i + 1..].iter().enumerate() {
            let a = &mut agg[jj];
            for u in 0..rep_take(g.len()) {
                let y = rep_pick(g, u);
                let d = match row {
                    Some(r) => r[y as usize] as f64,
                    None => apsp.at(x as usize, y as usize) as f64,
                };
                match linkage {
                    Linkage::Single => *a = a.min(d),
                    Linkage::Complete => *a = a.max(d),
                    Linkage::Average => *a += d,
                }
            }
        }
    }
    if linkage == Linkage::Average {
        for (jj, g) in groups[i + 1..].iter().enumerate() {
            agg[jj] /= (rep_take(xi.len()) * rep_take(g.len())) as f64;
        }
    }
    agg.into_iter().map(|v| v as f32).collect()
}

/// One basin's m×m group-distance matrix, rows filled in parallel.
fn layer_matrix(apsp: &dyn ApspOracle, groups: &[Vec<u32>], linkage: Linkage) -> Matrix {
    use crate::parlay::SendPtr;
    let m = groups.len();
    let mut d = Matrix::zeros(m, m);
    let ptr = SendPtr(d.data.as_mut_ptr());
    let ptr = &ptr;
    parlay::parallel_for_chunks(m - 1, 1, |lo, hi| {
        let mut scratch: Vec<f32> = Vec::new();
        for i in lo..hi {
            let row = group_row_distances(apsp, groups, i, linkage, &mut scratch);
            for (jj, v) in row.into_iter().enumerate() {
                let j = i + 1 + jj;
                // SAFETY: cells (i,j)/(j,i) are written only by row task i.
                unsafe {
                    ptr.write(i * m + j, v);
                    ptr.write(j * m + i, v);
                }
            }
        }
    });
    d
}

/// Fully agglomerate one basin's groups into `builder` (each group's
/// first vertex is its representative), never holding more than a
/// `chunk`×`chunk` distance matrix.
///
/// At or under `chunk` groups this is exact NN-chain HAC on the full
/// group-distance matrix. Above it, contiguous blocks of `chunk` groups
/// are agglomerated recursively and each block's member union becomes
/// one coarse group for the next level — every block of c groups still
/// emits exactly c−1 merges, so the layer's merge count is unchanged
/// and the dendrogram stays complete.
fn agglomerate_groups(
    builder: &mut DendroBuilder,
    apsp: &dyn ApspOracle,
    groups: &[Vec<u32>],
    linkage: Linkage,
    chunk: usize,
) {
    let m = groups.len();
    if m <= 1 {
        return;
    }
    if m <= chunk {
        let d = layer_matrix(apsp, groups, linkage);
        let sizes: Vec<f64> = groups.iter().map(|g| g.len() as f64).collect();
        for mg in nn_chain_hac(&d, &sizes, linkage) {
            builder.merge(groups[mg.a as usize][0], groups[mg.b as usize][0], mg.height);
        }
        return;
    }
    let mut coarse: Vec<Vec<u32>> = Vec::with_capacity(m.div_ceil(chunk));
    for block in groups.chunks(chunk) {
        agglomerate_groups(builder, apsp, block, linkage, chunk);
        coarse.push(block.iter().flatten().copied().collect());
    }
    agglomerate_groups(builder, apsp, &coarse, linkage, chunk);
}

/// HAC over pre-formed groups for a whole layer at once: every basin's
/// group-level distance matrix is filled by one parallel pass over all
/// (basin, row) tasks, then NN-chain merges are applied to `builder`
/// sequentially in basin order (each group's first vertex is its
/// representative) — deterministic regardless of thread count.
///
/// When any basin holds more than [`GROUP_CHUNK`] groups the layer
/// switches to per-basin [`agglomerate_groups`] (chunked coarsening, one
/// basin at a time) so matrix memory stays bounded; below that threshold
/// the one-pass path is used unchanged.
fn agglomerate_layer(
    builder: &mut DendroBuilder,
    apsp: &dyn ApspOracle,
    basins: &[Vec<Vec<u32>>],
    linkage: Linkage,
) {
    if basins.iter().any(|groups| groups.len() > GROUP_CHUNK) {
        for groups in basins {
            agglomerate_groups(builder, apsp, groups, linkage, GROUP_CHUNK);
        }
        return;
    }
    let mut mats: Vec<Matrix> = basins
        .iter()
        .map(|groups| {
            let m = groups.len();
            if m >= 2 {
                Matrix::zeros(m, m)
            } else {
                Matrix::zeros(0, 0)
            }
        })
        .collect();
    let tasks: Vec<(usize, usize)> = basins
        .iter()
        .enumerate()
        .flat_map(|(b, groups)| {
            let m = groups.len();
            (0..m.saturating_sub(1)).map(move |i| (b, i))
        })
        .collect();
    {
        use crate::parlay::SendPtr;
        let ptrs: Vec<SendPtr<f32>> =
            mats.iter_mut().map(|m| SendPtr(m.data.as_mut_ptr())).collect();
        let ptrs = &ptrs;
        let tasks = &tasks;
        parlay::parallel_for_chunks(tasks.len(), 1, |lo, hi| {
            let mut scratch: Vec<f32> = Vec::new();
            for t in lo..hi {
                let (b, i) = tasks[t];
                let groups = &basins[b];
                let m = groups.len();
                let row = group_row_distances(apsp, groups, i, linkage, &mut scratch);
                for (jj, v) in row.into_iter().enumerate() {
                    let j = i + 1 + jj;
                    // SAFETY: cell pair (i,j)/(j,i) of basin b is written
                    // only by task (b, i) — tasks are disjoint.
                    unsafe {
                        ptrs[b].write(i * m + j, v);
                        ptrs[b].write(j * m + i, v);
                    }
                }
            }
        });
    }
    for (b, groups) in basins.iter().enumerate() {
        if groups.len() <= 1 {
            continue;
        }
        let sizes: Vec<f64> = groups.iter().map(|g| g.len() as f64).collect();
        for mg in nn_chain_hac(&mats[b], &sizes, linkage) {
            builder.merge(groups[mg.a as usize][0], groups[mg.b as usize][0], mg.height);
        }
    }
}

/// Full DBHT output.
#[derive(Debug, Clone)]
pub struct DbhtResult {
    pub dendrogram: Dendrogram,
    pub assignment: Assignment,
    pub n_converging: usize,
}

/// Run DBHT on a constructed TMFG with an APSP oracle. `s` is any
/// similarity store (dense matrix or sparse candidate graph — DBHT only
/// reads pairs that are TMFG edges, which both hold); `apsp` is either
/// backend — this function allocates O(n) APSP scratch, so with a
/// [`crate::apsp::HubOracle`] the whole DBHT stage runs in O(n·h)
/// memory. Internal structural failures (an incomplete dendrogram, a
/// dangling basin) surface as [`TmfgError::InvariantViolation`], never a
/// panic.
pub fn dbht_dendrogram<S: SimilarityLookup + ?Sized>(
    s: &S,
    tmfg: &TmfgResult,
    apsp: &dyn ApspOracle,
    linkage: Linkage,
) -> Result<DbhtResult, TmfgError> {
    let n = tmfg.n;
    let bt = BubbleTree::new(tmfg);
    let dir = direct_edges(&bt, &tmfg.adjacency(), s);
    let assignment = assign(&bt, &dir, s, apsp)?;

    // Sort-based grouping: one (basin, bubble, vertex) triple per
    // vertex, sorted once. Deterministic, no hash maps, and every
    // group's member vector is built exactly once — layer 1 borrows the
    // nested structure that layer 2 then consumes, so n=2^20 does not
    // pay a second copy of the grouping.
    let mut items: Vec<(u32, u32, u32)> = (0..n)
        .map(|v| (assignment.vertex_basin[v], assignment.vertex_bubble[v], v as u32))
        .collect();
    items.sort_unstable();
    // layer2[b] = basin b's bubble groups, in (basin, bubble) order with
    // members ascending — the same order the map-based grouping produced.
    let mut layer2: Vec<Vec<Vec<u32>>> = Vec::new();
    let mut last_basin = None;
    let mut i = 0;
    while i < items.len() {
        let (basin, bubble, _) = items[i];
        let mut g: Vec<u32> = Vec::new();
        while i < items.len() && items[i].0 == basin && items[i].1 == bubble {
            g.push(items[i].2);
            i += 1;
        }
        if last_basin == Some(basin) {
            layer2.last_mut().expect("basin started").push(g);
        } else {
            layer2.push(vec![g]);
            last_basin = Some(basin);
        }
    }
    drop(items);

    let mut builder = DendroBuilder::new(n);

    // Layer 1: within-bubble-group complete linkage.
    // Precompute each group's intra merges in parallel, then apply in a
    // deterministic order. Groups are small relative to n, so pointwise
    // `at` beats materializing whole APSP rows here.
    {
        let group_list: Vec<&Vec<u32>> = layer2.iter().flatten().collect();
        let intra: Vec<Vec<super::linkage::Merge>> =
            parlay::par_map(group_list.len(), 1, |gi| {
                let g = group_list[gi];
                let m = g.len();
                if m <= 1 {
                    return Vec::new();
                }
                let mut d = Matrix::zeros(m, m);
                for i in 0..m {
                    for j in (i + 1)..m {
                        let v = apsp.at(g[i] as usize, g[j] as usize);
                        d.set(i, j, v);
                        d.set(j, i, v);
                    }
                }
                nn_chain_hac(&d, &vec![1.0; m], linkage)
            });
        for (gi, g) in group_list.iter().enumerate() {
            for mg in &intra[gi] {
                builder.merge(g[mg.a as usize], g[mg.b as usize], mg.height);
            }
        }
    }

    // Layer 2: between bubble groups within each basin — one parallel
    // pass over every basin's group-distance rows.
    agglomerate_layer(&mut builder, apsp, &layer2, linkage);

    // Layer 3: between basins.
    let basin_vertex_groups: Vec<Vec<u32>> = layer2
        .iter()
        .map(|groups| {
            let mut vs: Vec<u32> = groups.iter().flatten().copied().collect();
            vs.sort_unstable();
            vs
        })
        .collect();
    agglomerate_layer(
        &mut builder,
        apsp,
        std::slice::from_ref(&basin_vertex_groups),
        linkage,
    );

    if builder.n_merges() != n - 1 {
        return Err(TmfgError::invariant(format!(
            "dendrogram incomplete: {} merges for {n} leaves",
            builder.n_merges()
        )));
    }
    Ok(DbhtResult {
        dendrogram: builder.finish(),
        n_converging: assignment.converging.len(),
        assignment,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apsp::{exact_oracle, CsrGraph, HubOracle};
    use crate::data::synth::SynthSpec;
    use crate::metrics::adjusted_rand_index;
    use crate::tmfg::heap_tmfg;

    fn run(n: usize, k: usize, seed: u64, noise: f64) -> (DbhtResult, Vec<usize>, usize) {
        let ds = SynthSpec::new("t", n, 64, k).with_noise(noise).generate(seed);
        let s = crate::data::corr::pearson_correlation(&ds.data);
        let r = heap_tmfg(&s, &Default::default()).unwrap();
        let apsp = exact_oracle(&CsrGraph::from_tmfg(&r, &s));
        let out = dbht_dendrogram(&s, &r, &apsp, Linkage::Complete).unwrap();
        (out, ds.labels, ds.n_classes)
    }

    #[test]
    fn dendrogram_complete_all_sizes() {
        for n in [4usize, 5, 8, 30, 100] {
            let (out, _, _) = run(n, 3.min(n / 2).max(1), n as u64, 0.5);
            assert!(out.dendrogram.is_complete(), "n={n}");
            assert_eq!(out.dendrogram.n_leaves, n);
        }
    }

    #[test]
    fn recovers_well_separated_classes() {
        // DBHT clustering quality varies per instance (the paper's own
        // average ARI across real datasets is 0.388); check a fixed-seed
        // ensemble average instead of a single run.
        let mut sum = 0.0;
        let mut best: f64 = 0.0;
        let seeds = [7u64, 8, 9, 10];
        for &seed in &seeds {
            let (out, labels, k) = run(120, 3, seed, 0.3);
            let pred = out.dendrogram.cut(k);
            let ari = adjusted_rand_index(&labels, &pred);
            sum += ari;
            best = best.max(ari);
        }
        let mean = sum / seeds.len() as f64;
        assert!(mean > 0.35, "mean ARI too low: {mean}");
        assert!(best > 0.5, "best ARI too low: {best}");
    }

    #[test]
    fn cut_sizes() {
        let (out, _, _) = run(60, 4, 9, 0.5);
        for k in [1usize, 2, 4, 10, 60] {
            let labels = out.dendrogram.cut(k);
            let uniq: std::collections::HashSet<_> = labels.iter().collect();
            assert_eq!(uniq.len(), k, "k={k}");
        }
    }

    #[test]
    fn deterministic() {
        let (a, _, _) = run(50, 3, 11, 0.5);
        let (b, _, _) = run(50, 3, 11, 0.5);
        assert_eq!(a.dendrogram.nodes, b.dendrogram.nodes);
        assert_eq!(a.n_converging, b.n_converging);
    }

    #[test]
    fn linkage_variants_complete() {
        for linkage in [Linkage::Single, Linkage::Average, Linkage::Complete] {
            let ds = SynthSpec::new("t", 40, 48, 3).generate(13);
            let s = crate::data::corr::pearson_correlation(&ds.data);
            let r = heap_tmfg(&s, &Default::default()).unwrap();
            let apsp = exact_oracle(&CsrGraph::from_tmfg(&r, &s));
            let out = dbht_dendrogram(&s, &r, &apsp, linkage).unwrap();
            assert!(out.dendrogram.is_complete(), "{linkage:?}");
        }
    }

    #[test]
    fn rep_sampling_identity_below_cap_and_even_above() {
        let small: Vec<u32> = (0..REP_CAP as u32).collect();
        for t in 0..small.len() {
            assert_eq!(rep_pick(&small, t), small[t]);
        }
        let big: Vec<u32> = (0..(4 * REP_CAP) as u32).collect();
        assert_eq!(rep_take(big.len()), REP_CAP);
        let picks: Vec<u32> = (0..REP_CAP).map(|t| rep_pick(&big, t)).collect();
        // Distinct, ascending, spanning the member list.
        for w in picks.windows(2) {
            assert!(w[0] < w[1]);
        }
        assert_eq!(picks[0], 0);
        assert!(picks[REP_CAP - 1] >= big[big.len() - REP_CAP]);
    }

    #[test]
    fn sampled_groups_still_yield_complete_deterministic_dendrogram() {
        // n=300 with 2 classes typically leaves basins (layer-3 groups)
        // well past REP_CAP, exercising the sampled aggregation path.
        let (a, _, _) = run(300, 2, 21, 0.4);
        let (b, _, _) = run(300, 2, 21, 0.4);
        assert!(a.dendrogram.is_complete());
        assert_eq!(a.dendrogram.nodes, b.dendrogram.nodes);
    }

    #[test]
    fn chunked_coarsening_emits_full_merge_count() {
        let ds = SynthSpec::new("t", 60, 48, 3).generate(19);
        let s = crate::data::corr::pearson_correlation(&ds.data);
        let r = heap_tmfg(&s, &Default::default()).unwrap();
        let apsp = exact_oracle(&CsrGraph::from_tmfg(&r, &s));
        let groups: Vec<Vec<u32>> = (0..12).map(|g| (5 * g..5 * (g + 1)).collect()).collect();
        for linkage in [Linkage::Single, Linkage::Average, Linkage::Complete] {
            // Flat (chunk ≥ m) and chunked (chunk of 4 → 3 coarse blocks)
            // agglomeration must both merge 12 groups with 11 merges.
            for chunk in [12usize, 4] {
                let mut builder = DendroBuilder::new(60);
                agglomerate_groups(&mut builder, &apsp, &groups, linkage, chunk);
                assert_eq!(builder.n_merges(), groups.len() - 1, "{linkage:?} chunk={chunk}");
            }
        }
    }

    #[test]
    fn hub_oracle_gives_same_dendrogram_as_hub_matrix_all_linkages() {
        // The streaming backend must be indistinguishable from running
        // DBHT on the materialized hub matrix — merge-for-merge, for
        // every linkage (Average exercises the f64 accumulation-order
        // contract of the row-streaming group distances).
        use crate::apsp::{apsp_hub, DenseOracle, HubConfig};
        let ds = SynthSpec::new("t", 90, 48, 3).generate(17);
        let s = crate::data::corr::pearson_correlation(&ds.data);
        let r = heap_tmfg(&s, &Default::default()).unwrap();
        let g = CsrGraph::from_tmfg(&r, &s);
        let cfg = HubConfig::default();
        let dense = DenseOracle::new(apsp_hub(&g, &cfg));
        let oracle = HubOracle::build(&g, &cfg);
        for linkage in [Linkage::Single, Linkage::Average, Linkage::Complete] {
            let a = dbht_dendrogram(&s, &r, &dense, linkage).unwrap();
            let b = dbht_dendrogram(&s, &r, &oracle, linkage).unwrap();
            assert_eq!(a.dendrogram.nodes, b.dendrogram.nodes, "{linkage:?}");
            assert_eq!(
                a.assignment.vertex_bubble, b.assignment.vertex_bubble,
                "{linkage:?}"
            );
        }
    }
}
