//! Stitch the DBHT hierarchy (DESIGN.md §7.6): complete-linkage HAC on
//! APSP distances at three layers — inside each bubble group, between the
//! bubble groups of a converging basin, and between basins — combined into
//! one dendrogram over all vertices.

use super::bubble::BubbleTree;
use super::converging::{assign, Assignment};
use super::dendrogram::{DendroBuilder, Dendrogram};
use super::direction::direct_edges;
use super::linkage::{nn_chain_hac, Linkage};
use crate::data::matrix::{Matrix, SimilarityLookup};
use crate::error::TmfgError;
use crate::parlay;
use crate::tmfg::TmfgResult;
use std::collections::HashMap;

/// Group-level complete/single/average distance between two vertex sets
/// under the pointwise APSP metric.
fn group_distance(apsp: &Matrix, a: &[u32], b: &[u32], linkage: Linkage) -> f32 {
    let mut agg: f64 = match linkage {
        Linkage::Single => f64::INFINITY,
        _ => 0.0,
    };
    for &x in a {
        for &y in b {
            let d = apsp.at(x as usize, y as usize) as f64;
            match linkage {
                Linkage::Single => agg = agg.min(d),
                Linkage::Complete => agg = agg.max(d),
                Linkage::Average => agg += d,
            }
        }
    }
    if linkage == Linkage::Average {
        agg /= (a.len() * b.len()) as f64;
    }
    agg as f32
}

/// HAC over pre-formed groups: builds the group-level distance matrix in
/// parallel, runs NN-chain, and applies the merges to `builder` using
/// each group's first vertex as representative.
fn agglomerate_groups(
    builder: &mut DendroBuilder,
    apsp: &Matrix,
    groups: &[Vec<u32>],
    linkage: Linkage,
) {
    let m = groups.len();
    if m <= 1 {
        return;
    }
    let mut d = Matrix::zeros(m, m);
    {
        use crate::parlay::SendPtr;
        let dp = SendPtr(d.data.as_mut_ptr());
        parlay::parallel_for(m, 1, |i| {
            for j in (i + 1)..m {
                let v = group_distance(apsp, &groups[i], &groups[j], linkage);
                unsafe {
                    dp.write(i * m + j, v);
                    dp.write(j * m + i, v);
                }
            }
        });
    }
    let sizes: Vec<f64> = groups.iter().map(|g| g.len() as f64).collect();
    for mg in nn_chain_hac(&d, &sizes, linkage) {
        builder.merge(groups[mg.a as usize][0], groups[mg.b as usize][0], mg.height);
    }
}

/// Full DBHT output.
#[derive(Debug, Clone)]
pub struct DbhtResult {
    pub dendrogram: Dendrogram,
    pub assignment: Assignment,
    pub n_converging: usize,
}

/// Run DBHT on a constructed TMFG with a precomputed APSP matrix. `s`
/// is any similarity store (dense matrix or sparse candidate graph —
/// DBHT only reads pairs that are TMFG edges, which both hold).
/// Internal structural failures (an incomplete dendrogram, a dangling
/// basin) surface as [`TmfgError::InvariantViolation`], never a panic.
pub fn dbht_dendrogram<S: SimilarityLookup + ?Sized>(
    s: &S,
    tmfg: &TmfgResult,
    apsp: &Matrix,
    linkage: Linkage,
) -> Result<DbhtResult, TmfgError> {
    let n = tmfg.n;
    let bt = BubbleTree::new(tmfg);
    let dir = direct_edges(&bt, &tmfg.adjacency(), s);
    let assignment = assign(&bt, &dir, s, apsp)?;

    // groups[(basin, bubble)] = vertices
    let mut groups: HashMap<(u32, u32), Vec<u32>> = HashMap::new();
    for v in 0..n {
        groups
            .entry((assignment.vertex_basin[v], assignment.vertex_bubble[v]))
            .or_default()
            .push(v as u32);
    }

    let mut builder = DendroBuilder::new(n);

    // Layer 1: within-bubble-group complete linkage.
    // Collect groups per basin while we're at it.
    let mut basin_groups: HashMap<u32, Vec<Vec<u32>>> = HashMap::new();
    let mut keys: Vec<(u32, u32)> = groups.keys().copied().collect();
    keys.sort_unstable();
    // Precompute each group's intra merges in parallel, then apply in a
    // deterministic order.
    let group_list: Vec<&Vec<u32>> = keys.iter().map(|k| &groups[k]).collect();
    let intra: Vec<Vec<super::linkage::Merge>> = parlay::par_map(group_list.len(), 1, |gi| {
        let g = group_list[gi];
        let m = g.len();
        if m <= 1 {
            return Vec::new();
        }
        let mut d = Matrix::zeros(m, m);
        for i in 0..m {
            for j in (i + 1)..m {
                let v = apsp.at(g[i] as usize, g[j] as usize);
                d.set(i, j, v);
                d.set(j, i, v);
            }
        }
        nn_chain_hac(&d, &vec![1.0; m], linkage)
    });
    for (gi, key) in keys.iter().enumerate() {
        let g = &groups[key];
        for mg in &intra[gi] {
            builder.merge(g[mg.a as usize], g[mg.b as usize], mg.height);
        }
        basin_groups.entry(key.0).or_default().push(g.clone());
    }

    // Layer 2: between bubble groups within each basin.
    let mut basins: Vec<u32> = basin_groups.keys().copied().collect();
    basins.sort_unstable();
    for b in &basins {
        agglomerate_groups(&mut builder, apsp, &basin_groups[b], linkage);
    }

    // Layer 3: between basins.
    let basin_vertex_groups: Vec<Vec<u32>> = basins
        .iter()
        .map(|b| {
            let mut vs: Vec<u32> = basin_groups[b].iter().flatten().copied().collect();
            vs.sort_unstable();
            vs
        })
        .collect();
    agglomerate_groups(&mut builder, apsp, &basin_vertex_groups, linkage);

    if builder.n_merges() != n - 1 {
        return Err(TmfgError::invariant(format!(
            "dendrogram incomplete: {} merges for {n} leaves",
            builder.n_merges()
        )));
    }
    Ok(DbhtResult {
        dendrogram: builder.finish(),
        n_converging: assignment.converging.len(),
        assignment,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apsp::{apsp_exact, CsrGraph};
    use crate::data::synth::SynthSpec;
    use crate::metrics::adjusted_rand_index;
    use crate::tmfg::heap_tmfg;

    fn run(n: usize, k: usize, seed: u64, noise: f64) -> (DbhtResult, Vec<usize>, usize) {
        let ds = SynthSpec::new("t", n, 64, k).with_noise(noise).generate(seed);
        let s = crate::data::corr::pearson_correlation(&ds.data);
        let r = heap_tmfg(&s, &Default::default()).unwrap();
        let apsp = apsp_exact(&CsrGraph::from_tmfg(&r, &s));
        let out = dbht_dendrogram(&s, &r, &apsp, Linkage::Complete).unwrap();
        (out, ds.labels, ds.n_classes)
    }

    #[test]
    fn dendrogram_complete_all_sizes() {
        for n in [4usize, 5, 8, 30, 100] {
            let (out, _, _) = run(n, 3.min(n / 2).max(1), n as u64, 0.5);
            assert!(out.dendrogram.is_complete(), "n={n}");
            assert_eq!(out.dendrogram.n_leaves, n);
        }
    }

    #[test]
    fn recovers_well_separated_classes() {
        // DBHT clustering quality varies per instance (the paper's own
        // average ARI across real datasets is 0.388); check a fixed-seed
        // ensemble average instead of a single run.
        let mut sum = 0.0;
        let mut best: f64 = 0.0;
        let seeds = [7u64, 8, 9, 10];
        for &seed in &seeds {
            let (out, labels, k) = run(120, 3, seed, 0.3);
            let pred = out.dendrogram.cut(k);
            let ari = adjusted_rand_index(&labels, &pred);
            sum += ari;
            best = best.max(ari);
        }
        let mean = sum / seeds.len() as f64;
        assert!(mean > 0.35, "mean ARI too low: {mean}");
        assert!(best > 0.5, "best ARI too low: {best}");
    }

    #[test]
    fn cut_sizes() {
        let (out, _, _) = run(60, 4, 9, 0.5);
        for k in [1usize, 2, 4, 10, 60] {
            let labels = out.dendrogram.cut(k);
            let uniq: std::collections::HashSet<_> = labels.iter().collect();
            assert_eq!(uniq.len(), k, "k={k}");
        }
    }

    #[test]
    fn deterministic() {
        let (a, _, _) = run(50, 3, 11, 0.5);
        let (b, _, _) = run(50, 3, 11, 0.5);
        assert_eq!(a.dendrogram.nodes, b.dendrogram.nodes);
        assert_eq!(a.n_converging, b.n_converging);
    }

    #[test]
    fn linkage_variants_complete() {
        for linkage in [Linkage::Single, Linkage::Average, Linkage::Complete] {
            let ds = SynthSpec::new("t", 40, 48, 3).generate(13);
            let s = crate::data::corr::pearson_correlation(&ds.data);
            let r = heap_tmfg(&s, &Default::default()).unwrap();
            let apsp = apsp_exact(&CsrGraph::from_tmfg(&r, &s));
            let out = dbht_dendrogram(&s, &r, &apsp, linkage).unwrap();
            assert!(out.dendrogram.is_complete(), "{linkage:?}");
        }
    }
}
