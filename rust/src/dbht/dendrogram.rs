//! Dendrogram representation, incremental construction (union-find over
//! representative leaves, with per-path monotone height clamping), and
//! cutting to k clusters.

/// A rooted binary dendrogram over `n_leaves` leaves. Node ids: leaves are
//  `0..n_leaves`; internal node `n_leaves + i` is created by the i-th merge.
#[derive(Debug, Clone)]
pub struct Dendrogram {
    pub n_leaves: usize,
    /// (left child, right child, height) per internal node, in creation
    /// order. Heights are monotone along every leaf-to-root path.
    pub nodes: Vec<(u32, u32, f32)>,
}

impl Dendrogram {
    pub fn n_nodes(&self) -> usize {
        self.n_leaves + self.nodes.len()
    }

    pub fn is_complete(&self) -> bool {
        self.nodes.len() + 1 == self.n_leaves || self.n_leaves == 0
    }

    fn parents(&self) -> Vec<u32> {
        let total = self.n_nodes();
        let mut parent = vec![u32::MAX; total];
        for (i, &(l, r, _)) in self.nodes.iter().enumerate() {
            let id = (self.n_leaves + i) as u32;
            parent[l as usize] = id;
            parent[r as usize] = id;
        }
        parent
    }

    /// Cut into exactly `k` clusters (1 ≤ k ≤ n_leaves): remove the k−1
    /// internal nodes ranking highest by (height, creation order) — an
    /// upward-closed set thanks to monotone heights — and label each leaf
    /// by its remaining component. Returns dense labels 0..k.
    pub fn cut(&self, k: usize) -> Vec<usize> {
        let n = self.n_leaves;
        assert!(self.is_complete(), "cut requires a complete dendrogram");
        let k = k.clamp(1, n.max(1));
        if n == 0 {
            return Vec::new();
        }
        let m = self.nodes.len();
        let n_cut = k - 1; // top k-1 internal nodes are removed
        // rank internal nodes by (height, index); creation order breaks
        // ties so parents (created later, height ≥ children) rank higher.
        let mut order: Vec<usize> = (0..m).collect();
        order.sort_by(|&a, &b| {
            self.nodes[a]
                .2
                .total_cmp(&self.nodes[b].2)
                .then(a.cmp(&b))
        });
        let mut removed = vec![false; n + m];
        for &i in order.iter().rev().take(n_cut) {
            removed[n + i] = true;
        }
        // cluster root of node x: x itself if its parent is removed (or x
        // is root) and x is not removed.
        let parent = self.parents();
        let mut label = vec![usize::MAX; n + m];
        let mut next = 0usize;
        // process nodes top-down (root has the largest id)
        for x in (0..n + m).rev() {
            if removed[x] {
                continue;
            }
            let p = parent[x];
            if p == u32::MAX || removed[p as usize] {
                label[x] = next;
                next += 1;
            } else {
                label[x] = label[p as usize];
            }
        }
        debug_assert_eq!(next, k);
        label.truncate(n);
        label
    }
}

impl Dendrogram {
    /// Export as a Newick tree string (heights become branch lengths;
    /// leaves are named by `names`, or `v<i>` when `names` is None) —
    /// loadable by standard phylogenetics/clustering tooling.
    pub fn to_newick(&self, names: Option<&[String]>) -> String {
        assert!(self.is_complete(), "newick export requires a complete dendrogram");
        let n = self.n_leaves;
        if n == 0 {
            return ";".into();
        }
        let height_of = |id: usize| -> f32 {
            if id < n {
                0.0
            } else {
                self.nodes[id - n].2
            }
        };
        // Iterative post-order rendering (trees can be path-shaped).
        let root = n + self.nodes.len() - 1;
        let mut rendered: Vec<Option<String>> = vec![None; self.n_nodes()];
        let mut stack = vec![if self.nodes.is_empty() { 0 } else { root }];
        while let Some(&id) = stack.last() {
            if id < n {
                let name = names
                    .map(|ns| ns[id].clone())
                    .unwrap_or_else(|| format!("v{id}"));
                rendered[id] = Some(name);
                stack.pop();
                continue;
            }
            let (l, r, h) = self.nodes[id - n];
            match (&rendered[l as usize], &rendered[r as usize]) {
                (Some(ls), Some(rs)) => {
                    let bl = (h - height_of(l as usize)).max(0.0);
                    let br = (h - height_of(r as usize)).max(0.0);
                    rendered[id] = Some(format!("({ls}:{bl},{rs}:{br})"));
                    stack.pop();
                }
                _ => {
                    if rendered[l as usize].is_none() {
                        stack.push(l as usize);
                    }
                    if rendered[r as usize].is_none() {
                        stack.push(r as usize);
                    }
                }
            }
        }
        // The post-order loop renders every node; fall back to an empty
        // name rather than panicking if it ever did not.
        format!(
            "{};",
            rendered[if self.nodes.is_empty() { 0 } else { root }]
                .take()
                .unwrap_or_default()
        )
    }

    /// Export merges as JSON (scipy-linkage-like rows [left, right, height]).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("n_leaves", Json::Num(self.n_leaves as f64)),
            (
                "merges",
                Json::Arr(
                    self.nodes
                        .iter()
                        .map(|&(l, r, h)| {
                            Json::Arr(vec![
                                Json::Num(l as f64),
                                Json::Num(r as f64),
                                Json::Num(h as f64),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Incremental dendrogram builder. Merges are specified by *representative
/// leaves* — any leaf of each cluster — so layered construction (DBHT) and
/// height-sorted reconstruction (NN-chain output) both compose naturally.
#[derive(Debug)]
pub struct DendroBuilder {
    n_leaves: usize,
    nodes: Vec<(u32, u32, f32)>,
    /// union-find over all node ids
    uf: Vec<u32>,
    /// current dendrogram node of each union-find root
    cluster_node: Vec<u32>,
    /// current height of each cluster's top node
    cluster_height: Vec<f32>,
}

impl DendroBuilder {
    pub fn new(n_leaves: usize) -> DendroBuilder {
        DendroBuilder {
            n_leaves,
            nodes: Vec::with_capacity(n_leaves.saturating_sub(1)),
            uf: (0..n_leaves as u32).collect(),
            cluster_node: (0..n_leaves as u32).collect(),
            cluster_height: vec![0.0; n_leaves],
        }
    }

    fn find(&mut self, x: u32) -> u32 {
        let mut root = x;
        while self.uf[root as usize] != root {
            root = self.uf[root as usize];
        }
        let mut cur = x;
        while self.uf[cur as usize] != root {
            let next = self.uf[cur as usize];
            self.uf[cur as usize] = root;
            cur = next;
        }
        root
    }

    /// Merge the clusters containing leaves `a` and `b` at `height`
    /// (clamped to keep per-path monotonicity). No-op if already merged
    /// (returns None).
    pub fn merge(&mut self, a: u32, b: u32, height: f32) -> Option<u32> {
        let ra = self.find(a);
        let rb = self.find(b);
        if ra == rb {
            return None;
        }
        let (na, nb) = (self.cluster_node[ra as usize], self.cluster_node[rb as usize]);
        let h = height
            .max(self.cluster_height[ra as usize])
            .max(self.cluster_height[rb as usize]);
        let new_id = (self.n_leaves + self.nodes.len()) as u32;
        self.nodes.push((na, nb, h));
        // union: attach rb under ra
        self.uf[rb as usize] = ra;
        self.cluster_node[ra as usize] = new_id;
        self.cluster_height[ra as usize] = h;
        Some(new_id)
    }

    /// Number of merges applied so far.
    pub fn n_merges(&self) -> usize {
        self.nodes.len()
    }

    pub fn finish(self) -> Dendrogram {
        Dendrogram { n_leaves: self.n_leaves, nodes: self.nodes }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain_dendro(n: usize) -> Dendrogram {
        // merge 0-1 at h=1, then +2 at h=2, ...
        let mut b = DendroBuilder::new(n);
        for i in 1..n {
            b.merge(0, i as u32, i as f32).unwrap();
        }
        b.finish()
    }

    #[test]
    fn builder_basic() {
        let mut b = DendroBuilder::new(4);
        assert!(b.merge(0, 1, 1.0).is_some());
        assert!(b.merge(2, 3, 0.5).is_some());
        assert!(b.merge(0, 3, 2.0).is_some());
        assert!(b.merge(1, 2, 9.0).is_none(), "already one cluster");
        let d = b.finish();
        assert!(d.is_complete());
        assert_eq!(d.nodes.len(), 3);
    }

    #[test]
    fn heights_clamped_monotone() {
        let mut b = DendroBuilder::new(3);
        b.merge(0, 1, 5.0);
        b.merge(0, 2, 1.0); // lower than child → clamped to 5.0
        let d = b.finish();
        assert_eq!(d.nodes[1].2, 5.0);
    }

    #[test]
    fn cut_chain() {
        let d = chain_dendro(5);
        let l1 = d.cut(1);
        assert!(l1.iter().all(|&x| x == 0));
        let l5 = d.cut(5);
        let set: std::collections::HashSet<_> = l5.iter().collect();
        assert_eq!(set.len(), 5);
        // k=2 splits off the last-merged leaf (highest merge)
        let l2 = d.cut(2);
        assert_eq!(l2.iter().filter(|&&x| x == l2[4]).count(), 1);
        let base = l2[0];
        assert!(l2[..4].iter().all(|&x| x == base));
    }

    #[test]
    fn cut_respects_structure() {
        // two tight pairs merged high: cut(2) must recover the pairs
        let mut b = DendroBuilder::new(4);
        b.merge(0, 1, 0.1);
        b.merge(2, 3, 0.2);
        b.merge(0, 2, 5.0);
        let d = b.finish();
        let l = d.cut(2);
        assert_eq!(l[0], l[1]);
        assert_eq!(l[2], l[3]);
        assert_ne!(l[0], l[2]);
        // labels dense
        let mx = *l.iter().max().unwrap();
        assert_eq!(mx, 1);
    }

    #[test]
    fn cut_k_bounds() {
        let d = chain_dendro(6);
        assert_eq!(d.cut(0).iter().max(), Some(&0)); // clamped to 1
        let l = d.cut(100); // clamped to n
        let set: std::collections::HashSet<_> = l.iter().collect();
        assert_eq!(set.len(), 6);
    }

    #[test]
    fn newick_roundtrip_structure() {
        let mut b = DendroBuilder::new(3);
        b.merge(0, 1, 1.0);
        b.merge(0, 2, 2.5);
        let d = b.finish();
        let nw = d.to_newick(None);
        assert_eq!(nw, "((v0:1,v1:1):1.5,v2:2.5);");
        let named = d.to_newick(Some(&["a".into(), "b".into(), "c".into()]));
        assert!(named.contains("a:1") && named.contains("c:2.5"));
        // balanced parens, single trailing semicolon
        assert_eq!(nw.matches('(').count(), nw.matches(')').count());
        assert!(nw.ends_with(';'));
    }

    #[test]
    fn newick_single_leaf_and_deep_chain() {
        let d = DendroBuilder::new(1).finish();
        assert_eq!(d.to_newick(None), "v0;");
        // deep path-shaped tree must not overflow the stack
        let deep = chain_dendro(5000);
        let nw = deep.to_newick(None);
        assert!(nw.ends_with(';'));
        assert_eq!(nw.matches('(').count(), 4999);
    }

    #[test]
    fn json_export() {
        let mut b = DendroBuilder::new(3);
        b.merge(0, 1, 1.0);
        b.merge(0, 2, 2.0);
        let j = b.finish().to_json();
        assert_eq!(j.get("n_leaves").as_usize(), Some(3));
        let merges = j.get("merges").as_arr().unwrap();
        assert_eq!(merges.len(), 2);
        let s = j.to_string();
        let back = crate::util::json::Json::parse(&s).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn cut_with_tied_heights() {
        let mut b = DendroBuilder::new(4);
        b.merge(0, 1, 1.0);
        b.merge(2, 3, 1.0);
        b.merge(0, 2, 1.0);
        let d = b.finish();
        for k in 1..=4 {
            let l = d.cut(k);
            let set: std::collections::HashSet<_> = l.iter().collect();
            assert_eq!(set.len(), k, "k={k}");
        }
    }
}
