//! TMFG edge-sum quality metric (Fig. 7): the sum of similarity weights
//! over the filtered graph's edges. Higher is better — the TMFG objective
//! is to (approximately) maximize this; the paper reports each parallel
//! method's percent *reduction* relative to PAR-TDBHT-1.

use crate::data::matrix::SimilarityLookup;

/// Sum of S[u,v] over the given undirected edge list. Generic over the
/// similarity store (dense matrix or sparse candidate graph).
pub fn edge_sum<S: SimilarityLookup + ?Sized>(s: &S, edges: &[(u32, u32)]) -> f64 {
    edges
        .iter()
        .map(|&(u, v)| s.sim(u as usize, v as usize) as f64)
        .sum()
}

/// Percent reduction of `sum` relative to `baseline_sum` (positive =
/// worse than baseline), as plotted in Fig. 7.
pub fn edge_sum_reduction_pct(baseline_sum: f64, sum: f64) -> f64 {
    if baseline_sum.abs() < 1e-12 {
        return 0.0;
    }
    100.0 * (baseline_sum - sum) / baseline_sum.abs()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::matrix::Matrix;

    #[test]
    fn sums_edges() {
        let s = Matrix::from_vec(3, 3, vec![1.0, 0.5, 0.2, 0.5, 1.0, 0.1, 0.2, 0.1, 1.0]);
        let e = vec![(0u32, 1u32), (1, 2)];
        assert!((edge_sum(&s, &e) - 0.6).abs() < 1e-6);
        assert_eq!(edge_sum(&s, &[]), 0.0);
    }

    #[test]
    fn reduction_pct() {
        assert!((edge_sum_reduction_pct(100.0, 99.0) - 1.0).abs() < 1e-12);
        assert!((edge_sum_reduction_pct(100.0, 101.0) + 1.0).abs() < 1e-12);
        assert_eq!(edge_sum_reduction_pct(0.0, 5.0), 0.0);
    }
}
