//! Evaluation metrics: Adjusted Rand Index (Fig. 6) and TMFG edge sums
//! (Fig. 7).

pub mod ari;
pub mod edgesum;

pub use ari::adjusted_rand_index;
pub use edgesum::{edge_sum, edge_sum_reduction_pct};
