//! Adjusted Rand Index [Hubert & Arabie, 1985] — the paper's clustering
//! quality metric (§5, Evaluation):
//!
//!   ARI = (Σ_ij C(n_ij,2) − [Σ_i C(a_i,2) Σ_j C(b_j,2)] / C(n,2))
//!       / (½[Σ_i C(a_i,2) + Σ_j C(b_j,2)] − [Σ_i C(a_i,2) Σ_j C(b_j,2)] / C(n,2))
//!
//! 1.0 = identical partitions; 0 expected for random assignments.

use std::collections::HashMap;

#[inline]
fn choose2(x: u64) -> f64 {
    (x as f64) * (x as f64 - 1.0) / 2.0
}

/// Compute the ARI between two partitions given as dense label vectors.
/// Labels need not be contiguous. Panics if lengths differ or inputs are
/// empty.
pub fn adjusted_rand_index(truth: &[usize], pred: &[usize]) -> f64 {
    assert_eq!(truth.len(), pred.len(), "partition length mismatch");
    assert!(!truth.is_empty(), "empty partitions");
    let n = truth.len() as u64;

    let mut joint: HashMap<(usize, usize), u64> = HashMap::new();
    let mut rows: HashMap<usize, u64> = HashMap::new();
    let mut cols: HashMap<usize, u64> = HashMap::new();
    for (&t, &p) in truth.iter().zip(pred) {
        *joint.entry((t, p)).or_insert(0) += 1;
        *rows.entry(t).or_insert(0) += 1;
        *cols.entry(p).or_insert(0) += 1;
    }

    let sum_ij: f64 = joint.values().map(|&c| choose2(c)).sum();
    let sum_i: f64 = rows.values().map(|&c| choose2(c)).sum();
    let sum_j: f64 = cols.values().map(|&c| choose2(c)).sum();
    let total = choose2(n);
    let expected = sum_i * sum_j / total.max(1.0);
    let max_index = 0.5 * (sum_i + sum_j);
    let denom = max_index - expected;
    if denom.abs() < 1e-12 {
        // Degenerate: both partitions are all-singletons or one cluster.
        return if (sum_i - sum_j).abs() < 1e-12 { 1.0 } else { 0.0 };
    }
    (sum_ij - expected) / denom
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn identical_partitions() {
        let a = vec![0, 0, 1, 1, 2, 2];
        assert!((adjusted_rand_index(&a, &a) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn relabelled_partitions_are_identical() {
        let a = vec![0, 0, 1, 1, 2, 2];
        let b = vec![7, 7, 3, 3, 9, 9];
        assert!((adjusted_rand_index(&a, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn known_value() {
        // Classic example: truth [0,0,0,1,1,1], pred [0,0,1,1,2,2]
        // contingency: rows a=(3,3), cols b=(2,2,2), nij = (2,1,0 / 0,1,2)
        // sum_ij = C(2,2)*2 + ... = 1+0+0+0+0+1 = 2
        // sum_i = 3+3 = 6, sum_j = 1+1+1 = 3, total = C(6,2)=15
        // expected = 6*3/15 = 1.2; max = 4.5; ari = (2-1.2)/(4.5-1.2) = 0.2424...
        let t = vec![0, 0, 0, 1, 1, 1];
        let p = vec![0, 0, 1, 1, 2, 2];
        let ari = adjusted_rand_index(&t, &p);
        assert!((ari - 0.8 / 3.3).abs() < 1e-9, "{ari}");
    }

    #[test]
    fn random_assignment_near_zero() {
        let mut r = Rng::new(13);
        let n = 20_000;
        let t: Vec<usize> = (0..n).map(|_| r.next_below(5)).collect();
        let p: Vec<usize> = (0..n).map(|_| r.next_below(5)).collect();
        let ari = adjusted_rand_index(&t, &p);
        assert!(ari.abs() < 0.02, "expected ≈0, got {ari}");
    }

    #[test]
    fn symmetry() {
        let mut r = Rng::new(17);
        let t: Vec<usize> = (0..500).map(|_| r.next_below(4)).collect();
        let p: Vec<usize> = (0..500).map(|_| r.next_below(3)).collect();
        let a = adjusted_rand_index(&t, &p);
        let b = adjusted_rand_index(&p, &t);
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn bounded_above_by_one() {
        let mut r = Rng::new(23);
        for _ in 0..50 {
            let n = 50 + r.next_below(100);
            let t: Vec<usize> = (0..n).map(|_| r.next_below(6)).collect();
            let p: Vec<usize> = (0..n).map(|_| r.next_below(6)).collect();
            let ari = adjusted_rand_index(&t, &p);
            assert!(ari <= 1.0 + 1e-12);
        }
    }

    #[test]
    fn degenerate_single_cluster() {
        let t = vec![0; 10];
        let p = vec![0; 10];
        assert!((adjusted_rand_index(&t, &p) - 1.0).abs() < 1e-12);
        let q: Vec<usize> = (0..10).collect();
        // all-singleton vs one-cluster: denominator 0, partitions differ
        assert_eq!(adjusted_rand_index(&t, &q), 0.0);
    }
}
