//! Exact APSP: one binary-heap Dijkstra per source, sources in parallel
//! (Yu & Shun's approach). Also provides the truncated single-source
//! variant the hub-based approximation uses.

use super::graph::CsrGraph;
use crate::data::matrix::Matrix;
use crate::parlay::{self, SendPtr};
use std::cmp::Ordering;
use std::collections::BinaryHeap;

#[derive(PartialEq)]
pub(crate) struct QItem {
    dist: f32,
    v: u32,
}

impl Eq for QItem {}

impl Ord for QItem {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap via reversed comparison
        other.dist.total_cmp(&self.dist).then(other.v.cmp(&self.v))
    }
}

impl PartialOrd for QItem {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Single-source shortest paths; unreachable vertices get `f32::INFINITY`.
pub fn sssp(g: &CsrGraph, src: u32) -> Vec<f32> {
    let mut dist = vec![f32::INFINITY; g.n];
    sssp_into(g, src, f32::INFINITY, &mut dist);
    dist
}

/// Truncated SSSP: stops once the frontier distance exceeds `radius`.
/// `dist` must be pre-filled with INFINITY; entries settled within the
/// radius are written. Returns the number of settled vertices.
pub fn sssp_into(g: &CsrGraph, src: u32, radius: f32, dist: &mut [f32]) -> usize {
    let mut heap = BinaryHeap::with_capacity(64);
    sssp_into_heap(g, src, radius, dist, &mut heap)
}

/// [`sssp_into`] with a caller-owned heap, so a loop over many sources
/// reuses one allocation (§Perf L3 pattern: per-chunk scratch, not
/// per-source). The heap is drained on return.
pub(crate) fn sssp_into_heap(
    g: &CsrGraph,
    src: u32,
    radius: f32,
    dist: &mut [f32],
    heap: &mut BinaryHeap<QItem>,
) -> usize {
    debug_assert_eq!(dist.len(), g.n);
    heap.clear();
    dist[src as usize] = 0.0;
    heap.push(QItem { dist: 0.0, v: src });
    let mut settled = 0usize;
    while let Some(QItem { dist: d, v }) = heap.pop() {
        if d > dist[v as usize] {
            continue; // stale entry
        }
        if d > radius {
            // everything beyond the radius stays INFINITY (to be restored
            // by the caller); mark it back to avoid partial values
            dist[v as usize] = f32::INFINITY;
            continue;
        }
        settled += 1;
        for (u, w) in g.neighbors(v) {
            let nd = d + w;
            if nd < dist[u as usize] {
                dist[u as usize] = nd;
                heap.push(QItem { dist: nd, v: u });
            }
        }
    }
    // Clean tentative (never settled, beyond radius) entries.
    if radius.is_finite() {
        for x in dist.iter_mut() {
            if *x > radius {
                *x = f32::INFINITY;
            }
        }
    }
    settled
}

/// Sparse truncated SSSP for small balls (§Perf L3 iter. 3): like
/// [`sssp_into`] but records every touched vertex in `touched` and does
/// NOT do an O(n) cleanup pass — the caller filters `touched` by radius
/// and resets only those entries, making per-source cost proportional to
/// the ball size rather than to n. `dist` must be all-INFINITY on entry;
/// it is left dirty (reset it via `touched`).
pub fn sssp_ball(
    g: &CsrGraph,
    src: u32,
    radius: f32,
    dist: &mut [f32],
    touched: &mut Vec<u32>,
) {
    let mut heap = BinaryHeap::with_capacity(64);
    dist[src as usize] = 0.0;
    touched.push(src);
    heap.push(QItem { dist: 0.0, v: src });
    while let Some(QItem { dist: d, v }) = heap.pop() {
        if d > dist[v as usize] || d > radius {
            continue;
        }
        for (u, w) in g.neighbors(v) {
            let nd = d + w;
            if nd < dist[u as usize] {
                if dist[u as usize].is_infinite() {
                    touched.push(u);
                }
                dist[u as usize] = nd;
                heap.push(QItem { dist: nd, v: u });
            }
        }
    }
}

/// Exact APSP as a dense n×n matrix: parallel over sources, each source
/// settling distances directly into its output row (no per-source
/// scratch allocation — §Perf L3 iteration 1). Sources run in chunks so
/// the Dijkstra heap is allocated once per chunk and reused, mirroring
/// the truncated-ball scratch reuse in `apsp_hub` (§Perf L3 iter. 3).
pub fn apsp_exact(g: &CsrGraph) -> Matrix {
    let n = g.n;
    let mut out = Matrix::zeros(n, n);
    let op = SendPtr(out.data.as_mut_ptr());
    parlay::parallel_for_chunks(n, 4, |lo, hi| {
        let mut heap = BinaryHeap::with_capacity(256);
        for src in lo..hi {
            // SAFETY: row `src` written only by this iteration.
            let row = unsafe { std::slice::from_raw_parts_mut(op.ptr().add(src * n), n) };
            row.fill(f32::INFINITY);
            sssp_into_heap(g, src as u32, f32::INFINITY, row, &mut heap);
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn line_graph(n: usize) -> CsrGraph {
        let edges: Vec<(u32, u32, f32)> =
            (0..n - 1).map(|i| (i as u32, i as u32 + 1, 1.0)).collect();
        CsrGraph::from_edges(n, &edges)
    }

    #[test]
    fn sssp_on_line() {
        let g = line_graph(10);
        let d = sssp(&g, 0);
        for (i, &x) in d.iter().enumerate() {
            assert!((x - i as f32).abs() < 1e-6);
        }
    }

    #[test]
    fn sssp_disconnected() {
        let g = CsrGraph::from_edges(4, &[(0, 1, 1.0)]);
        let d = sssp(&g, 0);
        assert_eq!(d[1], 1.0);
        assert!(d[2].is_infinite() && d[3].is_infinite());
    }

    #[test]
    fn truncated_respects_radius() {
        let g = line_graph(20);
        let mut dist = vec![f32::INFINITY; 20];
        let settled = sssp_into(&g, 0, 5.0, &mut dist);
        assert_eq!(settled, 6); // vertices 0..=5
        for i in 0..20 {
            if i <= 5 {
                assert!((dist[i] - i as f32).abs() < 1e-6);
            } else {
                assert!(dist[i].is_infinite());
            }
        }
    }

    fn floyd_warshall(g: &CsrGraph) -> Vec<Vec<f32>> {
        let n = g.n;
        let mut d = vec![vec![f32::INFINITY; n]; n];
        for v in 0..n {
            d[v][v] = 0.0;
            for (u, w) in g.neighbors(v as u32) {
                if w < d[v][u as usize] {
                    d[v][u as usize] = w;
                }
            }
        }
        for k in 0..n {
            for i in 0..n {
                for j in 0..n {
                    let alt = d[i][k] + d[k][j];
                    if alt < d[i][j] {
                        d[i][j] = alt;
                    }
                }
            }
        }
        d
    }

    #[test]
    fn apsp_matches_floyd_warshall_random() {
        let mut r = Rng::new(31);
        for trial in 0..10 {
            let n = 5 + r.next_below(40);
            // random connected-ish graph: spanning path + extra edges
            let mut edges: Vec<(u32, u32, f32)> = (0..n - 1)
                .map(|i| (i as u32, i as u32 + 1, r.next_f32() + 0.01))
                .collect();
            for _ in 0..n {
                let u = r.next_below(n) as u32;
                let v = r.next_below(n) as u32;
                if u != v {
                    edges.push((u, v, r.next_f32() + 0.01));
                }
            }
            let g = CsrGraph::from_edges(n, &edges);
            let exact = apsp_exact(&g);
            let fw = floyd_warshall(&g);
            for i in 0..n {
                for j in 0..n {
                    assert!(
                        (exact.at(i, j) - fw[i][j]).abs() < 1e-4,
                        "trial {trial} ({i},{j}): {} vs {}",
                        exact.at(i, j),
                        fw[i][j]
                    );
                }
            }
        }
    }

    #[test]
    fn apsp_symmetric_zero_diag() {
        let g = line_graph(30);
        let m = apsp_exact(&g);
        assert!(m.is_symmetric(1e-6));
        for i in 0..30 {
            assert_eq!(m.at(i, i), 0.0);
        }
    }
}
