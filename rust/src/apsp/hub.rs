//! Approximate hub-based APSP (§4.3).
//!
//! 1. Choose `h` hub vertices (a deterministic stratified sample).
//! 2. Run exact Dijkstra from every hub (h × n distances).
//! 3. Run a *truncated* Dijkstra from every vertex `u`, with radius
//!    `α · d(u, nearest hub)` — the exact local ball.
//! 4. d̂(u,v) = exact if `v` is inside `u`'s ball; otherwise
//!    `min over u's q nearest hubs H of d(u,H) + d(H,v)`.
//!
//! The estimate is exact within balls, and an upper bound (triangle
//! inequality) elsewhere. The paper chose its parameters "arbitrarily"
//! and reports a 2–3× APSP speedup at unchanged clustering accuracy; we
//! expose them in [`HubConfig`].

use super::dijkstra::sssp;
use super::graph::CsrGraph;
use crate::data::matrix::Matrix;
use crate::parlay::{self, SendPtr};

#[derive(Debug, Clone)]
pub struct HubConfig {
    /// Number of hubs; 0 = auto (⌈√n⌉ clamped to [4, 64]).
    pub n_hubs: usize,
    /// Ball radius multiplier α (radius = α · distance to nearest hub).
    pub radius_mult: f32,
    /// Number of nearest hubs considered per source for far pairs.
    pub hubs_per_vertex: usize,
}

impl Default for HubConfig {
    fn default() -> Self {
        HubConfig { n_hubs: 0, radius_mult: 2.0, hubs_per_vertex: 4 }
    }
}

pub(crate) fn pick_hubs(n: usize, h: usize) -> Vec<u32> {
    // Deterministic stratified pick: evenly spaced vertex ids. Vertex ids
    // carry no geometric meaning, so this is a uniform sample.
    let h = h.min(n).max(1);
    (0..h).map(|i| ((i * n) / h) as u32).collect()
}

/// The hub count a config resolves to on an n-vertex graph.
pub(crate) fn resolve_hub_count(n: usize, cfg: &HubConfig) -> usize {
    if cfg.n_hubs == 0 {
        ((n as f64).sqrt().ceil() as usize).clamp(4, 64).min(n)
    } else {
        cfg.n_hubs.min(n)
    }
}

/// Exact distances from each hub (parallel over hubs), flattened h×n.
pub(crate) fn compute_hub_rows(g: &CsrGraph, hubs: &[u32]) -> Vec<f32> {
    let rows: Vec<Vec<f32>> = parlay::par_map(hubs.len(), 1, |k| sssp(g, hubs[k]));
    rows.into_iter().flatten().collect()
}

/// Per vertex: its q nearest hubs (by hub distance, stable over hub
/// index on ties), flattened n×q. Shared by the dense [`apsp_hub`] and
/// the [`super::oracle::HubOracle`] so their estimates agree
/// bit-for-bit.
pub(crate) fn compute_nearest_hubs(
    hub_rows: &[f32],
    n: usize,
    q: usize,
) -> Vec<(f32, u32)> {
    let h = if n == 0 { 0 } else { hub_rows.len() / n };
    let per: Vec<Vec<(f32, u32)>> = parlay::par_map(n, 64, |u| {
        let mut hd: Vec<(f32, u32)> =
            (0..h).map(|k| (hub_rows[k * n + u], k as u32)).collect();
        hd.sort_by(|a, b| a.0.total_cmp(&b.0));
        hd.truncate(q);
        hd
    });
    per.into_iter().flatten().collect()
}

/// The far-pair upper-bound row: `out[v] = min over near hubs H of
/// d(·,H) + d(H,v)` — assign from the nearest hub, fold `min` over the
/// rest. The one implementation behind both [`apsp_hub`]'s row pass and
/// [`super::oracle::HubOracle::row_into`], so their bit-identity holds
/// by construction rather than by manual sync.
pub(crate) fn hub_bound_row(near: &[(f32, u32)], hub_rows: &[f32], n: usize, out: &mut [f32]) {
    let (d0, k0) = near[0];
    let h0 = &hub_rows[k0 as usize * n..(k0 as usize + 1) * n];
    for v in 0..n {
        out[v] = d0 + h0[v];
    }
    for &(d, k) in &near[1..] {
        let hr = &hub_rows[k as usize * n..(k as usize + 1) * n];
        for v in 0..n {
            out[v] = out[v].min(d + hr[v]);
        }
    }
}

/// Approximate APSP as a dense n×n matrix.
pub fn apsp_hub(g: &CsrGraph, cfg: &HubConfig) -> Matrix {
    let n = g.n;
    let h = resolve_hub_count(n, cfg);
    let hubs = pick_hubs(n, h);
    let hub_rows = compute_hub_rows(g, &hubs);
    let q = cfg.hubs_per_vertex.clamp(1, h);
    let nearest = compute_nearest_hubs(&hub_rows, n, q);

    let mut out = Matrix::zeros(n, n);
    let op = SendPtr(out.data.as_mut_ptr());
    let hub_rows_ref = &hub_rows;
    let nearest_ref = &nearest;
    // Chunked over sources so the truncated-Dijkstra scratch (dist array +
    // touched list) is reused across a chunk and reset sparsely — per-source
    // cost proportional to ball size, not n (§Perf L3 iter. 3).
    parlay::parallel_for_chunks(n, 4, |lo, hi| {
        let mut dist = vec![f32::INFINITY; n];
        let mut touched: Vec<u32> = Vec::with_capacity(256);
        for u in lo..hi {
            let near = &nearest_ref[u * q..(u + 1) * q];
            let d_hub0 = near[0].0;
            // Far-pair estimate through the q nearest hubs: one unit-stride
            // pass per hub row (auto-vectorizable min).
            let row_out = unsafe { std::slice::from_raw_parts_mut(op.ptr().add(u * n), n) };
            hub_bound_row(near, hub_rows_ref, n, row_out);
            // Exact ball overwrite (sparse reset).
            let radius = if d_hub0.is_finite() {
                cfg.radius_mult * d_hub0
            } else {
                f32::INFINITY
            };
            super::dijkstra::sssp_ball(g, u as u32, radius, &mut dist, &mut touched);
            for &v in &touched {
                let dv = dist[v as usize];
                if dv <= radius {
                    row_out[v as usize] = dv;
                }
                dist[v as usize] = f32::INFINITY;
            }
            touched.clear();
            row_out[u] = 0.0;
        }
    });

    // Symmetrize (the hub estimate is not perfectly symmetric because the
    // per-source hub subsets differ): take the elementwise min, which can
    // only tighten the upper bound. Tiled B×B so the transposed accesses
    // stay cache-resident (§Perf L3 iter. 4). All access goes through one
    // raw pointer — a shared `&out.data` alongside `SendPtr` writes to
    // the same buffer would be UB under the aliasing rules. Each
    // unordered cell pair (i,j)/(j,i) belongs to exactly one (bi, bj)
    // block pair with bi ≤ bj, handled by task bi alone, so no cell is
    // read or written by two tasks.
    const B: usize = 64;
    let op2 = SendPtr(out.data.as_mut_ptr());
    let nblk = n.div_ceil(B);
    parlay::parallel_for(nblk, 1, |bi| {
        let i0 = bi * B;
        let i1 = (i0 + B).min(n);
        for bj in bi..nblk {
            let j0 = bj * B;
            let j1 = (j0 + B).min(n);
            for i in i0..i1 {
                let jstart = if bi == bj { i + 1 } else { j0 };
                for j in jstart..j1 {
                    unsafe {
                        let m = op2.read(i * n + j).min(op2.read(j * n + i));
                        op2.write(i * n + j, m);
                        op2.write(j * n + i, m);
                    }
                }
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apsp::dijkstra::apsp_exact;
    use crate::data::synth::SynthSpec;
    use crate::util::rng::Rng;

    fn tmfg_graph(n: usize, seed: u64) -> CsrGraph {
        let ds = SynthSpec::new("t", n, 48, 3).generate(seed);
        let s = crate::data::corr::pearson_correlation(&ds.data);
        let r = crate::tmfg::heap_tmfg(&s, &Default::default()).unwrap();
        CsrGraph::from_tmfg(&r, &s)
    }

    #[test]
    fn hub_is_upper_bound_and_exact_in_ball() {
        let g = tmfg_graph(120, 5);
        let exact = apsp_exact(&g);
        let approx = apsp_hub(&g, &HubConfig::default());
        let mut max_rel = 0.0f64;
        for i in 0..g.n {
            for j in 0..g.n {
                let e = exact.at(i, j);
                let a = approx.at(i, j);
                assert!(
                    a >= e - 1e-4,
                    "approx must upper-bound exact at ({i},{j}): {a} < {e}"
                );
                if e > 1e-6 {
                    max_rel = max_rel.max(((a - e) / e) as f64);
                }
            }
        }
        // With α=2 balls + 4 hubs the stretch should be modest on a TMFG.
        assert!(max_rel < 1.0, "max relative stretch {max_rel}");
    }

    #[test]
    fn hub_zero_diag_symmetric() {
        let g = tmfg_graph(80, 6);
        let m = apsp_hub(&g, &HubConfig::default());
        for i in 0..g.n {
            assert_eq!(m.at(i, i), 0.0);
        }
        assert!(m.is_symmetric(1e-5));
    }

    #[test]
    fn more_hubs_tighter() {
        let g = tmfg_graph(150, 7);
        let exact = apsp_exact(&g);
        let err = |m: &Matrix| {
            let mut s = 0.0f64;
            for i in 0..g.n {
                for j in 0..g.n {
                    s += (m.at(i, j) - exact.at(i, j)).max(0.0) as f64;
                }
            }
            s
        };
        let few = apsp_hub(&g, &HubConfig { n_hubs: 4, hubs_per_vertex: 2, radius_mult: 1.0 });
        let many = apsp_hub(&g, &HubConfig { n_hubs: 32, hubs_per_vertex: 8, radius_mult: 1.0 });
        assert!(err(&many) <= err(&few) + 1e-3, "{} vs {}", err(&many), err(&few));
    }

    #[test]
    fn exact_when_hubs_cover_everything() {
        // n_hubs = n → every vertex is a hub → estimate must be exact.
        let mut r = Rng::new(8);
        let n = 30;
        let mut edges: Vec<(u32, u32, f32)> =
            (0..n - 1).map(|i| (i as u32, i as u32 + 1, r.next_f32() + 0.1)).collect();
        edges.push((0, (n - 1) as u32, 0.5));
        let g = CsrGraph::from_edges(n, &edges);
        let exact = apsp_exact(&g);
        let approx = apsp_hub(
            &g,
            &HubConfig { n_hubs: n, hubs_per_vertex: n, radius_mult: 0.0 },
        );
        assert!(exact.max_abs_diff(&approx) < 1e-5);
    }

    #[test]
    fn pick_hubs_distinct_in_range() {
        let hubs = pick_hubs(100, 10);
        assert_eq!(hubs.len(), 10);
        let set: std::collections::HashSet<_> = hubs.iter().collect();
        assert_eq!(set.len(), 10);
        assert!(hubs.iter().all(|&h| h < 100));
        assert_eq!(pick_hubs(3, 10).len(), 3);
    }
}
