//! CSR adjacency for the (sparse, planar) TMFG.

use crate::data::corr::corr_to_distance;
use crate::data::matrix::SimilarityLookup;
use crate::tmfg::TmfgResult;

/// Compressed sparse row graph with f32 edge lengths.
#[derive(Debug, Clone)]
pub struct CsrGraph {
    pub n: usize,
    pub offsets: Vec<u32>,
    pub targets: Vec<u32>,
    pub weights: Vec<f32>,
}

impl CsrGraph {
    /// Build from an undirected edge list with explicit weights.
    pub fn from_edges(n: usize, edges: &[(u32, u32, f32)]) -> CsrGraph {
        let mut deg = vec![0u32; n];
        for &(u, v, _) in edges {
            deg[u as usize] += 1;
            deg[v as usize] += 1;
        }
        let mut offsets = vec![0u32; n + 1];
        for i in 0..n {
            offsets[i + 1] = offsets[i] + deg[i];
        }
        let m = offsets[n] as usize;
        let mut targets = vec![0u32; m];
        let mut weights = vec![0f32; m];
        let mut cursor: Vec<u32> = offsets[..n].to_vec();
        for &(u, v, w) in edges {
            let cu = cursor[u as usize] as usize;
            targets[cu] = v;
            weights[cu] = w;
            cursor[u as usize] += 1;
            let cv = cursor[v as usize] as usize;
            targets[cv] = u;
            weights[cv] = w;
            cursor[v as usize] += 1;
        }
        CsrGraph { n, offsets, targets, weights }
    }

    /// Build from a TMFG result, with edge lengths d = √(2(1−S[u,v])).
    /// Generic over the similarity store: with a sparse candidate graph,
    /// an edge the construction inserted via dense fallback (no stored
    /// similarity) gets the missing-entry weight √2 — finite, so APSP
    /// runs unchanged.
    pub fn from_tmfg<S: SimilarityLookup + ?Sized>(r: &TmfgResult, s: &S) -> CsrGraph {
        let edges: Vec<(u32, u32, f32)> = r
            .edges
            .iter()
            .map(|&(u, v)| (u, v, corr_to_distance(s.sim(u as usize, v as usize))))
            .collect();
        Self::from_edges(r.n, &edges)
    }

    #[inline]
    pub fn neighbors(&self, v: u32) -> impl Iterator<Item = (u32, f32)> + '_ {
        let lo = self.offsets[v as usize] as usize;
        let hi = self.offsets[v as usize + 1] as usize;
        self.targets[lo..hi]
            .iter()
            .copied()
            .zip(self.weights[lo..hi].iter().copied())
    }

    #[inline]
    pub fn degree(&self, v: u32) -> usize {
        (self.offsets[v as usize + 1] - self.offsets[v as usize]) as usize
    }

    pub fn n_edges(&self) -> usize {
        self.targets.len() / 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_csr() {
        // path 0-1-2 plus edge 0-2
        let g = CsrGraph::from_edges(3, &[(0, 1, 1.0), (1, 2, 2.0), (0, 2, 5.0)]);
        assert_eq!(g.n_edges(), 3);
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.degree(1), 2);
        let n0: Vec<(u32, f32)> = g.neighbors(0).collect();
        assert!(n0.contains(&(1, 1.0)) && n0.contains(&(2, 5.0)));
    }

    #[test]
    fn from_tmfg_planar_counts() {
        use crate::data::synth::SynthSpec;
        let ds = SynthSpec::new("t", 50, 48, 3).generate(2);
        let s = crate::data::corr::pearson_correlation(&ds.data);
        let r = crate::tmfg::heap_tmfg(&s, &Default::default()).unwrap();
        let g = CsrGraph::from_tmfg(&r, &s);
        assert_eq!(g.n, 50);
        assert_eq!(g.n_edges(), 3 * 50 - 6);
        // all weights in [0, 2] (valid correlation distances)
        assert!(g.weights.iter().all(|&w| (0.0..=2.0 + 1e-6).contains(&w)));
    }

    #[test]
    fn isolated_vertices_ok() {
        let g = CsrGraph::from_edges(4, &[(0, 1, 1.0)]);
        assert_eq!(g.degree(2), 0);
        assert_eq!(g.degree(3), 0);
    }
}
